//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): exercises every layer of the
//! stack on a real small workload and proves they compose:
//!
//!   L1 Pallas kernels -> L2 JAX graph -> `make artifacts` (AOT HLO) ->
//!   Rust PJRT runtime -> coordinator routing -> tree engine cross-check ->
//!   single-linkage -> quality metrics.
//!
//! Workload: a batch of clustering requests over integer-grid check-in-like
//! data (so f32/f64 agree bit-exactly), served through the coordinator with
//! per-request routing; reports per-backend latency/throughput and verifies
//! label agreement (ARI == 1) between the XLA and tree backends.
//!
//! ```sh
//! make artifacts && cargo run --release --example compare_backends
//! ```

use std::sync::Arc;
use std::time::Instant;

use parcluster::bench::fmt_secs;
use parcluster::coordinator::{Backend, ClusterJob, Coordinator, CoordinatorConfig};
use parcluster::dpc::DpcParams;
use parcluster::geom::PointSet;
use parcluster::metrics::adjusted_rand_index;
use parcluster::prng::SplitMix64;

/// Check-in-like integer workload: a few dense "city" blocks plus uniform
/// background, all on an integer grid.
fn workload(seed: u64, n: usize) -> PointSet {
    let mut rng = SplitMix64::new(seed);
    let mut coords = Vec::with_capacity(n * 2);
    let cities = [(100i64, 100i64), (400, 120), (250, 420)];
    for _ in 0..n {
        if rng.next_f64() < 0.8 {
            let (cx, cy) = cities[rng.next_below(3) as usize];
            coords.push((cx + rng.next_below(40) as i64) as f64);
            coords.push((cy + rng.next_below(40) as i64) as f64);
        } else {
            coords.push(rng.next_below(512) as f64);
            coords.push(rng.next_below(512) as f64);
        }
    }
    PointSet::new(coords, 2)
}

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::start(CoordinatorConfig::default())?;
    if !coord.has_xla() {
        eprintln!("XLA backend unavailable — run `make artifacts` first.");
        std::process::exit(2);
    }
    let params = DpcParams { d_cut: 6.0, rho_min: 3.0, delta_min: 60.0, ..DpcParams::default() };
    let n_requests = 24;
    let n_points = 2_000;
    println!("E2E: {n_requests} clustering requests x {n_points} points, both backends\n");

    let mut total = (0.0f64, 0.0f64);
    let mut agree = 0usize;
    let mut clusters = Vec::new();
    let t_all = Instant::now();
    for r in 0..n_requests {
        let pts = Arc::new(workload(1000 + r as u64, n_points));
        let xla = coord
            .run_sync(ClusterJob::new(Arc::clone(&pts), params).backend(Backend::XlaBruteForce).tag("xla"))
            .map_err(|e| anyhow::anyhow!(e))?;
        let tree = coord
            .run_sync(ClusterJob::new(Arc::clone(&pts), params).backend(Backend::TreeExact).tag("tree"))
            .map_err(|e| anyhow::anyhow!(e))?;
        assert_eq!(xla.backend_used, Backend::XlaBruteForce);
        assert_eq!(tree.backend_used, Backend::TreeExact);
        let ari = adjusted_rand_index(&xla.result.labels, &tree.result.labels);
        if ari == 1.0 && xla.result.rho == tree.result.rho && xla.result.dep == tree.result.dep {
            agree += 1;
        } else {
            println!("request {r}: DISAGREEMENT (ari={ari})");
        }
        total.0 += xla.wall_s;
        total.1 += tree.wall_s;
        clusters.push(tree.result.num_clusters);
    }
    let wall = t_all.elapsed().as_secs_f64();

    println!("requests            : {n_requests} ({} points each)", n_points);
    println!("exact agreement     : {agree}/{n_requests} (rho, dep, labels via ARI=1)");
    println!("clusters per request: {:?}", &clusters[..6.min(clusters.len())]);
    println!("xla  backend        : total {}  mean latency {}", fmt_secs(total.0), fmt_secs(total.0 / n_requests as f64));
    println!("tree backend        : total {}  mean latency {}", fmt_secs(total.1), fmt_secs(total.1 / n_requests as f64));
    println!(
        "throughput          : {:.0} points/s end-to-end (both backends, {} requests)",
        (2 * n_requests * n_points) as f64 / wall,
        2 * n_requests
    );
    println!("\nservice metrics:\n{}", coord.metrics.render());

    if agree != n_requests {
        anyhow::bail!("backends disagreed on {} requests", n_requests - agree);
    }
    println!("E2E OK: all layers compose; XLA and tree backends are bit-identical on this workload.");
    Ok(())
}
