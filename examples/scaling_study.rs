//! Scaling study (a compact, example-sized version of Figure 4a): runtime of
//! the five exact algorithms as n grows on `simden`, with fitted log-log
//! slopes. The full bench is `cargo bench --bench fig4a_scaling`.
//!
//! ```sh
//! cargo run --release --example scaling_study
//! ```

use parcluster::bench::{fmt_secs, loglog_slope, time_once, Table};
use parcluster::datasets::synthetic;
use parcluster::dpc::{Dpc, DepAlgo, DpcParams};

fn main() {
    let sizes = [1_000usize, 4_000, 16_000, 64_000];
    let algos = [DepAlgo::ExactBaseline, DepAlgo::Incomplete, DepAlgo::Fenwick, DepAlgo::Priority];
    let params = DpcParams { d_cut: 30.0, rho_min: 0.0, delta_min: 100.0, ..DpcParams::default() };

    let mut table = Table::new(&["algo", "n=1e3", "n=4e3", "n=1.6e4", "n=6.4e4", "slope"]);
    for algo in algos {
        let mut times = Vec::new();
        for &n in &sizes {
            let pts = synthetic::simden(n, 2, 42);
            let (secs, out) = time_once(|| Dpc::new(params).dep_algo(algo).run(&pts).expect("cluster"));
            assert!(out.num_clusters >= 1);
            times.push(secs);
        }
        let slope = loglog_slope(&sizes.iter().map(|&n| n as f64).collect::<Vec<_>>(), &times);
        let mut row = vec![algo.name().to_string()];
        row.extend(times.iter().map(|&t| fmt_secs(t)));
        row.push(format!("{slope:.2}"));
        table.row(row);
    }
    println!("simden total runtime (seconds) vs n — paper Figure 4a shape:");
    println!("(expect: priority's slope ~<= 1, exact-baseline clearly steeper)");
    table.print();
}
