//! Quickstart: generate a synthetic dataset, run exact DPC with the
//! priority search kd-tree, and inspect the result.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use parcluster::datasets::synthetic;
use parcluster::dpc::{Dpc, DepAlgo, DpcParams};

fn main() {
    // 50k points from the paper's `simden` generator (10 similar-density
    // random-walk clusters in 2-d).
    let pts = synthetic::simden(50_000, 2, 42);

    // Table-2 hyper-parameters for the synthetic family.
    let params = DpcParams { d_cut: 30.0, rho_min: 0.0, delta_min: 100.0 };

    // DPC-PRIORITY: the paper's fastest algorithm (Algorithm 1).
    let out = Dpc::new(params).dep_algo(DepAlgo::Priority).run(&pts).expect("well-formed input");

    println!("points    : {}", pts.len());
    println!("clusters  : {}", out.num_clusters);
    println!("noise     : {}", out.num_noise);
    println!(
        "timings   : density {:.3}s, dependent points {:.3}s, linkage {:.3}s",
        out.timings.density_s, out.timings.dep_s, out.timings.linkage_s
    );

    // Cluster sizes (top 10).
    let mut sizes: std::collections::HashMap<i64, usize> = std::collections::HashMap::new();
    for &l in &out.labels {
        if l >= 0 {
            *sizes.entry(l).or_insert(0) += 1;
        }
    }
    let mut sizes: Vec<(i64, usize)> = sizes.into_iter().collect();
    sizes.sort_by_key(|&(_, s)| std::cmp::Reverse(s));
    println!("largest clusters (center id: size):");
    for (center, size) in sizes.iter().take(10) {
        println!("  {center:>8}: {size}");
    }
}
