//! Quickstart: generate a synthetic dataset, run exact DPC with the
//! priority search kd-tree, and inspect the result — then run the same
//! pipeline on an `f32` store through the precision-generic data API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use parcluster::datasets::synthetic;
use parcluster::dpc::{Dpc, DepAlgo, DpcParams};
use parcluster::geom::PointStore;

fn main() {
    // 50k points from the paper's `simden` generator (10 similar-density
    // random-walk clusters in 2-d). `pts` is a PointStore<f64> (the
    // `PointSet` alias): its coordinates live in one shared Arc buffer.
    let pts = synthetic::simden(50_000, 2, 42);

    // Table-2 hyper-parameters for the synthetic family.
    let params = DpcParams { d_cut: 30.0, rho_min: 0.0, delta_min: 100.0, ..DpcParams::default() };

    // DPC-PRIORITY: the paper's fastest algorithm (Algorithm 1). Every
    // index built inside pins `pts` by refcount — no coordinate copies.
    let out = Dpc::new(params).dep_algo(DepAlgo::Priority).run(&pts).expect("well-formed input");

    println!("points    : {}", pts.len());
    println!("clusters  : {}", out.num_clusters);
    println!("noise     : {}", out.num_noise);
    println!(
        "timings   : density {:.3}s, dependent points {:.3}s, linkage {:.3}s",
        out.timings.density_s, out.timings.dep_s, out.timings.linkage_s
    );

    // The same pipeline, single precision: half the coordinate bandwidth on
    // every tree traversal. The cast rounds (this dataset is not integer-
    // valued), so cluster counts may differ slightly from f64 — on
    // f32-lossless data they are byte-identical (see the conformance
    // suite).
    let pts32 = PointStore::<f32>::cast_from_f64(&pts);
    let out32 = Dpc::new(params).dep_algo(DepAlgo::Priority).run(&pts32).expect("well-formed input");
    println!(
        "f32 run   : {} clusters, {} noise (density {:.3}s, dep {:.3}s)",
        out32.num_clusters, out32.num_noise, out32.timings.density_s, out32.timings.dep_s
    );

    // Cluster sizes (top 10).
    let mut sizes: std::collections::HashMap<i64, usize> = std::collections::HashMap::new();
    for &l in &out.labels {
        if l >= 0 {
            *sizes.entry(l).or_insert(0) += 1;
        }
    }
    let mut sizes: Vec<(i64, usize)> = sizes.into_iter().collect();
    sizes.sort_by_key(|&(_, s)| std::cmp::Reverse(s));
    println!("largest clusters (center id: size):");
    for (center, size) in sizes.iter().take(10) {
        println!("  {center:>8}: {size}");
    }
}
