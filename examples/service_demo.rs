//! Coordinator service demo: start the service, submit a mixed batch of
//! clustering jobs (different datasets, algorithms, and backends), and
//! report per-job results plus service metrics.
//!
//! ```sh
//! make artifacts && cargo run --release --example service_demo
//! ```

use std::sync::Arc;

use parcluster::bench::fmt_secs;
use parcluster::coordinator::{Backend, ClusterJob, Coordinator, CoordinatorConfig};
use parcluster::datasets;
use parcluster::dpc::DepAlgo;

fn main() -> anyhow::Result<()> {
    let cfg = CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() };
    let coord = Coordinator::start(cfg)?;
    println!("coordinator up: workers={}, xla_backend={}", coord.config().workers, coord.has_xla());

    // A mixed batch: small jobs (XLA-eligible under Auto), large jobs
    // (tree), explicit algorithm choices.
    let mut ids = Vec::new();
    for (name, n, algo, backend) in [
        ("query", 1_500usize, DepAlgo::Priority, Backend::Auto),
        ("gowalla", 1_000, DepAlgo::Fenwick, Backend::Auto),
        ("simden", 30_000, DepAlgo::Priority, Backend::Auto),
        ("uniform", 20_000, DepAlgo::Fenwick, Backend::TreeExact),
        ("varden", 15_000, DepAlgo::Incomplete, Backend::TreeExact),
        ("pamap2", 1_024, DepAlgo::Priority, Backend::Auto),
    ] {
        let ds = datasets::by_name(name, Some(n), 42).expect("dataset");
        let job = ClusterJob::new(Arc::new(ds.pts), ds.params).dep_algo(algo).backend(backend).tag(name);
        ids.push(coord.submit(job));
    }
    println!("submitted {} jobs\n", ids.len());

    println!(
        "{:<10} {:>8} {:>9} {:>8} {:>8} {:>10}",
        "dataset", "backend", "clusters", "noise", "wall", "algo"
    );
    for id in ids {
        match coord.wait(id) {
            Ok(out) => println!(
                "{:<10} {:>8} {:>9} {:>8} {:>8} {:>10}",
                out.tag,
                out.backend_used.name(),
                out.result.num_clusters,
                out.result.num_noise,
                fmt_secs(out.wall_s),
                "-"
            ),
            Err(e) => println!("job {id} FAILED: {e}"),
        }
    }

    println!("\nservice metrics:\n{}", coord.metrics.render());
    Ok(())
}
