//! §Perf probe: hot-path timings used for the optimization log in
//! EXPERIMENTS.md (density query loop, priority-NN loop, kd builds).
use parcluster::datasets::{by_name, synthetic};
use parcluster::dpc::{compute_density, dep, DensityAlgo};
use parcluster::kdtree::KdTree;
use parcluster::pskd::PriorityKdTree;
use parcluster::dpc::priority_key;
use std::time::Instant;

fn med3<F: FnMut() -> f64>(mut f: F) -> f64 {
    let mut v = [f(), f(), f()];
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[1]
}

fn main() {
    // 2-d large
    let pts = synthetic::simden(300_000, 2, 42);
    println!("kd build 300k 2d: {:.3}s", med3(|| { let t = Instant::now(); std::hint::black_box(KdTree::build(&pts)); t.elapsed().as_secs_f64() }));
    println!("density 300k 2d: {:.3}s", med3(|| { let t = Instant::now(); std::hint::black_box(compute_density(&pts, 30.0, DensityAlgo::TreePruned)); t.elapsed().as_secs_f64() }));
    let rho = compute_density(&pts, 30.0, DensityAlgo::TreePruned);
    let gamma: Vec<u64> = rho.iter().enumerate().map(|(i,&r)| priority_key(r, i as u32)).collect();
    println!("pskd build 300k 2d: {:.3}s", med3(|| { let t = Instant::now(); std::hint::black_box(PriorityKdTree::build(&pts, &gamma)); t.elapsed().as_secs_f64() }));
    println!("dep priority 300k 2d: {:.3}s", med3(|| { let t = Instant::now(); std::hint::black_box(dep::dep_priority(&pts, &rho, 0.0)); t.elapsed().as_secs_f64() }));
    println!("dep fenwick 300k 2d: {:.3}s", med3(|| { let t = Instant::now(); std::hint::black_box(dep::dep_fenwick(&pts, &rho, 0.0)); t.elapsed().as_secs_f64() }));

    // 5-d
    let ds = by_name("sensor", Some(100_000), 42).unwrap();
    println!("density sensor 100k 5d: {:.3}s", med3(|| { let t = Instant::now(); std::hint::black_box(compute_density(&ds.pts, ds.params.d_cut, DensityAlgo::TreePruned)); t.elapsed().as_secs_f64() }));
    let rho = compute_density(&ds.pts, ds.params.d_cut, DensityAlgo::TreePruned);
    println!("dep priority sensor 100k 5d: {:.3}s", med3(|| { let t = Instant::now(); std::hint::black_box(dep::dep_priority(&ds.pts, &rho, ds.params.rho_min)); t.elapsed().as_secs_f64() }));
}
