//! Streaming ingestion demo: grow a session batch by batch, watching the
//! kd-forest's binary-counter merges and the amortized repair stats, then
//! verify the final state against a from-scratch staged session.
//!
//!   cargo run --release --example streaming_demo

use parcluster::bench::{fmt_secs, Table};
use parcluster::datasets::synthetic;
use parcluster::dpc::{ClusterSession, DepAlgo, StreamingSession};
use parcluster::geom::PointSet;

fn main() {
    let n = 20_000usize;
    let d_cut = 30.0;
    let pts = synthetic::varden(n, 2, 7);
    let d = pts.dim();
    let batches = 10usize;
    let per = n.div_ceil(batches);

    let mut s = StreamingSession::<f64>::new(d, d_cut).expect("open stream");
    let mut table = Table::new(&["batch", "points", "total", "ingest", "levels", "clusters"]);
    let mut sent = 0usize;
    let mut batch_no = 0usize;
    while sent < n {
        let hi = (sent + per).min(n);
        let batch = PointSet::new(pts.coords()[sent * d..hi * d].to_vec(), d);
        let t = std::time::Instant::now();
        s.ingest(&batch).expect("ingest");
        let ingest_s = t.elapsed().as_secs_f64();
        let out = s.cut(5.0, 500.0).expect("cut");
        table.row(vec![
            batch_no.to_string(),
            (hi - sent).to_string(),
            hi.to_string(),
            fmt_secs(ingest_s),
            format!("{:?}", s.level_sizes()),
            out.num_clusters.to_string(),
        ]);
        sent = hi;
        batch_no += 1;
    }
    table.print();

    let st = s.stats();
    println!(
        "\nrepair stats: {} trees rebuilt ({} points) for {} ingested; \
         rho bumps {}, dep full re-queries {}, seeded races {} ({} deps changed)",
        st.trees_built,
        st.tree_points_built,
        st.points_ingested,
        st.rho_bumped,
        st.dep_full_queries,
        st.dep_seeded_races,
        st.dep_changed
    );
    // The Arc-backed store contract: rebuilt levels pin the session's
    // current coordinate buffer by refcount (older levels pin the snapshot
    // they were built against) — no defensive copies anywhere.
    println!(
        "levels sharing the current coordinate buffer: {}/{}",
        s.levels_sharing_current_buffer(),
        s.level_sizes().len()
    );

    // The exactness contract, checked end to end.
    let mut fresh = ClusterSession::build(&pts).expect("fresh build");
    let rho = fresh.density(d_cut).expect("density");
    let art = fresh.dependents(DepAlgo::Priority).expect("dependents");
    assert_eq!(s.rho(), &rho[..], "streaming rho must equal a fresh build");
    assert_eq!(s.dep(), &art.dep[..], "streaming dep must equal a fresh build");
    assert_eq!(s.delta(), &art.delta[..], "streaming delta must equal a fresh build");
    let a = s.cut(5.0, 500.0).expect("cut");
    let b = fresh.cut(5.0, 500.0).expect("cut");
    assert_eq!(a.labels, b.labels, "streaming labels must equal a fresh build");
    println!("exactness check vs from-scratch session: OK ({} clusters, {} noise)", a.num_clusters, a.num_noise);
}
