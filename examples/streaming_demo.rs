//! Durable streaming demo: grow a session batch by batch through a
//! write-ahead journal, checkpoint mid-stream, crash on purpose, and
//! restore — then verify the recovered state against a from-scratch
//! staged session.
//!
//!   cargo run --release --example streaming_demo
//!
//! Each batch is journaled *before* it is ingested (exactly what a
//! `serve --durable` coordinator does), so the "crash" — dropping
//! everything in memory — loses nothing: recovery loads the checkpoint
//! and replays the journal suffix through the same deterministic ingest
//! path, landing byte-identical to the never-crashed session.

use parcluster::bench::{fmt_secs, Table};
use parcluster::datasets::synthetic;
use parcluster::dpc::{ClusterSession, DensityModel, DepAlgo, StreamingSession};
use parcluster::durability::{
    checkpoint::{self, CheckpointData, DynStreamState},
    journal::JournalEntry,
    recovery::{recover, DynStream},
};
use parcluster::geom::{Dtype, DynPoints, PointSet};

fn main() {
    let n = 20_000usize;
    let d_cut = 30.0;
    let pts = synthetic::varden(n, 2, 7);
    let d = pts.dim();
    let batches = 10usize;
    let per = n.div_ceil(batches);
    let checkpoint_at = 6usize; // checkpoint after this many batches

    // Rotate segments at 128 KiB so the demo journal spans a chain and the
    // mid-stream checkpoint visibly GCs the segments below its horizon.
    let rotate_bytes = 128u64 << 10;

    let dir = std::env::temp_dir().join(format!("parcluster-streaming-demo-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut rec = recover(&dir, 1, rotate_bytes).expect("init durable dir");
    rec.writer
        .append(&JournalEntry::OpenStream {
            stream: 1,
            dim: d as u32,
            dtype: Dtype::F64,
            d_cut,
            density: DensityModel::CutoffCount,
        })
        .expect("journal open");

    let mut s = StreamingSession::<f64>::new(d, d_cut).expect("open stream");
    let mut table = Table::new(&["batch", "points", "total", "ingest", "levels", "clusters", "durability"]);
    let mut sent = 0usize;
    let mut batch_no = 0usize;
    while sent < n {
        let hi = (sent + per).min(n);
        let batch = PointSet::new(pts.coords()[sent * d..hi * d].to_vec(), d);
        // WAL first: the batch is on disk before the session sees it.
        rec.writer
            .append(&JournalEntry::Ingest {
                stream: 1,
                rho_min: 5.0,
                delta_min: 500.0,
                batch: DynPoints::F64(batch.clone()),
            })
            .expect("journal ingest");
        let t = std::time::Instant::now();
        s.ingest(&batch).expect("ingest");
        let ingest_s = t.elapsed().as_secs_f64();
        let out = s.cut(5.0, 500.0).expect("cut");
        let durability = if batch_no + 1 == checkpoint_at {
            let data = CheckpointData {
                streams: vec![(1, DynStreamState::F64(s.export_state()))],
                sessions: Vec::new(),
            };
            let m = checkpoint::write(&dir, &mut rec.writer, &data, 2, 1).expect("checkpoint");
            format!(
                "checkpoint {} @ segment {} offset {}",
                m.checkpoint_seq, m.journal_seq, m.journal_offset
            )
        } else {
            "journaled".to_string()
        };
        table.row(vec![
            batch_no.to_string(),
            (hi - sent).to_string(),
            hi.to_string(),
            fmt_secs(ingest_s),
            format!("{:?}", s.level_sizes()),
            out.num_clusters.to_string(),
            durability,
        ]);
        sent = hi;
        batch_no += 1;
    }
    table.print();

    let st = s.stats();
    println!(
        "\nrepair stats: {} trees rebuilt ({} points) for {} ingested; \
         rho bumps {}, dep full re-queries {}, seeded races {} ({} deps changed)",
        st.trees_built,
        st.tree_points_built,
        st.points_ingested,
        st.rho_bumped,
        st.dep_full_queries,
        st.dep_seeded_races,
        st.dep_changed
    );
    // The Arc-backed store contract: rebuilt levels pin the session's
    // current coordinate buffer by refcount (older levels pin the snapshot
    // they were built against) — no defensive copies anywhere.
    println!(
        "levels sharing the current coordinate buffer: {}/{}",
        s.levels_sharing_current_buffer(),
        s.level_sizes().len()
    );

    // CRASH: drop the live session AND the journal writer mid-flight.
    // Everything the server knew is gone; only the directory survives.
    drop(s);
    drop(rec);
    println!("\n-- simulated crash (all in-memory state dropped) --");

    let t = std::time::Instant::now();
    let recd = recover(&dir, 1, rotate_bytes).expect("recover");
    let recover_s = t.elapsed().as_secs_f64();
    println!(
        "recovered in {}: checkpoint {} + {} journal entries replayed \
         across {} segment(s) ({} torn bytes truncated)",
        fmt_secs(recover_s),
        recd.report.checkpoint_seq,
        recd.report.replayed,
        recd.report.segments,
        recd.report.torn_bytes
    );
    let DynStream::F64(restored) = &recd.streams[0].1 else { panic!("f64 stream") };

    // The exactness contract, checked end to end: the *recovered* state
    // equals a from-scratch staged session on all n points.
    let mut fresh = ClusterSession::build(&pts).expect("fresh build");
    let rho = fresh.density(d_cut).expect("density");
    let art = fresh.dependents(DepAlgo::Priority).expect("dependents");
    assert_eq!(restored.rho(), &rho[..], "recovered rho must equal a fresh build");
    assert_eq!(restored.dep(), &art.dep[..], "recovered dep must equal a fresh build");
    assert_eq!(restored.delta(), &art.delta[..], "recovered delta must equal a fresh build");
    let a = restored.cut(5.0, 500.0).expect("cut");
    let b = fresh.cut(5.0, 500.0).expect("cut");
    assert_eq!(a.labels, b.labels, "recovered labels must equal a fresh build");
    println!(
        "exactness check: recovered state == from-scratch session ({} clusters, {} noise)",
        a.num_clusters, a.num_noise
    );
    let _ = std::fs::remove_dir_all(&dir);
}
