use parcluster::datasets;
use parcluster::dpc::{compute_density, dep, DensityAlgo, DepAlgo};
use std::time::Instant;
fn main() {
    for n in [15000usize, 20000, 25000] {
        let ds = datasets::by_name("geolife", Some(n), 42).unwrap();
        let rho = compute_density(&ds.pts, ds.params.d_cut, DensityAlgo::TreePruned);
        let t = Instant::now();
        let _ = dep::compute_dependents(&ds.pts, &rho, ds.params.rho_min, DepAlgo::ExactBaseline);
        println!("geolife n={n} baseline dep: {:.2}s", t.elapsed().as_secs_f64());
    }
}
