//! Decision-graph workflow (Rodriguez & Laio's parameter-selection aid):
//! run a parameter-free scan, plot (ρ, δ), auto-suggest δ_min for a target
//! cluster count, and re-cluster with the suggestion.
//!
//! ```sh
//! cargo run --release --example decision_graph
//! ```

use parcluster::datasets;
use parcluster::dpc::{decision, Dpc, DpcParams};

fn main() {
    let ds = datasets::by_name("gowalla", Some(20_000), 42).expect("dataset");
    println!("dataset: {} (n={}, d={})", ds.name, ds.pts.len(), ds.pts.dim());

    // Scan pass: no thresholds, just compute (rho, delta) for every point.
    let scan_params = DpcParams { d_cut: ds.params.d_cut, rho_min: 0.0, delta_min: f64::INFINITY };
    let scan = Dpc::new(scan_params).run(&ds.pts);
    let graph = decision::decision_graph(&scan);

    println!("\ndecision graph (each mark is a point; centers = high rho AND high delta):");
    print!("{}", decision::ascii_plot(&graph, 72, 18));

    println!("\ntop-8 center candidates by rho*delta:");
    for p in graph.iter().take(8) {
        println!("  id {:>7}  rho {:>6}  delta {:>12.4}", p.id, p.rho, p.delta);
    }

    for k in [2, 5, 10] {
        let (rho_min, delta_min) = decision::suggest_params(&graph, k);
        let out = Dpc::new(DpcParams { d_cut: ds.params.d_cut, rho_min, delta_min }).run(&ds.pts);
        println!(
            "k={k:>2}: suggested delta_min={delta_min:<12.4} -> {} clusters, {} noise",
            out.num_clusters, out.num_noise
        );
    }
}
