//! Decision-graph workflow (Rodriguez & Laio's parameter-selection aid):
//! build a [`ClusterSession`] once, run the parameter-free scan, plot
//! (ρ, δ), then sweep suggested thresholds with cheap `.cut()` re-cuts —
//! each re-cut costs only the union-find linkage step, not the kd-tree,
//! density, or dependent-point work.
//!
//! ```sh
//! cargo run --release --example decision_graph
//! ```

use std::time::Instant;

use parcluster::datasets;
use parcluster::dpc::{decision, ClusterSession, DepAlgo};
use parcluster::error::DpcError;

fn main() -> Result<(), DpcError> {
    let ds = datasets::by_name("gowalla", Some(20_000), 42).expect("dataset");
    println!("dataset: {} (n={}, d={})", ds.name, ds.pts.len(), ds.pts.dim());

    // Stage 1+2 once: kd-tree, density at the Table-2 radius, full (ρ, δ).
    let t = Instant::now();
    let mut session = ClusterSession::build(&ds.pts)?;
    session.density(ds.params.d_cut)?;
    session.dependents(DepAlgo::Priority)?;
    let build_s = t.elapsed().as_secs_f64();

    // Scan cut: no thresholds, just expose (rho, delta) for every point.
    let scan = session.cut(0.0, f64::INFINITY)?;
    let graph = decision::decision_graph(&scan);

    println!("\ndecision graph (each mark is a point; centers = high rho AND high delta):");
    print!("{}", decision::ascii_plot(&graph, 72, 18));

    println!("\ntop-8 center candidates by rho*delta:");
    for p in graph.iter().take(8) {
        println!("  id {:>7}  rho {:>6}  delta {:>12.4}", p.id, p.rho, p.delta);
    }

    // The re-cut loop: every threshold choice below reuses the cached
    // artifacts — watch the per-cut wall-clock vs the one-time build cost.
    println!("\nsession build (tree + density + dependents): {build_s:.3}s; now re-cutting:");
    for k in [2, 5, 10] {
        let (rho_min, delta_min) = decision::suggest_params(&graph, k)?;
        let t = Instant::now();
        let out = session.cut(rho_min, delta_min)?;
        let cut_s = t.elapsed().as_secs_f64();
        println!(
            "k={k:>2}: delta_min={delta_min:<12.4} -> {} clusters, {} noise  (re-cut {cut_s:.4}s, {:.0}x cheaper than the build)",
            out.num_clusters,
            out.num_noise,
            build_s / cut_s.max(1e-9)
        );
    }
    let stats = session.stats();
    println!(
        "\nsession stats: {} density compute(s), {} dependents compute(s) for all cuts above",
        stats.density_computes, stats.dep_computes
    );
    Ok(())
}
