"""AOT lowering: JAX -> HLO text artifacts for the Rust PJRT runtime.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emits one artifact per padded size in SIZE_MENU:

    artifacts/dpc_bf_n{N}_d8.hlo.txt
    artifacts/manifest.txt   # lines: <name> <n_pad> <d_pad>

Signature of every artifact (return_tuple=True, so Rust unwraps a 3-tuple):

    (points f32[N,8], dcut_sq f32[1]) -> (rho i32[N], dep i32[N],
                                          dist_sq f32[N])

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import dpc_bruteforce

# Padded-size menu: powers of two that are multiples of the kernel tiles
# (TQ=128, TP=512). The Rust router dispatches a job of n points to the
# smallest artifact >= n, or to the tree engine if n exceeds the menu.
SIZE_MENU = [512, 1024, 2048, 4096, 8192]
D_PAD = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(n_pad: int) -> str:
    pts_spec = jax.ShapeDtypeStruct((n_pad, D_PAD), jnp.float32)
    dcut_spec = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(dpc_bruteforce).lower(pts_spec, dcut_spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default=",".join(str(s) for s in SIZE_MENU))
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s]
    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = []
    for n_pad in sizes:
        name = f"dpc_bf_n{n_pad}_d{D_PAD}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_one(n_pad)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {n_pad} {D_PAD}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')} ({len(sizes)} artifacts)")


if __name__ == "__main__":
    main()
