"""L2 — the JAX compute graph for tensorized brute-force DPC.

Composes the L1 Pallas kernels (`kernels.pairwise`) into the function the
Rust runtime executes:

    dpc_bruteforce(points f32[n, d], dcut_sq f32[]) ->
        (rho i32[n], dep i32[n], dist_sq f32[n])

`n` must be a multiple of the kernel tile sizes — [`pad_points`] handles
padding with the PAD_COORD sentinel (padding rows get rho = 0 from real
points' perspective... more precisely: real points never count padding rows
because their distance is ~1e18; padding rows' own outputs are garbage and
sliced off by the caller).

This file is build-time only: `aot.py` lowers `dpc_bruteforce` to HLO text
for the menu of padded sizes, and the Rust L3 coordinator executes the
artifacts via PJRT. Python never runs on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import pairwise


def dpc_bruteforce(points: jax.Array, dcut_sq: jax.Array):
    """The full tensorized DPC forward graph (Steps 1 + 2 of the paper).

    Step 3 (union-find single linkage) is irregular pointer-chasing and
    stays in Rust — it is a negligible fraction of runtime (paper §7.2).
    """
    rho = pairwise.density(points, dcut_sq)
    dep, dist = pairwise.dependents(points, rho)
    return rho, dep, dist


def pad_points(points: np.ndarray, n_pad: int, d_pad: int = 8) -> np.ndarray:
    """Pad an (n, d) float array to (n_pad, d_pad) f32 with PAD_COORD rows.

    Extra *columns* are zero (they contribute 0 to distances); extra *rows*
    are PAD_COORD (huge distance to everything).
    """
    n, d = points.shape
    if n > n_pad or d > d_pad:
        raise ValueError(f"cannot pad ({n},{d}) to ({n_pad},{d_pad})")
    out = np.zeros((n_pad, d_pad), dtype=np.float32)
    out[:n, :d] = points.astype(np.float32)
    # Staggered sentinels: each padding row sits at its own far-away location
    # so padding rows do NOT cluster with each other (identical sentinels
    # would give them huge densities and make them bogus dependent-point
    # candidates). With rho <= 1 and ids after all real ids, the priority
    # rule can never select a padding row for a real point.
    stagger = (np.arange(n, n_pad, dtype=np.float32) + 1.0)[:, None]
    out[n:, :] = pairwise.PAD_COORD * stagger
    return out


def choose_padded_size(n: int, menu: list[int]) -> int:
    """Smallest menu size >= n (the AOT artifact to dispatch to)."""
    for m in sorted(menu):
        if m >= n:
            return m
    raise ValueError(f"n={n} exceeds the largest AOT artifact ({max(menu)})")
