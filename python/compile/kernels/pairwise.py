"""L1 — Pallas kernels for tensorized brute-force DPC.

The paper's tree algorithms are irregular and live in the Rust L3 engine;
this module implements the *tensorized* O(n^2) DPC (the "Original DPC" row
of Table 1 — what a GPU/TPU implementation such as Liu et al. [47] computes)
as two tiled Pallas kernels. The Rust coordinator AOT-loads the lowered HLO
and routes small/dense jobs here (and uses it as an independent exactness
oracle for the tree engine).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the pairwise squared
distance matrix is computed tile-by-tile as

    D2[i, j] = |x_i|^2 + |x_j|^2 - 2 <x_i, x_j>

so the inner product lands on the MXU as a (TQ x d) @ (d x TP) matmul, with
the masks/reductions on the VPU. The 2-D BlockSpec grid (query tiles x point
tiles) expresses the HBM<->VMEM schedule a CUDA version would express with
threadblocks; the per-row accumulators (density count / running min) live in
the revisited output block across the point-tile axis (standard Pallas
accumulation: the point-tile axis is the minor grid dimension, so each
output block sees its j-tiles sequentially).

Kernels must be lowered with interpret=True on this CPU image (real TPU
lowering emits Mosaic custom-calls the CPU PJRT plugin cannot execute).

Conventions (identical to the Rust engine, crate::dpc):
 - density rho(i) = #{j : D(i,j) <= d_cut}, self-inclusive;
 - priority(j) > priority(i)  <=>  rho_j > rho_i, or rho_j == rho_i and
   j < i (lexicographic id tiebreak);
 - dependent point = argmin_{higher priority} (distance, id) — distance
   ties broken by the smaller id;
 - padding rows use the PAD_COORD sentinel coordinate, giving them huge
   distances to everything (excluded from every ball and candidate set).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Query-tile and point-tile sizes. TQ matches the MXU row dimension; TP wide
# enough to amortize the VPU mask work. VMEM footprint per step:
#   x_tile (128 x 8 x 4 B) + y_tile (512 x 8 x 4 B) + D2 tile (128 x 512 x 4B)
#   ~= 0.27 MiB  << 16 MiB VMEM.
TQ = 128
TP = 512

# Base sentinel coordinate for padding rows: distances to real points
# >= ~1e18, far above any d_cut^2 yet well below f32 overflow (3.4e38) even
# squared, staggered per row (see model.pad_points — padding rows must not
# cluster with each other), and summed over 8 lanes.
PAD_COORD = 1.0e9


def _density_kernel(dcut_sq_ref, x_ref, y_ref, rho_ref):
    """One (i-tile, j-tile) step: rho[i-tile] += #{j in tile : D2 <= dcut^2}.

    Grid = (n/TQ, n/TP); rho block depends only on i, so the j axis revisits
    and accumulates into it.
    """
    j = pl.program_id(1)
    x = x_ref[...]  # (TQ, d)
    y = y_ref[...]  # (TP, d)
    # ||x-y||^2 via the MXU: x@y^T is the (TQ, TP) matmul.
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # (TQ, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T  # (1, TP)
    d2 = xx + yy - 2.0 * jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    inball = (d2 <= dcut_sq_ref[0]).astype(jnp.int32)
    counts = jnp.sum(inball, axis=1)  # (TQ,)

    @pl.when(j == 0)
    def _init():
        rho_ref[...] = jnp.zeros_like(rho_ref)

    rho_ref[...] += counts


def _dep_kernel(dcut_sq_ref, x_ref, xrho_ref, y_ref, yrho_ref, dep_ref, dist_ref):
    """One (i-tile, j-tile) step of the dependent-point argmin.

    Maintains, per query row, the running (best_dist, best_id) over all
    higher-priority points seen so far. j-tiles arrive in ascending id
    order, and within a tile argmin picks the first (= smallest id) minimum,
    so a strict `<` merge preserves the smaller-id tiebreak globally.
    """
    del dcut_sq_ref  # unused; shared input signature with the density kernel
    i = pl.program_id(0)
    j = pl.program_id(1)
    x = x_ref[...]
    y = y_ref[...]
    xrho = xrho_ref[...]  # (TQ,)
    yrho = yrho_ref[...]  # (TP,)
    xx = jnp.sum(x * x, axis=1, keepdims=True)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T
    d2 = xx + yy - 2.0 * jnp.dot(x, y.T, preferred_element_type=jnp.float32)

    # Global ids of this tile's rows/cols.
    row_ids = i * TQ + jax.lax.broadcasted_iota(jnp.int32, (TQ, TP), 0)
    col_ids = j * TP + jax.lax.broadcasted_iota(jnp.int32, (TQ, TP), 1)
    # priority(col) > priority(row)?
    higher = (yrho[None, :] > xrho[:, None]) | ((yrho[None, :] == xrho[:, None]) & (col_ids < row_ids))
    masked = jnp.where(higher, d2, jnp.inf)

    tile_best = jnp.min(masked, axis=1)  # (TQ,)
    tile_arg = jnp.argmin(masked, axis=1).astype(jnp.int32)  # first min => smallest id
    tile_id = j * TP + tile_arg

    @pl.when(j == 0)
    def _init():
        dist_ref[...] = jnp.full_like(dist_ref, jnp.inf)
        dep_ref[...] = jnp.full_like(dep_ref, -1)

    improved = tile_best < dist_ref[...]
    dist_ref[...] = jnp.where(improved, tile_best, dist_ref[...])
    dep_ref[...] = jnp.where(improved & jnp.isfinite(tile_best), tile_id, dep_ref[...])


def _check_shapes(points):
    n, d = points.shape
    if n % TQ != 0 or n % TP != 0:
        raise ValueError(f"n={n} must be a multiple of TQ={TQ} and TP={TP}; pad first")
    return n, d


@functools.partial(jax.jit, static_argnames=())
def density(points: jax.Array, dcut_sq: jax.Array) -> jax.Array:
    """rho[i] = #points within sqrt(dcut_sq) of points[i] (self-inclusive).

    `points`: (n, d) f32, padded rows at PAD_COORD; `dcut_sq`: f32 scalar.
    """
    n, d = _check_shapes(points)
    dcut_arr = jnp.reshape(dcut_sq.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _density_kernel,
        grid=(n // TQ, n // TP),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((TQ, d), lambda i, j: (i, 0)),
            pl.BlockSpec((TP, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TQ,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(dcut_arr, points, points)


@functools.partial(jax.jit, static_argnames=())
def dependents(points: jax.Array, rho: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(dep, dist_sq) per point: nearest strictly-higher-priority neighbor.

    dep[i] = -1 where no higher-priority point exists (the global peak, and
    padding rows). `rho`: (n,) i32.
    """
    n, d = _check_shapes(points)
    dcut_arr = jnp.zeros((1,), jnp.float32)
    return pl.pallas_call(
        _dep_kernel,
        grid=(n // TQ, n // TP),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((TQ, d), lambda i, j: (i, 0)),
            pl.BlockSpec((TQ,), lambda i, j: (i,)),
            pl.BlockSpec((TP, d), lambda i, j: (j, 0)),
            pl.BlockSpec((TP,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((TQ,), lambda i, j: (i,)),
            pl.BlockSpec((TQ,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(dcut_arr, points, rho, points, rho)
