"""Pure-jnp oracle for the Pallas kernels — the build-time correctness
signal (pytest asserts kernel == ref on every shape/dtype sweep).

Implements the same semantics with dense O(n^2) jnp ops and no tiling, so a
bug in the Pallas BlockSpec plumbing cannot hide here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_dist_sq(points: jax.Array) -> jax.Array:
    """Full (n, n) squared-distance matrix, the same |x|^2+|y|^2-2xy formula
    the kernels use (so float behaviour matches)."""
    xx = jnp.sum(points * points, axis=1)
    d2 = xx[:, None] + xx[None, :] - 2.0 * points @ points.T
    return d2


def density(points: jax.Array, dcut_sq: jax.Array) -> jax.Array:
    """rho[i] = #{j : D2[i,j] <= dcut_sq} (self-inclusive)."""
    d2 = pairwise_dist_sq(points)
    return jnp.sum(d2 <= dcut_sq, axis=1).astype(jnp.int32)


def dependents(points: jax.Array, rho: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(dep, dist_sq): nearest strictly-higher-priority neighbor per row.

    priority(j) > priority(i)  <=>  rho_j > rho_i or (rho_j == rho_i and
    j < i); distance ties broken by smaller id (argmin picks the first
    minimum). dep = -1 where no candidate exists.
    """
    n = points.shape[0]
    d2 = pairwise_dist_sq(points)
    ids = jnp.arange(n, dtype=jnp.int32)
    higher = (rho[None, :] > rho[:, None]) | ((rho[None, :] == rho[:, None]) & (ids[None, :] < ids[:, None]))
    masked = jnp.where(higher, d2, jnp.inf)
    best = jnp.min(masked, axis=1)
    dep = jnp.argmin(masked, axis=1).astype(jnp.int32)
    dep = jnp.where(jnp.isfinite(best), dep, -1)
    return dep, best


def dpc_bruteforce_ref(points: jax.Array, dcut_sq: jax.Array):
    """Full reference pipeline: (rho, dep, dist_sq)."""
    rho = density(points, dcut_sq)
    dep, dist = dependents(points, rho)
    return rho, dep, dist
