"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle (ref.py),
including hypothesis sweeps over shapes, coordinate regimes, and d_cut.

These tests are the build-time gate: `make artifacts` output is only
trusted because this suite passes on the same kernel code.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import pairwise, ref
from compile.kernels.pairwise import PAD_COORD, TP, TQ

jax.config.update("jax_platform_name", "cpu")

N_PAD = 512  # one tile of TP, four tiles of TQ — smallest legal size


def make_points(rng: np.random.Generator, n_real: int, d: int, grid: int | None):
    """Random points padded to (N_PAD, 8) via model.pad_points (staggered
    sentinels). grid != None quantizes coords to integers in [0, grid) so
    f32 distance arithmetic is exact."""
    from compile.model import pad_points

    if grid is not None:
        pts = rng.integers(0, grid, size=(n_real, d)).astype(np.float32)
    else:
        pts = rng.uniform(0.0, 100.0, size=(n_real, d)).astype(np.float32)
    return jnp.asarray(pad_points(pts, N_PAD))


def brute_density(pts: np.ndarray, n_real: int, dcut_sq: float) -> np.ndarray:
    """Independent numpy oracle (different formula: explicit differences)."""
    x = pts[:n_real].astype(np.float64)
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    return (d2 <= dcut_sq + 1e-9).sum(1).astype(np.int32)


class TestDensityKernel:
    def test_matches_ref_small(self):
        rng = np.random.default_rng(0)
        pts = make_points(rng, 300, 2, grid=50)
        got = pairwise.density(pts, jnp.float32(25.0))
        want = ref.density(pts, jnp.float32(25.0))
        np.testing.assert_array_equal(got, want)

    def test_matches_independent_numpy_oracle_on_grid(self):
        rng = np.random.default_rng(1)
        n_real = 400
        pts = make_points(rng, n_real, 3, grid=20)
        got = np.asarray(pairwise.density(pts, jnp.float32(16.0)))[:n_real]
        want = brute_density(np.asarray(pts), n_real, 16.0)
        np.testing.assert_array_equal(got, want)

    def test_padding_rows_do_not_pollute_real_counts(self):
        from compile.model import pad_points

        # All real points identical: every real rho = n_real exactly.
        n_real = 37
        pts = pad_points(np.ones((n_real, 8), dtype=np.float32), N_PAD)
        got = np.asarray(pairwise.density(jnp.asarray(pts), jnp.float32(1.0)))
        assert (got[:n_real] == n_real).all()
        # Padding rows are isolated: rho <= 1 each.
        assert (got[n_real:] <= 1).all()

    def test_self_inclusive(self):
        from compile.model import pad_points

        pts = pad_points(np.zeros((1, 8), dtype=np.float32), N_PAD)
        got = np.asarray(pairwise.density(jnp.asarray(pts), jnp.float32(0.01)))
        assert got[0] == 1

    def test_rejects_unpadded_shapes(self):
        with pytest.raises(ValueError):
            pairwise.density(jnp.zeros((100, 8), jnp.float32), jnp.float32(1.0))

    @hypothesis.settings(max_examples=15, deadline=None)
    @hypothesis.given(
        seed=st.integers(0, 2**31),
        n_real=st.integers(2, N_PAD),
        d=st.integers(1, 8),
        dcut=st.floats(0.5, 50.0),
    )
    def test_hypothesis_matches_ref(self, seed, n_real, d, dcut):
        rng = np.random.default_rng(seed)
        pts = make_points(rng, n_real, d, grid=None)
        got = pairwise.density(pts, jnp.float32(dcut * dcut))
        want = ref.density(pts, jnp.float32(dcut * dcut))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestDependentKernel:
    def _rho(self, pts, dcut_sq=25.0):
        return pairwise.density(pts, jnp.float32(dcut_sq))

    def test_matches_ref(self):
        rng = np.random.default_rng(3)
        pts = make_points(rng, 350, 2, grid=40)
        rho = self._rho(pts)
        got_dep, got_dist = pairwise.dependents(pts, rho)
        want_dep, want_dist = ref.dependents(pts, rho)
        np.testing.assert_array_equal(np.asarray(got_dep), np.asarray(want_dep))
        np.testing.assert_allclose(np.asarray(got_dist), np.asarray(want_dist), rtol=1e-6)

    def test_priority_rule_ties_broken_by_smaller_id(self):
        from compile.model import pad_points

        # Three identical points: equal rho; dep must point to the smallest
        # lower id.
        jpts = jnp.asarray(pad_points(np.full((3, 8), 5.0, dtype=np.float32), N_PAD))
        rho = self._rho(jpts, dcut_sq=1.0)
        dep, dist = pairwise.dependents(jpts, rho)
        dep = np.asarray(dep)
        assert dep[0] == -1  # highest priority (smallest id at equal rho)
        assert dep[1] == 0
        assert dep[2] == 0  # distance ties to 0 and 1; smaller id wins
        assert np.asarray(dist)[2] == 0.0

    def test_global_peak_gets_minus_one(self):
        rng = np.random.default_rng(4)
        n_real = 200
        pts = make_points(rng, n_real, 2, grid=10)
        rho = self._rho(pts, dcut_sq=4.0)
        dep, _ = pairwise.dependents(pts, rho)
        dep = np.asarray(dep)[:n_real]
        assert (dep == -1).sum() == 1

    def test_dependent_has_strictly_higher_priority(self):
        rng = np.random.default_rng(5)
        n_real = 300
        pts = make_points(rng, n_real, 3, grid=15)
        rho_j = self._rho(pts, dcut_sq=9.0)
        dep, _ = pairwise.dependents(pts, rho_j)
        rho = np.asarray(rho_j)
        dep = np.asarray(dep)
        for i in range(n_real):
            j = dep[i]
            if j >= 0:
                assert (rho[j], -j) > (rho[i], -i), f"dep of {i} is {j}"

    @hypothesis.settings(max_examples=10, deadline=None)
    @hypothesis.given(
        seed=st.integers(0, 2**31),
        n_real=st.integers(2, N_PAD),
        d=st.integers(1, 8),
        grid=st.sampled_from([5, 20, 100]),
    )
    def test_hypothesis_matches_ref(self, seed, n_real, d, grid):
        rng = np.random.default_rng(seed)
        pts = make_points(rng, n_real, d, grid=grid)
        rho = self._rho(pts, dcut_sq=float(grid))
        got_dep, got_dist = pairwise.dependents(pts, rho)
        want_dep, want_dist = ref.dependents(pts, rho)
        np.testing.assert_array_equal(np.asarray(got_dep), np.asarray(want_dep))
        np.testing.assert_allclose(np.asarray(got_dist), np.asarray(want_dist), rtol=1e-6)


class TestMultiTile:
    """Exercise n > one tile in both grid dimensions."""

    def test_density_and_dep_at_1024(self):
        rng = np.random.default_rng(6)
        n = 1024
        pts_np = rng.integers(0, 30, size=(n, 2)).astype(np.float32)
        pts = np.zeros((n, 8), dtype=np.float32)
        pts[:, :2] = pts_np
        jpts = jnp.asarray(pts)
        rho = pairwise.density(jpts, jnp.float32(9.0))
        want_rho = ref.density(jpts, jnp.float32(9.0))
        np.testing.assert_array_equal(np.asarray(rho), np.asarray(want_rho))
        dep, dist = pairwise.dependents(jpts, rho)
        want_dep, want_dist = ref.dependents(jpts, rho)
        np.testing.assert_array_equal(np.asarray(dep), np.asarray(want_dep))
        np.testing.assert_allclose(np.asarray(dist), np.asarray(want_dist), rtol=1e-6)
