"""L2 model + AOT lowering tests: padding helpers, the composed graph, and
an HLO-text lowering smoke check (the artifact the Rust runtime loads)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref
from compile.kernels.pairwise import PAD_COORD

jax.config.update("jax_platform_name", "cpu")


class TestPadding:
    def test_pad_points_shape_and_sentinels(self):
        pts = np.arange(12, dtype=np.float64).reshape(6, 2)
        out = model.pad_points(pts, 512)
        assert out.shape == (512, 8)
        assert out.dtype == np.float32
        np.testing.assert_array_equal(out[:6, :2], pts.astype(np.float32))
        assert (out[:6, 2:] == 0.0).all()  # extra columns zero
        # Extra rows: staggered far-away sentinels (each >= PAD_COORD, all
        # rows distinct so they do not cluster with each other).
        assert (out[6:] >= PAD_COORD).all()
        assert len({float(v) for v in out[6:, 0]}) == out[6:].shape[0]

    def test_pad_points_rejects_oversize(self):
        with pytest.raises(ValueError):
            model.pad_points(np.zeros((600, 2)), 512)
        with pytest.raises(ValueError):
            model.pad_points(np.zeros((10, 9)), 512)

    def test_choose_padded_size(self):
        menu = [512, 1024, 4096]
        assert model.choose_padded_size(1, menu) == 512
        assert model.choose_padded_size(512, menu) == 512
        assert model.choose_padded_size(513, menu) == 1024
        with pytest.raises(ValueError):
            model.choose_padded_size(5000, menu)


class TestComposedModel:
    def test_model_matches_ref_pipeline(self):
        rng = np.random.default_rng(7)
        n_real = 300
        pts = model.pad_points(rng.integers(0, 25, size=(n_real, 3)).astype(np.float64), 512)
        jpts = jnp.asarray(pts)
        dcut_sq = jnp.float32(16.0)
        rho, dep, dist = model.dpc_bruteforce(jpts, dcut_sq)
        w_rho, w_dep, w_dist = ref.dpc_bruteforce_ref(jpts, dcut_sq)
        np.testing.assert_array_equal(np.asarray(rho), np.asarray(w_rho))
        np.testing.assert_array_equal(np.asarray(dep), np.asarray(w_dep))
        np.testing.assert_allclose(np.asarray(dist), np.asarray(w_dist), rtol=1e-6)

    def test_real_region_is_invariant_to_padding_amount(self):
        rng = np.random.default_rng(8)
        n_real = 200
        raw = rng.integers(0, 25, size=(n_real, 2)).astype(np.float64)
        dcut_sq = jnp.float32(9.0)
        out512 = model.dpc_bruteforce(jnp.asarray(model.pad_points(raw, 512)), dcut_sq)
        out1024 = model.dpc_bruteforce(jnp.asarray(model.pad_points(raw, 1024)), dcut_sq)
        for a, b in zip(out512, out1024):
            np.testing.assert_array_equal(np.asarray(a)[:n_real], np.asarray(b)[:n_real])


class TestAotLowering:
    def test_lower_one_produces_hlo_text(self):
        text = aot.lower_one(512)
        assert "HloModule" in text
        assert "ENTRY" in text
        # Signature: f32[512,8] input present.
        assert "f32[512,8]" in text.replace(" ", "")

    def test_manifest_menu_is_tile_aligned(self):
        from compile.kernels.pairwise import TP, TQ

        for n in aot.SIZE_MENU:
            assert n % TQ == 0 and n % TP == 0, n
