//! Table 3 / Figure 3 reproduction: per-step runtimes (density, dependent
//! point finding, total) of the five DPC implementations across the nine
//! benchmark datasets.
//!
//!   cargo bench --bench table3_runtimes            # default sizes
//!   PARBENCH_N=5000 cargo bench --bench table3_runtimes
//!
//! Differences vs the paper's setup (see EXPERIMENTS.md): single-core
//! container (paper: 30 cores / 60 HT), scaled-down n, surrogate real-world
//! datasets. The *shape* — who wins, roughly by what factor — is the
//! reproduction target. Entries projected to exceed the per-entry budget
//! are printed as "INF" (the paper's "—", did not terminate in 48h).

use std::time::Instant;

use parcluster::bench::{fmt_secs, Table};
use parcluster::datasets;
use parcluster::dpc::approx::run_approx_budgeted;
use parcluster::dpc::{compute_density, dep, linkage, DensityAlgo, DepAlgo, DpcParams};
use parcluster::geom::PointSet;

struct Entry {
    density: f64,
    dep: f64,
    total: f64,
}

fn run_exact(pts: &PointSet, params: DpcParams, algo: DepAlgo, density_algo: DensityAlgo) -> Entry {
    let t0 = Instant::now();
    let rho = compute_density(pts, params.d_cut, density_algo);
    let density = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let deps = dep::compute_dependents(pts, &rho, params.rho_min, algo);
    let dep_s = t1.elapsed().as_secs_f64();
    let t2 = Instant::now();
    let link = linkage::single_linkage(pts, &rho, &deps, params);
    let linkage_s = t2.elapsed().as_secs_f64();
    std::hint::black_box(link.num_clusters);
    Entry { density, dep: dep_s, total: density + dep_s + linkage_s }
}

fn main() {
    let n_default: usize = std::env::var("PARBENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(0);
    // (dataset, default n) — sized for a single-core container.
    let datasets_cfg: &[(&str, usize)] = &[
        ("uniform", 30_000),
        ("simden", 30_000),
        ("varden", 30_000),
        ("geolife", 30_000),
        ("pamap2", 20_000),
        ("sensor", 20_000),
        ("ht", 10_000),
        ("query", 20_000),
        ("gowalla", 30_000),
    ];
    let algos = [DepAlgo::ExactBaseline, DepAlgo::Fenwick, DepAlgo::Incomplete, DepAlgo::Priority];

    let mut table = Table::new(&[
        "dataset", "n",
        "base.den", "base.dep", "base.tot",
        "apx.den", "apx.dep", "apx.tot",
        "fen.den", "fen.dep", "fen.tot",
        "inc.den", "inc.dep", "inc.tot",
        "pri.den", "pri.dep", "pri.tot",
    ]);

    println!("# Table 3: per-step runtimes (seconds)");
    println!("# base = DPC-EXACT-BASELINE (incremental kd-tree + unpruned density)");
    println!("# apx  = DPC-APPROX-BASELINE (grid); fen/inc/pri = this paper's algorithms");
    for &(name, dn) in datasets_cfg {
        let n = if n_default > 0 { n_default } else { dn };
        let ds = datasets::by_name(name, Some(n), 42).expect("dataset");
        let mut row = vec![name.to_string(), n.to_string()];

        // Exact baseline: unpruned density + incremental-tree sequential dep.
        let e = run_exact(&ds.pts, ds.params, DepAlgo::ExactBaseline, DensityAlgo::BaselineIncremental);
        row.extend([fmt_secs(e.density), fmt_secs(e.dep), fmt_secs(e.total)]);

        // Approx baseline; INF = projected past the budget (the paper's "—",
        // did-not-terminate-in-48h entries).
        let budget_s: f64 = std::env::var("PARBENCH_APPROX_BUDGET").ok().and_then(|v| v.parse().ok()).unwrap_or(60.0);
        match run_approx_budgeted(&ds.pts, ds.params, budget_s) {
            Some(out) => {
                std::hint::black_box(out.num_clusters);
                row.extend([
                    fmt_secs(out.timings.density_s),
                    fmt_secs(out.timings.dep_s),
                    fmt_secs(out.timings.total_s()),
                ]);
            }
            None => row.extend(["INF".into(), "INF".into(), "INF".into()]),
        }

        // Our three algorithms (all share the pruned density step).
        for algo in &algos[1..] {
            let e = run_exact(&ds.pts, ds.params, *algo, DensityAlgo::TreePruned);
            row.extend([fmt_secs(e.density), fmt_secs(e.dep), fmt_secs(e.total)]);
        }
        table.row(row);
        eprintln!("done: {name} (n={n})");
    }
    table.print();

    println!("\n# Shape checks vs the paper:");
    println!("#  - pruned density (fen/inc/pri .den) should beat base.den everywhere");
    println!("#  - pri.dep fastest on most datasets; fen.dep close; inc.dep and base.dep slower");
    println!("#  - apx blows up (INF or large) on high-d (ht) and skewed (varden) data");
}
