//! Streaming ingest bench: `StreamingSession::ingest` + cut on a batch vs a
//! from-scratch `ClusterSession` pipeline (build + density + dependents +
//! cut) on the concatenated set — the serving-time win the kd-forest exists
//! for (a session absorbing traffic must not pay a full rebuild per batch).
//!
//!   cargo bench --bench stream_ingest
//!   PARBENCH_N=200000 cargo bench --bench stream_ingest
//!
//! Expected: ingest latency ≥5x below the full rebuild at a 10% batch on
//! n = 100k (the ingest rebuilds only colliding forest levels and repairs
//! (ρ, λ, δ) from the batch's neighborhoods; the rebuild re-runs every
//! range count and dependent query). Exits nonzero below the target.

use parcluster::bench::{fmt_secs, time_median, Table};
use parcluster::datasets::synthetic;
use parcluster::dpc::{ClusterSession, DepAlgo, StreamingSession};
use parcluster::geom::PointSet;

fn main() {
    let n: usize = std::env::var("PARBENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(100_000);
    let trials: usize = std::env::var("PARBENCH_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let d_cut = 30.0;
    let pts = synthetic::simden(n, 2, 42);
    let d = pts.dim();

    println!("# Streaming ingest vs full rebuild on simden n={n} (median of {trials})");
    let mut table = Table::new(&["batch", "full rebuild", "ingest+cut", "speedup", "identical"]);
    let mut speedup_at_10pct = 0.0f64;
    for frac in [0.01f64, 0.10] {
        let b = ((n as f64 * frac) as usize).max(1);
        let base_n = n - b;
        let base = PointSet::new(pts.coords()[..base_n * d].to_vec(), d);
        let batch = PointSet::new(pts.coords()[base_n * d..].to_vec(), d);

        // The price a non-streaming server pays per batch arrival.
        let full_s = time_median(trials, || {
            let mut s = ClusterSession::build(&pts).expect("build");
            s.density(d_cut).expect("density");
            s.dependents(DepAlgo::Priority).expect("dependents");
            std::hint::black_box(s.cut(0.0, f64::INFINITY).expect("cut"));
        });

        // Ingest price: base load is untimed per-trial setup.
        let mut samples: Vec<f64> = (0..trials.max(1))
            .map(|_| {
                let mut s = StreamingSession::<f64>::new(d, d_cut).expect("open");
                s.ingest(&base).expect("base ingest");
                let t = std::time::Instant::now();
                s.ingest(&batch).expect("ingest");
                std::hint::black_box(s.cut(0.0, f64::INFINITY).expect("cut"));
                t.elapsed().as_secs_f64()
            })
            .collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let ingest_s = samples[samples.len() / 2];

        // Exactness spot-check at bench scale.
        let mut s = StreamingSession::<f64>::new(d, d_cut).expect("open");
        s.ingest(&base).expect("base ingest");
        s.ingest(&batch).expect("ingest");
        let mut fresh = ClusterSession::build(&pts).expect("build");
        let rho = fresh.density(d_cut).expect("density");
        let art = fresh.dependents(DepAlgo::Priority).expect("dependents");
        let identical = s.rho() == &rho[..] && s.dep() == &art.dep[..] && s.delta() == &art.delta[..];

        let speedup = full_s / ingest_s.max(1e-12);
        if frac == 0.10 {
            speedup_at_10pct = speedup;
        }
        table.row(vec![
            format!("{:.0}% ({b})", frac * 100.0),
            fmt_secs(full_s),
            fmt_secs(ingest_s),
            format!("{speedup:.1}x"),
            if identical { "yes".into() } else { "NO".into() },
        ]);
        eprintln!("done: batch {:.0}%", frac * 100.0);
    }
    table.print();
    println!("\n# speedup at the 10% batch: {speedup_at_10pct:.1}x (target: >= 5x at n=100k)");
    if speedup_at_10pct < 5.0 {
        eprintln!("WARNING: streaming ingest below the 5x target");
        std::process::exit(1);
    }
}
