//! Leaf-kernel study: per-point gather loop vs blocked SoA leaf sweeps.
//!
//! Two levels. The *kernel* table isolates one leaf visit: the pre-blocked
//! per-point idiom (gather a lane's coordinates, then the scalar
//! `dist_sq`) against `dist_sq_block` in its portable-scalar and default
//! (SIMD where the host has it) forms, on a synthetic dim-major block
//! stream. The *tree* table measures what the hot paths actually buy:
//! range-count, range-weight-sum, and kNN over a full kd-tree with the
//! default kernel vs the scalar kernel forced — both paths byte-identical
//! by construction (asserted here, live).
//!
//! ```sh
//! cargo bench --bench leaf_kernel
//! ```

use std::time::Instant;

use parcluster::bench::{fmt_secs, Table};
use parcluster::geom::{
    block_kernel_name, force_scalar_kernel, scalar::dist_sq_block_scalar, PointStore, Scalar, BLOCK_LANES,
};
use parcluster::kdtree::{KdTree, NoStats};
use parcluster::prng::SplitMix64;
use parcluster::proputil::gen_uniform_points;

/// Median of three timed runs of `f`.
fn med3<F: FnMut() -> f64>(mut f: F) -> f64 {
    let mut t = [f(), f(), f()];
    t.sort_by(|a, b| a.partial_cmp(b).unwrap());
    t[1]
}

/// One synthetic dim-major block stream: `blocks` full blocks at dim `d`.
fn block_stream<S: Scalar>(rng: &mut SplitMix64, blocks: usize, d: usize) -> Vec<S> {
    (0..blocks * d * BLOCK_LANES).map(|_| S::from_f64(rng.uniform(0.0, 50.0))).collect()
}

fn kernel_row<S: Scalar>(rng: &mut SplitMix64, d: usize, table: &mut Table) {
    const BLOCKS: usize = 50_000;
    let stream = block_stream::<S>(rng, BLOCKS, d);
    let q: Vec<S> = (0..d).map(|_| S::from_f64(rng.uniform(0.0, 50.0))).collect();
    let stride = d * BLOCK_LANES;
    let mut sink = S::ZERO;

    // Pre-blocked idiom: per point, gather its coordinates out of the
    // dim-major rows, then the scalar pairwise kernel.
    let mut lane = vec![S::ZERO; d];
    let per_point = med3(|| {
        let t = Instant::now();
        for b in 0..BLOCKS {
            let block = &stream[b * stride..(b + 1) * stride];
            for l in 0..BLOCK_LANES {
                for (k, c) in lane.iter_mut().enumerate() {
                    *c = block[k * BLOCK_LANES + l];
                }
                sink += S::dist_sq(&lane, &q);
            }
        }
        t.elapsed().as_secs_f64()
    });

    let mut out = [S::ZERO; BLOCK_LANES];
    let blocked_scalar = med3(|| {
        let t = Instant::now();
        for b in 0..BLOCKS {
            dist_sq_block_scalar(&stream[b * stride..(b + 1) * stride], d, &q, &mut out);
            sink += out[0];
        }
        t.elapsed().as_secs_f64()
    });

    let blocked_default = med3(|| {
        let t = Instant::now();
        for b in 0..BLOCKS {
            S::dist_sq_block(&stream[b * stride..(b + 1) * stride], d, &q, &mut out);
            sink += out[0];
        }
        t.elapsed().as_secs_f64()
    });
    std::hint::black_box(sink);

    let dists = (BLOCKS * BLOCK_LANES) as f64;
    table.row(vec![
        format!("{} d={d}", S::DTYPE),
        format!("{:.0} M/s", dists / per_point / 1e6),
        format!("{:.0} M/s", dists / blocked_scalar / 1e6),
        format!("{:.0} M/s", dists / blocked_default / 1e6),
        format!("{:.2}x", per_point / blocked_default.max(1e-12)),
    ]);
}

fn tree_rows(n: usize, d: usize, table: &mut Table) {
    let mut rng = SplitMix64::new(0x1EAF + n as u64);
    let pts: PointStore<f64> = gen_uniform_points(&mut rng, n, d, 100.0);
    let tree = KdTree::build(&pts);
    let r_sq = 9.0;
    let weight = |ds: f64| (ds * 4.0) as u64 + 1;
    let queries: Vec<usize> = (0..n).step_by(16).collect();

    let mut run = |label: &str, f: &dyn Fn(&[f64]) -> u64| {
        let mut sums = (0u64, 0u64);
        let fast = med3(|| {
            let t = Instant::now();
            sums.0 = queries.iter().map(|&i| f(pts.point(i))).sum();
            t.elapsed().as_secs_f64()
        });
        force_scalar_kernel(true);
        let scalar = med3(|| {
            let t = Instant::now();
            sums.1 = queries.iter().map(|&i| f(pts.point(i))).sum();
            t.elapsed().as_secs_f64()
        });
        force_scalar_kernel(false);
        assert_eq!(sums.0, sums.1, "{label}: kernels disagree");
        table.row(vec![
            format!("{label} (n={n})"),
            fmt_secs(scalar),
            fmt_secs(fast),
            format!("{:.2}x", scalar / fast.max(1e-12)),
        ]);
    };

    run("range-count", &|q| tree.range_count(q, r_sq, &mut NoStats) as u64);
    run("range-weight-sum", &|q| tree.range_weight_sum(q, r_sq, &weight, &mut NoStats));
    run("knn (k=8)", &|q| tree.kth_nn_dist_sq(q, 8, u32::MAX).to_bits());
}

fn main() {
    let mut rng = SplitMix64::new(0x51D0);
    println!("default block kernel on this host: {}", block_kernel_name());

    let mut kt = Table::new(&["kernel case", "per-point", "blocked scalar", "blocked default", "speedup"]);
    for d in [2usize, 3, 8] {
        kernel_row::<f32>(&mut rng, d, &mut kt);
        kernel_row::<f64>(&mut rng, d, &mut kt);
    }
    kt.print();
    println!("(distances per second per core; speedup = per-point vs blocked default)");

    let mut tt = Table::new(&["tree query", "forced scalar", "default kernel", "speedup"]);
    for n in [50_000usize, 200_000] {
        tree_rows(n, 2, &mut tt);
    }
    tt.print();
    println!("(speedup > 1 means the SIMD leaf sweep wins; identical results asserted)");
}
