//! Backend crossover study (repo addition, exercises L1/L2/runtime): the
//! AOT-compiled tensorized Θ(n²) DPC vs the tree engine as n grows, i.e.
//! where the coordinator's Auto routing threshold should sit.
//!
//! The Θ(n²) engine is the "Original DPC" row of Table 1 — better constants
//! (dense matmul), worse asymptotics. Expect XLA to win or tie at small n
//! and lose badly by n ~ 10^4 (and remember: this CPU PJRT runs the Pallas
//! kernels in interpret-lowered HLO; on a real TPU the crossover moves
//! right but the asymptotics still win).
//!
//!   make artifacts && cargo bench --bench xla_crossover

use std::sync::Arc;
use std::time::Instant;

use parcluster::bench::{fmt_secs, Table};
use parcluster::dpc::{compute_density, dep, DensityAlgo, DepAlgo};
use parcluster::geom::PointSet;
use parcluster::prng::SplitMix64;
use parcluster::runtime::{artifacts_available, artifacts_dir, XlaService};

fn grid_points(seed: u64, n: usize) -> PointSet {
    let mut rng = SplitMix64::new(seed);
    let side = (4.0 * (n as f64).sqrt()) as u64 + 2;
    let coords: Vec<f64> = (0..n * 2).map(|_| rng.next_below(side) as f64).collect();
    PointSet::new(coords, 2)
}

fn main() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let svc = XlaService::start(&artifacts_dir()).expect("xla service");
    let sizes = [256usize, 512, 1024, 2048, 4096, 8192];
    let d_cut = 8.0;

    let mut table = Table::new(&["n", "xla steps1+2", "tree steps1+2", "tree/xla", "agree"]);
    println!("# XLA brute-force vs tree engine (steps 1+2), integer-grid 2-d data");
    for &n in &sizes {
        let pts = Arc::new(grid_points(7 + n as u64, n));

        // Warm both paths once (XLA compile is cached per padded size).
        let _ = svc.run(Arc::clone(&pts), d_cut).unwrap();
        let t0 = Instant::now();
        let xla_out = svc.run(Arc::clone(&pts), d_cut).unwrap();
        let xla_s = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let rho = compute_density(&pts, d_cut, DensityAlgo::TreePruned);
        let deps = dep::compute_dependents(&pts, &rho, 0.0, DepAlgo::Priority);
        let tree_s = t1.elapsed().as_secs_f64();

        let agree = xla_out.rho == rho && xla_out.dep == deps;
        table.row(vec![
            n.to_string(),
            fmt_secs(xla_s),
            fmt_secs(tree_s),
            format!("{:.2}x", tree_s / xla_s),
            if agree { "yes".into() } else { "NO".into() },
        ]);
        eprintln!("done: n={n}");
    }
    table.print();
    println!("\n# Routing guidance: set coordinator xla_threshold near the n where tree/xla < 1.");
}
