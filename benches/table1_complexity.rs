//! Table 1 empirical validation: machine-independent *work* (tree nodes
//! visited per query) and *span proxy* (max traversal depth) measured with
//! instrumented traversals, as n grows.
//!
//! What Table 1 predicts (average case, uniform data):
//!  - density, pruned kd-tree: per-query visited nodes ~ O(n^(1-1/d) + rho)
//!    — sublinear growth, far below the unpruned variant;
//!  - priority-NN (DPC-PRIORITY): O(log n) per query -> visited-node count
//!    grows ~ +const per 4x n;
//!  - Fenwick query (DPC-FENWICK): O(log^2 n) per query;
//!  - span proxy: max depth O(log n) for all balanced structures, but the
//!    *sequential chain* of exact-baseline/incomplete is n queries long
//!    (their Step-2 span is O(n log n)).
//!
//!   cargo bench --bench table1_complexity

use parcluster::bench::Table;
use parcluster::datasets::synthetic;
use parcluster::dpc::{compute_density, priority_key, DensityAlgo};
use parcluster::fenwick::FenwickDep;
use parcluster::kdtree::{KdTree, Stats};
use parcluster::pskd::PriorityKdTree;

fn main() {
    let sizes: Vec<usize> = std::env::var("PARBENCH_SIZES")
        .ok()
        .map(|s| s.split(',').map(|t| t.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![4_000, 16_000, 64_000, 256_000]);
    let d_cut = 30.0;
    let sample = 512; // queries sampled per measurement

    let mut table = Table::new(&[
        "n",
        "density.pruned nodes/q",
        "density.noprune nodes/q",
        "priority-NN nodes/q",
        "fenwick nodes/q",
        "max depth (kd)",
        "max depth (pskd est)",
    ]);

    println!("# Table 1 empirical work/span: instrumented traversal counters on uniform-like simden");
    let mut prev: Option<(f64, f64, f64, f64)> = None;
    let mut ratios = Vec::new();
    for &n in &sizes {
        let pts = synthetic::simden(n, 2, 42);
        let tree = KdTree::build(&pts);
        let rho = compute_density(&pts, d_cut, DensityAlgo::TreePruned);
        let gamma: Vec<u64> = rho.iter().enumerate().map(|(i, &r)| priority_key(r, i as u32)).collect();
        let pskd = PriorityKdTree::build(&pts, &gamma);
        let fen = FenwickDep::build(&pts, &gamma);

        let step = (n / sample).max(1);
        let mut s_pruned = Stats::default();
        let mut s_noprune = Stats::default();
        let mut s_pnn = Stats::default();
        let mut s_fen = Stats::default();
        let mut count = 0u64;
        for i in (0..n).step_by(step) {
            let q = pts.point(i);
            tree.range_count(q, d_cut * d_cut, &mut s_pruned);
            tree.range_count_noprune(q, d_cut * d_cut, &mut s_noprune);
            pskd.priority_nn(q, gamma[i], &mut s_pnn);
            fen.query(i as u32, &mut s_fen);
            count += 1;
        }
        let per = |s: &Stats| s.nodes_visited as f64 / count as f64;
        let row = (per(&s_pruned), per(&s_noprune), per(&s_pnn), per(&s_fen));
        table.row(vec![
            n.to_string(),
            format!("{:.1}", row.0),
            format!("{:.1}", row.1),
            format!("{:.1}", row.2),
            format!("{:.1}", row.3),
            s_pruned.max_depth.to_string(),
            pskd.depth().to_string(),
        ]);
        if let Some(p) = prev {
            ratios.push((n, row.0 / p.0, row.1 / p.1, row.2 / p.2, row.3 / p.3));
        }
        prev = Some(row);
        eprintln!("done: n={n}");
    }
    table.print();

    println!("\n# Growth per 4x n (work-bound check):");
    println!("#   O(log n)   -> ratio ~1.0-1.3   (priority-NN)");
    println!("#   O(log^2 n) -> ratio ~1.2-1.6   (fenwick)");
    println!("#   O(sqrt n)  -> ratio ~2.0       (unpruned density upper shape)");
    let mut t2 = Table::new(&["n", "density.pruned x", "density.noprune x", "priority x", "fenwick x"]);
    for (n, a, b, c, d) in ratios {
        t2.row(vec![n.to_string(), format!("{a:.2}"), format!("{b:.2}"), format!("{c:.2}"), format!("{d:.2}")]);
    }
    t2.print();
    println!("\n# Span proxy: kd max depth and pskd depth should grow ~ log n (add ~2 per 4x n).");
}
