//! Re-cut amortization bench: threshold-only `ClusterSession::cut()` vs a
//! fresh full `Dpc::run` at the same parameters — the serving-time win the
//! staged session exists for (the Rodriguez–Laio workflow re-cuts the same
//! dataset many times while the analyst reads the decision graph).
//!
//!   cargo bench --bench recut_latency
//!   PARBENCH_N=200000 cargo bench --bench recut_latency
//!
//! Expected: re-cut latency ≥10x below the full rerun at n = 100k (the cut
//! is a mask + union-find pass; the rerun pays kd-tree build + density +
//! dependent points again), and the gap widens with n.

use parcluster::bench::{fmt_secs, time_median, Table};
use parcluster::datasets::synthetic;
use parcluster::dpc::{ClusterSession, DepAlgo, Dpc, DpcParams};

fn main() {
    let n: usize = std::env::var("PARBENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(100_000);
    let trials: usize = std::env::var("PARBENCH_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let d_cut = 30.0;
    let pts = synthetic::simden(n, 2, 42);

    // The threshold sweep an analyst would drive from the decision graph.
    let sweeps: &[(f64, f64)] = &[(0.0, 100.0), (5.0, 100.0), (0.0, 300.0), (10.0, 50.0)];

    let mut session = ClusterSession::build(&pts).expect("build session");
    session.density(d_cut).expect("density");
    session.dependents(DepAlgo::Priority).expect("dependents");

    println!("# Re-cut latency vs full rerun on simden n={n} (median of {trials})");
    let mut table = Table::new(&["rho_min", "delta_min", "full run", "session cut", "speedup", "identical"]);
    let mut worst_speedup = f64::INFINITY;
    for &(rho_min, delta_min) in sweeps {
        let params = DpcParams { d_cut, rho_min, delta_min, ..DpcParams::default() };
        let full_s = time_median(trials, || {
            std::hint::black_box(Dpc::new(params).dep_algo(DepAlgo::Priority).run(&pts).expect("cluster"));
        });
        let cut_s = time_median(trials, || {
            std::hint::black_box(session.cut(rho_min, delta_min).expect("cut"));
        });
        let fresh = Dpc::new(params).dep_algo(DepAlgo::Priority).run(&pts).expect("cluster");
        let recut = session.cut(rho_min, delta_min).expect("cut");
        let identical = fresh.labels == recut.labels
            && fresh.rho == recut.rho
            && fresh.dep == recut.dep
            && fresh.delta == recut.delta
            && fresh.centers == recut.centers;
        let speedup = full_s / cut_s.max(1e-12);
        worst_speedup = worst_speedup.min(speedup);
        table.row(vec![
            format!("{rho_min}"),
            format!("{delta_min}"),
            fmt_secs(full_s),
            fmt_secs(cut_s),
            format!("{speedup:.1}x"),
            if identical { "yes".into() } else { "NO".into() },
        ]);
        eprintln!("done: rho_min={rho_min} delta_min={delta_min}");
    }
    table.print();
    let stats = session.stats();
    println!(
        "\n# session artifacts computed once: density x{}, dependents x{} (for {} timed cuts)",
        stats.density_computes,
        stats.dep_computes,
        sweeps.len()
    );
    println!("# worst-case speedup across the sweep: {worst_speedup:.1}x (target: >= 10x at n=100k)");
    if worst_speedup < 10.0 {
        eprintln!("WARNING: amortization below the 10x target");
        std::process::exit(1);
    }
}
