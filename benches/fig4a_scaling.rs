//! Figure 4a reproduction: total runtime vs dataset size on `simden`, with
//! fitted log-log slopes (the paper reports slopes: exact-baseline 1.31,
//! approx 0.94, fenwick 1.02, incomplete 1.05, priority 0.94).
//!
//!   cargo bench --bench fig4a_scaling

use parcluster::bench::{fmt_secs, loglog_slope, time_once, Table};
use parcluster::datasets::synthetic;
use parcluster::dpc::approx::run_approx;
use parcluster::dpc::{Dpc, DensityAlgo, DepAlgo, DpcParams};

fn main() {
    let sizes: Vec<usize> = std::env::var("PARBENCH_SIZES")
        .ok()
        .map(|s| s.split(',').map(|t| t.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![1_000, 4_000, 16_000, 64_000]);
    let params = DpcParams { d_cut: 30.0, rho_min: 0.0, delta_min: 100.0, ..DpcParams::default() };

    let mut headers: Vec<String> = vec!["algo".into()];
    headers.extend(sizes.iter().map(|n| format!("n={n}")));
    headers.push("slope".into());
    let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());

    let runs: Vec<(&str, Box<dyn Fn(&parcluster::geom::PointSet) -> f64>)> = vec![
        (
            "exact-baseline",
            Box::new(move |pts| {
                time_once(|| {
                    Dpc::new(params)
                        .dep_algo(DepAlgo::ExactBaseline)
                        .density_algo(DensityAlgo::BaselineIncremental)
                        .run(pts)
                        .expect("cluster")
                })
                .0
            }),
        ),
        ("approx-baseline", Box::new(move |pts| time_once(|| run_approx(pts, params)).0)),
        ("fenwick", Box::new(move |pts| time_once(|| Dpc::new(params).dep_algo(DepAlgo::Fenwick).run(pts).expect("cluster")).0)),
        ("incomplete", Box::new(move |pts| time_once(|| Dpc::new(params).dep_algo(DepAlgo::Incomplete).run(pts).expect("cluster")).0)),
        ("priority", Box::new(move |pts| time_once(|| Dpc::new(params).dep_algo(DepAlgo::Priority).run(pts).expect("cluster")).0)),
    ];

    println!("# Figure 4a: total runtime (s) on simden vs n, log-log slope fit");
    for (name, run) in &runs {
        let mut times = Vec::new();
        for &n in &sizes {
            let pts = synthetic::simden(n, 2, 42);
            times.push(run(&pts));
            eprintln!("done: {name} n={n}");
        }
        let slope = loglog_slope(&sizes.iter().map(|&n| n as f64).collect::<Vec<_>>(), &times);
        let mut row = vec![name.to_string()];
        row.extend(times.iter().map(|&t| fmt_secs(t)));
        row.push(format!("{slope:.2}"));
        table.row(row);
    }
    table.print();
    println!("\n# Paper slopes: base 1.31 | approx 0.94 | fenwick 1.02 | incomplete 1.05 | priority 0.94");
    println!("# Shape check: exact-baseline steepest; priority/fenwick near-linear.");
}
