//! Serve-surface throughput/latency bench: the loadgen harness against an
//! in-process TCP server, sweeping connection counts — the numbers for
//! EXPERIMENTS.md §Serve.
//!
//!   cargo bench --bench serve_throughput
//!   PARBENCH_N=500 PARBENCH_OPS=50 cargo bench --bench serve_throughput
//!
//! Expected: throughput grows with connections until the coordinator's
//! worker pool saturates (requests on one connection are strictly
//! serial — concurrency comes from more connections), and p99 stays
//! bounded because admission control sheds load as `Busy` (counted
//! separately, retried by the harness) instead of queueing unboundedly.

use std::sync::Arc;

use parcluster::bench::Table;
use parcluster::coordinator::{Coordinator, CoordinatorConfig};
use parcluster::serve::loadgen::{run, LoadgenOpts};
use parcluster::serve::{server, ServeState};

fn main() {
    let n: u64 = std::env::var("PARBENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(300);
    let ops: usize = std::env::var("PARBENCH_OPS").ok().and_then(|v| v.parse().ok()).unwrap_or(25);
    let workers: usize = std::env::var("PARBENCH_WORKERS").ok().and_then(|v| v.parse().ok()).unwrap_or(4);

    let cfg = CoordinatorConfig {
        artifacts_dir: std::path::PathBuf::from("/nonexistent"),
        workers,
        ..CoordinatorConfig::default()
    };
    let state = Arc::new(ServeState::new(Coordinator::start(cfg).expect("coordinator")));
    let handle = server::spawn("127.0.0.1:0", Arc::clone(&state)).expect("bind");
    let addr = handle.local_addr.to_string();

    println!("# Serve throughput: {ops} mixed ops/conn (50% ingest, 50% recut), n={n}/batch, {workers} workers");
    let mut table = Table::new(&["conns", "ops", "busy", "p50 (ms)", "p99 (ms)", "ops/s", "errors"]);
    for conns in [1usize, 2, 4, 8] {
        let report = run(&LoadgenOpts {
            addr: addr.clone(),
            connections: conns,
            ops_per_conn: ops,
            n,
            ..LoadgenOpts::default()
        });
        table.row(vec![
            conns.to_string(),
            report.ops.to_string(),
            report.busy.to_string(),
            format!("{:.2}", report.p50.as_secs_f64() * 1e3),
            format!("{:.2}", report.p99.as_secs_f64() * 1e3),
            format!("{:.1}", report.ops_per_sec),
            (report.proto_errors + report.request_errors).to_string(),
        ]);
        eprintln!("done: {conns} connections");
        if report.proto_errors > 0 {
            eprintln!("ERROR: {} protocol errors at {conns} connections", report.proto_errors);
            handle.shutdown();
            std::process::exit(1);
        }
    }
    table.print();
    println!("\n# paste the row matching the EXPERIMENTS.md §Serve template (conns=4)");
    handle.shutdown();
}
