//! Figure 6 (Appendix D) reproduction: effect of d_cut on DPC-PRIORITY's
//! runtime. X-axis = average fraction of points within the d_cut radius
//! (like the paper), series = total / density / dependent-point time.
//!
//! Expected shape: density time grows steeply with d_cut (larger query
//! balls intersect more cells); dependent-point time grows weakly (only via
//! fewer skipped noise points); total tracks density.
//!
//!   cargo bench --bench fig6_dcut

use parcluster::bench::{fmt_secs, Table};
use parcluster::datasets;
use parcluster::dpc::{compute_density, dep, DensityAlgo, DepAlgo};
use std::time::Instant;

fn main() {
    let n: usize = std::env::var("PARBENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(20_000);
    let cases: &[(&str, &[f64])] = &[
        ("uniform", &[10.0, 30.0, 60.0, 120.0]),
        ("simden", &[10.0, 30.0, 60.0, 120.0]),
        ("gowalla", &[0.01, 0.03, 0.1, 0.3]),
        ("sensor", &[0.1, 0.2, 0.4, 0.8]),
    ];

    let mut table = Table::new(&["dataset", "d_cut", "avg % in radius", "density", "dep", "total"]);
    println!("# Figure 6: DPC-PRIORITY runtime vs d_cut (n={n} per dataset)");
    for &(name, dcuts) in cases {
        let ds = datasets::by_name(name, Some(n), 42).expect("dataset");
        for &d_cut in dcuts {
            let t0 = Instant::now();
            let rho = compute_density(&ds.pts, d_cut, DensityAlgo::TreePruned);
            let density_s = t0.elapsed().as_secs_f64();
            let avg_pct = 100.0 * rho.iter().map(|&r| r as f64).sum::<f64>() / (n as f64) / (n as f64);
            let t1 = Instant::now();
            let deps = dep::compute_dependents(&ds.pts, &rho, ds.params.rho_min, DepAlgo::Priority);
            let dep_s = t1.elapsed().as_secs_f64();
            std::hint::black_box(&deps);
            table.row(vec![
                name.into(),
                format!("{d_cut}"),
                format!("{avg_pct:.3}%"),
                fmt_secs(density_s),
                fmt_secs(dep_s),
                fmt_secs(density_s + dep_s),
            ]);
            eprintln!("done: {name} d_cut={d_cut}");
        }
    }
    table.print();
    println!("\n# Shape check: density time increases with d_cut (Fig 6b); dep time only");
    println!("# weakly correlated (Fig 6c); total follows density (Fig 6a).");
}
