//! Durability overhead bench: what the write-ahead journal costs on the
//! ingest path, how fast recovery replays, and what a checkpoint buys.
//!
//!   cargo bench --bench journal_replay
//!   PARBENCH_N=200000 cargo bench --bench journal_replay
//!
//! Four questions, one table each:
//!
//! 1. **Append cost** — journaling an ingest batch under each fsync
//!    policy (1 = per-append, 64 = group commit, 0 = never), with and
//!    without segment rotation. The fsync-1 row is the durability
//!    ceiling: it bounds acknowledged-command latency, and group commit
//!    should close most of the gap to fsync-0. Rotation adds one extra
//!    fsync + create per segment boundary and should be noise.
//! 2. **Replay throughput** — `recover` on a journal-only history vs the
//!    live ingests that produced it. Replay runs the same deterministic
//!    ingest path, so it should land near live speed (the journal adds
//!    decode + no fsync).
//! 3. **Checkpoint leverage** — snapshot size and write time for a full
//!    image, an all-ref delta (unchanged forest), and a ~1%-growth delta
//!    (EXPERIMENTS.md §Durability: the delta should scale with what
//!    changed, not with the forest), plus the recovery speedup of
//!    checkpoint+suffix over full replay.

use parcluster::bench::{fmt_secs, time_median, Table};
use parcluster::datasets::synthetic;
use parcluster::dpc::{DensityModel, StreamingSession};
use parcluster::durability::{
    checkpoint::{self, CheckpointData, DynStreamState},
    journal::{self, JournalEntry},
    recovery::recover,
};
use parcluster::geom::{DynPoints, PointSet};
use std::path::PathBuf;
use std::time::Instant;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("parcluster-bench-journal-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn batches(pts: &PointSet, count: usize) -> Vec<PointSet> {
    let (n, d) = (pts.len(), pts.dim());
    let per = n.div_ceil(count);
    let mut out = Vec::new();
    let mut at = 0;
    while at < n {
        let hi = (at + per).min(n);
        out.push(PointSet::new(pts.coords()[at * d..hi * d].to_vec(), d));
        at = hi;
    }
    out
}

/// Total on-disk journal bytes across the segment chain.
fn journal_bytes(dir: &PathBuf) -> u64 {
    journal::list_segments(dir)
        .unwrap_or_default()
        .iter()
        .filter_map(|(_, p)| std::fs::metadata(p).ok())
        .map(|md| md.len())
        .sum()
}

fn main() {
    let n: usize = std::env::var("PARBENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(50_000);
    let trials: usize = std::env::var("PARBENCH_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let d_cut = 30.0;
    let pts = synthetic::simden(n, 2, 42);
    let all = batches(&pts, 10);

    // 1. Append cost per fsync policy × rotation (journal only, no compute).
    println!("# Journal append cost on simden n={n}, 10 batches (median of {trials})");
    let mut table = Table::new(&["fsync_every", "rotate", "journal 10 batches", "per batch", "bytes", "segments"]);
    for (fsync_every, rotate_bytes) in [(1u64, 0u64), (1, 256 << 10), (64, 0), (0, 0)] {
        let dir = tmpdir(&format!("append-{fsync_every}-{rotate_bytes}"));
        let mut bytes = 0u64;
        let mut segments = 0usize;
        let secs = time_median(trials, || {
            let _ = std::fs::remove_dir_all(&dir);
            let mut rec = recover(&dir, fsync_every, rotate_bytes).unwrap();
            rec.writer
                .append(&JournalEntry::OpenStream {
                    stream: 1,
                    dim: 2,
                    dtype: parcluster::geom::Dtype::F64,
                    d_cut,
                    density: DensityModel::CutoffCount,
                })
                .unwrap();
            for b in &all {
                rec.writer
                    .append(&JournalEntry::Ingest {
                        stream: 1,
                        rho_min: 0.0,
                        delta_min: f64::INFINITY,
                        batch: DynPoints::F64(b.clone()),
                    })
                    .unwrap();
            }
            rec.writer.sync().unwrap();
            segments = rec.writer.seq() as usize;
            bytes = journal_bytes(&dir);
        });
        table.row(vec![
            fsync_every.to_string(),
            if rotate_bytes == 0 { "off".into() } else { format!("{} KiB", rotate_bytes >> 10) },
            fmt_secs(secs),
            fmt_secs(secs / all.len() as f64),
            bytes.to_string(),
            segments.to_string(),
        ]);
        let _ = std::fs::remove_dir_all(&dir);
    }
    table.print();

    // 2. Live ingest vs recovery replay of the same history.
    println!("\n# Ingest vs replay on simden n={n} (median of {trials})");
    let live_s = time_median(trials, || {
        let mut s = StreamingSession::<f64>::new(2, d_cut).unwrap();
        for b in &all {
            s.ingest(b).unwrap();
        }
        std::hint::black_box(s.len());
    });
    let dir = tmpdir("replay");
    {
        let mut rec = recover(&dir, 0, 0).unwrap();
        rec.writer
            .append(&JournalEntry::OpenStream {
                stream: 1,
                dim: 2,
                dtype: parcluster::geom::Dtype::F64,
                d_cut,
                density: DensityModel::CutoffCount,
            })
            .unwrap();
        for b in &all {
            rec.writer
                .append(&JournalEntry::Ingest {
                    stream: 1,
                    rho_min: 0.0,
                    delta_min: f64::INFINITY,
                    batch: DynPoints::F64(b.clone()),
                })
                .unwrap();
        }
        rec.writer.sync().unwrap();
    }
    let replay_s = time_median(trials, || {
        let rec = recover(&dir, 0, 0).unwrap();
        std::hint::black_box(rec.streams.len());
    });
    let mut table = Table::new(&["path", "time", "points/s"]);
    table.row(vec!["live ingest".into(), fmt_secs(live_s), format!("{:.0}", n as f64 / live_s)]);
    table.row(vec!["full replay".into(), fmt_secs(replay_s), format!("{:.0}", n as f64 / replay_s)]);

    // 3. Checkpoint leverage: full image, all-ref delta, ~1%-growth delta.
    {
        let mut rec = recover(&dir, 0, 0).unwrap();
        let (_, stream) = rec.streams.pop().expect("stream recovered");
        let mut stream = stream;
        let state = match &stream {
            parcluster::durability::DynStream::F64(s) => DynStreamState::F64(s.export_state()),
            parcluster::durability::DynStream::F32(s) => DynStreamState::F32(s.export_state()),
        };
        let data = CheckpointData { streams: vec![(1, state)], sessions: Vec::new() };

        // First write has no predecessor: a fully-inline image.
        let t0 = Instant::now();
        let m_full = checkpoint::write(&dir, &mut rec.writer, &data, 2, 1).unwrap();
        let full_s = t0.elapsed().as_secs_f64();
        let full_size = std::fs::metadata(dir.join(format!("checkpoint-{}.pclc", m_full.checkpoint_seq)))
            .map(|md| md.len())
            .unwrap_or(0);
        table.row(vec!["checkpoint full image".into(), fmt_secs(full_s), format!("{full_size} bytes")]);

        // Unchanged forest: every level refs the predecessor.
        let mut last_seq = m_full.checkpoint_seq;
        let ident_s = time_median(trials, || {
            let m = checkpoint::write(&dir, &mut rec.writer, &data, 2, 1).unwrap();
            last_seq = m.checkpoint_seq;
        });
        let ident_size = std::fs::metadata(dir.join(format!("checkpoint-{last_seq}.pclc")))
            .map(|md| md.len())
            .unwrap_or(0);
        table.row(vec!["checkpoint delta (unchanged)".into(), fmt_secs(ident_s), format!("{ident_size} bytes")]);

        // ~1% more points: only the rebuilt low levels write; the big
        // levels ride along as refs to the previous file.
        let grow = (n / 100).max(1);
        let small = PointSet::new(pts.coords()[..grow * 2].to_vec(), 2);
        rec.writer
            .append(&JournalEntry::Ingest {
                stream: 1,
                rho_min: 0.0,
                delta_min: f64::INFINITY,
                batch: DynPoints::F64(small.clone()),
            })
            .unwrap();
        stream.ingest(&DynPoints::F64(small)).unwrap();
        let grown = match &stream {
            parcluster::durability::DynStream::F64(s) => DynStreamState::F64(s.export_state()),
            parcluster::durability::DynStream::F32(s) => DynStreamState::F32(s.export_state()),
        };
        let grown_data = CheckpointData { streams: vec![(1, grown)], sessions: Vec::new() };
        let t0 = Instant::now();
        let m_delta = checkpoint::write(&dir, &mut rec.writer, &grown_data, 2, 1).unwrap();
        let delta_s = t0.elapsed().as_secs_f64();
        let delta_size = std::fs::metadata(dir.join(format!("checkpoint-{}.pclc", m_delta.checkpoint_seq)))
            .map(|md| md.len())
            .unwrap_or(0);
        table.row(vec![
            format!("checkpoint delta (+{grow} pts)"),
            fmt_secs(delta_s),
            format!("{delta_size} bytes ({:.1}% of full)", 100.0 * delta_size as f64 / full_size.max(1) as f64),
        ]);
    }
    let ckpt_replay_s = time_median(trials, || {
        let rec = recover(&dir, 0, 0).unwrap();
        assert!(rec.report.checkpoint_seq > 0);
        std::hint::black_box(rec.streams.len());
    });
    table.row(vec![
        "checkpoint restore".into(),
        fmt_secs(ckpt_replay_s),
        format!("{:.0}", n as f64 / ckpt_replay_s),
    ]);
    table.print();

    let jlen = journal_bytes(&dir);
    println!("\njournal size: {jlen} bytes for {n} points in {} batches", all.len());
    println!(
        "checkpoint restore vs full replay: {:.1}x",
        replay_s / ckpt_replay_s.max(1e-9)
    );
    let _ = std::fs::remove_dir_all(&dir);
}
