//! Durability overhead bench: what the write-ahead journal costs on the
//! ingest path, how fast recovery replays, and what a checkpoint buys.
//!
//!   cargo bench --bench journal_replay
//!   PARBENCH_N=200000 cargo bench --bench journal_replay
//!
//! Three questions, one table each:
//!
//! 1. **Append cost** — journaling an ingest batch under each fsync
//!    policy (1 = per-append, 64 = group commit, 0 = never). The fsync-1
//!    row is the durability ceiling: it bounds acknowledged-command
//!    latency, and group commit should close most of the gap to fsync-0.
//! 2. **Replay throughput** — `recover` on a journal-only history vs the
//!    live ingests that produced it. Replay runs the same deterministic
//!    ingest path, so it should land near live speed (the journal adds
//!    decode + no fsync).
//! 3. **Checkpoint leverage** — snapshot size and write time, and the
//!    recovery speedup of checkpoint+suffix over full replay.

use parcluster::bench::{fmt_secs, time_median, Table};
use parcluster::datasets::synthetic;
use parcluster::dpc::{DensityModel, StreamingSession};
use parcluster::durability::{
    checkpoint::{self, CheckpointData, DynStreamState},
    journal::{JournalEntry, JOURNAL_FILE},
    recovery::recover,
};
use parcluster::geom::{DynPoints, PointSet};
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("parcluster-bench-journal-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn batches(pts: &PointSet, count: usize) -> Vec<PointSet> {
    let (n, d) = (pts.len(), pts.dim());
    let per = n.div_ceil(count);
    let mut out = Vec::new();
    let mut at = 0;
    while at < n {
        let hi = (at + per).min(n);
        out.push(PointSet::new(pts.coords()[at * d..hi * d].to_vec(), d));
        at = hi;
    }
    out
}

fn main() {
    let n: usize = std::env::var("PARBENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(50_000);
    let trials: usize = std::env::var("PARBENCH_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let d_cut = 30.0;
    let pts = synthetic::simden(n, 2, 42);
    let all = batches(&pts, 10);

    // 1. Append cost per fsync policy (journal only, no compute).
    println!("# Journal append cost on simden n={n}, 10 batches (median of {trials})");
    let mut table = Table::new(&["fsync_every", "journal 10 batches", "per batch", "bytes"]);
    for fsync_every in [1u64, 64, 0] {
        let dir = tmpdir(&format!("append-{fsync_every}"));
        let mut bytes = 0u64;
        let secs = time_median(trials, || {
            let _ = std::fs::remove_dir_all(&dir);
            let mut rec = recover(&dir, fsync_every).unwrap();
            rec.writer
                .append(&JournalEntry::OpenStream {
                    stream: 1,
                    dim: 2,
                    dtype: parcluster::geom::Dtype::F64,
                    d_cut,
                    density: DensityModel::CutoffCount,
                })
                .unwrap();
            for b in &all {
                rec.writer
                    .append(&JournalEntry::Ingest {
                        stream: 1,
                        rho_min: 0.0,
                        delta_min: f64::INFINITY,
                        batch: DynPoints::F64(b.clone()),
                    })
                    .unwrap();
            }
            rec.writer.sync().unwrap();
            bytes = rec.writer.len();
        });
        table.row(vec![
            fsync_every.to_string(),
            fmt_secs(secs),
            fmt_secs(secs / all.len() as f64),
            bytes.to_string(),
        ]);
        let _ = std::fs::remove_dir_all(&dir);
    }
    table.print();

    // 2. Live ingest vs recovery replay of the same history.
    println!("\n# Ingest vs replay on simden n={n} (median of {trials})");
    let live_s = time_median(trials, || {
        let mut s = StreamingSession::<f64>::new(2, d_cut).unwrap();
        for b in &all {
            s.ingest(b).unwrap();
        }
        std::hint::black_box(s.len());
    });
    let dir = tmpdir("replay");
    {
        let mut rec = recover(&dir, 0).unwrap();
        rec.writer
            .append(&JournalEntry::OpenStream {
                stream: 1,
                dim: 2,
                dtype: parcluster::geom::Dtype::F64,
                d_cut,
                density: DensityModel::CutoffCount,
            })
            .unwrap();
        for b in &all {
            rec.writer
                .append(&JournalEntry::Ingest {
                    stream: 1,
                    rho_min: 0.0,
                    delta_min: f64::INFINITY,
                    batch: DynPoints::F64(b.clone()),
                })
                .unwrap();
        }
        rec.writer.sync().unwrap();
    }
    let replay_s = time_median(trials, || {
        let rec = recover(&dir, 0).unwrap();
        std::hint::black_box(rec.streams.len());
    });
    let mut table = Table::new(&["path", "time", "points/s"]);
    table.row(vec!["live ingest".into(), fmt_secs(live_s), format!("{:.0}", n as f64 / live_s)]);
    table.row(vec!["full replay".into(), fmt_secs(replay_s), format!("{:.0}", n as f64 / replay_s)]);

    // 3. Checkpoint: write cost, size, and the recovery it buys.
    {
        let mut rec = recover(&dir, 0).unwrap();
        let (_, stream) = rec.streams.pop().expect("stream recovered");
        let state = match stream {
            parcluster::durability::DynStream::F64(s) => DynStreamState::F64(s.export_state()),
            parcluster::durability::DynStream::F32(s) => DynStreamState::F32(s.export_state()),
        };
        let data = CheckpointData { streams: vec![(1, state)], sessions: Vec::new() };
        let ckpt_s = time_median(trials, || {
            // Rewrites the checkpoint file each trial; the manifest flip
            // keeps exactly one live.
            std::hint::black_box(checkpoint::write(&dir, &mut rec.writer, &data, 2).unwrap());
        });
        let m = checkpoint::write(&dir, &mut rec.writer, &data, 2).unwrap();
        let size = std::fs::metadata(dir.join(format!("checkpoint-{}.pclc", m.checkpoint_seq)))
            .map(|md| md.len())
            .unwrap_or(0);
        table.row(vec!["checkpoint write".into(), fmt_secs(ckpt_s), format!("{size} bytes")]);
    }
    let ckpt_replay_s = time_median(trials, || {
        let rec = recover(&dir, 0).unwrap();
        assert!(rec.report.checkpoint_seq > 0);
        std::hint::black_box(rec.streams.len());
    });
    table.row(vec![
        "checkpoint restore".into(),
        fmt_secs(ckpt_replay_s),
        format!("{:.0}", n as f64 / ckpt_replay_s),
    ]);
    table.print();

    let jlen = std::fs::metadata(dir.join(JOURNAL_FILE)).map(|m| m.len()).unwrap_or(0);
    println!("\njournal size: {jlen} bytes for {n} points in {} batches", all.len());
    println!(
        "checkpoint restore vs full replay: {:.1}x",
        replay_s / ckpt_replay_s.max(1e-9)
    );
    let _ = std::fs::remove_dir_all(&dir);
}
