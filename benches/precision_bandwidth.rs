//! Precision bandwidth study: f32 vs f64 kd-tree build + density
//! (Step 1) throughput across sizes.
//!
//! The density step is memory-bandwidth-bound (leaf scans + bounds checks
//! stream coordinates), so the f32 store's half-width buffer should
//! approach a 2x win as n leaves cache — this bench locates the crossover.
//! Both runs are *exact at their precision*; on integer-coordinate data
//! they produce identical ρ (asserted here, a live conformance check).
//!
//! ```sh
//! cargo bench --bench precision_bandwidth
//! ```

use std::time::Instant;

use parcluster::bench::{fmt_secs, Table};
use parcluster::dpc::{compute_density, DensityAlgo};
use parcluster::geom::{PointStore, Scalar};
use parcluster::kdtree::KdTree;
use parcluster::prng::SplitMix64;
use parcluster::proputil::gen_grid_points;

/// Median of three timed runs of `f`.
fn med3<F: FnMut() -> f64>(mut f: F) -> f64 {
    let mut t = [f(), f(), f()];
    t.sort_by(|a, b| a.partial_cmp(b).unwrap());
    t[1]
}

fn timed_build_density<S: Scalar>(pts: &PointStore<S>, d_cut: f64) -> (f64, f64, Vec<u32>) {
    let build_s = med3(|| {
        let t = Instant::now();
        std::hint::black_box(KdTree::build(pts));
        t.elapsed().as_secs_f64()
    });
    let mut rho = Vec::new();
    let density_s = med3(|| {
        let t = Instant::now();
        rho = compute_density(pts, d_cut, DensityAlgo::TreePruned);
        t.elapsed().as_secs_f64()
    });
    (build_s, density_s, rho)
}

fn main() {
    let d = 2;
    let d_cut = 3.0; // integer radius: exact at both precisions
    let mut table = Table::new(&[
        "n",
        "build f64",
        "build f32",
        "build speedup",
        "density f64",
        "density f32",
        "density speedup",
    ]);
    for n in [20_000usize, 80_000, 320_000] {
        let mut rng = SplitMix64::new(0xBA0D + n as u64);
        // Integer grid: the f32 cast is lossless, so rho must match exactly.
        let side = ((n as f64).sqrt() * 2.0) as u64;
        let pts64 = gen_grid_points(&mut rng, n, d, side.max(8));
        let pts32 = PointStore::<f32>::try_lossless_from_f64(&pts64).expect("grid coords are f32-lossless");

        let (b64, q64, rho64) = timed_build_density(&pts64, d_cut);
        let (b32, q32, rho32) = timed_build_density(&pts32, d_cut);
        assert_eq!(rho64, rho32, "precision conformance violated at n={n}");

        table.row(vec![
            n.to_string(),
            fmt_secs(b64),
            fmt_secs(b32),
            format!("{:.2}x", b64 / b32.max(1e-12)),
            fmt_secs(q64),
            fmt_secs(q32),
            format!("{:.2}x", q64 / q32.max(1e-12)),
        ]);
    }
    table.print();
    println!("(speedup > 1 means f32 is faster; expect it to grow with n as the working set leaves cache)");
}
