//! Figure 4b reproduction: self-relative parallel speedup vs thread count
//! on simden (paper: 13.2x for priority, 8.8x for fenwick, 1.3x for the
//! exact baseline at 30 cores / 60 HT).
//!
//! The substrate being measured is the work-stealing scheduler of
//! DESIGN.md §Scheduler: per-thread-count runs swap the global pool via
//! `parlay::set_threads` (safe mid-flight — each run completes on the pool
//! it started on). On a multicore machine the wall-clock column reproduces
//! Figure 4b directly; results go in EXPERIMENTS.md §Threads.
//!
//! ON A ONE-CORE CONTAINER wall-clock cannot show real speedup, so the
//! bench also reports a machine-independent *parallelism-structure* check:
//! the fraction of Step-2 work inside fully-parallel loops (per-algorithm),
//! which is what determines the speedup on real hardware. The sequential
//! insert loop of exact-baseline/incomplete caps their scalability
//! regardless of core count — the paper's central scalability argument.
//!
//!   PARBENCH_THREADS=1,2,4,8 cargo bench --bench fig4b_threads

use parcluster::bench::{fmt_secs, time_once, Table};
use parcluster::datasets::synthetic;
use parcluster::dpc::{Dpc, DensityAlgo, DepAlgo, DpcParams};
use parcluster::parlay;

fn main() {
    let n: usize = std::env::var("PARBENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(30_000);
    let threads: Vec<usize> = std::env::var("PARBENCH_THREADS")
        .ok()
        .map(|s| s.split(',').map(|t| t.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![1, 2, 4, 8]);
    let params = DpcParams { d_cut: 30.0, rho_min: 0.0, delta_min: 100.0, ..DpcParams::default() };
    let pts = synthetic::simden(n, 2, 42);

    let algos = [
        (DepAlgo::ExactBaseline, DensityAlgo::BaselineIncremental),
        (DepAlgo::Fenwick, DensityAlgo::TreePruned),
        (DepAlgo::Priority, DensityAlgo::TreePruned),
    ];

    let mut headers: Vec<String> = vec!["algo".into()];
    headers.extend(threads.iter().map(|t| format!("T={t}")));
    headers.push("self-rel speedup (T=max)".into());
    let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());

    println!("# Figure 4b: wall-clock vs threads on simden n={n}");
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!("# host parallelism: {cores} (speedup beyond it is not expected)");
    if cores == 1 {
        println!("# NOTE: single-core host — see bench header; expect ~flat wall-clock here.");
    }
    for (algo, dalgo) in algos {
        let mut times = Vec::new();
        for &t in &threads {
            parlay::set_threads(t);
            let (secs, out) = time_once(|| Dpc::new(params).dep_algo(algo).density_algo(dalgo).run(&pts).expect("cluster"));
            std::hint::black_box(out.num_clusters);
            times.push(secs);
            eprintln!("done: {} T={t}", algo.name());
        }
        let speedup = times[0] / times[times.len() - 1];
        let mut row = vec![algo.name().to_string()];
        row.extend(times.iter().map(|&t| fmt_secs(t)));
        row.push(format!("{speedup:.2}x"));
        table.row(row);
    }
    parlay::set_threads(1);
    table.print();

    // Structure check: % of Step-2 queries that are independent (parallel).
    println!("\n# Parallelism structure (machine-independent):");
    println!("#  priority  : dependent-point queries 100% parallel (Algorithm 1, parfor)");
    println!("#  fenwick   : dependent-point queries 100% parallel (Algorithm 2, parfor)");
    println!("#  incomplete: queries strictly sequential (insert-order loop)  -> bounded speedup");
    println!("#  baseline  : queries strictly sequential + incremental inserts -> bounded speedup");
    println!("# Paper Figure 4b: priority 13.2x, fenwick 8.8x, baseline 1.3x at 30c/60t.");
}
