// Fixture: decode path (scanned as durability/wire.rs) allocating from a
// wire-supplied length before any bounds check, plus an unaudited index.
pub fn decode(buf: &[u8]) -> Vec<u8> {
    let len = buf[0] as usize;
    let mut out = Vec::with_capacity(len);
    out.extend_from_slice(&buf[1..1 + len]);
    out
}
