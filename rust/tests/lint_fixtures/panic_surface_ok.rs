// Fixture: every panic site is justified, poison-exempt, or in a test.
pub fn first(xs: &[u32]) -> u32 {
    // lint: allow(panic-surface) — fixture: caller guarantees non-empty.
    *xs.first().unwrap()
}

pub fn locked(m: &std::sync::Mutex<u32>) -> u32 {
    // Poison-exempt: .lock().unwrap() needs no allow.
    *m.lock().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn panics_are_fine_in_tests() {
        let xs = [1u32];
        assert_eq!(*xs.first().unwrap(), 1);
    }
}
