// Fixture: the same decode with bounds audits tying each allocation and
// index to the length check that precedes it.
pub fn decode(buf: &[u8]) -> Option<Vec<u8>> {
    if buf.is_empty() {
        return None;
    }
    // bounds: the is_empty guard above proves index 0 exists.
    let len = buf[0] as usize;
    if buf.len() < 1 + len {
        return None;
    }
    // bounds: len is covered by the buf.len() check above, so the
    // allocation never exceeds bytes actually received.
    let mut out = Vec::with_capacity(len);
    // bounds: same check covers the 1..1+len range.
    out.extend_from_slice(&buf[1..1 + len]);
    Some(out)
}
