// Fixture: production-path panics without suppression comments.
pub fn first(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}

pub fn named(xs: &[u32]) -> u32 {
    *xs.first().expect("non-empty")
}

pub fn boom() {
    panic!("explicit panic in production code");
}
