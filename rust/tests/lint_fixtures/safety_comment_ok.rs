// Fixture: unsafe sites carry SAFETY comments (or a `# Safety` doc
// section for unsafe fns).
pub fn head(xs: &[u32]) -> u32 {
    assert!(!xs.is_empty());
    // SAFETY: the assert above proves index 0 is in bounds.
    unsafe { *xs.get_unchecked(0) }
}

/// Reads one element without a bounds check.
///
/// # Safety
/// `i` must be in bounds for `xs`.
pub unsafe fn head_unchecked(xs: &[u32], i: usize) -> u32 {
    // SAFETY: caller contract — `i < xs.len()`.
    unsafe { *xs.get_unchecked(i) }
}
