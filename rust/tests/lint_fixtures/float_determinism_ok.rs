// Fixture: plain mul+add keeps kernel arithmetic reproducible; an
// explicitly justified FMA is also accepted.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

pub fn fused(a: f64, b: f64, c: f64) -> f64 {
    // lint: allow(float-determinism) — fixture: off the exactness path.
    a.mul_add(b, c)
}
