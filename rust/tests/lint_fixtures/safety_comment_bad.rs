// Fixture: an unsafe block with no SAFETY comment.
pub fn head(xs: &[u32]) -> u32 {
    unsafe { *xs.get_unchecked(0) }
}
