// Fixture: every Relaxed carries a `relaxed:` audit comment.
use std::sync::atomic::{AtomicUsize, Ordering};

pub fn bump(c: &AtomicUsize) -> usize {
    // relaxed: standalone counter — no other memory is published through it.
    c.fetch_add(1, Ordering::Relaxed)
}
