// Fixture: malformed suppressions — unknown rule name, and a missing
// justification. Neither suppresses, and each is itself a violation.
pub fn first(xs: &[u32]) -> u32 {
    // lint: allow(no-such-rule) — unknown rule name.
    *xs.first().unwrap()
}

pub fn second(xs: &[u32]) -> u32 {
    // lint: allow(panic-surface)
    *xs.first().unwrap()
}
