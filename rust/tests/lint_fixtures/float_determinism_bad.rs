// Fixture: FMA in a kernel path (scanned as geom/…) breaks the
// bit-identical-results contract.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        acc = x.mul_add(*y, acc);
    }
    acc
}
