// Fixture: well-formed suppressions — known rule, dash, justification.
pub fn first(xs: &[u32]) -> u32 {
    // lint: allow(panic-surface) — fixture: em-dash separator form.
    *xs.first().unwrap()
}

pub fn second(xs: &[u32]) -> u32 {
    // lint: allow(panic-surface) -- fixture: double-dash separator form.
    *xs.first().unwrap()
}
