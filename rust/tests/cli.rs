//! End-to-end checks of the `parcluster` binary's error paths: bad input
//! must exit with a typed message and status 1, never a panic backtrace.

use std::process::Command;

fn parcluster(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_parcluster")).args(args).output().expect("spawn parcluster")
}

#[test]
fn unknown_dataset_is_a_typed_error_not_a_panic() {
    for args in [
        &["cluster", "--dataset", "no-such-dataset"][..],
        &["generate", "--dataset", "no-such-dataset", "--out", "/dev/null"][..],
        &["decision", "--dataset", "no-such-dataset"][..],
    ] {
        let out = parcluster(args);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert_eq!(out.status.code(), Some(1), "{args:?}: status {:?}\nstderr: {stderr}", out.status);
        assert!(stderr.contains("unknown dataset"), "{args:?}: stderr was {stderr:?}");
        assert!(!stderr.contains("panicked"), "{args:?}: CLI panicked:\n{stderr}");
    }
}

#[test]
fn unknown_command_and_missing_input_fail_cleanly() {
    let out = parcluster(&["frobnicate"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));

    let out = parcluster(&["cluster"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--dataset"));
}

#[test]
fn datasets_inventory_prints_every_registry_row() {
    // The inventory loop routes through the same typed-error path; with a
    // healthy registry it must succeed and list the canonical names.
    let out = parcluster(&["datasets", "--n", "64"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    for name in ["name", "d_cut"] {
        assert!(stdout.contains(name), "missing column {name}: {stdout}");
    }
}
