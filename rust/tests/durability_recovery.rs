//! Durability integration suite: the recovery differential gate plus the
//! kill-point matrix.
//!
//! The contract under test (DESIGN.md §Durability): recovering a durable
//! directory yields state **byte-identical** to a fresh build over the
//! concatenated batches — for every density model and dtype, at any
//! thread count, at any segment-rotation threshold — and every corrupted
//! input yields a typed `DpcError::Corrupt*`, never a panic and never a
//! partial parse. Torn tails are legal only in the *final* segment;
//! everything below the manifest's replay horizon is ignorable garbage.

use std::path::PathBuf;
use std::sync::Arc;

use parcluster::coordinator::{Coordinator, CoordinatorConfig, OpenSpec};
use parcluster::dpc::{DensityModel, Dpc, DpcParams, StreamingSession};
use parcluster::durability::{
    checkpoint::{self, CheckpointData, DynStreamState},
    journal::{self, JournalEntry},
    manifest::{self, Manifest, MANIFEST_FILE},
    recovery::{recover, DynStream},
};
use parcluster::error::DpcError;
use parcluster::geom::{Dtype, DynPoints, PointSet};
use parcluster::parlay;
use parcluster::prng::SplitMix64;
use parcluster::proputil::gen_clustered_points;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("parcluster-durability-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Clustered batches (integer-snapped so f32 casts are lossless and the
/// f32/f64 legs can share one expected history).
fn batches(seed: u64, n: usize, splits: &[usize]) -> Vec<PointSet> {
    let mut rng = SplitMix64::new(seed);
    let pts = gen_clustered_points(&mut rng, n, 2, 3, 50.0, 1.8);
    let snapped: Vec<f64> = pts.coords().iter().map(|c| (c * 4.0).round() / 4.0).collect();
    let mut out = Vec::new();
    let mut at = 0;
    for &len in splits {
        out.push(PointSet::new(snapped[at * 2..(at + len) * 2].to_vec(), 2));
        at += len;
    }
    assert_eq!(at, n);
    out
}

/// Journal an OpenStream + every batch (checkpointing after
/// `checkpoint_after` batches if `Some`), rotating segments at
/// `rotate_bytes` (0 = single segment), then "crash" by dropping the
/// writer. Returns the stream id used.
fn write_history(
    dir: &PathBuf,
    dtype: Dtype,
    model: DensityModel,
    all: &[PointSet],
    checkpoint_after: Option<usize>,
    rotate_bytes: u64,
) -> u64 {
    let mut rec = recover(dir, 1, rotate_bytes).unwrap();
    rec.writer
        .append(&JournalEntry::OpenStream { stream: 1, dim: 2, dtype, d_cut: 3.0, density: model })
        .unwrap();
    let mut live32 = StreamingSession::<f32>::new_with_model(2, 3.0, model).unwrap();
    let mut live64 = StreamingSession::<f64>::new_with_model(2, 3.0, model).unwrap();
    for (i, b) in all.iter().enumerate() {
        let batch = DynPoints::F64(b.clone()).cast(dtype);
        rec.writer
            .append(&JournalEntry::Ingest { stream: 1, rho_min: 0.0, delta_min: 20.0, batch: batch.clone() })
            .unwrap();
        match &batch {
            DynPoints::F32(b) => live32.ingest(b).unwrap(),
            DynPoints::F64(b) => live64.ingest(b).unwrap(),
        }
        if checkpoint_after == Some(i + 1) {
            let state = match dtype {
                Dtype::F32 => DynStreamState::F32(live32.export_state()),
                Dtype::F64 => DynStreamState::F64(live64.export_state()),
            };
            let data = CheckpointData { streams: vec![(1, state)], sessions: Vec::new() };
            checkpoint::write(dir, &mut rec.writer, &data, 2, 1).unwrap();
        }
    }
    1
}

/// Fresh (never-crashed) f64 build over the same batches.
fn fresh_f64(model: DensityModel, all: &[PointSet]) -> StreamingSession<f64> {
    let mut s = StreamingSession::<f64>::new_with_model(2, 3.0, model).unwrap();
    for b in all {
        s.ingest(b).unwrap();
    }
    s
}

/// Fresh f32 build over the same batches, cast through the same
/// `DynPoints::cast` the journaled history used.
fn fresh_f32(model: DensityModel, all: &[PointSet]) -> StreamingSession<f32> {
    let mut s = StreamingSession::<f32>::new_with_model(2, 3.0, model).unwrap();
    for b in all {
        let DynPoints::F32(b32) = DynPoints::F64(b.clone()).cast(Dtype::F32) else { unreachable!() };
        s.ingest(&b32).unwrap();
    }
    s
}

/// Assert a recovered f64 stream holds a whole-batch prefix of `all` and
/// matches a fresh build over that prefix bit-for-bit.
fn assert_whole_batch_prefix(got: &StreamingSession<f64>, all: &[PointSet], ctx: &str) {
    let mut fresh =
        StreamingSession::<f64>::new_with_model(2, 3.0, DensityModel::CutoffCount).unwrap();
    for b in all {
        if fresh.len() + b.len() > got.len() {
            break;
        }
        fresh.ingest(b).unwrap();
    }
    assert_eq!(got.len(), fresh.len(), "{ctx}: prefix is whole batches");
    assert_eq!(got.rho(), fresh.rho(), "{ctx}");
    assert_eq!(got.delta(), fresh.delta(), "{ctx}");
}

/// The PR's acceptance gate: for every density model × dtype, a recovery
/// that stacks a mid-history checkpoint with a journal suffix — across a
/// *rotated* segment chain — produces (ρ, λ, δ) byte-identical to a
/// fresh build on the concatenated batches.
#[test]
fn recovery_differential_every_model_and_dtype() {
    let all = batches(41, 120, &[50, 40, 30]);
    for model in DensityModel::REPRESENTATIVE {
        for dtype in [Dtype::F64, Dtype::F32] {
            let dir = tmpdir(&format!("diff-{model}-{dtype}"));
            // ~1.2 KiB rotation: each f64 ingest frame (~650 B+) lands in
            // its own segment neighbourhood, so the history spans several.
            write_history(&dir, dtype, model, &all, Some(2), 1200);
            let rec = recover(&dir, 1, 1200).unwrap();
            assert_eq!(rec.report.checkpoint_seq, 1, "{model}/{dtype}");
            assert_eq!(rec.report.replayed, 1, "{model}/{dtype}: only the suffix replays");
            assert_eq!(rec.streams.len(), 1, "{model}/{dtype}");
            match &rec.streams[0].1 {
                DynStream::F64(got) => {
                    assert_eq!(dtype, Dtype::F64);
                    let fresh = fresh_f64(model, &all);
                    assert_eq!(got.rho(), fresh.rho(), "{model}/f64 rho");
                    assert_eq!(got.dep(), fresh.dep(), "{model}/f64 dep");
                    assert_eq!(got.delta(), fresh.delta(), "{model}/f64 delta");
                    assert_eq!(got.level_sizes(), fresh.level_sizes(), "{model}/f64 forest shape");
                }
                DynStream::F32(got) => {
                    assert_eq!(dtype, Dtype::F32);
                    let fresh = fresh_f32(model, &all);
                    assert_eq!(got.rho(), fresh.rho(), "{model}/f32 rho");
                    assert_eq!(got.dep(), fresh.dep(), "{model}/f32 dep");
                    assert_eq!(got.delta(), fresh.delta(), "{model}/f32 delta");
                    assert_eq!(got.level_sizes(), fresh.level_sizes(), "{model}/f32 forest shape");
                }
            }
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }
}

/// Recovery replays through the same deterministic parallel paths the live
/// server runs, so the thread count cannot change the recovered bytes:
/// a 1-thread and an 8-thread recovery agree with each other and with a
/// 1-thread fresh build.
#[test]
fn replay_is_thread_count_invariant() {
    let all = batches(43, 150, &[60, 50, 40]);
    let dir = tmpdir("threads");
    write_history(&dir, Dtype::F64, DensityModel::Epanechnikov, &all, None, 0);
    let prev = parlay::num_threads();
    parlay::set_threads(1);
    let fresh = fresh_f64(DensityModel::Epanechnikov, &all);
    let rec1 = recover(&dir, 1, 0).unwrap();
    parlay::set_threads(8);
    let rec8 = recover(&dir, 1, 0).unwrap();
    parlay::set_threads(prev);
    let (DynStream::F64(s1), DynStream::F64(s8)) = (&rec1.streams[0].1, &rec8.streams[0].1) else {
        panic!("f64 streams")
    };
    assert_eq!(s1.rho(), s8.rho());
    assert_eq!(s1.dep(), s8.dep());
    assert_eq!(s1.delta(), s8.delta());
    assert_eq!(s1.rho(), fresh.rho());
    assert_eq!(s1.dep(), fresh.dep());
    assert_eq!(s1.delta(), fresh.delta());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Kill point 1 — torn final frame: an append cut mid-write is silently
/// truncated; everything before it recovers, and the journal accepts new
/// appends at the truncation point.
#[test]
fn torn_final_frame_is_truncated_not_fatal() {
    let all = batches(47, 90, &[40, 30, 20]);
    let dir = tmpdir("torn");
    write_history(&dir, Dtype::F64, DensityModel::CutoffCount, &all, None, 0);
    let jpath = dir.join(journal::segment_file(1));
    let len = std::fs::metadata(&jpath).unwrap().len();
    // Cut the last frame short (well past its 8-byte prefix, well short of
    // its end) — the canonical kill -9 mid-append.
    let f = std::fs::OpenOptions::new().write(true).open(&jpath).unwrap();
    f.set_len(len - 37).unwrap();
    drop(f);

    let mut rec = recover(&dir, 1, 0).unwrap();
    assert!(rec.report.torn_bytes > 0, "the cut frame is torn, not corrupt");
    assert_eq!(rec.report.replayed, 3, "open + first two ingests survive");
    let DynStream::F64(got) = &rec.streams[0].1 else { panic!("f64 stream") };
    let fresh = fresh_f64(DensityModel::CutoffCount, &all[..2]);
    assert_eq!(got.rho(), fresh.rho());
    assert_eq!(got.delta(), fresh.delta());

    // The re-armed writer appends where the valid prefix ends; a second
    // recovery then sees the re-written batch.
    rec.writer
        .append(&JournalEntry::Ingest {
            stream: 1,
            rho_min: 0.0,
            delta_min: 20.0,
            batch: DynPoints::F64(all[2].clone()),
        })
        .unwrap();
    drop(rec);
    let rec2 = recover(&dir, 1, 0).unwrap();
    let DynStream::F64(got) = &rec2.streams[0].1 else { panic!("f64 stream") };
    let fresh = fresh_f64(DensityModel::CutoffCount, &all);
    assert_eq!(got.rho(), fresh.rho());
    assert_eq!(got.dep(), fresh.dep());
    assert_eq!(got.delta(), fresh.delta());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Kill points 2–5 — every *corrupted* (not merely torn) input is a typed
/// `DpcError::Corrupt*`: bit-flipped journal CRC, truncated checkpoint,
/// bit-flipped checkpoint, garbage manifest, stale manifest offset.
#[test]
fn corruption_yields_typed_errors_never_partial_state() {
    let all = batches(53, 90, &[40, 30, 20]);

    // Bit-flip inside a complete journal frame -> CorruptJournal.
    let dir = tmpdir("crcflip");
    write_history(&dir, Dtype::F64, DensityModel::CutoffCount, &all, None, 0);
    let jpath = dir.join(journal::segment_file(1));
    let mut bytes = std::fs::read(&jpath).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&jpath, &bytes).unwrap();
    assert!(matches!(recover(&dir, 1, 0), Err(DpcError::CorruptJournal { .. })));
    std::fs::remove_dir_all(&dir).unwrap();

    // Truncated checkpoint -> CorruptCheckpoint (whole-file CRC, no
    // partial parse).
    let dir = tmpdir("ckpttrunc");
    write_history(&dir, Dtype::F64, DensityModel::CutoffCount, &all, Some(2), 0);
    let cpath = dir.join("checkpoint-1.pclc");
    let clen = std::fs::metadata(&cpath).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&cpath).unwrap();
    f.set_len(clen / 2).unwrap();
    drop(f);
    assert!(matches!(recover(&dir, 1, 0), Err(DpcError::CorruptCheckpoint { .. })));
    std::fs::remove_dir_all(&dir).unwrap();

    // Bit-flipped checkpoint -> CorruptCheckpoint.
    let dir = tmpdir("ckptflip");
    write_history(&dir, Dtype::F64, DensityModel::CutoffCount, &all, Some(2), 0);
    let cpath = dir.join("checkpoint-1.pclc");
    let mut bytes = std::fs::read(&cpath).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&cpath, &bytes).unwrap();
    assert!(matches!(recover(&dir, 1, 0), Err(DpcError::CorruptCheckpoint { .. })));
    std::fs::remove_dir_all(&dir).unwrap();

    // Garbage manifest -> CorruptManifest.
    let dir = tmpdir("garbage");
    write_history(&dir, Dtype::F64, DensityModel::CutoffCount, &all, None, 0);
    std::fs::write(dir.join(MANIFEST_FILE), b"not a manifest, definitely").unwrap();
    assert!(matches!(recover(&dir, 1, 0), Err(DpcError::CorruptManifest { .. })));
    std::fs::remove_dir_all(&dir).unwrap();

    // Manifest offset past the named segment's end (a stale manifest
    // restored next to a shorter journal) -> CorruptManifest.
    let dir = tmpdir("stale");
    write_history(&dir, Dtype::F64, DensityModel::CutoffCount, &all, None, 0);
    let jlen = std::fs::metadata(dir.join(journal::segment_file(1))).unwrap().len();
    manifest::write(
        &dir,
        &Manifest {
            checkpoint_seq: 0,
            journal_seq: 1,
            journal_offset: jlen + 512,
            next_lsn: 99,
            next_session_id: 1,
        },
    )
    .unwrap();
    assert!(matches!(recover(&dir, 1, 0), Err(DpcError::CorruptManifest { .. })));
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Tentpole gate — rotation + GC bound the journal: a rotated history
/// spans several segments with contiguous LSNs; a checkpoint at the end
/// flips the manifest horizon forward and deletes every segment strictly
/// below it, and the survivors still recover byte-identical to fresh.
#[test]
fn rotation_spans_segments_and_checkpoint_gc_bounds_disk() {
    let all = batches(73, 120, &[30, 30, 30, 30]);
    let dir = tmpdir("rotate-gc");
    write_history(&dir, Dtype::F64, DensityModel::CutoffCount, &all, None, 1024);
    let segs = journal::list_segments(&dir).unwrap();
    assert!(segs.len() >= 3, "1 KiB rotation must split 4 ingests, got {} segment(s)", segs.len());
    let scan = journal::scan_dir(&dir, 1).unwrap();
    assert_eq!(scan.entries.len(), 5, "open + 4 ingests across the chain");
    for (i, f) in scan.entries.iter().enumerate() {
        assert_eq!(f.lsn, 1 + i as u64, "LSNs contiguous across segment boundaries");
    }

    // Recover the rotated chain, checkpoint at the very end, and the
    // journal's disk footprint collapses to the live segment.
    let mut rec = recover(&dir, 1, 1024).unwrap();
    assert_eq!(rec.report.segments, segs.len());
    let DynStream::F64(got) = &rec.streams[0].1 else { panic!("f64 stream") };
    let fresh = fresh_f64(DensityModel::CutoffCount, &all);
    assert_eq!(got.rho(), fresh.rho());
    assert_eq!(got.dep(), fresh.dep());
    assert_eq!(got.delta(), fresh.delta());

    let state = DynStreamState::F64(got.export_state());
    let data = CheckpointData { streams: vec![(1, state)], sessions: Vec::new() };
    let m = checkpoint::write(&dir, &mut rec.writer, &data, 2, 1).unwrap();
    drop(rec);
    let after = journal::list_segments(&dir).unwrap();
    assert!(
        after.iter().all(|&(seq, _)| seq >= m.journal_seq),
        "GC leaves nothing below the replay horizon {} (survivors: {:?})",
        m.journal_seq,
        after.iter().map(|&(s, _)| s).collect::<Vec<_>>()
    );
    assert!(after.len() < segs.len(), "the sweep actually deleted sealed segments");

    // The bounded directory still recovers to the identical state.
    let rec2 = recover(&dir, 1, 1024).unwrap();
    assert_eq!(rec2.report.checkpoint_seq, m.checkpoint_seq);
    assert_eq!(rec2.report.replayed, 0, "horizon is at the end: nothing to replay");
    let DynStream::F64(got2) = &rec2.streams[0].1 else { panic!("f64 stream") };
    assert_eq!(got2.rho(), fresh.rho());
    assert_eq!(got2.dep(), fresh.dep());
    assert_eq!(got2.delta(), fresh.delta());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Kill point 6 — crash *between* manifest flip and the GC sweep: stale
/// segments below the replay horizon are legal leftovers. Recovery must
/// ignore them entirely, and the next sweep removes them.
#[test]
fn gc_leftovers_below_horizon_are_ignored() {
    let all = batches(79, 120, &[30, 30, 30, 30]);
    let dir = tmpdir("gc-crash");
    write_history(&dir, Dtype::F64, DensityModel::CutoffCount, &all, None, 1024);
    // Stash every segment, then checkpoint (which GCs below the horizon).
    let saved: Vec<(u64, Vec<u8>)> = journal::list_segments(&dir)
        .unwrap()
        .into_iter()
        .map(|(seq, path)| (seq, std::fs::read(path).unwrap()))
        .collect();
    assert!(saved.len() >= 3);
    let mut rec = recover(&dir, 1, 1024).unwrap();
    let DynStream::F64(got) = &rec.streams[0].1 else { panic!("f64 stream") };
    let data = CheckpointData {
        streams: vec![(1, DynStreamState::F64(got.export_state()))],
        sessions: Vec::new(),
    };
    let m = checkpoint::write(&dir, &mut rec.writer, &data, 2, 1).unwrap();
    drop(rec);
    assert!(m.journal_seq > 1, "horizon moved past segment 1");

    // "Crash before the sweep finished": resurrect the GC'd segments.
    for (seq, bytes) in &saved {
        if *seq < m.journal_seq {
            std::fs::write(dir.join(journal::segment_file(*seq)), bytes).unwrap();
        }
    }
    let rec2 = recover(&dir, 1, 1024).unwrap();
    assert_eq!(rec2.report.replayed, 0, "leftovers below the horizon never replay");
    let DynStream::F64(got2) = &rec2.streams[0].1 else { panic!("f64 stream") };
    let fresh = fresh_f64(DensityModel::CutoffCount, &all);
    assert_eq!(got2.rho(), fresh.rho());
    assert_eq!(got2.delta(), fresh.delta());

    // The next sweep (any checkpoint) clears the leftovers for good.
    let removed = journal::gc_segments(&dir, m.journal_seq);
    assert!(!removed.is_empty());
    assert!(journal::list_segments(&dir).unwrap().iter().all(|&(s, _)| s >= m.journal_seq));
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Kill point 7 — crash *after* rotation created the successor but before
/// any append reached it: a header-only final segment is a legal empty
/// tail. Recovery replays the sealed predecessors and re-arms the writer
/// at the successor's header.
#[test]
fn header_only_final_segment_is_a_legal_empty_tail() {
    let all = batches(83, 90, &[40, 30, 20]);
    let dir = tmpdir("midrotate-created");
    write_history(&dir, Dtype::F64, DensityModel::CutoffCount, &all, None, 0);
    let scan = journal::scan_dir(&dir, 1).unwrap();
    let (succ, first_lsn) = (scan.last_seq() + 1, scan.next_lsn);
    // Hand-craft the successor exactly as a crashed rotate() leaves it:
    // magic + version + seq + first_lsn, nothing else.
    let mut hdr = Vec::with_capacity(journal::JOURNAL_HEADER_LEN as usize);
    hdr.extend_from_slice(&journal::JOURNAL_MAGIC);
    hdr.extend_from_slice(&journal::JOURNAL_VERSION.to_le_bytes());
    hdr.extend_from_slice(&succ.to_le_bytes());
    hdr.extend_from_slice(&first_lsn.to_le_bytes());
    std::fs::write(dir.join(journal::segment_file(succ)), &hdr).unwrap();

    let mut rec = recover(&dir, 1, 0).unwrap();
    assert_eq!(rec.report.replayed, 4, "open + 3 ingests from the sealed predecessor");
    assert_eq!(rec.report.segments, 2);
    assert_eq!(rec.writer.seq(), succ, "writer re-arms in the empty successor");
    assert!(rec.writer.is_empty());
    assert_eq!(rec.writer.next_lsn(), first_lsn, "LSNs continue across the empty tail");
    let DynStream::F64(got) = &rec.streams[0].1 else { panic!("f64 stream") };
    let fresh = fresh_f64(DensityModel::CutoffCount, &all);
    assert_eq!(got.rho(), fresh.rho());
    assert_eq!(got.delta(), fresh.delta());

    // Appends land in the successor and survive another recovery.
    rec.writer
        .append(&JournalEntry::Ingest {
            stream: 1,
            rho_min: 0.0,
            delta_min: 20.0,
            batch: DynPoints::F64(all[0].clone()),
        })
        .unwrap();
    drop(rec);
    let rec2 = recover(&dir, 1, 0).unwrap();
    assert_eq!(rec2.report.replayed, 5);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Kill point 8 — crash *between* sealing the old segment and creating
/// its successor: the chain just ends at a sealed, whole segment.
/// Recovery reopens it as the live segment and loses only the frames the
/// vanished successor would have held — always a whole-batch prefix.
#[test]
fn missing_successor_segment_recovers_the_sealed_prefix() {
    let all = batches(89, 120, &[30, 30, 30, 30]);
    let dir = tmpdir("midrotate-missing");
    write_history(&dir, Dtype::F64, DensityModel::CutoffCount, &all, None, 1024);
    let segs = journal::list_segments(&dir).unwrap();
    assert!(segs.len() >= 3);
    let (last_seq, last_path) = segs.last().unwrap().clone();
    std::fs::remove_file(&last_path).unwrap();

    let rec = recover(&dir, 1, 1024).unwrap();
    assert_eq!(rec.writer.seq(), last_seq - 1, "writer reopens the sealed predecessor");
    let DynStream::F64(got) = &rec.streams[0].1 else { panic!("f64 stream") };
    assert!(got.len() < 120, "the vanished segment's batches are gone");
    assert_whole_batch_prefix(got, &all, "missing successor");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Kill point 9 — torn tail in a *sealed* (non-final) segment: rotation
/// fsyncs a segment before its successor exists, so a short frame
/// anywhere but the final segment cannot be a crash artifact — it is
/// `CorruptJournal`, never a silent truncation.
#[test]
fn torn_tail_in_sealed_segment_is_corrupt() {
    let all = batches(97, 120, &[30, 30, 30, 30]);
    let dir = tmpdir("sealed-torn");
    write_history(&dir, Dtype::F64, DensityModel::CutoffCount, &all, None, 1024);
    let segs = journal::list_segments(&dir).unwrap();
    assert!(segs.len() >= 3);
    let (_, sealed_path) = &segs[segs.len() - 2];
    let len = std::fs::metadata(sealed_path).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(sealed_path).unwrap();
    f.set_len(len - 9).unwrap();
    drop(f);
    match recover(&dir, 1, 1024) {
        Err(DpcError::CorruptJournal { detail, .. }) => {
            assert!(detail.contains("torn tail"), "wrong detail: {detail}")
        }
        other => panic!("sealed torn tail must be CorruptJournal, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Kill point 10 — a segment missing from the *middle* of the chain (at
/// or above the horizon) is a gap, not a prefix: typed corruption.
#[test]
fn missing_segment_in_chain_is_corrupt() {
    let all = batches(101, 120, &[30, 30, 30, 30]);
    let dir = tmpdir("gap");
    write_history(&dir, Dtype::F64, DensityModel::CutoffCount, &all, None, 1024);
    let segs = journal::list_segments(&dir).unwrap();
    assert!(segs.len() >= 3);
    std::fs::remove_file(&segs[1].1).unwrap();
    assert!(matches!(recover(&dir, 1, 1024), Err(DpcError::CorruptJournal { .. })));
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Incremental checkpoints reassemble across files: a second checkpoint
/// delta-encoded against the first (sharing every unchanged level) must
/// recover byte-identical, and must be far smaller than the full image
/// it supersedes when only a small batch landed in between.
#[test]
fn delta_checkpoints_recover_byte_identical() {
    // 128 then 16: the second ingest leaves the 128-point level's bit set
    // in the Bentley–Saxe counter, so its blob is unchanged and refs.
    let all = batches(103, 144, &[128, 16]);
    let dir = tmpdir("delta");
    let mut rec = recover(&dir, 1, 0).unwrap();
    rec.writer
        .append(&JournalEntry::OpenStream {
            stream: 1,
            dim: 2,
            dtype: Dtype::F64,
            d_cut: 3.0,
            density: DensityModel::CutoffCount,
        })
        .unwrap();
    let mut live = StreamingSession::<f64>::new_with_model(2, 3.0, DensityModel::CutoffCount).unwrap();
    for b in &all {
        rec.writer
            .append(&JournalEntry::Ingest {
                stream: 1,
                rho_min: 0.0,
                delta_min: 20.0,
                batch: DynPoints::F64(b.clone()),
            })
            .unwrap();
        live.ingest(b).unwrap();
        let data = CheckpointData {
            streams: vec![(1, DynStreamState::F64(live.export_state()))],
            sessions: Vec::new(),
        };
        // retain 2 keeps checkpoint 1 around as the delta base.
        checkpoint::write(&dir, &mut rec.writer, &data, 2, 2).unwrap();
    }
    drop(rec);
    let full = std::fs::metadata(dir.join("checkpoint-1.pclc")).unwrap().len();
    let delta = std::fs::metadata(dir.join("checkpoint-2.pclc")).unwrap().len();
    // Checkpoint 2 inlines only the 16-point level (plus the per-point
    // artifact arrays); the 128-point level rides along as a ref.
    assert!(
        delta < full,
        "checkpoint 2 should be a delta (full {full} B, delta {delta} B)"
    );
    let rec2 = recover(&dir, 1, 0).unwrap();
    assert_eq!(rec2.report.checkpoint_seq, 2);
    assert_eq!(rec2.report.replayed, 0);
    let DynStream::F64(got) = &rec2.streams[0].1 else { panic!("f64 stream") };
    let fresh = fresh_f64(DensityModel::CutoffCount, &all);
    assert_eq!(got.rho(), fresh.rho());
    assert_eq!(got.dep(), fresh.dep());
    assert_eq!(got.delta(), fresh.delta());
    assert_eq!(got.level_sizes(), fresh.level_sizes());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// End-to-end through the public serve surface: a durable coordinator that
/// checkpoints, keeps working, and is killed restarts into a state whose
/// recut output matches a never-crashed coordinator's — across a rotated
/// segment chain.
#[test]
fn coordinator_checkpoint_crash_restart_round_trip() {
    let all = batches(59, 120, &[50, 40, 30]);
    let dir = tmpdir("coord");
    let cfg = CoordinatorConfig {
        artifacts_dir: PathBuf::from("/nonexistent"),
        durable_dir: Some(dir.clone()),
        // Rotate aggressively so the restart crosses segment boundaries.
        journal_rotate_bytes: 2048,
        ..CoordinatorConfig::default()
    };
    let sid;
    {
        let coord = Coordinator::start(cfg.clone()).unwrap();
        sid = coord.open_stream(OpenSpec::dim(2, 3.0)).unwrap();
        coord.wait(coord.submit_ingest(sid, Arc::new(all[0].clone()), 0.0, 20.0).unwrap()).unwrap();
        coord.checkpoint_now().unwrap();
        coord.wait(coord.submit_ingest(sid, Arc::new(all[1].clone()), 0.0, 20.0).unwrap()).unwrap();
        // kill -9: drop with a journal suffix past the checkpoint.
    }
    let coord = Coordinator::start(cfg).unwrap();
    let out = coord
        .wait(coord.submit_ingest(sid, Arc::new(all[2].clone()), 0.0, 20.0).unwrap())
        .unwrap();
    let fresh = fresh_f64(DensityModel::CutoffCount, &all);
    let want = fresh.cut(0.0, 20.0).unwrap();
    assert_eq!(out.result.rho, want.rho);
    assert_eq!(out.result.dep, want.dep);
    assert_eq!(out.result.delta, want.delta);
    assert_eq!(out.result.labels, want.labels);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Randomized crash-injection sweep (nightly: `--include-ignored`), over
/// a *segmented* golden layout: pick a random segment, truncate it or
/// flip a random bit; every outcome must be a clean prefix recovery or a
/// typed error — never a panic, never a partially-applied entry.
#[test]
#[ignore = "slow randomized sweep; nightly runs it via --include-ignored"]
fn randomized_crash_injection_sweep() {
    let all = batches(61, 120, &[30, 30, 30, 30]);
    let golden = tmpdir("sweep-golden");
    write_history(&golden, Dtype::F64, DensityModel::CutoffCount, &all, None, 1024);
    let segments: Vec<(u64, Vec<u8>)> = journal::list_segments(&golden)
        .unwrap()
        .into_iter()
        .map(|(seq, path)| (seq, std::fs::read(path).unwrap()))
        .collect();
    assert!(segments.len() >= 3, "golden layout must be segmented");
    let manifest_bytes = std::fs::read(golden.join(MANIFEST_FILE)).unwrap();
    std::fs::remove_dir_all(&golden).unwrap();

    let dir = tmpdir("sweep");
    let mut rng = SplitMix64::new(67);
    for trial in 0..200 {
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(MANIFEST_FILE), &manifest_bytes).unwrap();
        let victim = rng.next_below(segments.len() as u64) as usize;
        for (i, (seq, bytes)) in segments.iter().enumerate() {
            let mut j = bytes.clone();
            if i == victim {
                // Half the trials truncate (a crash mid-append — only
                // legal in the final segment); half flip a bit (a
                // disk/copy fault).
                if trial % 2 == 0 {
                    let cut = rng.next_below(j.len() as u64) as usize;
                    j.truncate(cut);
                } else {
                    let at = rng.next_below(j.len() as u64) as usize;
                    j[at] ^= 1 << rng.next_below(8);
                }
            }
            std::fs::write(dir.join(journal::segment_file(*seq)), &j).unwrap();
        }
        match recover(&dir, 1, 1024) {
            Ok(rec) => {
                // A recovered prefix must be internally consistent: the
                // stream (if its open survived) holds a batch-prefix state
                // that a fresh build can reproduce.
                if let Some((_, DynStream::F64(got))) = rec.streams.first() {
                    assert_whole_batch_prefix(got, &all, &format!("trial {trial}"));
                }
            }
            Err(
                DpcError::CorruptJournal { .. }
                | DpcError::CorruptCheckpoint { .. }
                | DpcError::CorruptManifest { .. },
            ) => {}
            Err(e) => panic!("trial {trial}: non-durability error {e}"),
        }
    }
    let _ = std::fs::remove_dir_all(&dir);

    // Scanning the chain directly (the `journal inspect` path) must also
    // stay calm on a torn final segment: report the tear, don't fail.
    let dir = tmpdir("sweep-scan");
    std::fs::create_dir_all(&dir).unwrap();
    for (seq, bytes) in &segments {
        let mut j = bytes.clone();
        if *seq == segments.last().unwrap().0 {
            j.truncate(j.len() - 3);
        }
        std::fs::write(dir.join(journal::segment_file(*seq)), &j).unwrap();
    }
    let scan = journal::scan_dir(&dir, 1).unwrap();
    assert!(scan.torn_bytes > 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Sessions round-trip through checkpoint + journal too: an OpenSession in
/// the journal suffix is rebuilt by replay with the exact artifacts of a
/// fresh `Dpc` run.
#[test]
fn session_commands_replay_to_fresh_artifacts() {
    let pts = batches(71, 80, &[80]).pop().unwrap();
    let dir = tmpdir("sessions");
    {
        let mut rec = recover(&dir, 1, 0).unwrap();
        rec.writer
            .append(&JournalEntry::OpenSession {
                session: 5,
                d_cut: 3.0,
                density: DensityModel::Epanechnikov,
                pts: DynPoints::F64(pts.clone()),
            })
            .unwrap();
        rec.writer.append(&JournalEntry::Recut { session: 5, rho_min: 8000.0, delta_min: 5.0 }).unwrap();
    }
    let rec = recover(&dir, 1, 0).unwrap();
    assert_eq!(rec.sessions.len(), 1);
    assert_eq!(rec.report.skipped, 0);
    let got = &rec.sessions[0];
    let want = Dpc::new(DpcParams {
        d_cut: 3.0,
        rho_min: 0.0,
        delta_min: f64::INFINITY,
        density: DensityModel::Epanechnikov,
        ..DpcParams::default()
    })
    .run(&pts)
    .unwrap();
    assert_eq!(got.rho, want.rho);
    assert_eq!(got.dep, want.dep);
    assert_eq!(got.delta, want.delta);
    assert_eq!(rec.next_session_id, 6);
    std::fs::remove_dir_all(&dir).unwrap();
}
