//! pallas-lint fixture suite + self-scan.
//!
//! Each rule gets a violating fixture asserted to trip and an
//! allowlisted/clean counterpart asserted quiet. Fixtures are scanned
//! under *virtual* relpaths so the path-scoped rules (kernel FMA, wire
//! safety) see the paths they key on. The final test pins the real
//! `rust/src` tree at zero violations — the same bar the CI `pallas_lint`
//! job enforces.

use std::path::Path;

use parcluster::lint::{scan_source, scan_tree, Rule};

fn rules_hit(relpath: &str, src: &str) -> Vec<Rule> {
    scan_source(relpath, src).into_iter().map(|v| v.rule).collect()
}

#[test]
fn panic_surface_fixture_trips() {
    let hits = rules_hit("dpc/fixture.rs", include_str!("lint_fixtures/panic_surface_bad.rs"));
    assert_eq!(hits.len(), 3, "unwrap, expect, panic! should each trip: {hits:?}");
    assert!(hits.iter().all(|r| *r == Rule::PanicSurface));
}

#[test]
fn panic_surface_fixture_clean() {
    let vs = scan_source("dpc/fixture.rs", include_str!("lint_fixtures/panic_surface_ok.rs"));
    assert!(vs.is_empty(), "allow comment, poison-exempt lock, and test region should all pass: {vs:?}");
}

#[test]
fn float_determinism_fixture_trips() {
    let hits = rules_hit("geom/fixture.rs", include_str!("lint_fixtures/float_determinism_bad.rs"));
    assert_eq!(hits, vec![Rule::FloatDeterminism]);
}

#[test]
fn float_determinism_fixture_clean() {
    let src = include_str!("lint_fixtures/float_determinism_ok.rs");
    let vs = scan_source("geom/fixture.rs", src);
    assert!(vs.is_empty(), "{vs:?}");
    // The same FMA outside a kernel path is not the lint's business.
    let vs = scan_source("serve/fixture.rs", include_str!("lint_fixtures/float_determinism_bad.rs"));
    assert!(vs.is_empty(), "FMA outside geom/kdtree/pskd must not trip: {vs:?}");
}

#[test]
fn relaxed_ordering_fixture_trips() {
    let hits = rules_hit("parlay/fixture.rs", include_str!("lint_fixtures/relaxed_ordering_bad.rs"));
    assert_eq!(hits, vec![Rule::RelaxedOrdering]);
}

#[test]
fn relaxed_ordering_fixture_clean() {
    let vs = scan_source("parlay/fixture.rs", include_str!("lint_fixtures/relaxed_ordering_ok.rs"));
    assert!(vs.is_empty(), "{vs:?}");
}

#[test]
fn wire_safety_fixture_trips() {
    let hits = rules_hit("durability/wire.rs", include_str!("lint_fixtures/wire_safety_bad.rs"));
    assert!(
        hits.contains(&Rule::WireSafety),
        "length-driven allocation before the bounds check must trip: {hits:?}"
    );
    assert!(
        hits.contains(&Rule::PanicSurface),
        "unaudited wire slice indexing must trip: {hits:?}"
    );
}

#[test]
fn wire_safety_fixture_clean() {
    let vs = scan_source("durability/wire.rs", include_str!("lint_fixtures/wire_safety_ok.rs"));
    assert!(vs.is_empty(), "{vs:?}");
    // The same code outside a wire decode path is unconstrained.
    let vs = scan_source("dpc/fixture.rs", include_str!("lint_fixtures/wire_safety_bad.rs"));
    assert!(vs.is_empty(), "wire rules must stay scoped to decode paths: {vs:?}");
}

#[test]
fn safety_comment_fixture_trips() {
    let hits = rules_hit("parlay/fixture.rs", include_str!("lint_fixtures/safety_comment_bad.rs"));
    assert_eq!(hits, vec![Rule::SafetyComment]);
}

#[test]
fn safety_comment_fixture_clean() {
    let vs = scan_source("parlay/fixture.rs", include_str!("lint_fixtures/safety_comment_ok.rs"));
    assert!(vs.is_empty(), "{vs:?}");
}

#[test]
fn allow_grammar_fixture_trips() {
    let hits = rules_hit("dpc/fixture.rs", include_str!("lint_fixtures/allow_grammar_bad.rs"));
    // A malformed allow is itself a violation AND fails to suppress the
    // site it hangs over.
    assert_eq!(hits.iter().filter(|r| **r == Rule::AllowGrammar).count(), 2, "{hits:?}");
    assert_eq!(hits.iter().filter(|r| **r == Rule::PanicSurface).count(), 2, "{hits:?}");
}

#[test]
fn allow_grammar_fixture_clean() {
    let vs = scan_source("dpc/fixture.rs", include_str!("lint_fixtures/allow_grammar_ok.rs"));
    assert!(vs.is_empty(), "both separator forms must parse: {vs:?}");
}

/// The bar CI holds `rust/src` to: zero violations, forever. A failure
/// here reads exactly like the `pallas_lint` binary's output — fix the
/// site or justify it with a suppression comment.
#[test]
fn self_scan_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let vs = scan_tree(&root).expect("rust/src is readable");
    assert!(
        vs.is_empty(),
        "pallas-lint violations in rust/src:\n{}",
        vs.iter().map(|v| format!("  {v}\n")).collect::<String>()
    );
}
