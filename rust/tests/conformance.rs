//! Exactness conformance suite — the paper's headline claim, enforced.
//!
//! 1. **Cross-algorithm**: every `DepAlgo` × `DensityAlgo` combination must
//!    produce identical (ρ, λ, δ, labels) on adversarial input families
//!    (uniform, clustered, duplicate-heavy, collinear).
//! 2. **Streaming**: after every `StreamingSession::ingest`, the maintained
//!    artifacts and any cut must be byte-identical to a fresh
//!    `ClusterSession` on the same prefix, for all five `DepAlgo`s.
//! 3. **Golden snapshot**: a committed dataset + expected labels/centers
//!    under `rust/tests/data/`, so an exactness regression shows as a
//!    readable per-point diff instead of a property-test shrink.
//! 4. **Precision**: on integer-coordinate (f32-lossless) data the f32 and
//!    f64 pipelines — one-shot and streaming — are byte-identical.
//! 5. **Edge cases** for the session/validation layer.

use parcluster::dpc::{
    ClusterSession, DensityAlgo, DensityModel, DepAlgo, Dpc, DpcParams, DpcResult, StreamingSession,
};
use parcluster::error::DpcError;
use parcluster::geom::{Dtype, PointSet, PointStore};
use parcluster::prng::SplitMix64;
use parcluster::proputil::{gen_clustered_points, gen_grid_points, gen_uniform_points};

// ---------------------------------------------------------------------------
// Input families
// ---------------------------------------------------------------------------

const FAMILIES: [&str; 4] = ["uniform", "clustered", "duplicate-heavy", "collinear"];

/// Deterministic generator per (family, seed); n stays small enough for the
/// Θ(n²) reference combinations.
fn gen_family(family: &str, seed: u64, n: usize) -> PointSet {
    let mut rng = SplitMix64::new(seed);
    match family {
        "uniform" => gen_uniform_points(&mut rng, n, 2, 40.0),
        "clustered" => gen_clustered_points(&mut rng, n, 3, 4, 60.0, 2.0),
        "duplicate-heavy" => {
            // A handful of sites, each stamped many times: maximal density
            // ties, so every id-tiebreak path is exercised.
            let sites: Vec<(f64, f64)> = (0..5).map(|_| (rng.uniform(0.0, 20.0), rng.uniform(0.0, 20.0))).collect();
            let mut coords = Vec::with_capacity(n * 2);
            for _ in 0..n {
                let (x, y) = sites[rng.next_below(sites.len() as u64) as usize];
                coords.push(x);
                coords.push(y);
            }
            PointSet::new(coords, 2)
        }
        "collinear" => {
            // Points on one line with irregular (sometimes duplicate)
            // spacing: degenerate bounding boxes in every split dimension.
            let mut coords = Vec::with_capacity(n * 2);
            for _ in 0..n {
                let t = rng.next_below(n as u64 / 2 + 1) as f64;
                coords.push(t);
                coords.push(2.0 * t);
            }
            PointSet::new(coords, 2)
        }
        other => panic!("unknown family {other}"),
    }
}

/// Models whose ρ is a fixed-point kernel mass (up to 4096 per neighbor)
/// rather than a neighbor count — thresholds must scale accordingly.
fn kernel_mass_units(model: DensityModel) -> bool {
    matches!(model, DensityModel::GaussianKernel | DensityModel::Epanechnikov)
}

fn family_d_cut(family: &str) -> f64 {
    match family {
        "uniform" => 4.0,
        "clustered" => 3.0,
        "duplicate-heavy" => 2.0,
        _ => 5.0,
    }
}

fn assert_identical(a: &DpcResult, b: &DpcResult, ctx: &str) {
    assert_eq!(a.rho, b.rho, "{ctx}: rho");
    assert_eq!(a.dep, b.dep, "{ctx}: dep");
    assert_eq!(a.delta, b.delta, "{ctx}: delta");
    assert_eq!(a.labels, b.labels, "{ctx}: labels");
    assert_eq!(a.centers, b.centers, "{ctx}: centers");
    assert_eq!(a.num_clusters, b.num_clusters, "{ctx}: num_clusters");
    assert_eq!(a.num_noise, b.num_noise, "{ctx}: num_noise");
}

// ---------------------------------------------------------------------------
// 1. Cross-algorithm conformance
// ---------------------------------------------------------------------------

#[test]
fn all_dep_density_combinations_identical_across_families() {
    for seed in [11u64, 12, 13] {
        for family in FAMILIES {
            let n = 80 + (seed as usize % 3) * 40;
            let pts = gen_family(family, seed, n);
            let params = DpcParams { d_cut: family_d_cut(family), rho_min: 2.0, delta_min: 6.0, ..DpcParams::default() };
            let reference = Dpc::new(params)
                .dep_algo(DepAlgo::Naive)
                .density_algo(DensityAlgo::Naive)
                .run(&pts)
                .unwrap();
            for dep_algo in DepAlgo::ALL {
                for density_algo in DensityAlgo::ALL {
                    let out = Dpc::new(params).dep_algo(dep_algo).density_algo(density_algo).run(&pts).unwrap();
                    assert_identical(
                        &out,
                        &reference,
                        &format!("{family} seed={seed} {dep_algo:?}×{density_algo:?}"),
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 2. Streaming conformance: every ingest state equals a fresh build
// ---------------------------------------------------------------------------

#[test]
fn streaming_state_matches_fresh_session_for_all_dep_algos() {
    for family in FAMILIES {
        let pts = gen_family(family, 77, 140);
        let d = pts.dim();
        let d_cut = family_d_cut(family);
        let mut stream = StreamingSession::<f64>::new(d, d_cut).unwrap();
        let mut sent = 0usize;
        for bsz in [33usize, 1, 60, 46] {
            let hi = (sent + bsz).min(pts.len());
            let batch = PointSet::new(pts.coords()[sent * d..hi * d].to_vec(), d);
            stream.ingest(&batch).unwrap();
            sent = hi;
            let prefix = PointSet::new(pts.coords()[..hi * d].to_vec(), d);
            let mut fresh = ClusterSession::build(&prefix).unwrap();
            let rho = fresh.density(d_cut).unwrap();
            assert_eq!(stream.rho(), &rho[..], "{family}: rho at {hi}");
            for algo in DepAlgo::ALL {
                let art = fresh.dependents(algo).unwrap();
                assert_eq!(stream.dep(), &art.dep[..], "{family}: dep at {hi} vs {algo:?}");
                assert_eq!(stream.delta(), &art.delta[..], "{family}: delta at {hi} vs {algo:?}");
                for (rho_min, delta_min) in [(0.0, 8.0), (3.0, 4.0)] {
                    let a = stream.cut(rho_min, delta_min).unwrap();
                    let b = fresh.cut(rho_min, delta_min).unwrap();
                    assert_identical(&a, &b, &format!("{family}: cut at {hi} vs {algo:?}"));
                }
            }
        }
        assert_eq!(sent, pts.len());
    }
}

// ---------------------------------------------------------------------------
// 2b. Density-model leg: cross-algorithm and streaming-vs-fresh parity per
//     model (the tentpole's conformance contract).
// ---------------------------------------------------------------------------

/// Every DepAlgo (and the naive-vs-tree density strategies) must agree under
/// every density model — the paper's exactness invariant generalized.
#[test]
fn density_models_conform_across_dep_algos_and_strategies() {
    for family in FAMILIES {
        let pts = gen_family(family, 21, 110);
        for model in DensityModel::REPRESENTATIVE {
            let params = DpcParams {
                d_cut: family_d_cut(family),
                rho_min: if kernel_mass_units(model) { 8000.0 } else { 2.0 },
                delta_min: 6.0,
                density: model,
                ..DpcParams::default()
            };
            let reference = Dpc::new(params)
                .dep_algo(DepAlgo::Naive)
                .density_algo(DensityAlgo::Naive)
                .run(&pts)
                .unwrap();
            for dep_algo in DepAlgo::ALL {
                let out = Dpc::new(params).dep_algo(dep_algo).run(&pts).unwrap();
                assert_identical(&out, &reference, &format!("{family} {model} {dep_algo:?}"));
            }
        }
    }
}

/// Streaming-vs-fresh parity per batch for each density model: the repair
/// path (cutoff, Gaussian) and the recompute path (kNN) both land on the
/// fresh session's bytes.
#[test]
fn streaming_matches_fresh_for_every_density_model() {
    for family in FAMILIES {
        let pts = gen_family(family, 78, 120);
        let d = pts.dim();
        let d_cut = family_d_cut(family);
        for model in DensityModel::REPRESENTATIVE {
            let mut stream = StreamingSession::<f64>::new_with_model(d, d_cut, model).unwrap();
            let mut sent = 0usize;
            for bsz in [31usize, 1, 55, 33] {
                let hi = (sent + bsz).min(pts.len());
                let batch = PointSet::new(pts.coords()[sent * d..hi * d].to_vec(), d);
                stream.ingest(&batch).unwrap();
                sent = hi;
                let prefix = PointSet::new(pts.coords()[..hi * d].to_vec(), d);
                let mut fresh = ClusterSession::build(&prefix).unwrap().with_density_model(model);
                let rho = fresh.density(d_cut).unwrap();
                assert_eq!(stream.rho(), &rho[..], "{family} {model}: rho at {hi}");
                let art = fresh.dependents(DepAlgo::Priority).unwrap();
                assert_eq!(stream.dep(), &art.dep[..], "{family} {model}: dep at {hi}");
                assert_eq!(stream.delta(), &art.delta[..], "{family} {model}: delta at {hi}");
                let (rho_min, delta_min) =
                    if kernel_mass_units(model) { (8000.0, 4.0) } else { (2.0, 4.0) };
                let a = stream.cut(rho_min, delta_min).unwrap();
                let b = fresh.cut(rho_min, delta_min).unwrap();
                assert_identical(&a, &b, &format!("{family} {model}: cut at {hi}"));
            }
            assert_eq!(sent, pts.len());
        }
    }
}

/// f32 ≡ f64 on integer-coordinate data holds for the new models too: the
/// kNN ranks compare exact integer squared distances and the Gaussian
/// weights hash the (identical) widened f64 distance, so precision cannot
/// perturb either.
#[test]
fn f32_and_f64_byte_identical_for_every_density_model() {
    let (pts64, pts32) = integer_points(404, 160, 2);
    for model in DensityModel::REPRESENTATIVE {
        let params = DpcParams {
            d_cut: 3.0,
            rho_min: if kernel_mass_units(model) { 8000.0 } else { 2.0 },
            delta_min: 4.0,
            dtype: Dtype::F64,
            density: model,
        };
        let params32 = DpcParams { dtype: Dtype::F32, ..params };
        let a = Dpc::new(params).run(&pts64).unwrap();
        let b = Dpc::new(params32).run(&pts32).unwrap();
        assert_identical(&a, &b, &format!("f32 vs f64 under {model}"));
    }
}

// ---------------------------------------------------------------------------
// 3. Golden snapshot
// ---------------------------------------------------------------------------

const GOLDEN_INPUT: &str = include_str!("data/golden_input.csv");
const GOLDEN_EXPECTED: &str = include_str!("data/golden_expected.csv");
// `--density cutoff` must stay bit-for-bit identical to the pre-model
// pipeline: the golden snapshot pins the default (cutoff) model explicitly.
const GOLDEN_PARAMS: DpcParams = DpcParams {
    d_cut: 2.0,
    rho_min: 3.0,
    delta_min: 5.0,
    dtype: Dtype::F64,
    density: DensityModel::CutoffCount,
};

struct Golden {
    rho: Vec<u32>,
    dep: Vec<Option<u32>>,
    labels: Vec<i64>,
    centers: Vec<u32>,
}

fn parse_golden() -> (PointSet, Golden) {
    let rows: Vec<Vec<f64>> = GOLDEN_INPUT
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.starts_with('#'))
        .map(|l| l.split(',').map(|c| c.trim().parse::<f64>().expect("coordinate")).collect())
        .collect();
    let pts = PointSet::from_rows(&rows);
    let mut g = Golden { rho: Vec::new(), dep: Vec::new(), labels: Vec::new(), centers: Vec::new() };
    for line in GOLDEN_EXPECTED.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with("id,") {
            continue;
        }
        if let Some(rest) = line.strip_prefix("centers,") {
            g.centers = rest.split_whitespace().map(|c| c.parse().expect("center id")).collect();
            continue;
        }
        let cells: Vec<i64> = line.split(',').map(|c| c.trim().parse().expect("cell")).collect();
        assert_eq!(cells.len(), 4, "expected `id,rho,dep,label`: {line:?}");
        assert_eq!(cells[0] as usize, g.rho.len(), "rows must be in id order");
        g.rho.push(cells[1] as u32);
        g.dep.push(if cells[2] < 0 { None } else { Some(cells[2] as u32) });
        g.labels.push(cells[3]);
    }
    assert_eq!(g.rho.len(), pts.len(), "expected file must cover every input point");
    (pts, g)
}

/// Render a per-point expected-vs-got table for failures — the readable
/// diff this snapshot exists for.
fn golden_diff(golden: &Golden, got: &DpcResult) -> String {
    let mut out = String::from("id | rho exp/got | dep exp/got | label exp/got\n");
    let fmt_dep = |d: Option<u32>| d.map_or("-".to_string(), |j| j.to_string());
    for i in 0..golden.rho.len() {
        let same = golden.rho[i] == got.rho[i] && golden.dep[i] == got.dep[i] && golden.labels[i] == got.labels[i];
        out.push_str(&format!(
            "{:>2} | {:>3} {:>3} | {:>3} {:>3} | {:>5} {:>5} {}\n",
            i,
            golden.rho[i],
            got.rho[i],
            fmt_dep(golden.dep[i]),
            fmt_dep(got.dep[i]),
            golden.labels[i],
            got.labels[i],
            if same { "" } else { "  <-- MISMATCH" },
        ));
    }
    out.push_str(&format!("centers: expected {:?}, got {:?}\n", golden.centers, got.centers));
    out
}

#[test]
fn golden_snapshot_matches_for_every_dep_algo() {
    let (pts, golden) = parse_golden();
    for algo in DepAlgo::ALL {
        let got = Dpc::new(GOLDEN_PARAMS).dep_algo(algo).run(&pts).unwrap();
        let ok = golden.rho == got.rho
            && golden.dep == got.dep
            && golden.labels == got.labels
            && golden.centers == got.centers;
        assert!(ok, "golden snapshot diverged under {algo:?}:\n{}", golden_diff(&golden, &got));
    }
}

#[test]
fn golden_snapshot_matches_streaming_ingest() {
    let (pts, golden) = parse_golden();
    let d = pts.dim();
    let mut stream = StreamingSession::<f64>::new(d, GOLDEN_PARAMS.d_cut).unwrap();
    // One blob per batch, then the stragglers — exercises cross-batch ρ bumps.
    for (lo, hi) in [(0usize, 5usize), (5, 11), (11, 13)] {
        stream.ingest(&PointSet::new(pts.coords()[lo * d..hi * d].to_vec(), d)).unwrap();
    }
    let got = stream.cut(GOLDEN_PARAMS.rho_min, GOLDEN_PARAMS.delta_min).unwrap();
    let ok = golden.rho == got.rho && golden.dep == got.dep && golden.labels == got.labels && golden.centers == got.centers;
    assert!(ok, "golden snapshot diverged after streaming ingest:\n{}", golden_diff(&golden, &got));
}

// ---------------------------------------------------------------------------
// 4. Precision conformance: on integer-coordinate data (losslessly
//    representable in f32) the f32 and f64 pipelines must produce
//    byte-identical DpcResults — every field, every algorithm.
// ---------------------------------------------------------------------------

/// Integer grid points + integer radius: every coordinate, squared
/// distance, and radius is exactly representable at both precisions, so
/// precision cannot perturb a single comparison or tie-break.
fn integer_points(seed: u64, n: usize, d: usize) -> (PointSet, PointStore<f32>) {
    let mut rng = SplitMix64::new(seed);
    let pts64 = gen_grid_points(&mut rng, n, d, 12);
    let pts32 = PointStore::<f32>::try_lossless_from_f64(&pts64).expect("grid coords are f32-lossless");
    (pts64, pts32)
}

#[test]
fn f32_and_f64_pipelines_byte_identical_on_integer_coords() {
    for (seed, n, d) in [(401u64, 150usize, 2usize), (402, 220, 3)] {
        let (pts64, pts32) = integer_points(seed, n, d);
        let params = DpcParams { d_cut: 3.0, rho_min: 2.0, delta_min: 4.0, dtype: Dtype::F64, ..DpcParams::default() };
        let params32 = DpcParams { dtype: Dtype::F32, ..params };
        for dep_algo in DepAlgo::ALL {
            for density_algo in DensityAlgo::ALL {
                let a = Dpc::new(params).dep_algo(dep_algo).density_algo(density_algo).run(&pts64).unwrap();
                let b = Dpc::new(params32).dep_algo(dep_algo).density_algo(density_algo).run(&pts32).unwrap();
                assert_identical(&a, &b, &format!("f32 vs f64 seed={seed} {dep_algo:?}×{density_algo:?}"));
            }
        }
    }
}

#[test]
fn f32_stream_ingest_matches_f32_fresh_and_f64_stream() {
    let (pts64, pts32) = integer_points(403, 180, 2);
    let d = pts64.dim();
    let d_cut = 2.0;
    let mut s64 = StreamingSession::<f64>::new(d, d_cut).unwrap();
    let mut s32 = StreamingSession::<f32>::new(d, d_cut).unwrap();
    let mut sent = 0usize;
    for bsz in [40usize, 1, 75, 64] {
        let hi = (sent + bsz).min(pts64.len());
        let b64 = PointSet::try_new(pts64.coords()[sent * d..hi * d].to_vec(), d).unwrap();
        let b32 = PointStore::<f32>::try_new(pts32.coords()[sent * d..hi * d].to_vec(), d).unwrap();
        s64.ingest(&b64).unwrap();
        s32.ingest(&b32).unwrap();
        sent = hi;
        // Stream-vs-fresh parity at f32 (the satellite's second leg).
        let prefix32 = PointStore::<f32>::try_new(pts32.coords()[..hi * d].to_vec(), d).unwrap();
        let mut fresh32 = ClusterSession::build(&prefix32).unwrap();
        let rho = fresh32.density(d_cut).unwrap();
        assert_eq!(s32.rho(), &rho[..], "f32 stream rho at {hi}");
        let art = fresh32.dependents(DepAlgo::Priority).unwrap();
        assert_eq!(s32.dep(), &art.dep[..], "f32 stream dep at {hi}");
        assert_eq!(s32.delta(), &art.delta[..], "f32 stream delta at {hi}");
        // Cross-precision parity on lossless data: the two streams agree
        // bit for bit after every batch.
        assert_eq!(s32.rho(), s64.rho(), "f32 vs f64 stream rho at {hi}");
        assert_eq!(s32.dep(), s64.dep(), "f32 vs f64 stream dep at {hi}");
        assert_eq!(s32.delta(), s64.delta(), "f32 vs f64 stream delta at {hi}");
        let a = s32.cut(2.0, 3.0).unwrap();
        let b = s64.cut(2.0, 3.0).unwrap();
        assert_identical(&a, &b, &format!("f32 vs f64 stream cut at {hi}"));
    }
    assert_eq!(sent, pts64.len());
}

// ---------------------------------------------------------------------------
// 5. Session/validation edge cases
// ---------------------------------------------------------------------------

#[test]
fn single_point_is_its_own_cluster() {
    let pts = PointSet::new(vec![3.0, 4.0], 2);
    for algo in DepAlgo::ALL {
        let out = Dpc::new(DpcParams { d_cut: 1.0, rho_min: 0.0, delta_min: 10.0, ..DpcParams::default() }).dep_algo(algo).run(&pts).unwrap();
        assert_eq!(out.rho, vec![1], "{algo:?}");
        assert_eq!(out.dep, vec![None]);
        assert!(out.delta[0].is_infinite());
        assert_eq!(out.labels, vec![0]);
        assert_eq!((out.num_clusters, out.num_noise), (1, 0));
    }
}

#[test]
fn all_duplicate_points_collapse_to_one_cluster() {
    let n = 40;
    let pts = PointSet::new(vec![7.0; n * 2], 2);
    for algo in DepAlgo::ALL {
        let out = Dpc::new(DpcParams { d_cut: 1.0, rho_min: 0.0, delta_min: 1.0, ..DpcParams::default() }).dep_algo(algo).run(&pts).unwrap();
        assert!(out.rho.iter().all(|&r| r == n as u32), "{algo:?}");
        // Id tiebreak: point 0 is the unique peak; everyone else depends on
        // it at distance zero.
        assert_eq!(out.dep[0], None);
        assert!(out.dep[1..].iter().all(|&d| d == Some(0)));
        assert!(out.delta[1..].iter().all(|&x| x == 0.0));
        assert_eq!((out.num_clusters, out.num_noise), (1, 0));
        assert!(out.labels.iter().all(|&l| l == 0));
    }
}

#[test]
fn zero_d_cut_is_rejected_everywhere() {
    let pts = PointSet::new(vec![0.0, 0.0, 1.0, 1.0], 2);
    let mut s = ClusterSession::build(&pts).unwrap();
    assert!(matches!(s.density(0.0), Err(DpcError::InvalidParam { name: "d_cut", .. })));
    assert!(matches!(
        Dpc::new(DpcParams { d_cut: 0.0, rho_min: 0.0, delta_min: 1.0, ..DpcParams::default() }).run(&pts),
        Err(DpcError::InvalidParam { name: "d_cut", .. })
    ));
    assert!(matches!(StreamingSession::<f64>::new(2, 0.0), Err(DpcError::InvalidParam { name: "d_cut", .. })));
}

#[test]
fn rho_min_above_max_density_marks_everything_noise() {
    let mut rng = SplitMix64::new(88);
    let pts = gen_clustered_points(&mut rng, 120, 2, 2, 50.0, 2.0);
    let mut s = ClusterSession::build(&pts).unwrap();
    let rho = s.density(4.0).unwrap();
    let over = *rho.iter().max().unwrap() as f64 + 1.0;
    s.dependents(DepAlgo::Priority).unwrap();
    let out = s.cut(over, 5.0).unwrap();
    assert_eq!(out.num_noise, pts.len());
    assert_eq!(out.num_clusters, 0);
    assert!(out.labels.iter().all(|&l| l == -1));
    assert!(out.centers.is_empty());
    assert!(out.dep.iter().all(|d| d.is_none()));
}

#[test]
fn second_radius_invalidates_cached_dep_artifacts() {
    let mut rng = SplitMix64::new(89);
    let pts = gen_uniform_points(&mut rng, 100, 2, 30.0);
    let mut s = ClusterSession::build(&pts).unwrap();
    s.density(3.0).unwrap();
    s.dependents(DepAlgo::Fenwick).unwrap();
    s.cut(0.0, 5.0).unwrap();
    // Re-density at a new radius: the active dependents stage is gone until
    // recomputed, and the fresh stage must match a from-scratch run.
    s.density(6.0).unwrap();
    assert!(matches!(s.cut(0.0, 5.0), Err(DpcError::MissingStage { need: "dependents", .. })));
    s.dependents(DepAlgo::Fenwick).unwrap();
    let recut = s.cut(0.0, 5.0).unwrap();
    let fresh = Dpc::new(DpcParams { d_cut: 6.0, rho_min: 0.0, delta_min: 5.0, ..DpcParams::default() })
        .dep_algo(DepAlgo::Fenwick)
        .run(&pts)
        .unwrap();
    assert_identical(&recut, &fresh, "post-invalidation recut");
    let st = s.stats();
    assert_eq!(st.density_computes, 2);
    assert_eq!(st.dep_computes, 2);
}
