//! End-to-end socket suite: a real TCP server (`serve::server::spawn`)
//! with real `TcpStream` clients, proving
//!
//! 1. results over the wire are **byte-identical** to direct in-process
//!    runs (the repo's exactness contract survives serialization),
//! 2. N concurrent connections of mixed traffic complete with zero
//!    protocol errors (the loadgen harness, self-served),
//! 3. admission control binds over the socket: tenant quotas, the
//!    global handle cap with LRU eviction, and `Busy` backpressure,
//! 4. a corrupt frame kills only its own connection; other connections
//!    and subsequent ones are untouched.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use parcluster::coordinator::{Coordinator, CoordinatorConfig};
use parcluster::datasets;
use parcluster::dpc::{DensityModel, Dpc, DpcParams};
use parcluster::geom::{Dtype, DynPoints, PointStore};
use parcluster::serve::loadgen::{self, Client, LoadgenOpts};
use parcluster::serve::proto::{Request, Response};
use parcluster::serve::{encode_frame, server, ServeState};

fn spawn_server(cfg_mut: impl FnOnce(&mut CoordinatorConfig)) -> (server::ServerHandle, Arc<ServeState>) {
    let mut cfg = CoordinatorConfig {
        artifacts_dir: std::path::PathBuf::from("/nonexistent"),
        workers: 2,
        ..CoordinatorConfig::default()
    };
    cfg_mut(&mut cfg);
    let state = Arc::new(ServeState::new(Coordinator::start(cfg).unwrap()));
    let handle = server::spawn("127.0.0.1:0", Arc::clone(&state)).unwrap();
    (handle, state)
}

fn connect(handle: &server::ServerHandle) -> Client {
    Client::connect(&handle.local_addr.to_string()).unwrap()
}

/// A full-result response over the socket equals a direct `Dpc` run on
/// the same generated points, field for field (dep sentinel unfolded).
#[test]
fn socket_results_are_byte_identical_to_direct_runs() {
    let (handle, _state) = spawn_server(|_| {});
    let mut client = connect(&handle);
    let (dataset, n, d_cut, rho_min, delta_min) = ("simden", 150u64, 3.0, 1.0, 15.0);
    let resp = client
        .call(&Request::Cluster {
            dataset: dataset.into(),
            n,
            d_cut,
            rho_min,
            delta_min,
            algo: None,
            density: DensityModel::CutoffCount,
            full: true,
        })
        .unwrap();
    let Response::Result { clusters, noise, full: Some(got), .. } = resp else {
        panic!("expected a full result, got {resp:?}");
    };

    // Direct run: same dataset generator, same seed (dispatch uses 42).
    let pts = datasets::by_name(dataset, Some(n as usize), 42).unwrap().pts;
    let want = Dpc::new(DpcParams { d_cut, rho_min, delta_min, ..DpcParams::default() }).run(&pts).unwrap();
    assert_eq!(got.rho, want.rho);
    assert_eq!(got.delta, want.delta);
    assert_eq!(got.labels, want.labels);
    assert_eq!(got.centers, want.centers);
    let want_dep: Vec<u32> = want.dep.iter().map(|d| d.map_or(u32::MAX, |v| v)).collect();
    assert_eq!(got.dep, want_dep);
    assert_eq!(clusters, want.num_clusters as u64);
    assert_eq!(noise, want.num_noise as u64);
    handle.shutdown();
}

/// Session lifecycle over the wire: open → recut (full) → close, with
/// the recut equal to a direct session-free run, and a second close a
/// typed error response.
#[test]
fn socket_session_lifecycle_round_trip() {
    let (handle, _state) = spawn_server(|_| {});
    let mut client = connect(&handle);
    let Response::Opened { id, evicted: None } = client
        .call(&Request::OpenSession {
            dataset: "simden".into(),
            n: 120,
            d_cut: 3.0,
            density: DensityModel::CutoffCount,
            tag: "sock".into(),
        })
        .unwrap()
    else {
        panic!("open failed");
    };
    let resp = client
        .call(&Request::Recut { session: id, rho_min: 0.0, delta_min: 20.0, full: true })
        .unwrap();
    let Response::Result { tag, full: Some(got), .. } = resp else { panic!("recut failed: {resp:?}") };
    assert_eq!(tag, "sock", "the open tag is echoed in job outputs");
    let pts = datasets::by_name("simden", Some(120), 42).unwrap().pts;
    let want = Dpc::new(DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() })
        .run(&pts)
        .unwrap();
    assert_eq!(got.labels, want.labels);
    assert_eq!(got.rho, want.rho);

    assert_eq!(client.call(&Request::CloseSession { session: id }).unwrap(), Response::Closed { id });
    let resp = client.call(&Request::CloseSession { session: id }).unwrap();
    assert!(matches!(resp, Response::Error { .. }), "double close: {resp:?}");
    handle.shutdown();
}

/// Streaming over the wire, including the binary-only `IngestPoints`:
/// the stream's cut equals a from-scratch run on the concatenated
/// batches.
#[test]
fn socket_stream_ingest_matches_direct() {
    let (handle, _state) = spawn_server(|_| {});
    let mut client = connect(&handle);
    let Response::Opened { id: stream, .. } = client
        .call(&Request::OpenStream {
            dim: 2,
            d_cut: 3.0,
            density: DensityModel::CutoffCount,
            tag: String::new(),
            dtype: Dtype::F64,
        })
        .unwrap()
    else {
        panic!("stream open failed");
    };
    let b1 = datasets::by_name("simden", Some(80), 1).unwrap().pts;
    let b2 = datasets::by_name("simden", Some(60), 2).unwrap().pts;
    client
        .call(&Request::IngestPoints {
            stream,
            batch: DynPoints::F64(b1.clone()),
            rho_min: 0.0,
            delta_min: 20.0,
            full: false,
        })
        .unwrap();
    let resp = client
        .call(&Request::IngestPoints {
            stream,
            batch: DynPoints::F64(b2.clone()),
            rho_min: 0.0,
            delta_min: 20.0,
            full: true,
        })
        .unwrap();
    let Response::Result { full: Some(got), .. } = resp else { panic!("ingest failed: {resp:?}") };

    let mut coords = b1.coords().to_vec();
    coords.extend_from_slice(b2.coords());
    let all = parcluster::geom::PointSet::new(coords, 2);
    let want = Dpc::new(DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() })
        .run(&all)
        .unwrap();
    assert_eq!(got.labels, want.labels);
    assert_eq!(got.rho, want.rho);
    assert_eq!(got.delta, want.delta);
    assert_eq!(client.call(&Request::CloseStream { stream }).unwrap(), Response::Closed { id: stream });
    handle.shutdown();
}

/// An f32 stream over the wire: the dtype travels in `OpenStream`, f32
/// batches round-trip the binary codec, and a mismatched f64 batch is a
/// typed error response that leaves the connection and stream usable.
#[test]
fn socket_f32_stream_enforces_dtype() {
    let (handle, _state) = spawn_server(|_| {});
    let mut client = connect(&handle);
    let Response::Opened { id: stream, .. } = client
        .call(&Request::OpenStream {
            dim: 2,
            d_cut: 3.0,
            density: DensityModel::CutoffCount,
            tag: "f32-sock".into(),
            dtype: Dtype::F32,
        })
        .unwrap()
    else {
        panic!("f32 stream open failed");
    };
    // An f64 batch into an f32 stream: typed error, nothing enqueued.
    let f64_batch = datasets::by_name("simden", Some(40), 1).unwrap().pts;
    let resp = client
        .call(&Request::IngestPoints {
            stream,
            batch: DynPoints::F64(f64_batch.clone()),
            rho_min: 0.0,
            delta_min: 20.0,
            full: false,
        })
        .unwrap();
    let Response::Error { detail } = resp else { panic!("expected dtype mismatch, got {resp:?}") };
    assert!(detail.contains("f32") && detail.contains("f64"), "{detail}");
    // A matching f32 batch lands and clusters.
    let coords32: Vec<f32> = f64_batch.coords().iter().map(|&c| c as f32).collect();
    let resp = client
        .call(&Request::IngestPoints {
            stream,
            batch: DynPoints::F32(PointStore::new(coords32, 2)),
            rho_min: 0.0,
            delta_min: 20.0,
            full: true,
        })
        .unwrap();
    let Response::Result { full: Some(got), .. } = resp else { panic!("f32 ingest failed: {resp:?}") };
    assert_eq!(got.labels.len(), 40);
    assert_eq!(client.call(&Request::CloseStream { stream }).unwrap(), Response::Closed { id: stream });
    handle.shutdown();
}

/// The acceptance gate: ≥4 concurrent connections of mixed open/ingest/
/// recut/close traffic, zero protocol errors, every op completing.
#[test]
fn loadgen_drives_four_concurrent_connections_clean() {
    let (handle, state) = spawn_server(|_| {});
    let report = loadgen::run(&LoadgenOpts {
        addr: handle.local_addr.to_string(),
        connections: 4,
        ops_per_conn: 6,
        n: 100,
        ..LoadgenOpts::default()
    });
    assert_eq!(report.proto_errors, 0, "protocol errors over the socket");
    assert_eq!(report.request_errors, 0, "request errors under well-formed traffic");
    assert_eq!(report.ops, 4 * 6, "every operation completed");
    assert!(report.p50 <= report.p99);
    assert!(report.ops_per_sec > 0.0);
    // All sessions/streams were closed by the workload's bookends.
    assert_eq!(state.admission.open_handles(), 0);
    handle.shutdown();
    assert!(state.coord.metrics.counter("serve_connections") >= 4);
}

/// Tenant quotas bind per connection-supplied tenant id, over the wire.
#[test]
fn socket_tenant_quota_and_busy_response() {
    let (handle, _state) = spawn_server(|c| c.max_sessions_per_tenant = 1);
    let mut a = connect(&handle);
    assert!(matches!(
        a.call(&Request::Hello { tenant: "acme".into() }).unwrap(),
        Response::Hello { .. }
    ));
    let open = Request::OpenSession {
        dataset: "simden".into(),
        n: 60,
        d_cut: 3.0,
        density: DensityModel::CutoffCount,
        tag: String::new(),
    };
    assert!(matches!(a.call(&open).unwrap(), Response::Opened { .. }));
    let resp = a.call(&open).unwrap();
    let Response::Error { detail } = resp else { panic!("expected quota error, got {resp:?}") };
    assert!(detail.contains("quota"), "{detail}");
    // Another connection with a different tenant gets in.
    let mut b = connect(&handle);
    assert!(matches!(b.call(&Request::Hello { tenant: "zen".into() }).unwrap(), Response::Hello { .. }));
    assert!(matches!(b.call(&open).unwrap(), Response::Opened { .. }));
    handle.shutdown();
}

/// The global cap evicts the LRU idle handle over the wire, and the
/// eviction is reported to the opener.
#[test]
fn socket_global_cap_evicts_lru() {
    let (handle, state) = spawn_server(|c| c.max_open_sessions = 2);
    let mut client = connect(&handle);
    let open = |client: &mut Client| {
        let resp = client
            .call(&Request::OpenSession {
                dataset: "simden".into(),
                n: 60,
                d_cut: 3.0,
                density: DensityModel::CutoffCount,
                tag: String::new(),
            })
            .unwrap();
        let Response::Opened { id, evicted } = resp else { panic!("open failed: {resp:?}") };
        (id, evicted)
    };
    let (first, _) = open(&mut client);
    let (second, _) = open(&mut client);
    // Touch the first so the second is LRU.
    client.call(&Request::Recut { session: first, rho_min: 0.0, delta_min: 20.0, full: false }).unwrap();
    let (_, evicted) = open(&mut client);
    assert_eq!(evicted, Some(second));
    assert!(state.coord.session(second).is_none(), "evicted session was closed on the coordinator");
    assert!(state.coord.session(first).is_some());
    handle.shutdown();
}

/// A corrupt frame (flipped payload byte) gets a final error response and
/// a dropped connection — while a concurrent healthy connection keeps
/// working, and a fresh connection is accepted afterwards.
#[test]
fn corrupt_frame_kills_only_its_own_connection() {
    let (handle, state) = spawn_server(|_| {});
    let addr = handle.local_addr.to_string();
    let mut healthy = connect(&handle);

    // Hand-corrupt a frame on a raw socket.
    let mut sock = TcpStream::connect(&addr).unwrap();
    let mut frame = encode_frame(&Request::Checkpoint.encode()).unwrap();
    let last = frame.len() - 1;
    frame[last] ^= 0xFF;
    sock.write_all(&frame).unwrap();
    // The server sends a best-effort error frame, then closes: read to EOF.
    let mut buf = Vec::new();
    sock.read_to_end(&mut buf).unwrap();
    if !buf.is_empty() {
        let mut fb = parcluster::serve::FrameBuf::new();
        fb.feed(&buf);
        let payload = fb.next_frame().unwrap().expect("one final frame");
        let resp = Response::decode(&payload).unwrap();
        assert!(matches!(resp, Response::Error { .. }), "{resp:?}");
    }

    // The healthy connection is unaffected.
    let resp = healthy.call(&Request::Checkpoint).unwrap();
    assert!(
        matches!(resp, Response::Error { .. }),
        "non-durable checkpoint is a typed error, not a hang: {resp:?}"
    );
    // And new connections still get served.
    let mut fresh = connect(&handle);
    assert!(matches!(fresh.call(&Request::Hello { tenant: "t".into() }).unwrap(), Response::Hello { .. }));
    assert!(state.coord.metrics.counter("serve_proto_errors") >= 1);
    handle.shutdown();
}

/// An undecodable payload inside a *valid* frame answers with an error
/// response and keeps the connection (framing is still synchronized).
#[test]
fn bad_payload_in_valid_frame_keeps_connection() {
    let (handle, _state) = spawn_server(|_| {});
    let addr = handle.local_addr.to_string();
    let mut sock = TcpStream::connect(&addr).unwrap();
    sock.write_all(&encode_frame(&[99, 99, 99]).unwrap()).unwrap(); // bad version/kind
    let mut fb = parcluster::serve::FrameBuf::new();
    let mut chunk = [0u8; 4096];
    let resp = loop {
        if let Some(p) = fb.next_frame().unwrap() {
            break Response::decode(&p).unwrap();
        }
        let n = sock.read(&mut chunk).unwrap();
        assert!(n > 0, "server closed on a recoverable error");
        fb.feed(&chunk[..n]);
    };
    assert!(matches!(resp, Response::Error { .. }), "{resp:?}");
    // Same socket still serves a well-formed request.
    sock.write_all(&encode_frame(&Request::Hello { tenant: "still-here".into() }.encode()).unwrap())
        .unwrap();
    let resp = loop {
        if let Some(p) = fb.next_frame().unwrap() {
            break Response::decode(&p).unwrap();
        }
        let n = sock.read(&mut chunk).unwrap();
        assert!(n > 0, "server closed after recovery");
        fb.feed(&chunk[..n]);
    };
    assert_eq!(resp, Response::Hello { tenant: "still-here".into() });
    handle.shutdown();
}

/// The stdin text surface and the socket binary surface produce the same
/// outcome for the same logical request (shared dispatcher, proven at
/// the transport level: parse a line, send it as binary, compare to the
/// direct dispatch of the same parsed request).
#[test]
fn line_parsed_requests_behave_identically_over_the_socket() {
    let (handle, state) = spawn_server(|_| {});
    let mut client = connect(&handle);
    // Drive the socket with requests parsed FROM TEXT LINES — the stdin
    // grammar — and check the wire results against direct runs.
    let open = Request::from_line("open simden 90 3.0 tag=via-line").unwrap().unwrap();
    let Response::Opened { id, .. } = client.call(&open).unwrap() else { panic!("open failed") };
    let recut = Request::from_line(&format!("recut {id} 1 15 full")).unwrap().unwrap();
    let Response::Result { tag, full: Some(got), .. } = client.call(&recut).unwrap() else {
        panic!("recut failed")
    };
    assert_eq!(tag, "via-line");
    let pts = datasets::by_name("simden", Some(90), 42).unwrap().pts;
    let want = Dpc::new(DpcParams { d_cut: 3.0, rho_min: 1.0, delta_min: 15.0, ..DpcParams::default() })
        .run(&pts)
        .unwrap();
    assert_eq!(got.labels, want.labels);
    let close = Request::from_line(&format!("close {id}")).unwrap().unwrap();
    assert_eq!(client.call(&close).unwrap(), Response::Closed { id });
    assert_eq!(state.admission.open_handles(), 0);
    handle.shutdown();
}
