//! Property-based invariant suite (DESIGN.md §6) over the `proputil`
//! harness: randomized inputs with deterministic replay seeds.

use parcluster::dpc::{self, compute_density, DensityAlgo, DepAlgo, Dpc, DpcParams};
use parcluster::fenwick::{fenwick_decompose, FenwickDep};
use parcluster::geom::PointSet;
use parcluster::kdtree::{brute_nn, brute_range_count, KdTree, NoStats};
use parcluster::parlay;
use parcluster::proputil::{self, Config};
use parcluster::prng::SplitMix64;
use parcluster::pskd::{brute_priority_nn, PriorityKdTree};
use parcluster::unionfind::{same_partition, ConcurrentUnionFind, SeqUnionFind};

/// Wrapper type so the harness can Debug-print failures compactly.
#[derive(Debug)]
struct Case {
    seed: u64,
    n: usize,
    d: usize,
}

fn gen_case(rng: &mut SplitMix64, max_n: usize, max_d: usize) -> Case {
    Case {
        seed: rng.next_u64(),
        n: proputil::gen_size(rng, 2, max_n),
        d: proputil::gen_size(rng, 1, max_d),
    }
}

fn gen_points(c: &Case, flavor: u64) -> PointSet {
    let mut rng = SplitMix64::new(c.seed ^ flavor);
    match flavor % 4 {
        0 => proputil::gen_uniform_points(&mut rng, c.n, c.d, 50.0),
        1 => proputil::gen_clustered_points(&mut rng, c.n, c.d, 1 + c.n / 50, 100.0, 2.0),
        2 => proputil::gen_grid_points(&mut rng, c.n, c.d, 8),
        _ => proputil::gen_degenerate_points(&mut rng, c.n, c.d),
    }
}

// 1. kd-tree NN == brute force.
#[test]
fn prop_kdtree_nn_matches_brute_force() {
    proputil::check("kdtree-nn", Config::cases(40), |rng| gen_case(rng, 400, 5), |c| {
        for flavor in 0..4 {
            let pts = gen_points(c, flavor);
            let tree = KdTree::build(&pts);
            for i in (0..pts.len()).step_by(1 + pts.len() / 16) {
                let got = tree.nn(pts.point(i), i as u32, &mut NoStats);
                let want = brute_nn(&pts, pts.point(i), i as u32);
                if got != want {
                    return Err(format!("flavor {flavor} query {i}: {got:?} != {want:?}"));
                }
            }
        }
        Ok(())
    });
}

// 2. Range count (pruned and unpruned) == brute force.
#[test]
fn prop_range_count_matches_brute_force() {
    proputil::check("range-count", Config::cases(40), |rng| gen_case(rng, 400, 5), |c| {
        let mut rr = SplitMix64::new(c.seed);
        for flavor in 0..4 {
            let pts = gen_points(c, flavor);
            let tree = KdTree::build(&pts);
            for _ in 0..8 {
                let i = rr.next_below(pts.len() as u64) as usize;
                let r = rr.uniform(0.0, 30.0);
                let want = brute_range_count(&pts, pts.point(i), r * r);
                let got = tree.range_count(pts.point(i), r * r, &mut NoStats);
                let got2 = tree.range_count_noprune(pts.point(i), r * r, &mut NoStats);
                if got != want || got2 != want {
                    return Err(format!("flavor {flavor} i={i} r={r}: {got}/{got2} != {want}"));
                }
            }
        }
        Ok(())
    });
}

// 3. Priority-NN == brute force over the higher-priority subset.
#[test]
fn prop_priority_nn_matches_brute_force() {
    proputil::check("priority-nn", Config::cases(30), |rng| gen_case(rng, 300, 4), |c| {
        for flavor in 0..4 {
            let pts = gen_points(c, flavor);
            let mut rng = SplitMix64::new(c.seed ^ 0xFFFF);
            // Priorities with deliberate collisions resolved by packing ids.
            let gamma: Vec<u64> = (0..pts.len())
                .map(|i| (rng.next_below(8) << 32) | (u32::MAX - i as u32) as u64)
                .collect();
            let tree = PriorityKdTree::build(&pts, &gamma);
            if !tree.check_heap_property() {
                return Err("heap property violated".into());
            }
            for i in (0..pts.len()).step_by(1 + pts.len() / 16) {
                let got = tree.priority_nn(pts.point(i), gamma[i], &mut NoStats);
                let want = brute_priority_nn(&pts, &gamma, pts.point(i), gamma[i]);
                if got != want {
                    return Err(format!("flavor {flavor} query {i}: {got:?} != {want:?}"));
                }
            }
        }
        Ok(())
    });
}

// 4. All five dependent-point algorithms agree (the exactness claim).
#[test]
fn prop_all_dep_algorithms_identical() {
    proputil::check("dep-agreement", Config::cases(25), |rng| gen_case(rng, 250, 4), |c| {
        for flavor in 0..4 {
            let pts = gen_points(c, flavor);
            let d_cut = 2.0 + (c.seed % 7) as f64;
            let rho_min = (c.seed % 3) as f64;
            let rho = compute_density(&pts, d_cut, DensityAlgo::TreePruned);
            let reference = dpc::dep::compute_dependents(&pts, &rho, rho_min, DepAlgo::Naive);
            for algo in [DepAlgo::ExactBaseline, DepAlgo::Incomplete, DepAlgo::Priority, DepAlgo::Fenwick] {
                let got = dpc::dep::compute_dependents(&pts, &rho, rho_min, algo);
                if got != reference {
                    let idx = (0..got.len()).find(|&i| got[i] != reference[i]).unwrap();
                    return Err(format!("flavor {flavor} {algo:?} differs at {idx}: {:?} != {:?}", got[idx], reference[idx]));
                }
            }
        }
        Ok(())
    });
}

// 5. Concurrent union-find == sequential DSU. Pins the pool to 4 threads for
// real contention; restores the ambient count afterwards so sibling tests
// keep whatever parallelism the environment (e.g. the PALLAS_THREADS CI
// matrix) configured, instead of being silently degraded to 1.
#[test]
fn prop_concurrent_union_find_matches_sequential() {
    let prev = parlay::num_threads();
    parlay::set_threads(4);
    proputil::check("union-find", Config::cases(30), |rng| {
        let n = proputil::gen_size(rng, 2, 800);
        let m = proputil::gen_size(rng, 1, 1200);
        let ops: Vec<(u32, u32)> =
            (0..m).map(|_| (rng.next_below(n as u64) as u32, rng.next_below(n as u64) as u32)).collect();
        (n, ops)
    }, |(n, ops)| {
        let cuf = ConcurrentUnionFind::new(*n);
        parlay::par_for(ops.len(), |i| cuf.union(ops[i].0, ops[i].1));
        let mut suf = SeqUnionFind::new(*n);
        for &(a, b) in ops {
            suf.union(a, b);
        }
        if !same_partition(&cuf.labels(), &suf.labels()) {
            return Err("partitions differ".into());
        }
        Ok(())
    });
    parlay::set_threads(prev);
}

// 6. Full pipeline: identical labels across all Step-2 algorithms.
#[test]
fn prop_pipeline_labels_identical_across_algorithms() {
    proputil::check("pipeline-labels", Config::cases(15), |rng| gen_case(rng, 200, 3), |c| {
        for flavor in 0..4 {
            let pts = gen_points(c, flavor);
            let params = DpcParams { d_cut: 3.0, rho_min: (c.seed % 3) as f64, delta_min: 5.0, ..DpcParams::default() };
            let reference = Dpc::new(params).dep_algo(DepAlgo::Naive).run(&pts).unwrap();
            for algo in [DepAlgo::ExactBaseline, DepAlgo::Incomplete, DepAlgo::Priority, DepAlgo::Fenwick] {
                let got = Dpc::new(params).dep_algo(algo).run(&pts).unwrap();
                if got.labels != reference.labels {
                    return Err(format!("flavor {flavor} {algo:?}: labels differ"));
                }
                if got.num_clusters != reference.num_clusters || got.num_noise != reference.num_noise {
                    return Err(format!("flavor {flavor} {algo:?}: counts differ"));
                }
            }
        }
        Ok(())
    });
}

// 7. Fenwick decomposition: disjoint cover with O(log) blocks.
#[test]
fn prop_fenwick_decomposition_tiles_prefix() {
    proputil::check("fenwick-decompose", Config::cases(50), |rng| proputil::gen_size(rng, 1, 100_000), |&i| {
        let blocks = fenwick_decompose(i);
        let total: usize = blocks.iter().map(|&j| j & j.wrapping_neg()).sum();
        if total != i {
            return Err(format!("blocks cover {total} != {i}"));
        }
        let maxlen = (usize::BITS - i.leading_zeros()) as usize;
        if blocks.len() > maxlen {
            return Err(format!("{} blocks > log bound {maxlen}", blocks.len()));
        }
        Ok(())
    });
}

// 8. Parallel sorts == std sort.
#[test]
fn prop_sorts_match_std() {
    proputil::check("sorts", Config::cases(20), |rng| {
        let n = proputil::gen_size(rng, 0, 30_000);
        let keys: Vec<u64> = (0..n)
            .map(|_| {
                let bits = 1 + rng.next_below(40);
                rng.next_below(1 << bits)
            })
            .collect();
        keys
    }, |keys| {
        let mut a: Vec<u64> = keys.clone();
        parlay::par_sort_unstable_by(&mut a, |x, y| x.cmp(y));
        let mut want = keys.clone();
        want.sort();
        if a != want {
            return Err("par_sort mismatch".into());
        }
        let mut pairs: Vec<(u64, u32)> = keys.iter().enumerate().map(|(i, &k)| (k, i as u32)).collect();
        let mut want_pairs = pairs.clone();
        want_pairs.sort(); // stable == sort by (key, id) for unique ids
        parlay::par_radix_sort_u64(&mut pairs);
        if pairs != want_pairs {
            return Err("radix sort mismatch".into());
        }
        Ok(())
    });
}

// 9. Fenwick queries == priority-NN brute force even under heavy ties.
#[test]
fn prop_fenwick_matches_brute_with_ties() {
    proputil::check("fenwick-ties", Config::cases(20), |rng| gen_case(rng, 200, 3), |c| {
        let pts = gen_points(c, 3); // degenerate flavor: heavy duplicates
        let mut rng = SplitMix64::new(c.seed ^ 0xABCD);
        let gamma: Vec<u64> = (0..pts.len())
            .map(|i| (rng.next_below(4) << 32) | (u32::MAX - i as u32) as u64)
            .collect();
        let f = FenwickDep::build(&pts, &gamma);
        for i in 0..pts.len() as u32 {
            let got = f.query(i, &mut NoStats);
            let want = brute_priority_nn(&pts, &gamma, pts.point(i as usize), gamma[i as usize]);
            if got != want {
                return Err(format!("query {i}: {got:?} != {want:?}"));
            }
        }
        Ok(())
    });
}

// 10. Decision-graph param suggestion recovers k clusters on blobby data.
#[test]
fn prop_decision_graph_suggestion_recovers_k() {
    proputil::check("decision-k", Config::cases(8), |rng| (rng.next_u64(), 2 + rng.next_below(3) as usize), |&(seed, k)| {
        let mut rng = SplitMix64::new(seed);
        // k well-separated tight blobs.
        let mut coords = Vec::new();
        for b in 0..k {
            let (cx, cy) = (b as f64 * 200.0, (b % 2) as f64 * 200.0);
            for _ in 0..60 {
                coords.push(cx + rng.normal());
                coords.push(cy + rng.normal());
            }
        }
        let pts = PointSet::new(coords, 2);
        let scan = Dpc::new(DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: f64::INFINITY, ..DpcParams::default() }).run(&pts).unwrap();
        let graph = dpc::decision::decision_graph(&scan);
        let (rho_min, delta_min) = dpc::decision::suggest_params(&graph, k).unwrap();
        let out = Dpc::new(DpcParams { d_cut: 3.0, rho_min, delta_min, ..DpcParams::default() }).run(&pts).unwrap();
        if out.num_clusters != k {
            return Err(format!("expected {k} clusters, got {}", out.num_clusters));
        }
        Ok(())
    });
}
