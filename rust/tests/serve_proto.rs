//! Serve-protocol conformance suite.
//!
//! Three contracts, mirroring the durability layer's codec discipline:
//!
//! 1. **Round-trip identity** — `decode(encode(r)) == r` for randomized
//!    requests/responses across the whole enum surface, and
//!    `from_line(to_line(r)) == r` for every line-expressible request
//!    (the stdin surface and the binary surface parse into the *same*
//!    value, so the two transports cannot drift).
//! 2. **Rejection matrix** — truncations at every byte boundary,
//!    bit flips, version/kind garbage, and oversized frames are all
//!    typed errors, never panics and never wrong-value decodes.
//! 3. **Framing taxonomy** — an incomplete frame is "keep reading", a
//!    corrupt frame is a connection-fatal error, exactly like the
//!    journal's torn-tail-vs-corruption split.

use parcluster::dpc::{DensityModel, DepAlgo};
use parcluster::geom::{Dtype, DynPoints, PointSet, PointStore};
use parcluster::prng::SplitMix64;
use parcluster::serve::proto::{FullResult, Request, Response};
use parcluster::serve::{encode_frame, FrameBuf, FrameError, HEADER, MAX_FRAME};

fn gen_density(rng: &mut SplitMix64) -> DensityModel {
    match rng.next_below(4) {
        0 => DensityModel::CutoffCount,
        1 => DensityModel::KnnRadius { k: 1 + rng.next_below(16) as usize },
        2 => DensityModel::GaussianKernel,
        _ => DensityModel::Epanechnikov,
    }
}

fn gen_tag(rng: &mut SplitMix64) -> String {
    // Whitespace-free (the line grammar is token-based); includes the
    // chars the binary codec must pass through untouched.
    let alphabet: Vec<char> = "abcXYZ019_-./:".chars().collect();
    (0..rng.next_below(12)).map(|_| alphabet[rng.next_below(alphabet.len() as u64) as usize]).collect()
}

/// Like [`gen_tag`] but never empty — for fields whose line form has no
/// "absent" rendering (a tenant id is a required positional token).
fn gen_name(rng: &mut SplitMix64) -> String {
    let mut s = gen_tag(rng);
    if s.is_empty() {
        s.push('x');
    }
    s
}

fn gen_f64(rng: &mut SplitMix64) -> f64 {
    // Mix of awkward values: exact decimals, irrationals-ish, extremes.
    match rng.next_below(5) {
        0 => 0.0,
        1 => f64::INFINITY,
        2 => rng.uniform(0.0, 1e-300),
        3 => rng.uniform(0.0, 1e12),
        _ => rng.uniform(0.0, 50.0),
    }
}

fn gen_request(rng: &mut SplitMix64) -> Request {
    match rng.next_below(10) {
        0 => Request::Hello { tenant: gen_name(rng) },
        1 => Request::Cluster {
            dataset: "simden".into(),
            n: rng.next_below(10_000),
            d_cut: gen_f64(rng),
            rho_min: gen_f64(rng),
            delta_min: gen_f64(rng),
            algo: match rng.next_below(6) {
                0 => None,
                i => Some(DepAlgo::ALL[(i - 1) as usize]),
            },
            density: gen_density(rng),
            full: rng.next_below(2) == 1,
        },
        2 => Request::OpenSession {
            dataset: "varden".into(),
            n: rng.next_below(10_000),
            d_cut: gen_f64(rng),
            density: gen_density(rng),
            tag: gen_tag(rng),
        },
        3 => Request::Recut {
            session: rng.next_u64(),
            rho_min: gen_f64(rng),
            delta_min: gen_f64(rng),
            full: rng.next_below(2) == 1,
        },
        4 => Request::CloseSession { session: rng.next_u64() },
        5 => Request::OpenStream {
            dim: 1 + rng.next_below(8) as u32,
            d_cut: gen_f64(rng),
            density: gen_density(rng),
            tag: gen_tag(rng),
            dtype: if rng.next_below(2) == 0 { Dtype::F64 } else { Dtype::F32 },
        },
        6 => Request::Ingest {
            stream: rng.next_u64(),
            dataset: "uniform".into(),
            n: rng.next_below(10_000),
            seed: rng.next_u64(),
            rho_min: gen_f64(rng),
            delta_min: gen_f64(rng),
            full: rng.next_below(2) == 1,
        },
        7 => {
            let d = 1 + rng.next_below(4) as usize;
            let n = 1 + rng.next_below(20) as usize;
            let coords: Vec<f64> = (0..n * d).map(|_| rng.uniform(-100.0, 100.0)).collect();
            // Both dtypes cross the wire; the batch codec is self-tagging.
            let batch = if rng.next_below(2) == 0 {
                DynPoints::F64(PointSet::new(coords, d))
            } else {
                DynPoints::F32(PointStore::new(coords.iter().map(|&c| c as f32).collect(), d))
            };
            Request::IngestPoints {
                stream: rng.next_u64(),
                batch,
                rho_min: gen_f64(rng),
                delta_min: gen_f64(rng),
                full: rng.next_below(2) == 1,
            }
        }
        8 => Request::CloseStream { stream: rng.next_u64() },
        _ => Request::Checkpoint,
    }
}

fn gen_response(rng: &mut SplitMix64) -> Response {
    match rng.next_below(7) {
        0 => Response::Hello { tenant: gen_tag(rng) },
        1 => Response::Opened {
            id: rng.next_u64(),
            evicted: (rng.next_below(2) == 1).then(|| rng.next_u64()),
        },
        2 => {
            let n = rng.next_below(30) as usize;
            Response::Result {
                job: rng.next_u64(),
                tag: gen_tag(rng),
                backend: "rust-tree".into(),
                clusters: rng.next_below(10),
                noise: rng.next_below(30),
                wall_s: gen_f64(rng),
                full: (rng.next_below(2) == 1).then(|| FullResult {
                    rho: (0..n).map(|_| rng.next_below(1 << 20) as u32).collect(),
                    dep: (0..n)
                        .map(|_| if rng.next_below(8) == 0 { u32::MAX } else { rng.next_below(n.max(1) as u64) as u32 })
                        .collect(),
                    delta: (0..n).map(|_| gen_f64(rng)).collect(),
                    labels: (0..n).map(|_| rng.next_below(10) as i64 - 1).collect(),
                    centers: (0..rng.next_below(5) as usize).map(|_| rng.next_below(n.max(1) as u64) as u32).collect(),
                }),
            }
        }
        3 => Response::Closed { id: rng.next_u64() },
        4 => Response::CheckpointTaken {
            seq: rng.next_u64(),
            journal_seq: rng.next_u64(),
            journal_offset: rng.next_u64(),
            next_lsn: rng.next_u64(),
        },
        5 => Response::Busy { detail: gen_tag(rng) },
        _ => Response::Error { detail: gen_tag(rng) },
    }
}

// --- 1. round-trip identity -------------------------------------------------

#[test]
fn prop_request_binary_round_trip_identity() {
    let mut rng = SplitMix64::new(0x5e7_1);
    for case in 0..500 {
        let req = gen_request(&mut rng);
        let back = Request::decode(&req.encode())
            .unwrap_or_else(|e| panic!("case {case}: decode failed: {e} for {req:?}"));
        assert_eq!(back, req, "case {case}");
    }
}

#[test]
fn prop_response_binary_round_trip_identity() {
    let mut rng = SplitMix64::new(0x5e7_2);
    for case in 0..500 {
        let resp = gen_response(&mut rng);
        let back = Response::decode(&resp.encode())
            .unwrap_or_else(|e| panic!("case {case}: decode failed: {e} for {resp:?}"));
        assert_eq!(back, resp, "case {case}");
    }
}

/// The line grammar and the binary codec parse into the same value: for
/// every line-expressible request, text round-trips losslessly (f64
/// `Display` is shortest-round-trip) and agrees with the binary path.
#[test]
fn prop_line_and_binary_surfaces_agree() {
    let mut rng = SplitMix64::new(0x5e7_3);
    let mut line_cases = 0;
    for _ in 0..500 {
        let req = gen_request(&mut rng);
        let Some(line) = req.to_line() else {
            assert!(matches!(req, Request::IngestPoints { .. }), "only IngestPoints is binary-only");
            continue;
        };
        line_cases += 1;
        let from_text = Request::from_line(&line).unwrap().unwrap_or_else(|| panic!("line {line:?} parsed to None"));
        let from_binary = Request::decode(&req.encode()).unwrap();
        assert_eq!(from_text, req, "text round trip for {line:?}");
        assert_eq!(from_binary, from_text, "binary and text disagree for {line:?}");
    }
    assert!(line_cases > 300, "generator should exercise the line grammar ({line_cases} cases)");
}

/// Frames survive arbitrary re-chunking (1-byte drip to jumbo reads).
#[test]
fn prop_framing_survives_rechunking() {
    let mut rng = SplitMix64::new(0x5e7_4);
    let reqs: Vec<Request> = (0..50).map(|_| gen_request(&mut rng)).collect();
    let mut stream = Vec::new();
    for r in &reqs {
        stream.extend_from_slice(&encode_frame(&r.encode()).unwrap());
    }
    for trial in 0..20 {
        let mut fb = FrameBuf::new();
        let mut got = Vec::new();
        let mut at = 0;
        while at < stream.len() {
            let step = 1 + rng.next_below(97) as usize;
            let hi = (at + step).min(stream.len());
            fb.feed(&stream[at..hi]);
            at = hi;
            while let Some(p) = fb.next_frame().unwrap() {
                got.push(Request::decode(&p).unwrap());
            }
        }
        assert_eq!(got, reqs, "trial {trial}");
        assert_eq!(fb.pending(), 0, "trial {trial}");
    }
}

// --- 2. rejection matrix ----------------------------------------------------

/// Every proper prefix of a valid message must fail to decode — a
/// truncation can never yield a wrong value silently.
#[test]
fn prop_every_truncation_is_rejected() {
    let mut rng = SplitMix64::new(0x5e7_5);
    for _ in 0..60 {
        let buf = gen_request(&mut rng).encode();
        for cut in 0..buf.len() {
            assert!(
                Request::decode(&buf[..cut]).is_err(),
                "prefix of {cut}/{} bytes decoded",
                buf.len()
            );
        }
        let buf = gen_response(&mut rng).encode();
        for cut in 0..buf.len() {
            assert!(Response::decode(&buf[..cut]).is_err(), "response prefix {cut} decoded");
        }
    }
}

/// Random bit flips either still decode (the flip hit a value byte — the
/// CRC layer above catches those on the wire) or fail typed; they never
/// panic. Flips in the version or kind byte must always fail.
#[test]
fn prop_bit_flips_never_panic() {
    let mut rng = SplitMix64::new(0x5e7_6);
    for _ in 0..300 {
        let mut buf = gen_request(&mut rng).encode();
        let at = rng.next_below(buf.len() as u64) as usize;
        buf[at] ^= 1 << rng.next_below(8);
        let result = Request::decode(&buf); // must return, not panic
        if at == 0 {
            assert!(result.is_err(), "corrupt version byte accepted");
        }
    }
}

#[test]
fn unknown_version_kind_and_trailing_bytes_are_typed_errors() {
    let good = Request::Recut { session: 1, rho_min: 0.5, delta_min: 2.0, full: false };
    let mut buf = good.encode();
    buf[0] = 99;
    assert!(Request::decode(&buf).unwrap_err().contains("version"));
    let mut buf = good.encode();
    buf[1] = 250;
    assert!(Request::decode(&buf).unwrap_err().contains("kind"));
    let mut buf = good.encode();
    buf.extend_from_slice(&[0, 0, 0]);
    assert!(Request::decode(&buf).unwrap_err().contains("trailing"));
    assert!(Request::decode(&[]).is_err());
    assert!(Response::decode(&[]).is_err());
}

/// A forged length field cannot drive allocation: string/array lengths
/// inside the body are validated against the bytes actually present.
#[test]
fn forged_interior_lengths_are_rejected_without_allocation() {
    // Hello's body is [u32 len][bytes]; claim 2^31 bytes with 5 present.
    let mut buf = vec![1u8, 0u8]; // version, kind=Hello
    buf.extend_from_slice(&(1u32 << 31).to_le_bytes());
    buf.extend_from_slice(b"five!");
    let err = Request::decode(&buf).unwrap_err();
    assert!(!err.is_empty());
}

// --- 3. framing taxonomy ----------------------------------------------------

#[test]
fn incomplete_frames_wait_and_corrupt_frames_kill() {
    let payload = Request::Checkpoint.encode();
    let frame = encode_frame(&payload).unwrap();

    // Incomplete: every prefix of the frame is "keep reading".
    for cut in 0..frame.len() {
        let mut fb = FrameBuf::new();
        fb.feed(&frame[..cut]);
        assert_eq!(fb.next_frame().unwrap(), None, "prefix {cut} should be incomplete");
        assert_eq!(fb.pending(), cut);
    }

    // Corrupt payload byte: CRC mismatch, connection-fatal.
    let mut bad = frame.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x10;
    let mut fb = FrameBuf::new();
    fb.feed(&bad);
    assert!(matches!(fb.next_frame(), Err(FrameError::CrcMismatch { .. })));

    // Oversized length: rejected from the header alone.
    let mut fb = FrameBuf::new();
    let mut huge = (MAX_FRAME + 1).to_le_bytes().to_vec();
    huge.extend_from_slice(&[0; 4]);
    fb.feed(&huge);
    assert!(matches!(fb.next_frame(), Err(FrameError::Oversized { .. })));

    // A valid frame after a partial feed still decodes (header split
    // across reads).
    let mut fb = FrameBuf::new();
    fb.feed(&frame[..HEADER / 2]);
    assert_eq!(fb.next_frame().unwrap(), None);
    fb.feed(&frame[HEADER / 2..]);
    assert_eq!(fb.next_frame().unwrap().unwrap(), payload);
}

/// The full-result payload — the biggest message the protocol ships —
/// round-trips through framing intact, dep sentinel and all.
#[test]
fn full_result_round_trips_through_framing() {
    let n = 10_000usize;
    let full = FullResult {
        rho: (0..n as u32).collect(),
        dep: (0..n as u32).map(|i| if i % 97 == 0 { u32::MAX } else { i / 2 }).collect(),
        delta: (0..n).map(|i| i as f64 * 0.125).collect(),
        labels: (0..n).map(|i| (i % 7) as i64 - 1).collect(),
        centers: vec![0, 97, 194],
    };
    let resp = Response::Result {
        job: 1,
        tag: "big".into(),
        backend: "rust-tree".into(),
        clusters: 6,
        noise: n as u64 / 7,
        wall_s: 1.5,
        full: Some(full),
    };
    let mut fb = FrameBuf::new();
    fb.feed(&encode_frame(&resp.encode()).unwrap());
    let back = Response::decode(&fb.next_frame().unwrap().unwrap()).unwrap();
    assert_eq!(back, resp);
}
