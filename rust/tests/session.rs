//! Session & typed-error suite: the staged `ClusterSession` must (a) turn
//! every malformed-input panic of the old API into a `DpcError`, and (b)
//! produce re-cuts byte-identical to fresh full runs while provably reusing
//! the cached Step-1/2 artifacts.

use std::sync::Arc;

use parcluster::coordinator::{Coordinator, CoordinatorConfig, OpenSpec};
use parcluster::dpc::{ClusterSession, DepAlgo, Dpc, DpcParams, DpcResult};
use parcluster::error::DpcError;
use parcluster::geom::PointSet;
use parcluster::proputil::{self, Config};
use parcluster::prng::SplitMix64;

fn assert_same_result(a: &DpcResult, b: &DpcResult, ctx: &str) {
    assert_eq!(a.rho, b.rho, "{ctx}: rho");
    assert_eq!(a.dep, b.dep, "{ctx}: dep");
    assert_eq!(a.delta, b.delta, "{ctx}: delta");
    assert_eq!(a.labels, b.labels, "{ctx}: labels");
    assert_eq!(a.centers, b.centers, "{ctx}: centers");
    assert_eq!(a.num_clusters, b.num_clusters, "{ctx}: num_clusters");
    assert_eq!(a.num_noise, b.num_noise, "{ctx}: num_noise");
}

// 1. The headline property: a session re-cut at any thresholds equals a
//    fresh full run at the same parameters, field for field, for every
//    Step-2 algorithm and input flavor.
#[test]
fn prop_recut_is_byte_identical_to_fresh_run() {
    proputil::check(
        "recut-equivalence",
        Config::cases(12),
        |rng| (rng.next_u64(), proputil::gen_size(rng, 30, 250)),
        |&(seed, n)| {
            let mut rng = SplitMix64::new(seed);
            let pts = match seed % 3 {
                0 => proputil::gen_uniform_points(&mut rng, n, 2, 50.0),
                1 => proputil::gen_clustered_points(&mut rng, n, 3, 1 + n / 40, 80.0, 2.0),
                _ => proputil::gen_degenerate_points(&mut rng, n, 2),
            };
            let d_cut = 2.0 + (seed % 5) as f64;
            for algo in [DepAlgo::Naive, DepAlgo::Priority, DepAlgo::Fenwick] {
                let mut session = ClusterSession::build(&pts).map_err(|e| e.to_string())?;
                session.density(d_cut).map_err(|e| e.to_string())?;
                session.dependents(algo).map_err(|e| e.to_string())?;
                for (rho_min, delta_min) in [(0.0, 5.0), (2.0, 3.0), (1.0, f64::INFINITY), (3.0, 0.0)] {
                    let recut = session.cut(rho_min, delta_min).map_err(|e| e.to_string())?;
                    let fresh = Dpc::new(DpcParams { d_cut, rho_min, delta_min, ..DpcParams::default() })
                        .dep_algo(algo)
                        .run(&pts)
                        .map_err(|e| e.to_string())?;
                    if recut.rho != fresh.rho
                        || recut.dep != fresh.dep
                        || recut.delta != fresh.delta
                        || recut.labels != fresh.labels
                        || recut.centers != fresh.centers
                    {
                        return Err(format!("{algo:?} rho_min={rho_min} delta_min={delta_min}: recut != fresh"));
                    }
                }
                // Every cut above reused the one cached compute per stage.
                let st = session.stats();
                if st.density_computes != 1 || st.dep_computes != 1 {
                    return Err(format!("artifacts recomputed: {st:?}"));
                }
            }
            Ok(())
        },
    );
}

// 2. Error paths: malformed input must surface as DpcError, never a panic.
#[test]
fn prop_malformed_inputs_are_typed_errors() {
    proputil::check(
        "typed-errors",
        Config::cases(24),
        |rng| (rng.next_u64(), proputil::gen_size(rng, 1, 60)),
        |&(seed, n)| {
            let mut rng = SplitMix64::new(seed);
            // Empty input.
            if !matches!(ClusterSession::build(&PointSet::empty(2)), Err(DpcError::EmptyInput)) {
                return Err("empty: wrong error".into());
            }
            // Ragged flat buffer: n*2 + 1 coords at d = 2.
            let coords: Vec<f64> = (0..n * 2 + 1).map(|_| rng.uniform(0.0, 9.0)).collect();
            if !matches!(PointSet::try_new(coords, 2), Err(DpcError::RaggedCoords { .. })) {
                return Err("ragged buffer: wrong error".into());
            }
            // Ragged rows.
            let mut rows: Vec<Vec<f64>> = (0..n.max(2)).map(|_| vec![rng.next_f64(), rng.next_f64()]).collect();
            rows[n.max(2) - 1].pop();
            if !matches!(PointSet::try_from_rows(&rows), Err(DpcError::DimensionMismatch { .. })) {
                return Err("ragged rows: wrong error".into());
            }
            // NaN / ∞ coordinates at a random position. The validated
            // constructor rejects them at the door ...
            for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
                let mut coords: Vec<f64> = (0..n * 2).map(|_| rng.uniform(0.0, 9.0)).collect();
                let pos = rng.next_below((n * 2) as u64) as usize;
                coords[pos] = bad;
                match PointSet::try_new(coords.clone(), 2) {
                    Err(DpcError::NonFiniteCoordinate { point, dim }) => {
                        if point * 2 + dim != pos {
                            return Err(format!("nonfinite at {pos}: reported ({point}, {dim})"));
                        }
                    }
                    other => return Err(format!("nonfinite: got {other:?}", other = other.err())),
                }
                // ... and a store poisoned through the unvalidated generator
                // path still fails typed, not by panic, in the session.
                let pts = PointSet::from_flat_fn(n, 2, |i| coords[i]);
                match ClusterSession::build(&pts) {
                    Err(DpcError::NonFiniteCoordinate { point, dim }) => {
                        if point * 2 + dim != pos {
                            return Err(format!("nonfinite at {pos}: reported ({point}, {dim})"));
                        }
                    }
                    other => return Err(format!("nonfinite: got {other:?}", other = other.err())),
                }
                // Same through the one-shot wrapper.
                let poisoned = [0.0, bad];
                let pts = PointSet::from_flat_fn(1, 2, |i| poisoned[i]);
                if !matches!(
                    Dpc::new(DpcParams { d_cut: 1.0, rho_min: 0.0, delta_min: 1.0, ..DpcParams::default() }).run(&pts),
                    Err(DpcError::NonFiniteCoordinate { .. })
                ) {
                    return Err("Dpc::run nonfinite: wrong error".into());
                }
            }
            // d_cut <= 0 / NaN.
            let pts = proputil::gen_uniform_points(&mut rng, n.max(2), 2, 5.0);
            for bad in [0.0, -1.0 - rng.next_f64(), f64::NAN] {
                if !matches!(
                    Dpc::new(DpcParams { d_cut: bad, rho_min: 0.0, delta_min: 1.0, ..DpcParams::default() }).run(&pts),
                    Err(DpcError::InvalidParam { name: "d_cut", .. })
                ) {
                    return Err(format!("d_cut={bad}: wrong error"));
                }
            }
            // NaN thresholds.
            if !matches!(
                Dpc::new(DpcParams { d_cut: 1.0, rho_min: f64::NAN, delta_min: 1.0, ..DpcParams::default() }).run(&pts),
                Err(DpcError::InvalidParam { name: "rho_min", .. })
            ) {
                return Err("rho_min NaN: wrong error".into());
            }
            if !matches!(
                Dpc::new(DpcParams { d_cut: 1.0, rho_min: 0.0, delta_min: f64::NAN, ..DpcParams::default() }).run(&pts),
                Err(DpcError::InvalidParam { name: "delta_min", .. })
            ) {
                return Err("delta_min NaN: wrong error".into());
            }
            Ok(())
        },
    );
}

// 3. Stage ordering is enforced with MissingStage, not panics or garbage.
#[test]
fn staged_api_enforces_order() {
    let mut rng = SplitMix64::new(5);
    let pts = proputil::gen_clustered_points(&mut rng, 120, 2, 2, 60.0, 2.0);
    let mut s = ClusterSession::build(&pts).unwrap();
    assert!(matches!(s.dependents(DepAlgo::Priority), Err(DpcError::MissingStage { need: "density", .. })));
    assert!(matches!(s.cut(0.0, 1.0), Err(DpcError::MissingStage { need: "density", .. })));
    s.density(3.0).unwrap();
    assert!(matches!(s.cut(0.0, 1.0), Err(DpcError::MissingStage { need: "dependents", .. })));
    s.dependents(DepAlgo::Priority).unwrap();
    s.cut(0.0, 1.0).unwrap();
}

// 4. The coordinator's session-scoped serving: open once, re-cut many,
//    always matching fresh runs; unknown sessions are typed errors.
#[test]
fn coordinator_session_recuts_match_fresh_runs() {
    let cfg = CoordinatorConfig {
        artifacts_dir: std::path::PathBuf::from("/nonexistent"),
        workers: 2,
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start(cfg).unwrap();
    let mut rng = SplitMix64::new(17);
    let pts = Arc::new(proputil::gen_clustered_points(&mut rng, 400, 2, 3, 150.0, 2.5));
    let d_cut = 4.0;
    let sid = coord.open_session(OpenSpec::points(Arc::clone(&pts), d_cut)).unwrap();
    let entry = coord.session(sid).expect("entry");
    assert_eq!(entry.built_by, "tree");
    assert_eq!(entry.rho.len(), pts.len());

    // Burst of concurrent re-cuts at different thresholds.
    let sweeps: Vec<(f64, f64)> = vec![(0.0, 10.0), (2.0, 25.0), (1.0, f64::INFINITY), (4.0, 5.0)];
    let ids: Vec<_> = sweeps.iter().map(|&(r, d)| coord.submit_recut(sid, r, d).unwrap()).collect();
    for (id, &(rho_min, delta_min)) in ids.into_iter().zip(&sweeps) {
        let out = coord.wait(id).unwrap();
        let params = DpcParams { d_cut, rho_min, delta_min, ..DpcParams::default() };
        let fresh = Dpc::new(params).run(&pts).unwrap();
        assert_same_result(&out.result, &fresh, &format!("rho_min={rho_min} delta_min={delta_min}"));
        // The coordinator's direct (non-session) pipeline — Step 2 computed
        // with the threshold rather than masked — must agree too.
        let direct = coord
            .run_sync(parcluster::coordinator::ClusterJob::new(Arc::clone(&pts), params))
            .unwrap();
        assert_same_result(&direct.result, &fresh, &format!("direct rho_min={rho_min}"));
    }

    assert!(matches!(coord.submit_recut(sid + 1, 0.0, 1.0), Err(DpcError::UnknownSession(_))));
    assert!(matches!(coord.submit_recut(sid, f64::NAN, 1.0), Err(DpcError::InvalidParam { name: "rho_min", .. })));
    coord.close_session(sid).unwrap();
    assert!(matches!(coord.close_session(sid), Err(DpcError::UnknownSession(_))));
    assert!(matches!(coord.submit_recut(sid, 0.0, 1.0), Err(DpcError::UnknownSession(_))));
}

// 5. Switching radii within one session: per-radius caches keep both
//    radii's recuts exact and cheap.
#[test]
fn multi_radius_session_stays_exact() {
    let mut rng = SplitMix64::new(23);
    let pts = proputil::gen_clustered_points(&mut rng, 300, 2, 4, 120.0, 2.0);
    let mut s = ClusterSession::build(&pts).unwrap();
    for &d_cut in &[3.0, 6.0, 3.0] {
        s.density(d_cut).unwrap();
        s.dependents(DepAlgo::Fenwick).unwrap();
        let recut = s.cut(1.0, 8.0).unwrap();
        let fresh = Dpc::new(DpcParams { d_cut, rho_min: 1.0, delta_min: 8.0, ..DpcParams::default() })
            .dep_algo(DepAlgo::Fenwick)
            .run(&pts)
            .unwrap();
        assert_same_result(&recut, &fresh, &format!("d_cut={d_cut}"));
    }
    // Two distinct radii -> exactly two computes per stage; the third pass
    // (back to 3.0) was served from cache.
    let st = s.stats();
    assert_eq!(st.density_computes, 2);
    assert_eq!(st.dep_computes, 2);
    assert_eq!(st.density_cache_hits, 1);
    assert_eq!(st.dep_cache_hits, 1);
}
