//! Integration: the AOT XLA brute-force engine vs the Rust tree engine.
//!
//! Requires `make artifacts` (skips gracefully if artifacts are absent).
//! Points are drawn on integer grids so f32 (XLA) and f64 (Rust) distance
//! arithmetic agree exactly — any mismatch is a real semantic bug, not a
//! rounding artifact.

use std::sync::Arc;

use parcluster::coordinator::{Backend, ClusterJob, Coordinator, CoordinatorConfig};
use parcluster::dpc::{compute_density, dep, DensityAlgo, Dpc, DpcParams, DepAlgo};
use parcluster::geom::PointSet;
use parcluster::metrics::adjusted_rand_index;
use parcluster::prng::SplitMix64;
use parcluster::runtime::{artifacts_available, artifacts_dir, XlaService};

fn grid_points(seed: u64, n: usize, d: usize, side: u64) -> PointSet {
    let mut rng = SplitMix64::new(seed);
    let coords: Vec<f64> = (0..n * d).map(|_| rng.next_below(side) as f64).collect();
    PointSet::new(coords, d)
}

fn require_artifacts() -> bool {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return false;
    }
    true
}

#[test]
fn xla_density_and_deps_match_tree_engine() {
    if !require_artifacts() {
        return;
    }
    let svc = XlaService::start(&artifacts_dir()).expect("start XLA service");
    for (seed, n, d, side, d_cut) in
        [(1u64, 300usize, 2usize, 40u64, 5.0f64), (2, 777, 3, 20, 4.0), (3, 512, 5, 10, 3.0), (4, 60, 2, 6, 2.0)]
    {
        let pts = Arc::new(grid_points(seed, n, d, side));
        let out = svc.run(Arc::clone(&pts), d_cut).expect("xla run");
        // Density must match the kd-tree count exactly.
        let rho = compute_density(&pts, d_cut, DensityAlgo::TreePruned);
        assert_eq!(out.rho, rho, "density mismatch (seed {seed})");
        // Dependents must match the priority algorithm exactly (grid coords
        // => no f32/f64 boundary or tie ambiguity).
        let dep_tree = dep::compute_dependents(&pts, &rho, 0.0, DepAlgo::Priority);
        assert_eq!(out.dep, dep_tree, "dependent mismatch (seed {seed})");
    }
}

#[test]
fn xla_handles_exact_padding_boundary() {
    if !require_artifacts() {
        return;
    }
    let svc = XlaService::start(&artifacts_dir()).expect("start XLA service");
    // n exactly equal to an artifact size: no padding rows at all.
    let pts = Arc::new(grid_points(5, 512, 2, 30));
    let out = svc.run(Arc::clone(&pts), 4.0).expect("xla run");
    let rho = compute_density(&pts, 4.0, DensityAlgo::TreePruned);
    assert_eq!(out.rho, rho);
}

#[test]
fn xla_rejects_oversize_jobs() {
    if !require_artifacts() {
        return;
    }
    let svc = XlaService::start(&artifacts_dir()).expect("start XLA service");
    let cap = svc.capacity();
    let pts = Arc::new(grid_points(6, cap + 1, 2, 10));
    assert!(svc.run(pts, 1.0).is_err());
}

#[test]
fn coordinator_routes_small_jobs_to_xla_and_matches_tree_labels() {
    if !require_artifacts() {
        return;
    }
    let cfg = CoordinatorConfig { xla_threshold: 2048, ..CoordinatorConfig::default() };
    let coord = Coordinator::start(cfg).expect("coordinator");
    assert!(coord.has_xla(), "artifacts exist but XLA engine failed to start");
    let pts = Arc::new(grid_points(7, 600, 2, 50));
    let params = DpcParams { d_cut: 6.0, rho_min: 2.0, delta_min: 15.0, ..DpcParams::default() };

    let out_xla = coord
        .run_sync(ClusterJob::new(Arc::clone(&pts), params).backend(Backend::XlaBruteForce))
        .expect("xla job");
    assert_eq!(out_xla.backend_used, Backend::XlaBruteForce);

    let out_tree = coord
        .run_sync(ClusterJob::new(Arc::clone(&pts), params).backend(Backend::TreeExact))
        .expect("tree job");
    assert_eq!(out_tree.backend_used, Backend::TreeExact);

    // Exactness across backends: identical densities, deps, and labels.
    assert_eq!(out_xla.result.rho, out_tree.result.rho);
    assert_eq!(out_xla.result.dep, out_tree.result.dep);
    assert_eq!(out_xla.result.labels, out_tree.result.labels);
    assert_eq!(adjusted_rand_index(&out_xla.result.labels, &out_tree.result.labels), 1.0);

    // Auto routing: small -> xla, big -> tree.
    let small = coord.run_sync(ClusterJob::new(Arc::clone(&pts), params).backend(Backend::Auto)).unwrap();
    assert_eq!(small.backend_used, Backend::XlaBruteForce);
    let big_pts = Arc::new(grid_points(8, 3000, 2, 80));
    let big = coord.run_sync(ClusterJob::new(big_pts, params).backend(Backend::Auto)).unwrap();
    assert_eq!(big.backend_used, Backend::TreeExact);
}

#[test]
fn full_pipeline_agreement_on_clustered_grid_data() {
    if !require_artifacts() {
        return;
    }
    // Two separated integer blobs; every backend and every dep algorithm
    // must produce the same 2-cluster labeling.
    let mut rng = SplitMix64::new(9);
    let mut coords = Vec::new();
    for base in [0i64, 1000] {
        for _ in 0..200 {
            coords.push((base + rng.next_below(20) as i64) as f64);
            coords.push((base + rng.next_below(20) as i64) as f64);
        }
    }
    let pts = Arc::new(PointSet::new(coords, 2));
    let params = DpcParams { d_cut: 8.0, rho_min: 0.0, delta_min: 100.0, ..DpcParams::default() };
    let reference = Dpc::new(params).dep_algo(DepAlgo::Naive).run(&pts).unwrap();
    assert_eq!(reference.num_clusters, 2);

    let coord = Coordinator::start(CoordinatorConfig::default()).unwrap();
    let out = coord.run_sync(ClusterJob::new(Arc::clone(&pts), params).backend(Backend::XlaBruteForce)).unwrap();
    assert_eq!(out.backend_used, Backend::XlaBruteForce);
    assert_eq!(out.result.labels, reference.labels);
    assert_eq!(out.result.num_clusters, 2);
}
