//! Oracle-backed differential suite: every (DensityModel × DepAlgo)
//! pipeline must be **byte-identical** to the sequential O(n²) brute-force
//! reference (`dpc::oracle`) — ρ, λ, δ, labels, centers, counts — on
//! adversarial dataset families and on randomly drawn hyper-parameters.
//!
//! This is the repo's strongest correctness instrument: the oracle shares
//! no traversal, no sort, no tree, and no parallelism with the pipeline
//! (only the two spec-defining functions `gaussian_weight` and
//! `radius_sq`), so any disagreement localizes a real defect rather than a
//! shared misunderstanding. Failures replay deterministically via the
//! `proputil::check` case seed.
//!
//! The `#[ignore]`d wide sweep multiplies cases and sizes for the nightly
//! `--include-ignored` CI job.

use parcluster::dpc::{oracle, DensityModel, DepAlgo, Dpc, DpcParams, DpcResult};
use parcluster::geom::PointSet;
use parcluster::prng::SplitMix64;
use parcluster::proputil::{
    self, gen_clustered_points, gen_dpc_params, gen_size, gen_uniform_points, Config,
};

// ---------------------------------------------------------------------------
// Dataset families (the ISSUE's five: uniform, clustered, duplicate-heavy,
// collinear, all-duplicate)
// ---------------------------------------------------------------------------

const FAMILIES: [&str; 5] = ["uniform", "clustered", "duplicate-heavy", "collinear", "all-duplicate"];

fn gen_family(family: &str, rng: &mut SplitMix64, n: usize) -> PointSet {
    match family {
        "uniform" => gen_uniform_points(rng, n, 2, 30.0),
        "clustered" => gen_clustered_points(rng, n, 3, 3, 50.0, 2.0),
        "duplicate-heavy" => {
            // A handful of sites stamped many times: maximal density ties.
            // (Stateful fill: `from_flat_fn` runs in flat-index order, so
            // the site drawn at a point's x-slot carries to its y-slot.)
            let sites: Vec<(f64, f64)> =
                (0..4).map(|_| (rng.uniform(0.0, 15.0), rng.uniform(0.0, 15.0))).collect();
            let mut site = (0.0, 0.0);
            PointSet::from_flat_fn(n, 2, |idx| {
                if idx % 2 == 0 {
                    site = sites[rng.next_below(4) as usize];
                    site.0
                } else {
                    site.1
                }
            })
        }
        "collinear" => {
            // One line, irregular duplicate-prone spacing: degenerate
            // bounding boxes in every split dimension.
            let mut t = 0.0f64;
            PointSet::from_flat_fn(n, 2, |idx| {
                if idx % 2 == 0 {
                    t = rng.next_below(n as u64 / 2 + 1) as f64;
                    t
                } else {
                    2.0 * t
                }
            })
        }
        "all-duplicate" => PointSet::new(vec![3.0; n * 2], 2),
        other => panic!("unknown family {other}"),
    }
}

/// Models whose ρ is a fixed-point kernel mass (up to 4096 per neighbor)
/// rather than a neighbor count — thresholds must scale accordingly.
fn kernel_mass_units(model: DensityModel) -> bool {
    matches!(model, DensityModel::GaussianKernel | DensityModel::Epanechnikov)
}

fn assert_matches_oracle(got: &DpcResult, want: &DpcResult, ctx: &str) -> Result<(), String> {
    if got.rho != want.rho {
        return Err(format!("{ctx}: rho diverged from oracle"));
    }
    if got.dep != want.dep {
        return Err(format!("{ctx}: dep diverged from oracle"));
    }
    if got.delta != want.delta {
        return Err(format!("{ctx}: delta diverged from oracle"));
    }
    if got.labels != want.labels {
        return Err(format!("{ctx}: labels diverged from oracle"));
    }
    if got.centers != want.centers {
        return Err(format!("{ctx}: centers diverged from oracle"));
    }
    if got.num_clusters != want.num_clusters || got.num_noise != want.num_noise {
        return Err(format!("{ctx}: cluster/noise counts diverged from oracle"));
    }
    Ok(())
}

/// One differential property run: random points from `family`, random
/// params (model included), checked against the oracle under every DepAlgo.
fn run_family_property(family: &'static str, cases: u64, seed: u64, n_lo: usize, n_hi: usize) {
    proputil::check(
        &format!("oracle-differential/{family}"),
        Config { cases, seed },
        |rng| {
            let n = gen_size(rng, n_lo, n_hi);
            let pts = gen_family(family, rng, n);
            let params = gen_dpc_params(rng);
            (pts, params)
        },
        |(pts, params)| {
            let want = oracle::oracle_pipeline(pts, *params);
            for dep_algo in DepAlgo::ALL {
                let got = Dpc::new(*params)
                    .dep_algo(dep_algo)
                    .run(pts)
                    .map_err(|e| format!("pipeline error under {dep_algo:?}: {e}"))?;
                assert_matches_oracle(&got, &want, &format!("{family} {} {dep_algo:?}", params.density))?;
            }
            Ok(())
        },
    );
}

#[test]
fn differential_uniform() {
    run_family_property("uniform", 12, 0xD1FF_0001, 40, 110);
}

#[test]
fn differential_clustered() {
    run_family_property("clustered", 12, 0xD1FF_0002, 40, 110);
}

#[test]
fn differential_duplicate_heavy() {
    run_family_property("duplicate-heavy", 12, 0xD1FF_0003, 40, 110);
}

#[test]
fn differential_collinear() {
    run_family_property("collinear", 12, 0xD1FF_0004, 40, 110);
}

#[test]
fn differential_all_duplicate() {
    run_family_property("all-duplicate", 8, 0xD1FF_0005, 20, 60);
}

/// Exhaustive small sweep: every (model × DepAlgo) on one fixed dataset per
/// family — fast, and the failure message names the exact cell.
#[test]
fn differential_exhaustive_model_by_algo_grid() {
    for family in FAMILIES {
        let mut rng = SplitMix64::new(0xD1FF_1000);
        let pts = gen_family(family, &mut rng, 90);
        for model in DensityModel::REPRESENTATIVE {
            // Gaussian ρ includes the point's own 4096 self-weight, so a
            // noise threshold must clear it to bite.
            let params = DpcParams {
                d_cut: 3.0,
                rho_min: if kernel_mass_units(model) { 9000.0 } else { 2.0 },
                delta_min: 5.0,
                density: model,
                ..DpcParams::default()
            };
            let want = oracle::oracle_pipeline(&pts, params);
            for dep_algo in DepAlgo::ALL {
                let got = Dpc::new(params).dep_algo(dep_algo).run(&pts).unwrap();
                assert_matches_oracle(&got, &want, &format!("{family} {model} {dep_algo:?}"))
                    .unwrap_or_else(|e| panic!("{e}"));
            }
        }
    }
}

/// The SIMD/scalar leaf-kernel differential: the full pipeline, run once on
/// the default `dist_sq_block` path (AVX where the host has it) and once
/// with the portable scalar kernel forced, must agree byte for byte with
/// each other and with the oracle — the end-to-end half of the exactness
/// contract in `geom::scalar` (its unit tests pin single kernel calls; the
/// `--features force-scalar-kernel` CI leg pins the compile-time variant).
#[test]
fn differential_forced_scalar_kernel_is_byte_identical() {
    use parcluster::geom::{force_scalar_kernel, kernel_toggle_guard};
    let _serial = kernel_toggle_guard();
    for family in FAMILIES {
        let mut rng = SplitMix64::new(0xD1FF_3000);
        let pts = gen_family(family, &mut rng, 100);
        let params = DpcParams { d_cut: 3.0, rho_min: 2.0, delta_min: 5.0, ..DpcParams::default() };
        let want = oracle::oracle_pipeline(&pts, params);
        let default_path = Dpc::new(params).run(&pts).unwrap();
        force_scalar_kernel(true);
        let scalar_path = Dpc::new(params).run(&pts).unwrap();
        force_scalar_kernel(false);
        assert_matches_oracle(&default_path, &want, &format!("{family} default-kernel"))
            .unwrap_or_else(|e| panic!("{e}"));
        assert_matches_oracle(&scalar_path, &want, &format!("{family} forced-scalar"))
            .unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(default_path.rho, scalar_path.rho, "{family}: kernels disagree on rho");
        assert_eq!(default_path.delta, scalar_path.delta, "{family}: kernels disagree on delta");
    }
}

/// Streaming sessions against the oracle: after every batch, the stream's
/// cut must match the oracle on the concatenated prefix, per model.
#[test]
fn differential_streaming_matches_oracle() {
    use parcluster::dpc::StreamingSession;
    for model in DensityModel::REPRESENTATIVE {
        let mut rng = SplitMix64::new(0xD1FF_2000);
        let pts = gen_family("clustered", &mut rng, 120);
        let d = pts.dim();
        let params = DpcParams {
            d_cut: 3.0,
            rho_min: if kernel_mass_units(model) { 8000.0 } else { 1.0 },
            delta_min: 6.0,
            density: model,
            ..DpcParams::default()
        };
        let mut s = StreamingSession::<f64>::new_with_model(d, params.d_cut, model).unwrap();
        let mut sent = 0usize;
        for bsz in [35usize, 1, 50, 34] {
            let hi = (sent + bsz).min(pts.len());
            let batch = PointSet::new(pts.coords()[sent * d..hi * d].to_vec(), d);
            s.ingest(&batch).unwrap();
            sent = hi;
            let prefix = PointSet::new(pts.coords()[..hi * d].to_vec(), d);
            let want = oracle::oracle_pipeline(&prefix, params);
            let got = s.cut(params.rho_min, params.delta_min).unwrap();
            assert_matches_oracle(&got, &want, &format!("stream {model} at {hi}"))
                .unwrap_or_else(|e| panic!("{e}"));
        }
        assert_eq!(sent, pts.len());
    }
}

/// The nightly wide sweep (`cargo test -- --include-ignored`): more cases,
/// larger inputs, both precisions. Too slow for the per-push jobs; the
/// scheduled CI leg runs it.
#[test]
#[ignore = "nightly-scale sweep; run with --include-ignored"]
fn differential_wide_sweep_nightly() {
    for (i, family) in FAMILIES.into_iter().enumerate() {
        run_family_property(family, 40, 0xA17E_0000u64.wrapping_add(i as u64), 80, 260);
    }
}
