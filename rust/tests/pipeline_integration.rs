//! End-to-end integration over the benchmark datasets: every Table-2
//! dataset clusters successfully at its paper hyper-parameters, with
//! sensible outputs and cross-algorithm agreement at reduced size.

use std::sync::Arc;

use parcluster::coordinator::{ClusterJob, Coordinator, CoordinatorConfig};
use parcluster::datasets;
use parcluster::dpc::approx::run_approx;
use parcluster::dpc::{Dpc, DepAlgo, DpcParams};
use parcluster::metrics::{adjusted_rand_index, normalized_mutual_info};

#[test]
fn every_benchmark_dataset_clusters_at_paper_params() {
    for name in datasets::registry(1.0) {
        let ds = datasets::by_name(name, Some(3000), 42).unwrap();
        let out = Dpc::new(ds.params).dep_algo(DepAlgo::Priority).run(&ds.pts).unwrap();
        assert_eq!(out.labels.len(), 3000, "{name}");
        // Structural sanity: every non-noise point has a cluster; all
        // cluster labels are centers.
        let centers: std::collections::HashSet<i64> = out.centers.iter().map(|&c| c as i64).collect();
        for (i, &l) in out.labels.iter().enumerate() {
            if l != -1 {
                assert!(centers.contains(&l), "{name}: point {i} label {l} is not a center");
            }
        }
        assert_eq!(out.num_clusters, out.centers.len(), "{name}");
        assert!(out.num_clusters >= 1, "{name}: no clusters at all");
        // The peak exists and has infinite delta.
        let peaks = out.delta.iter().filter(|d| d.is_infinite()).count();
        assert!(peaks >= 1, "{name}");
    }
}

#[test]
fn dep_algorithms_agree_on_every_dataset() {
    for name in datasets::registry(1.0) {
        let ds = datasets::by_name(name, Some(1200), 7).unwrap();
        let reference = Dpc::new(ds.params).dep_algo(DepAlgo::Priority).run(&ds.pts).unwrap();
        for algo in [DepAlgo::Fenwick, DepAlgo::Incomplete, DepAlgo::ExactBaseline] {
            let got = Dpc::new(ds.params).dep_algo(algo).run(&ds.pts).unwrap();
            assert_eq!(got.dep, reference.dep, "{name}/{algo:?}");
            assert_eq!(got.labels, reference.labels, "{name}/{algo:?}");
        }
    }
}

#[test]
fn approx_baseline_quality_is_high_on_blobby_datasets() {
    // The approximate grid baseline should reach high (not necessarily
    // perfect) agreement with the exact algorithm where clusters are
    // well-formed — the paper's quality argument for exactness is that
    // approx *can* deviate; ours: it broadly agrees but is not identical.
    let ds = datasets::by_name("simden", Some(4000), 11).unwrap();
    let exact = Dpc::new(ds.params).run(&ds.pts).unwrap();
    let approx = run_approx(&ds.pts, ds.params);
    let ari = adjusted_rand_index(&exact.labels, &approx.labels);
    let nmi = normalized_mutual_info(&exact.labels, &approx.labels);
    assert!(ari > 0.5, "simden ARI {ari}");
    assert!(nmi > 0.5, "simden NMI {nmi}");
}

#[test]
fn coordinator_runs_dataset_jobs_through_service() {
    let cfg = CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() };
    let coord = Coordinator::start(cfg).unwrap();
    let mut ids = Vec::new();
    for name in ["uniform", "simden", "gowalla"] {
        let ds = datasets::by_name(name, Some(1500), 3).unwrap();
        ids.push((name, coord.submit(ClusterJob::new(Arc::new(ds.pts), ds.params).tag(name))));
    }
    for (name, id) in ids {
        let out = coord.wait(id).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(out.tag, name);
        assert!(out.result.num_clusters >= 1, "{name}");
    }
    assert_eq!(coord.metrics.counter("jobs_submitted"), 3);
    assert_eq!(coord.metrics.counter("points_processed"), 4500);
}

#[test]
fn rho_min_monotonicity_more_noise_with_higher_threshold() {
    let ds = datasets::by_name("varden", Some(3000), 5).unwrap();
    let lo = Dpc::new(DpcParams { rho_min: 0.0, ..ds.params }).run(&ds.pts).unwrap();
    let hi = Dpc::new(DpcParams { rho_min: 20.0, ..ds.params }).run(&ds.pts).unwrap();
    assert!(hi.num_noise >= lo.num_noise);
    assert_eq!(lo.num_noise, 0);
}

#[test]
fn delta_min_monotonicity_fewer_clusters_with_higher_threshold() {
    let ds = datasets::by_name("simden", Some(3000), 5).unwrap();
    let fine = Dpc::new(DpcParams { delta_min: 10.0, ..ds.params }).run(&ds.pts).unwrap();
    let coarse = Dpc::new(DpcParams { delta_min: 500.0, ..ds.params }).run(&ds.pts).unwrap();
    assert!(coarse.num_clusters <= fine.num_clusters);
    assert!(coarse.num_clusters >= 1);
}
