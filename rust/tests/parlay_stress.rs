//! Scheduler stress suite (ISSUE 3): every `parlay` primitive must produce
//! sequential-identical output at every thread count, and nested fork-join
//! must stay deadlock-free under worker starvation.
//!
//! `set_threads` swaps the process-global pool, so every test that pins a
//! thread count holds `POOL_LOCK` — tests within this binary then observe
//! exactly the thread count they asked for. (Correctness never depends on
//! the count — that is the point of the suite — but the tests should
//! actually *exercise* 2, 7, and 16 workers, not whatever their neighbor
//! last set.)

use std::sync::Mutex;

use parcluster::datasets::synthetic;
use parcluster::dpc::{DensityAlgo, DepAlgo, Dpc, DpcParams};
use parcluster::parlay;
use parcluster::prng::SplitMix64;

static POOL_LOCK: Mutex<()> = Mutex::new(());

/// Thread counts from the issue: sequential, minimal stealing, odd (uneven
/// victim distribution), and oversubscribed (more workers than CI cores —
/// parking and help-first get real coverage).
const THREAD_COUNTS: [usize; 4] = [1, 2, 7, 16];

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // A panicking neighbor must not cascade: the pool itself is never left
    // in a broken state, so poisoning is ignorable.
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn par_scan_add_matches_sequential_across_thread_counts() {
    let _g = lock();
    let mut rng = SplitMix64::new(0x5CA9);
    let vals: Vec<usize> = (0..100_000).map(|_| (rng.next_u64() % 1000) as usize).collect();
    let mut expect = Vec::with_capacity(vals.len());
    let mut acc = 0usize;
    for &v in &vals {
        expect.push(acc);
        acc += v;
    }
    for &t in &THREAD_COUNTS {
        parlay::set_threads(t);
        let (scan, total) = parlay::par_scan_add(&vals);
        assert_eq!(total, acc, "total at T={t}");
        assert_eq!(scan, expect, "scan at T={t}");
    }
}

#[test]
fn par_sort_by_key_matches_sequential_across_thread_counts() {
    let _g = lock();
    let mut rng = SplitMix64::new(0x50F7);
    // Narrow key range forces heavy ties, and sorting by the key ALONE while
    // expecting (k, id) order pins the stable tie order — at every thread
    // count, i.e. across every chunk/merge-round layout.
    let base: Vec<(u64, u32)> = (0..80_000).map(|i| (rng.next_u64() % 64, i as u32)).collect();
    let mut expect = base.clone();
    expect.sort_by_key(|&(k, id)| (k, id));
    for &t in &THREAD_COUNTS {
        parlay::set_threads(t);
        let mut v = base.clone();
        parlay::par_sort_by_key(&mut v, |&(k, _)| k);
        assert_eq!(v, expect, "stable sort at T={t}");
    }
}

#[test]
fn par_radix_sort_matches_sequential_across_thread_counts() {
    let _g = lock();
    let mut rng = SplitMix64::new(0x4AD1);
    let base: Vec<(u64, u32)> = (0..80_000).map(|i| (rng.next_u64() % 100_000, i as u32)).collect();
    let mut expect = base.clone();
    expect.sort_by_key(|&(k, id)| (k, id)); // radix sort is stable
    for &t in &THREAD_COUNTS {
        parlay::set_threads(t);
        let mut v = base.clone();
        parlay::par_radix_sort_u64(&mut v);
        assert_eq!(v, expect, "radix at T={t}");
        // Regression: n below the chunk grid (n < 2·threads) used to panic
        // on an unclamped chunk start index.
        for n in 1..8usize {
            let mut tiny: Vec<(u64, u32)> = (0..n).map(|i| ((7 - i) as u64 % 3, i as u32)).collect();
            let mut tiny_expect = tiny.clone();
            tiny_expect.sort_by_key(|&(k, id)| (k, id));
            parlay::par_radix_sort_u64(&mut tiny);
            assert_eq!(tiny, tiny_expect, "tiny radix n={n} at T={t}");
        }
    }
}

#[test]
fn par_map_filter_reduce_match_sequential_across_thread_counts() {
    let _g = lock();
    let n = 50_000usize;
    for &t in &THREAD_COUNTS {
        parlay::set_threads(t);
        let m = parlay::par_map(n, |i| (i as u64).wrapping_mul(0x9E37_79B9));
        assert!(m.iter().enumerate().all(|(i, &x)| x == (i as u64).wrapping_mul(0x9E37_79B9)), "map at T={t}");
        let f = parlay::par_filter(n, |i| i % 7 == 0, |i| i);
        let expect: Vec<usize> = (0..n).filter(|i| i % 7 == 0).collect();
        assert_eq!(f, expect, "filter at T={t}");
        let s = parlay::par_reduce(n, 0u64, |i| i as u64, |a, b| a + b);
        assert_eq!(s, (n as u64 - 1) * n as u64 / 2, "reduce at T={t}");
    }
}

/// The depth bomb: a linear chain of nested joins far deeper than the worker
/// count. A pool whose joiners *block* instead of helping deadlocks here as
/// soon as `depth > threads` tasks are simultaneously waiting; a help-first
/// joiner executes its own forked child (or other pending tasks) and the
/// chain always advances.
#[test]
fn nested_join_depth_bomb_does_not_deadlock() {
    let _g = lock();
    fn chain(p: &parcluster::parlay::Pool, depth: u64) -> u64 {
        if depth == 0 {
            return 0;
        }
        // Fork the deep side as the *stealable* task and keep trivial work
        // inline, maximizing simultaneously-blocked joins.
        let (a, b) = p.join(|| depth % 3, || chain(p, depth - 1));
        a + b
    }
    for &t in &[2usize, 7, 16] {
        parlay::set_threads(t);
        let p = parcluster::parlay::pool::global();
        let depth = 600u64;
        let expect: u64 = (1..=depth).map(|d| d % 3).sum();
        assert_eq!(chain(&p, depth), expect, "chain at T={t}");
    }
    // Bushy variant: exponential fork-out with every frame joining.
    fn fib(p: &parcluster::parlay::Pool, n: u64) -> u64 {
        if n < 2 {
            return n;
        }
        let (a, b) = p.join(|| fib(p, n - 1), || fib(p, n - 2));
        a + b
    }
    parlay::set_threads(7);
    let p = parcluster::parlay::pool::global();
    assert_eq!(fib(&p, 20), 6765);
}

/// Acceptance criterion: DPC outputs are byte-identical across thread counts
/// (ρ, λ, δ, labels, centers), for the two Step-2 algorithms whose inner
/// loops are fully parallel.
#[test]
fn dpc_outputs_byte_identical_across_thread_counts() {
    let _g = lock();
    let pts = synthetic::simden(4_000, 2, 42);
    let params = DpcParams { d_cut: 30.0, rho_min: 2.0, delta_min: 60.0, ..DpcParams::default() };
    for dep_algo in [DepAlgo::Priority, DepAlgo::Fenwick] {
        parlay::set_threads(1);
        let seq = Dpc::new(params)
            .dep_algo(dep_algo)
            .density_algo(DensityAlgo::TreePruned)
            .run(&pts)
            .expect("sequential run");
        for &t in &THREAD_COUNTS[1..] {
            parlay::set_threads(t);
            let par = Dpc::new(params)
                .dep_algo(dep_algo)
                .density_algo(DensityAlgo::TreePruned)
                .run(&pts)
                .expect("parallel run");
            assert_eq!(par.rho, seq.rho, "rho {dep_algo:?} T={t}");
            assert_eq!(par.dep, seq.dep, "dep {dep_algo:?} T={t}");
            // δ compared bitwise: both sides must make identical FP choices.
            let seq_delta: Vec<u64> = seq.delta.iter().map(|d| d.to_bits()).collect();
            let par_delta: Vec<u64> = par.delta.iter().map(|d| d.to_bits()).collect();
            assert_eq!(par_delta, seq_delta, "delta {dep_algo:?} T={t}");
            assert_eq!(par.labels, seq.labels, "labels {dep_algo:?} T={t}");
            assert_eq!(par.centers, seq.centers, "centers {dep_algo:?} T={t}");
            assert_eq!(par.num_noise, seq.num_noise, "noise {dep_algo:?} T={t}");
        }
    }
}

/// Many small operations back-to-back: exercises parking/unparking churn
/// (workers go idle between ops) and injector submissions from this external
/// (non-worker) test thread.
#[test]
fn rapid_small_ops_survive_parking_churn() {
    let _g = lock();
    parlay::set_threads(8);
    for round in 0..200usize {
        let n = 64 + (round % 7) * 100;
        let v = parlay::par_map(n, |i| i * i);
        assert_eq!(v.len(), n);
        assert!(v.iter().enumerate().all(|(i, &x)| x == i * i), "round {round}");
        if round % 50 == 0 {
            // Interleave pool resizes mid-churn: set_threads must be safe
            // while the previous pool may still be winding down.
            parlay::set_threads(if round % 100 == 0 { 3 } else { 8 });
        }
    }
    parlay::set_threads(2);
}
