//! API-compatible stand-in for the subset of the PJRT `xla` bindings that
//! `parcluster::runtime::engine` uses.
//!
//! The build image has no network access and no native XLA toolchain, so
//! the real bindings cannot be vendored; this stub keeps the `xla` feature
//! *compilable* (CI's feature-matrix job builds and tests it) while every
//! runtime entry point fails with [`Error::StubOnly`] — which the service
//! layer already treats as "XLA unavailable, degrade to the tree backend".
//! To run for real, point the root Cargo.toml's `xla` path dependency at
//! the actual bindings; the signatures below mirror them.

use std::path::Path;

/// The one error this stub ever produces.
#[derive(Debug)]
pub enum Error {
    /// Raised by every entry point: the stub has no PJRT runtime behind it.
    StubOnly,
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla stub: built without real PJRT bindings")
    }
}

impl std::error::Error for Error {}

/// PJRT client handle. The stub cannot create one, so construction fails —
/// callers degrade before any other method can be reached.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(Error::StubOnly)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::StubOnly)
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::StubOnly)
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::StubOnly)
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self, Error> {
        Err(Error::StubOnly)
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Host-side literal. Constructors exist (they carry no data) so padding
/// code typechecks; every conversion out fails.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn scalar(_value: f32) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::StubOnly)
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal), Error> {
        Err(Error::StubOnly)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::StubOnly)
    }
}
