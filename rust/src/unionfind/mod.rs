//! Concurrent lock-free union-find (Jayanti–Tarjan style [41]) plus a
//! sequential reference implementation.
//!
//! Used by Step 3 of DPC (Algorithm 3): single-linkage clustering over the
//! dependency forest runs O(n) `UNION`s with `O(n α(n,n))` work and
//! `O(log n)` span, replacing the O(n) span of the baseline.
//!
//! The concurrent variant links by *random priority* (each element gets a
//! fixed pseudo-random weight; the lower-priority root is CAS-linked under
//! the higher-priority one) and performs path-halving with benign-race CAS
//! compression — linearizable unions without locks.

use std::sync::atomic::{AtomicU32, Ordering};

/// Lock-free concurrent union-find over `n` elements.
pub struct ConcurrentUnionFind {
    parent: Vec<AtomicU32>,
    /// Static random link priorities (break symmetry; expected O(α) finds).
    weight: Vec<u32>,
}

impl std::fmt::Debug for ConcurrentUnionFind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ConcurrentUnionFind").field("len", &self.parent.len()).finish_non_exhaustive()
    }
}

impl ConcurrentUnionFind {
    pub fn new(n: usize) -> Self {
        assert!(n < u32::MAX as usize);
        let parent = (0..n as u32).map(AtomicU32::new).collect();
        // SplitMix-scramble of the index: deterministic, uniform enough.
        let weight = (0..n as u64)
            .map(|i| {
                let mut z = i.wrapping_add(0x9E37_79B9_7F4A_7C15);
                z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
                (z ^ (z >> 31)) as u32
            })
            .collect();
        ConcurrentUnionFind { parent, weight }
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Find with path halving (concurrent-safe).
    pub fn find(&self, mut x: u32) -> u32 {
        loop {
            let p = self.parent[x as usize].load(Ordering::Acquire);
            if p == x {
                return x;
            }
            let gp = self.parent[p as usize].load(Ordering::Acquire);
            if gp == p {
                return p;
            }
            // Path halving: benign race; any stale write still points to an
            // ancestor.
            // relaxed: failure ordering only — on failure we reread through
            // `find`'s Acquire loads, so no data is published via this CAS.
            let _ = self.parent[x as usize].compare_exchange_weak(p, gp, Ordering::AcqRel, Ordering::Relaxed);
            x = gp;
        }
    }

    /// Merge the sets of `a` and `b` (thread-safe, lock-free).
    pub fn union(&self, a: u32, b: u32) {
        let mut a = a;
        let mut b = b;
        loop {
            a = self.find(a);
            b = self.find(b);
            if a == b {
                return;
            }
            // Link lower weight under higher (ties by id to stay acyclic).
            let (lo, hi) = if (self.weight[a as usize], a) < (self.weight[b as usize], b) { (a, b) } else { (b, a) };
            if self.parent[lo as usize]
                // relaxed: failure ordering only — the retry loop re-runs
                // `find`, whose Acquire loads re-establish the needed edges.
                .compare_exchange(lo, hi, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                return;
            }
            // Lost a race; retry with refreshed roots.
        }
    }

    /// Are `a` and `b` in the same set? (Quiescent accuracy: exact when no
    /// concurrent unions touch these sets.)
    pub fn same(&self, a: u32, b: u32) -> bool {
        loop {
            let ra = self.find(a);
            let rb = self.find(b);
            if ra == rb {
                return true;
            }
            // ra may have been linked concurrently; confirm it is still root.
            if self.parent[ra as usize].load(Ordering::Acquire) == ra {
                return false;
            }
        }
    }

    /// Canonical labels: `labels[i] = find(i)` for all i (call after all
    /// unions have completed).
    pub fn labels(&self) -> Vec<u32> {
        (0..self.parent.len() as u32).map(|i| self.find(i)).collect()
    }
}

/// Sequential union-find with union by rank + full path compression
/// (reference/oracle).
pub struct SeqUnionFind {
    parent: Vec<u32>,
    rank: Vec<u8>,
}

impl std::fmt::Debug for SeqUnionFind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SeqUnionFind").field("len", &self.parent.len()).finish_non_exhaustive()
    }
}

impl SeqUnionFind {
    pub fn new(n: usize) -> Self {
        SeqUnionFind { parent: (0..n as u32).collect(), rank: vec![0; n] }
    }

    pub fn find(&mut self, x: u32) -> u32 {
        let p = self.parent[x as usize];
        if p == x {
            return x;
        }
        let root = self.find(p);
        self.parent[x as usize] = root;
        root
    }

    pub fn union(&mut self, a: u32, b: u32) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return;
        }
        match self.rank[ra as usize].cmp(&self.rank[rb as usize]) {
            std::cmp::Ordering::Less => self.parent[ra as usize] = rb,
            std::cmp::Ordering::Greater => self.parent[rb as usize] = ra,
            std::cmp::Ordering::Equal => {
                self.parent[rb as usize] = ra;
                self.rank[ra as usize] += 1;
            }
        }
    }

    pub fn labels(&mut self) -> Vec<u32> {
        (0..self.parent.len() as u32).map(|i| self.find(i)).collect()
    }
}

/// Do two label vectors describe the same partition (up to renaming)?
pub fn same_partition(a: &[u32], b: &[u32]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    use std::collections::HashMap;
    let mut fwd: HashMap<u32, u32> = HashMap::new();
    let mut bwd: HashMap<u32, u32> = HashMap::new();
    for i in 0..a.len() {
        if *fwd.entry(a[i]).or_insert(b[i]) != b[i] {
            return false;
        }
        if *bwd.entry(b[i]).or_insert(a[i]) != a[i] {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parlay;
    use crate::prng::SplitMix64;

    #[test]
    fn basic_union_find() {
        let uf = ConcurrentUnionFind::new(10);
        assert!(!uf.same(0, 1));
        uf.union(0, 1);
        uf.union(2, 3);
        assert!(uf.same(0, 1));
        assert!(uf.same(1, 0));
        assert!(!uf.same(1, 2));
        uf.union(1, 3);
        assert!(uf.same(0, 2));
    }

    #[test]
    fn matches_sequential_on_random_unions() {
        let mut rng = SplitMix64::new(31);
        let n = 2000;
        let ops: Vec<(u32, u32)> = (0..1500)
            .map(|_| (rng.next_below(n as u64) as u32, rng.next_below(n as u64) as u32))
            .collect();
        let cuf = ConcurrentUnionFind::new(n);
        let mut suf = SeqUnionFind::new(n);
        for &(a, b) in &ops {
            cuf.union(a, b);
            suf.union(a, b);
        }
        assert!(same_partition(&cuf.labels(), &suf.labels()));
    }

    #[test]
    fn concurrent_stress_matches_sequential() {
        let _g = crate::parlay::pool::TEST_POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = parlay::num_threads();
        parlay::set_threads(4);
        let mut rng = SplitMix64::new(32);
        let n = 5000;
        let ops: Vec<(u32, u32)> = (0..8000)
            .map(|_| (rng.next_below(n as u64) as u32, rng.next_below(n as u64) as u32))
            .collect();
        let cuf = ConcurrentUnionFind::new(n);
        parlay::par_for(ops.len(), |i| {
            cuf.union(ops[i].0, ops[i].1);
        });
        let mut suf = SeqUnionFind::new(n);
        for &(a, b) in &ops {
            suf.union(a, b);
        }
        assert!(same_partition(&cuf.labels(), &suf.labels()));
        // Restore the ambient count (e.g. the PALLAS_THREADS CI matrix)
        // instead of degrading sibling tests to 1 thread.
        parlay::set_threads(prev);
    }

    #[test]
    fn chain_unions_single_component() {
        let uf = ConcurrentUnionFind::new(1000);
        for i in 0..999u32 {
            uf.union(i, i + 1);
        }
        let labels = uf.labels();
        assert!(labels.iter().all(|&l| l == labels[0]));
    }

    #[test]
    fn same_partition_detects_differences() {
        assert!(same_partition(&[0, 0, 1], &[5, 5, 9]));
        assert!(!same_partition(&[0, 0, 1], &[5, 9, 9]));
        assert!(!same_partition(&[0, 1], &[0, 1, 2]));
    }
}
