//! Deterministic pseudo-random number generation (the `rand` crate is not
//! available offline): SplitMix64 for seeding/streams and xoshiro256** for
//! bulk generation. Both are well-studied, tiny, and reproducible across
//! platforms — every dataset generator and property test in this repo is
//! seeded so results are exactly replayable.

/// SplitMix64 (Steele, Lea, Flood 2014). Good enough on its own for dataset
/// generation; also used to seed [`Xoshiro256`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)`. `n > 0`.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style rejection-free approximation is fine for our uses.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Xoshiro256 { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 1234567 (cross-checked against the
        // canonical C implementation).
        let mut r = SplitMix64::new(1234567);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut r2 = SplitMix64::new(1234567);
        assert_eq!(r2.next_u64(), a);
        assert_eq!(r2.next_u64(), b);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(42);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = SplitMix64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = SplitMix64::new(3);
        let n = 50_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256::new(99);
        let mut b = Xoshiro256::new(99);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
