//! Fenwick-tree-of-kd-trees dependent point finding (§5, Algorithm 2).
//!
//! Points are sorted by **descending** priority (density with id tiebreak)
//! into `P̄`. A Fenwick (binary indexed) decomposition covers `[1, n]` with
//! blocks `B[i] = [i - LSB(i) + 1, i]` (1-based), and one kd-tree is built
//! per block (parallel across blocks, `Σ|B[i]| = O(n log n)` total points).
//! The dependent point of the rank-`r` point is the NN over the prefix
//! `[1, r-1]`, which the Fenwick structure splits into `O(log n)` blocks
//! `S[r-1]`; the query runs a kd-tree NN in each and keeps the minimum
//! `(dist, id)`.
//!
//! Compared to the priority search kd-tree this does more work
//! (O(n log² n) average) but its average-case analysis only assumes local
//! uniformity of the *whole* point set, not of every priority-suffix
//! (§5 intro) — and it is faster on some real distributions (paper: PAMAP2).
//!
//! Like the priority search kd-tree, this structure consumes only the
//! integer γ ordering: every [`crate::dpc::DensityModel`] (cutoff count,
//! kNN rank, fixed-point Gaussian mass) flows through it unchanged.

use crate::geom::{PointStore, Scalar};
use crate::kdtree::{KdTree, StatSink};
use crate::parlay;

/// Decompose the 1-based prefix `[1, i]` into Fenwick block indices
/// (`S[i]` in the paper). Returns block indices `j`, each covering
/// `[j - LSB(j) + 1, j]`.
pub fn fenwick_decompose(i: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(usize::BITS as usize);
    let mut j = i;
    while j > 0 {
        out.push(j);
        j &= j - 1; // j -= LSB(j)
    }
    out
}

#[inline]
fn lsb(i: usize) -> usize {
    i & i.wrapping_neg()
}

/// The Fenwick dependent-point structure. Generic over the coordinate
/// [`Scalar`]; every block tree pins the one shared store by refcount.
pub struct FenwickDep<S: Scalar = f64> {
    pts: PointStore<S>,
    /// `sorted[r]` = point id with rank `r` (0-based, descending priority).
    sorted: Vec<u32>,
    /// `rank_of[id]` = 0-based rank.
    rank_of: Vec<u32>,
    /// `trees[i]` (1-based, `trees[0]` unused) = kd-tree over block `B[i]`.
    trees: Vec<Option<KdTree<S>>>,
}

impl<S: Scalar> std::fmt::Debug for FenwickDep<S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FenwickDep")
            .field("points", &self.sorted.len())
            .finish_non_exhaustive()
    }
}

impl<S: Scalar> FenwickDep<S> {
    /// Lines 9-13 of Algorithm 2: radix-sort by descending priority and
    /// build all block kd-trees in parallel.
    pub fn build(pts: &PointStore<S>, gamma: &[u64]) -> Self {
        let n = pts.len();
        assert_eq!(gamma.len(), n);
        assert!(n > 0);
        // Descending sort: radix-sort ascending on the complement.
        let mut items: Vec<(u64, u32)> = (0..n).map(|i| (!gamma[i], i as u32)).collect();
        parlay::par_radix_sort_u64(&mut items);
        let sorted: Vec<u32> = items.into_iter().map(|(_, id)| id).collect();
        let mut rank_of = vec![0u32; n];
        for (r, &id) in sorted.iter().enumerate() {
            rank_of[id as usize] = r as u32;
        }
        // Build B[i] over sorted[i-LSB(i) .. i] (0-based slice of the
        // 1-based range [i-LSB(i)+1, i]).
        let sorted_ref = &sorted;
        let mut trees: Vec<Option<KdTree<S>>> = parlay::par_map(n + 1, |i| {
            if i == 0 {
                return None;
            }
            let lo = i - lsb(i);
            Some(KdTree::build_from_ids(pts, sorted_ref[lo..i].to_vec()))
        });
        // Slot 0 is a placeholder.
        trees[0] = None;
        FenwickDep { pts: pts.clone(), sorted, rank_of, trees }
    }

    /// FENWICK-QUERY (Algorithm 2 lines 1-6) for the point with id `id`:
    /// nearest neighbor among all strictly-higher-priority points. `None`
    /// iff `id` is the global priority peak (rank 0).
    ///
    /// The O(log n) block queries of line 4 run sequentially here — the
    /// *outer* per-point loop (Algorithm 2 line 14) is already fully
    /// parallel, so inner parallelism would only add task overhead; the
    /// aggregation of line 6 becomes an exact sequential `(dist, id)` min.
    pub fn query<T: StatSink>(&self, id: u32, stats: &mut T) -> Option<(u32, S)> {
        let r = self.rank_of[id as usize] as usize;
        if r == 0 {
            return None;
        }
        let q = self.pts.point(id as usize);
        let mut best = (u32::MAX, S::INFINITY);
        let mut j = r; // 1-based prefix [1, r] = 0-based ranks [0, r-1]
        while j > 0 {
            // lint: allow(panic-surface) — the Fenwick traversal only
            // visits levels whose block tree was built during `insert`.
            let tree = self.trees[j].as_ref().expect("block tree exists");
            if let Some((p, ds)) = tree.nn(q, u32::MAX, stats) {
                if ds < best.1 || (ds == best.1 && p < best.0) {
                    best = (p, ds);
                }
            }
            j &= j - 1;
        }
        debug_assert!(best.0 != u32::MAX);
        Some(best)
    }

    /// Rank (0-based, descending priority) of a point id.
    pub fn rank_of(&self, id: u32) -> usize {
        self.rank_of[id as usize] as usize
    }

    /// The descending-priority order (testing/diagnostics).
    pub fn sorted_ids(&self) -> &[u32] {
        &self.sorted
    }

    /// Total points stored across all block trees (= Θ(n log n); test hook).
    pub fn total_stored(&self) -> usize {
        self.trees.iter().flatten().map(|t| t.size()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kdtree::NoStats;
    use crate::proputil::{gen_clustered_points, gen_uniform_points};
    use crate::prng::SplitMix64;
    use crate::pskd::brute_priority_nn;

    fn random_gamma(rng: &mut SplitMix64, n: usize) -> Vec<u64> {
        let mut idx: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut idx);
        let mut g = vec![0u64; n];
        for (i, &j) in idx.iter().enumerate() {
            g[j as usize] = i as u64;
        }
        g
    }

    #[test]
    fn decompose_is_disjoint_cover() {
        for i in 1..=512usize {
            let blocks = fenwick_decompose(i);
            // Blocks [j-LSB(j)+1, j] must tile [1, i] exactly.
            let mut covered = vec![false; i + 1];
            for &j in &blocks {
                let lo = j - lsb(j) + 1;
                for k in lo..=j {
                    assert!(!covered[k], "overlap at {k} for i={i}");
                    covered[k] = true;
                }
            }
            assert!(covered[1..].iter().all(|&c| c), "gap for i={i}");
            assert!(blocks.len() <= (usize::BITS - i.leading_zeros()) as usize + 1);
        }
    }

    #[test]
    fn block_sizes_sum_is_n_log_n_bounded() {
        let n = 1024usize;
        let total: usize = (1..=n).map(lsb).sum();
        // Σ LSB(i) for i in [1, n=2^k] is (k/2 + 1) n approx; just check the
        // O(n log n) bound.
        assert!(total <= n * (n.ilog2() as usize + 1));
    }

    #[test]
    fn fenwick_query_matches_brute_priority_nn_uniform() {
        let mut rng = SplitMix64::new(21);
        let n = 700;
        let pts = gen_uniform_points(&mut rng, n, 2, 100.0);
        let gamma = random_gamma(&mut rng, n);
        let f = FenwickDep::build(&pts, &gamma);
        for id in (0..n as u32).step_by(7) {
            let got = f.query(id, &mut NoStats);
            let want = brute_priority_nn(&pts, &gamma, pts.point(id as usize), gamma[id as usize]);
            assert_eq!(got, want, "id {id}");
        }
    }

    #[test]
    fn fenwick_query_matches_brute_priority_nn_clustered() {
        let mut rng = SplitMix64::new(22);
        let n = 600;
        let pts = gen_clustered_points(&mut rng, n, 3, 4, 50.0, 1.5);
        let gamma = random_gamma(&mut rng, n);
        let f = FenwickDep::build(&pts, &gamma);
        for id in (0..n as u32).step_by(5) {
            let got = f.query(id, &mut NoStats);
            let want = brute_priority_nn(&pts, &gamma, pts.point(id as usize), gamma[id as usize]);
            assert_eq!(got, want, "id {id}");
        }
    }

    #[test]
    fn peak_has_no_dependent() {
        let mut rng = SplitMix64::new(23);
        let pts = gen_uniform_points(&mut rng, 64, 2, 10.0);
        let gamma = random_gamma(&mut rng, 64);
        let f = FenwickDep::build(&pts, &gamma);
        let peak = (0..64u32).max_by_key(|&i| gamma[i as usize]).unwrap();
        assert_eq!(f.rank_of(peak), 0);
        assert_eq!(f.query(peak, &mut NoStats), None);
    }

    #[test]
    fn sorted_order_is_descending_priority() {
        let mut rng = SplitMix64::new(24);
        let pts = gen_uniform_points(&mut rng, 200, 2, 10.0);
        let gamma = random_gamma(&mut rng, 200);
        let f = FenwickDep::build(&pts, &gamma);
        let s = f.sorted_ids();
        for w in s.windows(2) {
            assert!(gamma[w[0] as usize] > gamma[w[1] as usize]);
        }
    }

    #[test]
    fn space_usage_is_n_log_n() {
        let mut rng = SplitMix64::new(25);
        let n = 2048;
        let pts = gen_uniform_points(&mut rng, n, 2, 10.0);
        let gamma = random_gamma(&mut rng, n);
        let f = FenwickDep::build(&pts, &gamma);
        assert!(f.total_stored() <= n * (n.ilog2() as usize + 1));
        assert!(f.total_stored() >= n); // at least every point stored once
    }
}
