//! Priority search kd-tree (§4.2) — the paper's main data-structure
//! contribution, a d-dimensional generalization of McCreight's priority
//! search tree and an optimization of the max kd-tree.
//!
//! Every node stores **the highest-priority point of its subtree at the node
//! itself** (not at a leaf), so γ values satisfy the max-heap property along
//! every root-to-leaf path. The remaining points are split evenly by the
//! median along the widest side of the node's cell. Consequences:
//!
//! - For any threshold γ_q, the node set `T_q = {v : γ(v) > γ_q}` is a
//!   connected upper portion of the tree (footnote 6), so a *priority
//!   nearest-neighbor* query — NN among points with priority > γ_q — is a
//!   plain NN search on an incomplete kd-tree whose active part is `T_q`:
//!   prune on `γ(v) ≤ γ_q` exactly like an `isActive == false` subtree.
//! - Each cell is uniquely associated with one point, which is what makes
//!   the Appendix-A priority range query bound `O(n^(1-1/d) + |Q|)` provable
//!   (impossible for a max kd-tree).
//!
//! With γ = DPC density (ties broken by id, packed into the key — see
//! [`crate::dpc::priority_key`]), one priority-NN query per point computes
//! all dependent points fully in parallel (Algorithm 1). The structure is
//! agnostic to *which* density produced γ: the pluggable density models
//! ([`crate::dpc::DensityModel`] — cutoff count, kNN rank, fixed-point
//! Gaussian mass) all feed integer ρ into the same key, so every model
//! reuses this tree and its exactness argument unchanged.
//!
//! Layout: a subtree over `m` points occupies exactly `m` contiguous arena
//! slots (each node consumes one point), so the parallel recursive build
//! writes disjoint regions lock-free. Construction: O(n log n) work,
//! O(log n log log n) span (theoretical; the per-node median select is
//! sequential in this implementation — see DESIGN.md §Perf).
//!
//! **Tail blocks**: every *maximal* small subtree (≤ 16 points whose
//! parent is larger; the whole tree when `n ≤ 16`) additionally records
//! its slot-ordered coordinates in a dim-major SoA block, mirroring the
//! kd-tree's blocked leaves. The same size argument applies — splitting
//! the `m − 1` rest of an `m ≥ 17` node leaves halves `≥ 8`, so maximal
//! tails span 8–16 consecutive slots and `slot / 8` indexes their blocks
//! collision-free. A priority-NN visit that reaches a tail root does one
//! [`Scalar::dist_sq_block`] sweep with a per-lane γ filter instead of
//! recursing node by node; the candidate set and the strict `(dist, id)`
//! min are unchanged, so results stay byte-identical.
//!
//! Generic over the coordinate [`Scalar`] (priorities stay `u64`, so the
//! heap/tie-break structure — and thus exactness — is precision-
//! independent); pins its input [`PointStore`] by refcount.

use crate::geom::{Bbox, PointStore, PointsView, Scalar, BLOCK_LANES};
use crate::kdtree::leaf::{LeafArena, BLOCK_MIN};
use crate::kdtree::StatSink;
use crate::parlay;

const NONE: u32 = u32::MAX;
const BUILD_GRAIN: usize = 2048;

/// Priority search kd-tree over a refcount-shared point store with one
/// `u64` priority per point. Priorities must be **unique** (callers pack a
/// tiebreaker into the low bits; see `dpc::priority_key`).
pub struct PriorityKdTree<S: Scalar = f64> {
    pts: PointStore<S>,
    node_point: Vec<u32>,
    node_gamma: Vec<u64>,
    /// Node points' coordinates, slot-ordered (§Perf: the candidate-distance
    /// computation at every visited node reads these contiguously instead of
    /// chasing into the point store).
    node_coords: Vec<S>,
    left: Vec<u32>,
    right: Vec<u32>,
    bounds: Vec<S>,
    /// `tail_len[slot] = m > 0` iff `slot` roots a maximal small subtree of
    /// `m` points (see the module doc): its slot-ordered coordinates live in
    /// `tails` block `slot / BLOCK_MIN`, and priority-NN sweeps all `m`
    /// lanes in one kernel call.
    tail_len: Vec<u8>,
    tails: LeafArena<S>,
    root: u32,
}

impl<S: Scalar> std::fmt::Debug for PriorityKdTree<S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PriorityKdTree")
            .field("points", &self.node_point.len())
            .finish_non_exhaustive()
    }
}

impl<S: Scalar> PriorityKdTree<S> {
    /// BUILD-PRIORITY-SEARCH-KD-TREE(P, γ).
    pub fn build(pts: &PointStore<S>, gamma: &[u64]) -> Self {
        assert_eq!(gamma.len(), pts.len());
        assert!(!pts.is_empty());
        let n = pts.len();
        let d = pts.dim();
        let mut ids: Vec<u32> = (0..n as u32).collect();
        let mut node_point = vec![NONE; n];
        let mut node_gamma = vec![0u64; n];
        let mut node_coords = vec![S::ZERO; n * d];
        let mut left = vec![NONE; n];
        let mut right = vec![NONE; n];
        let mut bounds = vec![S::ZERO; n * 2 * d];
        let mut tail_len = vec![0u8; n];
        // Maximal tails start ≥ BLOCK_MIN slots apart, so ceil(n/8) blocks
        // cover every `slot / BLOCK_MIN` index (same bound as kd-tree
        // leaves).
        let mut tails = LeafArena::new(n.div_ceil(BLOCK_MIN), d);
        {
            let b = PskdBuilder {
                pts: pts.view(),
                gamma,
                d,
                node_point: node_point.as_mut_ptr() as usize,
                node_gamma: node_gamma.as_mut_ptr() as usize,
                node_coords: node_coords.as_mut_ptr() as usize,
                left: left.as_mut_ptr() as usize,
                right: right.as_mut_ptr() as usize,
                bounds: bounds.as_mut_ptr() as usize,
                tail_len: tail_len.as_mut_ptr() as usize,
                tails: tails.as_mut_ptr() as usize,
                // Resolved once; the fork path below runs per node.
                pool: parlay::pool::global(),
            };
            b.build_rec(&mut ids, 0, n <= BLOCK_LANES);
        }
        PriorityKdTree {
            pts: pts.clone(),
            node_point,
            node_gamma,
            node_coords,
            left,
            right,
            bounds,
            tail_len,
            tails,
            root: 0,
        }
    }

    #[inline]
    pub fn points(&self) -> &PointStore<S> {
        &self.pts
    }

    #[inline]
    fn bbox_dist_sq(&self, i: u32, q: &[S]) -> S {
        let d = self.pts.dim();
        let base = i as usize * 2 * d;
        let (min, max) = (&self.bounds[base..base + d], &self.bounds[base + d..base + 2 * d]);
        let mut s = S::ZERO;
        for k in 0..d {
            let v = q[k];
            let t = if v < min[k] { min[k] - v } else if v > max[k] { v - max[k] } else { S::ZERO };
            s += t * t;
        }
        s
    }

    /// QUERY-PRIORITY-NN: nearest point with priority strictly greater than
    /// `gamma_q`. Ties in distance broken by smaller point id. Returns
    /// `(id, dist_sq)`; `None` iff no point has priority > `gamma_q` (i.e.
    /// the query is the global density peak).
    pub fn priority_nn<T: StatSink>(&self, q: &[S], gamma_q: u64, stats: &mut T) -> Option<(u32, S)> {
        let mut best = (NONE, S::INFINITY);
        self.pnn_rec(self.root, q, gamma_q, &mut best, stats, 1);
        if best.0 == NONE {
            None
        } else {
            Some(best)
        }
    }

    fn pnn_rec<T: StatSink>(&self, i: u32, q: &[S], gamma_q: u64, best: &mut (u32, S), stats: &mut T, depth: usize) {
        // Heap-property prune: γ of node = max γ of subtree.
        if self.node_gamma[i as usize] <= gamma_q {
            return;
        }
        stats.visit_node();
        stats.depth(depth);
        let m = self.tail_len[i as usize] as usize;
        if m > 0 {
            // Maximal tail subtree: all m node points sit in slots
            // [i, i + m), so one blocked kernel sweep replaces the
            // recursion. The per-lane γ filter is exactly the recursion's
            // candidate condition and the strict (dist, id) min is
            // order-independent, so the result is byte-identical; only the
            // visit/prune diagnostics differ (fewer nodes "visited").
            // Lanes ≥ m are never read — they belong to other subtrees.
            let mut dbuf = [S::ZERO; BLOCK_LANES];
            S::dist_sq_block(self.tails.block(i as usize / BLOCK_MIN), self.pts.dim(), q, &mut dbuf);
            for (l, &ds) in dbuf.iter().enumerate().take(m) {
                let s = i as usize + l;
                if self.node_gamma[s] <= gamma_q {
                    continue;
                }
                stats.scan_point();
                let p = self.node_point[s];
                if ds < best.1 || (ds == best.1 && p < best.0) {
                    *best = (p, ds);
                }
            }
            return;
        }
        // The node's own point is a valid candidate (γ > γ_q holds here).
        stats.scan_point();
        let d = self.pts.dim();
        let base = i as usize * d;
        let mut ds = S::ZERO;
        for k in 0..d {
            let t = self.node_coords[base + k] - q[k];
            ds += t * t;
        }
        if ds < best.1 || ds == best.1 {
            let p = self.node_point[i as usize];
            if ds < best.1 || p < best.0 {
                *best = (p, ds);
            }
        }
        let (l, r) = (self.left[i as usize], self.right[i as usize]);
        let dl = if l != NONE { self.bbox_dist_sq(l, q) } else { S::INFINITY };
        let dr = if r != NONE { self.bbox_dist_sq(r, q) } else { S::INFINITY };
        let (first, d1, second, d2) = if dl <= dr { (l, dl, r, dr) } else { (r, dr, l, dl) };
        if first != NONE && d1 <= best.1 {
            self.pnn_rec(first, q, gamma_q, best, stats, depth + 1);
        }
        if second != NONE && d2 <= best.1 {
            self.pnn_rec(second, q, gamma_q, best, stats, depth + 1);
        }
    }

    /// Priority range query (Appendix A): all points inside the ball
    /// `|x-q|² ≤ r_sq` with priority > `gamma_q`.
    pub fn priority_range(&self, q: &[S], r_sq: S, gamma_q: u64, out: &mut Vec<u32>) {
        self.prange_rec(self.root, q, r_sq, gamma_q, out);
    }

    fn prange_rec(&self, i: u32, q: &[S], r_sq: S, gamma_q: u64, out: &mut Vec<u32>) {
        if self.node_gamma[i as usize] <= gamma_q || self.bbox_dist_sq(i, q) > r_sq {
            return;
        }
        let p = self.node_point[i as usize];
        if self.pts.dist_sq_to(p as usize, q) <= r_sq {
            out.push(p);
        }
        let (l, r) = (self.left[i as usize], self.right[i as usize]);
        if l != NONE {
            self.prange_rec(l, q, r_sq, gamma_q, out);
        }
        if r != NONE {
            self.prange_rec(r, q, r_sq, gamma_q, out);
        }
    }

    /// Max depth of the tree (test/diagnostic; O(n)).
    pub fn depth(&self) -> usize {
        fn rec<S: Scalar>(t: &PriorityKdTree<S>, i: u32) -> usize {
            let (l, r) = (t.left[i as usize], t.right[i as usize]);
            let dl = if l != NONE { rec(t, l) } else { 0 };
            let dr = if r != NONE { rec(t, r) } else { 0 };
            1 + dl.max(dr)
        }
        rec(self, self.root)
    }

    /// Verify the heap property (test/diagnostic).
    pub fn check_heap_property(&self) -> bool {
        fn rec<S: Scalar>(t: &PriorityKdTree<S>, i: u32) -> bool {
            let g = t.node_gamma[i as usize];
            for c in [t.left[i as usize], t.right[i as usize]] {
                if c != NONE && (t.node_gamma[c as usize] > g || !rec(t, c)) {
                    return false;
                }
            }
            true
        }
        rec(self, self.root)
    }
}

struct PskdBuilder<'a, S: Scalar> {
    pts: PointsView<'a, S>,
    gamma: &'a [u64],
    d: usize,
    node_point: usize,
    node_gamma: usize,
    node_coords: usize,
    left: usize,
    right: usize,
    bounds: usize,
    tail_len: usize,
    tails: usize,
    pool: std::sync::Arc<parlay::Pool>,
}

// SAFETY: the raw base pointers are shared across build tasks, but the
// subtree at `slot` writes only slots `[slot, slot + m)` and the tail
// blocks derived from them — disjoint ranges across concurrent tasks — so
// shared `&PskdBuilder` access never races.
unsafe impl<S: Scalar> Sync for PskdBuilder<'_, S> {}

impl<S: Scalar> PskdBuilder<'_, S> {
    /// Subtree over `ids` occupies slots `[slot, slot + ids.len())`.
    /// `tail_root` marks it as a *maximal* small subtree (≤ BLOCK_LANES
    /// points, parent larger — or the whole tree): after its nodes are
    /// written, their slot-ordered coordinates are transposed into tail
    /// block `slot / BLOCK_MIN`.
    fn build_rec(&self, ids: &mut [u32], slot: usize, tail_root: bool) {
        let m = ids.len();
        debug_assert!(m >= 1);
        let d = self.d;
        // Cell = bbox over ALL points of the subtree (incl. the hoisted max).
        let bb = self.compute_bbox(ids);
        // SAFETY: `slot` is this task's exclusively owned node index (see
        // the Sync impl above), inside arenas sized for the whole tree.
        unsafe {
            let bptr = (self.bounds as *mut S).add(slot * 2 * d);
            for k in 0..d {
                *bptr.add(k) = bb.min()[k];
                *bptr.add(d + k) = bb.max()[k];
            }
        }
        // Hoist the max-priority point to this node.
        let mut max_i = 0usize;
        for (j, &id) in ids.iter().enumerate() {
            if self.gamma[id as usize] > self.gamma[ids[max_i] as usize] {
                max_i = j;
            }
            let _ = id;
        }
        ids.swap(0, max_i);
        let p = ids[0];
        // SAFETY: same exclusive ownership of `slot`; the coordinate copy
        // targets this node's `d`-scalar row only.
        unsafe {
            *(self.node_point as *mut u32).add(slot) = p;
            *(self.node_gamma as *mut u64).add(slot) = self.gamma[p as usize];
            let cptr = (self.node_coords as *mut S).add(slot * d);
            let src = self.pts.point(p as usize);
            std::ptr::copy_nonoverlapping(src.as_ptr(), cptr, d);
        }
        let rest = &mut ids[1..];
        let r = rest.len();
        if r == 0 {
            // SAFETY: same exclusive ownership of `slot`.
            unsafe {
                *(self.left as *mut u32).add(slot) = NONE;
                *(self.right as *mut u32).add(slot) = NONE;
            }
            if tail_root {
                // SAFETY: this task owns slots [slot, slot + 1).
                unsafe { self.finish_tail(slot, 1) };
            }
            return;
        }
        let dim = bb.widest_dim();
        let mid = r / 2;
        if mid > 0 {
            let pts = self.pts;
            rest.select_nth_unstable_by(mid, |&a, &b| {
                pts.coord(a as usize, dim)
                    .partial_cmp(&pts.coord(b as usize, dim))
                    // lint: allow(panic-surface) — coordinates are validated
                    // finite at ingest, so partial_cmp cannot see a NaN.
                    .unwrap()
                    .then(a.cmp(&b))
            });
        }
        let (lids, rids) = rest.split_at_mut(mid);
        let lslot = slot + 1;
        let rslot = slot + 1 + mid;
        // SAFETY: same exclusive ownership of `slot`.
        unsafe {
            *(self.left as *mut u32).add(slot) = if lids.is_empty() { NONE } else { lslot as u32 };
            *(self.right as *mut u32).add(slot) = if rids.is_empty() { NONE } else { rslot as u32 };
        }
        // A child becomes a tail root when this node is too large to be in
        // a tail itself but the child fits a block.
        let child_tail = |c: &[u32]| m > BLOCK_LANES && c.len() <= BLOCK_LANES;
        let (ltail, rtail) = (child_tail(lids), child_tail(rids));
        let go = |ids: &mut [u32], s: usize, tail: bool| {
            if !ids.is_empty() {
                self.build_rec(ids, s, tail);
            }
        };
        if m >= BUILD_GRAIN {
            self.pool.join(|| go(lids, lslot, ltail), || go(rids, rslot, rtail));
        } else {
            go(lids, lslot, ltail);
            go(rids, rslot, rtail);
        }
        if tail_root {
            // SAFETY: m ≤ BLOCK_LANES < BUILD_GRAIN, so the whole subtree
            // was built sequentially above by this task, which owns slots
            // [slot, slot + m) — and hence tail block slot / BLOCK_MIN —
            // exclusively.
            unsafe { self.finish_tail(slot, m) };
        }
    }

    /// Record a finished maximal tail: transpose the `m` slot-ordered node
    /// coordinates at `[slot, slot + m)` into dim-major tail block
    /// `slot / BLOCK_MIN`, padding lanes `m..BLOCK_LANES` with `+∞`.
    ///
    /// # Safety
    /// The caller's build task must own slots `[slot, slot + m)`; distinct
    /// maximal tails start ≥ BLOCK_MIN slots apart, so their blocks are
    /// disjoint and the write is raceless.
    unsafe fn finish_tail(&self, slot: usize, m: usize) {
        debug_assert!((1..=BLOCK_LANES).contains(&m));
        // SAFETY: the caller contract gives this task slots
        // [slot, slot + m) and the tail block slot / BLOCK_MIN; every
        // pointer below stays inside those exclusively owned ranges.
        unsafe {
            *(self.tail_len as *mut u8).add(slot) = m as u8;
            let d = self.d;
            let nc = self.node_coords as *const S;
            let block = (self.tails as *mut S).add((slot / BLOCK_MIN) * BLOCK_LANES * d);
            for k in 0..d {
                let row = block.add(k * BLOCK_LANES);
                for l in 0..BLOCK_LANES {
                    let v = if l < m { *nc.add((slot + l) * d + k) } else { S::INFINITY };
                    row.add(l).write(v);
                }
            }
        }
    }

    fn compute_bbox(&self, ids: &[u32]) -> Bbox<S> {
        let m = ids.len();
        if m < 65_536 {
            return self.pts.bbox_of(ids);
        }
        // Grain 1: a few heavy chunks would collapse to one sequential task
        // under the auto grain.
        let nchunks = 16;
        let chunk = m.div_ceil(nchunks);
        let boxes: Vec<Bbox<S>> = parlay::par_map_grained(nchunks, 1, |c| {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(m);
            self.pts.bbox_of(&ids[lo..hi.max(lo)])
        });
        let mut bb = Bbox::empty(self.d);
        for b in &boxes {
            bb.merge(b);
        }
        bb
    }
}

/// Brute-force priority-NN oracle: nearest point with priority > `gamma_q`,
/// ties by id.
pub fn brute_priority_nn<S: Scalar>(pts: &PointStore<S>, gamma: &[u64], q: &[S], gamma_q: u64) -> Option<(u32, S)> {
    let mut best: Option<(u32, S)> = None;
    for i in 0..pts.len() {
        if gamma[i] <= gamma_q {
            continue;
        }
        let ds = pts.dist_sq_to(i, q);
        match best {
            Some((bi, bd)) if ds > bd || (ds == bd && i as u32 > bi) => {}
            _ => best = Some((i as u32, ds)),
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::PointStore;
    use crate::kdtree::NoStats;
    use crate::proputil::{gen_clustered_points, gen_uniform_points};
    use crate::prng::SplitMix64;

    /// Unique priorities: random permutation of 0..n.
    fn random_gamma(rng: &mut SplitMix64, n: usize) -> Vec<u64> {
        let mut g: Vec<u64> = (0..n as u64).collect();
        let mut idx: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut idx);
        for (i, &j) in idx.iter().enumerate() {
            g[j as usize] = i as u64;
        }
        g
    }

    #[test]
    fn heap_property_holds() {
        let mut rng = SplitMix64::new(1);
        let pts = gen_uniform_points(&mut rng, 1000, 2, 100.0);
        let gamma = random_gamma(&mut rng, 1000);
        let t = PriorityKdTree::build(&pts, &gamma);
        assert!(t.check_heap_property());
    }

    #[test]
    fn depth_is_logarithmic() {
        let mut rng = SplitMix64::new(2);
        let n = 4096;
        let pts = gen_uniform_points(&mut rng, n, 2, 100.0);
        let gamma = random_gamma(&mut rng, n);
        let t = PriorityKdTree::build(&pts, &gamma);
        // Median splits on the REST of each node: depth ≤ ~log2(n) + slack.
        assert!(t.depth() <= 2 * (n as f64).log2() as usize, "depth={}", t.depth());
    }

    #[test]
    fn priority_nn_matches_brute_force_uniform() {
        let mut rng = SplitMix64::new(3);
        let pts = gen_uniform_points(&mut rng, 1500, 3, 100.0);
        let gamma = random_gamma(&mut rng, 1500);
        let t = PriorityKdTree::build(&pts, &gamma);
        for i in (0..1500).step_by(13) {
            let got = t.priority_nn(pts.point(i), gamma[i], &mut NoStats);
            let want = brute_priority_nn(&pts, &gamma, pts.point(i), gamma[i]);
            assert_eq!(got, want, "query {i}");
        }
    }

    #[test]
    fn priority_nn_matches_brute_force_clustered() {
        let mut rng = SplitMix64::new(4);
        let pts = gen_clustered_points(&mut rng, 1200, 2, 5, 100.0, 2.0);
        let gamma = random_gamma(&mut rng, 1200);
        let t = PriorityKdTree::build(&pts, &gamma);
        for i in (0..1200).step_by(11) {
            let got = t.priority_nn(pts.point(i), gamma[i], &mut NoStats);
            let want = brute_priority_nn(&pts, &gamma, pts.point(i), gamma[i]);
            assert_eq!(got, want, "query {i}");
        }
    }

    #[test]
    fn f32_priority_nn_matches_brute_force() {
        let mut rng = SplitMix64::new(14);
        let pts64 = gen_uniform_points(&mut rng, 900, 2, 80.0);
        let pts = PointStore::<f32>::cast_from_f64(&pts64);
        let gamma = random_gamma(&mut rng, 900);
        let t = PriorityKdTree::build(&pts, &gamma);
        assert!(t.points().shares_storage(&pts));
        for i in (0..900).step_by(17) {
            let got = t.priority_nn(pts.point(i), gamma[i], &mut NoStats);
            let want = brute_priority_nn(&pts, &gamma, pts.point(i), gamma[i]);
            assert_eq!(got, want, "query {i}");
        }
    }

    #[test]
    fn global_max_has_no_dependent() {
        let mut rng = SplitMix64::new(5);
        let pts = gen_uniform_points(&mut rng, 100, 2, 10.0);
        let gamma = random_gamma(&mut rng, 100);
        let t = PriorityKdTree::build(&pts, &gamma);
        let peak = (0..100).max_by_key(|&i| gamma[i]).unwrap();
        assert_eq!(t.priority_nn(pts.point(peak), gamma[peak], &mut NoStats), None);
    }

    #[test]
    fn priority_range_matches_filter() {
        let mut rng = SplitMix64::new(6);
        let pts = gen_uniform_points(&mut rng, 800, 2, 50.0);
        let gamma = random_gamma(&mut rng, 800);
        let t = PriorityKdTree::build(&pts, &gamma);
        let q = pts.point(17);
        let r_sq = 100.0;
        let gq = gamma[17];
        let mut got = Vec::new();
        t.priority_range(q, r_sq, gq, &mut got);
        got.sort();
        let want: Vec<u32> = (0..800u32)
            .filter(|&i| gamma[i as usize] > gq && pts.dist_sq_to(i as usize, q) <= r_sq)
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn tail_blocks_are_well_formed() {
        let mut rng = SplitMix64::new(21);
        for n in [1usize, 5, 16, 17, 33, 300, 2048] {
            let d = 2;
            let pts = gen_uniform_points(&mut rng, n, d, 50.0);
            let gamma = random_gamma(&mut rng, n);
            let t = PriorityKdTree::build(&pts, &gamma);
            let mut covered = vec![false; n];
            let mut blocks = std::collections::HashSet::new();
            for s in 0..n {
                let m = t.tail_len[s] as usize;
                if m == 0 {
                    continue;
                }
                assert!(m <= BLOCK_LANES, "n={n} slot {s}");
                if n > BLOCK_LANES {
                    assert!(m >= BLOCK_MIN, "n={n} tail at {s} has only {m} points");
                }
                assert!(blocks.insert(s / BLOCK_MIN), "n={n} tail block collision at slot {s}");
                let blk = t.tails.block(s / BLOCK_MIN);
                for l in 0..BLOCK_LANES {
                    for k in 0..d {
                        let want = if l < m { t.node_coords[(s + l) * d + k] } else { f64::INFINITY };
                        assert_eq!(blk[k * BLOCK_LANES + l], want, "n={n} slot {s} lane {l} dim {k}");
                    }
                }
                for c in covered.iter_mut().skip(s).take(m) {
                    assert!(!*c, "n={n}: slot inside two tails");
                    *c = true;
                }
            }
            // Every childless node roots a 1-point subtree, so it must lie
            // inside some maximal tail.
            for s in 0..n {
                if t.left[s] == NONE && t.right[s] == NONE {
                    assert!(covered[s], "n={n}: leaf slot {s} not covered by any tail");
                }
            }
        }
    }

    #[test]
    fn forced_scalar_tail_sweep_is_byte_identical() {
        use crate::geom::{force_scalar_kernel, kernel_toggle_guard};
        let _serial = kernel_toggle_guard();
        let mut rng = SplitMix64::new(22);
        let pts = gen_uniform_points(&mut rng, 700, 3, 60.0);
        let gamma = random_gamma(&mut rng, 700);
        let t = PriorityKdTree::build(&pts, &gamma);
        let queries: Vec<usize> = (0..700).step_by(19).collect();
        let fast: Vec<_> = queries.iter().map(|&i| t.priority_nn(pts.point(i), gamma[i], &mut NoStats)).collect();
        force_scalar_kernel(true);
        let slow: Vec<_> = queries.iter().map(|&i| t.priority_nn(pts.point(i), gamma[i], &mut NoStats)).collect();
        force_scalar_kernel(false);
        assert_eq!(fast, slow);
        for (&i, got) in queries.iter().zip(&fast) {
            assert_eq!(*got, brute_priority_nn(&pts, &gamma, pts.point(i), gamma[i]), "query {i}");
        }
    }

    #[test]
    fn single_point() {
        let pts = crate::geom::PointSet::new(vec![1.0, 1.0], 2);
        let t = PriorityKdTree::build(&pts, &[5]);
        assert_eq!(t.priority_nn(&[0.0, 0.0], 4, &mut NoStats), Some((0, 2.0)));
        assert_eq!(t.priority_nn(&[0.0, 0.0], 5, &mut NoStats), None);
    }
}
