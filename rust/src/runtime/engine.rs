//! The XLA brute-force DPC engine: manifest parsing, executable cache, and
//! padded execution.
//!
//! The PJRT-backed executor needs the `xla` crate, which is not available
//! in the offline build image — it sits behind the `xla` cargo feature (see
//! `Cargo.toml`). Without the feature this module still compiles: the
//! manifest parser, padding layout, and output types are feature-free (they
//! are what the integration tests and the coordinator's capability checks
//! use), and [`XlaDpcEngine::new`] returns an error so the service layer
//! degrades to the tree backend.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::geom::PointSet;

/// Pad-row base coordinate; must match `python/compile/kernels/pairwise.py`.
pub const PAD_COORD: f32 = 1.0e9;
/// Padded feature dimension of every artifact.
pub const D_PAD: usize = 8;

/// One artifact in `manifest.txt`: `<name> <n_pad> <d_pad>`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub n_pad: usize,
    pub d_pad: usize,
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = t.split_whitespace().collect();
            if parts.len() != 3 {
                bail!("manifest line {}: expected `<name> <n_pad> <d_pad>`, got {t:?}", lineno + 1);
            }
            entries.push(ManifestEntry {
                name: parts[0].to_string(),
                n_pad: parts[1].parse().context("n_pad")?,
                d_pad: parts[2].parse().context("d_pad")?,
            });
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        entries.sort_by_key(|e| e.n_pad);
        Ok(Manifest { entries })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Smallest artifact with `n_pad >= n`.
    pub fn pick(&self, n: usize) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.n_pad >= n)
    }

    pub fn max_n(&self) -> usize {
        self.entries.last().map(|e| e.n_pad).unwrap_or(0)
    }
}

/// Output of one brute-force DPC execution (truncated to the real n).
#[derive(Clone, Debug)]
pub struct XlaDpcOutput {
    pub rho: Vec<u32>,
    /// Dependent ids; `None` = global peak (or no candidate).
    pub dep: Vec<Option<u32>>,
    /// Squared dependent distances (f32 precision).
    pub dist_sq: Vec<f32>,
}

/// Pad `pts` to `(n_pad, D_PAD)` f32 row-major, staggered sentinels for
/// padding rows (mirrors `model.pad_points`).
pub fn pad_points(pts: &PointSet, n_pad: usize) -> Result<Vec<f32>> {
    let (n, d) = (pts.len(), pts.dim());
    if n > n_pad {
        bail!("{n} points exceed padded size {n_pad}");
    }
    if d > D_PAD {
        bail!("dimension {d} exceeds artifact dimension {D_PAD}");
    }
    let mut out = vec![0f32; n_pad * D_PAD];
    for i in 0..n {
        for k in 0..d {
            out[i * D_PAD + k] = pts.coord(i, k) as f32;
        }
    }
    for (row, i) in (n..n_pad).enumerate() {
        let v = PAD_COORD * (row as f32 + 1.0);
        for k in 0..D_PAD {
            out[i * D_PAD + k] = v;
        }
    }
    Ok(out)
}

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{anyhow, Result};

    use crate::geom::PointSet;

    use super::{pad_points, Manifest, XlaDpcOutput, D_PAD};

    /// AOT-compiled brute-force DPC on the PJRT CPU client.
    ///
    /// Executables are compiled lazily per padded size and cached. The
    /// client and cache are behind a mutex: PJRT CPU execution is internally
    /// single-stream here and callers (the coordinator) already batch.
    pub struct XlaDpcEngine {
        dir: PathBuf,
        manifest: Manifest,
        inner: Mutex<Inner>,
    }

    impl std::fmt::Debug for XlaDpcEngine {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("XlaDpcEngine")
                .field("dir", &self.dir)
                .field("manifest", &self.manifest)
                .finish_non_exhaustive()
        }
    }

    struct Inner {
        client: xla::PjRtClient,
        cache: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    }

    impl XlaDpcEngine {
        /// Load the manifest and create the PJRT CPU client.
        pub fn new(artifacts_dir: &Path) -> Result<Self> {
            let manifest = Manifest::load(artifacts_dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(XlaDpcEngine {
                dir: artifacts_dir.to_path_buf(),
                manifest,
                inner: Mutex::new(Inner { client, cache: BTreeMap::new() }),
            })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Largest point count this engine can handle.
        pub fn capacity(&self) -> usize {
            self.manifest.max_n()
        }

        /// See [`super::pad_points`].
        pub fn pad(pts: &PointSet, n_pad: usize) -> Result<Vec<f32>> {
            pad_points(pts, n_pad)
        }

        /// Execute brute-force DPC (density + dependent points) for `pts`.
        pub fn run(&self, pts: &PointSet, d_cut: f64) -> Result<XlaDpcOutput> {
            let n = pts.len();
            let entry = self
                .manifest
                .pick(n)
                .ok_or_else(|| anyhow!("n={n} exceeds largest artifact (capacity {})", self.capacity()))?;
            let n_pad = entry.n_pad;
            let padded = pad_points(pts, n_pad)?;

            let mut inner = self.inner.lock().unwrap();
            if !inner.cache.contains_key(&n_pad) {
                let path = self.dir.join(format!("{}.hlo.txt", entry.name));
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = inner.client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e:?}", entry.name))?;
                inner.cache.insert(n_pad, exe);
            }
            // lint: allow(panic-surface) — inserted just above under the
            // same lock guard; the key cannot disappear in between.
            let exe = inner.cache.get(&n_pad).expect("just inserted");

            let points_lit = xla::Literal::vec1(&padded)
                .reshape(&[n_pad as i64, D_PAD as i64])
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            let dcut_lit = xla::Literal::scalar((d_cut * d_cut) as f32);
            let result = exe
                .execute::<xla::Literal>(&[points_lit, dcut_lit])
                .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let (rho_l, dep_l, dist_l) = result.to_tuple3().map_err(|e| anyhow!("to_tuple3: {e:?}"))?;
            let rho_raw: Vec<i32> = rho_l.to_vec().map_err(|e| anyhow!("rho: {e:?}"))?;
            let dep_raw: Vec<i32> = dep_l.to_vec().map_err(|e| anyhow!("dep: {e:?}"))?;
            let dist_raw: Vec<f32> = dist_l.to_vec().map_err(|e| anyhow!("dist: {e:?}"))?;
            drop(inner);

            Ok(XlaDpcOutput {
                rho: rho_raw[..n].iter().map(|&r| r as u32).collect(),
                dep: dep_raw[..n]
                    .iter()
                    .map(|&d| if d < 0 || d as usize >= n { None } else { Some(d as u32) })
                    .collect(),
                dist_sq: dist_raw[..n].to_vec(),
            })
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::XlaDpcEngine;

/// Stub engine for builds without the `xla` feature: construction always
/// fails (after validating the manifest, so configuration errors still
/// surface first), which the service layer reports and degrades from.
#[cfg(not(feature = "xla"))]
#[derive(Debug)]
pub struct XlaDpcEngine {
    manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
impl XlaDpcEngine {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let _ = Manifest::load(artifacts_dir)?;
        bail!("parcluster was built without the `xla` feature; rebuild with `--features xla` (see Cargo.toml)")
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn capacity(&self) -> usize {
        self.manifest.max_n()
    }

    /// See [`pad_points`].
    pub fn pad(pts: &PointSet, n_pad: usize) -> Result<Vec<f32>> {
        pad_points(pts, n_pad)
    }

    pub fn run(&self, _pts: &PointSet, _d_cut: f64) -> Result<XlaDpcOutput> {
        bail!("xla feature disabled")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_and_sorts() {
        let m = Manifest::parse("b 1024 8\na 512 8\n# comment\n\nc 2048 8\n").unwrap();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.entries[0].n_pad, 512);
        assert_eq!(m.max_n(), 2048);
        assert_eq!(m.pick(513).unwrap().n_pad, 1024);
        assert_eq!(m.pick(512).unwrap().n_pad, 512);
        assert!(m.pick(4096).is_none());
    }

    #[test]
    fn manifest_rejects_bad_lines() {
        assert!(Manifest::parse("only two\n").is_err());
        assert!(Manifest::parse("").is_err());
        assert!(Manifest::parse("a b c\n").is_err());
    }

    #[test]
    fn pad_layout_matches_python() {
        let pts = PointSet::new(vec![1.0, 2.0, 3.0, 4.0], 2);
        let padded = pad_points(&pts, 4).unwrap();
        assert_eq!(padded.len(), 4 * D_PAD);
        assert_eq!(&padded[..2], &[1.0, 2.0]);
        assert_eq!(padded[2], 0.0); // zero-filled extra columns
        assert_eq!(&padded[D_PAD..D_PAD + 2], &[3.0, 4.0]);
        // Staggered sentinels.
        assert_eq!(padded[2 * D_PAD], PAD_COORD);
        assert_eq!(padded[3 * D_PAD], 2.0 * PAD_COORD);
    }

    #[test]
    fn pad_rejects_oversize() {
        let pts = PointSet::new(vec![0.0; 18], 9);
        assert!(pad_points(&pts, 16).is_err());
        let pts = PointSet::new(vec![0.0; 20], 2);
        assert!(pad_points(&pts, 4).is_err());
    }

    // Execution tests live in rust/tests/xla_integration.rs (they need the
    // artifacts built by `make artifacts` and the `xla` feature).
}
