//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs at request time: `make artifacts` lowers the L2 JAX
//! graph (which embeds the L1 Pallas kernels) to HLO **text** once, and this
//! module compiles each artifact on the PJRT CPU client at startup, caching
//! one executable per padded size (see `artifacts/manifest.txt`).
//!
//! The executed computation is the tensorized brute-force DPC
//! (Steps 1 + 2):
//!
//! ```text
//! (points f32[N,8], dcut_sq f32[]) -> (rho i32[N], dep i32[N], dist f32[N])
//! ```
//!
//! [`XlaDpcEngine::run`] pads the input to the smallest artifact size,
//! executes, and truncates the outputs back to the real `n`.

pub mod engine;
pub mod service;

pub use engine::{Manifest, ManifestEntry, XlaDpcEngine, XlaDpcOutput};
pub use service::XlaService;

use std::path::PathBuf;

/// Locate the artifacts directory: `$PARCLUSTER_ARTIFACTS`, else
/// `./artifacts` if present, else `<crate root>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("PARCLUSTER_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.join("manifest.txt").exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if the AOT artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}
