//! Thread-confined XLA service: the `xla` crate's PJRT handles are
//! `Rc`-based (not `Send`), so the engine lives on one dedicated thread and
//! the rest of the system talks to it through a channel. This also gives
//! natural request serialization (PJRT CPU execution is single-stream
//! anyway) and a clean place for request batching.

use std::path::Path;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

use anyhow::{anyhow, Result};

use crate::geom::PointSet;

use super::engine::{XlaDpcEngine, XlaDpcOutput};

enum Request {
    Run { pts: Arc<PointSet>, d_cut: f64, reply: mpsc::Sender<Result<XlaDpcOutput>> },
    Shutdown,
}

/// Send/Sync handle to the thread-confined [`XlaDpcEngine`].
pub struct XlaService {
    tx: Mutex<mpsc::Sender<Request>>,
    handle: Option<thread::JoinHandle<()>>,
    capacity: usize,
}

impl std::fmt::Debug for XlaService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaService").field("capacity", &self.capacity).finish_non_exhaustive()
    }
}

impl XlaService {
    /// Spawn the engine thread; fails if the artifacts/manifest cannot be
    /// loaded or the PJRT client cannot start.
    pub fn start(artifacts_dir: &Path) -> Result<Self> {
        let dir = artifacts_dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize>>();
        let handle = thread::Builder::new()
            .name("xla-engine".into())
            .spawn(move || {
                let engine = match XlaDpcEngine::new(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(e.capacity()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for req in rx {
                    match req {
                        Request::Run { pts, d_cut, reply } => {
                            let _ = reply.send(engine.run(&pts, d_cut));
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .map_err(|e| anyhow!("spawn xla-engine: {e}"))?;
        let capacity = ready_rx.recv().map_err(|_| anyhow!("xla-engine thread died during startup"))??;
        Ok(XlaService { tx: Mutex::new(tx), handle: Some(handle), capacity })
    }

    /// Largest point count the loaded artifacts support.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Execute brute-force DPC (Steps 1–2) on the engine thread.
    pub fn run(&self, pts: Arc<PointSet>, d_cut: f64) -> Result<XlaDpcOutput> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::Run { pts, d_cut, reply: reply_tx })
            .map_err(|_| anyhow!("xla-engine thread has exited"))?;
        reply_rx.recv().map_err(|_| anyhow!("xla-engine dropped the request"))?
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
