//! Clustering-quality metrics: Adjusted Rand Index and Normalized Mutual
//! Information. Used to verify (a) exactness — our five Step-2 algorithms
//! must yield ARI = 1 against each other — and (b) the quality of the
//! approximate baseline and the XLA brute-force backend against the exact
//! engine.
//!
//! Labels use the convention of [`crate::dpc::DpcResult`]: any i64, −1 =
//! noise. Noise is treated as its own (shared) label, matching how the
//! paper's quality comparisons count unassigned points.

use std::collections::HashMap;

fn contingency(a: &[i64], b: &[i64]) -> (HashMap<(i64, i64), f64>, HashMap<i64, f64>, HashMap<i64, f64>) {
    assert_eq!(a.len(), b.len());
    let mut joint: HashMap<(i64, i64), f64> = HashMap::new();
    let mut ma: HashMap<i64, f64> = HashMap::new();
    let mut mb: HashMap<i64, f64> = HashMap::new();
    for i in 0..a.len() {
        *joint.entry((a[i], b[i])).or_insert(0.0) += 1.0;
        *ma.entry(a[i]).or_insert(0.0) += 1.0;
        *mb.entry(b[i]).or_insert(0.0) += 1.0;
    }
    (joint, ma, mb)
}

fn comb2(x: f64) -> f64 {
    x * (x - 1.0) / 2.0
}

/// Adjusted Rand Index in [−1, 1]; 1 = identical partitions.
pub fn adjusted_rand_index(a: &[i64], b: &[i64]) -> f64 {
    let n = a.len() as f64;
    if n < 2.0 {
        return 1.0;
    }
    let (joint, ma, mb) = contingency(a, b);
    let sum_ij: f64 = joint.values().map(|&v| comb2(v)).sum();
    let sum_a: f64 = ma.values().map(|&v| comb2(v)).sum();
    let sum_b: f64 = mb.values().map(|&v| comb2(v)).sum();
    let expected = sum_a * sum_b / comb2(n);
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return 1.0; // degenerate: both trivial partitions
    }
    (sum_ij - expected) / (max_index - expected)
}

/// Normalized Mutual Information in [0, 1] (arithmetic-mean normalization).
pub fn normalized_mutual_info(a: &[i64], b: &[i64]) -> f64 {
    let n = a.len() as f64;
    if n == 0.0 {
        return 1.0;
    }
    let (joint, ma, mb) = contingency(a, b);
    let mut mi = 0.0;
    for (&(x, y), &nxy) in &joint {
        let px = ma[&x] / n;
        let py = mb[&y] / n;
        let pxy = nxy / n;
        mi += pxy * (pxy / (px * py)).ln();
    }
    let ha: f64 = -ma.values().map(|&v| (v / n) * (v / n).ln()).sum::<f64>();
    let hb: f64 = -mb.values().map(|&v| (v / n) * (v / n).ln()).sum::<f64>();
    if ha < 1e-12 && hb < 1e-12 {
        return 1.0;
    }
    (mi / (0.5 * (ha + hb))).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_partitions_score_one() {
        let a = vec![0, 0, 1, 1, 2, -1];
        assert_eq!(adjusted_rand_index(&a, &a), 1.0);
        assert!((normalized_mutual_info(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn renamed_labels_score_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        let b = vec![7, 7, 3, 3, 9, 9];
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
        assert!((normalized_mutual_info(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn independent_partitions_score_near_zero() {
        // Alternating vs. block labels over 1000 points.
        let a: Vec<i64> = (0..1000).map(|i| i % 2).collect();
        let b: Vec<i64> = (0..1000).map(|i| i / 500).collect();
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari.abs() < 0.05, "ari={ari}");
    }

    #[test]
    fn partial_agreement_between_zero_and_one() {
        let a = vec![0, 0, 0, 1, 1, 1];
        let b = vec![0, 0, 1, 1, 1, 1];
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari > 0.0 && ari < 1.0, "ari={ari}");
        let nmi = normalized_mutual_info(&a, &b);
        assert!(nmi > 0.0 && nmi < 1.0, "nmi={nmi}");
    }

    /// ARI against a fully hand-computed contingency table.
    ///
    /// a = [0,0,0,1,1,1], b = [0,0,1,1,1,1]:
    /// joint counts (0,0)=2, (0,1)=1, (1,1)=3 ⇒ Σᵢⱼ C(nᵢⱼ,2) = 1+0+3 = 4;
    /// row sums 3,3 ⇒ Σᵢ C(3,2) = 6; col sums 2,4 ⇒ Σⱼ = 1+6 = 7;
    /// expected = 6·7/C(6,2) = 42/15 = 2.8; max = (6+7)/2 = 6.5;
    /// ARI = (4 − 2.8)/(6.5 − 2.8) = 1.2/3.7.
    #[test]
    fn ari_matches_hand_computed_value() {
        let a = vec![0, 0, 0, 1, 1, 1];
        let b = vec![0, 0, 1, 1, 1, 1];
        assert!((adjusted_rand_index(&a, &b) - 1.2 / 3.7).abs() < 1e-12);
    }

    /// The canonical worst case: a perfect 2×2 "checkerboard" has every
    /// joint cell = 1, so Σᵢⱼ C(1,2) = 0, expected = 2·2/6 = 2/3, max = 2,
    /// ARI = (0 − 2/3)/(2 − 2/3) = −1/2 — and MI is exactly 0 (pxy = px·py
    /// everywhere), so NMI = 0.
    #[test]
    fn checkerboard_partitions_hand_computed() {
        let a = vec![0, 0, 1, 1];
        let b = vec![0, 1, 0, 1];
        assert!((adjusted_rand_index(&a, &b) + 0.5).abs() < 1e-12);
        assert!(normalized_mutual_info(&a, &b).abs() < 1e-12);
    }

    /// NMI against hand-computed entropies: a = [0,0,1,1] vs
    /// b = [0,0,0,1]. H(a) = ln 2; H(b) = −(¾ ln ¾ + ¼ ln ¼);
    /// MI = ½ ln(½ / (½·¾)) + ¼ ln(¼ / (½·¾)) + ¼ ln(¼ / (½·¼)).
    #[test]
    fn nmi_matches_hand_computed_value() {
        let a = vec![0, 0, 1, 1];
        let b = vec![0, 0, 0, 1];
        let ha = 2.0f64.ln();
        let hb = -(0.75f64 * 0.75f64.ln() + 0.25 * 0.25f64.ln());
        let mi = 0.5 * (0.5f64 / (0.5 * 0.75)).ln()
            + 0.25 * (0.25f64 / (0.5 * 0.75)).ln()
            + 0.25 * (0.25f64 / (0.5 * 0.25)).ln();
        let want = mi / (0.5 * (ha + hb));
        assert!((normalized_mutual_info(&a, &b) - want).abs() < 1e-12);
    }

    /// One partition lumping everything is independent of any other: MI = 0
    /// (NMI 0), and ARI's expected index equals the achieved index (ARI 0).
    #[test]
    fn trivial_vs_split_partition_scores_zero() {
        let a = vec![0, 0, 1, 1];
        let b = vec![7, 7, 7, 7];
        assert!(adjusted_rand_index(&a, &b).abs() < 1e-12);
        assert!(normalized_mutual_info(&a, &b).abs() < 1e-12);
    }

    /// Noise (−1) is a label like any other: relabeling it preserves 1.0,
    /// and moving one point out of noise costs agreement.
    #[test]
    fn noise_labels_participate_as_a_cluster() {
        let a = vec![-1, -1, 0, 0, 1];
        let b = vec![5, 5, 9, 9, 3];
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
        assert!((normalized_mutual_info(&a, &b) - 1.0).abs() < 1e-12);
        let c = vec![-1, 0, 0, 0, 1];
        assert!(adjusted_rand_index(&a, &c) < 1.0);
    }

    #[test]
    fn single_cluster_degenerate_cases() {
        let a = vec![0; 10];
        assert_eq!(adjusted_rand_index(&a, &a), 1.0);
        assert_eq!(normalized_mutual_info(&a, &a), 1.0);
    }
}
