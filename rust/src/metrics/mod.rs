//! Clustering-quality metrics: Adjusted Rand Index and Normalized Mutual
//! Information. Used to verify (a) exactness — our five Step-2 algorithms
//! must yield ARI = 1 against each other — and (b) the quality of the
//! approximate baseline and the XLA brute-force backend against the exact
//! engine.
//!
//! Labels use the convention of [`crate::dpc::DpcResult`]: any i64, −1 =
//! noise. Noise is treated as its own (shared) label, matching how the
//! paper's quality comparisons count unassigned points.

use std::collections::HashMap;

fn contingency(a: &[i64], b: &[i64]) -> (HashMap<(i64, i64), f64>, HashMap<i64, f64>, HashMap<i64, f64>) {
    assert_eq!(a.len(), b.len());
    let mut joint: HashMap<(i64, i64), f64> = HashMap::new();
    let mut ma: HashMap<i64, f64> = HashMap::new();
    let mut mb: HashMap<i64, f64> = HashMap::new();
    for i in 0..a.len() {
        *joint.entry((a[i], b[i])).or_insert(0.0) += 1.0;
        *ma.entry(a[i]).or_insert(0.0) += 1.0;
        *mb.entry(b[i]).or_insert(0.0) += 1.0;
    }
    (joint, ma, mb)
}

fn comb2(x: f64) -> f64 {
    x * (x - 1.0) / 2.0
}

/// Adjusted Rand Index in [−1, 1]; 1 = identical partitions.
pub fn adjusted_rand_index(a: &[i64], b: &[i64]) -> f64 {
    let n = a.len() as f64;
    if n < 2.0 {
        return 1.0;
    }
    let (joint, ma, mb) = contingency(a, b);
    let sum_ij: f64 = joint.values().map(|&v| comb2(v)).sum();
    let sum_a: f64 = ma.values().map(|&v| comb2(v)).sum();
    let sum_b: f64 = mb.values().map(|&v| comb2(v)).sum();
    let expected = sum_a * sum_b / comb2(n);
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return 1.0; // degenerate: both trivial partitions
    }
    (sum_ij - expected) / (max_index - expected)
}

/// Normalized Mutual Information in [0, 1] (arithmetic-mean normalization).
pub fn normalized_mutual_info(a: &[i64], b: &[i64]) -> f64 {
    let n = a.len() as f64;
    if n == 0.0 {
        return 1.0;
    }
    let (joint, ma, mb) = contingency(a, b);
    let mut mi = 0.0;
    for (&(x, y), &nxy) in &joint {
        let px = ma[&x] / n;
        let py = mb[&y] / n;
        let pxy = nxy / n;
        mi += pxy * (pxy / (px * py)).ln();
    }
    let ha: f64 = -ma.values().map(|&v| (v / n) * (v / n).ln()).sum::<f64>();
    let hb: f64 = -mb.values().map(|&v| (v / n) * (v / n).ln()).sum::<f64>();
    if ha < 1e-12 && hb < 1e-12 {
        return 1.0;
    }
    (mi / (0.5 * (ha + hb))).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_partitions_score_one() {
        let a = vec![0, 0, 1, 1, 2, -1];
        assert_eq!(adjusted_rand_index(&a, &a), 1.0);
        assert!((normalized_mutual_info(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn renamed_labels_score_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        let b = vec![7, 7, 3, 3, 9, 9];
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
        assert!((normalized_mutual_info(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn independent_partitions_score_near_zero() {
        // Alternating vs. block labels over 1000 points.
        let a: Vec<i64> = (0..1000).map(|i| i % 2).collect();
        let b: Vec<i64> = (0..1000).map(|i| i / 500).collect();
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari.abs() < 0.05, "ari={ari}");
    }

    #[test]
    fn partial_agreement_between_zero_and_one() {
        let a = vec![0, 0, 0, 1, 1, 1];
        let b = vec![0, 0, 1, 1, 1, 1];
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari > 0.0 && ari < 1.0, "ari={ari}");
        let nmi = normalized_mutual_info(&a, &b);
        assert!(nmi > 0.0 && nmi < 1.0, "nmi={nmi}");
    }

    #[test]
    fn single_cluster_degenerate_cases() {
        let a = vec![0; 10];
        assert_eq!(adjusted_rand_index(&a, &a), 1.0);
        assert_eq!(normalized_mutual_info(&a, &a), 1.0);
    }
}
