//! A minimal Rust lexer for `pallas-lint` (dependency-free by design).
//!
//! Produces just enough structure for the rule pass: identifier and
//! punctuation tokens with line numbers, a per-line map of comment text,
//! and the raw lines. Comments, string/char literals (including raw and
//! byte forms), lifetimes, and numeric literals are recognized so that
//! rule needles (`unwrap`, `Ordering::Relaxed`, …) can never false-match
//! inside a string or a comment. This is a *lexer*, not a parser — the
//! rules operate on token patterns, which is exactly the right fidelity
//! for contract linting (and keeps the checker ~free of parse-evolution
//! churn).

use std::collections::HashMap;

/// One lexed token. Literals and lifetimes are deliberately dropped from
/// the stream — no rule needs them, and their absence can't create false
/// token adjacencies for the patterns we match (none spans a literal).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (`unsafe`, `unwrap`, `Ordering`, …).
    Ident(String),
    /// A single punctuation character (`::` arrives as two `:`).
    Punct(char),
}

/// A token plus the 1-based line it starts on.
#[derive(Debug, Clone)]
pub struct SpannedTok {
    pub tok: Tok,
    pub line: u32,
}

/// Lexed view of one source file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<SpannedTok>,
    /// Concatenated comment text per 1-based line. Block comments append
    /// their full text to every line they span, so adjacency checks see
    /// them from any covered line.
    pub comments: HashMap<u32, String>,
    /// Raw source lines (index 0 = line 1).
    pub lines: Vec<String>,
}

impl Lexed {
    pub fn ident_at(&self, i: usize) -> Option<&str> {
        match self.tokens.get(i).map(|t| &t.tok) {
            Some(Tok::Ident(s)) => Some(s),
            _ => None,
        }
    }

    pub fn punct_at(&self, i: usize) -> Option<char> {
        match self.tokens.get(i).map(|t| &t.tok) {
            Some(Tok::Punct(c)) => Some(*c),
            _ => None,
        }
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `text`. Never fails: unterminated constructs consume to EOF, which
/// is the forgiving behavior a linter wants (the compiler owns rejection).
pub fn lex(text: &str) -> Lexed {
    let mut out = Lexed { lines: text.lines().map(str::to_string).collect(), ..Lexed::default() };
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut i = 0usize;
    let mut line: u32 = 1;

    // Record `chars[start..end]` as comment text for `first_line` and, if
    // the comment spans lines, each later covered line too.
    let push_comment = |out: &mut Lexed, chars: &[char], start: usize, end: usize, first_line: u32| {
        let text: String = chars[start..end].iter().collect();
        let mut l = first_line;
        for seg in text.split('\n') {
            let entry = out.comments.entry(l).or_default();
            if !entry.is_empty() {
                entry.push(' ');
            }
            entry.push_str(seg.trim());
            l += 1;
        }
    };

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also covers /// and //!).
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            push_comment(&mut out, &chars, start, i, line);
            continue;
        }
        // Block comment, possibly nested.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start = i;
            let first_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            push_comment(&mut out, &chars, start, i, first_line);
            continue;
        }
        // Identifier, keyword, or a string/char prefix (r, b, br, r#raw_id).
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_continue(chars[i]) {
                i += 1;
            }
            let word: String = chars[start..i].iter().collect();
            // String-literal prefixes: `r"`, `b"`, `br"`, `r#"` (any number
            // of hashes), `br#"`, and the byte-char `b'`.
            if matches!(word.as_str(), "r" | "b" | "br") && i < n {
                if chars[i] == '"' {
                    i = consume_string(&chars, i, &mut line, word.starts_with('r') || word == "br");
                    continue;
                }
                if chars[i] == '#' && (word == "r" || word == "br") {
                    let mut j = i;
                    while j < n && chars[j] == '#' {
                        j += 1;
                    }
                    if j < n && chars[j] == '"' {
                        i = consume_raw_string(&chars, i, &mut line);
                        continue;
                    }
                    // `r#ident` raw identifier.
                    if word == "r" && j == i + 1 && j < n && is_ident_start(chars[j]) {
                        i = j;
                        let id_start = i;
                        while i < n && is_ident_continue(chars[i]) {
                            i += 1;
                        }
                        let id: String = chars[id_start..i].iter().collect();
                        out.tokens.push(SpannedTok { tok: Tok::Ident(id), line });
                        continue;
                    }
                }
                if word == "b" && chars[i] == '\'' {
                    i = consume_char(&chars, i, &mut line);
                    continue;
                }
            }
            out.tokens.push(SpannedTok { tok: Tok::Ident(word), line });
            continue;
        }
        // Plain string literal.
        if c == '"' {
            i = consume_string(&chars, i, &mut line, false);
            continue;
        }
        // Lifetime or char literal.
        if c == '\'' {
            // Lifetime: 'ident NOT followed by a closing quote.
            if i + 1 < n && is_ident_start(chars[i + 1]) {
                let mut j = i + 1;
                while j < n && is_ident_continue(chars[j]) {
                    j += 1;
                }
                if j < n && chars[j] == '\'' {
                    // 'a' — a char literal after all.
                    i = j + 1;
                } else {
                    i = j; // lifetime: dropped from the stream
                }
                continue;
            }
            i = consume_char(&chars, i, &mut line);
            continue;
        }
        // Numeric literal (digits, type suffixes, `0x…`, and a decimal
        // point only when followed by a digit so `0..n` stays 3 tokens).
        if c.is_ascii_digit() {
            i += 1;
            while i < n {
                let d = chars[i];
                if is_ident_continue(d) {
                    i += 1;
                } else if d == '.' && i + 1 < n && chars[i + 1].is_ascii_digit() {
                    i += 1;
                } else {
                    break;
                }
            }
            continue;
        }
        out.tokens.push(SpannedTok { tok: Tok::Punct(c), line });
        i += 1;
    }
    out
}

/// Consume a (possibly byte) string starting at the opening quote; in raw
/// mode backslashes are literal.
fn consume_string(chars: &[char], mut i: usize, line: &mut u32, raw: bool) -> usize {
    let n = chars.len();
    i += 1; // opening quote
    while i < n {
        match chars[i] {
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            '\\' if !raw => i += 2,
            _ => i += 1,
        }
    }
    i
}

/// Consume `r#…#"…"#…#` from the first `#`.
fn consume_raw_string(chars: &[char], mut i: usize, line: &mut u32) -> usize {
    let n = chars.len();
    let mut hashes = 0usize;
    while i < n && chars[i] == '#' {
        hashes += 1;
        i += 1;
    }
    i += 1; // opening quote
    while i < n {
        if chars[i] == '\n' {
            *line += 1;
            i += 1;
            continue;
        }
        if chars[i] == '"' {
            let mut j = i + 1;
            let mut seen = 0usize;
            while j < n && chars[j] == '#' && seen < hashes {
                seen += 1;
                j += 1;
            }
            if seen == hashes {
                return j;
            }
        }
        i += 1;
    }
    i
}

/// Consume a char (or byte-char) literal from the opening quote.
fn consume_char(chars: &[char], mut i: usize, line: &mut u32) -> usize {
    let n = chars.len();
    i += 1; // opening quote
    while i < n {
        match chars[i] {
            '\'' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            '\\' => i += 2,
            _ => i += 1,
        }
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Ident(s) => Some(s.clone()),
                Tok::Punct(_) => None,
            })
            .collect()
    }

    #[test]
    fn strings_and_comments_hide_needles() {
        let src = r##"
            let a = "x.unwrap()"; // unwrap here is comment text
            let b = r#"panic!("still a string")"#;
            /* Ordering::Relaxed in a block comment */
            let c = 'u';
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap".to_string()), "{ids:?}");
        assert!(!ids.contains(&"panic".to_string()), "{ids:?}");
        assert!(!ids.contains(&"Relaxed".to_string()), "{ids:?}");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { let c = 'x'; x }";
        let ids = idents(src);
        // The 'x' char literal must not swallow the closing brace.
        assert!(lex(src).tokens.iter().any(|t| t.tok == Tok::Punct('}')));
        assert!(ids.contains(&"str".to_string()));
    }

    #[test]
    fn comment_text_lands_on_every_spanned_line() {
        let src = "/* one\ntwo SAFETY\nthree */\nlet x = 1;\n";
        let lx = lex(src);
        assert!(lx.comments.get(&2).is_some_and(|t| t.contains("SAFETY")));
        assert!(lx.comments.contains_key(&1) && lx.comments.contains_key(&3));
        assert!(!lx.comments.contains_key(&4));
    }

    #[test]
    fn line_numbers_track_multiline_strings() {
        let src = "let s = \"a\nb\nc\";\nfoo();\n";
        let lx = lex(src);
        let foo = lx.tokens.iter().find(|t| t.tok == Tok::Ident("foo".into()));
        assert_eq!(foo.map(|t| t.line), Some(4));
    }

    #[test]
    fn raw_identifiers_lex_as_idents() {
        let ids = idents("let r#type = 1; let s = r#\"raw \" string\"#;");
        assert!(ids.contains(&"type".to_string()));
        assert!(!ids.contains(&"raw".to_string()));
    }

    #[test]
    fn nested_block_comments_terminate_correctly() {
        let src = "/* outer /* inner */ still comment */ fn live() {}";
        let ids = idents(src);
        assert_eq!(ids, vec!["fn".to_string(), "live".to_string()]);
    }
}
