//! The pallas-lint rule catalog (DESIGN.md §Static analysis).
//!
//! Every rule is a token-pattern check over [`super::lexer::Lexed`] with a
//! shared *attachment* discipline for justification comments: a comment
//! satisfies a site if it sits on the same line, or anywhere in the
//! contiguous block of comment/attribute lines directly above the site's
//! line (a blank or code line breaks attachment). That is exactly where
//! human reviewers expect the justification to live.
//!
//! Suppression grammar (checked by the `allow-grammar` meta-rule):
//!
//! ```text
//! // lint: allow(panic-surface) — why this site cannot fire in practice
//! ```
//!
//! (Any rule name from the catalog may appear in place of
//! `panic-surface`; the justification must be non-empty.)
//!
//! (`--` is accepted in place of the em-dash.) Test regions — items under
//! `#[test]` or `#[cfg(test)]` — are excluded from every rule.

use std::collections::HashSet;
use std::fmt;

use super::lexer::{Lexed, Tok};

/// Rule identifiers; `name()` is the string used in allow comments, CI
/// output, and DESIGN.md.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    /// `unwrap`/`expect`/`panic!`-family (plus slice indexing in wire
    /// decode paths) in production code without a justification.
    PanicSurface,
    /// FMA-family operations in the bit-exactness kernel paths
    /// (`geom`/`kdtree`/`pskd`): fused rounding breaks the byte-identical
    /// ρ/λ/δ contract (DESIGN.md §2c).
    FloatDeterminism,
    /// `Ordering::Relaxed` without a `relaxed:` audit comment.
    RelaxedOrdering,
    /// Allocation (or slice indexing) in wire decode paths without a
    /// `bounds:` audit comment tying it to the length check that
    /// precedes it.
    WireSafety,
    /// `unsafe` without an attached `SAFETY`/`# Safety` comment.
    SafetyComment,
    /// A suppression comment that doesn't parse, names an unknown rule,
    /// or omits the justification.
    AllowGrammar,
}

impl Rule {
    pub const ALL: [Rule; 6] = [
        Rule::PanicSurface,
        Rule::FloatDeterminism,
        Rule::RelaxedOrdering,
        Rule::WireSafety,
        Rule::SafetyComment,
        Rule::AllowGrammar,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Rule::PanicSurface => "panic-surface",
            Rule::FloatDeterminism => "float-determinism",
            Rule::RelaxedOrdering => "relaxed-ordering",
            Rule::WireSafety => "wire-safety",
            Rule::SafetyComment => "safety-comment",
            Rule::AllowGrammar => "allow-grammar",
        }
    }

    fn from_name(name: &str) -> Option<Rule> {
        Rule::ALL.into_iter().find(|r| r.name() == name)
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One finding. `file` is the path relative to the scan root.
#[derive(Debug, Clone)]
pub struct Violation {
    pub file: String,
    pub line: u32,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Receiver methods whose `.unwrap()` is idiomatic, not a panic surface:
/// mutex/rwlock poisoning unwraps (poison is fatal by crate policy) and
/// condvar waits.
const POISON_EXEMPT_CALLEES: [&str; 5] = ["lock", "read", "write", "wait", "wait_timeout"];

/// Idents that mark an FMA-family operation.
fn is_fma_ident(id: &str) -> bool {
    id == "mul_add" || id == "fma" || id == "fmaf" || (id.starts_with("_mm") && id.contains("fm"))
}

/// Whether `path` (slash-separated, relative) is inside one of `dirs`.
fn in_dirs(path: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| path.starts_with(&format!("{d}/")) || path.starts_with(d) && path == *d)
}

/// Kernel paths under the float-determinism contract.
fn is_kernel_path(path: &str) -> bool {
    in_dirs(path, &["geom", "kdtree", "pskd"])
}

/// Wire decode paths under the wire-safety contract.
fn is_wire_path(path: &str) -> bool {
    path == "durability/wire.rs" || path == "serve/frame.rs"
}

/// Scan one already-lexed file. `path` drives the path-scoped rules and
/// is echoed into violations.
pub fn check(path: &str, lx: &Lexed) -> Vec<Violation> {
    let excluded = test_region_lines(lx);
    let mut out = Vec::new();
    let v = |out: &mut Vec<Violation>, line: u32, rule: Rule, message: String| {
        out.push(Violation { file: path.to_string(), line, rule, message });
    };

    check_allow_grammar(path, lx, &mut out);

    let toks = &lx.tokens;
    for i in 0..toks.len() {
        let line = toks[i].line;
        if excluded.contains(&line) {
            continue;
        }
        let id = match &toks[i].tok {
            Tok::Ident(s) => s.as_str(),
            Tok::Punct(_) => continue,
        };

        // --- panic-surface ------------------------------------------------
        if (id == "unwrap" || id == "expect")
            && lx.punct_at(i.wrapping_sub(1)) == Some('.')
            && lx.punct_at(i + 1) == Some('(')
        {
            let exempt = id == "unwrap" && poison_exempt(lx, i);
            if !exempt && !allowed(lx, line, Rule::PanicSurface) {
                v(&mut out, line, Rule::PanicSurface, format!(".{id}() without `lint: allow(panic-surface)`"));
            }
        }
        if matches!(id, "panic" | "unreachable" | "todo" | "unimplemented") && lx.punct_at(i + 1) == Some('!') {
            // `core::panic!` et al. in macro-rules output don't occur here;
            // plain invocation is the only shape in this tree.
            if !allowed(lx, line, Rule::PanicSurface) {
                v(&mut out, line, Rule::PanicSurface, format!("{id}! without `lint: allow(panic-surface)`"));
            }
        }
        // Slice indexing in wire decode paths: `ident[...]` where the
        // bracket opens an expression index (an ident directly before `[`
        // rules out attribute and type positions).
        if is_wire_path(path) && lx.punct_at(i + 1) == Some('[') && !matches!(id, "mut" | "dyn" | "in") {
            if !audited(lx, line, "bounds:") && !allowed(lx, line, Rule::PanicSurface) {
                v(
                    &mut out,
                    line,
                    Rule::PanicSurface,
                    format!("slice index `{id}[..]` in a wire path without a `bounds:` audit comment"),
                );
            }
        }

        // --- float-determinism -------------------------------------------
        if is_kernel_path(path) && is_fma_ident(id) && !allowed(lx, line, Rule::FloatDeterminism) {
            v(
                &mut out,
                line,
                Rule::FloatDeterminism,
                format!("`{id}` fuses the multiply-add rounding step; kernel paths must stay bit-identical (DESIGN.md §2c)"),
            );
        }

        // --- relaxed-ordering --------------------------------------------
        if id == "Relaxed"
            && lx.punct_at(i.wrapping_sub(1)) == Some(':')
            && lx.punct_at(i.wrapping_sub(2)) == Some(':')
            && lx.ident_at(i.wrapping_sub(3)) == Some("Ordering")
        {
            if !audited(lx, line, "relaxed:") && !allowed(lx, line, Rule::RelaxedOrdering) {
                v(
                    &mut out,
                    line,
                    Rule::RelaxedOrdering,
                    "Ordering::Relaxed without a `relaxed:` audit comment".to_string(),
                );
            }
        }

        // --- wire-safety ---------------------------------------------------
        if is_wire_path(path)
            && matches!(id, "with_capacity" | "reserve" | "resize" | "to_vec")
            && lx.punct_at(i + 1) == Some('(')
        {
            if !audited(lx, line, "bounds:") && !allowed(lx, line, Rule::WireSafety) {
                v(
                    &mut out,
                    line,
                    Rule::WireSafety,
                    format!("allocation `{id}` in a wire path without a `bounds:` audit comment citing the preceding length check"),
                );
            }
        }

        // --- safety-comment ------------------------------------------------
        if id == "unsafe" {
            let has = attached(lx, line, |t| t.contains("SAFETY") || t.contains("# Safety"));
            if !has && !allowed(lx, line, Rule::SafetyComment) {
                v(
                    &mut out,
                    line,
                    Rule::SafetyComment,
                    "`unsafe` without an attached SAFETY / `# Safety` comment".to_string(),
                );
            }
        }
    }
    out
}

/// `.unwrap()` whose receiver is a direct `lock()/read()/write()/wait(..)`
/// call: `<callee> ( … ) . unwrap` — walk back over the balanced argument
/// parens to find the callee.
fn poison_exempt(lx: &Lexed, unwrap_idx: usize) -> bool {
    // tokens: … callee ( args ) . unwrap
    if unwrap_idx < 4 || lx.punct_at(unwrap_idx - 2) != Some(')') {
        return false;
    }
    let mut depth = 0i32;
    let mut j = unwrap_idx - 2;
    loop {
        match lx.punct_at(j) {
            Some(')') => depth += 1,
            Some('(') => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
        if j == 0 {
            return false;
        }
        j -= 1;
    }
    j > 0 && lx.ident_at(j - 1).is_some_and(|c| POISON_EXEMPT_CALLEES.contains(&c))
}

/// Lines covered by `#[test]`- or `#[cfg(test)]`-attributed items
/// (including `mod tests` blocks). Token-level skip: after the marker
/// attribute (and any further attributes), the item extends to the
/// matching close brace — or to a top-level `;` for brace-less items —
/// tracking all three bracket kinds so `;` inside `[u8; 4]` or argument
/// lists can't end the skip early.
fn test_region_lines(lx: &Lexed) -> HashSet<u32> {
    let toks = &lx.tokens;
    let mut excluded = HashSet::new();
    let mut i = 0usize;
    while i < toks.len() {
        if lx.punct_at(i) == Some('#') && lx.punct_at(i + 1) == Some('[') {
            let (attr_ids, after) = read_attr(lx, i + 1);
            let is_test = attr_ids.iter().any(|s| s == "test") && !attr_ids.iter().any(|s| s == "not");
            if is_test {
                let start_line = toks[i].line;
                // Skip any stacked attributes between the marker and the item.
                let mut k = after;
                while lx.punct_at(k) == Some('#') && lx.punct_at(k + 1) == Some('[') {
                    let (_, nxt) = read_attr(lx, k + 1);
                    k = nxt;
                }
                // Consume the item.
                let (mut paren, mut brack, mut brace) = (0i32, 0i32, 0i32);
                let mut end = toks.len().saturating_sub(1);
                while k < toks.len() {
                    match lx.punct_at(k) {
                        Some('(') => paren += 1,
                        Some(')') => paren -= 1,
                        Some('[') => brack += 1,
                        Some(']') => brack -= 1,
                        Some('{') => brace += 1,
                        Some('}') => {
                            brace -= 1;
                            if brace == 0 && paren == 0 && brack == 0 {
                                end = k;
                                break;
                            }
                        }
                        Some(';') if brace == 0 && paren == 0 && brack == 0 => {
                            end = k;
                            break;
                        }
                        _ => {}
                    }
                    k += 1;
                }
                let end_line = toks.get(end).map_or(start_line, |t| t.line);
                for l in start_line..=end_line {
                    excluded.insert(l);
                }
                i = end + 1;
                continue;
            }
            i = after;
            continue;
        }
        i += 1;
    }
    excluded
}

/// Read an attribute starting at its `[` token; returns the identifiers
/// inside and the index just past the matching `]`.
fn read_attr(lx: &Lexed, open_idx: usize) -> (Vec<String>, usize) {
    let mut ids = Vec::new();
    let mut depth = 0i32;
    let mut j = open_idx;
    while j < lx.tokens.len() {
        match &lx.tokens[j].tok {
            Tok::Punct('[') => depth += 1,
            Tok::Punct(']') => {
                depth -= 1;
                if depth == 0 {
                    return (ids, j + 1);
                }
            }
            Tok::Ident(s) => ids.push(s.clone()),
            Tok::Punct(_) => {}
        }
        j += 1;
    }
    (ids, j)
}

/// Does a comment matching `pred` sit on `line` or in the contiguous
/// comment/attribute block directly above it?
fn attached(lx: &Lexed, line: u32, pred: impl Fn(&str) -> bool) -> bool {
    if lx.comments.get(&line).is_some_and(|t| pred(t)) {
        return true;
    }
    let mut j = line.saturating_sub(1);
    while j >= 1 {
        if lx.comments.get(&j).is_some_and(|t| pred(t)) {
            return true;
        }
        if !passable_line(lx, j) {
            return false;
        }
        j -= 1;
    }
    false
}

/// A line the attachment walk may cross: pure comment, attribute, or a
/// block-comment interior. Blank lines and code lines break attachment.
fn passable_line(lx: &Lexed, line: u32) -> bool {
    if lx.comments.contains_key(&line) {
        // A line with comment text is passable only if it has no code
        // before the comment (a trailing comment on a code line must not
        // extend attachment past that code).
        let raw = lx.lines.get(line as usize - 1).map(String::as_str).unwrap_or("");
        let t = raw.trim_start();
        return t.starts_with("//") || t.starts_with("/*") || t.starts_with('*') || t.starts_with("*/");
    }
    let raw = lx.lines.get(line as usize - 1).map(String::as_str).unwrap_or("");
    let t = raw.trim_start();
    t.starts_with("#[") || t.starts_with("#![")
}

/// Attached audit comment containing `tag` (e.g. `relaxed:`/`bounds:`).
fn audited(lx: &Lexed, line: u32, tag: &str) -> bool {
    attached(lx, line, |t| t.contains(tag))
}

/// Attached, well-formed suppression clause naming `rule`.
fn allowed(lx: &Lexed, line: u32, rule: Rule) -> bool {
    attached(lx, line, |t| parse_allows(t).iter().any(|a| matches!(a, Ok(r) if *r == rule)))
}

/// All suppression clauses in one comment text. `Err(offset)` marks a
/// malformed clause (bad grammar, unknown rule, or missing justification).
fn parse_allows(text: &str) -> Vec<Result<Rule, usize>> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = text[from..].find("lint: allow") {
        let at = from + p;
        let rest = &text[at + "lint: allow".len()..];
        from = at + "lint: allow".len();
        let Some(open_rest) = rest.strip_prefix('(') else {
            out.push(Err(at));
            continue;
        };
        let Some(close) = open_rest.find(')') else {
            out.push(Err(at));
            continue;
        };
        let name = open_rest[..close].trim();
        let Some(rule) = Rule::from_name(name) else {
            out.push(Err(at));
            continue;
        };
        // Separator (— or -) plus a non-empty justification.
        let after = open_rest[close + 1..].trim_start();
        let just = after.strip_prefix('—').or_else(|| after.strip_prefix('-')).map(|s| s.trim_matches('-').trim());
        match just {
            Some(j) if !j.is_empty() => out.push(Ok(rule)),
            _ => out.push(Err(at)),
        }
    }
    out
}

/// The allow-grammar meta-rule: every suppression mention must parse.
fn check_allow_grammar(path: &str, lx: &Lexed, out: &mut Vec<Violation>) {
    let mut lines: Vec<&u32> = lx.comments.keys().collect();
    lines.sort();
    let mut seen_multiline: HashSet<(u32, usize)> = HashSet::new();
    for &line in lines {
        let Some(text) = lx.comments.get(&line) else { continue };
        for a in parse_allows(text) {
            if let Err(off) = a {
                // Block comments repeat their text on every covered line;
                // report each malformed clause once (at its first line).
                if seen_multiline.insert((line, off)) && !lx.comments.get(&line.saturating_sub(1)).is_some_and(|p| p == text)
                {
                    out.push(Violation {
                        file: path.to_string(),
                        line,
                        rule: Rule::AllowGrammar,
                        message: "malformed `lint: allow` — expected `lint: allow(<rule>) — <justification>` with a known rule name".to_string(),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn scan(path: &str, src: &str) -> Vec<Violation> {
        check(path, &lex(src))
    }

    fn rules_hit(path: &str, src: &str) -> Vec<Rule> {
        scan(path, src).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn unwrap_flagged_and_allow_clears_it() {
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert_eq!(rules_hit("m.rs", bad), vec![Rule::PanicSurface]);
        let ok = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(panic-surface) — caller checked is_some\n    x.unwrap()\n}";
        assert!(scan("m.rs", ok).is_empty());
    }

    #[test]
    fn poison_unwraps_are_builtin_exempt() {
        let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }";
        assert!(scan("m.rs", src).is_empty());
        let src = "fn g(g: G) -> G { cv.wait(g).unwrap() }";
        assert!(scan("m.rs", src).is_empty());
        // …but an unwrap on something else is not.
        let src = "fn h() -> u32 { compute().unwrap() }";
        assert_eq!(rules_hit("m.rs", src), vec![Rule::PanicSurface]);
    }

    #[test]
    fn test_regions_are_excluded() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { foo().unwrap(); panic!(\"x\"); }\n}\n";
        assert!(scan("m.rs", src).is_empty());
        // Production code after a test item is still checked.
        let src2 = "#[test]\nfn t() { foo().unwrap(); }\nfn prod() { bar().unwrap(); }\n";
        let v = scan("m.rs", src2);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn cfg_not_test_is_production() {
        let src = "#[cfg(not(test))]\nfn prod() { foo().unwrap(); }\n";
        assert_eq!(rules_hit("m.rs", src), vec![Rule::PanicSurface]);
    }

    #[test]
    fn fma_only_fires_in_kernel_paths() {
        let src = "fn f(a: f64, b: f64, c: f64) -> f64 { a.mul_add(b, c) }";
        assert_eq!(rules_hit("geom/scalar.rs", src), vec![Rule::FloatDeterminism]);
        assert_eq!(rules_hit("kdtree/mod.rs", src), vec![Rule::FloatDeterminism]);
        assert!(scan("bench.rs", src).is_empty());
    }

    #[test]
    fn relaxed_needs_audit_tag() {
        let bad = "fn f(a: &AtomicU64) -> u64 { a.load(Ordering::Relaxed) }";
        assert_eq!(rules_hit("m.rs", bad), vec![Rule::RelaxedOrdering]);
        let ok = "fn f(a: &AtomicU64) -> u64 {\n    // relaxed: monotonic counter, no ordering dependency\n    a.load(Ordering::Relaxed)\n}";
        assert!(scan("m.rs", ok).is_empty());
        let trailing = "fn f(a: &AtomicU64) -> u64 { a.load(Ordering::Relaxed) } // relaxed: counter";
        assert!(scan("m.rs", trailing).is_empty());
    }

    #[test]
    fn wire_allocation_needs_bounds_audit() {
        let bad = "fn d(n: usize) -> Vec<u8> { Vec::with_capacity(n) }";
        assert_eq!(rules_hit("durability/wire.rs", bad), vec![Rule::WireSafety]);
        let ok = "fn d(n: usize) -> Vec<u8> {\n    // bounds: n checked against remaining() above\n    Vec::with_capacity(n)\n}";
        assert!(scan("durability/wire.rs", ok).is_empty());
        // Outside wire paths the allocation rule does not apply.
        assert!(scan("dpc/mod.rs", bad).is_empty());
    }

    #[test]
    fn wire_indexing_needs_bounds_audit() {
        let bad = "fn d(buf: &[u8], i: usize) -> u8 { buf[i] }";
        assert_eq!(rules_hit("serve/frame.rs", bad), vec![Rule::PanicSurface]);
        assert!(scan("kdtree/mod.rs", bad).is_empty());
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let bad = "fn f(p: *const u8) -> u8 { unsafe { *p } }";
        assert_eq!(rules_hit("m.rs", bad), vec![Rule::SafetyComment]);
        let ok = "fn f(p: *const u8) -> u8 {\n    // SAFETY: p is valid for reads (caller contract).\n    unsafe { *p }\n}";
        assert!(scan("m.rs", ok).is_empty());
        // A `# Safety` doc section on an unsafe fn counts, across attributes.
        let doc = "/// Does things.\n///\n/// # Safety\n/// `p` must be valid.\n#[inline]\npub unsafe fn g(p: *const u8) -> u8 { unsafe { *p } }";
        let v = scan("m.rs", doc);
        // The inner unsafe block is covered by the same attached doc walk.
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn attachment_breaks_across_code_lines() {
        let src = "// SAFETY: explains the FIRST block only\nlet a = unsafe { f() };\nlet b = unsafe { g() };\n";
        let v = scan("m.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn malformed_allow_is_its_own_violation() {
        let unknown = "// lint: allow(no-such-rule) — whatever\nfn f() {}\n";
        assert_eq!(rules_hit("m.rs", unknown), vec![Rule::AllowGrammar]);
        let missing_just = "// lint: allow(panic-surface)\nfn f() { x.unwrap(); }\n";
        let hits = rules_hit("m.rs", missing_just);
        assert!(hits.contains(&Rule::AllowGrammar));
        assert!(hits.contains(&Rule::PanicSurface), "malformed allow must not suppress");
    }

    #[test]
    fn ascii_double_dash_separator_accepted() {
        let ok = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(panic-surface) -- invariant: x set by caller\n    x.unwrap()\n}";
        assert!(scan("m.rs", ok).is_empty());
    }
}
