//! `pallas-lint`: the in-repo static-analysis pass (DESIGN.md §Static
//! analysis).
//!
//! The exactness and concurrency contracts this crate makes — typed
//! [`crate::DpcError`]s instead of panics, no-FMA bit-identical kernels,
//! audited `Ordering::Relaxed`, length-checked wire decoding, and
//! `SAFETY`-commented `unsafe` — are enforced here as token-pattern rules
//! over a small dependency-free lexer, run by the `pallas_lint` binary and
//! CI. The runtime half of the same program is [`crate::sync::ordered`],
//! which turns the lock-order contract into a debug-build assertion.
//!
//! Entry points: [`scan_source`] for one file (used by the fixture tests),
//! [`scan_tree`] for a whole `rust/src` tree (used by the binary and the
//! self-scan test).

pub mod lexer;
pub mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use rules::{Rule, Violation};

/// Lint one file's source text. `relpath` is the path relative to the
/// scan root (slash-separated) — it selects the path-scoped rules
/// (kernel/wire) and is echoed into each [`Violation`].
pub fn scan_source(relpath: &str, text: &str) -> Vec<Violation> {
    rules::check(relpath, &lexer::lex(text))
}

/// Lint every `.rs` file under `root`, depth-first in sorted order so
/// output (and CI diffs) are deterministic. Violations come back grouped
/// by file in that same order.
pub fn scan_tree(root: &Path) -> io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in files {
        let text = fs::read_to_string(&path)?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        out.extend(scan_source(&rel, &text));
    }
    Ok(out)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let ty = entry.file_type()?;
        if ty.is_dir() {
            collect_rs_files(&path, out)?;
        } else if ty.is_file() && path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_source_routes_path_scoped_rules() {
        let src = "fn d(n: usize) -> Vec<u8> { Vec::with_capacity(n) }";
        assert_eq!(scan_source("durability/wire.rs", src).len(), 1);
        assert!(scan_source("dpc/mod.rs", src).is_empty());
    }

    #[test]
    fn scan_tree_is_deterministic_and_recursive() {
        let dir = std::env::temp_dir().join(format!("pallas_lint_scan_{}", std::process::id()));
        let sub = dir.join("geom");
        std::fs::create_dir_all(&sub).expect("create fixture tree");
        std::fs::write(dir.join("b.rs"), "fn f() { x.unwrap(); }").expect("write fixture");
        std::fs::write(sub.join("a.rs"), "fn g(a: f64) -> f64 { a.mul_add(a, a) }").expect("write fixture");
        std::fs::write(dir.join("notes.txt"), "x.unwrap()").expect("write fixture");

        let v = scan_tree(&dir).expect("scan fixture tree");
        let files: Vec<&str> = v.iter().map(|x| x.file.as_str()).collect();
        assert_eq!(files, vec!["b.rs", "geom/a.rs"]);
        assert_eq!(v[0].rule, Rule::PanicSurface);
        assert_eq!(v[1].rule, Rule::FloatDeterminism);

        std::fs::remove_dir_all(&dir).expect("remove fixture tree");
    }
}
