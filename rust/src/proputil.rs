//! A minimal property-based testing harness (proptest/quickcheck are not
//! available offline). Each property runs `cases` times with a deterministic
//! per-case seed derived from a base seed; a failure reports the case index
//! and seed so it can be replayed exactly.
//!
//! Used by the invariant suites in `rust/tests/` (see DESIGN.md §6 for the
//! invariant list).

use crate::dpc::{DensityModel, DpcParams};
use crate::geom::PointSet;
use crate::prng::SplitMix64;

/// Property-run configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: u64,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xDA7A_5EED }
    }
}

impl Config {
    pub fn cases(n: u64) -> Self {
        Config { cases: n, ..Default::default() }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Run `prop` on `cfg.cases` generated inputs. `gen` receives a fresh
/// deterministic RNG per case. Panics with replay info on the first failure.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cfg: Config, gen: G, prop: P)
where
    G: Fn(&mut SplitMix64) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(case);
        let mut rng = SplitMix64::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // lint: allow(panic-surface) — test harness: panicking with the
            // seed and input is exactly how a property failure reports.
            panic!(
                "property '{name}' FAILED at case {case}/{} (seed {case_seed:#x}):\n  {msg}\n  input: {input:?}",
                cfg.cases
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------------

/// Random point count in `[lo, hi]`.
pub fn gen_size(rng: &mut SplitMix64, lo: usize, hi: usize) -> usize {
    lo + rng.next_below((hi - lo + 1) as u64) as usize
}

/// Uniform points in `[0, extent)^d` (filled straight into the store's
/// shared allocation — no `Vec → Arc` copy).
pub fn gen_uniform_points(rng: &mut SplitMix64, n: usize, d: usize, extent: f64) -> PointSet {
    PointSet::from_flat_fn(n, d, |_| rng.uniform(0.0, extent))
}

/// Points on an integer grid in `[0, side)^d` — distances are exactly
/// representable, which removes floating-point boundary ambiguity when
/// comparing two different distance formulas (e.g. Rust engine vs XLA).
pub fn gen_grid_points(rng: &mut SplitMix64, n: usize, d: usize, side: u64) -> PointSet {
    PointSet::from_flat_fn(n, d, |_| rng.next_below(side) as f64)
}

/// Clustered points: `k` Gaussian blobs with uniform centers.
pub fn gen_clustered_points(rng: &mut SplitMix64, n: usize, d: usize, k: usize, extent: f64, sigma: f64) -> PointSet {
    let centers: Vec<f64> = (0..k * d).map(|_| rng.uniform(0.0, extent)).collect();
    let mut c = 0usize;
    PointSet::from_flat_fn(n, d, |idx| {
        let kdim = idx % d;
        if kdim == 0 {
            c = rng.next_below(k as u64) as usize;
        }
        centers[c * d + kdim] + sigma * rng.normal()
    })
}

/// Degenerate sets that stress tie-breaking: many duplicate points plus
/// collinear runs.
pub fn gen_degenerate_points(rng: &mut SplitMix64, n: usize, d: usize) -> PointSet {
    let mut coords = Vec::with_capacity(n * d);
    let n_dup = n / 3;
    let n_line = n / 3;
    for _ in 0..n_dup {
        for k in 0..d {
            coords.push(if k == 0 { 5.0 } else { 1.0 });
        }
    }
    for i in 0..n_line {
        for k in 0..d {
            coords.push(if k == 0 { i as f64 } else { 0.0 });
        }
    }
    for _ in 0..(n - n_dup - n_line) {
        for _ in 0..d {
            coords.push(rng.next_below(8) as f64);
        }
    }
    PointSet::new(coords, d)
}

/// A random density model: the four definitions are equally likely, with
/// `k` drawn small enough (1..=8) that k-NN radii stay meaningful on
/// property-test-sized inputs.
pub fn gen_density_model(rng: &mut SplitMix64) -> DensityModel {
    match rng.next_below(4) {
        0 => DensityModel::CutoffCount,
        1 => DensityModel::KnnRadius { k: 1 + rng.next_below(8) as u32 },
        2 => DensityModel::GaussianKernel,
        _ => DensityModel::Epanechnikov,
    }
}

/// Random DPC hyper-parameters for the oracle-differential suite. ρ_min is
/// drawn in the chosen model's own units (neighbor counts, ranks in `0..n`,
/// or fixed-point kernel mass — see `DpcParams::density`), so noise
/// thresholds actually bite under every model.
pub fn gen_dpc_params(rng: &mut SplitMix64) -> DpcParams {
    let density = gen_density_model(rng);
    let d_cut = [1.0, 2.0, 3.0, 5.0][rng.next_below(4) as usize];
    let rho_min = match density {
        DensityModel::CutoffCount => rng.next_below(5) as f64,
        DensityModel::KnnRadius { .. } => rng.next_below(12) as f64,
        // Kernel-mass units (weights of up to 4096 per in-ball neighbor).
        DensityModel::GaussianKernel | DensityModel::Epanechnikov => (rng.next_below(5) * 3000) as f64,
    };
    let delta_min = [0.0, 2.0, 4.0, 8.0, f64::INFINITY][rng.next_below(5) as usize];
    DpcParams { d_cut, rho_min, delta_min, density, ..DpcParams::default() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", Config::cases(16), |rng| rng.next_below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err(format!("{x} >= 100"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", Config::cases(8), |rng| rng.next_below(10), |&x| {
            if x < 5 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn generators_produce_requested_shapes() {
        let mut rng = SplitMix64::new(1);
        let ps = gen_uniform_points(&mut rng, 100, 3, 10.0);
        assert_eq!((ps.len(), ps.dim()), (100, 3));
        let ps = gen_grid_points(&mut rng, 50, 2, 4);
        assert!(ps.coords().iter().all(|&c| c.fract() == 0.0 && c < 4.0));
        let ps = gen_clustered_points(&mut rng, 60, 2, 3, 100.0, 1.0);
        assert_eq!(ps.len(), 60);
        let ps = gen_degenerate_points(&mut rng, 30, 2);
        assert_eq!(ps.len(), 30);
    }

    #[test]
    fn param_generator_spans_all_models_and_stays_valid() {
        let mut rng = SplitMix64::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            let p = gen_dpc_params(&mut rng);
            assert!(p.density.validate().is_ok());
            assert!(p.d_cut > 0.0 && p.d_cut.is_finite());
            assert!(!p.rho_min.is_nan() && p.rho_min.is_finite());
            assert!(!p.delta_min.is_nan());
            seen.insert(std::mem::discriminant(&p.density));
        }
        assert_eq!(seen.len(), 4, "all four models must be generated");
    }

    #[test]
    fn gen_size_bounds() {
        let mut rng = SplitMix64::new(2);
        for _ in 0..100 {
            let s = gen_size(&mut rng, 5, 9);
            assert!((5..=9).contains(&s));
        }
    }
}
