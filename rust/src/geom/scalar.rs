//! The coordinate scalar abstraction: a **sealed** trait over `f32`/`f64`
//! that the whole data path ([`super::PointStore`], the kd-tree family, the
//! DPC kernels) is generic over.
//!
//! Why f32 matters here: the paper's traversals are memory-bandwidth-bound,
//! and half-width coordinates halve the bytes every leaf scan and bounds
//! check moves (PECANN and the MPI matrix-DPC systems both run their hot
//! paths in single precision). Exactness is *per scalar type*: priorities
//! and ρ stay integer, distance comparisons happen in `S`, and the paper's
//! tie-break rules are precision-independent — so an f32 pipeline is the
//! exact DPC of the f32 point set. On datasets whose coordinates are exactly
//! representable in f32 (integer grids, sensor codes, quantized features,
//! see [`Scalar::lossless_from_f64`]), the f32 and f64 pipelines produce
//! byte-identical results; `rust/tests/conformance.rs` enforces that.

use std::fmt;

use crate::error::DpcError;

mod sealed {
    /// Seals [`super::Scalar`]: the unsafe traversal code (raw-pointer arena
    /// builders, `get_unchecked` leaf scans) is audited for exactly these
    /// two layouts.
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
}

/// Runtime tag for a coordinate precision — what flows through
/// [`crate::dpc::DpcParams`], `JobSpec`, the CLI `--dtype` flag, and the
/// `datasets::io` v2 header byte.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    #[default]
    F64,
}

impl Dtype {
    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F64 => "f64",
        }
    }

    /// Bytes per coordinate — also the self-describing tag byte of the
    /// `datasets::io` v2 binary header.
    pub fn size_bytes(&self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 => 8,
        }
    }

    /// Inverse of [`Dtype::size_bytes`], for header decoding.
    pub fn from_tag(tag: u8) -> Option<Dtype> {
        match tag {
            4 => Some(Dtype::F32),
            8 => Some(Dtype::F64),
            _ => None,
        }
    }
}

impl fmt::Display for Dtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Dtype {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "f32" => Ok(Dtype::F32),
            "f64" => Ok(Dtype::F64),
            other => Err(format!("unknown dtype {other:?} (expected f32 or f64)")),
        }
    }
}

/// A coordinate scalar: `f32` or `f64` (sealed).
///
/// The trait carries exactly what the data path needs — a squared-distance
/// kernel, comparisons/extrema, a little-endian byte codec for the on-disk
/// format, and the f64 bridge (`from_f64`/`to_f64`/`lossless_from_f64`)
/// used at precision-conversion boundaries.
pub trait Scalar:
    sealed::Sealed
    + Copy
    + PartialOrd
    + Default
    + fmt::Debug
    + fmt::Display
    + Send
    + Sync
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::AddAssign
    + 'static
{
    const DTYPE: Dtype;
    const ZERO: Self;
    const INFINITY: Self;
    const NEG_INFINITY: Self;
    /// Size of the little-endian encoding (4 or 8).
    const BYTES: usize;

    /// Narrowing (for `f32`) conversion from `f64`, rounding to nearest.
    fn from_f64(v: f64) -> Self;

    /// Widening (exact for both types) conversion to `f64`.
    fn to_f64(self) -> f64;

    /// Does `v` survive a `f64 → Self → f64` round trip bit-exactly?
    /// (`true` for every value when `Self = f64`.) This is the predicate
    /// behind "f32 preserves exactness on integer-coordinate data".
    fn lossless_from_f64(v: f64) -> bool;

    /// Neither NaN nor ±∞.
    fn finite(self) -> bool;

    /// `min`/`max` with the IEEE "other operand on NaN" semantics of the
    /// inherent float methods (inputs are validated finite upstream).
    fn smin(self, other: Self) -> Self;
    fn smax(self, other: Self) -> Self;

    /// Squared Euclidean distance between two coordinate slices of equal
    /// length, accumulated in `Self`.
    #[inline]
    fn dist_sq(a: &[Self], b: &[Self]) -> Self {
        debug_assert_eq!(a.len(), b.len());
        let mut s = Self::ZERO;
        for k in 0..a.len() {
            let t = a[k] - b[k];
            s += t * t;
        }
        s
    }

    /// Append the little-endian encoding to `out`.
    fn write_le(self, out: &mut Vec<u8>);

    /// Decode from the first [`Scalar::BYTES`] bytes of `bytes`.
    fn read_le(bytes: &[u8]) -> Self;
}

impl Scalar for f32 {
    const DTYPE: Dtype = Dtype::F32;
    const ZERO: f32 = 0.0;
    const INFINITY: f32 = f32::INFINITY;
    const NEG_INFINITY: f32 = f32::NEG_INFINITY;
    const BYTES: usize = 4;

    #[inline]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }

    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline]
    fn lossless_from_f64(v: f64) -> bool {
        // NaN is not lossless (payload aside, NaN coordinates are rejected
        // upstream anyway); ±∞ round-trips but is equally rejected later.
        (v as f32) as f64 == v
    }

    #[inline]
    fn finite(self) -> bool {
        self.is_finite()
    }

    #[inline]
    fn smin(self, other: f32) -> f32 {
        self.min(other)
    }

    #[inline]
    fn smax(self, other: f32) -> f32 {
        self.max(other)
    }

    #[inline]
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    #[inline]
    fn read_le(bytes: &[u8]) -> f32 {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl Scalar for f64 {
    const DTYPE: Dtype = Dtype::F64;
    const ZERO: f64 = 0.0;
    const INFINITY: f64 = f64::INFINITY;
    const NEG_INFINITY: f64 = f64::NEG_INFINITY;
    const BYTES: usize = 8;

    #[inline]
    fn from_f64(v: f64) -> f64 {
        v
    }

    #[inline]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline]
    fn lossless_from_f64(_v: f64) -> bool {
        true
    }

    #[inline]
    fn finite(self) -> bool {
        self.is_finite()
    }

    #[inline]
    fn smin(self, other: f64) -> f64 {
        self.min(other)
    }

    #[inline]
    fn smax(self, other: f64) -> f64 {
        self.max(other)
    }

    #[inline]
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    #[inline]
    fn read_le(bytes: &[u8]) -> f64 {
        f64::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3], bytes[4], bytes[5], bytes[6], bytes[7]])
    }
}

/// The squared query radius at precision `S`: convert the user-facing
/// `d_cut` first, square second, so "the radius of an f32 pipeline" is a
/// representable f32 — every layer (density, sessions, streams, engines)
/// must use this one definition or f32/f64 conformance on lossless data
/// breaks at ball boundaries.
#[inline]
pub fn radius_sq<S: Scalar>(d_cut: f64) -> S {
    let r = S::from_f64(d_cut);
    r * r
}

/// First coordinate of `coords` (flat, row-major over dimension `d`) that is
/// not losslessly representable at precision `S`, as `(point, dim)`.
pub fn first_lossy_coord<S: Scalar>(coords: &[f64], d: usize) -> Option<(usize, usize)> {
    coords
        .iter()
        .position(|&c| !S::lossless_from_f64(c))
        .map(|idx| (idx / d, idx % d))
}

/// Typed error for a requested lossless conversion that would round.
pub fn lossy_cast_error<S: Scalar>(point: usize, dim: usize, value: f64) -> DpcError {
    DpcError::LossyCast { point, dim, value, dtype: S::DTYPE.name() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_tags_round_trip() {
        for dt in [Dtype::F32, Dtype::F64] {
            assert_eq!(Dtype::from_tag(dt.size_bytes() as u8), Some(dt));
            assert_eq!(dt.name().parse::<Dtype>().unwrap(), dt);
        }
        assert_eq!(Dtype::from_tag(0), None);
        assert_eq!(Dtype::from_tag(16), None);
        assert!("f16".parse::<Dtype>().is_err());
        assert_eq!(Dtype::default(), Dtype::F64);
    }

    #[test]
    fn lossless_predicate() {
        // Small integers and power-of-two fractions survive f32.
        for v in [0.0, 1.0, -7.0, 1024.0, 0.5, 0.25, 16777216.0] {
            assert!(f32::lossless_from_f64(v), "{v}");
        }
        // 2^24 + 1 and typical decimals do not.
        for v in [16777217.0, 0.1, 1e300] {
            assert!(!f32::lossless_from_f64(v), "{v}");
        }
        assert!(f64::lossless_from_f64(0.1));
    }

    #[test]
    fn byte_codec_round_trips() {
        let mut buf = Vec::new();
        1.5f32.write_le(&mut buf);
        (-3.25f64).write_le(&mut buf);
        assert_eq!(buf.len(), f32::BYTES + f64::BYTES);
        assert_eq!(f32::read_le(&buf[..4]), 1.5);
        assert_eq!(f64::read_le(&buf[4..]), -3.25);
    }

    #[test]
    fn dist_sq_kernel_matches_both_precisions() {
        let a64 = [0.0f64, 0.0, 3.0];
        let b64 = [4.0f64, 0.0, 0.0];
        assert_eq!(f64::dist_sq(&a64, &b64), 25.0);
        let a32 = [0.0f32, 0.0, 3.0];
        let b32 = [4.0f32, 0.0, 0.0];
        assert_eq!(f32::dist_sq(&a32, &b32), 25.0);
    }

    #[test]
    fn radius_sq_converts_before_squaring() {
        // 0.1 is lossy in f32: the f32 radius is round(0.1)² computed in
        // f32, not round(0.01).
        let r32: f32 = radius_sq(0.1);
        assert_eq!(r32, 0.1f32 * 0.1f32);
        let r64: f64 = radius_sq(0.1);
        assert_eq!(r64, 0.1f64 * 0.1f64);
    }

    #[test]
    fn first_lossy_coord_reports_position() {
        let coords = [1.0, 2.0, 0.1, 4.0];
        assert_eq!(first_lossy_coord::<f32>(&coords, 2), Some((1, 0)));
        assert_eq!(first_lossy_coord::<f64>(&coords, 2), None);
        assert_eq!(first_lossy_coord::<f32>(&[1.0, 2.0], 2), None);
    }
}
