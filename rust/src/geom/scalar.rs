//! The coordinate scalar abstraction: a **sealed** trait over `f32`/`f64`
//! that the whole data path ([`super::PointStore`], the kd-tree family, the
//! DPC kernels) is generic over.
//!
//! Why f32 matters here: the paper's traversals are memory-bandwidth-bound,
//! and half-width coordinates halve the bytes every leaf scan and bounds
//! check moves (PECANN and the MPI matrix-DPC systems both run their hot
//! paths in single precision). Exactness is *per scalar type*: priorities
//! and ρ stay integer, distance comparisons happen in `S`, and the paper's
//! tie-break rules are precision-independent — so an f32 pipeline is the
//! exact DPC of the f32 point set. On datasets whose coordinates are exactly
//! representable in f32 (integer grids, sensor codes, quantized features,
//! see [`Scalar::lossless_from_f64`]), the f32 and f64 pipelines produce
//! byte-identical results; `rust/tests/conformance.rs` enforces that.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::error::DpcError;

/// Lane count of a blocked leaf: every kd-tree leaf (8–16 points, see
/// `kdtree::leaf`) occupies one dim-major block of this many lanes, so a
/// single [`Scalar::dist_sq_block`] call covers any leaf. 16 f32 lanes are
/// exactly one cache line per dimension row (two for f64), and two AVX
/// `f32x8` registers (four `f64x4`).
pub const BLOCK_LANES: usize = 16;

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Force [`Scalar::dist_sq_block`] down the portable scalar path,
/// process-wide, at runtime. The oracle differential suite flips this to
/// pin the SIMD and scalar kernels byte-identical within one process; the
/// `force-scalar-kernel` cargo feature is the compile-time equivalent CI's
/// feature matrix builds.
pub fn force_scalar_kernel(on: bool) {
    // relaxed: standalone toggle — both kernel paths are bit-identical, so
    // no reader depends on when the flip becomes visible.
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// Whether [`force_scalar_kernel`] currently pins the portable path.
pub fn scalar_kernel_forced() -> bool {
    // relaxed: see `force_scalar_kernel` — visibility timing is immaterial.
    FORCE_SCALAR.load(Ordering::Relaxed)
}

/// Serializes tests and benches that flip [`force_scalar_kernel`]: the
/// toggle is process-global and the test harness runs threads
/// concurrently. Concurrent *readers* need no guard — both kernel paths
/// are bit-identical, so a mid-test flip cannot change any result.
pub fn kernel_toggle_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Which `dist_sq_block` implementation the next call will take — for
/// bench/diagnostic labels, not dispatch.
pub fn block_kernel_name() -> &'static str {
    #[cfg(all(target_arch = "x86_64", not(feature = "force-scalar-kernel")))]
    if simd::avx_available() && !scalar_kernel_forced() {
        return "avx";
    }
    "scalar"
}

/// Portable reference implementation of [`Scalar::dist_sq_block`]. The
/// inner lane loop has a fixed trip count and no data-dependent control
/// flow, so LLVM autovectorizes it on targets without a hand-written
/// override; it is also the byte-exactness baseline the SIMD paths are
/// differential-tested against.
#[inline]
pub fn dist_sq_block_scalar<S: Scalar>(block: &[S], d: usize, q: &[S], out: &mut [S; BLOCK_LANES]) {
    debug_assert_eq!(block.len(), d * BLOCK_LANES);
    debug_assert_eq!(q.len(), d);
    *out = [S::ZERO; BLOCK_LANES];
    for k in 0..d {
        let row = &block[k * BLOCK_LANES..(k + 1) * BLOCK_LANES];
        let qk = q[k];
        for (acc, &x) in out.iter_mut().zip(row) {
            let t = x - qk;
            *acc += t * t;
        }
    }
}

/// Hand-written AVX lane kernels, dispatched at runtime (`cpuid` probed
/// once, cached). Per lane they run the exact operation sequence of
/// [`dist_sq_block_scalar`] — ascending-dimension subtract, multiply, add,
/// never FMA — so results are bit-identical to the portable path; IEEE-754
/// arithmetic is deterministic given the same operation order.
#[cfg(all(target_arch = "x86_64", not(feature = "force-scalar-kernel")))]
mod simd {
    use super::BLOCK_LANES;
    use std::arch::x86_64::*;
    use std::sync::atomic::{AtomicU8, Ordering};

    /// 0 = unprobed, 1 = AVX present, 2 = absent.
    static AVX: AtomicU8 = AtomicU8::new(0);

    #[inline]
    pub fn avx_available() -> bool {
        // relaxed: idempotent probe cache — racing probes all write the
        // same cpuid-derived answer.
        match AVX.load(Ordering::Relaxed) {
            1 => true,
            2 => false,
            _ => {
                let ok = std::arch::is_x86_feature_detected!("avx");
                // relaxed: same value from every racer; see above.
                AVX.store(if ok { 1 } else { 2 }, Ordering::Relaxed);
                ok
            }
        }
    }

    /// 16 f32 lanes as two 256-bit accumulators.
    ///
    /// # Safety
    /// Requires AVX (checked by the caller via [`avx_available`]) and
    /// `block.len() == d * BLOCK_LANES`, `q.len() == d`.
    #[target_feature(enable = "avx")]
    pub unsafe fn dist_sq_block_f32(block: &[f32], d: usize, q: &[f32], out: &mut [f32; BLOCK_LANES]) {
        debug_assert_eq!(block.len(), d * BLOCK_LANES);
        debug_assert_eq!(q.len(), d);
        // SAFETY: caller contract — AVX is present, `block` holds
        // d × BLOCK_LANES scalars and `q` holds d, so every unchecked
        // index and unaligned 8-lane load/store below stays in bounds.
        unsafe {
            let p = block.as_ptr();
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            for k in 0..d {
                let qk = _mm256_set1_ps(*q.get_unchecked(k));
                let row = p.add(k * BLOCK_LANES);
                let t0 = _mm256_sub_ps(_mm256_loadu_ps(row), qk);
                let t1 = _mm256_sub_ps(_mm256_loadu_ps(row.add(8)), qk);
                acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(t0, t0));
                acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(t1, t1));
            }
            _mm256_storeu_ps(out.as_mut_ptr(), acc0);
            _mm256_storeu_ps(out.as_mut_ptr().add(8), acc1);
        }
    }

    /// 16 f64 lanes as four 256-bit accumulators.
    ///
    /// # Safety
    /// Same contract as [`dist_sq_block_f32`].
    #[target_feature(enable = "avx")]
    pub unsafe fn dist_sq_block_f64(block: &[f64], d: usize, q: &[f64], out: &mut [f64; BLOCK_LANES]) {
        debug_assert_eq!(block.len(), d * BLOCK_LANES);
        debug_assert_eq!(q.len(), d);
        // SAFETY: caller contract — AVX is present and the slice lengths
        // match the block layout, so every unchecked index and unaligned
        // 4-lane load/store below stays in bounds.
        unsafe {
            let p = block.as_ptr();
            let mut acc = [_mm256_setzero_pd(); 4];
            for k in 0..d {
                let qk = _mm256_set1_pd(*q.get_unchecked(k));
                let row = p.add(k * BLOCK_LANES);
                for (v, a) in acc.iter_mut().enumerate() {
                    let t = _mm256_sub_pd(_mm256_loadu_pd(row.add(4 * v)), qk);
                    *a = _mm256_add_pd(*a, _mm256_mul_pd(t, t));
                }
            }
            for (v, a) in acc.iter().enumerate() {
                _mm256_storeu_pd(out.as_mut_ptr().add(4 * v), *a);
            }
        }
    }
}

mod sealed {
    /// Seals [`super::Scalar`]: the unsafe traversal code (raw-pointer arena
    /// builders, `get_unchecked` leaf scans) is audited for exactly these
    /// two layouts.
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
}

/// Runtime tag for a coordinate precision — what flows through
/// [`crate::dpc::DpcParams`], `JobSpec`, the CLI `--dtype` flag, and the
/// `datasets::io` v2 header byte.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    #[default]
    F64,
}

impl Dtype {
    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F64 => "f64",
        }
    }

    /// Bytes per coordinate — also the self-describing tag byte of the
    /// `datasets::io` v2 binary header.
    pub fn size_bytes(&self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 => 8,
        }
    }

    /// Inverse of [`Dtype::size_bytes`], for header decoding.
    pub fn from_tag(tag: u8) -> Option<Dtype> {
        match tag {
            4 => Some(Dtype::F32),
            8 => Some(Dtype::F64),
            _ => None,
        }
    }
}

impl fmt::Display for Dtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Dtype {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "f32" => Ok(Dtype::F32),
            "f64" => Ok(Dtype::F64),
            other => Err(format!("unknown dtype {other:?} (expected f32 or f64)")),
        }
    }
}

/// A coordinate scalar: `f32` or `f64` (sealed).
///
/// The trait carries exactly what the data path needs — a squared-distance
/// kernel, comparisons/extrema, a little-endian byte codec for the on-disk
/// format, and the f64 bridge (`from_f64`/`to_f64`/`lossless_from_f64`)
/// used at precision-conversion boundaries.
pub trait Scalar:
    sealed::Sealed
    + Copy
    + PartialOrd
    + Default
    + fmt::Debug
    + fmt::Display
    + Send
    + Sync
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::AddAssign
    + 'static
{
    const DTYPE: Dtype;
    const ZERO: Self;
    const INFINITY: Self;
    const NEG_INFINITY: Self;
    /// Size of the little-endian encoding (4 or 8).
    const BYTES: usize;

    /// Narrowing (for `f32`) conversion from `f64`, rounding to nearest.
    fn from_f64(v: f64) -> Self;

    /// Widening (exact for both types) conversion to `f64`.
    fn to_f64(self) -> f64;

    /// Does `v` survive a `f64 → Self → f64` round trip bit-exactly?
    /// (`true` for every value when `Self = f64`.) This is the predicate
    /// behind "f32 preserves exactness on integer-coordinate data".
    fn lossless_from_f64(v: f64) -> bool;

    /// Neither NaN nor ±∞.
    fn finite(self) -> bool;

    /// `min`/`max` with the IEEE "other operand on NaN" semantics of the
    /// inherent float methods (inputs are validated finite upstream).
    fn smin(self, other: Self) -> Self;
    fn smax(self, other: Self) -> Self;

    /// Squared Euclidean distance between two coordinate slices of equal
    /// length, accumulated in `Self`.
    #[inline]
    fn dist_sq(a: &[Self], b: &[Self]) -> Self {
        debug_assert_eq!(a.len(), b.len());
        let mut s = Self::ZERO;
        for k in 0..a.len() {
            let t = a[k] - b[k];
            s += t * t;
        }
        s
    }

    /// Squared distances from the query `q` (length `d`) to all
    /// [`BLOCK_LANES`] lanes of a dim-major coordinate block
    /// (`block[k * BLOCK_LANES + l]` is coordinate `k` of lane `l`;
    /// `block.len() == d * BLOCK_LANES`), written to `out`.
    ///
    /// Exactness contract: every implementation — this portable default
    /// and the SIMD overrides — accumulates each lane in ascending
    /// dimension order with a separate multiply and add (no FMA), the
    /// same operation sequence as [`Scalar::dist_sq`]. IEEE-754 ops are
    /// deterministic, so all paths return bit-identical lanes; the oracle
    /// suite's forced-scalar differential leg pins this rather than
    /// assuming it. Padding lanes filled with [`Scalar::INFINITY`] come
    /// out as `INFINITY` (the query is finite, so no `∞ − ∞` NaN arises).
    #[inline]
    fn dist_sq_block(block: &[Self], d: usize, q: &[Self], out: &mut [Self; BLOCK_LANES]) {
        dist_sq_block_scalar(block, d, q, out)
    }

    /// Append the little-endian encoding to `out`.
    fn write_le(self, out: &mut Vec<u8>);

    /// Decode from the first [`Scalar::BYTES`] bytes of `bytes`.
    fn read_le(bytes: &[u8]) -> Self;
}

impl Scalar for f32 {
    const DTYPE: Dtype = Dtype::F32;
    const ZERO: f32 = 0.0;
    const INFINITY: f32 = f32::INFINITY;
    const NEG_INFINITY: f32 = f32::NEG_INFINITY;
    const BYTES: usize = 4;

    #[inline]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }

    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline]
    fn lossless_from_f64(v: f64) -> bool {
        // NaN is not lossless (payload aside, NaN coordinates are rejected
        // upstream anyway); ±∞ round-trips but is equally rejected later.
        (v as f32) as f64 == v
    }

    #[inline]
    fn finite(self) -> bool {
        self.is_finite()
    }

    #[inline]
    fn smin(self, other: f32) -> f32 {
        self.min(other)
    }

    #[inline]
    fn smax(self, other: f32) -> f32 {
        self.max(other)
    }

    #[inline]
    fn dist_sq_block(block: &[f32], d: usize, q: &[f32], out: &mut [f32; BLOCK_LANES]) {
        #[cfg(all(target_arch = "x86_64", not(feature = "force-scalar-kernel")))]
        if simd::avx_available() && !scalar_kernel_forced() {
            // SAFETY: AVX presence checked on this line; slice lengths are
            // debug-asserted inside and guaranteed by the leaf arena.
            unsafe { simd::dist_sq_block_f32(block, d, q, out) };
            return;
        }
        dist_sq_block_scalar(block, d, q, out)
    }

    #[inline]
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    #[inline]
    fn read_le(bytes: &[u8]) -> f32 {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl Scalar for f64 {
    const DTYPE: Dtype = Dtype::F64;
    const ZERO: f64 = 0.0;
    const INFINITY: f64 = f64::INFINITY;
    const NEG_INFINITY: f64 = f64::NEG_INFINITY;
    const BYTES: usize = 8;

    #[inline]
    fn from_f64(v: f64) -> f64 {
        v
    }

    #[inline]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline]
    fn lossless_from_f64(_v: f64) -> bool {
        true
    }

    #[inline]
    fn finite(self) -> bool {
        self.is_finite()
    }

    #[inline]
    fn smin(self, other: f64) -> f64 {
        self.min(other)
    }

    #[inline]
    fn smax(self, other: f64) -> f64 {
        self.max(other)
    }

    #[inline]
    fn dist_sq_block(block: &[f64], d: usize, q: &[f64], out: &mut [f64; BLOCK_LANES]) {
        #[cfg(all(target_arch = "x86_64", not(feature = "force-scalar-kernel")))]
        if simd::avx_available() && !scalar_kernel_forced() {
            // SAFETY: AVX presence checked on this line; slice lengths are
            // debug-asserted inside and guaranteed by the leaf arena.
            unsafe { simd::dist_sq_block_f64(block, d, q, out) };
            return;
        }
        dist_sq_block_scalar(block, d, q, out)
    }

    #[inline]
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    #[inline]
    fn read_le(bytes: &[u8]) -> f64 {
        f64::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3], bytes[4], bytes[5], bytes[6], bytes[7]])
    }
}

/// The squared query radius at precision `S`: convert the user-facing
/// `d_cut` first, square second, so "the radius of an f32 pipeline" is a
/// representable f32 — every layer (density, sessions, streams, engines)
/// must use this one definition or f32/f64 conformance on lossless data
/// breaks at ball boundaries.
#[inline]
pub fn radius_sq<S: Scalar>(d_cut: f64) -> S {
    let r = S::from_f64(d_cut);
    r * r
}

/// First coordinate of `coords` (flat, row-major over dimension `d`) that is
/// not losslessly representable at precision `S`, as `(point, dim)`.
pub fn first_lossy_coord<S: Scalar>(coords: &[f64], d: usize) -> Option<(usize, usize)> {
    coords
        .iter()
        .position(|&c| !S::lossless_from_f64(c))
        .map(|idx| (idx / d, idx % d))
}

/// Typed error for a requested lossless conversion that would round.
pub fn lossy_cast_error<S: Scalar>(point: usize, dim: usize, value: f64) -> DpcError {
    DpcError::LossyCast { point, dim, value, dtype: S::DTYPE.name() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_tags_round_trip() {
        for dt in [Dtype::F32, Dtype::F64] {
            assert_eq!(Dtype::from_tag(dt.size_bytes() as u8), Some(dt));
            assert_eq!(dt.name().parse::<Dtype>().unwrap(), dt);
        }
        assert_eq!(Dtype::from_tag(0), None);
        assert_eq!(Dtype::from_tag(16), None);
        assert!("f16".parse::<Dtype>().is_err());
        assert_eq!(Dtype::default(), Dtype::F64);
    }

    #[test]
    fn lossless_predicate() {
        // Small integers and power-of-two fractions survive f32.
        for v in [0.0, 1.0, -7.0, 1024.0, 0.5, 0.25, 16777216.0] {
            assert!(f32::lossless_from_f64(v), "{v}");
        }
        // 2^24 + 1 and typical decimals do not.
        for v in [16777217.0, 0.1, 1e300] {
            assert!(!f32::lossless_from_f64(v), "{v}");
        }
        assert!(f64::lossless_from_f64(0.1));
    }

    #[test]
    fn byte_codec_round_trips() {
        let mut buf = Vec::new();
        1.5f32.write_le(&mut buf);
        (-3.25f64).write_le(&mut buf);
        assert_eq!(buf.len(), f32::BYTES + f64::BYTES);
        assert_eq!(f32::read_le(&buf[..4]), 1.5);
        assert_eq!(f64::read_le(&buf[4..]), -3.25);
    }

    #[test]
    fn dist_sq_kernel_matches_both_precisions() {
        let a64 = [0.0f64, 0.0, 3.0];
        let b64 = [4.0f64, 0.0, 0.0];
        assert_eq!(f64::dist_sq(&a64, &b64), 25.0);
        let a32 = [0.0f32, 0.0, 3.0];
        let b32 = [4.0f32, 0.0, 0.0];
        assert_eq!(f32::dist_sq(&a32, &b32), 25.0);
    }

    #[test]
    fn radius_sq_converts_before_squaring() {
        // 0.1 is lossy in f32: the f32 radius is round(0.1)² computed in
        // f32, not round(0.01).
        let r32: f32 = radius_sq(0.1);
        assert_eq!(r32, 0.1f32 * 0.1f32);
        let r64: f64 = radius_sq(0.1);
        assert_eq!(r64, 0.1f64 * 0.1f64);
    }

    fn fill_block<S: Scalar>(d: usize, lanes: usize) -> (Vec<S>, Vec<S>) {
        // Deterministic awkward values (not representable sums) so any
        // reassociation or FMA contraction in a kernel would change bits.
        let mut block = vec![S::INFINITY; d * BLOCK_LANES];
        for l in 0..lanes {
            for k in 0..d {
                let v = 0.1 + (l as f64) * 0.3 + (k as f64) * 0.7 - ((l * k) as f64) * 0.01;
                block[k * BLOCK_LANES + l] = S::from_f64(v);
            }
        }
        let q: Vec<S> = (0..d).map(|k| S::from_f64(0.2 + 0.05 * k as f64)).collect();
        (block, q)
    }

    fn block_kernel_case<S: Scalar>(d: usize, lanes: usize) {
        let (block, q) = fill_block::<S>(d, lanes);
        let mut out = [S::ZERO; BLOCK_LANES];
        S::dist_sq_block(&block, d, &q, &mut out);
        // Reference: the per-point kernel over each lane's gathered coords.
        for l in 0..BLOCK_LANES {
            let lane: Vec<S> = (0..d).map(|k| block[k * BLOCK_LANES + l]).collect();
            let want = S::dist_sq(&lane, &q);
            if l < lanes {
                assert!(out[l] == want, "lane {l}: {:?} != {want:?}", out[l]);
            } else {
                assert!(out[l] == S::INFINITY, "padding lane {l} must be +inf");
            }
        }
        // Forced-scalar path agrees bit-for-bit with whatever ran above.
        let mut scalar_out = [S::ZERO; BLOCK_LANES];
        dist_sq_block_scalar(&block, d, &q, &mut scalar_out);
        assert!(out == scalar_out, "SIMD and scalar block kernels disagree");
    }

    #[test]
    fn block_kernel_matches_per_point_kernel_and_pads_with_inf() {
        for d in [1, 2, 3, 5, 8] {
            for lanes in [1, 7, 8, 13, BLOCK_LANES] {
                block_kernel_case::<f32>(d, lanes);
                block_kernel_case::<f64>(d, lanes);
            }
        }
    }

    #[test]
    fn force_scalar_toggle_round_trips() {
        let _serial = kernel_toggle_guard();
        assert!(!scalar_kernel_forced());
        force_scalar_kernel(true);
        assert!(scalar_kernel_forced());
        assert_eq!(block_kernel_name(), "scalar");
        force_scalar_kernel(false);
        assert!(!scalar_kernel_forced());
    }

    #[test]
    fn first_lossy_coord_reports_position() {
        let coords = [1.0, 2.0, 0.1, 4.0];
        assert_eq!(first_lossy_coord::<f32>(&coords, 2), Some((1, 0)));
        assert_eq!(first_lossy_coord::<f64>(&coords, 2), None);
        assert_eq!(first_lossy_coord::<f32>(&[1.0, 2.0], 2), None);
    }
}
