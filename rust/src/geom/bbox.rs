//! Axis-aligned bounding boxes ("cells" in the paper's kd-tree terminology)
//! with the two geometric predicates the DPC traversals need:
//!
//! - `dist_sq_to(q)`: minimum squared distance from the cell to a query
//!   point — the standard NN / range-search pruning test;
//! - `inside_ball(c, r²)`: whether the **farthest corner** of the cell is
//!   within the ball — the §6.1 density-computation optimization (a cell
//!   fully inside the query ball contributes its point count wholesale).
//!
//! Generic over the coordinate [`Scalar`]; all predicates compute in `S`.

use super::scalar::Scalar;

#[derive(Clone, Debug, PartialEq)]
pub struct Bbox<S: Scalar = f64> {
    min: Vec<S>,
    max: Vec<S>,
}

impl<S: Scalar> Bbox<S> {
    /// An empty (inverted) box; `expand` fixes it up.
    pub fn empty(d: usize) -> Self {
        Bbox { min: vec![S::INFINITY; d], max: vec![S::NEG_INFINITY; d] }
    }

    pub fn new(min: Vec<S>, max: Vec<S>) -> Self {
        assert_eq!(min.len(), max.len());
        Bbox { min, max }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.min.len()
    }

    pub fn min(&self) -> &[S] {
        &self.min
    }

    pub fn max(&self) -> &[S] {
        &self.max
    }

    #[inline]
    pub fn expand(&mut self, p: &[S]) {
        for k in 0..self.min.len() {
            if p[k] < self.min[k] {
                self.min[k] = p[k];
            }
            if p[k] > self.max[k] {
                self.max[k] = p[k];
            }
        }
    }

    pub fn merge(&mut self, other: &Bbox<S>) {
        for k in 0..self.min.len() {
            self.min[k] = self.min[k].smin(other.min[k]);
            self.max[k] = self.max[k].smax(other.max[k]);
        }
    }

    /// Index of the widest side (the paper splits cells perpendicular to the
    /// longest side).
    pub fn widest_dim(&self) -> usize {
        let mut best = 0;
        let mut best_w = S::NEG_INFINITY;
        for k in 0..self.min.len() {
            let w = self.max[k] - self.min[k];
            if w > best_w {
                best_w = w;
                best = k;
            }
        }
        best
    }

    /// Minimum squared distance from `q` to any point of the box (0 if `q`
    /// is inside).
    #[inline]
    pub fn dist_sq_to(&self, q: &[S]) -> S {
        let mut s = S::ZERO;
        for k in 0..self.min.len() {
            let v = q[k];
            let t = if v < self.min[k] {
                self.min[k] - v
            } else if v > self.max[k] {
                v - self.max[k]
            } else {
                S::ZERO
            };
            s += t * t;
        }
        s
    }

    /// Squared distance from `q` to the **farthest corner** of the box.
    ///
    /// Per dimension the farthest side is `max(q − min, max − q)` — with
    /// `min ≤ max` this equals `max(|q − min|, |q − max|)` for every `q`
    /// position (below, inside, above), so no `abs` is needed.
    #[inline]
    pub fn far_corner_dist_sq(&self, q: &[S]) -> S {
        let mut s = S::ZERO;
        for k in 0..self.min.len() {
            let t = (q[k] - self.min[k]).smax(self.max[k] - q[k]);
            s += t * t;
        }
        s
    }

    /// §6.1 containment test: is the whole cell inside the ball
    /// `{x : |x-c|² ≤ r_sq}`?
    #[inline]
    pub fn inside_ball(&self, c: &[S], r_sq: S) -> bool {
        self.far_corner_dist_sq(c) <= r_sq
    }

    /// Does the cell intersect the ball `{x : |x-c|² ≤ r_sq}`?
    #[inline]
    pub fn intersects_ball(&self, c: &[S], r_sq: S) -> bool {
        self.dist_sq_to(c) <= r_sq
    }

    pub fn contains(&self, p: &[S]) -> bool {
        (0..self.min.len()).all(|k| self.min[k] <= p[k] && p[k] <= self.max[k])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit_box() -> Bbox {
        Bbox::new(vec![0.0, 0.0], vec![1.0, 1.0])
    }

    #[test]
    fn expand_from_empty() {
        let mut bb = Bbox::<f64>::empty(2);
        bb.expand(&[1.0, 2.0]);
        bb.expand(&[-1.0, 0.5]);
        assert_eq!(bb.min(), &[-1.0, 0.5]);
        assert_eq!(bb.max(), &[1.0, 2.0]);
    }

    #[test]
    fn dist_inside_is_zero() {
        assert_eq!(unit_box().dist_sq_to(&[0.5, 0.5]), 0.0);
    }

    #[test]
    fn dist_outside() {
        assert_eq!(unit_box().dist_sq_to(&[2.0, 0.5]), 1.0);
        assert_eq!(unit_box().dist_sq_to(&[2.0, 2.0]), 2.0);
    }

    #[test]
    fn far_corner() {
        // From the origin corner the far corner of the unit box is (1,1).
        assert_eq!(unit_box().far_corner_dist_sq(&[0.0, 0.0]), 2.0);
        // From the center all corners are at distance sqrt(0.5).
        assert!((unit_box().far_corner_dist_sq(&[0.5, 0.5]) - 0.5).abs() < 1e-12);
        // Query outside the box on both sides of a dimension.
        assert_eq!(unit_box().far_corner_dist_sq(&[2.0, 0.5]), 4.0 + 0.25);
        assert_eq!(unit_box().far_corner_dist_sq(&[-1.0, 0.5]), 4.0 + 0.25);
    }

    #[test]
    fn inside_ball_requires_far_corner() {
        let bb = unit_box();
        assert!(bb.inside_ball(&[0.5, 0.5], 0.5 + 1e-9));
        assert!(!bb.inside_ball(&[0.5, 0.5], 0.49));
    }

    #[test]
    fn intersects_ball_edge_cases() {
        let bb = unit_box();
        assert!(bb.intersects_ball(&[2.0, 0.5], 1.0)); // touches at boundary
        assert!(!bb.intersects_ball(&[2.0, 0.5], 0.99));
    }

    #[test]
    fn widest_dim_picks_longest() {
        let bb = Bbox::new(vec![0.0, 0.0, 0.0], vec![1.0, 5.0, 2.0]);
        assert_eq!(bb.widest_dim(), 1);
    }

    #[test]
    fn merge_unions() {
        let mut a = Bbox::new(vec![0.0], vec![1.0]);
        a.merge(&Bbox::new(vec![-2.0], vec![0.5]));
        assert_eq!(a.min(), &[-2.0]);
        assert_eq!(a.max(), &[1.0]);
    }

    #[test]
    fn f32_boxes_work() {
        let mut bb = Bbox::<f32>::empty(2);
        bb.expand(&[1.0, 2.0]);
        bb.expand(&[3.0, -1.0]);
        assert_eq!(bb.dist_sq_to(&[0.0, 0.0]), 1.0);
        assert_eq!(bb.far_corner_dist_sq(&[0.0, 0.0]), 9.0 + 4.0);
        assert!(bb.contains(&[2.0, 0.0]));
    }
}
