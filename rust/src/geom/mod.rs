//! Geometric primitives: flat point sets, axis-aligned bounding boxes, and
//! squared-Euclidean distance kernels.
//!
//! Points are stored row-major (`coords[i*d + k]`), which keeps each point's
//! coordinates on one cache line during tree traversals — the dominant access
//! pattern in this crate. Distances are computed and compared **squared**
//! everywhere (monotone for Euclidean metrics), taking a single `sqrt` only
//! at user-facing boundaries.

pub mod bbox;

pub use bbox::Bbox;

/// A set of `n` points in `d`-dimensional space, row-major.
#[derive(Clone, Debug)]
pub struct PointSet {
    coords: Vec<f64>,
    n: usize,
    d: usize,
}

impl PointSet {
    pub fn new(coords: Vec<f64>, d: usize) -> Self {
        assert!(d > 0, "dimension must be positive");
        assert_eq!(coords.len() % d, 0, "coords length {} not divisible by d={}", coords.len(), d);
        let n = coords.len() / d;
        PointSet { coords, n, d }
    }

    pub fn empty(d: usize) -> Self {
        PointSet { coords: Vec::new(), n: 0, d }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let d = rows[0].len();
        let mut coords = Vec::with_capacity(rows.len() * d);
        for r in rows {
            assert_eq!(r.len(), d);
            coords.extend_from_slice(r);
        }
        PointSet::new(coords, d)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn point(&self, i: usize) -> &[f64] {
        &self.coords[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn coord(&self, i: usize, k: usize) -> f64 {
        self.coords[i * self.d + k]
    }

    pub fn coords(&self) -> &[f64] {
        &self.coords
    }

    pub fn push(&mut self, p: &[f64]) {
        assert_eq!(p.len(), self.d);
        self.coords.extend_from_slice(p);
        self.n += 1;
    }

    /// Squared Euclidean distance between stored points `i` and `j`.
    #[inline]
    pub fn dist_sq(&self, i: usize, j: usize) -> f64 {
        dist_sq(self.point(i), self.point(j))
    }

    /// Squared Euclidean distance from stored point `i` to an arbitrary `q`.
    #[inline]
    pub fn dist_sq_to(&self, i: usize, q: &[f64]) -> f64 {
        dist_sq(self.point(i), q)
    }

    /// Bounding box over a subset of point ids.
    pub fn bbox_of(&self, ids: &[u32]) -> Bbox {
        let mut bb = Bbox::empty(self.d);
        for &i in ids {
            bb.expand(self.point(i as usize));
        }
        bb
    }

    /// Bounding box over all points.
    pub fn bbox(&self) -> Bbox {
        let mut bb = Bbox::empty(self.d);
        for i in 0..self.n {
            bb.expand(self.point(i));
        }
        bb
    }
}

/// Squared Euclidean distance between two coordinate slices.
#[inline]
pub fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for k in 0..a.len() {
        let t = a[k] - b[k];
        s += t * t;
    }
    s
}

/// Euclidean distance (single sqrt; use [`dist_sq`] in hot paths).
#[inline]
pub fn dist(a: &[f64], b: &[f64]) -> f64 {
    dist_sq(a, b).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pointset_roundtrip() {
        let ps = PointSet::new(vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0], 2);
        assert_eq!(ps.len(), 3);
        assert_eq!(ps.dim(), 2);
        assert_eq!(ps.point(1), &[3.0, 4.0]);
        assert_eq!(ps.dist_sq(0, 1), 25.0);
        assert_eq!(ps.dist_sq_to(0, &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn from_rows_matches() {
        let ps = PointSet::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(ps.dim(), 3);
        assert_eq!(ps.coord(1, 2), 6.0);
    }

    #[test]
    fn push_extends() {
        let mut ps = PointSet::empty(2);
        ps.push(&[1.0, 2.0]);
        ps.push(&[3.0, 4.0]);
        assert_eq!(ps.len(), 2);
        assert_eq!(ps.point(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn bad_coords_len_panics() {
        PointSet::new(vec![1.0, 2.0, 3.0], 2);
    }

    #[test]
    fn dist_matches_dist_sq() {
        assert!((dist(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
