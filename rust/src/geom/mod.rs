//! Geometric primitives: precision-generic, refcount-shared point stores,
//! zero-copy views, axis-aligned bounding boxes, and squared-Euclidean
//! distance kernels.
//!
//! The data layer is generic over a [`Scalar`] (`f32` or `f64`, sealed):
//!
//! - [`PointStore<S>`] owns its coordinates in one `Arc<[S]>` row-major
//!   buffer (`coords[i*d + k]`), so cloning a store — what the staged
//!   session, the Bentley–Saxe stream forest, and every kd-tree do to pin
//!   their input — is a refcount bump, never a coordinate copy.
//! - [`PointsView<'_, S>`] is the `Copy` borrowed form handed to the tree
//!   builders and distance kernels.
//! - [`DynPoints`] is the runtime-tagged union used at dtype boundaries
//!   (binary files, CLI flags, coordinator payloads).
//!
//! `type PointSet = PointStore<f64>` keeps the pre-generic name working:
//! existing call sites migrate mechanically.
//!
//! Distances are computed and compared **squared**, *in `S`*, everywhere
//! (monotone for Euclidean metrics); a single `sqrt` — always in f64 — runs
//! at user-facing boundaries. Exactness is therefore per scalar type, and
//! byte-identical across types whenever the coordinates and radius are
//! losslessly representable in both (see [`Scalar::lossless_from_f64`]).

pub mod bbox;
pub mod scalar;

pub use bbox::Bbox;
pub use scalar::{
    block_kernel_name, force_scalar_kernel, kernel_toggle_guard, radius_sq, scalar_kernel_forced, Dtype, Scalar,
    BLOCK_LANES,
};

use std::sync::Arc;

use crate::error::DpcError;

/// A set of `n` points in `d`-dimensional space, row-major, with the
/// coordinate buffer behind an `Arc`: `clone` is O(1) and shares storage.
#[derive(Clone, Debug)]
pub struct PointStore<S: Scalar = f64> {
    coords: Arc<[S]>,
    n: usize,
    d: usize,
}

/// Bit-exact equality: same shape and the same coordinate bits. This is
/// the identity the wire/journal codecs preserve (constructors reject
/// NaN, so bitwise and `==` semantics never diverge in practice).
impl<S: Scalar> PartialEq for PointStore<S> {
    fn eq(&self, other: &Self) -> bool {
        self.n == other.n
            && self.d == other.d
            && self
                .coords
                .iter()
                .zip(other.coords.iter())
                .all(|(a, b)| a.to_f64().to_bits() == b.to_f64().to_bits())
    }
}

impl<S: Scalar> Eq for PointStore<S> {}

/// The pre-generic name: a double-precision point store.
pub type PointSet = PointStore<f64>;

impl<S: Scalar> PointStore<S> {
    /// Fallible constructor: rejects `d == 0`, coordinate buffers whose
    /// length is not a multiple of `d`, and NaN/±∞ coordinates
    /// ([`DpcError::NonFiniteCoordinate`] — non-finite values would
    /// otherwise survive until a sort comparator deep in the density
    /// kernels and panic there). This is the entry point for user-supplied
    /// data; [`PointStore::new`] is the panicking convenience for
    /// generators and tests whose inputs are correct by construction.
    ///
    /// Note the `Vec → Arc<[S]>` conversion copies the buffer once (the
    /// `Arc` header precludes reusing the `Vec` allocation) — a one-time
    /// construction cost; every share after that (sessions, trees, stream
    /// levels, job payloads) is a refcount bump. Callers that already hold
    /// a shared buffer should use [`PointStore::try_from_shared`]; code
    /// that *produces* coordinates (generators, file readers, the stream's
    /// growth path) should fill the shared allocation directly via
    /// [`PointStore::from_flat_fn`] / [`PointStore::try_from_flat_fn`] and
    /// skip the copy entirely.
    pub fn try_new(coords: Vec<S>, d: usize) -> Result<Self, DpcError> {
        let ps = Self::try_from_shared(Arc::from(coords), d)?;
        ps.validate_finite()?;
        Ok(ps)
    }

    /// Build a store by writing coordinates straight into one shared
    /// allocation — no intermediate `Vec` and no `Vec → Arc` copy. `f` is
    /// called once per flat index `i*d + k`, **in order**, so stateful
    /// generators (RNGs, random walks) observe the same draw sequence as a
    /// push loop.
    pub fn from_flat_fn(n: usize, d: usize, mut f: impl FnMut(usize) -> S) -> Self {
        assert!(d > 0, "dimension must be positive");
        let mut buf = Arc::new_uninit_slice(n * d);
        // lint: allow(panic-surface) — the Arc was allocated on the line
        // above and has not been cloned, so get_mut always succeeds.
        let slots = Arc::get_mut(&mut buf).expect("freshly allocated Arc is unique");
        for (i, slot) in slots.iter_mut().enumerate() {
            slot.write(f(i));
        }
        // SAFETY: the loop above wrote every slot exactly once.
        let coords = unsafe { buf.assume_init() };
        PointStore { coords, n, d }
    }

    /// Fallible [`PointStore::from_flat_fn`]: the first `Err` aborts the
    /// fill and surfaces unchanged (the partially-written allocation is
    /// dropped — scalars are `Copy`, so nothing needs finalizing). This is
    /// the binary reader's path: decode straight into the shared buffer.
    pub fn try_from_flat_fn(
        n: usize,
        d: usize,
        mut f: impl FnMut(usize) -> Result<S, DpcError>,
    ) -> Result<Self, DpcError> {
        if d == 0 {
            return Err(DpcError::InvalidParam { name: "dim", value: 0.0, requirement: "must be positive" });
        }
        let mut buf = Arc::new_uninit_slice(n * d);
        // lint: allow(panic-surface) — the Arc was allocated on the line
        // above and has not been cloned, so get_mut always succeeds.
        let slots = Arc::get_mut(&mut buf).expect("freshly allocated Arc is unique");
        for (i, slot) in slots.iter_mut().enumerate() {
            slot.write(f(i)?);
        }
        // SAFETY: the loop above wrote every slot exactly once (an early
        // `Err` returns before this line).
        let coords = unsafe { buf.assume_init() };
        Ok(PointStore { coords, n, d })
    }

    /// Zero-copy constructor over an already-shared buffer (the `Arc` is
    /// kept, not copied): same *shape* checks as [`PointStore::try_new`],
    /// but no coordinate scan — re-wrapping a buffer that some validated
    /// store already owns must stay O(1). Callers wrapping data from an
    /// unvalidated source should follow up with
    /// [`PointStore::validate_finite`].
    pub fn try_from_shared(coords: Arc<[S]>, d: usize) -> Result<Self, DpcError> {
        if d == 0 {
            return Err(DpcError::InvalidParam { name: "dim", value: 0.0, requirement: "must be positive" });
        }
        if coords.len() % d != 0 {
            return Err(DpcError::RaggedCoords { len: coords.len(), dim: d });
        }
        let n = coords.len() / d;
        Ok(PointStore { coords, n, d })
    }

    /// Panicking convenience over [`Self::try_new`] for callers with
    /// statically well-formed input (tests, generators).
    pub fn new(coords: Vec<S>, d: usize) -> Self {
        // lint: allow(panic-surface) — documented panicking constructor;
        // fallible callers use try_new.
        Self::try_new(coords, d).expect("well-formed coordinate buffer")
    }

    pub fn empty(d: usize) -> Self {
        PointStore { coords: Arc::from(Vec::new()), n: 0, d }
    }

    /// Fallible row-wise constructor: rejects empty input and ragged rows.
    pub fn try_from_rows(rows: &[Vec<S>]) -> Result<Self, DpcError> {
        if rows.is_empty() {
            return Err(DpcError::EmptyInput);
        }
        let d = rows[0].len();
        let mut coords = Vec::with_capacity(rows.len() * d);
        for r in rows {
            if r.len() != d {
                return Err(DpcError::DimensionMismatch { expected: d, got: r.len() });
            }
            coords.extend_from_slice(r);
        }
        Self::try_new(coords, d)
    }

    /// Panicking convenience over [`Self::try_from_rows`].
    pub fn from_rows(rows: &[Vec<S>]) -> Self {
        // lint: allow(panic-surface) — documented panicking constructor;
        // fallible callers use try_from_rows.
        Self::try_from_rows(rows).expect("non-empty, non-ragged rows")
    }

    /// The runtime precision tag of this store.
    pub fn dtype(&self) -> Dtype {
        S::DTYPE
    }

    /// The borrowed, `Copy` form of this store — what tree builders and
    /// distance kernels take.
    #[inline]
    pub fn view(&self) -> PointsView<'_, S> {
        PointsView { coords: &self.coords, n: self.n, d: self.d }
    }

    /// Do two stores share one coordinate allocation? (The observable
    /// behind "sessions/streams/trees pin by refcount, not by copy".)
    pub fn shares_storage(&self, other: &PointStore<S>) -> bool {
        Arc::ptr_eq(&self.coords, &other.coords)
    }

    /// Rounding precision conversion from an f64 store (a genuine buffer
    /// copy — precision boundaries are the one place the data layer
    /// copies). Collects straight into the `Arc`: slice iterators are
    /// `TrustedLen`, so the conversion is one allocation, not Vec-then-Arc.
    pub fn cast_from_f64(src: &PointStore<f64>) -> PointStore<S> {
        let coords: Arc<[S]> = src.coords.iter().map(|&c| S::from_f64(c)).collect();
        PointStore { coords, n: src.n, d: src.d }
    }

    /// Lossless-or-error precision conversion from an f64 store: the first
    /// coordinate that would round surfaces as [`DpcError::LossyCast`].
    pub fn try_lossless_from_f64(src: &PointStore<f64>) -> Result<PointStore<S>, DpcError> {
        if let Some((point, dim)) = scalar::first_lossy_coord::<S>(&src.coords, src.d) {
            return Err(scalar::lossy_cast_error::<S>(point, dim, src.coord(point, dim)));
        }
        Ok(Self::cast_from_f64(src))
    }

    /// Widening conversion (exact, but a buffer copy — use `clone()` when
    /// `S` is already f64, or [`DynPoints::into_f64`] which shares in that
    /// case).
    pub fn to_f64(&self) -> PointStore<f64> {
        let coords: Arc<[f64]> = self.coords.iter().map(|&c| c.to_f64()).collect();
        PointStore { coords, n: self.n, d: self.d }
    }

    /// Scan for NaN/∞ coordinates, reporting the first offender's (point,
    /// dimension). Clustering math (kd-tree bounds, squared distances)
    /// silently misbehaves on non-finite input, so public entry points run
    /// this once up front.
    pub fn validate_finite(&self) -> Result<(), DpcError> {
        for (idx, &c) in self.coords.iter().enumerate() {
            if !c.finite() {
                return Err(DpcError::NonFiniteCoordinate { point: idx / self.d, dim: idx % self.d });
            }
        }
        Ok(())
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn point(&self, i: usize) -> &[S] {
        &self.coords[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn coord(&self, i: usize, k: usize) -> S {
        self.coords[i * self.d + k]
    }

    pub fn coords(&self) -> &[S] {
        &self.coords
    }

    /// The shared coordinate buffer itself (refcount clone, never a copy).
    pub fn shared_coords(&self) -> Arc<[S]> {
        Arc::clone(&self.coords)
    }

    /// Squared Euclidean distance between stored points `i` and `j`.
    #[inline]
    pub fn dist_sq(&self, i: usize, j: usize) -> S {
        S::dist_sq(self.point(i), self.point(j))
    }

    /// Squared Euclidean distance from stored point `i` to an arbitrary `q`.
    #[inline]
    pub fn dist_sq_to(&self, i: usize, q: &[S]) -> S {
        S::dist_sq(self.point(i), q)
    }

    /// Bounding box over a subset of point ids.
    pub fn bbox_of(&self, ids: &[u32]) -> Bbox<S> {
        self.view().bbox_of(ids)
    }

    /// Bounding box over all points.
    pub fn bbox(&self) -> Bbox<S> {
        self.view().bbox()
    }
}

/// A cheap borrowed view of a [`PointStore`]'s points: one slice reference
/// plus the shape. `Copy`, so traversal code passes it by value.
#[derive(Clone, Copy, Debug)]
pub struct PointsView<'a, S: Scalar = f64> {
    coords: &'a [S],
    n: usize,
    d: usize,
}

impl<'a, S: Scalar> PointsView<'a, S> {
    /// View over a raw flat buffer (shape-checked like
    /// [`PointStore::try_new`], but borrowing).
    pub fn try_new(coords: &'a [S], d: usize) -> Result<Self, DpcError> {
        if d == 0 {
            return Err(DpcError::InvalidParam { name: "dim", value: 0.0, requirement: "must be positive" });
        }
        if coords.len() % d != 0 {
            return Err(DpcError::RaggedCoords { len: coords.len(), dim: d });
        }
        Ok(PointsView { coords, n: coords.len() / d, d })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn point(&self, i: usize) -> &'a [S] {
        &self.coords[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn coord(&self, i: usize, k: usize) -> S {
        self.coords[i * self.d + k]
    }

    pub fn coords(&self) -> &'a [S] {
        self.coords
    }

    #[inline]
    pub fn dist_sq(&self, i: usize, j: usize) -> S {
        S::dist_sq(self.point(i), self.point(j))
    }

    #[inline]
    pub fn dist_sq_to(&self, i: usize, q: &[S]) -> S {
        S::dist_sq(self.point(i), q)
    }

    /// Bounding box over a subset of point ids.
    pub fn bbox_of(&self, ids: &[u32]) -> Bbox<S> {
        let mut bb = Bbox::empty(self.d);
        for &i in ids {
            bb.expand(self.point(i as usize));
        }
        bb
    }

    /// Bounding box over all points.
    pub fn bbox(&self) -> Bbox<S> {
        let mut bb = Bbox::empty(self.d);
        for i in 0..self.n {
            bb.expand(self.point(i));
        }
        bb
    }
}

impl<'a, S: Scalar> From<&'a PointStore<S>> for PointsView<'a, S> {
    fn from(ps: &'a PointStore<S>) -> Self {
        ps.view()
    }
}

/// A runtime-tagged point store: what dtype boundaries (binary files, CLI
/// flags, coordinator payloads) traffic in before monomorphizing.
#[derive(Clone, Debug, PartialEq)]
pub enum DynPoints {
    F32(PointStore<f32>),
    F64(PointStore<f64>),
}

impl DynPoints {
    pub fn dtype(&self) -> Dtype {
        match self {
            DynPoints::F32(_) => Dtype::F32,
            DynPoints::F64(_) => Dtype::F64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            DynPoints::F32(p) => p.len(),
            DynPoints::F64(p) => p.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        match self {
            DynPoints::F32(p) => p.dim(),
            DynPoints::F64(p) => p.dim(),
        }
    }

    /// Widen to f64 (refcount share when already f64).
    pub fn into_f64(self) -> PointStore<f64> {
        match self {
            DynPoints::F32(p) => p.to_f64(),
            DynPoints::F64(p) => p,
        }
    }

    /// Re-run the constructor's NaN/∞ scan (see
    /// [`PointStore::validate_finite`]).
    pub fn validate_finite(&self) -> Result<(), DpcError> {
        match self {
            DynPoints::F32(p) => p.validate_finite(),
            DynPoints::F64(p) => p.validate_finite(),
        }
    }

    /// Convert to the requested precision by rounding cast; the matching-
    /// precision case shares storage instead of copying.
    pub fn cast(&self, dtype: Dtype) -> DynPoints {
        match (self, dtype) {
            (DynPoints::F32(p), Dtype::F32) => DynPoints::F32(p.clone()),
            (DynPoints::F64(p), Dtype::F64) => DynPoints::F64(p.clone()),
            (DynPoints::F32(p), Dtype::F64) => DynPoints::F64(p.to_f64()),
            (DynPoints::F64(p), Dtype::F32) => DynPoints::F32(PointStore::<f32>::cast_from_f64(p)),
        }
    }
}

/// Squared Euclidean distance between two coordinate slices.
#[inline]
pub fn dist_sq<S: Scalar>(a: &[S], b: &[S]) -> S {
    S::dist_sq(a, b)
}

/// Euclidean distance in f64 (single sqrt; use [`dist_sq`] in hot paths).
#[inline]
pub fn dist<S: Scalar>(a: &[S], b: &[S]) -> f64 {
    S::dist_sq(a, b).to_f64().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pointset_roundtrip() {
        let ps = PointSet::new(vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0], 2);
        assert_eq!(ps.len(), 3);
        assert_eq!(ps.dim(), 2);
        assert_eq!(ps.dtype(), Dtype::F64);
        assert_eq!(ps.point(1), &[3.0, 4.0]);
        assert_eq!(ps.dist_sq(0, 1), 25.0);
        assert_eq!(ps.dist_sq_to(0, &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn f32_store_roundtrip() {
        let ps = PointStore::<f32>::new(vec![0.0, 0.0, 3.0, 4.0], 2);
        assert_eq!((ps.len(), ps.dim()), (2, 2));
        assert_eq!(ps.dtype(), Dtype::F32);
        assert_eq!(ps.dist_sq(0, 1), 25.0f32);
    }

    #[test]
    fn from_rows_matches() {
        let ps = PointSet::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(ps.dim(), 3);
        assert_eq!(ps.coord(1, 2), 6.0);
    }

    #[test]
    fn clone_and_view_share_storage() {
        let ps = PointSet::new(vec![1.0, 2.0, 3.0, 4.0], 2);
        let ps2 = ps.clone();
        assert!(ps.shares_storage(&ps2));
        let v = ps.view();
        assert_eq!(v.point(1), ps.point(1));
        assert_eq!(v.dist_sq(0, 1), ps.dist_sq(0, 1));
        // A rebuilt store with equal contents does NOT share.
        let ps3 = PointSet::new(ps.coords().to_vec(), 2);
        assert!(!ps.shares_storage(&ps3));
        // Zero-copy re-wrap of the shared buffer does.
        let ps4 = PointSet::try_from_shared(ps.shared_coords(), 2).unwrap();
        assert!(ps.shares_storage(&ps4));
    }

    #[test]
    fn from_flat_fn_fills_in_order() {
        let mut calls = Vec::new();
        let ps = PointSet::from_flat_fn(3, 2, |i| {
            calls.push(i);
            i as f64 * 10.0
        });
        assert_eq!(calls, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!((ps.len(), ps.dim()), (3, 2));
        assert_eq!(ps.point(1), &[20.0, 30.0]);
        // Zero points is a valid (empty) store.
        let empty = PointSet::from_flat_fn(0, 2, |_| unreachable!("no slots to fill"));
        assert!(empty.is_empty());
    }

    #[test]
    fn try_from_flat_fn_propagates_the_first_error() {
        let got = PointSet::try_from_flat_fn(2, 2, |i| {
            if i < 3 {
                Ok(i as f64)
            } else {
                Err(DpcError::NonFiniteCoordinate { point: i / 2, dim: i % 2 })
            }
        });
        assert!(matches!(got, Err(DpcError::NonFiniteCoordinate { point: 1, dim: 1 })));
        assert!(matches!(
            PointSet::try_from_flat_fn(1, 0, |_| Ok(0.0)),
            Err(DpcError::InvalidParam { .. })
        ));
        let ok = PointSet::try_from_flat_fn(2, 1, |i| Ok(i as f64)).unwrap();
        assert_eq!(ok.coords(), &[0.0, 1.0]);
    }

    #[test]
    fn casts_between_precisions() {
        let ps = PointSet::new(vec![1.0, 2.0, 3.0, 4.0], 2);
        let ps32 = PointStore::<f32>::try_lossless_from_f64(&ps).unwrap();
        assert_eq!(ps32.point(1), &[3.0f32, 4.0]);
        let back = ps32.to_f64();
        assert_eq!(back.coords(), ps.coords());
        // A lossy value is rejected with its position.
        let lossy = PointSet::new(vec![1.0, 0.1], 2);
        assert!(matches!(
            PointStore::<f32>::try_lossless_from_f64(&lossy),
            Err(DpcError::LossyCast { point: 0, dim: 1, .. })
        ));
        // ...but the rounding cast accepts it.
        let rounded = PointStore::<f32>::cast_from_f64(&lossy);
        assert_eq!(rounded.coord(0, 1), 0.1f32);
    }

    #[test]
    fn dyn_points_casts() {
        let dp = DynPoints::F64(PointSet::new(vec![1.0, 2.0], 2));
        assert_eq!((dp.dtype(), dp.len(), dp.dim()), (Dtype::F64, 1, 2));
        let dp32 = dp.cast(Dtype::F32);
        assert_eq!(dp32.dtype(), Dtype::F32);
        let widened = dp32.into_f64();
        assert_eq!(widened.coords(), &[1.0, 2.0]);
        // Same-precision cast shares storage.
        let DynPoints::F64(orig) = &dp else { unreachable!() };
        let DynPoints::F64(same) = dp.cast(Dtype::F64) else { unreachable!() };
        assert!(orig.shares_storage(&same));
    }

    #[test]
    #[should_panic]
    fn bad_coords_len_panics() {
        PointSet::new(vec![1.0, 2.0, 3.0], 2);
    }

    #[test]
    fn try_new_rejects_bad_shapes() {
        assert!(matches!(PointSet::try_new(vec![1.0, 2.0, 3.0], 2), Err(DpcError::RaggedCoords { len: 3, dim: 2 })));
        assert!(matches!(PointSet::try_new(vec![1.0], 0), Err(DpcError::InvalidParam { .. })));
        assert!(PointSet::try_new(vec![1.0, 2.0], 2).is_ok());
        assert!(matches!(PointsView::try_new(&[1.0, 2.0, 3.0][..], 2), Err(DpcError::RaggedCoords { .. })));
    }

    #[test]
    fn try_from_rows_rejects_ragged_and_empty() {
        assert!(matches!(PointSet::try_from_rows(&[]), Err(DpcError::EmptyInput)));
        let ragged = vec![vec![1.0, 2.0], vec![3.0]];
        assert!(matches!(PointSet::try_from_rows(&ragged), Err(DpcError::DimensionMismatch { expected: 2, got: 1 })));
    }

    /// Plant `bad` at flat index `at` of an `n × d` store, bypassing the
    /// validating constructors (the generator path stays unvalidated by
    /// design — this is how tests build intentionally poisoned stores).
    fn poisoned<S: Scalar>(n: usize, d: usize, at: usize, bad: S) -> PointStore<S> {
        PointStore::from_flat_fn(n, d, |i| if i == at { bad } else { S::from_f64(i as f64) })
    }

    #[test]
    fn validate_finite_reports_position() {
        let ps = poisoned::<f64>(3, 2, 3, f64::NAN);
        assert!(matches!(ps.validate_finite(), Err(DpcError::NonFiniteCoordinate { point: 1, dim: 1 })));
        let ps = poisoned::<f64>(1, 2, 1, f64::INFINITY);
        assert!(matches!(ps.validate_finite(), Err(DpcError::NonFiniteCoordinate { point: 0, dim: 1 })));
        assert!(PointSet::new(vec![1.0, 2.0], 2).validate_finite().is_ok());
        let ps = poisoned::<f32>(1, 2, 1, f32::NAN);
        assert!(matches!(ps.validate_finite(), Err(DpcError::NonFiniteCoordinate { point: 0, dim: 1 })));
    }

    #[test]
    fn try_new_rejects_non_finite_coordinates() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let got = PointSet::try_new(vec![0.0, 1.0, 2.0, bad], 2);
            assert!(matches!(got, Err(DpcError::NonFiniteCoordinate { point: 1, dim: 1 })), "{bad}");
        }
        let got = PointStore::<f32>::try_new(vec![f32::NAN, 1.0], 2);
        assert!(matches!(got, Err(DpcError::NonFiniteCoordinate { point: 0, dim: 0 })));
        // Row-wise construction funnels through the same gate.
        let got = PointSet::try_from_rows(&[vec![0.0, 1.0], vec![f64::NAN, 3.0]]);
        assert!(matches!(got, Err(DpcError::NonFiniteCoordinate { point: 1, dim: 0 })));
    }

    #[test]
    fn dist_matches_dist_sq() {
        assert!((dist(&[0.0f64, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((dist(&[0.0f32, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}
