//! Geometric primitives: flat point sets, axis-aligned bounding boxes, and
//! squared-Euclidean distance kernels.
//!
//! Points are stored row-major (`coords[i*d + k]`), which keeps each point's
//! coordinates on one cache line during tree traversals — the dominant access
//! pattern in this crate. Distances are computed and compared **squared**
//! everywhere (monotone for Euclidean metrics), taking a single `sqrt` only
//! at user-facing boundaries.

pub mod bbox;

pub use bbox::Bbox;

use crate::error::DpcError;

/// A set of `n` points in `d`-dimensional space, row-major.
#[derive(Clone, Debug)]
pub struct PointSet {
    coords: Vec<f64>,
    n: usize,
    d: usize,
}

impl PointSet {
    /// Fallible constructor: rejects `d == 0` and coordinate buffers whose
    /// length is not a multiple of `d`. This is the entry point for
    /// user-supplied data; [`PointSet::new`] is the panicking convenience
    /// for generators and tests whose inputs are correct by construction.
    pub fn try_new(coords: Vec<f64>, d: usize) -> Result<Self, DpcError> {
        if d == 0 {
            return Err(DpcError::InvalidParam { name: "dim", value: 0.0, requirement: "must be positive" });
        }
        if coords.len() % d != 0 {
            return Err(DpcError::RaggedCoords { len: coords.len(), dim: d });
        }
        let n = coords.len() / d;
        Ok(PointSet { coords, n, d })
    }

    pub fn new(coords: Vec<f64>, d: usize) -> Self {
        Self::try_new(coords, d).expect("well-formed coordinate buffer")
    }

    pub fn empty(d: usize) -> Self {
        PointSet { coords: Vec::new(), n: 0, d }
    }

    /// Fallible row-wise constructor: rejects empty input and ragged rows.
    pub fn try_from_rows(rows: &[Vec<f64>]) -> Result<Self, DpcError> {
        if rows.is_empty() {
            return Err(DpcError::EmptyInput);
        }
        let d = rows[0].len();
        let mut coords = Vec::with_capacity(rows.len() * d);
        for r in rows {
            if r.len() != d {
                return Err(DpcError::DimensionMismatch { expected: d, got: r.len() });
            }
            coords.extend_from_slice(r);
        }
        Self::try_new(coords, d)
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        Self::try_from_rows(rows).expect("non-empty, non-ragged rows")
    }

    /// Scan for NaN/∞ coordinates, reporting the first offender's (point,
    /// dimension). Clustering math (kd-tree bounds, squared distances)
    /// silently misbehaves on non-finite input, so public entry points run
    /// this once up front.
    pub fn validate_finite(&self) -> Result<(), DpcError> {
        for (idx, &c) in self.coords.iter().enumerate() {
            if !c.is_finite() {
                return Err(DpcError::NonFinite { point: idx / self.d, dim: idx % self.d });
            }
        }
        Ok(())
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn point(&self, i: usize) -> &[f64] {
        &self.coords[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn coord(&self, i: usize, k: usize) -> f64 {
        self.coords[i * self.d + k]
    }

    pub fn coords(&self) -> &[f64] {
        &self.coords
    }

    pub fn push(&mut self, p: &[f64]) {
        assert_eq!(p.len(), self.d);
        self.coords.extend_from_slice(p);
        self.n += 1;
    }

    /// Squared Euclidean distance between stored points `i` and `j`.
    #[inline]
    pub fn dist_sq(&self, i: usize, j: usize) -> f64 {
        dist_sq(self.point(i), self.point(j))
    }

    /// Squared Euclidean distance from stored point `i` to an arbitrary `q`.
    #[inline]
    pub fn dist_sq_to(&self, i: usize, q: &[f64]) -> f64 {
        dist_sq(self.point(i), q)
    }

    /// Bounding box over a subset of point ids.
    pub fn bbox_of(&self, ids: &[u32]) -> Bbox {
        let mut bb = Bbox::empty(self.d);
        for &i in ids {
            bb.expand(self.point(i as usize));
        }
        bb
    }

    /// Bounding box over all points.
    pub fn bbox(&self) -> Bbox {
        let mut bb = Bbox::empty(self.d);
        for i in 0..self.n {
            bb.expand(self.point(i));
        }
        bb
    }
}

/// Squared Euclidean distance between two coordinate slices.
#[inline]
pub fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for k in 0..a.len() {
        let t = a[k] - b[k];
        s += t * t;
    }
    s
}

/// Euclidean distance (single sqrt; use [`dist_sq`] in hot paths).
#[inline]
pub fn dist(a: &[f64], b: &[f64]) -> f64 {
    dist_sq(a, b).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pointset_roundtrip() {
        let ps = PointSet::new(vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0], 2);
        assert_eq!(ps.len(), 3);
        assert_eq!(ps.dim(), 2);
        assert_eq!(ps.point(1), &[3.0, 4.0]);
        assert_eq!(ps.dist_sq(0, 1), 25.0);
        assert_eq!(ps.dist_sq_to(0, &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn from_rows_matches() {
        let ps = PointSet::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(ps.dim(), 3);
        assert_eq!(ps.coord(1, 2), 6.0);
    }

    #[test]
    fn push_extends() {
        let mut ps = PointSet::empty(2);
        ps.push(&[1.0, 2.0]);
        ps.push(&[3.0, 4.0]);
        assert_eq!(ps.len(), 2);
        assert_eq!(ps.point(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn bad_coords_len_panics() {
        PointSet::new(vec![1.0, 2.0, 3.0], 2);
    }

    #[test]
    fn try_new_rejects_bad_shapes() {
        assert!(matches!(PointSet::try_new(vec![1.0, 2.0, 3.0], 2), Err(DpcError::RaggedCoords { len: 3, dim: 2 })));
        assert!(matches!(PointSet::try_new(vec![1.0], 0), Err(DpcError::InvalidParam { .. })));
        assert!(PointSet::try_new(vec![1.0, 2.0], 2).is_ok());
    }

    #[test]
    fn try_from_rows_rejects_ragged_and_empty() {
        assert!(matches!(PointSet::try_from_rows(&[]), Err(DpcError::EmptyInput)));
        let ragged = vec![vec![1.0, 2.0], vec![3.0]];
        assert!(matches!(PointSet::try_from_rows(&ragged), Err(DpcError::DimensionMismatch { expected: 2, got: 1 })));
    }

    #[test]
    fn validate_finite_reports_position() {
        let ps = PointSet::new(vec![0.0, 1.0, 2.0, f64::NAN, 4.0, 5.0], 2);
        assert!(matches!(ps.validate_finite(), Err(DpcError::NonFinite { point: 1, dim: 1 })));
        let ps = PointSet::new(vec![0.0, f64::INFINITY], 2);
        assert!(matches!(ps.validate_finite(), Err(DpcError::NonFinite { point: 0, dim: 1 })));
        assert!(PointSet::new(vec![1.0, 2.0], 2).validate_finite().is_ok());
    }

    #[test]
    fn dist_matches_dist_sq() {
        assert!((dist(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
