//! The TCP front end: a thread-per-connection acceptor over non-blocking
//! reads, feeding the same dispatcher as the stdin loop.
//!
//! Dependency-free by construction (`std::net` only — the container has
//! no tokio and the repo's policy is no new dependencies): the acceptor
//! polls a non-blocking listener so it can observe the shutdown flag,
//! and each connection thread drives a read-timeout socket through a
//! [`FrameBuf`], dispatching one request at a time. Responses are
//! written back in request order — the protocol is strictly
//! request/response per connection; concurrency comes from opening more
//! connections (which is exactly what `loadgen` does).
//!
//! A corrupt frame (bad CRC, oversized length) kills only its own
//! connection: byte-stream framing cannot resynchronize after a bad
//! length, so the server sends a final `Error` response if it can and
//! drops the socket. A cleanly closed socket mid-frame is treated like
//! the journal's torn tail — abandoned work, no error.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::dispatch::{dispatch, ConnCtx, ServeState};
use super::frame::{encode_frame, FrameBuf};
use super::proto::{Request, Response};

/// How often blocked reads/accepts wake to check the shutdown flag.
const POLL: Duration = Duration::from_millis(50);

/// Handle to a running TCP server; dropping the handle does NOT stop it
/// — call [`ServerHandle::shutdown`].
pub struct ServerHandle {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for ServerHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerHandle").field("local_addr", &self.local_addr).finish_non_exhaustive()
    }
}

impl ServerHandle {
    /// Signal shutdown and join the acceptor (connection threads drain
    /// on their next poll tick).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

/// Bind `addr` (e.g. `127.0.0.1:7401`, or port 0 for an OS-assigned
/// port) and serve until [`ServerHandle::shutdown`].
pub fn spawn(addr: &str, state: Arc<ServeState>) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local_addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let acceptor = std::thread::Builder::new()
        .name("serve-accept".into())
        .spawn(move || accept_loop(listener, state, stop2))
        // lint: allow(panic-surface) — spawn failure at server startup has
        // no useful recovery; surfacing it immediately is correct.
        .expect("spawn acceptor");
    Ok(ServerHandle { local_addr, stop, acceptor: Some(acceptor) })
}

fn accept_loop(listener: TcpListener, state: Arc<ServeState>, stop: Arc<AtomicBool>) {
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((sock, _peer)) => {
                let state = Arc::clone(&state);
                let stop = Arc::clone(&stop);
                state.coord.metrics.inc("serve_connections");
                conns.push(
                    std::thread::Builder::new()
                        .name("serve-conn".into())
                        .spawn(move || {
                            if let Err(e) = connection_loop(sock, &state, &stop) {
                                // An I/O failure on one connection is that
                                // connection's problem, not the server's.
                                state.coord.metrics.inc("serve_conn_errors");
                                let _ = e;
                            }
                        })
                        // lint: allow(panic-surface) — thread-spawn failure
                        // means resource exhaustion; dying loudly beats
                        // silently dropping the accepted connection.
                        .expect("spawn connection thread"),
                );
                // Reap finished connection threads so a long-lived server
                // doesn't accumulate handles.
                conns.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(POLL),
            Err(_) => std::thread::sleep(POLL),
        }
    }
    for h in conns {
        let _ = h.join();
    }
}

/// Frame a response for the wire. A response that overflows `MAX_FRAME`
/// (a full-result payload over an enormous stream) is downgraded to a
/// small typed `Error` the client can actually receive — the connection
/// stays synchronized and usable, where the old `debug_assert!`-only cap
/// would have shipped a frame the peer must treat as corruption.
fn encode_response(state: &ServeState, resp: Response) -> Vec<u8> {
    match encode_frame(&resp.encode()) {
        Ok(bytes) => bytes,
        Err(e) => {
            state.coord.metrics.inc("serve_oversized_responses");
            let fallback = Response::Error { detail: e.to_string() };
            // The fallback is a few hundred bytes — re-encoding cannot
            // overflow the cap; `unwrap_or_default` only placates the
            // type, an empty write is unreachable.
            encode_frame(&fallback.encode()).unwrap_or_default()
        }
    }
}

fn connection_loop(sock: TcpStream, state: &ServeState, stop: &AtomicBool) -> std::io::Result<()> {
    // Blocking socket with a short read timeout: the thread parks in the
    // kernel between requests but still honors shutdown within a tick.
    sock.set_read_timeout(Some(POLL))?;
    sock.set_nodelay(true)?;
    let mut sock = sock;
    let mut fb = FrameBuf::new();
    let mut ctx = ConnCtx::default();
    let mut chunk = [0u8; 64 * 1024];
    loop {
        // Drain every complete frame before reading again.
        loop {
            match fb.next_frame() {
                Ok(None) => break,
                Ok(Some(payload)) => {
                    let resp = match Request::decode(&payload) {
                        Ok(req) => dispatch(state, &mut ctx, req),
                        // Undecodable payload inside a valid frame: the
                        // framing is still synchronized, so answer and
                        // keep the connection.
                        Err(detail) => {
                            state.coord.metrics.inc("serve_proto_errors");
                            Response::Error { detail }
                        }
                    };
                    sock.write_all(&encode_response(state, resp))?;
                }
                Err(e) => {
                    // Framing broke: best-effort final error, then drop.
                    state.coord.metrics.inc("serve_proto_errors");
                    let resp = Response::Error { detail: e.to_string() };
                    let _ = sock.write_all(&encode_response(state, resp));
                    return Ok(());
                }
            }
        }
        if stop.load(Ordering::Acquire) {
            return Ok(());
        }
        match sock.read(&mut chunk) {
            Ok(0) => return Ok(()), // peer closed; mid-frame bytes are a torn tail
            Ok(n) => fb.feed(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
}
