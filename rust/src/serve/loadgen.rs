//! Load-generation harness for the TCP serve surface.
//!
//! [`Client`] is a minimal synchronous protocol client (connect, frame a
//! [`Request`], block for the framed [`Response`]) — also the reference
//! implementation for anyone speaking the protocol from outside this
//! repo. [`run`] drives M concurrent connections through a deterministic
//! mixed workload (open → ingest×K / recut×K → close per connection,
//! seeded per connection id) and reports latency percentiles and
//! throughput for EXPERIMENTS.md §Serve.
//!
//! Protocol errors are counted, not tolerated: the harness's contract
//! (and the CI smoke run's assertion) is zero `proto_errors` — a `Busy`
//! response is *not* a protocol error, it's the admission control
//! working, and the generator backs off and retries.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::prng::SplitMix64;

use super::frame::{encode_frame, FrameBuf};
use super::proto::{Request, Response};

/// Synchronous protocol client: one request in flight at a time.
pub struct Client {
    sock: TcpStream,
    fb: FrameBuf,
}

impl std::fmt::Debug for Client {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Client").field("peer", &self.sock.peer_addr().ok()).finish_non_exhaustive()
    }
}

impl Client {
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let sock = TcpStream::connect(addr)?;
        sock.set_nodelay(true)?;
        Ok(Client { sock, fb: FrameBuf::new() })
    }

    /// Send one request and block for its response. A frame or decode
    /// failure is an `Err` (the connection is unusable afterwards).
    pub fn call(&mut self, req: &Request) -> Result<Response, String> {
        let framed = encode_frame(&req.encode()).map_err(|e| format!("encode: {e}"))?;
        self.sock.write_all(&framed).map_err(|e| format!("send: {e}"))?;
        let mut chunk = [0u8; 64 * 1024];
        loop {
            if let Some(payload) = self.fb.next_frame().map_err(|e| e.to_string())? {
                return Response::decode(&payload);
            }
            let n = self.sock.read(&mut chunk).map_err(|e| format!("recv: {e}"))?;
            if n == 0 {
                return Err("server closed the connection mid-response".into());
            }
            self.fb.feed(&chunk[..n]);
        }
    }
}

/// Workload shape for one loadgen run.
#[derive(Clone, Debug)]
pub struct LoadgenOpts {
    pub addr: String,
    /// Concurrent connections (each on its own thread).
    pub connections: usize,
    /// Operations per connection, *excluding* the open/close bookends.
    pub ops_per_conn: usize,
    /// Points per opened session / ingested batch.
    pub n: u64,
    /// Dataset name fed to the server-side generator.
    pub dataset: String,
    /// Fraction of ops that are stream ingests (the rest are session
    /// recuts), in percent.
    pub ingest_pct: u8,
    /// Retries per op on `Busy` before counting it as saturated.
    pub busy_retries: usize,
    /// Tenant id sent in each connection's hello (empty = anonymous).
    pub tenant: String,
}

impl Default for LoadgenOpts {
    fn default() -> Self {
        LoadgenOpts {
            addr: String::new(),
            connections: 4,
            ops_per_conn: 25,
            n: 200,
            dataset: "simden".into(),
            ingest_pct: 50,
            busy_retries: 50,
            tenant: String::new(),
        }
    }
}

/// Aggregate results across every connection.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    /// Completed operations (each got a non-`Busy`, non-`Error` response).
    pub ops: u64,
    /// `Busy` responses observed (then retried).
    pub busy: u64,
    /// `Error` responses (server-side request failures).
    pub request_errors: u64,
    /// Transport/framing/codec failures — the smoke gate asserts zero.
    pub proto_errors: u64,
    pub wall: Duration,
    pub p50: Duration,
    pub p99: Duration,
    /// Completed ops per second of wall time.
    pub ops_per_sec: f64,
}

fn percentile(sorted: &[Duration], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

struct ConnStats {
    latencies: Vec<Duration>,
    busy: u64,
    request_errors: u64,
    proto_errors: u64,
}

/// One connection's scripted life: hello, open a session and a stream,
/// then `ops_per_conn` operations mixing recuts and ingests, then close
/// both. Deterministic per `(conn_id)` so runs are comparable.
fn run_conn(opts: &LoadgenOpts, conn_id: usize) -> ConnStats {
    let mut stats = ConnStats { latencies: Vec::new(), busy: 0, request_errors: 0, proto_errors: 0 };
    let mut rng = SplitMix64::new(0x10ad_6e00 + conn_id as u64);
    let mut client = match Client::connect(&opts.addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("loadgen conn {conn_id}: connect failed: {e}");
            stats.proto_errors += 1;
            return stats;
        }
    };
    // A call that survives Busy with bounded retries; returns None on a
    // protocol error (after recording it).
    let mut timed_call = |client: &mut Client,
                          req: &Request,
                          stats: &mut ConnStats,
                          record: bool|
     -> Option<Response> {
        for _ in 0..=opts.busy_retries {
            let t = Instant::now();
            match client.call(req) {
                Ok(Response::Busy { .. }) => {
                    stats.busy += 1;
                    std::thread::sleep(Duration::from_millis(2));
                }
                Ok(Response::Error { detail }) => {
                    stats.request_errors += 1;
                    eprintln!("loadgen conn {conn_id}: request error: {detail}");
                    return None;
                }
                Ok(resp) => {
                    if record {
                        stats.latencies.push(t.elapsed());
                    }
                    return Some(resp);
                }
                Err(e) => {
                    stats.proto_errors += 1;
                    eprintln!("loadgen conn {conn_id}: protocol error: {e}");
                    return None;
                }
            }
        }
        stats.busy += 1;
        None
    };
    if !opts.tenant.is_empty() {
        let hello = Request::Hello { tenant: opts.tenant.clone() };
        timed_call(&mut client, &hello, &mut stats, false);
    }
    let open = Request::OpenSession {
        dataset: opts.dataset.clone(),
        n: opts.n,
        d_cut: 3.0,
        density: crate::dpc::DensityModel::CutoffCount,
        tag: format!("loadgen-{conn_id}"),
    };
    let Some(Response::Opened { id: session, .. }) = timed_call(&mut client, &open, &mut stats, false)
    else {
        return stats;
    };
    let stream_open = Request::OpenStream {
        dim: 2,
        d_cut: 3.0,
        density: crate::dpc::DensityModel::CutoffCount,
        tag: format!("loadgen-{conn_id}-stream"),
        dtype: crate::geom::Dtype::F64,
    };
    let Some(Response::Opened { id: stream, .. }) =
        timed_call(&mut client, &stream_open, &mut stats, false)
    else {
        return stats;
    };
    for op in 0..opts.ops_per_conn {
        let req = if rng.next_below(100) < opts.ingest_pct as u64 {
            Request::Ingest {
                stream,
                dataset: opts.dataset.clone(),
                n: opts.n,
                // Distinct batches per op, stable across runs.
                seed: (conn_id * 1_000 + op) as u64,
                rho_min: 0.0,
                delta_min: 20.0,
                full: false,
            }
        } else {
            Request::Recut {
                session,
                rho_min: rng.uniform(0.0, 2.0),
                delta_min: rng.uniform(5.0, 25.0),
                full: false,
            }
        };
        timed_call(&mut client, &req, &mut stats, true);
    }
    timed_call(&mut client, &Request::CloseStream { stream }, &mut stats, false);
    timed_call(&mut client, &Request::CloseSession { session }, &mut stats, false);
    stats
}

/// Run the workload and aggregate. Spawns `opts.connections` client
/// threads against `opts.addr`.
pub fn run(opts: &LoadgenOpts) -> LoadgenReport {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..opts.connections)
        .map(|conn_id| {
            let opts = opts.clone();
            std::thread::Builder::new()
                .name(format!("loadgen-{conn_id}"))
                .spawn(move || run_conn(&opts, conn_id))
                // lint: allow(panic-surface) — loadgen is a CLI harness;
                // failing to spawn a thread is unrecoverable here.
                .expect("spawn loadgen thread")
        })
        .collect();
    let mut all = Vec::new();
    let mut report = LoadgenReport::default();
    for h in handles {
        // lint: allow(panic-surface) — propagating a worker panic out of
        // the CLI harness is the intended failure mode.
        let stats = h.join().expect("loadgen thread panicked");
        report.busy += stats.busy;
        report.request_errors += stats.request_errors;
        report.proto_errors += stats.proto_errors;
        all.extend(stats.latencies);
    }
    report.wall = t0.elapsed();
    report.ops = all.len() as u64;
    all.sort();
    report.p50 = percentile(&all, 0.50);
    report.p99 = percentile(&all, 0.99);
    report.ops_per_sec = report.ops as f64 / report.wall.as_secs_f64().max(1e-9);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_order_statistics() {
        let ms: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        assert_eq!(percentile(&ms, 0.50), Duration::from_millis(50));
        assert_eq!(percentile(&ms, 0.99), Duration::from_millis(99));
        assert_eq!(percentile(&ms, 1.0), Duration::from_millis(100));
        assert_eq!(percentile(&[], 0.5), Duration::ZERO);
    }
}
