//! Serve-side admission control: who may hold how many open handles.
//!
//! The coordinator's own gate (`max_inflight_jobs`) bounds *work in
//! flight*; this module bounds *state at rest* — open sessions and
//! streams — which is what a long-lived server actually leaks. Two
//! knobs, both from [`crate::coordinator::CoordinatorConfig`]:
//!
//! - `max_sessions_per_tenant`: a tenant id (supplied by the
//!   connection's `hello`, empty for anonymous) may hold at most this
//!   many open handles; further opens fail with
//!   [`DpcError::QuotaExceeded`]. 0 = unlimited.
//! - `max_open_sessions`: global cap. An open at the cap evicts the
//!   least-recently-used *idle* handle (no job currently running against
//!   it) to make room; if every handle is busy the open fails with
//!   [`DpcError::Backpressure`]. 0 = unlimited.
//!
//! Recency is a logical clock bumped on every touch, not wall time —
//! deterministic under test and free of `Instant` syscalls on the hot
//! path. Lock ordering: the registry lock is taken by the serve layer
//! only, and the coordinator never takes it, so holding it across a
//! `close_session` call (eviction) cannot deadlock. That contract is
//! machine-checked: the registry is an [`OrderedMutex`] at
//! [`rank::SERVE_ADMISSION`], the lowest rank in the table, so debug
//! builds abort if any coordinator path ever takes it while holding a
//! coordinator lock.

use std::collections::HashMap;

use crate::error::DpcError;
use crate::sync::{rank, OrderedMutex, OrderedMutexGuard};

/// What an admission handle points at (decides which close the evictor
/// calls).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HandleKind {
    Session,
    Stream,
}

#[derive(Debug)]
struct Handle {
    tenant: String,
    kind: HandleKind,
    last_used: u64,
    /// Jobs currently running against this handle; only `busy == 0`
    /// handles are eviction candidates.
    busy: u32,
}

#[derive(Debug, Default)]
struct Inner {
    handles: HashMap<u64, Handle>,
    clock: u64,
}

/// The shared handle registry. One per server, shared by every surface.
#[derive(Debug)]
pub struct Admission {
    max_per_tenant: usize,
    max_open: usize,
    inner: OrderedMutex<Inner, { rank::SERVE_ADMISSION }>,
}

/// A locked view for the open path: quota check, eviction pick, and
/// registration must be one atomic step or concurrent opens overshoot
/// the caps.
#[derive(Debug)]
pub struct AdmissionGuard<'a> {
    inner: OrderedMutexGuard<'a, Inner, { rank::SERVE_ADMISSION }>,
    max_per_tenant: usize,
    max_open: usize,
}

impl Admission {
    pub fn new(max_per_tenant: usize, max_open: usize) -> Self {
        Admission { max_per_tenant, max_open, inner: OrderedMutex::new(Inner::default()) }
    }

    /// Lock the registry for an open (see [`AdmissionGuard`]).
    pub fn lock(&self) -> AdmissionGuard<'_> {
        AdmissionGuard {
            inner: self.inner.lock(),
            max_per_tenant: self.max_per_tenant,
            max_open: self.max_open,
        }
    }

    /// Bump a handle's recency (any request that names it).
    pub fn touch(&self, id: u64) {
        let mut g = self.inner.lock();
        g.clock += 1;
        let now = g.clock;
        if let Some(h) = g.handles.get_mut(&id) {
            h.last_used = now;
        }
    }

    /// Mark a job in flight against `id` (shields it from eviction).
    pub fn begin_job(&self, id: u64) {
        if let Some(h) = self.inner.lock().handles.get_mut(&id) {
            h.busy += 1;
        }
    }

    pub fn end_job(&self, id: u64) {
        if let Some(h) = self.inner.lock().handles.get_mut(&id) {
            h.busy = h.busy.saturating_sub(1);
        }
    }

    /// Drop a handle after an explicit close.
    pub fn remove(&self, id: u64) {
        self.inner.lock().handles.remove(&id);
    }

    /// Open handles held by `tenant` (quota accounting).
    pub fn tenant_open(&self, tenant: &str) -> usize {
        self.inner.lock().handles.values().filter(|h| h.tenant == tenant).count()
    }

    pub fn open_handles(&self) -> usize {
        self.inner.lock().handles.len()
    }

    /// Seed the registry after durable recovery: recovered handles
    /// belong to no tenant (quotas bind new traffic, not history) but do
    /// count against the global cap and are immediately evictable.
    pub fn seed_recovered(&self, ids: impl IntoIterator<Item = (u64, HandleKind)>) {
        let mut g = self.inner.lock();
        for (id, kind) in ids {
            g.handles.insert(id, Handle { tenant: String::new(), kind, last_used: 0, busy: 0 });
        }
    }
}

impl AdmissionGuard<'_> {
    /// Admit one open for `tenant`. Returns the handle to evict first
    /// (already deregistered here — the caller must close it on the
    /// coordinator while still holding this guard), or `None` if there
    /// is room.
    pub fn check_open(&mut self, tenant: &str) -> Result<Option<(u64, HandleKind)>, DpcError> {
        if self.max_per_tenant > 0 {
            let open = self.inner.handles.values().filter(|h| h.tenant == tenant).count();
            if open >= self.max_per_tenant {
                return Err(DpcError::QuotaExceeded {
                    tenant: tenant.to_string(),
                    open,
                    limit: self.max_per_tenant,
                });
            }
        }
        if self.max_open == 0 || self.inner.handles.len() < self.max_open {
            return Ok(None);
        }
        let victim = self
            .inner
            .handles
            .iter()
            .filter(|(_, h)| h.busy == 0)
            .min_by_key(|(id, h)| (h.last_used, **id))
            .map(|(id, h)| (*id, h.kind));
        match victim {
            Some((id, kind)) => {
                self.inner.handles.remove(&id);
                Ok(Some((id, kind)))
            }
            None => Err(DpcError::Backpressure {
                in_flight: self.inner.handles.len() as u64,
                limit: self.max_open as u64,
            }),
        }
    }

    /// Record a freshly opened handle (most-recently-used by
    /// construction).
    pub fn register(&mut self, id: u64, tenant: &str, kind: HandleKind) {
        self.inner.clock += 1;
        let now = self.inner.clock;
        self.inner.handles.insert(
            id,
            Handle { tenant: tenant.to_string(), kind, last_used: now, busy: 0 },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_by_default() {
        let a = Admission::new(0, 0);
        let mut g = a.lock();
        for id in 0..100 {
            assert!(g.check_open("t").unwrap().is_none());
            g.register(id, "t", HandleKind::Session);
        }
        drop(g);
        assert_eq!(a.open_handles(), 100);
    }

    #[test]
    fn tenant_quota_is_per_tenant() {
        let a = Admission::new(2, 0);
        let mut g = a.lock();
        g.register(1, "acme", HandleKind::Session);
        g.register(2, "acme", HandleKind::Stream);
        let err = g.check_open("acme").unwrap_err();
        assert!(matches!(err, DpcError::QuotaExceeded { open: 2, limit: 2, .. }));
        // A different tenant is unaffected.
        assert!(g.check_open("other").unwrap().is_none());
        drop(g);
        // Closing frees quota.
        a.remove(1);
        assert!(a.lock().check_open("acme").unwrap().is_none());
    }

    #[test]
    fn global_cap_evicts_least_recently_used_idle_handle() {
        let a = Admission::new(0, 2);
        let mut g = a.lock();
        g.register(1, "", HandleKind::Session);
        g.register(2, "", HandleKind::Stream);
        drop(g);
        a.touch(1); // 2 is now the LRU
        let victim = a.lock().check_open("").unwrap();
        assert_eq!(victim, Some((2, HandleKind::Stream)));
        let mut g = a.lock();
        g.register(3, "", HandleKind::Session);
        drop(g);
        assert_eq!(a.open_handles(), 2);
    }

    #[test]
    fn busy_handles_are_not_evicted() {
        let a = Admission::new(0, 2);
        let mut g = a.lock();
        g.register(1, "", HandleKind::Session);
        g.register(2, "", HandleKind::Session);
        drop(g);
        a.begin_job(1);
        a.begin_job(2);
        // Every handle busy: the open fails instead of evicting.
        assert!(matches!(a.lock().check_open("").unwrap_err(), DpcError::Backpressure { .. }));
        a.end_job(2);
        // 2 is idle again and older than nothing — it's the only idle one.
        assert_eq!(a.lock().check_open("").unwrap(), Some((2, HandleKind::Session)));
    }

    #[test]
    fn recovered_handles_count_and_evict_first() {
        let a = Admission::new(0, 2);
        a.seed_recovered([(7, HandleKind::Stream), (8, HandleKind::Session)]);
        assert_eq!(a.open_handles(), 2);
        a.touch(8);
        // 7 untouched since recovery: first out.
        assert_eq!(a.lock().check_open("t").unwrap(), Some((7, HandleKind::Stream)));
        // Recovered handles belong to no tenant, so quotas don't see them.
        assert_eq!(a.tenant_open("t"), 0);
    }
}
