//! The serve subsystem: every way a request reaches the coordinator.
//!
//! One request model, three transports:
//!
//! - [`proto`] — the [`proto::Request`]/[`proto::Response`] enums, with
//!   two codecs: a versioned, CRC-framed binary encoding (reusing
//!   `durability::wire`) and a line grammar. The stdin loop and the TCP
//!   server parse into the *same* types, so a command means the same
//!   thing everywhere.
//! - [`frame`] — `[len][crc][payload]` framing with the journal's
//!   torn-vs-corrupt taxonomy transplanted to sockets.
//! - [`server`] — the dependency-free TCP front end (thread per
//!   connection, non-blocking accept, poll-for-shutdown).
//! - [`dispatch`] — the single dispatcher both surfaces feed, wrapping
//!   the coordinator with serve-side [`admission`] control (per-tenant
//!   quotas, global handle cap with LRU idle eviction) on top of the
//!   coordinator's own in-flight job gate.
//! - [`loadgen`] — the reference protocol client plus the concurrent
//!   workload harness behind the `loadgen` binary (EXPERIMENTS.md
//!   §Serve).

pub mod admission;
pub mod dispatch;
pub mod frame;
pub mod loadgen;
pub mod proto;
pub mod server;

pub use dispatch::{dispatch, ConnCtx, ServeState};
pub use frame::{encode_frame, FrameBuf, FrameError, HEADER, MAX_FRAME};
pub use proto::{FullResult, Request, Response, MIN_PROTO_VERSION, PROTO_VERSION};
pub use server::{spawn, ServerHandle};
