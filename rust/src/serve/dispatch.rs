//! One dispatcher for every serve surface.
//!
//! The stdin loop parses lines into [`Request`]s, the TCP server decodes
//! frames into [`Request`]s, and both hand them here — so a command
//! means exactly the same thing (same validation, same admission, same
//! coordinator calls, same response) no matter how it arrived.
//!
//! Dispatch is synchronous: one request, one [`Response`], in order.
//! Backpressure from the coordinator's admission gate surfaces as
//! [`Response::Busy`] (nothing was enqueued; the client should back off
//! and retry) rather than queueing unboundedly — the issue the old
//! submit-all-then-wait stdin loop had.

use std::sync::Arc;

use crate::coordinator::{ClusterJob, Coordinator, OpenSpec};
use crate::datasets;
use crate::dpc::DpcParams;
use crate::error::DpcError;

use super::admission::{Admission, HandleKind};
use super::proto::{FullResult, Request, Response};

/// Everything a serve surface needs: the coordinator plus the serve-side
/// admission registry, seeded with whatever a durable recovery restored.
#[derive(Debug)]
pub struct ServeState {
    pub coord: Coordinator,
    pub admission: Admission,
}

impl ServeState {
    pub fn new(coord: Coordinator) -> Self {
        let cfg = coord.config();
        let admission = Admission::new(cfg.max_sessions_per_tenant, cfg.max_open_sessions);
        admission.seed_recovered(
            coord
                .session_ids()
                .into_iter()
                .map(|id| (id, HandleKind::Session))
                .chain(coord.stream_ids().into_iter().map(|id| (id, HandleKind::Stream))),
        );
        ServeState { coord, admission }
    }
}

/// Per-connection context: the tenant id is connection state (set by
/// `hello`), not per-request payload.
#[derive(Default, Debug)]
pub struct ConnCtx {
    pub tenant: String,
}

fn err_response(e: DpcError) -> Response {
    match e {
        DpcError::Backpressure { .. } => Response::Busy { detail: e.to_string() },
        other => Response::Error { detail: other.to_string() },
    }
}

fn dataset_points(name: &str, n: u64, seed: u64) -> Result<crate::geom::PointSet, Response> {
    match datasets::by_name(name, Some(n as usize), seed) {
        Some(ds) => Ok(ds.pts),
        None => Err(Response::Error { detail: format!("unknown dataset {name:?}") }),
    }
}

/// Open a session or stream under admission control: tenant quota, then
/// the global cap (evicting the LRU idle handle if needed), then the
/// coordinator open, then registration — all under the registry lock so
/// concurrent opens can't overshoot. The coordinator never takes this
/// lock, so closing the victim inside it cannot deadlock.
fn open_under_admission(
    state: &ServeState,
    tenant: &str,
    kind: HandleKind,
    open: impl FnOnce() -> Result<u64, DpcError>,
) -> Response {
    let mut guard = state.admission.lock();
    let victim = match guard.check_open(tenant) {
        Ok(v) => v,
        Err(e) => return err_response(e),
    };
    if let Some((vid, vkind)) = victim {
        // The victim was already deregistered; a racing close may have
        // beaten us to the coordinator, which is fine.
        let _ = match vkind {
            HandleKind::Session => state.coord.close_session(vid),
            HandleKind::Stream => state.coord.close_stream(vid),
        };
        state.coord.metrics.inc("serve_evictions");
    }
    match open() {
        Ok(id) => {
            guard.register(id, tenant, kind);
            Response::Opened { id, evicted: victim.map(|(vid, _)| vid) }
        }
        Err(e) => err_response(e),
    }
}

/// Submit-and-wait for the job-shaped requests, bracketed by busy marks
/// so the handle can't be LRU-evicted mid-job.
fn run_job(
    state: &ServeState,
    handle: Option<u64>,
    full: bool,
    submit: impl FnOnce() -> Result<u64, DpcError>,
) -> Response {
    if let Some(h) = handle {
        state.admission.touch(h);
        state.admission.begin_job(h);
    }
    let resp = match submit() {
        Err(e) => err_response(e),
        Ok(job) => match state.coord.wait(job) {
            Err(msg) => Response::Error { detail: msg },
            Ok(out) => Response::Result {
                job,
                tag: out.tag,
                backend: out.backend_used.name().to_string(),
                clusters: out.result.num_clusters as u64,
                noise: out.result.num_noise as u64,
                wall_s: out.wall_s,
                full: full.then(|| FullResult::from_result(&out.result)),
            },
        },
    };
    if let Some(h) = handle {
        state.admission.end_job(h);
    }
    resp
}

/// Handle one request. Never panics on user input; every failure is a
/// [`Response::Error`] or [`Response::Busy`] and the connection stays
/// usable.
pub fn dispatch(state: &ServeState, ctx: &mut ConnCtx, req: Request) -> Response {
    state.coord.metrics.inc("serve_requests");
    match req {
        Request::Hello { tenant } => {
            ctx.tenant = tenant.clone();
            Response::Hello { tenant }
        }
        Request::Cluster { dataset, n, d_cut, rho_min, delta_min, algo, density, full } => {
            let pts = match dataset_points(&dataset, n, 42) {
                Ok(p) => p,
                Err(resp) => return resp,
            };
            run_job(state, None, full, || {
                let params = DpcParams { d_cut, rho_min, delta_min, density, ..DpcParams::default() };
                let mut job = ClusterJob::new(Arc::new(pts), params).tag(&dataset);
                if let Some(a) = algo {
                    job = job.dep_algo(a);
                }
                state.coord.try_submit(job)
            })
        }
        Request::OpenSession { dataset, n, d_cut, density, tag } => {
            let pts = match dataset_points(&dataset, n, 42) {
                Ok(p) => p,
                Err(resp) => return resp,
            };
            let tenant = ctx.tenant.clone();
            open_under_admission(state, &tenant, HandleKind::Session, || {
                state.coord.open_session(OpenSpec::points(Arc::new(pts), d_cut).density(density).tag(tag))
            })
        }
        Request::Recut { session, rho_min, delta_min, full } => run_job(state, Some(session), full, || {
            state.coord.submit_recut(session, rho_min, delta_min)
        }),
        Request::CloseSession { session } => match state.coord.close_session(session) {
            Ok(()) => {
                state.admission.remove(session);
                Response::Closed { id: session }
            }
            Err(e) => err_response(e),
        },
        Request::OpenStream { dim, d_cut, density, tag, dtype } => {
            let tenant = ctx.tenant.clone();
            open_under_admission(state, &tenant, HandleKind::Stream, || {
                state
                    .coord
                    .open_stream(OpenSpec::dim(dim as usize, d_cut).density(density).tag(tag).dtype(dtype))
            })
        }
        Request::Ingest { stream, dataset, n, seed, rho_min, delta_min, full } => {
            let pts = match dataset_points(&dataset, n, seed) {
                Ok(p) => p,
                Err(resp) => return resp,
            };
            run_job(state, Some(stream), full, || {
                state.coord.submit_ingest(stream, Arc::new(pts), rho_min, delta_min)
            })
        }
        Request::IngestPoints { stream, batch, rho_min, delta_min, full } => {
            // The dyn path checks the batch's dtype against the stream's
            // before journaling; a mismatch comes back as a typed error.
            run_job(state, Some(stream), full, || {
                state.coord.submit_ingest_dyn(stream, batch, rho_min, delta_min)
            })
        }
        Request::CloseStream { stream } => match state.coord.close_stream(stream) {
            Ok(()) => {
                state.admission.remove(stream);
                Response::Closed { id: stream }
            }
            Err(e) => err_response(e),
        },
        Request::Checkpoint => match state.coord.checkpoint_now() {
            Ok(m) => Response::CheckpointTaken {
                seq: m.checkpoint_seq,
                journal_seq: m.journal_seq,
                journal_offset: m.journal_offset,
                next_lsn: m.next_lsn,
            },
            Err(e) => err_response(e),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::dpc::DensityModel;

    fn state_with(cfg_mut: impl FnOnce(&mut CoordinatorConfig)) -> ServeState {
        let mut cfg = CoordinatorConfig {
            artifacts_dir: std::path::PathBuf::from("/nonexistent"),
            ..CoordinatorConfig::default()
        };
        cfg_mut(&mut cfg);
        ServeState::new(Coordinator::start(cfg).unwrap())
    }

    fn open_req(tag: &str) -> Request {
        Request::OpenSession {
            dataset: "simden".into(),
            n: 60,
            d_cut: 3.0,
            density: DensityModel::CutoffCount,
            tag: tag.into(),
        }
    }

    #[test]
    fn full_text_session_lifecycle_through_dispatch() {
        let state = state_with(|_| {});
        let mut ctx = ConnCtx::default();
        let Response::Opened { id, evicted: None } =
            dispatch(&state, &mut ctx, Request::from_line("open simden 60 3.0").unwrap().unwrap())
            else {
                panic!("open failed")
            };
        let resp = dispatch(
            &state,
            &mut ctx,
            Request::from_line(&format!("recut {id} 0 20 full")).unwrap().unwrap(),
        );
        let Response::Result { clusters, full: Some(f), .. } = resp else {
            panic!("recut failed: {resp:?}")
        };
        assert!(clusters >= 1);
        assert_eq!(f.labels.len(), 60);
        assert!(matches!(
            dispatch(&state, &mut ctx, Request::from_line(&format!("close {id}")).unwrap().unwrap()),
            Response::Closed { .. }
        ));
        // Closing again is a typed error, not a panic.
        assert!(matches!(
            dispatch(&state, &mut ctx, Request::CloseSession { session: id }),
            Response::Error { .. }
        ));
    }

    #[test]
    fn tenant_quota_binds_through_dispatch() {
        let state = state_with(|c| c.max_sessions_per_tenant = 1);
        let mut ctx = ConnCtx::default();
        assert!(matches!(
            dispatch(&state, &mut ctx, Request::Hello { tenant: "acme".into() }),
            Response::Hello { .. }
        ));
        assert!(matches!(dispatch(&state, &mut ctx, open_req("a")), Response::Opened { .. }));
        let resp = dispatch(&state, &mut ctx, open_req("b"));
        let Response::Error { detail } = resp else { panic!("expected quota error, got {resp:?}") };
        assert!(detail.contains("quota"), "{detail}");
        // A different tenant on another connection still gets in.
        let mut other = ConnCtx { tenant: "zen".into() };
        assert!(matches!(dispatch(&state, &mut other, open_req("c")), Response::Opened { .. }));
    }

    #[test]
    fn global_cap_evicts_lru_idle_session() {
        let state = state_with(|c| c.max_open_sessions = 2);
        let mut ctx = ConnCtx::default();
        let Response::Opened { id: first, .. } = dispatch(&state, &mut ctx, open_req("a")) else {
            panic!()
        };
        let Response::Opened { id: second, .. } = dispatch(&state, &mut ctx, open_req("b")) else {
            panic!()
        };
        // Touch the first so the second becomes LRU.
        dispatch(&state, &mut ctx, Request::Recut { session: first, rho_min: 0.0, delta_min: 20.0, full: false });
        let Response::Opened { id: third, evicted: Some(victim) } =
            dispatch(&state, &mut ctx, open_req("c"))
            else {
                panic!("expected eviction")
            };
        assert_eq!(victim, second);
        assert!(state.coord.session(second).is_none(), "evicted session is closed");
        assert!(state.coord.session(first).is_some());
        assert!(state.coord.session(third).is_some());
        assert_eq!(state.coord.metrics.counter("serve_evictions"), 1);
    }

    #[test]
    fn error_mapping_separates_busy_from_failure() {
        // Backpressure (from either admission gate) → Busy: retryable,
        // nothing enqueued. Everything else → Error.
        assert!(matches!(
            err_response(DpcError::Backpressure { in_flight: 4, limit: 4 }),
            Response::Busy { .. }
        ));
        assert!(matches!(err_response(DpcError::UnknownSession(9)), Response::Error { .. }));
        assert!(matches!(
            err_response(DpcError::QuotaExceeded { tenant: "t".into(), open: 1, limit: 1 }),
            Response::Error { .. }
        ));
    }

    #[test]
    fn unknown_dataset_and_unknown_handle_stay_usable() {
        let state = state_with(|_| {});
        let mut ctx = ConnCtx::default();
        let resp = dispatch(
            &state,
            &mut ctx,
            Request::Cluster {
                dataset: "no-such-set".into(),
                n: 10,
                d_cut: 1.0,
                rho_min: 0.0,
                delta_min: 1.0,
                algo: None,
                density: DensityModel::CutoffCount,
                full: false,
            },
        );
        let Response::Error { detail } = resp else { panic!("expected error, got {resp:?}") };
        assert!(detail.contains("unknown dataset"), "{detail}");
        assert!(matches!(
            dispatch(&state, &mut ctx, Request::Recut { session: 404, rho_min: 0.0, delta_min: 1.0, full: false }),
            Response::Error { .. }
        ));
        // The dispatcher still serves after both failures.
        assert!(matches!(dispatch(&state, &mut ctx, open_req("ok")), Response::Opened { .. }));
    }
}
