//! Length-prefixed, CRC-guarded framing for the TCP serve surface.
//!
//! Layout (all little-endian, mirroring the journal's frame discipline):
//!
//! ```text
//! [u32 len] [u32 crc32(payload)] [payload: len bytes]
//! ```
//!
//! where the payload is a `serve::proto` message (`[version][kind][body]`).
//! The taxonomy is the journal's, transplanted to a socket: an
//! *incomplete* frame (header or payload not fully arrived) is normal —
//! keep reading; a frame that is fully present but *invalid* (length over
//! [`MAX_FRAME`], CRC mismatch) is corruption — the connection is broken
//! and must be dropped, because byte-stream framing cannot resynchronize
//! after a bad length.
//!
//! [`FrameBuf`] is the incremental decoder for non-blocking reads: feed
//! it whatever `read()` returned, pull zero or more complete payloads
//! out. It never allocates for a frame until the header passes the size
//! check, so a forged length can't drive a huge reservation.

use crate::durability::crc32::crc32;

/// Hard cap on a single frame's payload. Large enough for a full-result
/// response over millions of points; small enough that a corrupt length
/// field is caught long before `usize`-scale allocation.
pub const MAX_FRAME: u32 = 64 * 1024 * 1024;

/// Frame header size: `u32` length + `u32` CRC.
pub const HEADER: usize = 8;

/// A fully-present-but-invalid frame. Incomplete frames are *not*
/// errors — [`FrameBuf::next_frame`] returns `Ok(None)` for those.
#[derive(Debug, PartialEq)]
pub enum FrameError {
    Oversized { len: u32 },
    CrcMismatch { want: u32, got: u32 },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Oversized { len } => {
                write!(f, "frame claims {len} bytes, over the {MAX_FRAME}-byte cap")
            }
            FrameError::CrcMismatch { want, got } => {
                write!(f, "frame crc mismatch: header says {want:#010x}, payload hashes to {got:#010x}")
            }
        }
    }
}

/// Frame a payload for the wire. The [`MAX_FRAME`] cap is enforced here
/// in every build, not just debug: a peer that decodes by the same rules
/// would drop the connection on an oversized frame, so emitting one is
/// strictly worse than failing locally — the caller downgrades to a
/// small typed-error response instead. (A `debug_assert!` once stood
/// here; release builds of a server with a big enough result set could
/// sail straight past it.)
pub fn encode_frame(payload: &[u8]) -> Result<Vec<u8>, FrameError> {
    if payload.len() > MAX_FRAME as usize {
        // The saturating cast only shapes the error message; the branch
        // itself is the cap.
        return Err(FrameError::Oversized { len: u32::try_from(payload.len()).unwrap_or(u32::MAX) });
    }
    // bounds: the cap check above bounds the reservation at MAX_FRAME.
    let mut out = Vec::with_capacity(HEADER + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    Ok(out)
}

/// Incremental frame decoder over an arbitrary byte-chunk stream.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
    /// Bytes already consumed from the front of `buf` (compacted lazily
    /// so each `feed` is amortized O(chunk)).
    start: usize,
}

impl std::fmt::Debug for FrameBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FrameBuf")
            .field("pending", &self.pending())
            .field("start", &self.start)
            .finish_non_exhaustive()
    }
}

impl FrameBuf {
    pub fn new() -> Self {
        FrameBuf::default()
    }

    /// Append bytes as they arrive from the socket.
    pub fn feed(&mut self, chunk: &[u8]) {
        // Compact before growing: keeps the buffer bounded by one frame
        // plus one read chunk instead of the whole connection history.
        if self.start > 0 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(chunk);
    }

    /// Bytes buffered but not yet consumed (incomplete-frame detection:
    /// a connection that closes with `pending() > 0` died mid-frame).
    pub fn pending(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Pull the next complete payload, if one has fully arrived.
    /// `Ok(None)` = need more bytes; `Err` = the stream is corrupt and
    /// the connection must be dropped.
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>, FrameError> {
        // bounds: `start <= buf.len()` is a struct invariant — it only
        // advances by `total` after proving that many bytes are buffered.
        let avail = &self.buf[self.start..];
        if avail.len() < HEADER {
            return Ok(None);
        }
        // bounds: the HEADER guard above proves at least 8 bytes remain.
        let len = u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]);
        if len > MAX_FRAME {
            return Err(FrameError::Oversized { len });
        }
        // bounds: same HEADER guard covers offsets 4..8.
        let want_crc = u32::from_le_bytes([avail[4], avail[5], avail[6], avail[7]]);
        let total = HEADER + len as usize;
        if avail.len() < total {
            return Ok(None);
        }
        // bounds: the avail.len() < total return above proves the slice.
        let payload = &avail[HEADER..total];
        let got = crc32(payload);
        if got != want_crc {
            return Err(FrameError::CrcMismatch { want: want_crc, got });
        }
        // bounds: len cleared the MAX_FRAME cap before we buffered this
        // much, so the copy is at most MAX_FRAME bytes of checksummed data.
        let out = payload.to_vec();
        self.start += total;
        Ok(Some(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_round_trip_through_arbitrary_chunking() {
        let payloads: Vec<Vec<u8>> = vec![vec![], vec![1], vec![2; 1000], (0..=255).collect()];
        let mut stream = Vec::new();
        for p in &payloads {
            stream.extend_from_slice(&encode_frame(p).unwrap());
        }
        // Feed in pathological chunk sizes: 1 byte at a time, then 7s.
        for chunk in [1usize, 7] {
            let mut fb = FrameBuf::new();
            let mut got = Vec::new();
            for c in stream.chunks(chunk) {
                fb.feed(c);
                while let Some(p) = fb.next_frame().unwrap() {
                    got.push(p);
                }
            }
            assert_eq!(got, payloads, "chunk size {chunk}");
            assert_eq!(fb.pending(), 0);
        }
    }

    #[test]
    fn encode_enforces_the_cap_at_the_boundary() {
        // Exactly at the cap: allowed.
        let at_cap = vec![0u8; MAX_FRAME as usize];
        let framed = encode_frame(&at_cap).unwrap();
        assert_eq!(framed.len(), HEADER + MAX_FRAME as usize);
        // One byte over: a typed error in RELEASE builds too — this is
        // the regression test for the debug_assert!-only cap.
        let over = vec![0u8; MAX_FRAME as usize + 1];
        assert_eq!(encode_frame(&over).unwrap_err(), FrameError::Oversized { len: MAX_FRAME + 1 });
    }

    #[test]
    fn truncated_frame_is_incomplete_not_corrupt() {
        let frame = encode_frame(&[1, 2, 3, 4]).unwrap();
        let mut fb = FrameBuf::new();
        fb.feed(&frame[..frame.len() - 1]);
        assert_eq!(fb.next_frame().unwrap(), None, "torn tail: wait for more bytes");
        assert_eq!(fb.pending(), frame.len() - 1);
        fb.feed(&frame[frame.len() - 1..]);
        assert_eq!(fb.next_frame().unwrap().unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn oversized_length_is_rejected_before_buffering_payload() {
        let mut fb = FrameBuf::new();
        let mut header = (MAX_FRAME + 1).to_le_bytes().to_vec();
        header.extend_from_slice(&[0; 4]);
        fb.feed(&header);
        assert!(matches!(fb.next_frame(), Err(FrameError::Oversized { .. })));
    }

    #[test]
    fn flipped_bit_is_crc_mismatch() {
        let mut frame = encode_frame(&[9, 9, 9]).unwrap();
        let last = frame.len() - 1;
        frame[last] ^= 0x01;
        let mut fb = FrameBuf::new();
        fb.feed(&frame);
        assert!(matches!(fb.next_frame(), Err(FrameError::CrcMismatch { .. })));
    }

    #[test]
    fn corrupt_header_crc_is_mismatch_too() {
        let mut frame = encode_frame(&[5; 16]).unwrap();
        frame[4] ^= 0xFF;
        let mut fb = FrameBuf::new();
        fb.feed(&frame);
        assert!(matches!(fb.next_frame(), Err(FrameError::CrcMismatch { .. })));
    }
}
