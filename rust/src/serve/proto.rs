//! The one request/response vocabulary every serve surface speaks.
//!
//! The stdin loop and the TCP front end used to be a risk of drifting
//! into two dialects; instead both parse into [`Request`] and render
//! [`Response`] — the text grammar ([`Request::from_line`] /
//! [`Request::to_line`]) and the binary codec ([`Request::encode`] /
//! [`Request::decode`]) are two skins over the same types, dispatched by
//! the same function (`serve::dispatch`). The equivalence is enforced by
//! property tests: for every line-expressible request,
//! `from_line(to_line(r)) == r` and `decode(encode(r)) == r`.
//!
//! Binary bodies reuse the durability layer's bounds-checked
//! [`wire`] codecs, so the serve protocol inherits the journal's
//! total-decoding discipline: every length is validated against the
//! bytes present before allocation, unknown tags are typed errors, and
//! trailing bytes inside a frame are corruption. Floats travel as raw
//! bit patterns (exactness is the repo's contract) and print via Rust's
//! shortest-round-trip `Display`, so the text surface is exactly as
//! lossless as the binary one.
//!
//! Versioning: every message starts with its protocol version; encoders
//! emit [`PROTO_VERSION`], decoders accept any version back to
//! [`MIN_PROTO_VERSION`] and fill the fields that version could not
//! express with its implied defaults (a v1 `OpenStream` is f64, a v1
//! point batch is f64, a v1 `CheckpointTaken` came from the
//! single-journal layout, segment 1). Kind tags and field layouts are
//! append-only within a version, like the journal's.
//!
//! v1 → v2: `OpenStream` gained a dtype tag (f32 streams), point
//! batches became dtype-tagged [`DynPoints`], and `CheckpointTaken`
//! gained `journal_seq` (the segmented journal's replay-horizon
//! segment).

use crate::coordinator::config::parse_dep_algo;
use crate::dpc::{DensityModel, DepAlgo};
use crate::durability::wire::{self, Cursor};
use crate::geom::{Dtype, DynPoints};

/// The version encoders speak. Bumped on any layout change; decoders
/// stay compatible back to [`MIN_PROTO_VERSION`].
pub const PROTO_VERSION: u8 = 2;

/// Oldest version decoders still accept (filling v1's missing fields
/// with their implied defaults).
pub const MIN_PROTO_VERSION: u8 = 1;

/// Everything a serve client can ask for. One enum for all surfaces;
/// [`Request::IngestPoints`] (a raw coordinate batch) is binary-only,
/// everything else round-trips through the line grammar too.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Bind this connection to a tenant id (admission quotas key on it).
    Hello { tenant: String },
    /// One-shot full pipeline over a named dataset.
    Cluster {
        dataset: String,
        n: u64,
        d_cut: f64,
        rho_min: f64,
        delta_min: f64,
        algo: Option<DepAlgo>,
        density: DensityModel,
        full: bool,
    },
    /// Open a cached session over a named dataset.
    OpenSession { dataset: String, n: u64, d_cut: f64, density: DensityModel, tag: String },
    /// Linkage-only re-cut of an open session.
    Recut { session: u64, rho_min: f64, delta_min: f64, full: bool },
    CloseSession { session: u64 },
    /// Open a streaming session. `dtype` fixes the coordinate precision
    /// for the stream's whole life; every ingested batch must match.
    OpenStream { dim: u32, d_cut: f64, density: DensityModel, tag: String, dtype: Dtype },
    /// Ingest a batch drawn from a named dataset generator.
    Ingest { stream: u64, dataset: String, n: u64, seed: u64, rho_min: f64, delta_min: f64, full: bool },
    /// Ingest a client-supplied coordinate batch (binary-only: points
    /// have no lossless whitespace-token form). The batch is
    /// dtype-tagged on the wire; a mismatch against the stream's dtype
    /// is a typed server-side error, not a silent cast.
    IngestPoints { stream: u64, batch: DynPoints, rho_min: f64, delta_min: f64, full: bool },
    CloseStream { stream: u64 },
    /// Durable mode: snapshot state now.
    Checkpoint,
}

/// Full per-point arrays, shipped only when a request asked for `full`
/// (they dominate the response size). `dep` uses `u32::MAX` as the
/// "no dependent" sentinel — point counts are bounded far below it.
#[derive(Clone, Debug, PartialEq)]
pub struct FullResult {
    pub rho: Vec<u32>,
    pub dep: Vec<u32>,
    pub delta: Vec<f64>,
    pub labels: Vec<i64>,
    pub centers: Vec<u32>,
}

impl FullResult {
    pub fn from_result(r: &crate::dpc::DpcResult) -> Self {
        FullResult {
            rho: r.rho.clone(),
            dep: r.dep.iter().map(|d| d.map_or(u32::MAX, |v| v)).collect(),
            delta: r.delta.clone(),
            labels: r.labels.clone(),
            centers: r.centers.clone(),
        }
    }
}

/// Exactly one [`Response`] per [`Request`], in request order.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Hello { tenant: String },
    /// A session or stream open succeeded (possibly after an LRU
    /// eviction, reported in `evicted`).
    Opened { id: u64, evicted: Option<u64> },
    /// A cluster/recut/ingest job completed.
    Result {
        job: u64,
        tag: String,
        backend: String,
        clusters: u64,
        noise: u64,
        wall_s: f64,
        full: Option<FullResult>,
    },
    Closed { id: u64 },
    /// `journal_seq`/`journal_offset` name the segmented journal's
    /// replay horizon — every segment strictly below `journal_seq` is
    /// GC-eligible once this manifest is durable.
    CheckpointTaken { seq: u64, journal_seq: u64, journal_offset: u64, next_lsn: u64 },
    /// Admission control: back off and retry (nothing was enqueued).
    Busy { detail: String },
    /// The request failed; the connection stays usable.
    Error { detail: String },
}

// ---------------------------------------------------------------------------
// Binary codec
// ---------------------------------------------------------------------------

fn put_bool(out: &mut Vec<u8>, v: bool) {
    out.push(v as u8);
}

fn get_bool(cur: &mut Cursor<'_>) -> Result<bool, String> {
    match cur.u8()? {
        0 => Ok(false),
        1 => Ok(true),
        other => Err(format!("bool field carries {other} (want 0 or 1)")),
    }
}

/// `0` = None, else 1 + position in [`DepAlgo::ALL`] (append-only order).
fn put_algo(out: &mut Vec<u8>, algo: Option<DepAlgo>) {
    let tag = match algo {
        None => 0u8,
        // lint: allow(panic-surface) — DepAlgo::ALL enumerates every
        // variant by construction; position always finds a match.
        Some(a) => 1 + DepAlgo::ALL.iter().position(|x| *x == a).expect("algo in ALL") as u8,
    };
    out.push(tag);
}

fn get_algo(cur: &mut Cursor<'_>) -> Result<Option<DepAlgo>, String> {
    match cur.u8()? {
        0 => Ok(None),
        i if (i as usize) <= DepAlgo::ALL.len() => Ok(Some(DepAlgo::ALL[i as usize - 1])),
        other => Err(format!("unknown dep-algo tag {other}")),
    }
}

/// Dtype travels as its `size_bytes` tag, the same self-describing byte
/// the point-batch codec and the dataset binary header use.
fn put_dtype(out: &mut Vec<u8>, dtype: Dtype) {
    out.push(dtype.size_bytes() as u8);
}

fn get_dtype(cur: &mut Cursor<'_>) -> Result<Dtype, String> {
    let tag = cur.u8()?;
    Dtype::from_tag(tag).ok_or_else(|| format!("unknown dtype tag {tag} (want 4 or 8)"))
}

/// Detail strings are operator-facing; clamp so a pathological error
/// message can never push a frame past the decoder's string bound.
fn put_detail(out: &mut Vec<u8>, s: &str) {
    let clamped: String = s.chars().take(1024).collect();
    wire::put_str(out, &clamped);
}

/// Returns the message's version so decoders can fill fields a v1 peer
/// could not express. Note no single-bit flip of the current version
/// byte (2) lands on the other accepted version (1), so corruption
/// cannot silently downgrade a message.
fn check_version(cur: &mut Cursor<'_>) -> Result<u8, String> {
    let v = cur.u8()?;
    if !(MIN_PROTO_VERSION..=PROTO_VERSION).contains(&v) {
        return Err(format!(
            "protocol version {v} (this build speaks {MIN_PROTO_VERSION}..={PROTO_VERSION})"
        ));
    }
    Ok(v)
}

impl Request {
    /// `[version][kind][body]` — framing (length + CRC) is `serve::frame`'s
    /// job, not the message codec's.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = vec![PROTO_VERSION];
        match self {
            Request::Hello { tenant } => {
                out.push(0);
                wire::put_str(&mut out, tenant);
            }
            Request::Cluster { dataset, n, d_cut, rho_min, delta_min, algo, density, full } => {
                out.push(1);
                wire::put_str(&mut out, dataset);
                wire::put_u64(&mut out, *n);
                wire::put_f64(&mut out, *d_cut);
                wire::put_f64(&mut out, *rho_min);
                wire::put_f64(&mut out, *delta_min);
                put_algo(&mut out, *algo);
                wire::put_density(&mut out, *density);
                put_bool(&mut out, *full);
            }
            Request::OpenSession { dataset, n, d_cut, density, tag } => {
                out.push(2);
                wire::put_str(&mut out, dataset);
                wire::put_u64(&mut out, *n);
                wire::put_f64(&mut out, *d_cut);
                wire::put_density(&mut out, *density);
                wire::put_str(&mut out, tag);
            }
            Request::Recut { session, rho_min, delta_min, full } => {
                out.push(3);
                wire::put_u64(&mut out, *session);
                wire::put_f64(&mut out, *rho_min);
                wire::put_f64(&mut out, *delta_min);
                put_bool(&mut out, *full);
            }
            Request::CloseSession { session } => {
                out.push(4);
                wire::put_u64(&mut out, *session);
            }
            Request::OpenStream { dim, d_cut, density, tag, dtype } => {
                out.push(5);
                wire::put_u32(&mut out, *dim);
                wire::put_f64(&mut out, *d_cut);
                wire::put_density(&mut out, *density);
                wire::put_str(&mut out, tag);
                // v2 appended field: v1 ended at the tag string.
                put_dtype(&mut out, *dtype);
            }
            Request::Ingest { stream, dataset, n, seed, rho_min, delta_min, full } => {
                out.push(6);
                wire::put_u64(&mut out, *stream);
                wire::put_str(&mut out, dataset);
                wire::put_u64(&mut out, *n);
                wire::put_u64(&mut out, *seed);
                wire::put_f64(&mut out, *rho_min);
                wire::put_f64(&mut out, *delta_min);
                put_bool(&mut out, *full);
            }
            Request::IngestPoints { stream, batch, rho_min, delta_min, full } => {
                out.push(7);
                wire::put_u64(&mut out, *stream);
                // put_store leads with the dtype tag, so an f64 batch is
                // byte-identical to the v1 encoding of the same batch.
                match batch {
                    DynPoints::F32(p) => wire::put_store(&mut out, p),
                    DynPoints::F64(p) => wire::put_store(&mut out, p),
                }
                wire::put_f64(&mut out, *rho_min);
                wire::put_f64(&mut out, *delta_min);
                put_bool(&mut out, *full);
            }
            Request::CloseStream { stream } => {
                out.push(8);
                wire::put_u64(&mut out, *stream);
            }
            Request::Checkpoint => out.push(9),
        }
        out
    }

    /// Total decode: bounds-checked, version-checked, and trailing bytes
    /// inside the message are an error (the frame already delimited it).
    /// v1 messages decode with their implied defaults (f64 everywhere,
    /// journal segment 1).
    pub fn decode(buf: &[u8]) -> Result<Request, String> {
        let mut cur = Cursor::new(buf);
        let v = check_version(&mut cur)?;
        let kind = cur.u8()?;
        let req = match kind {
            0 => Request::Hello { tenant: wire::get_str(&mut cur)? },
            1 => Request::Cluster {
                dataset: wire::get_str(&mut cur)?,
                n: cur.u64()?,
                d_cut: cur.f64()?,
                rho_min: cur.f64()?,
                delta_min: cur.f64()?,
                algo: get_algo(&mut cur)?,
                density: wire::get_density(&mut cur)?,
                full: get_bool(&mut cur)?,
            },
            2 => Request::OpenSession {
                dataset: wire::get_str(&mut cur)?,
                n: cur.u64()?,
                d_cut: cur.f64()?,
                density: wire::get_density(&mut cur)?,
                tag: wire::get_str(&mut cur)?,
            },
            3 => Request::Recut {
                session: cur.u64()?,
                rho_min: cur.f64()?,
                delta_min: cur.f64()?,
                full: get_bool(&mut cur)?,
            },
            4 => Request::CloseSession { session: cur.u64()? },
            5 => Request::OpenStream {
                dim: cur.u32()?,
                d_cut: cur.f64()?,
                density: wire::get_density(&mut cur)?,
                tag: wire::get_str(&mut cur)?,
                // v1 could only open f64 streams.
                dtype: if v >= 2 { get_dtype(&mut cur)? } else { Dtype::F64 },
            },
            6 => Request::Ingest {
                stream: cur.u64()?,
                dataset: wire::get_str(&mut cur)?,
                n: cur.u64()?,
                seed: cur.u64()?,
                rho_min: cur.f64()?,
                delta_min: cur.f64()?,
                full: get_bool(&mut cur)?,
            },
            7 => {
                let stream = cur.u64()?;
                let batch = wire::get_points(&mut cur)?;
                // The batch codec is self-describing in both versions,
                // but a v1 peer's contract was f64-only — hold it to it.
                if v < 2 && batch.dtype() != Dtype::F64 {
                    return Err(format!(
                        "{} point batch in a v{v} message (dtypes need v2)",
                        batch.dtype()
                    ));
                }
                Request::IngestPoints {
                    stream,
                    batch,
                    rho_min: cur.f64()?,
                    delta_min: cur.f64()?,
                    full: get_bool(&mut cur)?,
                }
            }
            8 => Request::CloseStream { stream: cur.u64()? },
            9 => Request::Checkpoint,
            other => return Err(format!("unknown request kind {other}")),
        };
        cur.expect_end("request")?;
        Ok(req)
    }

    // -----------------------------------------------------------------
    // Line grammar (the stdin surface, and loadgen's script format)
    // -----------------------------------------------------------------

    /// Parse one text line. `Ok(None)` for blanks and `#` comments;
    /// `Err` never kills a serve loop (the caller reports and continues).
    ///
    /// Trailing optional tokens are resolved by *what parses*, not by
    /// position: a dep-algo name, a density-model name, a dtype name,
    /// `tag=<label>`, and the literal `full` can appear in any order
    /// after the required fields (their vocabularies are disjoint).
    pub fn from_line(line: &str) -> Result<Option<Request>, String> {
        let t = line.split('#').next().unwrap_or("").trim();
        if t.is_empty() {
            return Ok(None);
        }
        let parts: Vec<&str> = t.split_whitespace().collect();
        let req = match parts[0] {
            "hello" => {
                let &[_, tenant] = parts.as_slice() else {
                    return Err(format!("want `hello <tenant>`, got {t:?}"));
                };
                Request::Hello { tenant: tenant.to_string() }
            }
            "open" => {
                if parts.len() < 4 {
                    return Err(format!("want `open <dataset> <n> <d_cut> [density] [tag=T]`, got {t:?}"));
                }
                let n = parse_num::<u64>("n", parts[2])?;
                let d_cut = parse_num::<f64>("d_cut", parts[3])?;
                let tr = parse_trailing(&parts[4..])?;
                Request::OpenSession {
                    dataset: parts[1].to_string(),
                    n,
                    d_cut,
                    density: tr.density,
                    tag: tr.tag,
                }
            }
            "recut" => {
                if parts.len() < 4 {
                    return Err(format!("want `recut <session> <rho_min> <delta_min> [full]`, got {t:?}"));
                }
                let session = parse_num::<u64>("session", parts[1])?;
                let rho_min = parse_num::<f64>("rho_min", parts[2])?;
                let delta_min = parse_num::<f64>("delta_min", parts[3])?;
                let tr = parse_trailing(&parts[4..])?;
                Request::Recut { session, rho_min, delta_min, full: tr.full }
            }
            "close" => {
                let &[_, sid] = parts.as_slice() else {
                    return Err(format!("want `close <session>`, got {t:?}"));
                };
                Request::CloseSession { session: parse_num::<u64>("session", sid)? }
            }
            "stream" => {
                if parts.len() < 3 {
                    return Err(format!(
                        "want `stream <dim> <d_cut> [density] [f32|f64] [tag=T]`, got {t:?}"
                    ));
                }
                let dim = parse_num::<u32>("dim", parts[1])?;
                let d_cut = parse_num::<f64>("d_cut", parts[2])?;
                let tr = parse_trailing(&parts[3..])?;
                Request::OpenStream {
                    dim,
                    d_cut,
                    density: tr.density,
                    tag: tr.tag,
                    dtype: tr.dtype.unwrap_or(Dtype::F64),
                }
            }
            "ingest" => {
                if parts.len() < 6 {
                    return Err(format!(
                        "want `ingest <stream> <dataset> <n> <rho_min> <delta_min> [seed=S] [full]`, got {t:?}"
                    ));
                }
                let stream = parse_num::<u64>("stream", parts[1])?;
                let n = parse_num::<u64>("n", parts[3])?;
                let rho_min = parse_num::<f64>("rho_min", parts[4])?;
                let delta_min = parse_num::<f64>("delta_min", parts[5])?;
                let tr = parse_trailing(&parts[6..])?;
                Request::Ingest {
                    stream,
                    dataset: parts[2].to_string(),
                    n,
                    seed: tr.seed.unwrap_or(42),
                    rho_min,
                    delta_min,
                    full: tr.full,
                }
            }
            "closestream" => {
                let &[_, sid] = parts.as_slice() else {
                    return Err(format!("want `closestream <stream>`, got {t:?}"));
                };
                Request::CloseStream { stream: parse_num::<u64>("stream", sid)? }
            }
            "checkpoint" => {
                if parts.len() > 2 || (parts.len() == 2 && parts[1] != "now") {
                    return Err(format!("want `checkpoint [now]`, got {t:?}"));
                }
                Request::Checkpoint
            }
            dataset => {
                if parts.len() < 5 {
                    return Err(format!(
                        "want `<dataset> <n> <d_cut> <rho_min> <delta_min> [algo] [density] [full]`, got {t:?}"
                    ));
                }
                let n = parse_num::<u64>("n", parts[1])?;
                let d_cut = parse_num::<f64>("d_cut", parts[2])?;
                let rho_min = parse_num::<f64>("rho_min", parts[3])?;
                let delta_min = parse_num::<f64>("delta_min", parts[4])?;
                let mut algo = None;
                let mut density = DensityModel::CutoffCount;
                let mut full = false;
                for tok in &parts[5..] {
                    if *tok == "full" {
                        full = true;
                    } else if let Ok(a) = parse_dep_algo(tok) {
                        algo = Some(a);
                    } else if let Ok(m) = tok.parse::<DensityModel>() {
                        density = m;
                    } else {
                        return Err(format!("unknown job option {tok:?} (algo, density, or `full`)"));
                    }
                }
                Request::Cluster { dataset: dataset.to_string(), n, d_cut, rho_min, delta_min, algo, density, full }
            }
        };
        Ok(Some(req))
    }

    /// Canonical text rendering; `None` for binary-only requests.
    /// `from_line(to_line(r).unwrap()) == r` for every `Some` — Rust's
    /// `f64` `Display` is shortest-round-trip, so no precision is lost.
    pub fn to_line(&self) -> Option<String> {
        let line = match self {
            Request::Hello { tenant } => format!("hello {tenant}"),
            Request::Cluster { dataset, n, d_cut, rho_min, delta_min, algo, density, full } => {
                let mut s = format!("{dataset} {n} {d_cut} {rho_min} {delta_min}");
                if let Some(a) = algo {
                    s.push_str(&format!(" {}", a.name()));
                }
                if *density != DensityModel::CutoffCount {
                    s.push_str(&format!(" {density}"));
                }
                if *full {
                    s.push_str(" full");
                }
                s
            }
            Request::OpenSession { dataset, n, d_cut, density, tag } => {
                let mut s = format!("open {dataset} {n} {d_cut}");
                if *density != DensityModel::CutoffCount {
                    s.push_str(&format!(" {density}"));
                }
                if !tag.is_empty() {
                    s.push_str(&format!(" tag={tag}"));
                }
                s
            }
            Request::Recut { session, rho_min, delta_min, full } => {
                let mut s = format!("recut {session} {rho_min} {delta_min}");
                if *full {
                    s.push_str(" full");
                }
                s
            }
            Request::CloseSession { session } => format!("close {session}"),
            Request::OpenStream { dim, d_cut, density, tag, dtype } => {
                let mut s = format!("stream {dim} {d_cut}");
                if *density != DensityModel::CutoffCount {
                    s.push_str(&format!(" {density}"));
                }
                if *dtype != Dtype::F64 {
                    s.push_str(&format!(" {dtype}"));
                }
                if !tag.is_empty() {
                    s.push_str(&format!(" tag={tag}"));
                }
                s
            }
            Request::Ingest { stream, dataset, n, seed, rho_min, delta_min, full } => {
                let mut s = format!("ingest {stream} {dataset} {n} {rho_min} {delta_min} seed={seed}");
                if *full {
                    s.push_str(" full");
                }
                s
            }
            Request::IngestPoints { .. } => return None,
            Request::CloseStream { stream } => format!("closestream {stream}"),
            Request::Checkpoint => "checkpoint".to_string(),
        };
        Some(line)
    }
}

fn parse_num<T: std::str::FromStr>(name: &str, tok: &str) -> Result<T, String> {
    tok.parse::<T>().map_err(|_| format!("non-numeric {name}: {tok:?}"))
}

/// What the shared trailing-token parser collected.
struct Trailing {
    density: DensityModel,
    tag: String,
    full: bool,
    seed: Option<u64>,
    dtype: Option<Dtype>,
}

/// Shared trailing-token parser: `[density] [f32|f64] [tag=T] [seed=S]
/// [full]` in any order — the vocabularies are disjoint ("f32"/"f64"
/// name no density model). Commands that take no dtype simply ignore a
/// parsed one, the same stance the grammar already takes on densities
/// in `recut`.
fn parse_trailing(toks: &[&str]) -> Result<Trailing, String> {
    let mut tr = Trailing {
        density: DensityModel::CutoffCount,
        tag: String::new(),
        full: false,
        seed: None,
        dtype: None,
    };
    for tok in toks {
        if *tok == "full" {
            tr.full = true;
        } else if let Some(t) = tok.strip_prefix("tag=") {
            tr.tag = t.to_string();
        } else if let Some(s) = tok.strip_prefix("seed=") {
            tr.seed = Some(parse_num::<u64>("seed", s)?);
        } else if let Ok(d) = tok.parse::<Dtype>() {
            tr.dtype = Some(d);
        } else if let Ok(m) = tok.parse::<DensityModel>() {
            tr.density = m;
        } else {
            return Err(format!(
                "unknown option {tok:?} (density, f32|f64, tag=T, seed=S, or `full`)"
            ));
        }
    }
    Ok(tr)
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = vec![PROTO_VERSION];
        match self {
            Response::Hello { tenant } => {
                out.push(0);
                wire::put_str(&mut out, tenant);
            }
            Response::Opened { id, evicted } => {
                out.push(1);
                wire::put_u64(&mut out, *id);
                match evicted {
                    None => out.push(0),
                    Some(e) => {
                        out.push(1);
                        wire::put_u64(&mut out, *e);
                    }
                }
            }
            Response::Result { job, tag, backend, clusters, noise, wall_s, full } => {
                out.push(2);
                wire::put_u64(&mut out, *job);
                wire::put_str(&mut out, tag);
                wire::put_str(&mut out, backend);
                wire::put_u64(&mut out, *clusters);
                wire::put_u64(&mut out, *noise);
                wire::put_f64(&mut out, *wall_s);
                match full {
                    None => out.push(0),
                    Some(f) => {
                        out.push(1);
                        wire::put_u32_slice(&mut out, &f.rho);
                        wire::put_u32_slice(&mut out, &f.dep);
                        wire::put_f64_slice(&mut out, &f.delta);
                        wire::put_i64_slice(&mut out, &f.labels);
                        wire::put_u32_slice(&mut out, &f.centers);
                    }
                }
            }
            Response::Closed { id } => {
                out.push(3);
                wire::put_u64(&mut out, *id);
            }
            Response::CheckpointTaken { seq, journal_seq, journal_offset, next_lsn } => {
                out.push(4);
                wire::put_u64(&mut out, *seq);
                wire::put_u64(&mut out, *journal_offset);
                wire::put_u64(&mut out, *next_lsn);
                // v2 appended field: v1 ended at next_lsn.
                wire::put_u64(&mut out, *journal_seq);
            }
            Response::Busy { detail } => {
                out.push(5);
                put_detail(&mut out, detail);
            }
            Response::Error { detail } => {
                out.push(6);
                put_detail(&mut out, detail);
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Response, String> {
        let mut cur = Cursor::new(buf);
        let v = check_version(&mut cur)?;
        let kind = cur.u8()?;
        let resp = match kind {
            0 => Response::Hello { tenant: wire::get_str(&mut cur)? },
            1 => Response::Opened {
                id: cur.u64()?,
                evicted: match get_bool(&mut cur)? {
                    false => None,
                    true => Some(cur.u64()?),
                },
            },
            2 => Response::Result {
                job: cur.u64()?,
                tag: wire::get_str(&mut cur)?,
                backend: wire::get_str(&mut cur)?,
                clusters: cur.u64()?,
                noise: cur.u64()?,
                wall_s: cur.f64()?,
                full: match get_bool(&mut cur)? {
                    false => None,
                    true => Some(FullResult {
                        rho: wire::get_u32_vec(&mut cur)?,
                        dep: wire::get_u32_vec(&mut cur)?,
                        delta: wire::get_f64_vec(&mut cur)?,
                        labels: wire::get_i64_vec(&mut cur)?,
                        centers: wire::get_u32_vec(&mut cur)?,
                    }),
                },
            },
            3 => Response::Closed { id: cur.u64()? },
            4 => {
                let seq = cur.u64()?;
                let journal_offset = cur.u64()?;
                let next_lsn = cur.u64()?;
                // A v1 server ran the single-journal layout: segment 1.
                let journal_seq = if v >= 2 { cur.u64()? } else { 1 };
                Response::CheckpointTaken { seq, journal_seq, journal_offset, next_lsn }
            }
            5 => Response::Busy { detail: wire::get_str(&mut cur)? },
            6 => Response::Error { detail: wire::get_str(&mut cur)? },
            other => return Err(format!("unknown response kind {other}")),
        };
        cur.expect_end("response")?;
        Ok(resp)
    }

    /// Human rendering for the stdin surface (full arrays are summarized
    /// — the text surface is for operators, the binary one for bytes).
    pub fn to_line(&self) -> String {
        match self {
            Response::Hello { tenant } => format!("hello: tenant {tenant:?}"),
            Response::Opened { id, evicted: None } => format!("opened {id}"),
            Response::Opened { id, evicted: Some(e) } => format!("opened {id} (evicted idle session {e})"),
            Response::Result { job, tag, backend, clusters, noise, wall_s, full } => {
                let mut s = format!(
                    "job {job}: tag={tag} backend={backend} clusters={clusters} noise={noise} wall={}",
                    crate::bench::fmt_secs(*wall_s)
                );
                if let Some(f) = full {
                    s.push_str(&format!(" points={}", f.labels.len()));
                }
                s
            }
            Response::Closed { id } => format!("closed {id}"),
            Response::CheckpointTaken { seq, journal_seq, journal_offset, next_lsn } => {
                format!(
                    "checkpoint {seq} taken (journal segment {journal_seq} offset {journal_offset}, next lsn {next_lsn})"
                )
            }
            Response::Busy { detail } => format!("busy: {detail}"),
            Response::Error { detail } => format!("error: {detail}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::{PointSet, PointStore};

    #[test]
    fn line_grammar_round_trips() {
        let reqs = [
            Request::Hello { tenant: "acme".into() },
            Request::Cluster {
                dataset: "simden".into(),
                n: 500,
                d_cut: 3.5,
                rho_min: 0.0,
                delta_min: f64::INFINITY,
                algo: Some(DepAlgo::Fenwick),
                density: DensityModel::KnnRadius { k: 8 },
                full: true,
            },
            Request::OpenSession {
                dataset: "varden".into(),
                n: 200,
                d_cut: 0.1,
                density: DensityModel::GaussianKernel,
                tag: "t1".into(),
            },
            Request::Recut { session: 7, rho_min: 2.5, delta_min: 10.0, full: false },
            Request::CloseSession { session: 7 },
            Request::OpenStream {
                dim: 3,
                d_cut: 2.0,
                density: DensityModel::CutoffCount,
                tag: String::new(),
                dtype: Dtype::F64,
            },
            Request::OpenStream {
                dim: 4,
                d_cut: 1.5,
                density: DensityModel::GaussianKernel,
                tag: "sensors".into(),
                dtype: Dtype::F32,
            },
            Request::Ingest {
                stream: 9,
                dataset: "simden".into(),
                n: 100,
                seed: 7,
                rho_min: 0.5,
                delta_min: 20.0,
                full: true,
            },
            Request::CloseStream { stream: 9 },
            Request::Checkpoint,
        ];
        for req in reqs {
            let line = req.to_line().expect("line-expressible");
            let back = Request::from_line(&line).unwrap().unwrap();
            assert_eq!(back, req, "line {line:?}");
        }
    }

    #[test]
    fn comments_and_blanks_are_none() {
        assert_eq!(Request::from_line("").unwrap(), None);
        assert_eq!(Request::from_line("  # job list").unwrap(), None);
        assert_eq!(
            Request::from_line("close 3 # drop it").unwrap(),
            Some(Request::CloseSession { session: 3 })
        );
    }

    #[test]
    fn malformed_lines_are_errors_not_panics() {
        for line in [
            "open onlyname",
            "recut notanumber 0 1",
            "close",
            "stream 2",
            "ingest 1 ds 10 0",
            "checkpoint later",
            "simden 100 3.0 0",
            "simden 100 3.0 0 20 bogus-option",
            "open ds 10 1.0 notadensity",
            "stream 2 1.0 f16",
        ] {
            assert!(Request::from_line(line).is_err(), "{line:?} should fail");
        }
    }

    #[test]
    fn ingest_points_round_trips_both_dtypes() {
        let f64_req = Request::IngestPoints {
            stream: 1,
            batch: DynPoints::F64(PointSet::new(vec![0.0, 0.0], 2)),
            rho_min: 0.0,
            delta_min: 1.0,
            full: false,
        };
        assert_eq!(f64_req.to_line(), None, "binary-only");
        assert_eq!(Request::decode(&f64_req.encode()).unwrap(), f64_req);
        let f32_req = Request::IngestPoints {
            stream: 2,
            batch: DynPoints::F32(PointStore::new(vec![1.0f32, 2.0, 3.0, 4.0], 2)),
            rho_min: 0.5,
            delta_min: 2.0,
            full: true,
        };
        assert_eq!(Request::decode(&f32_req.encode()).unwrap(), f32_req);
    }

    #[test]
    fn responses_round_trip_binary() {
        let resps = [
            Response::Hello { tenant: "t".into() },
            Response::Opened { id: 3, evicted: None },
            Response::Opened { id: 4, evicted: Some(1) },
            Response::Result {
                job: 11,
                tag: "simden".into(),
                backend: "rust-tree".into(),
                clusters: 2,
                noise: 5,
                wall_s: 0.125,
                full: Some(FullResult {
                    rho: vec![3, 1],
                    dep: vec![u32::MAX, 0],
                    delta: vec![f64::INFINITY, 0.5],
                    labels: vec![0, -1],
                    centers: vec![0],
                }),
            },
            Response::Closed { id: 3 },
            Response::CheckpointTaken { seq: 3, journal_seq: 2, journal_offset: 640, next_lsn: 9 },
            Response::Busy { detail: "64 jobs in flight".into() },
            Response::Error { detail: "unknown session 5".into() },
        ];
        for resp in resps {
            assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
        }
    }

    // v1 compatibility: a v2 message body truncated at v1's last field,
    // with the version byte rewritten, is exactly what a v1 peer sends.
    #[test]
    fn v1_messages_still_decode_with_their_implied_defaults() {
        // OpenStream: v1 ended at the tag string (no dtype byte).
        let v2 = Request::OpenStream {
            dim: 3,
            d_cut: 2.0,
            density: DensityModel::CutoffCount,
            tag: "old".into(),
            dtype: Dtype::F64,
        }
        .encode();
        let mut v1 = v2[..v2.len() - 1].to_vec();
        v1[0] = 1;
        let Request::OpenStream { dtype, tag, .. } = Request::decode(&v1).unwrap() else {
            panic!("wrong kind")
        };
        assert_eq!(dtype, Dtype::F64, "v1 streams are implicitly f64");
        assert_eq!(tag, "old");

        // IngestPoints with an f64 batch: byte-identical body, only the
        // version byte differs.
        let req = Request::IngestPoints {
            stream: 5,
            batch: DynPoints::F64(PointSet::new(vec![1.0, 2.0], 2)),
            rho_min: 0.0,
            delta_min: 1.0,
            full: false,
        };
        let mut v1 = req.encode();
        v1[0] = 1;
        assert_eq!(Request::decode(&v1).unwrap(), req);

        // CheckpointTaken: v1 ended at next_lsn; journal_seq defaults to
        // the single-journal layout's only segment.
        let v2 = Response::CheckpointTaken { seq: 2, journal_seq: 1, journal_offset: 99, next_lsn: 7 }
            .encode();
        let mut v1 = v2[..v2.len() - 8].to_vec();
        v1[0] = 1;
        assert_eq!(
            Response::decode(&v1).unwrap(),
            Response::CheckpointTaken { seq: 2, journal_seq: 1, journal_offset: 99, next_lsn: 7 }
        );
    }

    #[test]
    fn v1_f32_batches_are_rejected() {
        let req = Request::IngestPoints {
            stream: 5,
            batch: DynPoints::F32(PointStore::new(vec![1.0f32, 2.0], 2)),
            rho_min: 0.0,
            delta_min: 1.0,
            full: false,
        };
        let mut v1 = req.encode();
        v1[0] = 1;
        let err = Request::decode(&v1).unwrap_err();
        assert!(err.contains("v2"), "{err}");
    }

    #[test]
    fn decoder_rejects_version_kind_and_trailing_garbage() {
        let mut buf = Request::Checkpoint.encode();
        buf[0] = PROTO_VERSION + 1;
        assert!(Request::decode(&buf).unwrap_err().contains("version"));
        let mut buf = Request::Checkpoint.encode();
        buf[0] = 0;
        assert!(Request::decode(&buf).unwrap_err().contains("version"));
        let mut buf = Request::Checkpoint.encode();
        buf[1] = 200;
        assert!(Request::decode(&buf).unwrap_err().contains("kind"));
        let mut buf = Request::CloseSession { session: 1 }.encode();
        buf.push(0);
        assert!(Request::decode(&buf).unwrap_err().contains("trailing"));
        assert!(Request::decode(&[]).is_err());
        let mut buf = Response::Closed { id: 1 }.encode();
        buf.truncate(buf.len() - 1);
        assert!(Response::decode(&buf).is_err());
    }

    #[test]
    fn bool_fields_reject_non_canonical_bytes() {
        let mut buf = Request::Recut { session: 1, rho_min: 0.0, delta_min: 1.0, full: true }.encode();
        let last = buf.len() - 1;
        buf[last] = 2;
        assert!(Request::decode(&buf).unwrap_err().contains("bool"));
    }
}
