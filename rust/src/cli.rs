//! Command-line parsing (clap is not available offline): a small
//! `--flag value` / `--switch` parser plus the subcommand surface of the
//! `parcluster` binary.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Parsed arguments: positionals plus `--key value` / `--switch` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
    /// Flags consumed via accessors (unknown-flag detection).
    known: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `argv[1..]`. A token `--name` followed by a non-`--` token is a
    /// valued flag; a `--name` followed by another flag (or nothing) is a
    /// switch.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut out = Args::default();
        let toks: Vec<String> = argv.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), toks[i + 1].clone());
                    i += 2;
                } else {
                    out.switches.push(name.to_string());
                    i += 1;
                }
            } else {
                out.positional.push(t.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.known.borrow_mut().push(name.to_string());
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(x) => Ok(Some(x)),
                Err(e) => bail!("--{name} {v:?}: {e}"),
            },
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow::anyhow!("missing required flag --{name}"))
    }

    pub fn switch(&self, name: &str) -> bool {
        self.known.borrow_mut().push(name.to_string());
        self.switches.iter().any(|s| s == name)
    }

    /// Error on any flag that was never consumed (typo safety). Call after
    /// all accessors.
    pub fn reject_unknown(&self) -> Result<()> {
        let known = self.known.borrow();
        for k in self.flags.keys() {
            if !known.iter().any(|n| n == k) {
                bail!("unknown flag --{k}");
            }
        }
        for s in &self.switches {
            if !known.iter().any(|n| n == s) {
                bail!("unknown switch --{s}");
            }
        }
        Ok(())
    }
}

pub const USAGE: &str = "\
parcluster — parallel exact Density Peaks Clustering (DPC)

USAGE:
  parcluster <COMMAND> [FLAGS]

COMMANDS:
  datasets                         print the benchmark dataset inventory (Table 2)
  generate   --dataset NAME [--n N] [--seed S] --out FILE [--csv] [--dtype f32|f64]
             (--dtype tags the v2 binary format; v1 files remain readable)
  cluster    (--dataset NAME [--n N] | --input FILE) [--d-cut X] [--rho-min X]
             [--delta-min X] [--algo A] [--backend B] [--threads T]
             [--labels-out FILE] [--seed S] [--dtype f32|f64]
             [--density cutoff|knn:<k>|gauss]
             (--dtype f32 runs the exact pipeline on single-precision
             coordinates — identical clusters whenever the data is f32-
             losslessly representable, e.g. integer coordinates;
             --density picks the exact density definition — rho_min is in
             the model's units: a count, a rank, or kernel mass/4096)
  decision   (--dataset NAME [--n N] | --input FILE) [--d-cut X] [--k K]
             [--csv-out FILE] [--seed S]
  stream     (--dataset NAME [--n N] | --input FILE) [--batches K] [--d-cut X]
             [--rho-min X] [--delta-min X] [--density M] [--verify] [--seed S]
             ingest the input in K batches through a streaming session,
             reporting per-batch latency (--verify re-checks exactness
             against a from-scratch run after every batch)
  serve      [--config FILE] [--workers N] [--durable DIR] [--fsync-every N]
             [--journal-rotate-bytes N] [--checkpoint-retain N]
             [--listen HOST:PORT] [--max-inflight N] [--max-open-sessions N]
             [--max-sessions-per-tenant N]
             read requests from stdin, one per line (responses print in
             request order; trailing options parse in any order):
             `<dataset> <n> <d_cut> <rho_min> <delta_min> [algo] [density] [full]`  full pipeline job
             `hello <tenant>`                                      bind a tenant id (quotas)
             `open <dataset> <n> <d_cut> [density] [tag=T]`        open a cached session
             `recut <session> <rho_min> <delta_min> [full]`        linkage-only re-cut
             `close <session>`                                     drop a session's cache
             `stream <dim> <d_cut> [density] [f32|f64] [tag=T]`    open a streaming session
             `ingest <stream> <dataset> <n> <rho_min> <delta_min> [seed=S] [full]`  batch + cut
             `closestream <stream>`                                drop a streaming session
             `checkpoint`                                          snapshot durable state now
             (--durable write-ahead-journals every command into DIR and
             restores streams/sessions from DIR on startup; --fsync-every
             sets group commit: 1 = every append (default), N = every N, 0 = never;
             --journal-rotate-bytes seals a journal segment at N bytes
             (default 64 MiB, 0 = never) so checkpoints can delete whole
             segments below the replay horizon; --checkpoint-retain keeps
             the last N checkpoints as delta bases (default 1, min 1);
             --listen also serves the same requests as a length-prefixed,
             CRC-framed binary protocol over TCP — the `loadgen` binary is
             the reference client; --max-inflight bounds jobs in flight
             (excess requests get a retryable `busy` response) and
             --max-open-sessions / --max-sessions-per-tenant bound open
             handles, evicting the least-recently-used idle one at the
             global cap; all three default to 0 = unlimited)
             [the `loadgen` binary drives a serve --listen endpoint with
             concurrent mixed traffic and reports p50/p99 latency and
             throughput — see `loadgen --help`]
  journal    inspect --dir DIR    print the manifest, checkpoints, and every
             journal frame (segment, offset, LSN, kind) of a durable
             directory's segment chain — including sealed segments below
             the replay horizon that GC has not yet swept — plus whether
             the final segment's tail is clean or torn — read-only
  help

Algorithms (--algo): naive | exact-baseline | incomplete | priority | fenwick
Backends  (--backend): auto | tree | xla
Dtypes    (--dtype):   f32 | f64 (default: the input's stored dtype — f64 for
                       datasets/CSV; the xla backend serves f64 jobs only)
Densities (--density): cutoff (alias tophat; the paper's count-within-d_cut, default)
                     | knn:<k> (rank of the k-th-NN distance, e.g. knn:8)
                     | gauss (fixed-point Gaussian kernel truncated at d_cut)
                     | epan (fixed-point Epanechnikov kernel, 1 - (d/d_cut)^2)
                       (the xla backend serves cutoff jobs only)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn parses_flags_switches_positionals() {
        let a = args("cluster --n 100 --csv --dataset simden");
        assert_eq!(a.positional, vec!["cluster"]);
        assert_eq!(a.get("n"), Some("100"));
        assert_eq!(a.get("dataset"), Some("simden"));
        assert!(a.switch("csv"));
        assert!(!a.switch("quiet"));
    }

    #[test]
    fn typed_accessors() {
        let a = args("--n 42 --x 1.5");
        assert_eq!(a.get_or("n", 7usize).unwrap(), 42);
        assert_eq!(a.get_or("missing", 7usize).unwrap(), 7);
        assert_eq!(a.get_or("x", 0.0f64).unwrap(), 1.5);
        assert!(a.get_parse::<usize>("x").is_err());
    }

    #[test]
    fn require_and_unknown_detection() {
        let a = args("--good 1 --bad 2");
        assert!(a.require("good").is_ok());
        assert!(a.require("absent").is_err());
        // `bad` not consumed:
        assert!(a.reject_unknown().is_err());
        let _ = a.get("bad");
        assert!(a.reject_unknown().is_ok());
    }
}
