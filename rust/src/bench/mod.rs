//! Benchmark harness (criterion is unavailable offline): wall-clock timing
//! with warmup + repeated trials, plus plain-text table/series printers that
//! mirror the paper's Table 3 / Figure 3/4/6 layouts. Used by the
//! `benches/*.rs` targets (all `harness = false`).

use std::time::Instant;

/// Run `f` once for warmup, then `trials` times; report the median seconds.
pub fn time_median<F: FnMut()>(trials: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut samples: Vec<f64> = (0..trials.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    // lint: allow(panic-surface) — wall-clock samples are finite by
    // construction, so partial_cmp cannot see a NaN.
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Time a single run of `f`, returning (seconds, result).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (f64, T) {
    let t = Instant::now();
    let out = f();
    (t.elapsed().as_secs_f64(), out)
}

/// Fixed-width table printer.
#[derive(Debug)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (c, cell) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$} | ", cell, w = widths[c]));
            }
            s.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&format!("|{}|", widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds like the paper's tables (3 significant-ish digits).
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "INF".into();
    }
    if s >= 100.0 {
        format!("{s:.1}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else {
        format!("{s:.4}")
    }
}

/// Least-squares slope of log10(y) vs log10(x) — the paper's Figure-4a
/// scaling-fit methodology.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let lx: Vec<f64> = xs.iter().map(|x| x.log10()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.log10()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let cov: f64 = lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    cov / var
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_median_is_positive() {
        let t = time_median(3, || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let s = t.render();
        assert_eq!(s.lines().count(), 4);
        assert!(s.contains("333"));
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(f64::INFINITY), "INF");
        assert_eq!(fmt_secs(123.456), "123.5");
        assert_eq!(fmt_secs(1.234), "1.23");
        assert_eq!(fmt_secs(0.01234), "0.0123");
    }

    #[test]
    fn loglog_slope_of_quadratic_is_two() {
        let xs = vec![10.0, 100.0, 1000.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x * x).collect();
        assert!((loglog_slope(&xs, &ys) - 2.0).abs() < 1e-9);
    }
}
