//! Cache-line-aligned SoA leaf blocks for the kd-tree family.
//!
//! Every leaf of a [`super::KdTree`] (and every tail subtree of a
//! [`crate::pskd::PriorityKdTree`]) owns one fixed-capacity **block** of
//! [`BLOCK_LANES`] = 16 lanes in a flat arena, stored dim-major:
//! `block[k * BLOCK_LANES + l]` is coordinate `k` of lane `l`. A leaf visit
//! is then one [`Scalar::dist_sq_block`] sweep — 16 squared distances per
//! call out of contiguous, 64-byte-aligned rows — instead of a per-point
//! gather loop.
//!
//! # Block indexing without a node field
//!
//! Blocks are addressed by `perm_offset / BLOCK_MIN`, not by a pointer or
//! an extra per-node index. This works because the builder's median split
//! guarantees every leaf holds between [`BLOCK_MIN`] = 8 and 16 points
//! (splitting `m ≥ 17` yields halves `≥ 8`; recursion stops at `m ≤ 16`),
//! except a lone root leaf when the whole tree has `≤ 16` points. Leaves
//! partition `0..n` into consecutive runs of length `≥ 8` (the small-root
//! case has a single run), so distinct leaves' start offsets differ by at
//! least 8 and `offset / 8` is injective. An arena of `ceil(n / 8)` blocks
//! therefore fits every leaf, at the cost of holes (blocks no leaf maps
//! to) when leaves run longer than 8 — bounded 2× space for index-free,
//! raceless addressing: parallel builder tasks own disjoint offset ranges,
//! hence disjoint blocks.
//!
//! Unused lanes of a block are padded with [`Scalar::INFINITY`]: the
//! kernel then reports `+∞` distance for them (queries are validated
//! finite, so no `∞ − ∞` NaN can arise), and every consumer additionally
//! iterates only the leaf's live lanes, so padding never reaches a
//! tie-break comparison.

use std::marker::PhantomData;

use crate::geom::{Scalar, BLOCK_LANES};

/// Minimum points per leaf block (= half the leaf-size cap): the divisor
/// that makes `perm_offset / BLOCK_MIN` a collision-free block index.
pub const BLOCK_MIN: usize = BLOCK_LANES / 2;

/// One cache line of raw storage. The arena's backing vector is a
/// `Vec<CacheLine>`, so its allocation — and, because a block's byte size
/// (`16 lanes × d × 4-or-8 bytes`) is always a multiple of 64, every
/// block — starts on a 64-byte boundary.
#[repr(C, align(64))]
#[derive(Clone, Copy)]
struct CacheLine([u8; 64]);

/// Flat arena of dim-major leaf blocks. Built once (filled through a raw
/// pointer by the parallel tree builder), then read-only.
pub struct LeafArena<S: Scalar> {
    lines: Vec<CacheLine>,
    /// Total scalars = `blocks × BLOCK_LANES × dim`.
    scalars: usize,
    /// Scalars per block (`BLOCK_LANES × dim`), cached for indexing.
    stride: usize,
    _marker: PhantomData<S>,
}

impl<S: Scalar> std::fmt::Debug for LeafArena<S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LeafArena")
            .field("blocks", &self.blocks())
            .field("stride", &self.stride)
            .finish_non_exhaustive()
    }
}

impl<S: Scalar> LeafArena<S> {
    /// Arena sized for `blocks` blocks of dimension `d`, zero-filled.
    /// Holes (blocks no leaf claims) keep the zero fill and are never
    /// read; claimed blocks are fully overwritten by the builder
    /// (coordinates in the live lanes, `+∞` padding in the rest).
    pub fn new(blocks: usize, d: usize) -> Self {
        let stride = BLOCK_LANES * d;
        let scalars = blocks * stride;
        let bytes = scalars * std::mem::size_of::<S>();
        debug_assert_eq!(bytes % 64, 0, "blocks are whole cache lines");
        LeafArena { lines: vec![CacheLine([0u8; 64]); bytes / 64], scalars, stride, _marker: PhantomData }
    }

    /// Raw base pointer for the builder's writes. Builder tasks write
    /// disjoint blocks (see the module doc), so no synchronization is
    /// needed beyond the build's own join.
    pub fn as_mut_ptr(&mut self) -> *mut S {
        self.lines.as_mut_ptr() as *mut S
    }

    /// The dim-major coordinate block at index `b`
    /// (`BLOCK_LANES × d` scalars).
    #[inline]
    pub fn block(&self, b: usize) -> &[S] {
        let start = b * self.stride;
        debug_assert!(start + self.stride <= self.scalars, "block {b} out of bounds");
        // SAFETY: CacheLine is plain initialized bytes, S is f32/f64 (any
        // bit pattern valid), the 64-byte alignment exceeds S's, and the
        // range check above keeps the slice inside the allocation.
        unsafe { std::slice::from_raw_parts((self.lines.as_ptr() as *const S).add(start), self.stride) }
    }

    /// Number of blocks the arena holds.
    pub fn blocks(&self) -> usize {
        if self.stride == 0 {
            0
        } else {
            self.scalars / self.stride
        }
    }

    /// Arena footprint in bytes (diagnostics; 64 × number of cache lines).
    pub fn bytes(&self) -> usize {
        self.lines.len() * 64
    }
}

/// Fill block `b` of the arena behind `base` (obtained from
/// [`LeafArena::as_mut_ptr`]): lane `l < m` gets point `ids[l]`'s
/// coordinates from `coords` (row-major, dimension `d`), lanes `m..16` get
/// `+∞` padding.
///
/// # Safety
/// `base` must point at an arena of dimension `d` with more than `b`
/// blocks, and no other thread may touch block `b` concurrently (the tree
/// builders guarantee this: each leaf's offset range — hence block — is
/// owned by exactly one build task).
pub unsafe fn fill_block<S: Scalar>(base: *mut S, b: usize, coords: &[S], d: usize, ids: &[u32]) {
    let m = ids.len();
    debug_assert!(m <= BLOCK_LANES);
    // SAFETY: the caller contract places block `b` inside the arena.
    let block = unsafe { base.add(b * BLOCK_LANES * d) };
    for k in 0..d {
        // SAFETY: `k < d` keeps the row inside block `b`.
        let row = unsafe { block.add(k * BLOCK_LANES) };
        for l in 0..BLOCK_LANES {
            let v = if l < m {
                // SAFETY: `ids` holds valid point ids for `coords`
                // (caller contract) and `k < d`, so the flat index is
                // in bounds of the row-major coordinate slice.
                unsafe { *coords.get_unchecked(ids[l] as usize * d + k) }
            } else {
                S::INFINITY
            };
            // SAFETY: `l < BLOCK_LANES` keeps the write inside the row.
            unsafe { row.add(l).write(v) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_blocks_are_cache_line_aligned() {
        for d in [1, 2, 3, 7] {
            let arena = LeafArena::<f32>::new(3, d);
            for b in 0..3 {
                assert_eq!(arena.block(b).as_ptr() as usize % 64, 0, "d={d} b={b}");
            }
            assert_eq!(arena.blocks(), 3);
            assert_eq!(arena.bytes(), 3 * BLOCK_LANES * d * 4);
        }
        let arena64 = LeafArena::<f64>::new(2, 3);
        assert_eq!(arena64.block(1).as_ptr() as usize % 64, 0);
    }

    #[test]
    fn fill_block_transposes_and_pads() {
        // 3 points in 2-d, gathered out of order into lanes 0..3.
        let coords = vec![10.0f64, 11.0, 20.0, 21.0, 30.0, 31.0];
        let mut arena = LeafArena::<f64>::new(2, 2);
        unsafe { fill_block(arena.as_mut_ptr(), 1, &coords, 2, &[2, 0, 1]) };
        let blk = arena.block(1);
        assert_eq!(&blk[0..3], &[30.0, 10.0, 20.0]); // x row, lanes 0..3
        assert_eq!(&blk[BLOCK_LANES..BLOCK_LANES + 3], &[31.0, 11.0, 21.0]); // y row
        for l in 3..BLOCK_LANES {
            assert_eq!(blk[l], f64::INFINITY);
            assert_eq!(blk[BLOCK_LANES + l], f64::INFINITY);
        }
        // The untouched block keeps its zero fill.
        assert!(arena.block(0).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn filled_block_feeds_the_kernel() {
        let coords = vec![1.0f32, 2.0, 4.0, 6.0];
        let mut arena = LeafArena::<f32>::new(1, 2);
        unsafe { fill_block(arena.as_mut_ptr(), 0, &coords, 2, &[0, 1]) };
        let mut out = [0.0f32; BLOCK_LANES];
        f32::dist_sq_block(arena.block(0), 2, &[1.0, 2.0], &mut out);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[1], 25.0);
        assert!(out[2..].iter().all(|&v| v == f32::INFINITY));
    }
}
