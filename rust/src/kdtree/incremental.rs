//! Incremental (pointer-based, dynamically allocated) kd-tree — a faithful
//! reimplementation of the data structure inside DPC-EXACT-BASELINE
//! (Amagata–Hara [3]). Points are inserted one at a time via top-down
//! traversals with cyclic splitting dimensions; the tree can become
//! unbalanced, and nodes are heap-allocated individually (the cache-
//! unfriendliness the paper contrasts against in §7.2).
//!
//! This exists purely as the *baseline* under benchmark; the paper's
//! improvements (incomplete kd-tree, priority search kd-tree, Fenwick tree)
//! live in sibling modules. It deliberately does NOT use the blocked SoA
//! leaves of [`super::leaf`]: those rely on the arena builder's 8–16-point
//! leaf guarantee, which per-point insertion cannot maintain — one-point
//! "leaves" scattered across the heap are exactly the layout being
//! measured against. Generic over the coordinate [`Scalar`] like the rest
//! of the tree family; pins its input store by refcount.

use crate::geom::{PointStore, Scalar};

use super::StatSink;

struct Node {
    point: u32,
    left: Option<Box<Node>>,
    right: Option<Box<Node>>,
}

pub struct IncrementalKdTree<S: Scalar = f64> {
    pts: PointStore<S>,
    root: Option<Box<Node>>,
    len: usize,
}

impl<S: Scalar> std::fmt::Debug for IncrementalKdTree<S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("IncrementalKdTree").field("len", &self.len).finish_non_exhaustive()
    }
}

impl<S: Scalar> IncrementalKdTree<S> {
    pub fn new(pts: &PointStore<S>) -> Self {
        IncrementalKdTree { pts: pts.clone(), root: None, len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert point id `p` (top-down traversal, cyclic split dimension).
    pub fn insert(&mut self, p: u32) {
        let d = self.pts.dim();
        let pts = &self.pts;
        let mut cur = &mut self.root;
        let mut depth = 0usize;
        loop {
            match cur {
                None => {
                    *cur = Some(Box::new(Node { point: p, left: None, right: None }));
                    self.len += 1;
                    return;
                }
                Some(node) => {
                    let dim = depth % d;
                    let nv = pts.coord(node.point as usize, dim);
                    let pv = pts.coord(p as usize, dim);
                    cur = if pv < nv { &mut node.left } else { &mut node.right };
                    depth += 1;
                }
            }
        }
    }

    /// Range count without subtree-count pruning: tests every node's point
    /// individually, descending children whenever the query ball crosses
    /// the splitting hyperplane. This is the DPC-EXACT-BASELINE density
    /// step: pointer-chasing over individually heap-allocated nodes, no
    /// §6.1 containment shortcut.
    pub fn range_count<T: StatSink>(&self, q: &[S], r_sq: S, stats: &mut T) -> usize {
        match &self.root {
            Some(root) => Self::count_rec(&self.pts, root, q, r_sq, 0, stats),
            None => 0,
        }
    }

    fn count_rec<T: StatSink>(pts: &PointStore<S>, node: &Node, q: &[S], r_sq: S, depth: usize, stats: &mut T) -> usize {
        stats.visit_node();
        stats.scan_point();
        let mut c = usize::from(pts.dist_sq_to(node.point as usize, q) <= r_sq);
        let dim = depth % pts.dim();
        let diff = q[dim] - pts.coord(node.point as usize, dim);
        let (near, far) = if diff < S::ZERO { (&node.left, &node.right) } else { (&node.right, &node.left) };
        if let Some(n) = near {
            c += Self::count_rec(pts, n, q, r_sq, depth + 1, stats);
        }
        if diff * diff <= r_sq {
            if let Some(f) = far {
                c += Self::count_rec(pts, f, q, r_sq, depth + 1, stats);
            }
        }
        c
    }

    /// Nearest neighbor among inserted points, excluding `exclude`; ties by
    /// smaller id.
    pub fn nn<T: StatSink>(&self, q: &[S], exclude: u32, stats: &mut T) -> Option<(u32, S)> {
        let mut best = (u32::MAX, S::INFINITY);
        if let Some(root) = &self.root {
            Self::nn_rec(&self.pts, root, q, 0, exclude, &mut best, stats, 1);
        }
        if best.0 == u32::MAX {
            None
        } else {
            Some(best)
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn nn_rec<T: StatSink>(
        pts: &PointStore<S>,
        node: &Node,
        q: &[S],
        depth: usize,
        exclude: u32,
        best: &mut (u32, S),
        stats: &mut T,
        level: usize,
    ) {
        stats.visit_node();
        stats.depth(level);
        if node.point != exclude {
            stats.scan_point();
            let ds = pts.dist_sq_to(node.point as usize, q);
            if ds < best.1 || (ds == best.1 && node.point < best.0) {
                *best = (node.point, ds);
            }
        }
        let dim = depth % pts.dim();
        let diff = q[dim] - pts.coord(node.point as usize, dim);
        let (near, far) = if diff < S::ZERO { (&node.left, &node.right) } else { (&node.right, &node.left) };
        if let Some(n) = near {
            Self::nn_rec(pts, n, q, depth + 1, exclude, best, stats, level + 1);
        }
        if diff * diff <= best.1 {
            if let Some(f) = far {
                Self::nn_rec(pts, f, q, depth + 1, exclude, best, stats, level + 1);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::PointSet;
    use crate::kdtree::{brute_nn, NoStats};
    use crate::proputil::gen_uniform_points;
    use crate::prng::SplitMix64;

    #[test]
    fn empty_returns_none() {
        let pts = PointSet::new(vec![0.0, 0.0], 2);
        let t = IncrementalKdTree::new(&pts);
        assert_eq!(t.nn(&[0.0, 0.0], u32::MAX, &mut NoStats), None);
    }

    #[test]
    fn incremental_nn_matches_brute_force_over_inserted_prefix() {
        let mut rng = SplitMix64::new(11);
        let pts = gen_uniform_points(&mut rng, 300, 2, 50.0);
        let mut t = IncrementalKdTree::new(&pts);
        let mut order: Vec<u32> = (0..300u32).collect();
        rng.shuffle(&mut order);
        let mut inserted: Vec<u32> = Vec::new();
        for &p in order.iter() {
            if !inserted.is_empty() {
                let q = pts.point(p as usize);
                let got = t.nn(q, p, &mut NoStats).unwrap();
                // brute force over inserted prefix
                let mut best = (u32::MAX, f64::INFINITY);
                for &j in &inserted {
                    let ds = pts.dist_sq_to(j as usize, q);
                    if ds < best.1 || (ds == best.1 && j < best.0) {
                        best = (j, ds);
                    }
                }
                assert_eq!(got, best);
            }
            t.insert(p);
            inserted.push(p);
        }
        assert_eq!(t.len(), 300);
    }

    #[test]
    fn range_count_matches_brute_force() {
        let mut rng = SplitMix64::new(13);
        let pts = gen_uniform_points(&mut rng, 400, 3, 20.0);
        let mut t = IncrementalKdTree::new(&pts);
        let mut order: Vec<u32> = (0..400u32).collect();
        rng.shuffle(&mut order);
        for &p in &order {
            t.insert(p);
        }
        for i in (0..400).step_by(17) {
            for r in [0.0, 2.0, 5.0, 50.0] {
                let want = crate::kdtree::brute_range_count(&pts, pts.point(i), r * r);
                let got = t.range_count(pts.point(i), r * r, &mut NoStats);
                assert_eq!(got, want, "i={i} r={r}");
            }
        }
    }

    #[test]
    fn range_count_empty_tree_is_zero() {
        let pts = PointSet::new(vec![0.0, 0.0], 2);
        let t = IncrementalKdTree::new(&pts);
        assert_eq!(t.range_count(&[0.0, 0.0], 100.0, &mut NoStats), 0);
    }

    #[test]
    fn full_tree_matches_global_brute_force() {
        let mut rng = SplitMix64::new(12);
        let pts = gen_uniform_points(&mut rng, 500, 4, 10.0);
        let mut t = IncrementalKdTree::new(&pts);
        for p in 0..500u32 {
            t.insert(p);
        }
        for i in (0..500).step_by(29) {
            let got = t.nn(pts.point(i), i as u32, &mut NoStats).unwrap();
            let want = brute_nn(&pts, pts.point(i), i as u32).unwrap();
            assert_eq!(got, want);
        }
    }
}
