//! Incomplete kd-tree (§4.1): a balanced kd-tree built over **all** points up
//! front, in which points start *inactive*. "Inserting" a point merely
//! activates it and marks its ancestor path active (a bottom-up walk along
//! parent pointers — no per-insert top-down traversal, no rebalancing).
//! Nearest-neighbor searches prune any subtree whose `isActive` flag is
//! false (Figure 1 of the paper).
//!
//! This is the paper's replacement for Amagata–Hara's incremental kd-tree in
//! the sequential dependent-point loop (DPC-INCOMPLETE), and the conceptual
//! stepping stone to the priority search kd-tree.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::geom::{Scalar, BLOCK_LANES};

use super::{KdTree, StatSink};

pub struct IncompleteKdTree<'t, S: Scalar = f64> {
    tree: &'t KdTree<S>,
    node_active: Vec<AtomicBool>,
    point_active: Vec<AtomicBool>,
}

impl<S: Scalar> std::fmt::Debug for IncompleteKdTree<'_, S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("IncompleteKdTree")
            .field("nodes", &self.node_active.len())
            .field("points", &self.point_active.len())
            .finish_non_exhaustive()
    }
}

impl<'t, S: Scalar> IncompleteKdTree<'t, S> {
    pub fn new(tree: &'t KdTree<S>) -> Self {
        IncompleteKdTree {
            node_active: (0..tree.num_slots()).map(|_| AtomicBool::new(false)).collect(),
            point_active: (0..tree.points().len()).map(|_| AtomicBool::new(false)).collect(),
            tree,
        }
    }

    /// Activate point `p`: bottom-up walk from its leaf, stopping at the
    /// first already-active ancestor. O(path length) with no comparisons —
    /// the advantage over incremental insertion the paper highlights.
    pub fn activate(&self, p: u32) {
        self.point_active[p as usize].store(true, Ordering::Release);
        let mut cur = self.tree.leaf_of(p);
        loop {
            let was = self.node_active[cur as usize].swap(true, Ordering::AcqRel);
            if was {
                break; // ancestors already active
            }
            let parent = self.tree.parent_of(cur);
            if parent == u32::MAX {
                break;
            }
            cur = parent;
        }
    }

    pub fn is_active(&self, p: u32) -> bool {
        self.point_active[p as usize].load(Ordering::Acquire)
    }

    /// Nearest *active* neighbor of `q`, excluding id `exclude`; ties by
    /// smaller id. Subtrees with no active point are pruned (grey subtree in
    /// Figure 1).
    pub fn nn<T: StatSink>(&self, q: &[S], exclude: u32, stats: &mut T) -> Option<(u32, S)> {
        let root = self.tree.root_idx();
        if !self.node_active[root as usize].load(Ordering::Acquire) {
            return None;
        }
        let mut best = (u32::MAX, S::INFINITY);
        self.nn_rec(root, q, exclude, &mut best, stats, 1);
        if best.0 == u32::MAX {
            None
        } else {
            Some(best)
        }
    }

    fn nn_rec<T: StatSink>(&self, i: u32, q: &[S], exclude: u32, best: &mut (u32, S), stats: &mut T, depth: usize) {
        stats.visit_node();
        stats.depth(depth);
        if self.tree.is_leaf_idx(i) {
            // One block sweep for the whole leaf; the per-lane activity
            // filter runs on the precomputed distances.
            let mut dbuf = [S::ZERO; BLOCK_LANES];
            let ids = self.tree.leaf_scan_idx(i, q, &mut dbuf);
            for (l, &p) in ids.iter().enumerate() {
                if p == exclude || !self.point_active[p as usize].load(Ordering::Acquire) {
                    continue;
                }
                stats.scan_point();
                let ds = dbuf[l];
                if ds < best.1 || (ds == best.1 && p < best.0) {
                    *best = (p, ds);
                }
            }
            return;
        }
        let (l, r) = self.tree.children(i);
        let la = self.node_active[l as usize].load(Ordering::Acquire);
        let ra = self.node_active[r as usize].load(Ordering::Acquire);
        let dl = if la { self.tree.bbox_dist(l, q) } else { S::INFINITY };
        let dr = if ra { self.tree.bbox_dist(r, q) } else { S::INFINITY };
        let (first, d1, second, d2) = if dl <= dr { (l, dl, r, dr) } else { (r, dr, l, dl) };
        if d1 <= best.1 && d1.finite() {
            self.nn_rec(first, q, exclude, best, stats, depth + 1);
        }
        if d2 <= best.1 && d2.finite() {
            self.nn_rec(second, q, exclude, best, stats, depth + 1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::PointSet;
    use crate::kdtree::NoStats;
    use crate::proputil::gen_uniform_points;
    use crate::prng::SplitMix64;

    fn brute_active_nn(pts: &PointSet, active: &[bool], q: &[f64], exclude: u32) -> Option<(u32, f64)> {
        let mut best: Option<(u32, f64)> = None;
        for i in 0..pts.len() {
            if i as u32 == exclude || !active[i] {
                continue;
            }
            let ds = pts.dist_sq_to(i, q);
            match best {
                Some((bi, bd)) if ds > bd || (ds == bd && i as u32 > bi) => {}
                _ => best = Some((i as u32, ds)),
            }
        }
        best
    }

    #[test]
    fn empty_tree_returns_none() {
        let mut rng = SplitMix64::new(1);
        let pts = gen_uniform_points(&mut rng, 100, 2, 50.0);
        let tree = KdTree::build_with_maps(&pts);
        let inc = IncompleteKdTree::new(&tree);
        assert_eq!(inc.nn(pts.point(0), u32::MAX, &mut NoStats), None);
    }

    #[test]
    fn incremental_activation_matches_brute_force() {
        let mut rng = SplitMix64::new(2);
        let pts = gen_uniform_points(&mut rng, 400, 3, 100.0);
        let tree = KdTree::build_with_maps(&pts);
        let inc = IncompleteKdTree::new(&tree);
        let mut active = vec![false; pts.len()];
        let mut order: Vec<u32> = (0..pts.len() as u32).collect();
        rng.shuffle(&mut order);
        for (step, &p) in order.iter().enumerate() {
            // Query BEFORE activating p (the dependent-point pattern).
            let q = pts.point(p as usize);
            let got = inc.nn(q, p, &mut NoStats);
            let want = brute_active_nn(&pts, &active, q, p);
            assert_eq!(got, want, "step {step} point {p}");
            inc.activate(p);
            active[p as usize] = true;
        }
    }

    #[test]
    fn activate_is_idempotent() {
        let mut rng = SplitMix64::new(3);
        let pts = gen_uniform_points(&mut rng, 50, 2, 10.0);
        let tree = KdTree::build_with_maps(&pts);
        let inc = IncompleteKdTree::new(&tree);
        inc.activate(7);
        inc.activate(7);
        assert!(inc.is_active(7));
        let got = inc.nn(pts.point(3), 3, &mut NoStats).unwrap();
        assert_eq!(got.0, 7);
    }

    #[test]
    fn excluded_point_is_skipped_even_if_active() {
        let pts = PointSet::new(vec![0.0, 0.0, 1.0, 0.0, 5.0, 0.0], 2);
        let tree = KdTree::build_with_maps(&pts);
        let inc = IncompleteKdTree::new(&tree);
        inc.activate(0);
        inc.activate(1);
        // NN of point 0 excluding itself: point 1.
        assert_eq!(inc.nn(pts.point(0), 0, &mut NoStats), Some((1, 1.0)));
        // Exclude 1 too (simulate): query from its coords.
        assert_eq!(inc.nn(pts.point(1), 1, &mut NoStats), Some((0, 1.0)));
    }
}
