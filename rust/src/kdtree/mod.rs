//! Parallel balanced kd-tree (the paper's §3.2 workhorse), generic over the
//! coordinate [`Scalar`] (`f32`/`f64`).
//!
//! - **Arena layout, preallocated**: all nodes live in one flat `Vec`,
//!   allocated up front (the paper credits preallocation for part of its
//!   density-step speedup over the baseline's dynamically-allocated nodes,
//!   §7.2). A subtree over `m` points occupies a contiguous slot range of
//!   size `2m-1`, so parallel recursive construction writes disjoint slots
//!   without locks.
//! - **Ownership**: a tree pins its input by cloning the [`PointStore`] — a
//!   refcount bump on the shared `Arc<[S]>` buffer, never a coordinate
//!   copy. That removes the old borrow lifetime, so sessions and the
//!   Bentley–Saxe stream forest hold trees without self-reference tricks.
//! - **Split rule**: median along the widest dimension of the node's cell
//!   (the bounding box of its points), leaves hold ≤ `LEAF_SIZE` points —
//!   and, because a median split of `m ≥ 17` leaves halves of `≥ 8`, every
//!   leaf except a lone small root holds **8–16** points.
//! - **Blocked leaves**: each leaf owns one cache-line-aligned, dim-major
//!   SoA block of 16 lanes in a flat [`leaf::LeafArena`], addressed by
//!   `lo / 8` (injective precisely because of the 8–16 guarantee — see the
//!   `leaf` module doc). A leaf visit is a single [`Scalar::dist_sq_block`]
//!   sweep — scalar by default, AVX `f32x8`/`f64x4` when the CPU has it —
//!   instead of a per-point distance loop; both kernels are bit-identical
//!   by construction and pinned so by the oracle suite's forced-scalar leg.
//! - **Queries**: nearest-neighbor / K-NN with cell-distance pruning, range
//!   **count** with the §6.1 optimization (cells fully inside the query ball
//!   contribute `count` without traversal) plus an unoptimized variant used
//!   by the DPC-EXACT-BASELINE reproduction, and range report. All distance
//!   math runs in `S`.
//! - **Instrumentation**: every traversal can feed a [`StatSink`] so the
//!   Table-1 bench can measure empirical work (nodes visited) and span
//!   (traversal depth) — machine-independent evidence for the complexity
//!   claims.

pub mod incomplete;
pub mod incremental;
pub mod leaf;

use crate::geom::{Bbox, PointStore, PointsView, Scalar, BLOCK_LANES};
use crate::parlay;
use leaf::{LeafArena, BLOCK_MIN};

pub const LEAF_SIZE: usize = 16;
/// Subtrees smaller than this build sequentially. With the work-stealing
/// scheduler a fork is one deque push, so this floor only amortizes task
/// allocation — steals are rare because thieves take the biggest subtrees.
const BUILD_GRAIN: usize = 2048;
const NONE: u32 = u32::MAX;

/// Observer for traversal statistics. The no-op impl compiles away.
pub trait StatSink {
    #[inline]
    fn visit_node(&mut self) {}
    #[inline]
    fn scan_point(&mut self) {}
    #[inline]
    fn depth(&mut self, _d: usize) {}
}

/// Zero-cost sink.
#[derive(Debug)]
pub struct NoStats;
impl StatSink for NoStats {}

/// Counting sink for the empirical-complexity bench (Table 1).
#[derive(Default, Debug, Clone)]
pub struct Stats {
    pub nodes_visited: u64,
    pub points_scanned: u64,
    pub max_depth: usize,
}

impl StatSink for Stats {
    #[inline]
    fn visit_node(&mut self) {
        self.nodes_visited += 1;
    }
    #[inline]
    fn scan_point(&mut self) {
        self.points_scanned += 1;
    }
    #[inline]
    fn depth(&mut self, d: usize) {
        self.max_depth = self.max_depth.max(d);
    }
}

#[derive(Clone, Copy, Debug)]
struct Node {
    left: u32,
    right: u32,
    /// Point range [lo, hi) in `perm` — `hi - lo` is the subtree count used
    /// by the §6.1 pruning.
    lo: u32,
    hi: u32,
}

/// Balanced kd-tree over a refcount-shared [`PointStore`].
pub struct KdTree<S: Scalar = f64> {
    pts: PointStore<S>,
    nodes: Vec<Node>,
    /// Flat bounds arena: `[node * 2d .. node * 2d + d)` = min,
    /// `[.. + d ..)` = max.
    bounds: Vec<S>,
    /// Permutation of point ids; leaves own contiguous ranges of it.
    perm: Vec<u32>,
    /// Dim-major SoA coordinate blocks, one per leaf at block index
    /// `lo / BLOCK_MIN` (see the [`leaf`] module doc for why that is
    /// collision-free). Replaces the old perm-ordered AoS copy: leaf scans
    /// are now one [`Scalar::dist_sq_block`] sweep over aligned rows.
    leaves: LeafArena<S>,
    root: u32,
    /// parent[node] (NONE for root). Needed by the incomplete-tree wrapper.
    parent: Vec<u32>,
    /// leaf_of_point[original id] = leaf node index.
    leaf_of_point: Vec<u32>,
}

impl<S: Scalar> std::fmt::Debug for KdTree<S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KdTree")
            .field("points", &self.perm.len())
            .field("nodes", &self.nodes.len())
            .finish_non_exhaustive()
    }
}

impl<S: Scalar> KdTree<S> {
    /// Build over all points of `pts` (parallel recursion). The store is
    /// pinned by refcount.
    pub fn build(pts: &PointStore<S>) -> Self {
        let ids: Vec<u32> = (0..pts.len() as u32).collect();
        Self::build_impl(pts, ids, false)
    }

    /// Build with parent pointers and the point→leaf map populated — required
    /// by [`incomplete::IncompleteKdTree`]. (Opt-in because the leaf map is
    /// O(|P|) per tree, which would make the Fenwick structure's n block
    /// trees quadratic in memory.)
    pub fn build_with_maps(pts: &PointStore<S>) -> Self {
        let ids: Vec<u32> = (0..pts.len() as u32).collect();
        Self::build_impl(pts, ids, true)
    }

    /// Build over a subset of point ids (used by the Fenwick structure and
    /// the stream forest).
    pub fn build_from_ids(pts: &PointStore<S>, ids: Vec<u32>) -> Self {
        Self::build_impl(pts, ids, false)
    }

    fn build_impl(pts: &PointStore<S>, mut ids: Vec<u32>, with_maps: bool) -> Self {
        let n = ids.len();
        let d = pts.dim();
        // Unreachable from the public API: every entry point (sessions,
        // streams, the coordinator, the Fenwick/forest structures) rejects
        // empty inputs with `DpcError::EmptyInput` first. The assert guards
        // direct library misuse, not user input.
        assert!(n > 0, "cannot build kd-tree over zero points");
        let slots = 2 * n - 1;
        let mut nodes = vec![Node { left: NONE, right: NONE, lo: 0, hi: 0 }; slots];
        let mut bounds = vec![S::ZERO; slots * 2 * d];
        // Leaves start at perm offsets ≥ 8 apart, so `ceil(n/8)` blocks
        // cover every `lo / BLOCK_MIN` index the builder can produce.
        let mut leaves = LeafArena::new(n.div_ceil(BLOCK_MIN), d);
        let mut parent = if with_maps { vec![NONE; slots] } else { Vec::new() };
        let mut leaf_of_point = if with_maps { vec![NONE; pts.len()] } else { Vec::new() };
        {
            let b = Builder {
                pts: pts.view(),
                nodes_ptr: nodes.as_mut_ptr() as usize,
                bounds_ptr: bounds.as_mut_ptr() as usize,
                arena_ptr: leaves.as_mut_ptr() as usize,
                parent_ptr: if with_maps { parent.as_mut_ptr() as usize } else { 0 },
                leaf_ptr: if with_maps { leaf_of_point.as_mut_ptr() as usize } else { 0 },
                d,
                // Resolved once: the recursion forks on every node above
                // BUILD_GRAIN, and re-reading the global costs an RwLock
                // acquisition per fork.
                pool: parlay::pool::global(),
            };
            b.build_rec(&mut ids, 0, 0, NONE);
        }
        KdTree {
            pts: pts.clone(),
            nodes,
            bounds,
            perm: ids,
            leaves,
            root: 0,
            parent,
            leaf_of_point,
        }
    }

    #[inline]
    pub fn points(&self) -> &PointStore<S> {
        &self.pts
    }

    #[inline]
    pub fn size(&self) -> usize {
        self.perm.len()
    }

    #[inline]
    fn node(&self, i: u32) -> &Node {
        &self.nodes[i as usize]
    }

    #[inline]
    fn bbox_dist_sq(&self, i: u32, q: &[S]) -> S {
        let d = self.pts.dim();
        let base = i as usize * 2 * d;
        let (min, max) = (&self.bounds[base..base + d], &self.bounds[base + d..base + 2 * d]);
        let mut s = S::ZERO;
        for k in 0..d {
            let v = q[k];
            let t = if v < min[k] { min[k] - v } else if v > max[k] { v - max[k] } else { S::ZERO };
            s += t * t;
        }
        s
    }

    #[inline]
    fn bbox_far_corner_sq(&self, i: u32, q: &[S]) -> S {
        let d = self.pts.dim();
        let base = i as usize * 2 * d;
        let (min, max) = (&self.bounds[base..base + d], &self.bounds[base + d..base + 2 * d]);
        let mut s = S::ZERO;
        for k in 0..d {
            // max(q-min, max-q) == max(|q-min|, |q-max|) whenever min ≤ max.
            let t = (q[k] - min[k]).smax(max[k] - q[k]);
            s += t * t;
        }
        s
    }

    /// Bounding box of a node (copies; for tests/debug).
    pub fn node_bbox(&self, i: u32) -> Bbox<S> {
        let d = self.pts.dim();
        let base = i as usize * 2 * d;
        Bbox::new(self.bounds[base..base + d].to_vec(), self.bounds[base + d..base + 2 * d].to_vec())
    }

    #[inline]
    fn is_leaf(&self, i: u32) -> bool {
        self.node(i).left == NONE
    }

    #[inline]
    fn leaf_points(&self, i: u32) -> &[u32] {
        let n = self.node(i);
        &self.perm[n.lo as usize..n.hi as usize]
    }

    /// One-sweep leaf visit: computes the squared distance from `q` to
    /// every lane of leaf `n`'s coordinate block into `dbuf` and returns
    /// the leaf's point ids (lane `l` ↔ `ids[l]`; lanes past `ids.len()`
    /// are `+∞` padding and must not be consumed).
    #[inline]
    fn leaf_scan(&self, n: &Node, q: &[S], dbuf: &mut [S; BLOCK_LANES]) -> &[u32] {
        let lo = n.lo as usize;
        S::dist_sq_block(self.leaves.block(lo / BLOCK_MIN), self.pts.dim(), q, dbuf);
        &self.perm[lo..n.hi as usize]
    }

    // -----------------------------------------------------------------
    // Range count (Step 1 density): QUERY-RANGE(x, r) of the paper.
    // -----------------------------------------------------------------

    /// Count points within squared radius `r_sq` of `q`, **with** the §6.1
    /// subtree-count pruning.
    pub fn range_count<T: StatSink>(&self, q: &[S], r_sq: S, stats: &mut T) -> usize {
        self.range_count_rec(self.root, q, r_sq, true, stats, 1)
    }

    /// Unoptimized variant (no cell-containment shortcut) — models the
    /// DPC-EXACT-BASELINE density step, which iterates over every point in
    /// range.
    pub fn range_count_noprune<T: StatSink>(&self, q: &[S], r_sq: S, stats: &mut T) -> usize {
        self.range_count_rec(self.root, q, r_sq, false, stats, 1)
    }

    fn range_count_rec<T: StatSink>(&self, i: u32, q: &[S], r_sq: S, prune: bool, stats: &mut T, depth: usize) -> usize {
        stats.visit_node();
        stats.depth(depth);
        if self.bbox_dist_sq(i, q) > r_sq {
            return 0;
        }
        let n = self.node(i);
        if prune && self.bbox_far_corner_sq(i, q) <= r_sq {
            return (n.hi - n.lo) as usize;
        }
        if self.is_leaf(i) {
            let mut dbuf = [S::ZERO; BLOCK_LANES];
            let m = self.leaf_scan(n, q, &mut dbuf).len();
            let mut c = 0;
            for &ds in &dbuf[..m] {
                stats.scan_point();
                if ds <= r_sq {
                    c += 1;
                }
            }
            return c;
        }
        self.range_count_rec(n.left, q, r_sq, prune, stats, depth + 1)
            + self.range_count_rec(n.right, q, r_sq, prune, stats, depth + 1)
    }

    /// Report ids of points within squared radius `r_sq` of `q`.
    pub fn range_report(&self, q: &[S], r_sq: S, out: &mut Vec<u32>) {
        self.range_report_rec(self.root, q, r_sq, out);
    }

    /// Sum `weight(dist_sq)` over every point within squared radius `r_sq`
    /// of `q` — the traversal behind the fixed-point Gaussian density model.
    /// `u64` addition commutes and associates, so the sum is independent of
    /// traversal order and of how points are partitioned across trees (the
    /// streaming forest aggregates one sum over all its levels). No §6.1
    /// containment shortcut: per-point weights need per-point distances.
    pub fn range_weight_sum<T: StatSink, F: Fn(S) -> u64>(&self, q: &[S], r_sq: S, weight: &F, stats: &mut T) -> u64 {
        self.range_weight_sum_rec(self.root, q, r_sq, weight, stats, 1)
    }

    fn range_weight_sum_rec<T: StatSink, F: Fn(S) -> u64>(
        &self,
        i: u32,
        q: &[S],
        r_sq: S,
        weight: &F,
        stats: &mut T,
        depth: usize,
    ) -> u64 {
        stats.visit_node();
        stats.depth(depth);
        if self.bbox_dist_sq(i, q) > r_sq {
            return 0;
        }
        let n = self.node(i);
        if self.is_leaf(i) {
            let mut dbuf = [S::ZERO; BLOCK_LANES];
            let m = self.leaf_scan(n, q, &mut dbuf).len();
            let mut s = 0u64;
            for &ds in &dbuf[..m] {
                stats.scan_point();
                if ds <= r_sq {
                    s += weight(ds);
                }
            }
            return s;
        }
        self.range_weight_sum_rec(n.left, q, r_sq, weight, stats, depth + 1)
            + self.range_weight_sum_rec(n.right, q, r_sq, weight, stats, depth + 1)
    }

    fn range_report_rec(&self, i: u32, q: &[S], r_sq: S, out: &mut Vec<u32>) {
        if self.bbox_dist_sq(i, q) > r_sq {
            return;
        }
        let n = self.node(i);
        if self.bbox_far_corner_sq(i, q) <= r_sq {
            out.extend_from_slice(&self.perm[n.lo as usize..n.hi as usize]);
            return;
        }
        if self.is_leaf(i) {
            let mut dbuf = [S::ZERO; BLOCK_LANES];
            let ids = self.leaf_scan(n, q, &mut dbuf);
            for (l, &p) in ids.iter().enumerate() {
                if dbuf[l] <= r_sq {
                    out.push(p);
                }
            }
            return;
        }
        self.range_report_rec(n.left, q, r_sq, out);
        self.range_report_rec(n.right, q, r_sq, out);
    }

    // -----------------------------------------------------------------
    // Nearest neighbor: QUERY-NN(x) of the paper.
    // -----------------------------------------------------------------

    /// Nearest neighbor of `q`, excluding point id `exclude` (pass
    /// `u32::MAX` to exclude nothing). Ties broken by smaller id.
    /// Returns `(id, dist_sq)` or `None` if the tree holds only `exclude`.
    pub fn nn<T: StatSink>(&self, q: &[S], exclude: u32, stats: &mut T) -> Option<(u32, S)> {
        let mut best = (NONE, S::INFINITY);
        self.nn_rec(self.root, q, exclude, &mut best, stats, 1);
        if best.0 == NONE {
            None
        } else {
            Some(best)
        }
    }

    fn nn_rec<T: StatSink>(&self, i: u32, q: &[S], exclude: u32, best: &mut (u32, S), stats: &mut T, depth: usize) {
        stats.visit_node();
        stats.depth(depth);
        let n = self.node(i);
        if self.is_leaf(i) {
            let mut dbuf = [S::ZERO; BLOCK_LANES];
            let ids = self.leaf_scan(n, q, &mut dbuf);
            for (l, &p) in ids.iter().enumerate() {
                stats.scan_point();
                let ds = dbuf[l];
                if ds > best.1 || p == exclude {
                    continue;
                }
                if ds < best.1 || p < best.0 {
                    *best = (p, ds);
                }
            }
            return;
        }
        let dl = self.bbox_dist_sq(n.left, q);
        let dr = self.bbox_dist_sq(n.right, q);
        let (first, d1, second, d2) = if dl <= dr { (n.left, dl, n.right, dr) } else { (n.right, dr, n.left, dl) };
        if d1 <= best.1 {
            self.nn_rec(first, q, exclude, best, stats, depth + 1);
        }
        if d2 <= best.1 {
            self.nn_rec(second, q, exclude, best, stats, depth + 1);
        }
    }

    /// Nearest neighbor of `q` among points accepted by `keep`, folded into
    /// a running `best = (id, dist_sq)`. Pass `(u32::MAX, S::INFINITY)` to
    /// start fresh, or a previous winner to race it against this tree's
    /// points — the streaming forest threads one best through every level
    /// tree, and seeds it with a cached dependent so the traversal prunes at
    /// the old δ. Ordering matches [`KdTree::nn`]: min by `(dist_sq, id)`.
    pub fn nn_filtered<T: StatSink, F: Fn(u32) -> bool>(
        &self,
        q: &[S],
        keep: F,
        best: &mut (u32, S),
        stats: &mut T,
    ) {
        if self.bbox_dist_sq(self.root, q) > best.1 {
            return;
        }
        self.nn_filtered_rec(self.root, q, &keep, best, stats, 1);
    }

    fn nn_filtered_rec<T: StatSink, F: Fn(u32) -> bool>(
        &self,
        i: u32,
        q: &[S],
        keep: &F,
        best: &mut (u32, S),
        stats: &mut T,
        depth: usize,
    ) {
        stats.visit_node();
        stats.depth(depth);
        let n = self.node(i);
        if self.is_leaf(i) {
            let mut dbuf = [S::ZERO; BLOCK_LANES];
            let ids = self.leaf_scan(n, q, &mut dbuf);
            for (l, &p) in ids.iter().enumerate() {
                stats.scan_point();
                let ds = dbuf[l];
                if ds <= best.1 && (ds < best.1 || p < best.0) && keep(p) {
                    *best = (p, ds);
                }
            }
            return;
        }
        let dl = self.bbox_dist_sq(n.left, q);
        let dr = self.bbox_dist_sq(n.right, q);
        let (first, d1, second, d2) = if dl <= dr { (n.left, dl, n.right, dr) } else { (n.right, dr, n.left, dl) };
        if d1 <= best.1 {
            self.nn_filtered_rec(first, q, keep, best, stats, depth + 1);
        }
        if d2 <= best.1 {
            self.nn_filtered_rec(second, q, keep, best, stats, depth + 1);
        }
    }

    /// K nearest neighbors of `q` (excluding `exclude`), ascending by
    /// `(dist_sq, id)`.
    pub fn knn(&self, q: &[S], k: usize, exclude: u32) -> Vec<(u32, S)> {
        if k == 0 {
            return Vec::new();
        }
        let mut heap: Vec<(S, u32)> = Vec::with_capacity(k + 1); // max-heap by (dist, id)
        self.knn_rec(self.root, q, k, exclude, &mut heap);
        let mut out: Vec<(u32, S)> = heap.into_iter().map(|(d, p)| (p, d)).collect();
        // lint: allow(panic-surface) — heap distances come from finite
        // validated coordinates, so partial_cmp cannot see a NaN.
        out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
        out
    }

    /// The *k-th*-nearest-neighbor squared distance of `q` (excluding point
    /// id `exclude`): the largest distance among the k nearest by
    /// `(dist_sq, id)`, or `S::INFINITY` when fewer than `k` candidates
    /// exist — the exact quantity the `knn:<k>` density model ranks. Shares
    /// [`KdTree::knn`]'s bounded-heap traversal without materializing the
    /// sorted result.
    pub fn kth_nn_dist_sq(&self, q: &[S], k: usize, exclude: u32) -> S {
        debug_assert!(k >= 1, "k-NN radius needs k >= 1");
        let mut heap: Vec<(S, u32)> = Vec::with_capacity(k + 1);
        self.knn_rec(self.root, q, k, exclude, &mut heap);
        if heap.len() < k {
            S::INFINITY
        } else {
            heap[0].0
        }
    }

    /// Fold this tree's points into a caller-owned bounded kNN max-heap
    /// (ordered by `(dist_sq, id)`, capacity `k`). Threading one heap
    /// through several trees selects the k global minima of their union —
    /// selection under a total order is partition-independent — so the
    /// streaming forest's multi-tree k-NN equals the single-tree answer
    /// bit for bit. `heap[0].0` is the running k-th distance once the heap
    /// is full.
    pub fn knn_fold(&self, q: &[S], k: usize, exclude: u32, heap: &mut Vec<(S, u32)>) {
        if k == 0 {
            return;
        }
        self.knn_rec(self.root, q, k, exclude, heap);
    }

    fn knn_rec(&self, i: u32, q: &[S], k: usize, exclude: u32, heap: &mut Vec<(S, u32)>) {
        let bound = if heap.len() == k { heap[0].0 } else { S::INFINITY };
        if self.bbox_dist_sq(i, q) > bound {
            return;
        }
        let n = self.node(i);
        if self.is_leaf(i) {
            let mut dbuf = [S::ZERO; BLOCK_LANES];
            let ids = self.leaf_scan(n, q, &mut dbuf);
            for (l, &p) in ids.iter().enumerate() {
                if p == exclude {
                    continue;
                }
                let cand = (dbuf[l], p);
                if heap.len() < k {
                    heap.push(cand);
                    heap_up(heap);
                } else if cand < heap[0] {
                    heap[0] = cand;
                    heap_down(heap);
                }
            }
            return;
        }
        let dl = self.bbox_dist_sq(n.left, q);
        let dr = self.bbox_dist_sq(n.right, q);
        let (first, second) = if dl <= dr { (n.left, n.right) } else { (n.right, n.left) };
        self.knn_rec(first, q, k, exclude, heap);
        self.knn_rec(second, q, k, exclude, heap);
    }

    // Accessors for the incomplete-tree wrapper.
    pub(crate) fn root_idx(&self) -> u32 {
        self.root
    }
    pub(crate) fn num_slots(&self) -> usize {
        self.nodes.len()
    }
    pub(crate) fn parent_of(&self, i: u32) -> u32 {
        self.parent[i as usize]
    }
    pub(crate) fn leaf_of(&self, point: u32) -> u32 {
        self.leaf_of_point[point as usize]
    }
    pub(crate) fn is_leaf_idx(&self, i: u32) -> bool {
        self.is_leaf(i)
    }
    pub(crate) fn children(&self, i: u32) -> (u32, u32) {
        let n = self.node(i);
        (n.left, n.right)
    }
    pub(crate) fn bbox_dist(&self, i: u32, q: &[S]) -> S {
        self.bbox_dist_sq(i, q)
    }
    pub(crate) fn leaf_pts(&self, i: u32) -> &[u32] {
        self.leaf_points(i)
    }
    /// [`KdTree::leaf_scan`] by node index — the incomplete-tree wrapper's
    /// entry into the blocked leaf sweep.
    pub(crate) fn leaf_scan_idx(&self, i: u32, q: &[S], dbuf: &mut [S; BLOCK_LANES]) -> &[u32] {
        self.leaf_scan(self.node(i), q, dbuf)
    }
}

// ---------------------------------------------------------------------------
// Construction
// ---------------------------------------------------------------------------

/// Shared-nothing builder: subtree over `m` ids occupies exactly `2m-1`
/// contiguous node slots, so recursive halves write disjoint regions (raw
/// pointer writes, no locks). Works against a borrowed [`PointsView`] — the
/// finished tree pins the store separately.
struct Builder<'p, S: Scalar> {
    pts: PointsView<'p, S>,
    nodes_ptr: usize,
    bounds_ptr: usize,
    /// Base of the leaf-block arena; a leaf at perm offset `lo` owns block
    /// `lo / BLOCK_MIN` exclusively (offset ranges are disjoint across
    /// tasks), so block writes need no synchronization.
    arena_ptr: usize,
    parent_ptr: usize,
    leaf_ptr: usize,
    d: usize,
    pool: std::sync::Arc<parlay::Pool>,
}

// SAFETY: the raw base pointers are shared across build tasks, but each
// recursive task writes only its own subtree's slot range and leaf blocks
// (disjoint by the `2m-1` slot layout and the perm-offset block map), so
// concurrent `&Builder` access never races.
unsafe impl<S: Scalar> Sync for Builder<'_, S> {}

impl<S: Scalar> Builder<'_, S> {
    /// `ids` is the subrange of the permutation this subtree owns;
    /// `perm_off` its absolute offset; `slot` this node's arena index.
    fn build_rec(&self, ids: &mut [u32], perm_off: usize, slot: usize, parent: u32) {
        let m = ids.len();
        debug_assert!(m >= 1);
        let d = self.d;
        // Compute the cell (bbox of the subtree's points).
        let bb = self.compute_bbox(ids);
        // SAFETY: `slot` is this task's exclusively owned node index (see
        // the Sync impl above), inside arenas sized for the whole tree.
        unsafe {
            let bptr = (self.bounds_ptr as *mut S).add(slot * 2 * d);
            for k in 0..d {
                *bptr.add(k) = bb.min()[k];
                *bptr.add(d + k) = bb.max()[k];
            }
            if self.parent_ptr != 0 {
                *(self.parent_ptr as *mut u32).add(slot) = parent;
            }
        }
        if m <= LEAF_SIZE {
            // SAFETY: same exclusive ownership as above — `slot`, the leaf
            // block at `perm_off / BLOCK_MIN`, and the per-point leaf-map
            // entries for `ids` all belong to this task alone.
            unsafe {
                *(self.nodes_ptr as *mut Node).add(slot) = Node {
                    left: NONE,
                    right: NONE,
                    lo: perm_off as u32,
                    hi: (perm_off + m) as u32,
                };
                // Transpose this leaf's coordinates into its SoA block
                // (+∞ padding beyond lane m).
                leaf::fill_block(self.arena_ptr as *mut S, perm_off / BLOCK_MIN, self.pts.coords(), d, ids);
                if self.leaf_ptr != 0 {
                    let lp = self.leaf_ptr as *mut u32;
                    for &p in ids.iter() {
                        *lp.add(p as usize) = slot as u32;
                    }
                }
            }
            return;
        }
        let dim = bb.widest_dim();
        let mid = m / 2;
        let pts = self.pts;
        ids.select_nth_unstable_by(mid, |&a, &b| {
            pts.coord(a as usize, dim)
                .partial_cmp(&pts.coord(b as usize, dim))
                // lint: allow(panic-surface) — coordinates are validated
                // finite at ingest, so partial_cmp cannot see a NaN.
                .unwrap()
                .then(a.cmp(&b))
        });
        let (left_ids, right_ids) = ids.split_at_mut(mid);
        let left_slot = slot + 1;
        let right_slot = slot + 2 * mid; // left subtree occupies 2*mid-1 slots
        // SAFETY: `slot` is exclusively owned by this task (Sync impl).
        unsafe {
            *(self.nodes_ptr as *mut Node).add(slot) = Node {
                left: left_slot as u32,
                right: right_slot as u32,
                lo: perm_off as u32,
                hi: (perm_off + m) as u32,
            };
        }
        if m >= BUILD_GRAIN {
            self.pool.join(
                || self.build_rec(left_ids, perm_off, left_slot, slot as u32),
                || self.build_rec(right_ids, perm_off + mid, right_slot, slot as u32),
            );
        } else {
            self.build_rec(left_ids, perm_off, left_slot, slot as u32);
            self.build_rec(right_ids, perm_off + mid, right_slot, slot as u32);
        }
    }

    fn compute_bbox(&self, ids: &[u32]) -> Bbox<S> {
        let m = ids.len();
        if m < 65_536 {
            return self.pts.bbox_of(ids);
        }
        // Parallel chunked reduce for very large nodes. Grain 1: a few heavy
        // chunks would collapse to one sequential task under the auto grain.
        let nchunks = 16;
        let chunk = m.div_ceil(nchunks);
        let boxes: Vec<Bbox<S>> = parlay::par_map_grained(nchunks, 1, |c| {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(m);
            self.pts.bbox_of(&ids[lo..hi.max(lo)])
        });
        let mut bb = Bbox::empty(self.d);
        for b in &boxes {
            bb.merge(b);
        }
        bb
    }
}

// Small binary-heap helpers on a Vec<(S, u32)> max-heap (root = max).
fn heap_up<S: Scalar>(h: &mut [(S, u32)]) {
    let mut i = h.len() - 1;
    while i > 0 {
        let p = (i - 1) / 2;
        if h[i] > h[p] {
            h.swap(i, p);
            i = p;
        } else {
            break;
        }
    }
}

fn heap_down<S: Scalar>(h: &mut [(S, u32)]) {
    let n = h.len();
    let mut i = 0;
    loop {
        let (l, r) = (2 * i + 1, 2 * i + 2);
        let mut m = i;
        if l < n && h[l] > h[m] {
            m = l;
        }
        if r < n && h[r] > h[m] {
            m = r;
        }
        if m == i {
            break;
        }
        h.swap(i, m);
        i = m;
    }
}

// ---------------------------------------------------------------------------
// Brute-force oracles (shared by tests and property suites)
// ---------------------------------------------------------------------------

/// O(n) reference NN: min (dist_sq, id), excluding `exclude`.
pub fn brute_nn<S: Scalar>(pts: &PointStore<S>, q: &[S], exclude: u32) -> Option<(u32, S)> {
    let mut best: Option<(u32, S)> = None;
    for i in 0..pts.len() {
        if i as u32 == exclude {
            continue;
        }
        let ds = pts.dist_sq_to(i, q);
        match best {
            Some((bi, bd)) if ds > bd || (ds == bd && i as u32 > bi) => {}
            _ => best = Some((i as u32, ds)),
        }
    }
    best
}

/// O(n) reference filtered NN: min `(dist_sq, id)` over points accepted by
/// `keep`, folded into `best` with the same comparator as
/// [`KdTree::nn_filtered`].
pub fn brute_nn_filtered<S: Scalar, F: Fn(u32) -> bool>(pts: &PointStore<S>, q: &[S], keep: F, best: &mut (u32, S)) {
    for i in 0..pts.len() as u32 {
        if !keep(i) {
            continue;
        }
        let ds = pts.dist_sq_to(i as usize, q);
        if ds < best.1 || (ds == best.1 && i < best.0) {
            *best = (i, ds);
        }
    }
}

/// O(n) reference range count.
pub fn brute_range_count<S: Scalar>(pts: &PointStore<S>, q: &[S], r_sq: S) -> usize {
    (0..pts.len()).filter(|&i| pts.dist_sq_to(i, q) <= r_sq).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::{PointSet, PointStore};
    use crate::proputil::{gen_degenerate_points, gen_uniform_points};
    use crate::prng::SplitMix64;

    fn sample_points(seed: u64, n: usize, d: usize) -> PointSet {
        let mut rng = SplitMix64::new(seed);
        gen_uniform_points(&mut rng, n, d, 100.0)
    }

    #[test]
    fn nn_matches_brute_force_2d() {
        let pts = sample_points(1, 2000, 2);
        let tree = KdTree::build(&pts);
        for i in (0..pts.len()).step_by(37) {
            let q = pts.point(i);
            let got = tree.nn(q, i as u32, &mut NoStats).unwrap();
            let want = brute_nn(&pts, q, i as u32).unwrap();
            assert_eq!(got, want, "query {i}");
        }
    }

    #[test]
    fn nn_matches_brute_force_high_dim() {
        for d in [1, 3, 5, 8] {
            let pts = sample_points(d as u64, 500, d);
            let tree = KdTree::build(&pts);
            for i in (0..pts.len()).step_by(23) {
                let got = tree.nn(pts.point(i), i as u32, &mut NoStats).unwrap();
                let want = brute_nn(&pts, pts.point(i), i as u32).unwrap();
                assert_eq!(got, want, "d={d} query {i}");
            }
        }
    }

    #[test]
    fn f32_tree_matches_f32_brute_force() {
        let pts64 = sample_points(31, 800, 3);
        let pts = PointStore::<f32>::cast_from_f64(&pts64);
        let tree = KdTree::build(&pts);
        assert!(tree.points().shares_storage(&pts));
        for i in (0..pts.len()).step_by(19) {
            let q = pts.point(i);
            let got = tree.nn(q, i as u32, &mut NoStats).unwrap();
            let want = brute_nn(&pts, q, i as u32).unwrap();
            assert_eq!(got, want, "query {i}");
            let r_sq = 25.0f32;
            assert_eq!(tree.range_count(q, r_sq, &mut NoStats), brute_range_count(&pts, q, r_sq), "count {i}");
        }
    }

    #[test]
    fn tree_pins_store_by_refcount() {
        let pts = sample_points(32, 100, 2);
        let tree = KdTree::build(&pts);
        assert!(tree.points().shares_storage(&pts));
        // The original handle can drop; the tree keeps the buffer alive.
        let q = pts.point(0).to_vec();
        drop(pts);
        assert!(tree.nn(&q, u32::MAX, &mut NoStats).is_some());
    }

    #[test]
    fn nn_with_duplicates_ties_by_id() {
        let mut rng = SplitMix64::new(5);
        let pts = gen_degenerate_points(&mut rng, 120, 2);
        let tree = KdTree::build(&pts);
        for i in 0..pts.len() {
            let got = tree.nn(pts.point(i), i as u32, &mut NoStats).unwrap();
            let want = brute_nn(&pts, pts.point(i), i as u32).unwrap();
            assert_eq!(got, want, "query {i}");
        }
    }

    #[test]
    fn nn_filtered_matches_brute_force() {
        let pts = sample_points(9, 1500, 2);
        let tree = KdTree::build(&pts);
        // Random-looking but deterministic priority per id.
        let gamma: Vec<u64> = (0..pts.len() as u32).map(|i| (i as u64).wrapping_mul(0x9E37_79B9) % 1000).collect();
        for i in (0..pts.len()).step_by(29) {
            let q = pts.point(i);
            let gi = gamma[i];
            let mut got = (NONE, f64::INFINITY);
            tree.nn_filtered(q, |p| gamma[p as usize] > gi, &mut got, &mut NoStats);
            let mut want = (NONE, f64::INFINITY);
            brute_nn_filtered(&pts, q, |p| gamma[p as usize] > gi, &mut want);
            assert_eq!(got, want, "query {i}");
        }
    }

    #[test]
    fn nn_filtered_respects_seeded_best() {
        let pts = sample_points(10, 800, 3);
        let tree = KdTree::build(&pts);
        let q = pts.point(0);
        // Seed with the true NN (excluding self): no even-id point closer can
        // exist, so the seed must survive an odd-rejecting filter that would
        // otherwise pick a different point.
        let seed = brute_nn(&pts, q, 0).unwrap();
        let mut got = seed;
        tree.nn_filtered(q, |p| p % 2 == 0 && p != 0, &mut got, &mut NoStats);
        let mut want = seed;
        brute_nn_filtered(&pts, q, |p| p % 2 == 0 && p != 0, &mut want);
        assert_eq!(got, want);
        // And with an unreachable seed the filter result matches brute force.
        let mut got = (NONE, f64::INFINITY);
        tree.nn_filtered(q, |p| p % 2 == 1, &mut got, &mut NoStats);
        let mut want = (NONE, f64::INFINITY);
        brute_nn_filtered(&pts, q, |p| p % 2 == 1, &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn nn_filtered_rejecting_everything_leaves_best_untouched() {
        let pts = sample_points(11, 300, 2);
        let tree = KdTree::build(&pts);
        let mut best = (NONE, f64::INFINITY);
        tree.nn_filtered(pts.point(5), |_| false, &mut best, &mut NoStats);
        assert_eq!(best, (NONE, f64::INFINITY));
    }

    #[test]
    fn range_count_matches_brute_force() {
        let pts = sample_points(2, 3000, 3);
        let tree = KdTree::build(&pts);
        for (i, r) in [(0usize, 5.0f64), (100, 20.0), (500, 50.0), (999, 0.0), (1500, 200.0)] {
            let q = pts.point(i);
            let want = brute_range_count(&pts, q, r * r);
            assert_eq!(tree.range_count(q, r * r, &mut NoStats), want, "pruned i={i} r={r}");
            assert_eq!(tree.range_count_noprune(q, r * r, &mut NoStats), want, "noprune i={i} r={r}");
        }
    }

    #[test]
    fn range_report_matches_filter() {
        let pts = sample_points(3, 1000, 2);
        let tree = KdTree::build(&pts);
        let q = pts.point(123);
        let r_sq = 15.0 * 15.0;
        let mut got = Vec::new();
        tree.range_report(q, r_sq, &mut got);
        got.sort();
        let want: Vec<u32> =
            (0..pts.len() as u32).filter(|&i| pts.dist_sq_to(i as usize, q) <= r_sq).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn knn_matches_brute_force() {
        let pts = sample_points(4, 800, 3);
        let tree = KdTree::build(&pts);
        for k in [1usize, 5, 17] {
            let q = pts.point(42);
            let got = tree.knn(q, k, 42);
            let mut all: Vec<(u32, f64)> = (0..pts.len() as u32)
                .filter(|&i| i != 42)
                .map(|i| (i, pts.dist_sq_to(i as usize, q)))
                .collect();
            all.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
            all.truncate(k);
            assert_eq!(got, all, "k={k}");
        }
    }

    #[test]
    fn kth_nn_dist_matches_brute_force() {
        let pts = sample_points(12, 700, 3);
        let tree = KdTree::build(&pts);
        for i in (0..pts.len()).step_by(31) {
            let q = pts.point(i);
            let mut ds: Vec<f64> =
                (0..pts.len()).filter(|&j| j != i).map(|j| pts.dist_sq_to(j, q)).collect();
            ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for k in [1usize, 4, 13] {
                assert_eq!(tree.kth_nn_dist_sq(q, k, i as u32), ds[k - 1], "i={i} k={k}");
            }
        }
        // Fewer than k candidates => infinity.
        let tiny = PointSet::new(vec![0.0, 0.0, 1.0, 1.0], 2);
        let t = KdTree::build(&tiny);
        assert_eq!(t.kth_nn_dist_sq(tiny.point(0), 1, 0), 2.0);
        assert_eq!(t.kth_nn_dist_sq(tiny.point(0), 2, 0), f64::INFINITY);
    }

    #[test]
    fn knn_fold_over_a_partition_matches_one_tree() {
        let pts = sample_points(13, 900, 2);
        let whole = KdTree::build(&pts);
        // Partition ids into three arbitrary trees (a mini Bentley–Saxe
        // forest) and fold one heap through all of them.
        let parts: Vec<Vec<u32>> = (0..3)
            .map(|r| (0..pts.len() as u32).filter(|i| i % 3 == r).collect())
            .collect();
        let trees: Vec<KdTree> = parts.into_iter().map(|ids| KdTree::build_from_ids(&pts, ids)).collect();
        for i in (0..pts.len()).step_by(41) {
            let q = pts.point(i);
            for k in [1usize, 5] {
                let mut heap = Vec::with_capacity(k + 1);
                for t in &trees {
                    t.knn_fold(q, k, i as u32, &mut heap);
                }
                assert_eq!(heap.len(), k);
                assert_eq!(heap[0].0, whole.kth_nn_dist_sq(q, k, i as u32), "i={i} k={k}");
            }
        }
    }

    #[test]
    fn range_weight_sum_matches_brute_force_and_partitions() {
        let mut rng = SplitMix64::new(14);
        let pts = gen_degenerate_points(&mut rng, 300, 2);
        let tree = KdTree::build(&pts);
        let r_sq = 9.0f64;
        // An arbitrary deterministic integer weight of the distance.
        let weight = |ds: f64| (ds * 100.0).round() as u64 + 1;
        for i in (0..pts.len()).step_by(17) {
            let q = pts.point(i);
            let want: u64 =
                (0..pts.len()).map(|j| pts.dist_sq_to(j, q)).filter(|&ds| ds <= r_sq).map(weight).sum();
            assert_eq!(tree.range_weight_sum(q, r_sq, &weight, &mut NoStats), want, "query {i}");
            // Partition independence: two half-trees sum to the same value.
            let evens: Vec<u32> = (0..pts.len() as u32).filter(|i| i % 2 == 0).collect();
            let odds: Vec<u32> = (0..pts.len() as u32).filter(|i| i % 2 == 1).collect();
            let a = KdTree::build_from_ids(&pts, evens);
            let b = KdTree::build_from_ids(&pts, odds);
            let split = a.range_weight_sum(q, r_sq, &weight, &mut NoStats)
                + b.range_weight_sum(q, r_sq, &weight, &mut NoStats);
            assert_eq!(split, want, "partitioned query {i}");
        }
    }

    #[test]
    fn build_from_subset_queries_only_subset() {
        let pts = sample_points(6, 500, 2);
        let ids: Vec<u32> = (0..500u32).filter(|i| i % 2 == 0).collect();
        let tree = KdTree::build_from_ids(&pts, ids.clone());
        assert_eq!(tree.size(), ids.len());
        let q = pts.point(1); // odd point, not in tree
        let got = tree.nn(q, NONE, &mut NoStats).unwrap();
        assert!(got.0 % 2 == 0);
        // brute force over subset
        let mut best = (NONE, f64::INFINITY);
        for &i in &ids {
            let ds = pts.dist_sq_to(i as usize, q);
            if ds < best.1 || (ds == best.1 && i < best.0) {
                best = (i, ds);
            }
        }
        assert_eq!(got, best);
    }

    #[test]
    fn single_point_tree() {
        let pts = PointSet::new(vec![1.0, 2.0], 2);
        let tree = KdTree::build(&pts);
        assert_eq!(tree.nn(&[0.0, 0.0], NONE, &mut NoStats), Some((0, 5.0)));
        assert_eq!(tree.nn(&[0.0, 0.0], 0, &mut NoStats), None);
        assert_eq!(tree.range_count(&[1.0, 2.0], 0.0, &mut NoStats), 1);
    }

    #[test]
    fn stats_are_collected() {
        let pts = sample_points(7, 5000, 2);
        let tree = KdTree::build(&pts);
        let mut st = Stats::default();
        tree.nn(pts.point(0), 0, &mut st);
        assert!(st.nodes_visited > 0);
        assert!(st.max_depth > 1);
        // With pruning the visited count for a huge radius is tiny (root
        // containment) vs noprune which must touch every leaf.
        let mut s1 = Stats::default();
        let mut s2 = Stats::default();
        tree.range_count(pts.point(0), 1e12, &mut s1);
        tree.range_count_noprune(pts.point(0), 1e12, &mut s2);
        assert!(s1.nodes_visited < s2.nodes_visited / 10, "{} vs {}", s1.nodes_visited, s2.nodes_visited);
    }

    /// The structural invariant behind index-free block addressing: every
    /// leaf holds 8–16 points (except a lone root leaf on tiny inputs),
    /// and `lo / BLOCK_MIN` never collides across leaves.
    #[test]
    fn leaf_sizes_and_block_indices_are_well_formed() {
        for n in [1usize, 7, 16, 17, 100, 1000, 4097] {
            let pts = sample_points(n as u64, n, 3);
            let tree = KdTree::build(&pts);
            let mut seen = std::collections::HashSet::new();
            for (i, node) in tree.nodes.iter().enumerate() {
                if node.left != NONE {
                    continue;
                }
                let (lo, hi) = (node.lo as usize, node.hi as usize);
                let m = hi - lo;
                assert!((1..=LEAF_SIZE).contains(&m), "n={n} leaf {i} has {m} points");
                if n > LEAF_SIZE {
                    assert!(m >= BLOCK_MIN, "n={n} leaf {i} has {m} < {BLOCK_MIN} points");
                }
                assert!(seen.insert(lo / BLOCK_MIN), "n={n} block collision at lo={lo}");
                assert!(lo / BLOCK_MIN < tree.leaves.blocks(), "n={n} block index out of range");
                // The block's live lanes hold exactly the leaf's coordinates.
                let blk = tree.leaves.block(lo / BLOCK_MIN);
                for (l, &p) in tree.perm[lo..hi].iter().enumerate() {
                    for k in 0..3 {
                        assert_eq!(blk[k * BLOCK_LANES + l], pts.coord(p as usize, k));
                    }
                }
                for l in m..BLOCK_LANES {
                    assert_eq!(blk[l], f64::INFINITY, "n={n} lane {l} not padded");
                }
            }
        }
    }

    /// The SIMD and forced-scalar leaf sweeps must agree bit for bit on
    /// whole-tree query results (the in-process half of the differential
    /// contract; the oracle suite runs the full-pipeline half).
    #[test]
    fn forced_scalar_kernel_is_byte_identical() {
        use crate::geom::{force_scalar_kernel, kernel_toggle_guard};
        let _serial = kernel_toggle_guard();
        let pts = sample_points(77, 1200, 3);
        let tree = KdTree::build(&pts);
        let queries: Vec<usize> = (0..pts.len()).step_by(97).collect();
        let run = |t: &KdTree| -> Vec<(usize, u64, (u32, f64), f64)> {
            queries
                .iter()
                .map(|&i| {
                    let q = pts.point(i);
                    (
                        t.range_count(q, 49.0, &mut NoStats),
                        t.range_weight_sum(q, 49.0, &|ds| (ds * 8.0) as u64, &mut NoStats),
                        t.nn(q, i as u32, &mut NoStats).unwrap(),
                        t.kth_nn_dist_sq(q, 5, i as u32),
                    )
                })
                .collect()
        };
        let default_path = run(&tree);
        force_scalar_kernel(true);
        let scalar_path = run(&tree);
        force_scalar_kernel(false);
        assert_eq!(default_path, scalar_path);
    }

    #[test]
    fn parent_and_leaf_maps_consistent() {
        let pts = sample_points(8, 1000, 2);
        let tree = KdTree::build_with_maps(&pts);
        assert_eq!(tree.parent_of(tree.root_idx()), NONE);
        for p in 0..pts.len() as u32 {
            let leaf = tree.leaf_of(p);
            assert!(tree.is_leaf_idx(leaf));
            assert!(tree.leaf_pts(leaf).contains(&p));
            // walk to root
            let mut cur = leaf;
            let mut steps = 0;
            while tree.parent_of(cur) != NONE {
                cur = tree.parent_of(cur);
                steps += 1;
                assert!(steps < 64, "parent chain too long");
            }
            assert_eq!(cur, tree.root_idx());
        }
    }
}
