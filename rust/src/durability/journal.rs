//! The write-ahead journal: an append-only, **segmented** log of every
//! state-changing serve-mode command, durable before the command is
//! acknowledged.
//!
//! ## On-disk layout (`journal-<seq>.pclj`, decimal seq from 1)
//!
//! The log is a sequence of segment files with contiguous sequence
//! numbers. Each segment:
//!
//! ```text
//! header:  magic "PCLJ" (4) | version u32 LE | seq u64 LE | first_lsn u64 LE   — 24 bytes
//! frame:   len u32 LE | crc u32 LE | payload (len bytes)
//! payload: lsn u64 LE | kind u8 | body (kind-specific, see JournalEntry)
//! ```
//!
//! The CRC-32 covers the payload only. LSNs are contiguous from 1 across
//! the whole *log* — each segment header pins where its slice of the
//! sequence starts, so a scan can verify continuity across segment
//! boundaries without trusting filenames alone (the header `seq` must
//! also match the filename).
//!
//! ## Rotation and GC
//!
//! [`JournalWriter::append`] seals the live segment and opens the next
//! one when a frame would push it past the configured `rotate_bytes`
//! threshold (0 = never rotate). Sealing syncs the old file **before**
//! the new one is created, so a crash can never leave an unsynced torn
//! tail in a non-final segment. Checkpoints advance the manifest's
//! replay position to a `(seq, offset)` pair; after the manifest flip,
//! whole segments strictly below that horizon are deleted
//! ([`super::checkpoint::write`]) in ascending order — the surviving
//! files are always a contiguous suffix, and on-disk journal bytes are
//! bounded by the live segments past the horizon instead of the full
//! history.
//!
//! ## Torn tail vs corruption
//!
//! [`scan_dir`] distinguishes the two failure shapes a crash can leave:
//!
//! - **Torn tail** — the *final* segment ends before a frame's declared
//!   bytes are all present. This is the expected result of dying
//!   mid-`write`; the scan reports the incomplete suffix (`torn_bytes`)
//!   and recovery truncates it silently. Every acknowledged entry is
//!   still intact.
//! - **Corruption** — a complete frame whose CRC mismatches, whose LSN
//!   breaks the contiguous sequence, or whose payload does not decode —
//!   or a short frame in any segment *other than the last* (sealed
//!   segments were synced whole; a hole there can only be bit rot or
//!   interference). These surface as [`DpcError::CorruptJournal`] with
//!   the byte offset — never a partial parse.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::dpc::DensityModel;
use crate::error::DpcError;
use crate::geom::{Dtype, DynPoints};

use super::crc32::crc32;
use super::wire::{self, Cursor};

pub const JOURNAL_MAGIC: [u8; 4] = *b"PCLJ";
pub const JOURNAL_VERSION: u32 = 2;
/// Header length: magic + version + seq + first_lsn.
pub const JOURNAL_HEADER_LEN: u64 = 24;
/// Frame prefix: len + crc.
const FRAME_PREFIX: usize = 8;

/// Filename of journal segment `seq` (`journal-<seq>.pclj`).
pub fn segment_file(seq: u64) -> String {
    format!("journal-{seq}.pclj")
}

/// Inverse of [`segment_file`]: parse a directory entry name, `None` for
/// anything that is not a journal segment.
pub fn parse_segment_name(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("journal-")?.strip_suffix(".pclj")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Every journal segment in `dir`, sorted ascending by seq. Does not
/// open the files — callers decide which suffix to scan.
pub fn list_segments(dir: &Path) -> Result<Vec<(u64, PathBuf)>, DpcError> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        if let Some(seq) = entry.file_name().to_str().and_then(parse_segment_name) {
            out.push((seq, entry.path()));
        }
    }
    out.sort_by_key(|&(seq, _)| seq);
    Ok(out)
}

/// Check that an encoded payload fits the frame format's u32 length field,
/// returning the prefix value to write. A >4 GiB batch (≈270M f64 2-d
/// points in one ingest) would otherwise wrap `as u32` and poison the
/// journal; separated out so the bound is testable without allocating one.
fn check_frame_len(len: usize) -> Result<u32, DpcError> {
    u32::try_from(len).map_err(|_| DpcError::OversizedJournalEntry { len: len as u64, max: u32::MAX as u64 })
}

/// One logged command. Bodies mirror the coordinator's public API inputs
/// exactly — replay feeds them back through the same entry points.
#[derive(Clone, Debug)]
pub enum JournalEntry {
    /// `open_stream`: a new streaming session.
    OpenStream { stream: u64, dim: u32, dtype: Dtype, d_cut: f64, density: DensityModel },
    /// `ingest`: one batch appended to a stream, with the cut parameters
    /// in effect for the post-ingest artifact refresh.
    Ingest { stream: u64, rho_min: f64, delta_min: f64, batch: DynPoints },
    /// `close_stream`.
    CloseStream { stream: u64 },
    /// `open_session`: a one-shot (non-streaming) clustering session.
    OpenSession { session: u64, d_cut: f64, density: DensityModel, pts: DynPoints },
    /// `recut`: re-threshold an open session. Replay recomputes the same
    /// cached artifacts from `OpenSession`, so this entry is audit-only.
    Recut { session: u64, rho_min: f64, delta_min: f64 },
    /// `close_session`.
    CloseSession { session: u64 },
}

impl JournalEntry {
    pub fn kind_name(&self) -> &'static str {
        match self {
            JournalEntry::OpenStream { .. } => "open-stream",
            JournalEntry::Ingest { .. } => "ingest",
            JournalEntry::CloseStream { .. } => "close-stream",
            JournalEntry::OpenSession { .. } => "open-session",
            JournalEntry::Recut { .. } => "recut",
            JournalEntry::CloseSession { .. } => "close-session",
        }
    }

    fn encode_body(&self, out: &mut Vec<u8>) {
        match self {
            JournalEntry::OpenStream { stream, dim, dtype, d_cut, density } => {
                out.push(1);
                wire::put_u64(out, *stream);
                wire::put_u32(out, *dim);
                out.push(dtype.size_bytes() as u8);
                wire::put_f64(out, *d_cut);
                wire::put_density(out, *density);
            }
            JournalEntry::Ingest { stream, rho_min, delta_min, batch } => {
                out.push(2);
                wire::put_u64(out, *stream);
                wire::put_f64(out, *rho_min);
                wire::put_f64(out, *delta_min);
                wire::put_points(out, batch);
            }
            JournalEntry::CloseStream { stream } => {
                out.push(3);
                wire::put_u64(out, *stream);
            }
            JournalEntry::OpenSession { session, d_cut, density, pts } => {
                out.push(4);
                wire::put_u64(out, *session);
                wire::put_f64(out, *d_cut);
                wire::put_density(out, *density);
                wire::put_points(out, pts);
            }
            JournalEntry::Recut { session, rho_min, delta_min } => {
                out.push(5);
                wire::put_u64(out, *session);
                wire::put_f64(out, *rho_min);
                wire::put_f64(out, *delta_min);
            }
            JournalEntry::CloseSession { session } => {
                out.push(6);
                wire::put_u64(out, *session);
            }
        }
    }

    fn decode(cur: &mut Cursor<'_>) -> Result<JournalEntry, String> {
        let kind = cur.u8()?;
        let entry = match kind {
            1 => {
                let stream = cur.u64()?;
                let dim = cur.u32()?;
                let tag = cur.u8()?;
                let dtype =
                    Dtype::from_tag(tag).ok_or_else(|| format!("unknown dtype tag {tag}"))?;
                let d_cut = cur.f64()?;
                let density = wire::get_density(cur)?;
                JournalEntry::OpenStream { stream, dim, dtype, d_cut, density }
            }
            2 => JournalEntry::Ingest {
                stream: cur.u64()?,
                rho_min: cur.f64()?,
                delta_min: cur.f64()?,
                batch: wire::get_points(cur)?,
            },
            3 => JournalEntry::CloseStream { stream: cur.u64()? },
            4 => JournalEntry::OpenSession {
                session: cur.u64()?,
                d_cut: cur.f64()?,
                density: wire::get_density(cur)?,
                pts: wire::get_points(cur)?,
            },
            5 => JournalEntry::Recut {
                session: cur.u64()?,
                rho_min: cur.f64()?,
                delta_min: cur.f64()?,
            },
            6 => JournalEntry::CloseSession { session: cur.u64()? },
            other => return Err(format!("unknown journal entry kind {other}")),
        };
        cur.expect_end(entry.kind_name())?;
        Ok(entry)
    }
}

fn encode_header(seq: u64, first_lsn: u64) -> Vec<u8> {
    let mut header = Vec::with_capacity(JOURNAL_HEADER_LEN as usize);
    header.extend_from_slice(&JOURNAL_MAGIC);
    wire::put_u32(&mut header, JOURNAL_VERSION);
    wire::put_u64(&mut header, seq);
    wire::put_u64(&mut header, first_lsn);
    header
}

/// Best-effort directory fsync so a just-created or just-deleted segment
/// entry survives a crash; on filesystems that refuse to fsync dirs this
/// degrades gracefully (same policy as the manifest flip).
fn sync_dir(dir: &Path) {
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
}

/// Append handle over the segment chain. All writes go through
/// [`JournalWriter::append`], which assigns the LSN, frames, checksums,
/// rotates at the byte threshold, and applies the fsync policy.
#[derive(Debug)]
pub struct JournalWriter {
    file: File,
    dir: PathBuf,
    /// Sequence number of the live (last) segment.
    seq: u64,
    /// Current end-of-segment byte offset (== live segment length).
    len: u64,
    next_lsn: u64,
    /// `1` = fsync every append (default), `N` = group-commit every N
    /// appends, `0` = never (the OS flushes; an acknowledged-but-unsynced
    /// suffix may be lost to a crash, but what survives is always a
    /// consistent prefix).
    fsync_every: u64,
    unsynced: u64,
    /// Rotate to a new segment when the live one would exceed this many
    /// bytes (0 = never rotate — the PR-6 single-file behaviour).
    rotate_bytes: u64,
}

impl JournalWriter {
    /// Create a fresh journal: segment 1, header only, synced. Fails if
    /// the segment already exists — an existing journal must be scanned,
    /// not clobbered.
    pub fn create(dir: &Path, fsync_every: u64, rotate_bytes: u64) -> Result<Self, DpcError> {
        let path = dir.join(segment_file(1));
        let mut file = OpenOptions::new().write(true).create_new(true).open(&path)?;
        file.write_all(&encode_header(1, 1))?;
        file.sync_data()?;
        sync_dir(dir);
        Ok(JournalWriter {
            file,
            dir: dir.to_path_buf(),
            seq: 1,
            len: JOURNAL_HEADER_LEN,
            next_lsn: 1,
            fsync_every,
            unsynced: 0,
            rotate_bytes,
        })
    }

    /// Open the *last* segment of an existing journal for appending at
    /// `valid_len`, truncating any torn tail beyond it (as reported by
    /// [`scan_dir`]).
    pub fn open_end(
        dir: &Path,
        seq: u64,
        valid_len: u64,
        next_lsn: u64,
        fsync_every: u64,
        rotate_bytes: u64,
    ) -> Result<Self, DpcError> {
        let path = dir.join(segment_file(seq));
        let file = OpenOptions::new().read(true).write(true).open(&path)?;
        if file.metadata()?.len() > valid_len {
            file.set_len(valid_len)?;
            file.sync_data()?;
        }
        let mut file = file;
        file.seek(SeekFrom::Start(valid_len))?;
        Ok(JournalWriter {
            file,
            dir: dir.to_path_buf(),
            seq,
            len: valid_len,
            next_lsn,
            fsync_every,
            unsynced: 0,
            rotate_bytes,
        })
    }

    /// Directory holding the segment chain.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of the live segment.
    pub fn path(&self) -> PathBuf {
        self.dir.join(segment_file(self.seq))
    }

    /// Sequence number of the live segment.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Byte offset one past the last framed entry in the live segment.
    pub fn len(&self) -> u64 {
        self.len
    }

    /// The replay position a checkpoint taken *now* should record:
    /// `(live segment seq, offset one past the last framed entry)`.
    pub fn position(&self) -> (u64, u64) {
        (self.seq, self.len)
    }

    /// No entries in the live segment (rotation never leaves an empty
    /// sealed segment behind, so for segment 1 this means an empty log).
    pub fn is_empty(&self) -> bool {
        self.len == JOURNAL_HEADER_LEN
    }

    /// The LSN the next [`JournalWriter::append`] will assign.
    pub fn next_lsn(&self) -> u64 {
        self.next_lsn
    }

    /// Seal the live segment and start the next one. Ordering is the
    /// crash-safety argument: the old segment is fsynced *before* the new
    /// file exists, so once a successor segment is visible, every sealed
    /// predecessor is complete on disk — which is exactly the invariant
    /// that lets [`scan_dir`] treat a short frame in a non-final segment
    /// as corruption. A crash between the sync and the create just leaves
    /// a full, still-live segment (recovery reopens it and rotates on the
    /// next append); a crash after the create leaves a header-only final
    /// segment (a legal empty tail).
    fn rotate(&mut self) -> Result<(), DpcError> {
        self.file.sync_data()?;
        self.unsynced = 0;
        let next_seq = self.seq + 1;
        let path = self.dir.join(segment_file(next_seq));
        let mut file = OpenOptions::new().write(true).create_new(true).open(&path)?;
        file.write_all(&encode_header(next_seq, self.next_lsn))?;
        file.sync_data()?;
        sync_dir(&self.dir);
        self.file = file;
        self.seq = next_seq;
        self.len = JOURNAL_HEADER_LEN;
        Ok(())
    }

    /// Frame, checksum, and write `entry`; returns its LSN. Durability
    /// follows the `fsync_every` policy — callers that need a hard
    /// guarantee right now (checkpointing) call [`JournalWriter::sync`].
    ///
    /// Payloads that overflow the frame format's u32 length field are
    /// rejected with [`DpcError::OversizedJournalEntry`] before a single
    /// byte hits the file — a silently-truncated length prefix would frame
    /// the entry's own bytes as garbage follow-on frames and corrupt the
    /// journal for every later reader.
    pub fn append(&mut self, entry: &JournalEntry) -> Result<u64, DpcError> {
        let lsn = self.next_lsn;
        let mut payload = Vec::with_capacity(64);
        wire::put_u64(&mut payload, lsn);
        entry.encode_body(&mut payload);
        let len = check_frame_len(payload.len())?;
        let mut frame = Vec::with_capacity(FRAME_PREFIX + payload.len());
        wire::put_u32(&mut frame, len);
        wire::put_u32(&mut frame, crc32(&payload));
        frame.extend_from_slice(&payload);
        // Rotate first if this frame would push a non-empty live segment
        // past the threshold: segments stay under `rotate_bytes` unless a
        // single frame alone exceeds it.
        if self.rotate_bytes != 0
            && self.len > JOURNAL_HEADER_LEN
            && self.len + frame.len() as u64 > self.rotate_bytes
        {
            self.rotate()?;
        }
        self.file.write_all(&frame)?;
        self.len += frame.len() as u64;
        self.next_lsn += 1;
        self.unsynced += 1;
        if self.fsync_every != 0 && self.unsynced >= self.fsync_every {
            self.sync()?;
        }
        Ok(lsn)
    }

    /// Force everything appended so far to stable storage. (Sealed
    /// segments were synced at rotation; only the live one can be dirty.)
    pub fn sync(&mut self) -> Result<(), DpcError> {
        if self.unsynced > 0 || self.fsync_every != 1 {
            self.file.sync_data()?;
        }
        self.unsynced = 0;
        Ok(())
    }
}

/// One decoded frame, with its position (segment + byte offset) for
/// error reporting and checkpoint replay offsets.
#[derive(Clone, Debug)]
pub struct ScannedFrame {
    /// Segment the frame lives in.
    pub seq: u64,
    /// Byte offset of the frame's length prefix within that segment.
    pub offset: u64,
    pub lsn: u64,
    pub entry: JournalEntry,
}

/// Per-segment summary from a [`scan_dir`] pass (sizes for `journal
/// inspect`, the tail state for recovery).
#[derive(Clone, Debug)]
pub struct SegmentInfo {
    pub seq: u64,
    pub path: PathBuf,
    /// LSN of the segment's first frame, from its header.
    pub first_lsn: u64,
    pub frames: usize,
    /// Byte offset one past the last fully-valid frame.
    pub valid_len: u64,
    /// Bytes of incomplete final frame beyond `valid_len` (0 = clean;
    /// nonzero is only legal in the last segment).
    pub torn_bytes: u64,
}

/// Result of scanning a segment chain.
#[derive(Debug)]
pub struct ScanOutcome {
    /// Every decoded frame across the scanned segments, in LSN order.
    pub entries: Vec<ScannedFrame>,
    /// The scanned segments, ascending by seq (never empty).
    pub segments: Vec<SegmentInfo>,
    /// Torn bytes in the final segment (0 = clean shutdown).
    pub torn_bytes: u64,
    /// The LSN a writer reopened at the end of the chain should assign
    /// next.
    pub next_lsn: u64,
}

impl ScanOutcome {
    /// The live (last) segment's seq.
    pub fn last_seq(&self) -> u64 {
        // lint: allow(panic-surface) — scan_dir never returns an empty
        // segment list (it errors instead), so last() always exists.
        self.segments.last().expect("scan has at least one segment").seq
    }

    /// Valid byte length of the live segment — where appends resume.
    pub fn valid_len(&self) -> u64 {
        // lint: allow(panic-surface) — same invariant as last_seq.
        self.segments.last().expect("scan has at least one segment").valid_len
    }
}

struct SegmentScan {
    first_lsn: u64,
    entries: Vec<ScannedFrame>,
    valid_len: u64,
    torn_bytes: u64,
    next_lsn: u64,
}

/// Read and validate one segment file. `expect_seq` pins the header's
/// seq to the filename; LSN continuity against the *chain* is the
/// caller's job (it knows the running expected LSN).
fn scan_segment(path: &Path, expect_seq: u64) -> Result<SegmentScan, DpcError> {
    let mut buf = Vec::new();
    File::open(path)?.read_to_end(&mut buf)?;
    if buf.len() < JOURNAL_HEADER_LEN as usize {
        return Err(DpcError::CorruptJournal {
            offset: 0,
            detail: format!(
                "segment {expect_seq} is {} bytes, shorter than the {JOURNAL_HEADER_LEN}-byte header",
                buf.len()
            ),
        });
    }
    if buf[..4] != JOURNAL_MAGIC {
        return Err(DpcError::CorruptJournal {
            offset: 0,
            detail: format!("segment {expect_seq}: bad magic {:?} (want \"PCLJ\")", &buf[..4]),
        });
    }
    let mut cur = Cursor::new(&buf[4..JOURNAL_HEADER_LEN as usize]);
    let header = (|| -> Result<(u32, u64, u64), String> {
        Ok((cur.u32()?, cur.u64()?, cur.u64()?))
    })();
    // bounds: the length check above proved JOURNAL_HEADER_LEN bytes exist,
    // so the three header reads cannot fail; keep the Result plumbing for
    // totality anyway.
    let (version, seq, first_lsn) =
        header.map_err(|detail| DpcError::CorruptJournal { offset: 4, detail })?;
    if version != JOURNAL_VERSION {
        return Err(DpcError::CorruptJournal {
            offset: 4,
            detail: format!("unsupported journal version {version} (want {JOURNAL_VERSION})"),
        });
    }
    if seq != expect_seq {
        return Err(DpcError::CorruptJournal {
            offset: 8,
            detail: format!("segment header carries seq {seq}, filename says {expect_seq}"),
        });
    }
    if first_lsn == 0 {
        return Err(DpcError::CorruptJournal {
            offset: 16,
            detail: format!("segment {seq} header carries first_lsn 0 (LSNs start at 1)"),
        });
    }

    let mut entries = Vec::new();
    let mut pos = JOURNAL_HEADER_LEN as usize;
    let mut expected_lsn = first_lsn;
    while pos < buf.len() {
        let avail = buf.len() - pos;
        if avail < FRAME_PREFIX {
            break; // torn: not even a full frame prefix
        }
        let len = u32::from_le_bytes([buf[pos], buf[pos + 1], buf[pos + 2], buf[pos + 3]]) as usize;
        let crc = u32::from_le_bytes([buf[pos + 4], buf[pos + 5], buf[pos + 6], buf[pos + 7]]);
        if avail < FRAME_PREFIX + len {
            break; // torn: payload incomplete
        }
        let payload = &buf[pos + FRAME_PREFIX..pos + FRAME_PREFIX + len];
        if crc32(payload) != crc {
            return Err(DpcError::CorruptJournal {
                offset: pos as u64,
                detail: format!(
                    "segment {seq}: frame CRC mismatch (stored {crc:#010x}, computed {:#010x})",
                    crc32(payload)
                ),
            });
        }
        let mut cur = Cursor::new(payload);
        let lsn = cur.u64().map_err(|detail| DpcError::CorruptJournal { offset: pos as u64, detail })?;
        if lsn != expected_lsn {
            return Err(DpcError::CorruptJournal {
                offset: pos as u64,
                detail: format!(
                    "segment {seq}: LSN discontinuity: frame carries {lsn}, expected {expected_lsn}"
                ),
            });
        }
        let entry = JournalEntry::decode(&mut cur)
            .map_err(|detail| DpcError::CorruptJournal { offset: pos as u64, detail })?;
        entries.push(ScannedFrame { seq, offset: pos as u64, lsn, entry });
        expected_lsn += 1;
        pos += FRAME_PREFIX + len;
    }
    Ok(SegmentScan {
        first_lsn,
        entries,
        valid_len: pos as u64,
        torn_bytes: (buf.len() - pos) as u64,
        next_lsn: expected_lsn,
    })
}

/// Read and validate the segment chain from `from_seq` to the end.
///
/// Segments strictly below `from_seq` are ignored — they are below the
/// caller's replay horizon (a crash between a manifest flip and the GC
/// sweep legally leaves such leftovers; the next checkpoint deletes
/// them). The scanned suffix must be seq-contiguous, LSN-contiguous
/// across boundaries, and whole except for a torn tail in the *final*
/// segment; anything else is [`DpcError::CorruptJournal`].
pub fn scan_dir(dir: &Path, from_seq: u64) -> Result<ScanOutcome, DpcError> {
    let all = list_segments(dir)?;
    let chain: Vec<&(u64, PathBuf)> = all.iter().filter(|&&(seq, _)| seq >= from_seq).collect();
    if chain.is_empty() {
        return Err(DpcError::CorruptJournal {
            offset: 0,
            detail: format!("no journal segment at or above seq {from_seq} in {}", dir.display()),
        });
    }
    if chain[0].0 != from_seq {
        return Err(DpcError::CorruptJournal {
            offset: 0,
            detail: format!("journal segment {from_seq} is missing (chain starts at {})", chain[0].0),
        });
    }
    let mut entries = Vec::new();
    let mut segments = Vec::new();
    let mut expected_lsn: Option<u64> = None;
    for (i, &&(seq, ref path)) in chain.iter().enumerate() {
        if i > 0 && seq != chain[i - 1].0 + 1 {
            return Err(DpcError::CorruptJournal {
                offset: 0,
                detail: format!("segment gap: {} is followed by {seq}", chain[i - 1].0),
            });
        }
        let s = scan_segment(path, seq)?;
        if let Some(want) = expected_lsn {
            if s.first_lsn != want {
                return Err(DpcError::CorruptJournal {
                    offset: 16,
                    detail: format!(
                        "segment {seq} header claims first LSN {}, chain expects {want}",
                        s.first_lsn
                    ),
                });
            }
        }
        let last = i + 1 == chain.len();
        if !last && s.torn_bytes != 0 {
            return Err(DpcError::CorruptJournal {
                offset: s.valid_len,
                detail: format!(
                    "segment {seq} has a {}-byte torn tail but is not the final segment (sealed segments are synced whole)",
                    s.torn_bytes
                ),
            });
        }
        expected_lsn = Some(s.next_lsn);
        let frames = s.entries.len();
        entries.extend(s.entries);
        segments.push(SegmentInfo {
            seq,
            path: path.clone(),
            first_lsn: s.first_lsn,
            frames,
            valid_len: s.valid_len,
            torn_bytes: s.torn_bytes,
        });
    }
    // lint: allow(panic-surface) — the chain is non-empty, so the loop ran
    // at least once and both unwraps below are on populated values.
    let torn_bytes = segments.last().map(|s| s.torn_bytes).unwrap_or(0);
    let next_lsn = expected_lsn.unwrap_or(1);
    Ok(ScanOutcome { entries, segments, torn_bytes, next_lsn })
}

/// Delete every segment strictly below `horizon_seq`, in **ascending**
/// order — a crash mid-sweep then leaves a contiguous suffix (a gap in
/// the middle of the surviving chain would scan as corruption). Called
/// after the manifest flip; best-effort (correctness never depends on
/// the deletes, only disk usage does). Returns the seqs actually
/// removed.
pub fn gc_segments(dir: &Path, horizon_seq: u64) -> Vec<u64> {
    let mut removed = Vec::new();
    let Ok(all) = list_segments(dir) else {
        return removed;
    };
    for (seq, path) in all {
        if seq >= horizon_seq {
            break;
        }
        if std::fs::remove_file(&path).is_ok() {
            removed.push(seq);
        } else {
            // Stop at the first failure so the survivors stay contiguous.
            break;
        }
    }
    if !removed.is_empty() {
        sync_dir(dir);
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::PointSet;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "parcluster-journal-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_entries() -> Vec<JournalEntry> {
        vec![
            JournalEntry::OpenStream {
                stream: 1,
                dim: 2,
                dtype: Dtype::F64,
                d_cut: 3.0,
                density: DensityModel::Epanechnikov,
            },
            JournalEntry::Ingest {
                stream: 1,
                rho_min: 2.0,
                delta_min: 4.0,
                batch: DynPoints::F64(PointSet::new(vec![1.0, 2.0, 3.0, 4.0], 2)),
            },
            JournalEntry::OpenSession {
                session: 2,
                d_cut: 1.5,
                density: DensityModel::KnnRadius { k: 3 },
                pts: DynPoints::F64(PointSet::new(vec![0.0, 0.0, 1.0, 1.0], 2)),
            },
            JournalEntry::Recut { session: 2, rho_min: 1.0, delta_min: f64::INFINITY },
            JournalEntry::CloseSession { session: 2 },
            JournalEntry::CloseStream { stream: 1 },
        ]
    }

    fn assert_same_entry(a: &JournalEntry, b: &JournalEntry) {
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn segment_names_round_trip() {
        assert_eq!(segment_file(1), "journal-1.pclj");
        assert_eq!(parse_segment_name("journal-1.pclj"), Some(1));
        assert_eq!(parse_segment_name("journal-42.pclj"), Some(42));
        assert_eq!(parse_segment_name("journal-.pclj"), None);
        assert_eq!(parse_segment_name("journal-x.pclj"), None);
        assert_eq!(parse_segment_name("journal.pclj"), None);
        assert_eq!(parse_segment_name("checkpoint-1.pclc"), None);
    }

    #[test]
    fn oversized_payloads_are_rejected_up_front() {
        // The bound itself, without allocating 4 GiB.
        assert_eq!(check_frame_len(0).unwrap(), 0);
        assert_eq!(check_frame_len(u32::MAX as usize).unwrap(), u32::MAX);
        assert!(matches!(
            check_frame_len(u32::MAX as usize + 1),
            Err(DpcError::OversizedJournalEntry { len, max })
                if len == u32::MAX as u64 + 1 && max == u32::MAX as u64
        ));
        // And the writer stays clean after a rejected append: nothing was
        // framed, so normal entries still land with consecutive LSNs.
        let dir = tmpdir("oversize");
        let mut w = JournalWriter::create(&dir, 1, 0).unwrap();
        let before = w.len();
        assert_eq!(w.next_lsn(), 1);
        w.append(&JournalEntry::CloseStream { stream: 9 }).unwrap();
        assert!(w.len() > before);
        assert_eq!(w.next_lsn(), 2);
        let scan = scan_dir(&dir, 1).unwrap();
        assert_eq!(scan.entries.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn append_scan_round_trip() {
        let dir = tmpdir("roundtrip");
        let mut w = JournalWriter::create(&dir, 1, 0).unwrap();
        let entries = sample_entries();
        for (i, e) in entries.iter().enumerate() {
            assert_eq!(w.append(e).unwrap(), i as u64 + 1);
        }
        let end = w.len();
        assert_eq!(w.position(), (1, end));
        drop(w);

        let scan = scan_dir(&dir, 1).unwrap();
        assert_eq!(scan.entries.len(), entries.len());
        assert_eq!(scan.segments.len(), 1);
        assert_eq!(scan.valid_len(), end);
        assert_eq!(scan.last_seq(), 1);
        assert_eq!(scan.torn_bytes, 0);
        assert_eq!(scan.next_lsn, entries.len() as u64 + 1);
        for (got, want) in scan.entries.iter().zip(&entries) {
            assert_same_entry(&got.entry, want);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rotation_splits_segments_and_preserves_lsn_chain() {
        let dir = tmpdir("rotate");
        // Tiny threshold: every frame rotates once the segment is
        // non-empty, so N appends land in N segments.
        let mut w = JournalWriter::create(&dir, 1, JOURNAL_HEADER_LEN + 1).unwrap();
        let entries = sample_entries();
        for e in &entries {
            w.append(e).unwrap();
        }
        assert_eq!(w.seq(), entries.len() as u64);
        drop(w);
        let scan = scan_dir(&dir, 1).unwrap();
        assert_eq!(scan.segments.len(), entries.len());
        assert_eq!(scan.entries.len(), entries.len());
        assert_eq!(scan.next_lsn, entries.len() as u64 + 1);
        for (i, s) in scan.segments.iter().enumerate() {
            assert_eq!(s.seq, i as u64 + 1);
            assert_eq!(s.first_lsn, i as u64 + 1);
            assert_eq!(s.frames, 1);
        }
        // Entries carry their (seq, offset) position.
        for (i, f) in scan.entries.iter().enumerate() {
            assert_eq!((f.seq, f.offset), (i as u64 + 1, JOURNAL_HEADER_LEN));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn generous_threshold_keeps_one_segment() {
        let dir = tmpdir("nosplit");
        let mut w = JournalWriter::create(&dir, 1, 1 << 20).unwrap();
        for e in sample_entries() {
            w.append(&e).unwrap();
        }
        assert_eq!(w.seq(), 1);
        drop(w);
        assert_eq!(scan_dir(&dir, 1).unwrap().segments.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_deletes_strictly_below_horizon() {
        let dir = tmpdir("gc");
        let mut w = JournalWriter::create(&dir, 1, JOURNAL_HEADER_LEN + 1).unwrap();
        for e in sample_entries() {
            w.append(&e).unwrap();
        }
        let live = w.seq();
        drop(w);
        let removed = gc_segments(&dir, live);
        assert_eq!(removed, (1..live).collect::<Vec<_>>());
        // The suffix still scans clean from the horizon.
        let scan = scan_dir(&dir, live).unwrap();
        assert_eq!(scan.segments.len(), 1);
        assert_eq!(scan.entries.len(), 1);
        assert_eq!(scan.next_lsn, sample_entries().len() as u64 + 1);
        // Scanning from seq 1 now fails — the chain no longer starts there.
        assert!(matches!(scan_dir(&dir, 1), Err(DpcError::CorruptJournal { .. })));
        // GC at the same horizon again is a no-op.
        assert!(gc_segments(&dir, live).is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_in_final_segment_is_reported_then_truncated_on_reopen() {
        let dir = tmpdir("torn");
        let mut w = JournalWriter::create(&dir, 1, 0).unwrap();
        for e in sample_entries() {
            w.append(&e).unwrap();
        }
        let full = w.len();
        drop(w);

        // Chop the final frame in half: torn, not corrupt.
        let clean = scan_dir(&dir, 1).unwrap();
        let last_off = clean.entries.last().unwrap().offset;
        let cut = last_off + (full - last_off) / 2;
        let path = dir.join(segment_file(1));
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(cut).unwrap();
        drop(f);

        let torn = scan_dir(&dir, 1).unwrap();
        assert_eq!(torn.entries.len(), clean.entries.len() - 1);
        assert_eq!(torn.valid_len(), last_off);
        assert_eq!(torn.torn_bytes, cut - last_off);

        // Reopen at the valid prefix: tail physically removed, appends
        // continue the LSN sequence.
        let mut w =
            JournalWriter::open_end(&dir, torn.last_seq(), torn.valid_len(), torn.next_lsn, 1, 0)
                .unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), torn.valid_len());
        w.append(&JournalEntry::CloseStream { stream: 1 }).unwrap();
        drop(w);
        let again = scan_dir(&dir, 1).unwrap();
        assert_eq!(again.entries.len(), torn.entries.len() + 1);
        assert_eq!(again.torn_bytes, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_in_sealed_segment_is_corruption() {
        let dir = tmpdir("torn-sealed");
        let mut w = JournalWriter::create(&dir, 1, JOURNAL_HEADER_LEN + 1).unwrap();
        for e in sample_entries() {
            w.append(&e).unwrap();
        }
        assert!(w.seq() > 2);
        drop(w);
        // Shorten segment 2 (sealed, not final) by a few bytes.
        let path = dir.join(segment_file(2));
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 3).unwrap();
        drop(f);
        match scan_dir(&dir, 1) {
            Err(DpcError::CorruptJournal { detail, .. }) => {
                assert!(detail.contains("not the final segment"), "{detail}")
            }
            other => panic!("expected CorruptJournal, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn segment_gap_and_header_mismatch_are_corruption() {
        let dir = tmpdir("gap");
        let mut w = JournalWriter::create(&dir, 1, JOURNAL_HEADER_LEN + 1).unwrap();
        for e in sample_entries() {
            w.append(&e).unwrap();
        }
        drop(w);
        // Remove a middle segment: gap.
        std::fs::remove_file(dir.join(segment_file(3))).unwrap();
        match scan_dir(&dir, 1) {
            Err(DpcError::CorruptJournal { detail, .. }) => assert!(detail.contains("gap"), "{detail}"),
            other => panic!("expected CorruptJournal, got {other:?}"),
        }
        // Renaming a segment breaks the header/filename pin.
        std::fs::rename(dir.join(segment_file(4)), dir.join(segment_file(3))).unwrap();
        match scan_dir(&dir, 1) {
            Err(DpcError::CorruptJournal { detail, .. }) => {
                assert!(detail.contains("filename says"), "{detail}")
            }
            other => panic!("expected CorruptJournal, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bit_flip_in_complete_frame_is_corruption() {
        let dir = tmpdir("bitflip");
        let mut w = JournalWriter::create(&dir, 1, 0).unwrap();
        for e in sample_entries() {
            w.append(&e).unwrap();
        }
        drop(w);
        let path = dir.join(segment_file(1));
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        match scan_dir(&dir, 1) {
            Err(DpcError::CorruptJournal { .. }) => {}
            other => panic!("expected CorruptJournal, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lsn_discontinuity_is_corruption() {
        let dir = tmpdir("lsn");
        let mut w = JournalWriter::create(&dir, 1, 0).unwrap();
        w.append(&JournalEntry::CloseStream { stream: 1 }).unwrap();
        drop(w);
        // Re-frame a second entry with LSN 7 (valid CRC, wrong sequence).
        let mut payload = Vec::new();
        wire::put_u64(&mut payload, 7);
        JournalEntry::CloseStream { stream: 2 }.encode_body(&mut payload);
        let mut frame = Vec::new();
        wire::put_u32(&mut frame, payload.len() as u32);
        wire::put_u32(&mut frame, crc32(&payload));
        frame.extend_from_slice(&payload);
        let mut f = OpenOptions::new().append(true).open(dir.join(segment_file(1))).unwrap();
        f.write_all(&frame).unwrap();
        drop(f);
        match scan_dir(&dir, 1) {
            Err(DpcError::CorruptJournal { detail, .. }) => {
                assert!(detail.contains("discontinuity"), "{detail}")
            }
            other => panic!("expected CorruptJournal, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn group_commit_knob_batches_syncs() {
        // fsync timing is invisible to a same-process reader; this checks
        // the bookkeeping (appends succeed, lengths advance) under every
        // policy value, including 0 = never.
        for fsync_every in [0u64, 1, 3] {
            let dir = tmpdir(&format!("sync{fsync_every}"));
            let mut w = JournalWriter::create(&dir, fsync_every, 0).unwrap();
            for e in sample_entries() {
                w.append(&e).unwrap();
            }
            w.sync().unwrap();
            assert_eq!(scan_dir(&dir, 1).unwrap().entries.len(), sample_entries().len());
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }

    #[test]
    fn create_refuses_existing_segment() {
        let dir = tmpdir("exists");
        JournalWriter::create(&dir, 1, 0).unwrap();
        assert!(JournalWriter::create(&dir, 1, 0).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
