//! The write-ahead journal: an append-only log of every state-changing
//! serve-mode command, durable before the command is acknowledged.
//!
//! ## File format (`journal.pclj`)
//!
//! ```text
//! header:  magic "PCLJ" (4 bytes) | version u32 LE        — 8 bytes
//! frame:   len u32 LE | crc u32 LE | payload (len bytes)
//! payload: lsn u64 LE | kind u8 | body (kind-specific, see JournalEntry)
//! ```
//!
//! The CRC-32 covers the payload only. LSNs are contiguous from 1 across
//! the whole file — the journal is never head-truncated (checkpoints make
//! replay *start* later, they do not rewrite history), so `journal
//! inspect` can always audit the full command sequence.
//!
//! ## Torn tail vs corruption
//!
//! [`scan`] distinguishes the two failure shapes a crash can leave:
//!
//! - **Torn tail** — the file ends before a frame's declared bytes are all
//!   present. This is the expected result of dying mid-`write`; the scan
//!   reports the incomplete suffix (`torn_bytes`) and recovery truncates
//!   it silently. Every acknowledged entry is still intact.
//! - **Corruption** — a *complete* frame whose CRC mismatches, whose LSN
//!   breaks the contiguous sequence, or whose payload does not decode.
//!   That can only come from bit rot or external interference, so it
//!   surfaces as [`DpcError::CorruptJournal`] with the byte offset —
//!   never a partial parse.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::dpc::DensityModel;
use crate::error::DpcError;
use crate::geom::{Dtype, DynPoints};

use super::crc32::crc32;
use super::wire::{self, Cursor};

pub const JOURNAL_MAGIC: [u8; 4] = *b"PCLJ";
pub const JOURNAL_VERSION: u32 = 1;
/// Header length: magic + version.
pub const JOURNAL_HEADER_LEN: u64 = 8;
/// Frame prefix: len + crc.
const FRAME_PREFIX: usize = 8;

pub const JOURNAL_FILE: &str = "journal.pclj";

/// Check that an encoded payload fits the frame format's u32 length field,
/// returning the prefix value to write. A >4 GiB batch (≈270M f64 2-d
/// points in one ingest) would otherwise wrap `as u32` and poison the
/// journal; separated out so the bound is testable without allocating one.
fn check_frame_len(len: usize) -> Result<u32, DpcError> {
    u32::try_from(len).map_err(|_| DpcError::OversizedJournalEntry { len: len as u64, max: u32::MAX as u64 })
}

/// One logged command. Bodies mirror the coordinator's public API inputs
/// exactly — replay feeds them back through the same entry points.
#[derive(Clone, Debug)]
pub enum JournalEntry {
    /// `open_stream`: a new streaming session.
    OpenStream { stream: u64, dim: u32, dtype: Dtype, d_cut: f64, density: DensityModel },
    /// `ingest`: one batch appended to a stream, with the cut parameters
    /// in effect for the post-ingest artifact refresh.
    Ingest { stream: u64, rho_min: f64, delta_min: f64, batch: DynPoints },
    /// `close_stream`.
    CloseStream { stream: u64 },
    /// `open_session`: a one-shot (non-streaming) clustering session.
    OpenSession { session: u64, d_cut: f64, density: DensityModel, pts: DynPoints },
    /// `recut`: re-threshold an open session. Replay recomputes the same
    /// cached artifacts from `OpenSession`, so this entry is audit-only.
    Recut { session: u64, rho_min: f64, delta_min: f64 },
    /// `close_session`.
    CloseSession { session: u64 },
}

impl JournalEntry {
    pub fn kind_name(&self) -> &'static str {
        match self {
            JournalEntry::OpenStream { .. } => "open-stream",
            JournalEntry::Ingest { .. } => "ingest",
            JournalEntry::CloseStream { .. } => "close-stream",
            JournalEntry::OpenSession { .. } => "open-session",
            JournalEntry::Recut { .. } => "recut",
            JournalEntry::CloseSession { .. } => "close-session",
        }
    }

    fn encode_body(&self, out: &mut Vec<u8>) {
        match self {
            JournalEntry::OpenStream { stream, dim, dtype, d_cut, density } => {
                out.push(1);
                wire::put_u64(out, *stream);
                wire::put_u32(out, *dim);
                out.push(dtype.size_bytes() as u8);
                wire::put_f64(out, *d_cut);
                wire::put_density(out, *density);
            }
            JournalEntry::Ingest { stream, rho_min, delta_min, batch } => {
                out.push(2);
                wire::put_u64(out, *stream);
                wire::put_f64(out, *rho_min);
                wire::put_f64(out, *delta_min);
                wire::put_points(out, batch);
            }
            JournalEntry::CloseStream { stream } => {
                out.push(3);
                wire::put_u64(out, *stream);
            }
            JournalEntry::OpenSession { session, d_cut, density, pts } => {
                out.push(4);
                wire::put_u64(out, *session);
                wire::put_f64(out, *d_cut);
                wire::put_density(out, *density);
                wire::put_points(out, pts);
            }
            JournalEntry::Recut { session, rho_min, delta_min } => {
                out.push(5);
                wire::put_u64(out, *session);
                wire::put_f64(out, *rho_min);
                wire::put_f64(out, *delta_min);
            }
            JournalEntry::CloseSession { session } => {
                out.push(6);
                wire::put_u64(out, *session);
            }
        }
    }

    fn decode(cur: &mut Cursor<'_>) -> Result<JournalEntry, String> {
        let kind = cur.u8()?;
        let entry = match kind {
            1 => {
                let stream = cur.u64()?;
                let dim = cur.u32()?;
                let tag = cur.u8()?;
                let dtype =
                    Dtype::from_tag(tag).ok_or_else(|| format!("unknown dtype tag {tag}"))?;
                let d_cut = cur.f64()?;
                let density = wire::get_density(cur)?;
                JournalEntry::OpenStream { stream, dim, dtype, d_cut, density }
            }
            2 => JournalEntry::Ingest {
                stream: cur.u64()?,
                rho_min: cur.f64()?,
                delta_min: cur.f64()?,
                batch: wire::get_points(cur)?,
            },
            3 => JournalEntry::CloseStream { stream: cur.u64()? },
            4 => JournalEntry::OpenSession {
                session: cur.u64()?,
                d_cut: cur.f64()?,
                density: wire::get_density(cur)?,
                pts: wire::get_points(cur)?,
            },
            5 => JournalEntry::Recut {
                session: cur.u64()?,
                rho_min: cur.f64()?,
                delta_min: cur.f64()?,
            },
            6 => JournalEntry::CloseSession { session: cur.u64()? },
            other => return Err(format!("unknown journal entry kind {other}")),
        };
        cur.expect_end(entry.kind_name())?;
        Ok(entry)
    }
}

/// Append handle. All writes go through [`JournalWriter::append`], which
/// assigns the LSN, frames, checksums, and applies the fsync policy.
#[derive(Debug)]
pub struct JournalWriter {
    file: File,
    path: PathBuf,
    /// Current end-of-journal byte offset (== file length).
    len: u64,
    next_lsn: u64,
    /// `1` = fsync every append (default), `N` = group-commit every N
    /// appends, `0` = never (the OS flushes; an acknowledged-but-unsynced
    /// suffix may be lost to a crash, but what survives is always a
    /// consistent prefix).
    fsync_every: u64,
    unsynced: u64,
}

impl JournalWriter {
    /// Create a fresh journal (header only, synced). Fails if the file
    /// already exists — an existing journal must be scanned, not clobbered.
    pub fn create(path: &Path, fsync_every: u64) -> Result<Self, DpcError> {
        let mut file = OpenOptions::new().write(true).create_new(true).open(path)?;
        let mut header = Vec::with_capacity(JOURNAL_HEADER_LEN as usize);
        header.extend_from_slice(&JOURNAL_MAGIC);
        wire::put_u32(&mut header, JOURNAL_VERSION);
        file.write_all(&header)?;
        file.sync_data()?;
        Ok(JournalWriter {
            file,
            path: path.to_path_buf(),
            len: JOURNAL_HEADER_LEN,
            next_lsn: 1,
            fsync_every,
            unsynced: 0,
        })
    }

    /// Open an existing journal for appending at `valid_len`, truncating
    /// any torn tail beyond it (as reported by [`scan`]).
    pub fn open_end(
        path: &Path,
        valid_len: u64,
        next_lsn: u64,
        fsync_every: u64,
    ) -> Result<Self, DpcError> {
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        if file.metadata()?.len() > valid_len {
            file.set_len(valid_len)?;
            file.sync_data()?;
        }
        let mut file = file;
        file.seek(SeekFrom::Start(valid_len))?;
        Ok(JournalWriter {
            file,
            path: path.to_path_buf(),
            len: valid_len,
            next_lsn,
            fsync_every,
            unsynced: 0,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Byte offset one past the last durable-framed entry.
    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == JOURNAL_HEADER_LEN
    }

    /// The LSN the next [`JournalWriter::append`] will assign.
    pub fn next_lsn(&self) -> u64 {
        self.next_lsn
    }

    /// Frame, checksum, and write `entry`; returns its LSN. Durability
    /// follows the `fsync_every` policy — callers that need a hard
    /// guarantee right now (checkpointing) call [`JournalWriter::sync`].
    ///
    /// Payloads that overflow the frame format's u32 length field are
    /// rejected with [`DpcError::OversizedJournalEntry`] before a single
    /// byte hits the file — a silently-truncated length prefix would frame
    /// the entry's own bytes as garbage follow-on frames and corrupt the
    /// journal for every later reader.
    pub fn append(&mut self, entry: &JournalEntry) -> Result<u64, DpcError> {
        let lsn = self.next_lsn;
        let mut payload = Vec::with_capacity(64);
        wire::put_u64(&mut payload, lsn);
        entry.encode_body(&mut payload);
        let len = check_frame_len(payload.len())?;
        let mut frame = Vec::with_capacity(FRAME_PREFIX + payload.len());
        wire::put_u32(&mut frame, len);
        wire::put_u32(&mut frame, crc32(&payload));
        frame.extend_from_slice(&payload);
        self.file.write_all(&frame)?;
        self.len += frame.len() as u64;
        self.next_lsn += 1;
        self.unsynced += 1;
        if self.fsync_every != 0 && self.unsynced >= self.fsync_every {
            self.sync()?;
        }
        Ok(lsn)
    }

    /// Force everything appended so far to stable storage.
    pub fn sync(&mut self) -> Result<(), DpcError> {
        if self.unsynced > 0 || self.fsync_every != 1 {
            self.file.sync_data()?;
        }
        self.unsynced = 0;
        Ok(())
    }
}

/// One decoded frame, with its position for error reporting and
/// checkpoint offsets.
#[derive(Clone, Debug)]
pub struct ScannedFrame {
    /// Byte offset of the frame's length prefix.
    pub offset: u64,
    pub lsn: u64,
    pub entry: JournalEntry,
}

/// Result of a full journal scan.
#[derive(Debug)]
pub struct ScanOutcome {
    pub entries: Vec<ScannedFrame>,
    /// Byte offset one past the last fully-valid frame — where appends
    /// resume after truncating the tail.
    pub valid_len: u64,
    /// Bytes of incomplete final frame beyond `valid_len` (0 = clean).
    pub torn_bytes: u64,
    /// The LSN a writer reopened at `valid_len` should assign next.
    pub next_lsn: u64,
}

/// Read and validate the whole journal. Torn tails are *reported*, not
/// errors; anything else malformed is [`DpcError::CorruptJournal`].
pub fn scan(path: &Path) -> Result<ScanOutcome, DpcError> {
    let mut buf = Vec::new();
    File::open(path)?.read_to_end(&mut buf)?;
    if buf.len() < JOURNAL_HEADER_LEN as usize {
        return Err(DpcError::CorruptJournal {
            offset: 0,
            detail: format!("file is {} bytes, shorter than the 8-byte header", buf.len()),
        });
    }
    if buf[..4] != JOURNAL_MAGIC {
        return Err(DpcError::CorruptJournal {
            offset: 0,
            detail: format!("bad magic {:?} (want \"PCLJ\")", &buf[..4]),
        });
    }
    let version = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]);
    if version != JOURNAL_VERSION {
        return Err(DpcError::CorruptJournal {
            offset: 4,
            detail: format!("unsupported journal version {version} (want {JOURNAL_VERSION})"),
        });
    }

    let mut entries = Vec::new();
    let mut pos = JOURNAL_HEADER_LEN as usize;
    let mut expected_lsn = 1u64;
    while pos < buf.len() {
        let avail = buf.len() - pos;
        if avail < FRAME_PREFIX {
            break; // torn: not even a full frame prefix
        }
        let len = u32::from_le_bytes([buf[pos], buf[pos + 1], buf[pos + 2], buf[pos + 3]]) as usize;
        let crc = u32::from_le_bytes([buf[pos + 4], buf[pos + 5], buf[pos + 6], buf[pos + 7]]);
        if avail < FRAME_PREFIX + len {
            break; // torn: payload incomplete
        }
        let payload = &buf[pos + FRAME_PREFIX..pos + FRAME_PREFIX + len];
        if crc32(payload) != crc {
            return Err(DpcError::CorruptJournal {
                offset: pos as u64,
                detail: format!("frame CRC mismatch (stored {crc:#010x}, computed {:#010x})", crc32(payload)),
            });
        }
        let mut cur = Cursor::new(payload);
        let lsn = cur.u64().map_err(|detail| DpcError::CorruptJournal { offset: pos as u64, detail })?;
        if lsn != expected_lsn {
            return Err(DpcError::CorruptJournal {
                offset: pos as u64,
                detail: format!("LSN discontinuity: frame carries {lsn}, expected {expected_lsn}"),
            });
        }
        let entry = JournalEntry::decode(&mut cur)
            .map_err(|detail| DpcError::CorruptJournal { offset: pos as u64, detail })?;
        entries.push(ScannedFrame { offset: pos as u64, lsn, entry });
        expected_lsn += 1;
        pos += FRAME_PREFIX + len;
    }
    Ok(ScanOutcome {
        entries,
        valid_len: pos as u64,
        torn_bytes: (buf.len() - pos) as u64,
        next_lsn: expected_lsn,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::PointSet;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "parcluster-journal-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_entries() -> Vec<JournalEntry> {
        vec![
            JournalEntry::OpenStream {
                stream: 1,
                dim: 2,
                dtype: Dtype::F64,
                d_cut: 3.0,
                density: DensityModel::Epanechnikov,
            },
            JournalEntry::Ingest {
                stream: 1,
                rho_min: 2.0,
                delta_min: 4.0,
                batch: DynPoints::F64(PointSet::new(vec![1.0, 2.0, 3.0, 4.0], 2)),
            },
            JournalEntry::OpenSession {
                session: 2,
                d_cut: 1.5,
                density: DensityModel::KnnRadius { k: 3 },
                pts: DynPoints::F64(PointSet::new(vec![0.0, 0.0, 1.0, 1.0], 2)),
            },
            JournalEntry::Recut { session: 2, rho_min: 1.0, delta_min: f64::INFINITY },
            JournalEntry::CloseSession { session: 2 },
            JournalEntry::CloseStream { stream: 1 },
        ]
    }

    fn assert_same_entry(a: &JournalEntry, b: &JournalEntry) {
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn oversized_payloads_are_rejected_up_front() {
        // The bound itself, without allocating 4 GiB.
        assert_eq!(check_frame_len(0).unwrap(), 0);
        assert_eq!(check_frame_len(u32::MAX as usize).unwrap(), u32::MAX);
        assert!(matches!(
            check_frame_len(u32::MAX as usize + 1),
            Err(DpcError::OversizedJournalEntry { len, max })
                if len == u32::MAX as u64 + 1 && max == u32::MAX as u64
        ));
        // And the writer stays clean after a rejected append: nothing was
        // framed, so normal entries still land with consecutive LSNs.
        let dir = tmpdir("oversize");
        let path = dir.join(JOURNAL_FILE);
        let mut w = JournalWriter::create(&path, 1).unwrap();
        let before = w.len();
        assert_eq!(w.next_lsn(), 1);
        w.append(&JournalEntry::CloseStream { stream: 9 }).unwrap();
        assert!(w.len() > before);
        assert_eq!(w.next_lsn(), 2);
        let scan = scan(&path).unwrap();
        assert_eq!(scan.entries.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn append_scan_round_trip() {
        let dir = tmpdir("roundtrip");
        let path = dir.join(JOURNAL_FILE);
        let mut w = JournalWriter::create(&path, 1).unwrap();
        let entries = sample_entries();
        for (i, e) in entries.iter().enumerate() {
            assert_eq!(w.append(e).unwrap(), i as u64 + 1);
        }
        let end = w.len();
        drop(w);

        let scan = scan(&path).unwrap();
        assert_eq!(scan.entries.len(), entries.len());
        assert_eq!(scan.valid_len, end);
        assert_eq!(scan.torn_bytes, 0);
        assert_eq!(scan.next_lsn, entries.len() as u64 + 1);
        for (got, want) in scan.entries.iter().zip(&entries) {
            assert_same_entry(&got.entry, want);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_is_reported_then_truncated_on_reopen() {
        let dir = tmpdir("torn");
        let path = dir.join(JOURNAL_FILE);
        let mut w = JournalWriter::create(&path, 1).unwrap();
        for e in sample_entries() {
            w.append(&e).unwrap();
        }
        let full = w.len();
        drop(w);

        // Chop the final frame in half: torn, not corrupt.
        let clean = scan(&path).unwrap();
        let last_off = clean.entries.last().unwrap().offset;
        let cut = last_off + (full - last_off) / 2;
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(cut).unwrap();
        drop(f);

        let torn = scan(&path).unwrap();
        assert_eq!(torn.entries.len(), clean.entries.len() - 1);
        assert_eq!(torn.valid_len, last_off);
        assert_eq!(torn.torn_bytes, cut - last_off);

        // Reopen at the valid prefix: tail physically removed, appends
        // continue the LSN sequence.
        let mut w = JournalWriter::open_end(&path, torn.valid_len, torn.next_lsn, 1).unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), torn.valid_len);
        w.append(&JournalEntry::CloseStream { stream: 1 }).unwrap();
        drop(w);
        let again = scan(&path).unwrap();
        assert_eq!(again.entries.len(), torn.entries.len() + 1);
        assert_eq!(again.torn_bytes, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bit_flip_in_complete_frame_is_corruption() {
        let dir = tmpdir("bitflip");
        let path = dir.join(JOURNAL_FILE);
        let mut w = JournalWriter::create(&path, 1).unwrap();
        for e in sample_entries() {
            w.append(&e).unwrap();
        }
        drop(w);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        match scan(&path) {
            Err(DpcError::CorruptJournal { .. }) => {}
            other => panic!("expected CorruptJournal, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lsn_discontinuity_is_corruption() {
        let dir = tmpdir("lsn");
        let path = dir.join(JOURNAL_FILE);
        let mut w = JournalWriter::create(&path, 1).unwrap();
        w.append(&JournalEntry::CloseStream { stream: 1 }).unwrap();
        drop(w);
        // Re-frame a second entry with LSN 7 (valid CRC, wrong sequence).
        let mut payload = Vec::new();
        wire::put_u64(&mut payload, 7);
        JournalEntry::CloseStream { stream: 2 }.encode_body(&mut payload);
        let mut frame = Vec::new();
        wire::put_u32(&mut frame, payload.len() as u32);
        wire::put_u32(&mut frame, crc32(&payload));
        frame.extend_from_slice(&payload);
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&frame).unwrap();
        drop(f);
        match scan(&path) {
            Err(DpcError::CorruptJournal { detail, .. }) => {
                assert!(detail.contains("discontinuity"), "{detail}")
            }
            other => panic!("expected CorruptJournal, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn group_commit_knob_batches_syncs() {
        // fsync timing is invisible to a same-process reader; this checks
        // the bookkeeping (appends succeed, lengths advance) under every
        // policy value, including 0 = never.
        for fsync_every in [0u64, 1, 3] {
            let dir = tmpdir(&format!("sync{fsync_every}"));
            let path = dir.join(JOURNAL_FILE);
            let mut w = JournalWriter::create(&path, fsync_every).unwrap();
            for e in sample_entries() {
                w.append(&e).unwrap();
            }
            w.sync().unwrap();
            assert_eq!(scan(&path).unwrap().entries.len(), sample_entries().len());
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }

    #[test]
    fn create_refuses_existing_file() {
        let dir = tmpdir("exists");
        let path = dir.join(JOURNAL_FILE);
        JournalWriter::create(&path, 1).unwrap();
        assert!(JournalWriter::create(&path, 1).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
