//! Little-endian wire codecs shared by the journal, checkpoint, and
//! manifest formats.
//!
//! Decoding is *total*: every read goes through the bounds-checked
//! [`Cursor`], every length is validated against the bytes actually
//! present before a single element is allocated, and every decoder
//! returns `Result` — a malformed buffer yields a detail string (which
//! the caller wraps into the appropriate `DpcError::Corrupt*` variant
//! with positional context), never a panic or a partially-filled value.

use crate::dpc::DensityModel;
use crate::geom::{Dtype, DynPoints, PointStore, Scalar};

/// Bounds-checked forward reader over a byte slice.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl std::fmt::Debug for Cursor<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Cursor")
            .field("pos", &self.pos)
            .field("len", &self.buf.len())
            .finish()
    }
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    /// Bytes consumed so far (for error positions).
    pub fn offset(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "need {n} bytes at offset {}, only {} remain",
                self.pos,
                self.remaining()
            ));
        }
        // bounds: the remaining() < n guard above proves pos + n <= len.
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        // bounds: take(4) returned exactly 4 bytes or erred out above.
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        // bounds: take(8) returned exactly 8 bytes or erred out above.
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Assert the buffer is fully consumed — trailing garbage inside a
    /// length-delimited frame is corruption, not slack.
    pub fn expect_end(&self, what: &str) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err(format!("{what}: {} trailing bytes after payload", self.remaining()));
        }
        Ok(())
    }
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

// ---------------------------------------------------------------------------
// DensityModel codec
// ---------------------------------------------------------------------------

/// `u8` tag + `u32` k (zero unless k-NN). Tags are append-only: 0 cutoff,
/// 1 knn, 2 gauss, 3 epan.
pub fn put_density(out: &mut Vec<u8>, model: DensityModel) {
    let (tag, k) = match model {
        DensityModel::CutoffCount => (0u8, 0u32),
        DensityModel::KnnRadius { k } => (1, k),
        DensityModel::GaussianKernel => (2, 0),
        DensityModel::Epanechnikov => (3, 0),
    };
    out.push(tag);
    put_u32(out, k);
}

pub fn get_density(cur: &mut Cursor<'_>) -> Result<DensityModel, String> {
    let tag = cur.u8()?;
    let k = cur.u32()?;
    let model = match tag {
        0 => DensityModel::CutoffCount,
        1 => DensityModel::KnnRadius { k },
        2 => DensityModel::GaussianKernel,
        3 => DensityModel::Epanechnikov,
        other => return Err(format!("unknown density model tag {other}")),
    };
    if tag != 1 && k != 0 {
        return Err(format!("density tag {tag} carries spurious k = {k}"));
    }
    model.validate().map_err(|e| e.to_string())?;
    Ok(model)
}

// ---------------------------------------------------------------------------
// Point-batch codec
// ---------------------------------------------------------------------------

/// `u8` dtype tag (4 = f32, 8 = f64, matching the `datasets::io` v2
/// header byte) + `u64` n + `u32` dim + n·dim little-endian coordinates.
pub fn put_points(out: &mut Vec<u8>, pts: &DynPoints) {
    match pts {
        DynPoints::F32(p) => put_store(out, p),
        DynPoints::F64(p) => put_store(out, p),
    }
}

pub fn put_store<S: Scalar>(out: &mut Vec<u8>, pts: &PointStore<S>) {
    out.push(S::DTYPE.size_bytes() as u8);
    put_u64(out, pts.len() as u64);
    put_u32(out, pts.dim() as u32);
    for &c in pts.coords() {
        c.write_le(out);
    }
}

pub fn get_points(cur: &mut Cursor<'_>) -> Result<DynPoints, String> {
    let tag = cur.u8()?;
    let dtype =
        Dtype::from_tag(tag).ok_or_else(|| format!("unknown dtype tag {tag} in point batch"))?;
    match dtype {
        Dtype::F32 => Ok(DynPoints::F32(get_store_body(cur)?)),
        Dtype::F64 => Ok(DynPoints::F64(get_store_body(cur)?)),
    }
}

/// Decode a `PointStore<S>` whose dtype tag must match `S` exactly (used
/// by the checkpoint's typed stream sections).
pub fn get_store<S: Scalar>(cur: &mut Cursor<'_>) -> Result<PointStore<S>, String> {
    let tag = cur.u8()?;
    if tag as usize != S::DTYPE.size_bytes() {
        return Err(format!("dtype tag {tag} does not match expected {}", S::DTYPE));
    }
    get_store_body(cur)
}

fn get_store_body<S: Scalar>(cur: &mut Cursor<'_>) -> Result<PointStore<S>, String> {
    let n = cur.u64()?;
    let d = cur.u32()? as usize;
    // n = 0 is legal (a checkpointed stream that has not ingested yet);
    // d = 0 never is.
    if d == 0 {
        return Err(format!("point batch with dim = 0 (n = {n})"));
    }
    // Size check BEFORE allocation: the coordinate payload must actually
    // be present, so a forged n can never drive a huge reservation.
    let want = (n as usize)
        .checked_mul(d)
        .and_then(|c| c.checked_mul(S::BYTES))
        .ok_or_else(|| format!("point batch size overflows: n = {n}, dim = {d}"))?;
    if cur.remaining() < want {
        return Err(format!(
            "point batch claims {want} coordinate bytes, only {} remain",
            cur.remaining()
        ));
    }
    let n = n as usize;
    // bounds: n·d·S::BYTES passed the checked_mul and the remaining() check
    // above, so the capacity is covered by bytes actually on the wire.
    let mut coords = Vec::with_capacity(n * d);
    for _ in 0..n * d {
        coords.push(S::read_le(cur.take(S::BYTES)?));
    }
    PointStore::try_new(coords, d).map_err(|e| e.to_string())
}

/// `u64` length + raw bytes, for variable-length strings (stream names
/// never occur — this carries `built_by` engine labels in checkpoints).
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

pub fn get_str(cur: &mut Cursor<'_>) -> Result<String, String> {
    let len = cur.u64()? as usize;
    if len > 4096 {
        return Err(format!("string length {len} exceeds sanity bound 4096"));
    }
    let bytes = cur.take(len)?;
    // bounds: len passed the 4096 sanity cap and take(len) proved the bytes
    // exist, so this copies at most 4 KiB of received data.
    String::from_utf8(bytes.to_vec()).map_err(|_| "string is not valid UTF-8".into())
}

/// `u64` count + `u32` elements.
pub fn put_u32_slice(out: &mut Vec<u8>, xs: &[u32]) {
    put_u64(out, xs.len() as u64);
    for &x in xs {
        put_u32(out, x);
    }
}

pub fn get_u32_vec(cur: &mut Cursor<'_>) -> Result<Vec<u32>, String> {
    let len = cur.u64()? as usize;
    if cur.remaining() < len.checked_mul(4).ok_or("u32 slice length overflows")? {
        return Err(format!("u32 slice claims {len} elements, buffer too short"));
    }
    (0..len).map(|_| cur.u32()).collect()
}

/// `u64` count + bit-pattern `u64` elements. f64 slices travel as raw bits
/// (like every scalar f64 here) so NaN payloads and signed zeros survive.
pub fn put_f64_slice(out: &mut Vec<u8>, xs: &[f64]) {
    put_u64(out, xs.len() as u64);
    for &x in xs {
        put_f64(out, x);
    }
}

pub fn get_f64_vec(cur: &mut Cursor<'_>) -> Result<Vec<f64>, String> {
    let len = cur.u64()? as usize;
    if cur.remaining() < len.checked_mul(8).ok_or("f64 slice length overflows")? {
        return Err(format!("f64 slice claims {len} elements, buffer too short"));
    }
    (0..len).map(|_| cur.f64()).collect()
}

/// `u64` count + two's-complement `u64` elements (cluster labels, where
/// −1 marks noise).
pub fn put_i64_slice(out: &mut Vec<u8>, xs: &[i64]) {
    put_u64(out, xs.len() as u64);
    for &x in xs {
        put_u64(out, x as u64);
    }
}

pub fn get_i64_vec(cur: &mut Cursor<'_>) -> Result<Vec<i64>, String> {
    let len = cur.u64()? as usize;
    if cur.remaining() < len.checked_mul(8).ok_or("i64 slice length overflows")? {
        return Err(format!("i64 slice claims {len} elements, buffer too short"));
    }
    (0..len).map(|_| cur.u64().map(|v| v as i64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::PointSet;

    #[test]
    fn cursor_reads_and_bounds() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 7);
        put_u64(&mut buf, 1 << 40);
        put_f64(&mut buf, -2.5);
        buf.push(9);
        let mut cur = Cursor::new(&buf);
        assert_eq!(cur.u32().unwrap(), 7);
        assert_eq!(cur.u64().unwrap(), 1 << 40);
        assert_eq!(cur.f64().unwrap(), -2.5);
        assert_eq!(cur.u8().unwrap(), 9);
        cur.expect_end("test").unwrap();
        assert!(cur.u8().is_err(), "read past end must fail");
    }

    #[test]
    fn density_round_trips_and_rejects_bad_tags() {
        for model in [
            DensityModel::CutoffCount,
            DensityModel::KnnRadius { k: 5 },
            DensityModel::GaussianKernel,
            DensityModel::Epanechnikov,
        ] {
            let mut buf = Vec::new();
            put_density(&mut buf, model);
            assert_eq!(get_density(&mut Cursor::new(&buf)).unwrap(), model);
        }
        let bad = [7u8, 0, 0, 0, 0];
        assert!(get_density(&mut Cursor::new(&bad)).is_err());
        // Spurious k on a non-knn tag is corruption, not slack.
        let spurious = [0u8, 3, 0, 0, 0];
        assert!(get_density(&mut Cursor::new(&spurious)).is_err());
        // knn with k = 0 fails model validation.
        let zero_k = [1u8, 0, 0, 0, 0];
        assert!(get_density(&mut Cursor::new(&zero_k)).is_err());
    }

    #[test]
    fn points_round_trip_both_dtypes() {
        let f64_pts = DynPoints::F64(PointSet::new(vec![1.0, 2.0, 3.0, 4.5], 2));
        let f32_pts = DynPoints::F32(PointStore::<f32>::new(vec![1.0, 2.0, 3.0], 3));
        for pts in [f64_pts, f32_pts] {
            let mut buf = Vec::new();
            put_points(&mut buf, &pts);
            let mut cur = Cursor::new(&buf);
            let back = get_points(&mut cur).unwrap();
            cur.expect_end("points").unwrap();
            assert_eq!(back.dtype(), pts.dtype());
            assert_eq!((back.len(), back.dim()), (pts.len(), pts.dim()));
            assert_eq!(back.clone().into_f64().coords(), pts.clone().into_f64().coords());
        }
    }

    #[test]
    fn forged_point_count_is_rejected_before_allocation() {
        let mut buf = Vec::new();
        put_points(&mut buf, &DynPoints::F64(PointSet::new(vec![1.0, 2.0], 2)));
        // Inflate n to a huge value; the coordinate bytes are absent, so
        // the size check must fire (and must not try to allocate first).
        buf[1..9].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(get_points(&mut Cursor::new(&buf)).is_err());
    }

    #[test]
    fn typed_store_rejects_dtype_mismatch() {
        let mut buf = Vec::new();
        put_store(&mut buf, &PointSet::new(vec![1.0, 2.0], 2));
        assert!(get_store::<f32>(&mut Cursor::new(&buf)).is_err());
        assert!(get_store::<f64>(&mut Cursor::new(&buf)).is_ok());
    }

    #[test]
    fn strings_and_slices_round_trip() {
        let mut buf = Vec::new();
        put_str(&mut buf, "rust-tree");
        put_u32_slice(&mut buf, &[3, 1, 4, 1, 5]);
        put_f64_slice(&mut buf, &[0.5, f64::INFINITY, -0.0]);
        put_i64_slice(&mut buf, &[-1, 0, i64::MAX]);
        let mut cur = Cursor::new(&buf);
        assert_eq!(get_str(&mut cur).unwrap(), "rust-tree");
        assert_eq!(get_u32_vec(&mut cur).unwrap(), vec![3, 1, 4, 1, 5]);
        let fs = get_f64_vec(&mut cur).unwrap();
        assert_eq!(fs[0], 0.5);
        assert_eq!(fs[1], f64::INFINITY);
        assert!(fs[2] == 0.0 && fs[2].is_sign_negative(), "-0.0 survives");
        assert_eq!(get_i64_vec(&mut cur).unwrap(), vec![-1, 0, i64::MAX]);
        cur.expect_end("strings").unwrap();
    }

    #[test]
    fn forged_slice_counts_are_rejected_before_allocation() {
        let mut buf = Vec::new();
        put_f64_slice(&mut buf, &[1.0]);
        buf[0..8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(get_f64_vec(&mut Cursor::new(&buf)).is_err());
        let mut buf = Vec::new();
        put_i64_slice(&mut buf, &[1]);
        buf[0..8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(get_i64_vec(&mut Cursor::new(&buf)).is_err());
    }
}
