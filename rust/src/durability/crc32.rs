//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — hand-rolled so
//! the durability formats stay dependency-free. The table is built in a
//! `const fn` at compile time; [`Crc32`] is a streaming hasher for
//! whole-file checksums, [`crc32`] the one-shot convenience.
//!
//! The choice of CRC-32 over a cryptographic hash is deliberate: journal
//! and checkpoint corruption here means torn writes and bit rot, not an
//! adversary, and a 4-byte check keeps frame overhead negligible.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Streaming CRC-32 hasher.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = (self.state >> 8) ^ TABLE[((self.state ^ b as u32) & 0xFF) as usize];
        }
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The classic check value for CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let whole = crc32(&data);
        let mut h = Crc32::new();
        for chunk in data.chunks(37) {
            h.update(chunk);
        }
        assert_eq!(h.finish(), whole);
    }

    #[test]
    fn single_bit_flip_changes_crc() {
        let mut data = b"journal frame payload".to_vec();
        let before = crc32(&data);
        data[7] ^= 0x10;
        assert_ne!(crc32(&data), before);
    }
}
