//! CRC-64 (ECMA-182 via the reflected XZ polynomial 0xC96C5795D7870F42)
//! — hand-rolled like [`super::crc32`] so the durability formats stay
//! dependency-free. The table is built in a `const fn` at compile time;
//! [`crc64`] is the one-shot used for content-addressing checkpoint
//! level blobs.
//!
//! Why 64 bits here when frames get by with 32: a checkpoint blob key
//! `(crc64, len)` is an *identity* — two different level buffers mapping
//! to the same key would silently splice the wrong coordinates into a
//! restored forest. At 32 bits a few tens of thousands of blobs already
//! give birthday-collision odds worth worrying about; at 64 bits (plus
//! the length discriminant) the chance is negligible for any realistic
//! checkpoint population. Corruption *detection* still happens at the
//! whole-file CRC-32 layer; the CRC-64 key is for addressing.

const fn build_table() -> [u64; 256] {
    let mut table = [0u64; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u64;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xC96C_5795_D787_0F42 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u64; 256] = build_table();

/// Streaming CRC-64 hasher.
#[derive(Clone, Debug)]
pub struct Crc64 {
    state: u64,
}

impl Default for Crc64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc64 {
    pub fn new() -> Self {
        Crc64 { state: 0xFFFF_FFFF_FFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = (self.state >> 8) ^ TABLE[((self.state ^ b as u64) & 0xFF) as usize];
        }
    }

    pub fn finish(&self) -> u64 {
        self.state ^ 0xFFFF_FFFF_FFFF_FFFF
    }
}

/// One-shot CRC-64 of a byte slice.
pub fn crc64(bytes: &[u8]) -> u64 {
    let mut h = Crc64::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The CRC-64/XZ check value.
        assert_eq!(crc64(b"123456789"), 0x995D_C9BB_DF19_39FA);
        assert_eq!(crc64(b""), 0);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let whole = crc64(&data);
        let mut h = Crc64::new();
        for chunk in data.chunks(41) {
            h.update(chunk);
        }
        assert_eq!(h.finish(), whole);
    }

    #[test]
    fn single_bit_flip_changes_crc() {
        let mut data = b"checkpoint level blob".to_vec();
        let before = crc64(&data);
        data[3] ^= 0x01;
        assert_ne!(crc64(&data), before);
    }
}
