//! Checkpoints: a point-in-time serialization of every live stream's
//! Bentley–Saxe forest state and every one-shot session's cached
//! (ρ, λ, δ) artifacts, so recovery replays only the journal suffix
//! written after the snapshot.
//!
//! ## File format (`checkpoint-<seq>.pclc`, version 2)
//!
//! ```text
//! magic "PCLC" | version u32
//! | n_streams u64 | stream... | n_sessions u64 | session...
//! | crc u32                       — CRC-32 of every preceding byte
//! stream:  id u64 | dtype u8 | d_cut f64 | density
//!          | n u64 | dim u32 | n_levels u64 | level...
//!          | rho u32-slice | dep u32-slice (u32::MAX = None)
//!          | delta count u64 + f64... | stats (8×u64 + 2×f64)
//! level:   k u32 | tag u8
//!          tag 0 (inline): blob_len u64 | blob bytes
//!          tag 1 (ref):    home_seq u64 | crc64 u64 | blob_len u64
//! blob:    ids u32-slice | gathered coords (ids.len()·dim raw LE scalars)
//! session: id u64 | d_cut f64 | density | pts (f64 store)
//!          | rho u32-slice | dep u32-slice | delta | built_by str
//!          | density_secs f64 | dep_secs f64
//! ```
//!
//! ## Incremental checkpoints
//!
//! Bentley–Saxe levels are immutable once built — a merge *replaces*
//! levels, it never mutates one — so most levels survive unchanged
//! between checkpoints, and the big ones (which dominate bytes) survive
//! longest. Version 2 exploits that: each level is serialized as a
//! standalone **blob** (its ids plus their gathered coordinate rows) and
//! content-addressed by the key `(crc64(blob), blob_len)`. When a blob's
//! key already exists in the previous checkpoint, the new file stores a
//! 25-byte **ref** naming the checkpoint file where the blob lives
//! inline, instead of the blob itself — so a checkpoint writes only the
//! levels rebuilt since the last snapshot plus a small index. Refs never
//! chain: a ref always names the physical file holding the inline bytes
//! (when the previous checkpoint itself held a ref, the new one copies
//! that ref's home, not the previous checkpoint's seq).
//!
//! Reassembly scatters each level's gathered rows back through its ids
//! into the flat `n × dim` buffer; since the levels partition the id
//! space, the rebuilt store is byte-identical to the one exported. The
//! CRC-64 key is verified at resolution (the blob map is keyed by the
//! computed CRC of the referenced file's actual bytes), so a stale or
//! corrupt referenced file yields [`DpcError::CorruptCheckpoint`], never
//! spliced coordinates.
//!
//! ## GC
//!
//! Old checkpoints are collected by a refcount-aware sweep
//! ([`gc`]): the newest `retain` checkpoint files are roots, every
//! file a root references is live, and everything else is deleted.
//! Journal segments strictly below the manifest's replay horizon are
//! swept at the same time ([`super::journal::gc_segments`]). Both sweeps
//! run strictly *after* the manifest flip and are best-effort —
//! correctness never depends on a delete.
//!
//! Decoding is all-or-nothing: the whole-file CRC is verified *before*
//! any section is parsed, and every section parse is bounds-checked, so a
//! truncated or bit-flipped checkpoint yields
//! [`DpcError::CorruptCheckpoint`] and zero restored state. One-shot
//! sessions are f64-only in serve mode, and the checkpoint section
//! mirrors that; streams are dtype-tagged and fully precision-generic.
//!
//! Writing is crash-safe by ordering: the checkpoint file is written and
//! fsynced *first*, the manifest flips to it *second* (atomically — see
//! [`super::manifest`]), and only then does GC run. A crash between any
//! two steps leaves the previous (checkpoint, journal position) pair
//! fully usable.

use std::collections::{HashMap, HashSet};
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::dpc::{DensityModel, StreamState, StreamStats};
use crate::error::DpcError;
use crate::geom::{Dtype, PointSet, PointStore, Scalar};

use super::crc32::crc32;
use super::crc64::crc64;
use super::journal::{self, JournalWriter};
use super::manifest::{self, Manifest};
use super::wire::{self, Cursor};

pub const CHECKPOINT_MAGIC: [u8; 4] = *b"PCLC";
pub const CHECKPOINT_VERSION: u32 = 2;

/// A level blob's content address: `(crc64 of the blob bytes, length)`.
pub type BlobKey = (u64, u64);

/// `checkpoint-<seq>.pclc` in the durable directory.
pub fn checkpoint_file(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("checkpoint-{seq}.pclc"))
}

/// Inverse of [`checkpoint_file`]'s naming: parse a directory entry name.
pub fn parse_checkpoint_name(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("checkpoint-")?.strip_suffix(".pclc")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Every checkpoint file in `dir`, sorted ascending by seq.
pub fn list_checkpoints(dir: &Path) -> Result<Vec<(u64, PathBuf)>, DpcError> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        if let Some(seq) = entry.file_name().to_str().and_then(parse_checkpoint_name) {
            out.push((seq, entry.path()));
        }
    }
    out.sort_by_key(|&(seq, _)| seq);
    Ok(out)
}

/// A dtype-tagged stream snapshot (the runtime union of
/// [`StreamState<f32>`] / [`StreamState<f64>`]).
#[derive(Clone, Debug)]
pub enum DynStreamState {
    F32(StreamState<f32>),
    F64(StreamState<f64>),
}

impl DynStreamState {
    pub fn dtype(&self) -> Dtype {
        match self {
            DynStreamState::F32(_) => Dtype::F32,
            DynStreamState::F64(_) => Dtype::F64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            DynStreamState::F32(s) => s.pts.len(),
            DynStreamState::F64(s) => s.pts.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A one-shot session's cached artifacts, as held by the coordinator:
/// enough to serve `recut`/`artifact` queries after restart without
/// re-clustering.
#[derive(Clone, Debug)]
pub struct SessionState {
    pub id: u64,
    pub d_cut: f64,
    pub density: DensityModel,
    pub pts: PointSet,
    pub rho: Vec<u32>,
    pub dep: Vec<Option<u32>>,
    pub delta: Vec<f64>,
    /// Engine label of the build that produced the artifacts (display
    /// only — restored sessions keep the original label).
    pub built_by: String,
    pub density_secs: f64,
    pub dep_secs: f64,
}

/// Everything a checkpoint captures.
#[derive(Clone, Debug, Default)]
pub struct CheckpointData {
    /// `(stream_id, state)`, any order.
    pub streams: Vec<(u64, DynStreamState)>,
    pub sessions: Vec<SessionState>,
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_dep(out: &mut Vec<u8>, dep: &[Option<u32>]) {
    wire::put_u64(out, dep.len() as u64);
    for d in dep {
        wire::put_u32(out, d.map_or(u32::MAX, |x| x));
    }
}

fn put_f64_slice(out: &mut Vec<u8>, xs: &[f64]) {
    wire::put_u64(out, xs.len() as u64);
    for &x in xs {
        wire::put_f64(out, x);
    }
}

fn put_stats(out: &mut Vec<u8>, s: &StreamStats) {
    for v in [
        s.ingests,
        s.points_ingested,
        s.trees_built,
        s.tree_points_built,
        s.rho_bumped,
        s.dep_full_queries,
        s.dep_seeded_races,
        s.dep_changed,
    ] {
        wire::put_u64(out, v);
    }
    wire::put_f64(out, s.rho_secs);
    wire::put_f64(out, s.dep_secs);
}

/// Encode one level's content-addressed blob: its ids and their gathered
/// coordinate rows. Unchanged levels produce byte-identical blobs (the
/// store is immutable and the gather is in id order), which is what makes
/// the `(crc64, len)` key a stable identity across checkpoints.
fn encode_blob<S: Scalar>(ids: &[u32], st: &StreamState<S>) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + ids.len() * (4 + st.pts.dim() * S::BYTES));
    wire::put_u32_slice(&mut out, ids);
    for c in st.level_coords(ids) {
        c.write_le(&mut out);
    }
    out
}

fn put_stream_state<S: Scalar>(
    out: &mut Vec<u8>,
    st: &StreamState<S>,
    avail: &HashMap<BlobKey, u64>,
) {
    wire::put_f64(out, st.d_cut);
    wire::put_density(out, st.model);
    wire::put_u64(out, st.pts.len() as u64);
    wire::put_u32(out, st.pts.dim() as u32);
    wire::put_u64(out, st.levels.len() as u64);
    for (k, ids) in &st.levels {
        wire::put_u32(out, *k);
        let blob = encode_blob(ids, st);
        let key: BlobKey = (crc64(&blob), blob.len() as u64);
        if let Some(&home) = avail.get(&key) {
            out.push(1);
            wire::put_u64(out, home);
            wire::put_u64(out, key.0);
            wire::put_u64(out, key.1);
        } else {
            out.push(0);
            wire::put_u64(out, blob.len() as u64);
            out.extend_from_slice(&blob);
        }
    }
    wire::put_u32_slice(out, &st.rho);
    put_dep(out, &st.dep);
    put_f64_slice(out, &st.delta);
    put_stats(out, &st.stats);
}

/// Encode a checkpoint, turning any level blob whose key appears in
/// `avail` into a ref to its home checkpoint. An empty map produces a
/// fully self-contained (all-inline) image.
pub fn encode_with_refs(data: &CheckpointData, avail: &HashMap<BlobKey, u64>) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&CHECKPOINT_MAGIC);
    wire::put_u32(&mut out, CHECKPOINT_VERSION);
    wire::put_u64(&mut out, data.streams.len() as u64);
    for (id, state) in &data.streams {
        wire::put_u64(&mut out, *id);
        match state {
            DynStreamState::F32(st) => {
                out.push(Dtype::F32.size_bytes() as u8);
                put_stream_state(&mut out, st, avail);
            }
            DynStreamState::F64(st) => {
                out.push(Dtype::F64.size_bytes() as u8);
                put_stream_state(&mut out, st, avail);
            }
        }
    }
    wire::put_u64(&mut out, data.sessions.len() as u64);
    for s in &data.sessions {
        wire::put_u64(&mut out, s.id);
        wire::put_f64(&mut out, s.d_cut);
        wire::put_density(&mut out, s.density);
        wire::put_store(&mut out, &s.pts);
        wire::put_u32_slice(&mut out, &s.rho);
        put_dep(&mut out, &s.dep);
        put_f64_slice(&mut out, &s.delta);
        wire::put_str(&mut out, &s.built_by);
        wire::put_f64(&mut out, s.density_secs);
        wire::put_f64(&mut out, s.dep_secs);
    }
    let crc = crc32(&out);
    wire::put_u32(&mut out, crc);
    out
}

/// Encode a fully self-contained checkpoint (every level inline).
pub fn encode(data: &CheckpointData) -> Vec<u8> {
    encode_with_refs(data, &HashMap::new())
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

fn get_dep(cur: &mut Cursor<'_>) -> Result<Vec<Option<u32>>, String> {
    let raw = wire::get_u32_vec(cur)?;
    Ok(raw.into_iter().map(|x| if x == u32::MAX { None } else { Some(x) }).collect())
}

fn get_f64_vec(cur: &mut Cursor<'_>) -> Result<Vec<f64>, String> {
    let len = cur.u64()? as usize;
    if cur.remaining() < len.checked_mul(8).ok_or("f64 slice length overflows")? {
        return Err(format!("f64 slice claims {len} elements, buffer too short"));
    }
    (0..len).map(|_| cur.f64()).collect()
}

fn get_stats(cur: &mut Cursor<'_>) -> Result<StreamStats, String> {
    Ok(StreamStats {
        ingests: cur.u64()?,
        points_ingested: cur.u64()?,
        trees_built: cur.u64()?,
        tree_points_built: cur.u64()?,
        rho_bumped: cur.u64()?,
        dep_full_queries: cur.u64()?,
        dep_seeded_races: cur.u64()?,
        dep_changed: cur.u64()?,
        rho_secs: cur.f64()?,
        dep_secs: cur.f64()?,
    })
}

/// Where a parsed level's bytes live.
enum LevelSrc<'a> {
    /// Blob inline in this file (integrity covered by the whole-file CRC).
    Inline(&'a [u8]),
    /// Blob inline in checkpoint `home`, addressed by its key.
    Ref { home: u64, key: BlobKey },
}

struct ParsedLevel<'a> {
    k: u32,
    src: LevelSrc<'a>,
}

struct ParsedStream<'a> {
    id: u64,
    dtype: Dtype,
    d_cut: f64,
    density: DensityModel,
    n: usize,
    dim: usize,
    levels: Vec<ParsedLevel<'a>>,
    rho: Vec<u32>,
    dep: Vec<Option<u32>>,
    delta: Vec<f64>,
    stats: StreamStats,
}

struct Parsed<'a> {
    streams: Vec<ParsedStream<'a>>,
    sessions: Vec<SessionState>,
}

fn corrupt(detail: String) -> DpcError {
    DpcError::CorruptCheckpoint { detail }
}

/// Structural parse: CRC-verify the whole file, then walk every section,
/// keeping level blobs as borrowed slices / unresolved refs. Nothing is
/// reassembled yet.
fn parse(bytes: &[u8]) -> Result<Parsed<'_>, DpcError> {
    if bytes.len() < 8 + 4 {
        return Err(corrupt(format!("file is {} bytes, shorter than header + CRC", bytes.len())));
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
    if crc32(body) != stored {
        return Err(corrupt(format!(
            "whole-file CRC mismatch (stored {stored:#010x}, computed {:#010x})",
            crc32(body)
        )));
    }
    let mut cur = Cursor::new(body);
    let magic = cur.take(4).map_err(corrupt)?;
    if magic != CHECKPOINT_MAGIC {
        return Err(corrupt(format!("bad magic {magic:?} (want \"PCLC\")")));
    }
    let version = cur.u32().map_err(corrupt)?;
    if version != CHECKPOINT_VERSION {
        return Err(corrupt(format!(
            "unsupported checkpoint version {version} (want {CHECKPOINT_VERSION}; pre-segmentation dirs must be rebuilt)"
        )));
    }

    let n_streams = cur.u64().map_err(corrupt)? as usize;
    let mut streams = Vec::with_capacity(n_streams.min(1024));
    for i in 0..n_streams {
        let sec = |d: String| corrupt(format!("stream {i}: {d}"));
        let id = cur.u64().map_err(sec)?;
        let tag = cur.u8().map_err(sec)?;
        let dtype = Dtype::from_tag(tag).ok_or_else(|| sec(format!("unknown dtype tag {tag}")))?;
        let d_cut = cur.f64().map_err(sec)?;
        let density = wire::get_density(&mut cur).map_err(sec)?;
        let n = cur.u64().map_err(sec)? as usize;
        let dim = cur.u32().map_err(sec)? as usize;
        if dim == 0 {
            return Err(sec(format!("dim = 0 (n = {n})")));
        }
        let n_levels = cur.u64().map_err(sec)? as usize;
        if n_levels > usize::BITS as usize {
            return Err(sec(format!("{n_levels} forest levels exceeds the {} possible", usize::BITS)));
        }
        let mut levels = Vec::with_capacity(n_levels);
        for li in 0..n_levels {
            let lsec = |d: String| corrupt(format!("stream {i} level {li}: {d}"));
            let k = cur.u32().map_err(lsec)?;
            let src = match cur.u8().map_err(lsec)? {
                0 => {
                    let blob_len = cur.u64().map_err(lsec)? as usize;
                    LevelSrc::Inline(cur.take(blob_len).map_err(lsec)?)
                }
                1 => {
                    let home = cur.u64().map_err(lsec)?;
                    let crc = cur.u64().map_err(lsec)?;
                    let len = cur.u64().map_err(lsec)?;
                    if home == 0 {
                        return Err(lsec("ref names checkpoint 0 (seqs start at 1)".into()));
                    }
                    LevelSrc::Ref { home, key: (crc, len) }
                }
                other => return Err(lsec(format!("unknown level tag {other}"))),
            };
            levels.push(ParsedLevel { k, src });
        }
        streams.push(ParsedStream {
            id,
            dtype,
            d_cut,
            density,
            n,
            dim,
            levels,
            rho: wire::get_u32_vec(&mut cur).map_err(sec)?,
            dep: get_dep(&mut cur).map_err(sec)?,
            delta: get_f64_vec(&mut cur).map_err(sec)?,
            stats: get_stats(&mut cur).map_err(sec)?,
        });
    }

    let n_sessions = cur.u64().map_err(corrupt)? as usize;
    let mut sessions = Vec::with_capacity(n_sessions.min(1024));
    for i in 0..n_sessions {
        let sec = |d: String| corrupt(format!("session {i}: {d}"));
        sessions.push(SessionState {
            id: cur.u64().map_err(sec)?,
            d_cut: cur.f64().map_err(sec)?,
            density: wire::get_density(&mut cur).map_err(sec)?,
            pts: wire::get_store::<f64>(&mut cur).map_err(sec)?,
            rho: wire::get_u32_vec(&mut cur).map_err(sec)?,
            dep: get_dep(&mut cur).map_err(sec)?,
            delta: get_f64_vec(&mut cur).map_err(sec)?,
            built_by: wire::get_str(&mut cur).map_err(sec)?,
            density_secs: cur.f64().map_err(sec)?,
            dep_secs: cur.f64().map_err(sec)?,
        });
    }
    cur.expect_end("checkpoint").map_err(corrupt)?;
    Ok(Parsed { streams, sessions })
}

/// Decode one level blob against the stream's dim: `(ids, gathered rows)`.
fn decode_blob<S: Scalar>(blob: &[u8], dim: usize) -> Result<(Vec<u32>, Vec<S>), String> {
    let mut cur = Cursor::new(blob);
    let ids = wire::get_u32_vec(&mut cur)?;
    let want = ids
        .len()
        .checked_mul(dim)
        .and_then(|c| c.checked_mul(S::BYTES))
        .ok_or("level blob size overflows")?;
    if cur.remaining() != want {
        return Err(format!(
            "level blob carries {} coordinate bytes, its {} ids × dim {dim} need {want}",
            cur.remaining(),
            ids.len()
        ));
    }
    let mut coords = Vec::with_capacity(ids.len() * dim);
    for _ in 0..ids.len() * dim {
        coords.push(S::read_le(cur.take(S::BYTES)?));
    }
    Ok((ids, coords))
}

/// Rebuild one stream's [`StreamState`] from its parsed section and the
/// resolved external blobs. The reassembled point store is byte-identical
/// to the exported one: each level's gathered rows scatter back through
/// its ids, and the levels must partition `0..n` exactly.
fn build_stream<S: Scalar>(
    ps: ParsedStream<'_>,
    external: &HashMap<BlobKey, Vec<u8>>,
) -> Result<StreamState<S>, DpcError> {
    let sec = |d: String| corrupt(format!("stream id {}: {d}", ps.id));
    // Resolve every blob to real bytes *before* sizing any allocation:
    // inline blobs are slices of this file, refs come from the loaded
    // (disk-backed) external map, so a forged `n` can only pass the
    // structural size equation below by actually shipping the bytes.
    let mut blobs = Vec::with_capacity(ps.levels.len());
    for (li, lvl) in ps.levels.iter().enumerate() {
        let bytes: &[u8] = match &lvl.src {
            LevelSrc::Inline(b) => b,
            LevelSrc::Ref { home, key } => external.get(key).map(Vec::as_slice).ok_or_else(|| {
                sec(format!(
                    "level {li}: blob {:#018x}/{} referenced from checkpoint {home} is unavailable",
                    key.0, key.1
                ))
            })?,
        };
        blobs.push(bytes);
    }
    let per_point = 4 + ps.dim * S::BYTES;
    let total: usize = blobs.iter().map(|b| b.len()).sum();
    let want = ps
        .n
        .checked_mul(per_point)
        .and_then(|c| c.checked_add(8 * ps.levels.len()))
        .ok_or_else(|| sec("stream size overflows".into()))?;
    if total != want {
        return Err(sec(format!(
            "level blobs total {total} bytes, {} points × dim {} across {} levels need {want}",
            ps.n,
            ps.dim,
            ps.levels.len()
        )));
    }
    let mut coords = vec![S::ZERO; ps.n * ps.dim];
    let mut covered = vec![false; ps.n];
    let mut levels = Vec::with_capacity(ps.levels.len());
    for (li, (lvl, blob)) in ps.levels.iter().zip(&blobs).enumerate() {
        let (ids, rows) =
            decode_blob::<S>(blob, ps.dim).map_err(|d| sec(format!("level {li}: {d}")))?;
        for (row, &id) in ids.iter().enumerate() {
            let id = id as usize;
            if id >= ps.n {
                return Err(sec(format!("level {li}: id {id} out of range (n = {})", ps.n)));
            }
            if covered[id] {
                return Err(sec(format!("level {li}: id {id} appears in more than one level")));
            }
            covered[id] = true;
            coords[id * ps.dim..(id + 1) * ps.dim]
                .copy_from_slice(&rows[row * ps.dim..(row + 1) * ps.dim]);
        }
        levels.push((lvl.k, ids));
    }
    let missing = covered.iter().filter(|&&c| !c).count();
    if missing != 0 {
        return Err(sec(format!("{missing} of {} points appear in no level", ps.n)));
    }
    let pts = PointStore::try_new(coords, ps.dim).map_err(|e| sec(e.to_string()))?;
    Ok(StreamState {
        d_cut: ps.d_cut,
        model: ps.density,
        pts,
        levels,
        rho: ps.rho,
        dep: ps.dep,
        delta: ps.delta,
        stats: ps.stats,
    })
}

fn assemble(
    parsed: Parsed<'_>,
    external: &HashMap<BlobKey, Vec<u8>>,
) -> Result<CheckpointData, DpcError> {
    let mut streams = Vec::with_capacity(parsed.streams.len());
    for ps in parsed.streams {
        let id = ps.id;
        let state = match ps.dtype {
            Dtype::F32 => DynStreamState::F32(build_stream::<f32>(ps, external)?),
            Dtype::F64 => DynStreamState::F64(build_stream::<f64>(ps, external)?),
        };
        streams.push((id, state));
    }
    Ok(CheckpointData { streams, sessions: parsed.sessions })
}

/// Decode a *self-contained* checkpoint image. All-or-nothing: any defect
/// — truncation, CRC mismatch, undecodable section, trailing bytes, or a
/// ref to another file (which a bare byte buffer cannot resolve) — aborts
/// with [`DpcError::CorruptCheckpoint`] before any state escapes. Images
/// on disk may carry refs; read those through [`read`].
pub fn decode(bytes: &[u8]) -> Result<CheckpointData, DpcError> {
    assemble(parse(bytes)?, &HashMap::new())
}

/// Read + decode `checkpoint-<seq>.pclc`, resolving level refs against
/// the checkpoint files they name. Every touched file is whole-file
/// CRC-verified before any blob is trusted, and refs resolve by content
/// key — a missing, stale, or corrupt referenced file is
/// [`DpcError::CorruptCheckpoint`].
pub fn read(dir: &Path, seq: u64) -> Result<CheckpointData, DpcError> {
    let path = checkpoint_file(dir, seq);
    let mut buf = Vec::new();
    File::open(&path)?.read_to_end(&mut buf)?;
    let parsed = parse(&buf)?;
    let mut homes: HashSet<u64> = HashSet::new();
    for s in &parsed.streams {
        for l in &s.levels {
            if let LevelSrc::Ref { home, .. } = l.src {
                homes.insert(home);
            }
        }
    }
    let mut external: HashMap<BlobKey, Vec<u8>> = HashMap::new();
    for home in homes {
        if home == seq {
            return Err(corrupt(format!("checkpoint {seq} references itself")));
        }
        let hp = checkpoint_file(dir, home);
        let mut hbuf = Vec::new();
        File::open(&hp)
            .and_then(|mut f| f.read_to_end(&mut hbuf))
            .map_err(|e| corrupt(format!("referenced checkpoint {home} unreadable: {e}")))?;
        let hparsed = parse(&hbuf)
            .map_err(|e| corrupt(format!("referenced checkpoint {home} invalid: {e}")))?;
        for s in &hparsed.streams {
            for l in &s.levels {
                if let LevelSrc::Inline(b) = l.src {
                    external.entry((crc64(b), b.len() as u64)).or_insert_with(|| b.to_vec());
                }
            }
        }
    }
    assemble(parsed, &external)
}

/// The blob keys an existing checkpoint makes addressable, mapped to the
/// checkpoint file that holds each blob *inline* (refs contribute their
/// already-resolved home, so refs built from this map never chain).
fn available_blobs(dir: &Path, seq: u64) -> Result<HashMap<BlobKey, u64>, DpcError> {
    let mut buf = Vec::new();
    File::open(checkpoint_file(dir, seq))?.read_to_end(&mut buf)?;
    let parsed = parse(&buf)?;
    let mut map = HashMap::new();
    for s in &parsed.streams {
        for l in &s.levels {
            match &l.src {
                LevelSrc::Inline(b) => {
                    map.insert((crc64(b), b.len() as u64), seq);
                }
                LevelSrc::Ref { home, key } => {
                    map.insert(*key, *home);
                }
            }
        }
    }
    Ok(map)
}

/// Refcount-aware checkpoint GC. The newest `retain` (min 1) checkpoint
/// files at or below `newest` are roots; every checkpoint a root
/// references is live; everything else — including crashed leftovers
/// above `newest` that no manifest ever reached — is deleted. Aborts
/// (deleting nothing) if any root is unreadable: a conservative sweep
/// can only leak disk, never break recovery. Returns the seqs removed.
pub fn gc(dir: &Path, newest: u64, retain: u64) -> Vec<u64> {
    let Ok(all) = list_checkpoints(dir) else {
        return Vec::new();
    };
    let retain = retain.max(1) as usize;
    let roots: Vec<u64> =
        all.iter().rev().map(|&(s, _)| s).filter(|&s| s <= newest).take(retain).collect();
    if roots.first() != Some(&newest) {
        return Vec::new(); // the manifest's checkpoint is missing — leave everything alone
    }
    let mut live: HashSet<u64> = roots.iter().copied().collect();
    for &root in &roots {
        let Ok(bytes) = std::fs::read(checkpoint_file(dir, root)) else {
            return Vec::new();
        };
        let Ok(parsed) = parse(&bytes) else {
            return Vec::new();
        };
        for s in &parsed.streams {
            for l in &s.levels {
                if let LevelSrc::Ref { home, .. } = l.src {
                    live.insert(home);
                }
            }
        }
    }
    let mut removed = Vec::new();
    for (seq, path) in all {
        if !live.contains(&seq) && std::fs::remove_file(&path).is_ok() {
            removed.push(seq);
        }
    }
    removed
}

/// Take a checkpoint: sync the journal, write + fsync the next
/// `checkpoint-<seq>.pclc` (delta-encoded against the previous one),
/// flip the manifest to `(seq, journal position)`, then garbage-collect
/// unreachable checkpoint files and journal segments below the new
/// replay horizon. Returns the new manifest.
///
/// The caller must ensure `data` reflects exactly the journal prefix up
/// to `journal.position()` — i.e. all appended entries have been applied
/// and no new ones can land mid-snapshot (the coordinator holds its
/// journal lock across the quiesce + export).
pub fn write(
    dir: &Path,
    journal: &mut JournalWriter,
    data: &CheckpointData,
    next_session_id: u64,
    retain: u64,
) -> Result<Manifest, DpcError> {
    journal.sync()?;
    let prev = manifest::read(dir)?;
    let seq = prev.map_or(1, |m| m.checkpoint_seq + 1);
    // Delta-encode against the previous checkpoint when possible; a
    // missing or unreadable predecessor just degrades to a full image.
    let avail = match prev.map(|m| m.checkpoint_seq) {
        Some(p) if p != 0 => available_blobs(dir, p).unwrap_or_default(),
        _ => HashMap::new(),
    };
    let path = checkpoint_file(dir, seq);
    {
        let mut f = OpenOptions::new().write(true).create(true).truncate(true).open(&path)?;
        f.write_all(&encode_with_refs(data, &avail))?;
        f.sync_data()?;
    }
    let (journal_seq, journal_offset) = journal.position();
    let m = Manifest {
        checkpoint_seq: seq,
        journal_seq,
        journal_offset,
        next_lsn: journal.next_lsn(),
        next_session_id,
    };
    manifest::write(dir, &m)?;
    // Both sweeps are best-effort cleanup after the flip, not correctness
    // steps: checkpoints unreachable from the retained roots, then
    // journal segments wholly below the new replay horizon.
    let _ = gc(dir, seq, retain);
    let _ = journal::gc_segments(dir, journal_seq);
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::StreamingSession;
    use crate::geom::PointStore;
    use crate::prng::SplitMix64;
    use crate::proputil::gen_clustered_points;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("parcluster-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_data_seeded(seed: u64) -> CheckpointData {
        let mut rng = SplitMix64::new(seed);
        let pts = gen_clustered_points(&mut rng, 70, 2, 3, 40.0, 1.5);
        let mut s64 =
            StreamingSession::<f64>::new_with_model(2, 3.0, DensityModel::Epanechnikov).unwrap();
        s64.ingest(&pts).unwrap();
        let mut s32 =
            StreamingSession::<f32>::new_with_model(3, 2.0, DensityModel::CutoffCount).unwrap();
        s32.ingest(&PointStore::<f32>::new(vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0], 3)).unwrap();
        let session = SessionState {
            id: 4,
            d_cut: 3.0,
            density: DensityModel::GaussianKernel,
            pts: pts.clone(),
            rho: s64.rho().to_vec(),
            dep: s64.dep().to_vec(),
            delta: s64.delta().to_vec(),
            built_by: "rust-tree".into(),
            density_secs: 0.25,
            dep_secs: 0.5,
        };
        CheckpointData {
            streams: vec![
                (1, DynStreamState::F64(s64.export_state())),
                (2, DynStreamState::F32(s32.export_state())),
            ],
            sessions: vec![session],
        }
    }

    fn sample_data() -> CheckpointData {
        sample_data_seeded(99)
    }

    fn assert_same_data(a: &CheckpointData, b: &CheckpointData) {
        assert_eq!(a.streams.len(), b.streams.len());
        for ((ida, sa), (idb, sb)) in a.streams.iter().zip(&b.streams) {
            assert_eq!(ida, idb);
            match (sa, sb) {
                (DynStreamState::F64(x), DynStreamState::F64(y)) => {
                    assert_eq!(x.pts.coords(), y.pts.coords());
                    assert_eq!(x.levels, y.levels);
                    assert_eq!(x.rho, y.rho);
                    assert_eq!(x.dep, y.dep);
                    assert_eq!(x.delta, y.delta);
                }
                (DynStreamState::F32(x), DynStreamState::F32(y)) => {
                    assert_eq!(x.pts.coords(), y.pts.coords());
                    assert_eq!(x.levels, y.levels);
                    assert_eq!(x.rho, y.rho);
                }
                _ => panic!("dtype mismatch between checkpoints"),
            }
        }
        assert_eq!(a.sessions.len(), b.sessions.len());
    }

    #[test]
    fn encode_decode_round_trip_preserves_everything() {
        let data = sample_data();
        let back = decode(&encode(&data)).unwrap();
        assert_eq!(back.streams.len(), 2);
        assert_eq!(back.sessions.len(), 1);
        let (id, DynStreamState::F64(st)) = &back.streams[0] else {
            panic!("stream 0 must be f64")
        };
        let DynStreamState::F64(want) = &data.streams[0].1 else { unreachable!() };
        assert_eq!(*id, 1);
        assert_eq!(st.rho, want.rho);
        assert_eq!(st.dep, want.dep);
        assert_eq!(st.delta, want.delta);
        assert_eq!(st.levels, want.levels);
        assert_eq!(st.pts.coords(), want.pts.coords());
        assert_eq!(st.stats.ingests, want.stats.ingests);
        let (_, DynStreamState::F32(st32)) = &back.streams[1] else {
            panic!("stream 1 must be f32")
        };
        assert_eq!(st32.pts.dim(), 3);
        let s = &back.sessions[0];
        assert_eq!((s.id, s.built_by.as_str()), (4, "rust-tree"));
        assert_eq!(s.rho, data.sessions[0].rho);
        assert_eq!(s.delta, data.sessions[0].delta);

        // The restored stream state must reconstruct a working session.
        let restored = StreamingSession::from_state(st.clone()).unwrap();
        assert_eq!(restored.rho(), want.rho.as_slice());
    }

    #[test]
    fn truncation_and_bit_flips_are_all_or_nothing() {
        let bytes = encode(&sample_data());
        for cut in [0, 5, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                matches!(decode(&bytes[..cut]), Err(DpcError::CorruptCheckpoint { .. })),
                "truncation at {cut} must be CorruptCheckpoint"
            );
        }
        for pos in [8, bytes.len() / 3, bytes.len() - 6] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x08;
            assert!(
                matches!(decode(&bad), Err(DpcError::CorruptCheckpoint { .. })),
                "bit flip at {pos} must be CorruptCheckpoint"
            );
        }
    }

    #[test]
    fn delta_checkpoints_reference_unchanged_levels() {
        let dir = tmpdir("delta");
        let mut journal = JournalWriter::create(&dir, 1, 0).unwrap();
        let data = sample_data();

        let m1 = write(&dir, &mut journal, &data, 5, 1).unwrap();
        assert_eq!((m1.checkpoint_seq, m1.journal_seq), (1, 1));
        let full_len = std::fs::metadata(checkpoint_file(&dir, 1)).unwrap().len();

        // Identical forest ⇒ every level refs checkpoint 1; the delta
        // image carries only the index + inline artifacts.
        let m2 = write(&dir, &mut journal, &data, 5, 1).unwrap();
        assert_eq!(m2.checkpoint_seq, 2);
        let delta_len = std::fs::metadata(checkpoint_file(&dir, 2)).unwrap().len();
        assert!(
            delta_len < full_len,
            "delta ({delta_len} B) must be smaller than full ({full_len} B)"
        );
        assert!(
            checkpoint_file(&dir, 1).exists(),
            "checkpoint 1 is referenced by 2 and must survive GC"
        );

        // Reassembly through the refs is byte-identical.
        assert_same_data(&read(&dir, 2).unwrap(), &read(&dir, 1).unwrap());
        assert_same_data(&read(&dir, 2).unwrap(), &decode(&encode(&data)).unwrap());

        // A delta image is NOT self-contained: bare decode must refuse it
        // rather than hand back a forest with holes.
        let bytes = std::fs::read(checkpoint_file(&dir, 2)).unwrap();
        assert!(matches!(decode(&bytes), Err(DpcError::CorruptCheckpoint { .. })));

        // Fully-changed forest ⇒ nothing to reference; the old chain is
        // no longer live and the sweep reclaims both old files.
        let other = sample_data_seeded(1234);
        let m3 = write(&dir, &mut journal, &other, 6, 1).unwrap();
        assert_eq!(m3.checkpoint_seq, 3);
        assert!(!checkpoint_file(&dir, 1).exists(), "unreferenced checkpoint 1 must be swept");
        assert!(!checkpoint_file(&dir, 2).exists(), "unreferenced checkpoint 2 must be swept");
        assert_same_data(&read(&dir, 3).unwrap(), &decode(&encode(&other)).unwrap());
        assert_eq!(manifest::read(&dir).unwrap(), Some(m3));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn retain_keeps_history_roots() {
        let dir = tmpdir("retain");
        let mut journal = JournalWriter::create(&dir, 1, 0).unwrap();
        let a = sample_data_seeded(7);
        let b = sample_data_seeded(8);
        write(&dir, &mut journal, &a, 2, 2).unwrap();
        write(&dir, &mut journal, &b, 2, 2).unwrap();
        write(&dir, &mut journal, &b, 2, 2).unwrap();
        // retain 2 keeps roots {3, 2}; 2 references nothing from 1 (a ≠ b),
        // so 1 is swept.
        assert!(!checkpoint_file(&dir, 1).exists());
        assert!(checkpoint_file(&dir, 2).exists());
        assert!(checkpoint_file(&dir, 3).exists());
        assert_same_data(&read(&dir, 3).unwrap(), &read(&dir, 2).unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn write_flips_manifest_and_journal_gc_trims_segments() {
        use super::super::journal::{segment_file, JournalEntry};
        let dir = tmpdir("write");
        // Tiny rotation threshold: every append seals a segment.
        let mut journal =
            JournalWriter::create(&dir, 1, super::super::journal::JOURNAL_HEADER_LEN + 1).unwrap();
        for i in 0..4 {
            journal
                .append(&JournalEntry::OpenStream {
                    stream: i,
                    dim: 2,
                    dtype: Dtype::F64,
                    d_cut: 3.0,
                    density: DensityModel::CutoffCount,
                })
                .unwrap();
        }
        let live_seq = journal.seq();
        assert!(live_seq >= 4);
        let m = write(&dir, &mut journal, &sample_data(), 5, 1).unwrap();
        assert_eq!(m.checkpoint_seq, 1);
        assert_eq!((m.journal_seq, m.journal_offset), journal.position());
        assert_eq!(manifest::read(&dir).unwrap(), Some(m));
        // Segments below the replay horizon are gone; the live one stays.
        for seq in 1..live_seq {
            assert!(!dir.join(segment_file(seq)).exists(), "segment {seq} must be GC'd");
        }
        assert!(dir.join(segment_file(live_seq)).exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_names_round_trip() {
        assert_eq!(parse_checkpoint_name("checkpoint-12.pclc"), Some(12));
        assert_eq!(parse_checkpoint_name("checkpoint-.pclc"), None);
        assert_eq!(parse_checkpoint_name("journal-3.pclj"), None);
    }
}
