//! Checkpoints: a point-in-time serialization of every live stream's
//! Bentley–Saxe forest state and every one-shot session's cached
//! (ρ, λ, δ) artifacts, so recovery replays only the journal suffix
//! written after the snapshot.
//!
//! ## File format (`checkpoint-<seq>.pclc`)
//!
//! ```text
//! magic "PCLC" | version u32
//! | n_streams u64 | stream... | n_sessions u64 | session...
//! | crc u32                       — CRC-32 of every preceding byte
//! stream:  id u64 | dtype u8 | d_cut f64 | density | pts (typed store)
//!          | n_levels u64 | (k u32 | ids u32-slice)...
//!          | rho u32-slice | dep u32-slice (u32::MAX = None)
//!          | delta count u64 + f64... | stats (8×u64 + 2×f64)
//! session: id u64 | d_cut f64 | density | pts (f64 store)
//!          | rho u32-slice | dep u32-slice | delta | built_by str
//!          | density_secs f64 | dep_secs f64
//! ```
//!
//! Decoding is all-or-nothing: the whole-file CRC is verified *before*
//! any section is parsed, and every section parse is bounds-checked, so a
//! truncated or bit-flipped checkpoint yields
//! [`DpcError::CorruptCheckpoint`] and zero restored state. One-shot
//! sessions are f64-only in serve mode, and the checkpoint section
//! mirrors that; streams are dtype-tagged and fully precision-generic.
//!
//! Writing is crash-safe by ordering: the checkpoint file is written and
//! fsynced *first*, the manifest flips to it *second* (atomically — see
//! [`super::manifest`]), and only then are older checkpoint files
//! deleted. A crash between any two steps leaves the previous
//! (checkpoint, offset) pair fully usable.

use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::dpc::{DensityModel, StreamState, StreamStats};
use crate::error::DpcError;
use crate::geom::{Dtype, PointSet, Scalar};

use super::crc32::crc32;
use super::journal::JournalWriter;
use super::manifest::{self, Manifest};
use super::wire::{self, Cursor};

pub const CHECKPOINT_MAGIC: [u8; 4] = *b"PCLC";
pub const CHECKPOINT_VERSION: u32 = 1;

/// `checkpoint-<seq>.pclc` in the durable directory.
pub fn checkpoint_file(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("checkpoint-{seq}.pclc"))
}

/// A dtype-tagged stream snapshot (the runtime union of
/// [`StreamState<f32>`] / [`StreamState<f64>`]).
#[derive(Clone, Debug)]
pub enum DynStreamState {
    F32(StreamState<f32>),
    F64(StreamState<f64>),
}

impl DynStreamState {
    pub fn dtype(&self) -> Dtype {
        match self {
            DynStreamState::F32(_) => Dtype::F32,
            DynStreamState::F64(_) => Dtype::F64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            DynStreamState::F32(s) => s.pts.len(),
            DynStreamState::F64(s) => s.pts.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A one-shot session's cached artifacts, as held by the coordinator:
/// enough to serve `recut`/`artifact` queries after restart without
/// re-clustering.
#[derive(Clone, Debug)]
pub struct SessionState {
    pub id: u64,
    pub d_cut: f64,
    pub density: DensityModel,
    pub pts: PointSet,
    pub rho: Vec<u32>,
    pub dep: Vec<Option<u32>>,
    pub delta: Vec<f64>,
    /// Engine label of the build that produced the artifacts (display
    /// only — restored sessions keep the original label).
    pub built_by: String,
    pub density_secs: f64,
    pub dep_secs: f64,
}

/// Everything a checkpoint captures.
#[derive(Clone, Debug, Default)]
pub struct CheckpointData {
    /// `(stream_id, state)`, any order.
    pub streams: Vec<(u64, DynStreamState)>,
    pub sessions: Vec<SessionState>,
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_dep(out: &mut Vec<u8>, dep: &[Option<u32>]) {
    wire::put_u64(out, dep.len() as u64);
    for d in dep {
        wire::put_u32(out, d.map_or(u32::MAX, |x| x));
    }
}

fn put_f64_slice(out: &mut Vec<u8>, xs: &[f64]) {
    wire::put_u64(out, xs.len() as u64);
    for &x in xs {
        wire::put_f64(out, x);
    }
}

fn put_stats(out: &mut Vec<u8>, s: &StreamStats) {
    for v in [
        s.ingests,
        s.points_ingested,
        s.trees_built,
        s.tree_points_built,
        s.rho_bumped,
        s.dep_full_queries,
        s.dep_seeded_races,
        s.dep_changed,
    ] {
        wire::put_u64(out, v);
    }
    wire::put_f64(out, s.rho_secs);
    wire::put_f64(out, s.dep_secs);
}

fn put_stream_state<S: Scalar>(out: &mut Vec<u8>, st: &StreamState<S>) {
    wire::put_f64(out, st.d_cut);
    wire::put_density(out, st.model);
    wire::put_store(out, &st.pts);
    wire::put_u64(out, st.levels.len() as u64);
    for (k, ids) in &st.levels {
        wire::put_u32(out, *k);
        wire::put_u32_slice(out, ids);
    }
    wire::put_u32_slice(out, &st.rho);
    put_dep(out, &st.dep);
    put_f64_slice(out, &st.delta);
    put_stats(out, &st.stats);
}

pub fn encode(data: &CheckpointData) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&CHECKPOINT_MAGIC);
    wire::put_u32(&mut out, CHECKPOINT_VERSION);
    wire::put_u64(&mut out, data.streams.len() as u64);
    for (id, state) in &data.streams {
        wire::put_u64(&mut out, *id);
        match state {
            DynStreamState::F32(st) => {
                out.push(Dtype::F32.size_bytes() as u8);
                put_stream_state(&mut out, st);
            }
            DynStreamState::F64(st) => {
                out.push(Dtype::F64.size_bytes() as u8);
                put_stream_state(&mut out, st);
            }
        }
    }
    wire::put_u64(&mut out, data.sessions.len() as u64);
    for s in &data.sessions {
        wire::put_u64(&mut out, s.id);
        wire::put_f64(&mut out, s.d_cut);
        wire::put_density(&mut out, s.density);
        wire::put_store(&mut out, &s.pts);
        wire::put_u32_slice(&mut out, &s.rho);
        put_dep(&mut out, &s.dep);
        put_f64_slice(&mut out, &s.delta);
        wire::put_str(&mut out, &s.built_by);
        wire::put_f64(&mut out, s.density_secs);
        wire::put_f64(&mut out, s.dep_secs);
    }
    let crc = crc32(&out);
    wire::put_u32(&mut out, crc);
    out
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

fn get_dep(cur: &mut Cursor<'_>) -> Result<Vec<Option<u32>>, String> {
    let raw = wire::get_u32_vec(cur)?;
    Ok(raw.into_iter().map(|x| if x == u32::MAX { None } else { Some(x) }).collect())
}

fn get_f64_vec(cur: &mut Cursor<'_>) -> Result<Vec<f64>, String> {
    let len = cur.u64()? as usize;
    if cur.remaining() < len.checked_mul(8).ok_or("f64 slice length overflows")? {
        return Err(format!("f64 slice claims {len} elements, buffer too short"));
    }
    (0..len).map(|_| cur.f64()).collect()
}

fn get_stats(cur: &mut Cursor<'_>) -> Result<StreamStats, String> {
    Ok(StreamStats {
        ingests: cur.u64()?,
        points_ingested: cur.u64()?,
        trees_built: cur.u64()?,
        tree_points_built: cur.u64()?,
        rho_bumped: cur.u64()?,
        dep_full_queries: cur.u64()?,
        dep_seeded_races: cur.u64()?,
        dep_changed: cur.u64()?,
        rho_secs: cur.f64()?,
        dep_secs: cur.f64()?,
    })
}

fn get_stream_state<S: Scalar>(cur: &mut Cursor<'_>) -> Result<StreamState<S>, String> {
    let d_cut = cur.f64()?;
    let model = wire::get_density(cur)?;
    let pts = wire::get_store::<S>(cur)?;
    let n_levels = cur.u64()? as usize;
    if n_levels > usize::BITS as usize {
        return Err(format!("{n_levels} forest levels exceeds the {} possible", usize::BITS));
    }
    let mut levels = Vec::with_capacity(n_levels);
    for _ in 0..n_levels {
        let k = cur.u32()?;
        let ids = wire::get_u32_vec(cur)?;
        levels.push((k, ids));
    }
    Ok(StreamState {
        d_cut,
        model,
        pts,
        levels,
        rho: wire::get_u32_vec(cur)?,
        dep: get_dep(cur)?,
        delta: get_f64_vec(cur)?,
        stats: get_stats(cur)?,
    })
}

/// Decode a checkpoint image. All-or-nothing: any defect — truncation,
/// CRC mismatch, undecodable section, trailing bytes — aborts with
/// [`DpcError::CorruptCheckpoint`] before any state escapes.
pub fn decode(bytes: &[u8]) -> Result<CheckpointData, DpcError> {
    let corrupt = |detail: String| DpcError::CorruptCheckpoint { detail };
    if bytes.len() < 8 + 4 {
        return Err(corrupt(format!("file is {} bytes, shorter than header + CRC", bytes.len())));
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
    if crc32(body) != stored {
        return Err(corrupt(format!(
            "whole-file CRC mismatch (stored {stored:#010x}, computed {:#010x})",
            crc32(body)
        )));
    }
    let mut cur = Cursor::new(body);
    let magic = cur.take(4).map_err(&corrupt)?;
    if magic != CHECKPOINT_MAGIC {
        return Err(corrupt(format!("bad magic {magic:?} (want \"PCLC\")")));
    }
    let version = cur.u32().map_err(&corrupt)?;
    if version != CHECKPOINT_VERSION {
        return Err(corrupt(format!("unsupported checkpoint version {version}")));
    }

    let n_streams = cur.u64().map_err(&corrupt)? as usize;
    let mut streams = Vec::with_capacity(n_streams.min(1024));
    for i in 0..n_streams {
        let id = cur.u64().map_err(&corrupt)?;
        let tag = cur.u8().map_err(&corrupt)?;
        let dtype = Dtype::from_tag(tag)
            .ok_or_else(|| corrupt(format!("stream {i}: unknown dtype tag {tag}")))?;
        let state = match dtype {
            Dtype::F32 => DynStreamState::F32(
                get_stream_state(&mut cur).map_err(|d| corrupt(format!("stream {i}: {d}")))?,
            ),
            Dtype::F64 => DynStreamState::F64(
                get_stream_state(&mut cur).map_err(|d| corrupt(format!("stream {i}: {d}")))?,
            ),
        };
        streams.push((id, state));
    }

    let n_sessions = cur.u64().map_err(&corrupt)? as usize;
    let mut sessions = Vec::with_capacity(n_sessions.min(1024));
    for i in 0..n_sessions {
        let sec = |d: String| corrupt(format!("session {i}: {d}"));
        sessions.push(SessionState {
            id: cur.u64().map_err(sec)?,
            d_cut: cur.f64().map_err(sec)?,
            density: wire::get_density(&mut cur).map_err(sec)?,
            pts: wire::get_store::<f64>(&mut cur).map_err(sec)?,
            rho: wire::get_u32_vec(&mut cur).map_err(sec)?,
            dep: get_dep(&mut cur).map_err(sec)?,
            delta: get_f64_vec(&mut cur).map_err(sec)?,
            built_by: wire::get_str(&mut cur).map_err(sec)?,
            density_secs: cur.f64().map_err(sec)?,
            dep_secs: cur.f64().map_err(sec)?,
        });
    }
    cur.expect_end("checkpoint").map_err(&corrupt)?;
    Ok(CheckpointData { streams, sessions })
}

/// Read + decode `checkpoint-<seq>.pclc`.
pub fn read(dir: &Path, seq: u64) -> Result<CheckpointData, DpcError> {
    let path = checkpoint_file(dir, seq);
    let mut buf = Vec::new();
    File::open(&path)?.read_to_end(&mut buf)?;
    decode(&buf)
}

/// Take a checkpoint: sync the journal, write + fsync the next
/// `checkpoint-<seq>.pclc`, flip the manifest to `(seq, journal end)`,
/// then garbage-collect older checkpoint files. Returns the new manifest.
///
/// The caller must ensure `data` reflects exactly the journal prefix up
/// to `journal.len()` — i.e. all appended entries have been applied and
/// no new ones can land mid-snapshot (the coordinator holds its journal
/// lock across the quiesce + export).
pub fn write(
    dir: &Path,
    journal: &mut JournalWriter,
    data: &CheckpointData,
    next_session_id: u64,
) -> Result<Manifest, DpcError> {
    journal.sync()?;
    let prev = manifest::read(dir)?;
    let seq = prev.map_or(1, |m| m.checkpoint_seq + 1);
    let path = checkpoint_file(dir, seq);
    {
        let mut f = OpenOptions::new().write(true).create(true).truncate(true).open(&path)?;
        f.write_all(&encode(data))?;
        f.sync_data()?;
    }
    let m = Manifest {
        checkpoint_seq: seq,
        journal_offset: journal.len(),
        next_lsn: journal.next_lsn(),
        next_session_id,
    };
    manifest::write(dir, &m)?;
    // Old checkpoints are now unreachable from the manifest; their
    // deletion is best-effort cleanup, not a correctness step.
    if let Some(prev) = prev {
        if prev.checkpoint_seq != 0 {
            let _ = std::fs::remove_file(checkpoint_file(dir, prev.checkpoint_seq));
        }
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::StreamingSession;
    use crate::geom::{DynPoints, PointStore};
    use crate::prng::SplitMix64;
    use crate::proputil::gen_clustered_points;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("parcluster-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_data() -> CheckpointData {
        let mut rng = SplitMix64::new(99);
        let pts = gen_clustered_points(&mut rng, 70, 2, 3, 40.0, 1.5);
        let mut s64 =
            StreamingSession::<f64>::new_with_model(2, 3.0, DensityModel::Epanechnikov).unwrap();
        s64.ingest(&pts).unwrap();
        let mut s32 =
            StreamingSession::<f32>::new_with_model(3, 2.0, DensityModel::CutoffCount).unwrap();
        s32.ingest(&PointStore::<f32>::new(vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0], 3)).unwrap();
        let session = SessionState {
            id: 4,
            d_cut: 3.0,
            density: DensityModel::GaussianKernel,
            pts: pts.clone(),
            rho: s64.rho().to_vec(),
            dep: s64.dep().to_vec(),
            delta: s64.delta().to_vec(),
            built_by: "rust-tree".into(),
            density_secs: 0.25,
            dep_secs: 0.5,
        };
        CheckpointData {
            streams: vec![
                (1, DynStreamState::F64(s64.export_state())),
                (2, DynStreamState::F32(s32.export_state())),
            ],
            sessions: vec![session],
        }
    }

    #[test]
    fn encode_decode_round_trip_preserves_everything() {
        let data = sample_data();
        let back = decode(&encode(&data)).unwrap();
        assert_eq!(back.streams.len(), 2);
        assert_eq!(back.sessions.len(), 1);
        let (id, DynStreamState::F64(st)) = &back.streams[0] else {
            panic!("stream 0 must be f64")
        };
        let DynStreamState::F64(want) = &data.streams[0].1 else { unreachable!() };
        assert_eq!(*id, 1);
        assert_eq!(st.rho, want.rho);
        assert_eq!(st.dep, want.dep);
        assert_eq!(st.delta, want.delta);
        assert_eq!(st.levels, want.levels);
        assert_eq!(st.pts.coords(), want.pts.coords());
        assert_eq!(st.stats.ingests, want.stats.ingests);
        let (_, DynStreamState::F32(st32)) = &back.streams[1] else {
            panic!("stream 1 must be f32")
        };
        assert_eq!(st32.pts.dim(), 3);
        let s = &back.sessions[0];
        assert_eq!((s.id, s.built_by.as_str()), (4, "rust-tree"));
        assert_eq!(s.rho, data.sessions[0].rho);
        assert_eq!(s.delta, data.sessions[0].delta);

        // The restored stream state must reconstruct a working session.
        let restored = StreamingSession::from_state(st.clone()).unwrap();
        assert_eq!(restored.rho(), want.rho.as_slice());
    }

    #[test]
    fn truncation_and_bit_flips_are_all_or_nothing() {
        let bytes = encode(&sample_data());
        for cut in [0, 5, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                matches!(decode(&bytes[..cut]), Err(DpcError::CorruptCheckpoint { .. })),
                "truncation at {cut} must be CorruptCheckpoint"
            );
        }
        for pos in [8, bytes.len() / 3, bytes.len() - 6] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x08;
            assert!(
                matches!(decode(&bad), Err(DpcError::CorruptCheckpoint { .. })),
                "bit flip at {pos} must be CorruptCheckpoint"
            );
        }
    }

    #[test]
    fn write_flips_manifest_and_collects_old_files() {
        use super::super::journal::{JournalWriter, JOURNAL_FILE};
        let dir = tmpdir("write");
        let mut journal = JournalWriter::create(&dir.join(JOURNAL_FILE), 1).unwrap();
        journal
            .append(&super::super::journal::JournalEntry::OpenStream {
                stream: 1,
                dim: 2,
                dtype: Dtype::F64,
                d_cut: 3.0,
                density: DensityModel::CutoffCount,
            })
            .unwrap();
        manifest::write(
            &dir,
            &Manifest {
                checkpoint_seq: 0,
                journal_offset: super::super::journal::JOURNAL_HEADER_LEN,
                next_lsn: 1,
                next_session_id: 1,
            },
        )
        .unwrap();

        let m1 = write(&dir, &mut journal, &sample_data(), 5).unwrap();
        assert_eq!(m1.checkpoint_seq, 1);
        assert_eq!(m1.journal_offset, journal.len());
        assert!(checkpoint_file(&dir, 1).exists());

        let m2 = write(&dir, &mut journal, &sample_data(), 6).unwrap();
        assert_eq!(m2.checkpoint_seq, 2);
        assert!(checkpoint_file(&dir, 2).exists());
        assert!(!checkpoint_file(&dir, 1).exists(), "old checkpoint must be collected");
        assert_eq!(manifest::read(&dir).unwrap(), Some(m2));
        assert_eq!(read(&dir, 2).unwrap().streams.len(), 2);

        // Ingest batch codec sanity: DynPoints round-trips through the
        // journal entry the checkpoint's offset points past.
        let scan = super::super::journal::scan(&dir.join(JOURNAL_FILE)).unwrap();
        assert_eq!(scan.entries.len(), 1);
        let _ = DynPoints::F64(PointStore::new(vec![1.0, 2.0], 2));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
