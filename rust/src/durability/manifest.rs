//! The durability manifest: a single fixed-size record naming the latest
//! valid `(checkpoint, journal position)` pair. Recovery reads it first
//! and trusts nothing it does not point at.
//!
//! ## File format (`MANIFEST`)
//!
//! ```text
//! magic "PCLM" | version u32 | checkpoint_seq u64 (0 = no checkpoint)
//! | journal_seq u64 | journal_offset u64 | next_lsn u64
//! | next_session_id u64 | crc u32
//! ```
//!
//! Version 2 (this layout, 52 bytes) replaced the pre-segmentation v1
//! record by inserting `journal_seq`: with a segmented journal the replay
//! position is a `(segment seq, byte offset)` pair, not a bare offset.
//! v1 manifests are rejected as [`DpcError::CorruptManifest`] — the
//! formats are pre-release and migrate by rebuilding the durable dir,
//! not by in-place upgrade (see DESIGN.md §Durability).
//!
//! The CRC-32 covers every preceding byte. The record is written with the
//! classic atomic-replace dance — write `MANIFEST.tmp`, fsync it, rename
//! over `MANIFEST`, fsync the directory — so a crash at any instant
//! leaves either the old record or the new one, never a mix. Readers
//! therefore treat a short/garbled manifest as [`DpcError::CorruptManifest`],
//! not as something to repair around.

use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::Path;

use crate::error::DpcError;

use super::crc32::crc32;
use super::wire::{self, Cursor};

pub const MANIFEST_MAGIC: [u8; 4] = *b"PCLM";
pub const MANIFEST_VERSION: u32 = 2;
pub const MANIFEST_FILE: &str = "MANIFEST";

/// Total encoded size: 4 + 4 + 8·5 + 4.
const MANIFEST_LEN: usize = 52;

/// The durable root of trust for a `--durable` directory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Manifest {
    /// Sequence number of the newest valid checkpoint
    /// (`checkpoint-<seq>.pclc`); 0 means "no checkpoint yet — replay the
    /// journal from segment 1".
    pub checkpoint_seq: u64,
    /// Journal segment replay starts in (`journal-<seq>.pclj`). Segments
    /// strictly below this are past the replay horizon and eligible for
    /// GC; leftovers below it are ignored by recovery.
    pub journal_seq: u64,
    /// Byte offset within that segment replay starts from: everything at
    /// or past this offset post-dates the checkpoint.
    pub journal_offset: u64,
    /// First LSN not covered by the checkpoint (the LSN expected at
    /// `journal_offset`, or the writer's next LSN if the journal ends
    /// exactly there).
    pub next_lsn: u64,
    /// Coordinator id-allocator floor as of the checkpoint.
    pub next_session_id: u64,
}

impl Manifest {
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(MANIFEST_LEN);
        out.extend_from_slice(&MANIFEST_MAGIC);
        wire::put_u32(&mut out, MANIFEST_VERSION);
        wire::put_u64(&mut out, self.checkpoint_seq);
        wire::put_u64(&mut out, self.journal_seq);
        wire::put_u64(&mut out, self.journal_offset);
        wire::put_u64(&mut out, self.next_lsn);
        wire::put_u64(&mut out, self.next_session_id);
        let crc = crc32(&out);
        wire::put_u32(&mut out, crc);
        out
    }
}

/// Atomically replace the manifest in `dir`.
pub fn write(dir: &Path, m: &Manifest) -> Result<(), DpcError> {
    let tmp = dir.join("MANIFEST.tmp");
    let dst = dir.join(MANIFEST_FILE);
    {
        let mut f = OpenOptions::new().write(true).create(true).truncate(true).open(&tmp)?;
        f.write_all(&m.encode())?;
        f.sync_data()?;
    }
    std::fs::rename(&tmp, &dst)?;
    // Make the rename itself durable. Directory fsync is not supported on
    // every platform; failure to open the dir read-only is non-fatal.
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_data();
    }
    Ok(())
}

/// Read the manifest; `Ok(None)` when the file does not exist (a fresh
/// directory), [`DpcError::CorruptManifest`] when it exists but fails
/// validation.
pub fn read(dir: &Path) -> Result<Option<Manifest>, DpcError> {
    let path = dir.join(MANIFEST_FILE);
    let mut buf = Vec::new();
    match File::open(&path) {
        Ok(mut f) => f.read_to_end(&mut buf)?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let corrupt = |detail: String| DpcError::CorruptManifest { detail };
    if buf.len() != MANIFEST_LEN {
        return Err(corrupt(format!("manifest is {} bytes, want {MANIFEST_LEN}", buf.len())));
    }
    let (body, crc_bytes) = buf.split_at(MANIFEST_LEN - 4);
    let stored = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
    if crc32(body) != stored {
        return Err(corrupt(format!(
            "CRC mismatch (stored {stored:#010x}, computed {:#010x})",
            crc32(body)
        )));
    }
    let mut cur = Cursor::new(body);
    let magic = cur.take(4).map_err(&corrupt)?;
    if magic != MANIFEST_MAGIC {
        return Err(corrupt(format!("bad magic {magic:?} (want \"PCLM\")")));
    }
    let version = cur.u32().map_err(&corrupt)?;
    if version != MANIFEST_VERSION {
        return Err(corrupt(format!(
            "unsupported manifest version {version} (want {MANIFEST_VERSION}; pre-segmentation dirs must be rebuilt)"
        )));
    }
    let m = Manifest {
        checkpoint_seq: cur.u64().map_err(&corrupt)?,
        journal_seq: cur.u64().map_err(&corrupt)?,
        journal_offset: cur.u64().map_err(&corrupt)?,
        next_lsn: cur.u64().map_err(&corrupt)?,
        next_session_id: cur.u64().map_err(&corrupt)?,
    };
    if m.journal_seq == 0 {
        return Err(corrupt("journal_seq must be positive (segments start at 1)".into()));
    }
    if m.next_lsn == 0 || m.next_session_id == 0 {
        return Err(corrupt("next_lsn and next_session_id must be positive".into()));
    }
    Ok(Some(m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("parcluster-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn round_trip_and_missing() {
        let dir = tmpdir("rt");
        assert!(read(&dir).unwrap().is_none(), "fresh dir has no manifest");
        let m = Manifest {
            checkpoint_seq: 3,
            journal_seq: 2,
            journal_offset: 1024,
            next_lsn: 17,
            next_session_id: 5,
        };
        write(&dir, &m).unwrap();
        assert_eq!(read(&dir).unwrap(), Some(m));
        // Overwrite is atomic-replace, not append.
        let m2 = Manifest {
            checkpoint_seq: 4,
            journal_seq: 7,
            journal_offset: 2048,
            next_lsn: 30,
            next_session_id: 6,
        };
        write(&dir, &m2).unwrap();
        assert_eq!(read(&dir).unwrap(), Some(m2));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corruption_shapes_are_typed() {
        let dir = tmpdir("corrupt");
        let m = Manifest {
            checkpoint_seq: 1,
            journal_seq: 1,
            journal_offset: 24,
            next_lsn: 1,
            next_session_id: 1,
        };
        write(&dir, &m).unwrap();
        let path = dir.join(MANIFEST_FILE);
        let good = std::fs::read(&path).unwrap();

        // Truncated.
        std::fs::write(&path, &good[..good.len() - 1]).unwrap();
        assert!(matches!(read(&dir), Err(DpcError::CorruptManifest { .. })));

        // Bit flip in the body.
        let mut flipped = good.clone();
        flipped[10] ^= 0x01;
        std::fs::write(&path, &flipped).unwrap();
        assert!(matches!(read(&dir), Err(DpcError::CorruptManifest { .. })));

        // Garbage of the right length.
        std::fs::write(&path, vec![0xAB; good.len()]).unwrap();
        assert!(matches!(read(&dir), Err(DpcError::CorruptManifest { .. })));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn v1_manifest_is_rejected_with_guidance() {
        // Hand-build a valid-CRC version-1 record (44 bytes, no
        // journal_seq): must be refused, not misparsed.
        let dir = tmpdir("v1");
        let mut out = Vec::new();
        out.extend_from_slice(&MANIFEST_MAGIC);
        wire::put_u32(&mut out, 1);
        for v in [0u64, 8, 1, 1] {
            wire::put_u64(&mut out, v);
        }
        let crc = crc32(&out);
        wire::put_u32(&mut out, crc);
        std::fs::write(dir.join(MANIFEST_FILE), &out).unwrap();
        match read(&dir) {
            Err(DpcError::CorruptManifest { detail }) => {
                // 44 ≠ 52 bytes trips the length gate first; either
                // message is an acceptable typed rejection.
                assert!(detail.contains("52") || detail.contains("version"), "{detail}");
            }
            other => panic!("expected CorruptManifest, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn zero_journal_seq_is_rejected() {
        let dir = tmpdir("zeroseq");
        let mut out = Vec::new();
        out.extend_from_slice(&MANIFEST_MAGIC);
        wire::put_u32(&mut out, MANIFEST_VERSION);
        for v in [0u64, 0, 24, 1, 1] {
            wire::put_u64(&mut out, v);
        }
        let crc = crc32(&out);
        wire::put_u32(&mut out, crc);
        std::fs::write(dir.join(MANIFEST_FILE), &out).unwrap();
        match read(&dir) {
            Err(DpcError::CorruptManifest { detail }) => {
                assert!(detail.contains("journal_seq"), "{detail}")
            }
            other => panic!("expected CorruptManifest, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
