//! Durable streams: write-ahead journal + checkpoint/restore for serve
//! mode.
//!
//! A `--durable <dir>` coordinator writes every state-changing command —
//! `open_stream` / `ingest` / `close_stream`, `open_session` / `recut` /
//! `close_session` — to an append-only, CRC-framed, *segmented* journal
//! before acknowledging it, and periodically snapshots the live state
//! (each stream's Bentley–Saxe forest, each session's cached (ρ, λ, δ)
//! artifacts) into a checkpoint named by an atomically-replaced manifest.
//! After a crash, [`recover`] loads the newest checkpoint and replays the
//! journal suffix through the normal ingest paths; because every path is
//! deterministic, the restored artifacts are byte-identical to a fresh
//! build over the concatenated batches — for every density model, dtype,
//! and thread count.
//!
//! Disk use is bounded: the journal rotates to a new segment at a
//! configurable byte threshold, and every checkpoint ends with two GC
//! sweeps — whole journal segments strictly below the manifest's replay
//! horizon, and checkpoint files no live snapshot references (checkpoints
//! are *incremental*: unchanged forest levels are stored once and
//! referenced by content address from later snapshots).
//!
//! The directory layout:
//!
//! ```text
//! <dir>/journal-<seq>.pclj     command-log segments       (magic "PCLJ")
//! <dir>/checkpoint-<seq>.pclc  state snapshots            (magic "PCLC")
//! <dir>/MANIFEST               root of trust              (magic "PCLM")
//! ```
//!
//! Module map — each file owns one format or one phase:
//!
//! - [`crc32`]: the shared IEEE CRC-32 (hand-rolled, dependency-free) —
//!   corruption detection on every frame and file.
//! - [`crc64`]: CRC-64/XZ — content identity for checkpoint level blobs
//!   (64 bits so collisions across a checkpoint chain are negligible).
//! - [`wire`]: bounds-checked little-endian codecs (cursor, density
//!   model, point batches) used by all the formats.
//! - [`journal`]: segment framing, rotation, the fsync/group-commit
//!   policy, the torn-tail-vs-corruption scan, and segment GC.
//! - [`checkpoint`]: whole-file-CRC incremental snapshots and the
//!   write-then-flip-then-collect checkpoint protocol.
//! - [`manifest`]: the fixed-size atomic root record.
//! - [`recovery`]: manifest → checkpoint → replay orchestration.
//!
//! See DESIGN.md §Durability for the crash-consistency argument.

pub mod checkpoint;
pub mod crc32;
pub mod crc64;
pub mod journal;
pub mod manifest;
pub mod recovery;
pub mod wire;

pub use checkpoint::{CheckpointData, DynStreamState, SessionState};
pub use journal::{JournalEntry, JournalWriter, ScanOutcome, ScannedFrame, SegmentInfo};
pub use manifest::Manifest;
pub use recovery::{recover, DynStream, Recovered, RecoveryReport};
