//! Durable streams: write-ahead journal + checkpoint/restore for serve
//! mode.
//!
//! A `--durable <dir>` coordinator writes every state-changing command —
//! `open_stream` / `ingest` / `close_stream`, `open_session` / `recut` /
//! `close_session` — to an append-only, CRC-framed journal *before*
//! acknowledging it, and periodically snapshots the live state (each
//! stream's Bentley–Saxe forest, each session's cached (ρ, λ, δ)
//! artifacts) into a checkpoint named by an atomically-replaced manifest.
//! After a crash, [`recover`] loads the newest checkpoint and replays the
//! journal suffix through the normal ingest paths; because every path is
//! deterministic, the restored artifacts are byte-identical to a fresh
//! build over the concatenated batches — for every density model, dtype,
//! and thread count.
//!
//! The directory layout:
//!
//! ```text
//! <dir>/journal.pclj          append-only command log   (magic "PCLJ")
//! <dir>/checkpoint-<seq>.pclc newest state snapshot     (magic "PCLC")
//! <dir>/MANIFEST              root of trust             (magic "PCLM")
//! ```
//!
//! Module map — each file owns one format or one phase:
//!
//! - [`crc32`]: the shared IEEE CRC-32 (hand-rolled, dependency-free).
//! - [`wire`]: bounds-checked little-endian codecs (cursor, density
//!   model, point batches) used by all three formats.
//! - [`journal`]: framing, the fsync/group-commit policy, and the
//!   torn-tail-vs-corruption scan.
//! - [`checkpoint`]: whole-file-CRC state snapshots and the
//!   write-then-flip-then-collect checkpoint protocol.
//! - [`manifest`]: the fixed-size atomic root record.
//! - [`recovery`]: manifest → checkpoint → replay orchestration.
//!
//! See DESIGN.md §Durability for the crash-consistency argument.

pub mod checkpoint;
pub mod crc32;
pub mod journal;
pub mod manifest;
pub mod recovery;
pub mod wire;

pub use checkpoint::{CheckpointData, DynStreamState, SessionState};
pub use journal::{JournalEntry, JournalWriter, ScanOutcome, ScannedFrame};
pub use manifest::Manifest;
pub use recovery::{recover, DynStream, Recovered, RecoveryReport};
