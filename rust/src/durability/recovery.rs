//! Crash recovery: manifest → checkpoint → journal-suffix replay.
//!
//! [`recover`] rebuilds exactly the state a durable coordinator held at
//! its last acknowledged command: it loads the newest checkpoint named by
//! the manifest (resolving delta refs against prior checkpoint files),
//! then replays every journal entry past the checkpoint's replay
//! position — a `(segment seq, byte offset)` pair — through the *normal*
//! ingest/build paths: the same [`StreamingSession`] merge/repair code
//! and the same exact pipeline the live server runs. Because every one of
//! those paths is deterministic and thread-count-independent (the
//! conformance suites pin this), the recovered (ρ, λ, δ) artifacts are
//! byte-identical to a fresh build over the concatenated batches.
//!
//! Segments strictly below the manifest's `journal_seq` are *ignored*,
//! not scanned: a crash between a checkpoint's manifest flip and its GC
//! sweep legally leaves stale segments behind, and the next checkpoint
//! deletes them. The writer is re-armed at the end of the **last**
//! segment.
//!
//! Failure taxonomy (what each input defect becomes):
//!
//! | defect                                 | outcome                        |
//! |----------------------------------------|--------------------------------|
//! | incomplete frame ending the last segment | silently truncated, replay ok |
//! | short frame in a sealed (non-final) segment | [`DpcError::CorruptJournal`] |
//! | complete frame, bad CRC/LSN/payload    | [`DpcError::CorruptJournal`]   |
//! | gap or header mismatch in the segment chain | [`DpcError::CorruptJournal`] |
//! | checkpoint truncated / bit-flipped / ref unresolvable | [`DpcError::CorruptCheckpoint`] |
//! | manifest garbled, or position past end | [`DpcError::CorruptManifest`]  |
//! | journal present, manifest missing      | [`DpcError::CorruptManifest`]  |
//! | replayed command fails (e.g. bad pts)  | entry skipped, counted         |
//!
//! A *skipped* entry mirrors live behaviour: a command the live server
//! accepted into the journal but whose job then failed leaves no state,
//! so replaying its failure leaves no state either.

use std::path::Path;

use crate::dpc::{Dpc, DpcParams, DpcResult, StreamingSession, StreamStats};
use crate::error::DpcError;
use crate::geom::{Dtype, DynPoints};

use super::checkpoint::{self, CheckpointData, DynStreamState, SessionState};
use super::journal::{self, JournalEntry, JournalWriter, JOURNAL_HEADER_LEN};
use super::manifest::{self, Manifest};

/// A live streaming session at either precision — the runtime union the
/// replay loop drives, and the type the coordinator keeps per stream so
/// crash-recovered f32 streams stay first-class (ingestable) instead of
/// warn-and-drop dead ends.
#[derive(Debug)]
pub enum DynStream {
    F32(StreamingSession<f32>),
    F64(StreamingSession<f64>),
}

impl DynStream {
    pub fn dtype(&self) -> Dtype {
        match self {
            DynStream::F32(_) => Dtype::F32,
            DynStream::F64(_) => Dtype::F64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            DynStream::F32(s) => s.len(),
            DynStream::F64(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        match self {
            DynStream::F32(s) => s.dim(),
            DynStream::F64(s) => s.dim(),
        }
    }

    pub fn d_cut(&self) -> f64 {
        match self {
            DynStream::F32(s) => s.d_cut(),
            DynStream::F64(s) => s.d_cut(),
        }
    }

    pub fn density_model(&self) -> crate::dpc::DensityModel {
        match self {
            DynStream::F32(s) => s.density_model(),
            DynStream::F64(s) => s.density_model(),
        }
    }

    pub fn rho(&self) -> &[u32] {
        match self {
            DynStream::F32(s) => s.rho(),
            DynStream::F64(s) => s.rho(),
        }
    }

    pub fn dep(&self) -> &[Option<u32>] {
        match self {
            DynStream::F32(s) => s.dep(),
            DynStream::F64(s) => s.dep(),
        }
    }

    pub fn delta(&self) -> &[f64] {
        match self {
            DynStream::F32(s) => s.delta(),
            DynStream::F64(s) => s.delta(),
        }
    }

    pub fn stats(&self) -> StreamStats {
        match self {
            DynStream::F32(s) => s.stats(),
            DynStream::F64(s) => s.stats(),
        }
    }

    pub fn level_sizes(&self) -> Vec<usize> {
        match self {
            DynStream::F32(s) => s.level_sizes(),
            DynStream::F64(s) => s.level_sizes(),
        }
    }

    pub fn cut(&self, rho_min: f64, delta_min: f64) -> Result<DpcResult, DpcError> {
        match self {
            DynStream::F32(s) => s.cut(rho_min, delta_min),
            DynStream::F64(s) => s.cut(rho_min, delta_min),
        }
    }

    pub fn export_state(&self) -> DynStreamState {
        match self {
            DynStream::F32(s) => DynStreamState::F32(s.export_state()),
            DynStream::F64(s) => DynStreamState::F64(s.export_state()),
        }
    }

    /// Open a fresh empty stream of the given precision.
    pub fn new_with_model(
        dtype: Dtype,
        dim: usize,
        d_cut: f64,
        density: crate::dpc::DensityModel,
    ) -> Result<DynStream, DpcError> {
        Ok(match dtype {
            Dtype::F32 => DynStream::F32(StreamingSession::new_with_model(dim, d_cut, density)?),
            Dtype::F64 => DynStream::F64(StreamingSession::new_with_model(dim, d_cut, density)?),
        })
    }

    pub fn from_state(state: DynStreamState) -> Result<DynStream, DpcError> {
        // Structural defects inside a CRC-valid checkpoint are still
        // checkpoint corruption, not parameter errors.
        let wrap = |e: DpcError| DpcError::CorruptCheckpoint { detail: e.to_string() };
        Ok(match state {
            DynStreamState::F32(st) => DynStream::F32(StreamingSession::from_state(st).map_err(wrap)?),
            DynStreamState::F64(st) => DynStream::F64(StreamingSession::from_state(st).map_err(wrap)?),
        })
    }

    /// Feed a batch whose precision must match the stream's. A mismatch
    /// is the typed [`DpcError::DtypeMismatch`] — never a silent cast,
    /// which would break the byte-identity contract.
    pub fn ingest(&mut self, batch: &DynPoints) -> Result<(), DpcError> {
        match (self, batch) {
            (DynStream::F32(s), DynPoints::F32(b)) => s.ingest(b),
            (DynStream::F64(s), DynPoints::F64(b)) => s.ingest(b),
            (s, b) => Err(DpcError::DtypeMismatch {
                expected: s.dtype().name(),
                got: b.dtype().name(),
            }),
        }
    }
}

/// What happened during a [`recover`] pass, for logs and tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecoveryReport {
    /// Sequence of the checkpoint restored from (0 = none, full replay).
    pub checkpoint_seq: u64,
    /// Journal entries replayed after the checkpoint position.
    pub replayed: usize,
    /// Replayed entries that failed to apply and were dropped.
    pub skipped: usize,
    /// Bytes of torn tail truncated from the final segment before
    /// appending resumes.
    pub torn_bytes: u64,
    /// Journal segments scanned (from the replay horizon to the end).
    pub segments: usize,
}

/// The full recovered serve state plus the re-armed journal writer.
#[derive(Debug)]
pub struct Recovered {
    /// `(id, stream)` for every stream open at the crash.
    pub streams: Vec<(u64, DynStream)>,
    /// Every one-shot session open at the crash, artifacts rebuilt.
    pub sessions: Vec<SessionState>,
    /// Floor for the coordinator's shared session/stream id allocator.
    pub next_session_id: u64,
    /// Journal writer positioned at the end of the last segment's valid
    /// prefix.
    pub writer: JournalWriter,
    pub report: RecoveryReport,
}

fn rebuild_session(
    id: u64,
    d_cut: f64,
    density: crate::dpc::DensityModel,
    pts: &DynPoints,
) -> Result<SessionState, DpcError> {
    // Serve-mode sessions are f64 (the coordinator's public surface);
    // artifacts are the rho_min = 0 full forest, every threshold a mask.
    let pts = pts.clone().into_f64();
    let params = DpcParams { d_cut, rho_min: 0.0, delta_min: f64::INFINITY, density, ..DpcParams::default() };
    let out = Dpc::new(params).run(&pts)?;
    Ok(SessionState {
        id,
        d_cut,
        density,
        pts,
        rho: out.rho,
        dep: out.dep,
        delta: out.delta,
        built_by: "replay".into(),
        density_secs: out.timings.density_s,
        dep_secs: out.timings.dep_s,
    })
}

/// Recover (or freshly initialize) a durable directory.
///
/// - Empty/missing directory: create it, write a header-only first
///   segment and a no-checkpoint manifest, return empty state.
/// - Otherwise: validate manifest → checkpoint → segment chain from the
///   manifest's replay horizon, truncate any torn tail in the final
///   segment, replay the suffix, and hand back a writer that appends
///   where the valid prefix ends (rotating at `rotate_bytes`; 0 = never).
pub fn recover(dir: &Path, fsync_every: u64, rotate_bytes: u64) -> Result<Recovered, DpcError> {
    std::fs::create_dir_all(dir)?;

    let Some(m) = manifest::read(dir)? else {
        if !journal::list_segments(dir)?.is_empty() {
            return Err(DpcError::CorruptManifest {
                detail: "journal segments exist but MANIFEST is missing (did a partial copy drop it?)"
                    .into(),
            });
        }
        let writer = JournalWriter::create(dir, fsync_every, rotate_bytes)?;
        manifest::write(
            dir,
            &Manifest {
                checkpoint_seq: 0,
                journal_seq: 1,
                journal_offset: JOURNAL_HEADER_LEN,
                next_lsn: 1,
                next_session_id: 1,
            },
        )?;
        return Ok(Recovered {
            streams: Vec::new(),
            sessions: Vec::new(),
            next_session_id: 1,
            writer,
            report: RecoveryReport { segments: 1, ..RecoveryReport::default() },
        });
    };

    if journal::list_segments(dir)?.is_empty() {
        return Err(DpcError::CorruptManifest {
            detail: "MANIFEST points at a journal that does not exist".into(),
        });
    }
    let scan = journal::scan_dir(dir, m.journal_seq)?;
    // The manifest's replay position must be a frame boundary (or the
    // end) inside the segment it names. `scan_dir` guarantees the first
    // scanned segment IS `m.journal_seq`.
    let horizon_valid_len = scan.segments[0].valid_len;
    if m.journal_offset > horizon_valid_len {
        return Err(DpcError::CorruptManifest {
            detail: format!(
                "journal position ({}, {}) is past segment {}'s valid length {}",
                m.journal_seq, m.journal_offset, m.journal_seq, horizon_valid_len
            ),
        });
    }
    let on_boundary = m.journal_offset == horizon_valid_len
        || scan.entries.iter().any(|f| f.seq == m.journal_seq && f.offset == m.journal_offset);
    if !on_boundary {
        return Err(DpcError::CorruptManifest {
            detail: format!(
                "journal position ({}, {}) is not a frame boundary",
                m.journal_seq, m.journal_offset
            ),
        });
    }
    let replay_from = scan.entries.partition_point(|f| {
        f.seq < m.journal_seq || (f.seq == m.journal_seq && f.offset < m.journal_offset)
    });
    let expected_lsn = scan.entries.get(replay_from).map_or(scan.next_lsn, |f| f.lsn);
    if m.next_lsn != expected_lsn {
        return Err(DpcError::CorruptManifest {
            detail: format!(
                "manifest next_lsn {} disagrees with journal LSN {} at position ({}, {})",
                m.next_lsn, expected_lsn, m.journal_seq, m.journal_offset
            ),
        });
    }

    // Checkpoint (if any) seeds the state maps.
    let data = if m.checkpoint_seq == 0 {
        CheckpointData::default()
    } else {
        checkpoint::read(dir, m.checkpoint_seq)?
    };
    let mut streams: Vec<(u64, DynStream)> = Vec::with_capacity(data.streams.len());
    for (id, st) in data.streams {
        streams.push((id, DynStream::from_state(st)?));
    }
    let mut sessions = data.sessions;

    // Replay the suffix through the normal paths.
    let mut report = RecoveryReport {
        checkpoint_seq: m.checkpoint_seq,
        torn_bytes: scan.torn_bytes,
        segments: scan.segments.len(),
        ..RecoveryReport::default()
    };
    let mut max_id_seen = 0u64;
    for frame in &scan.entries[replay_from..] {
        report.replayed += 1;
        let applied = match &frame.entry {
            JournalEntry::OpenStream { stream, dim, dtype, d_cut, density } => {
                max_id_seen = max_id_seen.max(*stream);
                if streams.iter().any(|(id, _)| id == stream) {
                    false
                } else {
                    match DynStream::new_with_model(*dtype, *dim as usize, *d_cut, *density) {
                        Ok(s) => {
                            streams.push((*stream, s));
                            true
                        }
                        Err(_) => false,
                    }
                }
            }
            JournalEntry::Ingest { stream, batch, .. } => {
                match streams.iter_mut().find(|(id, _)| id == stream) {
                    Some((_, s)) => s.ingest(batch).is_ok(),
                    None => false,
                }
            }
            JournalEntry::CloseStream { stream } => {
                let before = streams.len();
                streams.retain(|(id, _)| id != stream);
                streams.len() != before
            }
            JournalEntry::OpenSession { session, d_cut, density, pts } => {
                max_id_seen = max_id_seen.max(*session);
                if sessions.iter().any(|s| s.id == *session) {
                    false
                } else {
                    match rebuild_session(*session, *d_cut, *density, pts) {
                        Ok(s) => {
                            sessions.push(s);
                            true
                        }
                        Err(_) => false,
                    }
                }
            }
            // Recuts read cached artifacts; replay has nothing to apply.
            JournalEntry::Recut { .. } => true,
            JournalEntry::CloseSession { session } => {
                let before = sessions.len();
                sessions.retain(|s| s.id != *session);
                sessions.len() != before
            }
        };
        if !applied {
            report.skipped += 1;
        }
    }

    let writer = JournalWriter::open_end(
        dir,
        scan.last_seq(),
        scan.valid_len(),
        scan.next_lsn,
        fsync_every,
        rotate_bytes,
    )?;
    Ok(Recovered {
        streams,
        sessions,
        next_session_id: m.next_session_id.max(max_id_seen + 1),
        writer,
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::DensityModel;
    use crate::geom::PointSet;
    use crate::prng::SplitMix64;
    use crate::proputil::gen_clustered_points;
    use std::path::PathBuf;

    use super::super::journal::segment_file;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("parcluster-recover-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn batches(seed: u64, n: usize, splits: &[usize]) -> Vec<PointSet> {
        let mut rng = SplitMix64::new(seed);
        let pts = gen_clustered_points(&mut rng, n, 2, 3, 50.0, 1.8);
        let mut out = Vec::new();
        let mut at = 0;
        for &len in splits {
            out.push(PointSet::new(
                pts.coords()[at * 2..(at + len) * 2].to_vec(),
                2,
            ));
            at += len;
        }
        assert_eq!(at, n);
        out
    }

    #[test]
    fn fresh_directory_initializes_empty() {
        let dir = tmpdir("fresh");
        let rec = recover(&dir, 1, 0).unwrap();
        assert!(rec.streams.is_empty() && rec.sessions.is_empty());
        assert_eq!(rec.next_session_id, 1);
        assert_eq!(rec.report.replayed, 0);
        assert!(dir.join(segment_file(1)).exists());
        assert!(manifest::read(&dir).unwrap().is_some());
        // Recovering again over the initialized-but-idle dir is a no-op.
        drop(rec);
        let rec2 = recover(&dir, 1, 0).unwrap();
        assert!(rec2.streams.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn journal_only_replay_matches_fresh_build() {
        let dir = tmpdir("replay");
        let all = batches(7, 150, &[60, 25, 65]);
        {
            let mut rec = recover(&dir, 1, 0).unwrap();
            rec.writer
                .append(&JournalEntry::OpenStream {
                    stream: 1,
                    dim: 2,
                    dtype: Dtype::F64,
                    d_cut: 3.0,
                    density: DensityModel::Epanechnikov,
                })
                .unwrap();
            for b in &all {
                rec.writer
                    .append(&JournalEntry::Ingest {
                        stream: 1,
                        rho_min: 0.0,
                        delta_min: 20.0,
                        batch: DynPoints::F64(b.clone()),
                    })
                    .unwrap();
            }
            // Simulated crash: writer dropped without checkpoint/close.
        }
        let rec = recover(&dir, 1, 0).unwrap();
        assert_eq!(rec.report.replayed, 4);
        assert_eq!(rec.report.skipped, 0);
        assert_eq!(rec.streams.len(), 1);
        let DynStream::F64(got) = &rec.streams[0].1 else { panic!("f64 stream") };

        let mut fresh =
            StreamingSession::<f64>::new_with_model(2, 3.0, DensityModel::Epanechnikov).unwrap();
        for b in &all {
            fresh.ingest(b).unwrap();
        }
        assert_eq!(got.rho(), fresh.rho());
        assert_eq!(got.dep(), fresh.dep());
        assert_eq!(got.delta(), fresh.delta());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rotated_journal_replay_matches_fresh_build() {
        let dir = tmpdir("rotated");
        let all = batches(13, 150, &[30, 30, 30, 30, 30]);
        {
            // ~1 KiB segments: the five ingests span several segments.
            let mut rec = recover(&dir, 1, 1024).unwrap();
            rec.writer
                .append(&JournalEntry::OpenStream {
                    stream: 1,
                    dim: 2,
                    dtype: Dtype::F64,
                    d_cut: 3.0,
                    density: DensityModel::CutoffCount,
                })
                .unwrap();
            for b in &all {
                rec.writer
                    .append(&JournalEntry::Ingest {
                        stream: 1,
                        rho_min: 0.0,
                        delta_min: 0.0,
                        batch: DynPoints::F64(b.clone()),
                    })
                    .unwrap();
            }
            assert!(rec.writer.seq() > 1, "rotation must have happened");
        }
        let rec = recover(&dir, 1, 1024).unwrap();
        assert!(rec.report.segments > 1);
        assert_eq!(rec.report.skipped, 0);
        let DynStream::F64(got) = &rec.streams[0].1 else { panic!("f64 stream") };
        let mut fresh =
            StreamingSession::<f64>::new_with_model(2, 3.0, DensityModel::CutoffCount).unwrap();
        for b in &all {
            fresh.ingest(b).unwrap();
        }
        assert_eq!(got.rho(), fresh.rho());
        assert_eq!(got.dep(), fresh.dep());
        assert_eq!(got.delta(), fresh.delta());
        assert_eq!(got.level_sizes(), fresh.level_sizes());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn replay_skips_failed_and_out_of_order_entries() {
        let dir = tmpdir("skips");
        {
            let mut rec = recover(&dir, 1, 0).unwrap();
            // Ingest into a stream that was never opened.
            rec.writer
                .append(&JournalEntry::Ingest {
                    stream: 9,
                    rho_min: 0.0,
                    delta_min: 0.0,
                    batch: DynPoints::F64(PointSet::new(vec![1.0, 2.0], 2)),
                })
                .unwrap();
            // Close a stream that does not exist.
            rec.writer.append(&JournalEntry::CloseStream { stream: 9 }).unwrap();
            // A working open + wrong-dimension ingest (fails inside the
            // session, must be skipped, stream survives).
            rec.writer
                .append(&JournalEntry::OpenStream {
                    stream: 1,
                    dim: 2,
                    dtype: Dtype::F64,
                    d_cut: 1.0,
                    density: DensityModel::CutoffCount,
                })
                .unwrap();
            rec.writer
                .append(&JournalEntry::Ingest {
                    stream: 1,
                    rho_min: 0.0,
                    delta_min: 0.0,
                    batch: DynPoints::F64(PointSet::new(vec![1.0, 2.0, 3.0], 3)),
                })
                .unwrap();
        }
        let rec = recover(&dir, 1, 0).unwrap();
        assert_eq!(rec.report.replayed, 4);
        assert_eq!(rec.report.skipped, 3);
        assert_eq!(rec.streams.len(), 1);
        assert!(rec.streams[0].1.is_empty(), "failed ingest leaves no points");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dtype_mismatched_ingest_is_typed_and_skipped_on_replay() {
        // Direct: the runtime union refuses a cross-precision batch with
        // the typed error (not a log line, not a cast).
        let mut s = DynStream::new_with_model(Dtype::F32, 2, 1.0, DensityModel::CutoffCount).unwrap();
        let err = s.ingest(&DynPoints::F64(PointSet::new(vec![1.0, 2.0], 2))).unwrap_err();
        assert!(
            matches!(err, DpcError::DtypeMismatch { expected: "f32", got: "f64" }),
            "got {err:?}"
        );
        // And an f32 batch into the f32 stream works.
        s.ingest(&DynPoints::F32(crate::geom::PointStore::<f32>::new(vec![1.0, 2.0], 2))).unwrap();
        assert_eq!(s.len(), 1);

        // Replay: a journaled mismatched ingest is skipped, stream survives.
        let dir = tmpdir("dtypemix");
        {
            let mut rec = recover(&dir, 1, 0).unwrap();
            rec.writer
                .append(&JournalEntry::OpenStream {
                    stream: 1,
                    dim: 2,
                    dtype: Dtype::F32,
                    d_cut: 1.0,
                    density: DensityModel::CutoffCount,
                })
                .unwrap();
            rec.writer
                .append(&JournalEntry::Ingest {
                    stream: 1,
                    rho_min: 0.0,
                    delta_min: 0.0,
                    batch: DynPoints::F64(PointSet::new(vec![1.0, 2.0], 2)),
                })
                .unwrap();
        }
        let rec = recover(&dir, 1, 0).unwrap();
        assert_eq!(rec.report.skipped, 1);
        assert_eq!(rec.streams[0].1.dtype(), Dtype::F32);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn session_replay_rebuilds_artifacts() {
        let dir = tmpdir("session");
        let pts = batches(11, 80, &[80]).pop().unwrap();
        {
            let mut rec = recover(&dir, 1, 0).unwrap();
            rec.writer
                .append(&JournalEntry::OpenSession {
                    session: 3,
                    d_cut: 3.0,
                    density: DensityModel::GaussianKernel,
                    pts: DynPoints::F64(pts.clone()),
                })
                .unwrap();
            rec.writer
                .append(&JournalEntry::Recut { session: 3, rho_min: 1.0, delta_min: 5.0 })
                .unwrap();
        }
        let rec = recover(&dir, 1, 0).unwrap();
        assert_eq!(rec.sessions.len(), 1);
        assert_eq!(rec.next_session_id, 4);
        let s = &rec.sessions[0];
        let want = Dpc::new(DpcParams {
            d_cut: 3.0,
            rho_min: 0.0,
            delta_min: f64::INFINITY,
            density: DensityModel::GaussianKernel,
            ..DpcParams::default()
        })
        .run(&pts)
        .unwrap();
        assert_eq!(s.rho, want.rho);
        assert_eq!(s.dep, want.dep);
        assert_eq!(s.delta, want.delta);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_journal_disagreements_are_corrupt_manifest() {
        // Manifest missing but journal present.
        let dir = tmpdir("nomanifest");
        {
            let _ = recover(&dir, 1, 0).unwrap();
        }
        std::fs::remove_file(dir.join(manifest::MANIFEST_FILE)).unwrap();
        assert!(matches!(recover(&dir, 1, 0), Err(DpcError::CorruptManifest { .. })));
        std::fs::remove_dir_all(&dir).unwrap();

        // Manifest pointing past the journal's end.
        let dir = tmpdir("staleoffset");
        {
            let _ = recover(&dir, 1, 0).unwrap();
        }
        manifest::write(
            &dir,
            &Manifest {
                checkpoint_seq: 0,
                journal_seq: 1,
                journal_offset: 4096,
                next_lsn: 1,
                next_session_id: 1,
            },
        )
        .unwrap();
        assert!(matches!(recover(&dir, 1, 0), Err(DpcError::CorruptManifest { .. })));
        std::fs::remove_dir_all(&dir).unwrap();

        // Manifest pointing at a missing journal.
        let dir = tmpdir("nojournal");
        {
            let _ = recover(&dir, 1, 0).unwrap();
        }
        std::fs::remove_file(dir.join(segment_file(1))).unwrap();
        assert!(matches!(recover(&dir, 1, 0), Err(DpcError::CorruptManifest { .. })));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_restart_replays_only_the_suffix() {
        let dir = tmpdir("ckptsuffix");
        let all = batches(23, 120, &[50, 40, 30]);
        {
            let mut rec = recover(&dir, 1, 0).unwrap();
            rec.writer
                .append(&JournalEntry::OpenStream {
                    stream: 1,
                    dim: 2,
                    dtype: Dtype::F64,
                    d_cut: 3.0,
                    density: DensityModel::CutoffCount,
                })
                .unwrap();
            let mut live =
                StreamingSession::<f64>::new_with_model(2, 3.0, DensityModel::CutoffCount).unwrap();
            for b in &all[..2] {
                rec.writer
                    .append(&JournalEntry::Ingest {
                        stream: 1,
                        rho_min: 0.0,
                        delta_min: 0.0,
                        batch: DynPoints::F64(b.clone()),
                    })
                    .unwrap();
                live.ingest(b).unwrap();
            }
            // Checkpoint covering the first two batches...
            let data = CheckpointData {
                streams: vec![(1, DynStreamState::F64(live.export_state()))],
                sessions: Vec::new(),
            };
            checkpoint::write(&dir, &mut rec.writer, &data, 2, 1).unwrap();
            // ...then one post-checkpoint batch before the "crash".
            rec.writer
                .append(&JournalEntry::Ingest {
                    stream: 1,
                    rho_min: 0.0,
                    delta_min: 0.0,
                    batch: DynPoints::F64(all[2].clone()),
                })
                .unwrap();
        }
        let rec = recover(&dir, 1, 0).unwrap();
        assert_eq!(rec.report.checkpoint_seq, 1);
        assert_eq!(rec.report.replayed, 1, "only the post-checkpoint ingest replays");
        let DynStream::F64(got) = &rec.streams[0].1 else { panic!("f64 stream") };

        let mut fresh =
            StreamingSession::<f64>::new_with_model(2, 3.0, DensityModel::CutoffCount).unwrap();
        for b in &all {
            fresh.ingest(b).unwrap();
        }
        assert_eq!(got.rho(), fresh.rho());
        assert_eq!(got.dep(), fresh.dep());
        assert_eq!(got.delta(), fresh.delta());
        assert_eq!(got.level_sizes(), fresh.level_sizes());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_past_rotation_replays_across_the_horizon() {
        // Checkpoint lands mid-chain; pre-horizon segments are GC'd; the
        // suffix replays from the recorded (seq, offset).
        let dir = tmpdir("ckptrotate");
        let all = batches(29, 120, &[40, 40, 40]);
        {
            let mut rec = recover(&dir, 1, 512).unwrap();
            rec.writer
                .append(&JournalEntry::OpenStream {
                    stream: 1,
                    dim: 2,
                    dtype: Dtype::F64,
                    d_cut: 3.0,
                    density: DensityModel::CutoffCount,
                })
                .unwrap();
            let mut live =
                StreamingSession::<f64>::new_with_model(2, 3.0, DensityModel::CutoffCount).unwrap();
            for b in &all[..2] {
                rec.writer
                    .append(&JournalEntry::Ingest {
                        stream: 1,
                        rho_min: 0.0,
                        delta_min: 0.0,
                        batch: DynPoints::F64(b.clone()),
                    })
                    .unwrap();
                live.ingest(b).unwrap();
            }
            let data = CheckpointData {
                streams: vec![(1, DynStreamState::F64(live.export_state()))],
                sessions: Vec::new(),
            };
            let m = checkpoint::write(&dir, &mut rec.writer, &data, 2, 1).unwrap();
            assert!(m.journal_seq > 1, "rotation must have moved the horizon");
            // Pre-horizon segments were swept by the checkpoint's GC.
            assert!(!dir.join(segment_file(1)).exists());
            rec.writer
                .append(&JournalEntry::Ingest {
                    stream: 1,
                    rho_min: 0.0,
                    delta_min: 0.0,
                    batch: DynPoints::F64(all[2].clone()),
                })
                .unwrap();
        }
        let rec = recover(&dir, 1, 512).unwrap();
        assert_eq!(rec.report.checkpoint_seq, 1);
        let DynStream::F64(got) = &rec.streams[0].1 else { panic!("f64 stream") };
        let mut fresh =
            StreamingSession::<f64>::new_with_model(2, 3.0, DensityModel::CutoffCount).unwrap();
        for b in &all {
            fresh.ingest(b).unwrap();
        }
        assert_eq!(got.rho(), fresh.rho());
        assert_eq!(got.delta(), fresh.delta());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
