//! Surrogate generators for the paper's six real-world datasets (Table 2).
//!
//! The originals (GeoLife GPS traces, PAMAP2 activity monitoring, the gas
//! Sensor array, HT humidity/temperature, UCI Query workloads, Gowalla
//! check-ins) are not available in this offline environment. Each surrogate
//! reproduces the *qualitative density structure* that drives DPC's relative
//! performance on that dataset — dimension, spatial skew, duplicate rate,
//! and cluster granularity — at the paper's coordinate scale, so the
//! Table-2 hyper-parameters (`d_cut`, ρ_min, δ_min) remain meaningful.
//! DESIGN.md §5 documents the substitution rationale per dataset.

use crate::geom::PointSet;
use crate::prng::SplitMix64;

/// GeoLife-like (d=3): GPS trajectories — many long random-walk tracks with
/// tight waypoint spacing (extreme density along paths), a few wide-ranging
/// excursions. Coordinates scaled so `d_cut = 1` captures track neighbors.
pub fn geolife_like(n: usize, seed: u64) -> PointSet {
    let mut rng = SplitMix64::new(seed ^ 0x6E01);
    let mut coords = Vec::with_capacity(n * 3);
    let n_tracks = (n / 2000).max(5);
    let per = n / n_tracks;
    let mut emitted = 0;
    for t in 0..n_tracks {
        let count = if t == n_tracks - 1 { n - emitted } else { per };
        // Tracks concentrate around a few "cities".
        let city = rng.next_below(4) as f64;
        let mut pos = [
            city * 300.0 + rng.uniform(0.0, 60.0),
            rng.uniform(0.0, 60.0),
            rng.uniform(0.0, 10.0), // altitude-ish, tight
        ];
        for _ in 0..count {
            pos[0] += rng.uniform(-0.4, 0.4);
            pos[1] += rng.uniform(-0.4, 0.4);
            pos[2] += rng.uniform(-0.05, 0.05);
            coords.extend_from_slice(&pos);
        }
        emitted += count;
    }
    PointSet::new(coords, 3)
}

/// PAMAP2-like (d=4): wearable-sensor channels — an AR(1) process that
/// switches between a handful of activity regimes (tight clusters in
/// normalized sensor space, unit scale ~0..1, `d_cut = 0.02`).
pub fn pamap2_like(n: usize, seed: u64) -> PointSet {
    let mut rng = SplitMix64::new(seed ^ 0x9A3A);
    let d = 4;
    let n_regimes = 8usize;
    let regimes: Vec<f64> = (0..n_regimes * d).map(|_| rng.uniform(0.1, 0.9)).collect();
    let mut coords = Vec::with_capacity(n * d);
    let mut regime = 0usize;
    let mut state = [0.5f64; 4];
    for _ in 0..n {
        if rng.next_f64() < 0.001 {
            regime = rng.next_below(n_regimes as u64) as usize;
        }
        for k in 0..d {
            let target = regimes[regime * d + k];
            state[k] = 0.98 * state[k] + 0.02 * target + 0.004 * rng.normal();
            coords.push(state[k]);
        }
    }
    PointSet::new(coords, d)
}

/// Sensor-like (d=5): gas-sensor array under temperature modulation —
/// a small number of broad operating-mode clusters with within-mode drift
/// (scale ~0..10, `d_cut = 0.2`).
pub fn sensor_like(n: usize, seed: u64) -> PointSet {
    let mut rng = SplitMix64::new(seed ^ 0x5E50);
    let d = 5;
    let n_modes = 6usize;
    let modes: Vec<f64> = (0..n_modes * d).map(|_| rng.uniform(1.0, 9.0)).collect();
    let mut coords = Vec::with_capacity(n * d);
    for _ in 0..n {
        let m = rng.next_below(n_modes as u64) as usize;
        // Drift phase: stretches clusters into filaments.
        let phase = rng.next_f64();
        for k in 0..d {
            let drift = 0.8 * phase * if k % 2 == 0 { 1.0 } else { -1.0 };
            coords.push(modes[m * d + k] + drift + 0.08 * rng.normal());
        }
    }
    PointSet::new(coords, d)
}

/// HT-like (d=8): home humidity/temperature telemetry — slow AR(1) drift
/// with a daily periodic component across correlated channels (scale ~0..20,
/// `d_cut = 0.5`). High dimension with strong channel correlation.
pub fn ht_like(n: usize, seed: u64) -> PointSet {
    let mut rng = SplitMix64::new(seed ^ 0x6877);
    let d = 8;
    let mut coords = Vec::with_capacity(n * d);
    let mut base = 10.0f64;
    for t in 0..n {
        base = 0.999 * base + 0.001 * 10.0 + 0.02 * rng.normal();
        let daily = (t as f64 * std::f64::consts::TAU / 1440.0).sin();
        for k in 0..d {
            let chan_gain = 1.0 + 0.1 * k as f64;
            coords.push(base * chan_gain * 0.1 + daily * (0.5 + 0.05 * k as f64) + 0.06 * rng.normal() + 8.0);
        }
    }
    PointSet::new(coords, d)
}

/// Query-like (d=3): UCI query-analytics workloads — quantized query
/// parameters on a coarse lattice (unit scale, `d_cut = 0.01`), i.e. many
/// near-duplicates, mirroring the de-duplicated original.
pub fn query_like(n: usize, seed: u64) -> PointSet {
    let mut rng = SplitMix64::new(seed ^ 0x4E3A);
    let d = 3;
    // 150 "popular" query templates on a coarse lattice.
    let n_sites = 150usize;
    let sites: Vec<f64> = (0..n_sites * d)
        .map(|k| {
            let buckets = if k % d == 2 { 10 } else { 40 };
            rng.next_below(buckets) as f64 / buckets as f64
        })
        .collect();
    let mut coords = Vec::with_capacity(n * d);
    for _ in 0..n {
        // Mixture: 70% jittered repeats of popular templates, 30% uniform.
        if rng.next_f64() < 0.7 {
            let s = rng.next_below(n_sites as u64) as usize;
            for k in 0..d {
                coords.push(sites[s * d + k] + 0.003 * rng.normal());
            }
        } else {
            for _ in 0..d {
                coords.push(rng.next_f64());
            }
        }
    }
    PointSet::new(coords, d)
}

/// Gowalla-like (d=2): location check-ins — heavy-tailed city-size
/// distribution (Zipfian weights), dense urban cores with sprawling tails
/// (degree scale ~0..360 like lon/lat, `d_cut = 0.03`).
pub fn gowalla_like(n: usize, seed: u64) -> PointSet {
    let mut rng = SplitMix64::new(seed ^ 0x60AA);
    let n_cities = 300usize;
    // Zipf weights.
    let weights: Vec<f64> = (1..=n_cities).map(|r| 1.0 / r as f64).collect();
    let total: f64 = weights.iter().sum();
    let centers: Vec<(f64, f64)> = (0..n_cities).map(|_| (rng.uniform(0.0, 360.0), rng.uniform(-90.0, 90.0))).collect();
    let mut coords = Vec::with_capacity(n * 2);
    for _ in 0..n {
        // Sample a city by weight.
        let mut u = rng.next_f64() * total;
        let mut c = 0;
        while c + 1 < n_cities && u > weights[c] {
            u -= weights[c];
            c += 1;
        }
        let spread = 0.02 + 0.3 * rng.next_f64() * rng.next_f64(); // core + sprawl
        coords.push(centers[c].0 + spread * rng.normal());
        coords.push(centers[c].1 + spread * rng.normal() * 0.5);
    }
    PointSet::new(coords, 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::{compute_density, DensityAlgo};

    #[test]
    fn dimensions_match_table2() {
        assert_eq!(geolife_like(500, 1).dim(), 3);
        assert_eq!(pamap2_like(500, 1).dim(), 4);
        assert_eq!(sensor_like(500, 1).dim(), 5);
        assert_eq!(ht_like(500, 1).dim(), 8);
        assert_eq!(query_like(500, 1).dim(), 3);
        assert_eq!(gowalla_like(500, 1).dim(), 2);
    }

    #[test]
    fn densities_nonzero_but_much_less_than_n() {
        // §7.1's d_cut selection rule must hold on the surrogates at the
        // Table-2 d_cut values.
        let cases: Vec<(PointSet, f64)> = vec![
            (geolife_like(20_000, 2), 1.0),
            (pamap2_like(20_000, 2), 0.02),
            (sensor_like(20_000, 2), 0.2),
            (ht_like(20_000, 2), 0.5),
            (query_like(20_000, 2), 0.01),
            (gowalla_like(20_000, 2), 0.03),
        ];
        for (i, (pts, d_cut)) in cases.iter().enumerate() {
            let rho = compute_density(pts, *d_cut, DensityAlgo::TreePruned);
            let mean: f64 = rho.iter().map(|&r| r as f64).sum::<f64>() / pts.len() as f64;
            assert!(mean > 1.05, "case {i}: mean density {mean} too low");
            assert!(mean < pts.len() as f64 * 0.25, "case {i}: mean density {mean} too high");
        }
    }

    #[test]
    fn gowalla_is_heavy_tailed() {
        let pts = gowalla_like(20_000, 3);
        let rho = compute_density(&pts, 0.03, DensityAlgo::TreePruned);
        let mut sorted: Vec<u32> = rho.clone();
        sorted.sort_unstable();
        let p50 = sorted[sorted.len() / 2] as f64;
        let p99 = sorted[sorted.len() * 99 / 100] as f64;
        assert!(p99 > 5.0 * p50.max(1.0), "p99={p99} p50={p50}");
    }

    #[test]
    fn query_has_many_near_duplicates() {
        let pts = query_like(10_000, 4);
        let rho = compute_density(&pts, 0.01, DensityAlgo::TreePruned);
        let dense = rho.iter().filter(|&&r| r > 10).count();
        assert!(dense > 1000, "lattice clumps expected, got {dense}");
    }
}
