//! Point-set IO: a simple little-endian binary format (`PCLB`) and CSV.
//!
//! Binary layout: magic `PCLB`, u32 version, u64 n, u32 d, then n·d f64
//! little-endian coordinates. Used to cache generated datasets between
//! bench runs and to hand points to external tools.
//!
//! Reads return [`DpcError`]: underlying filesystem failures as
//! `DpcError::Io`, malformed content (bad magic, ragged rows, non-finite
//! coordinates) as the matching typed variant — nothing in this module
//! panics on user files.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::DpcError;
use crate::geom::PointSet;

const MAGIC: &[u8; 4] = b"PCLB";
const VERSION: u32 = 1;

fn bad_data(msg: String) -> DpcError {
    DpcError::Io(std::io::Error::new(std::io::ErrorKind::InvalidData, msg))
}

/// Write a point set in the binary format.
pub fn write_binary(pts: &PointSet, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(pts.len() as u64).to_le_bytes())?;
    w.write_all(&(pts.dim() as u32).to_le_bytes())?;
    for &c in pts.coords() {
        w.write_all(&c.to_le_bytes())?;
    }
    Ok(())
}

/// Read a point set in the binary format.
pub fn read_binary(path: &Path) -> Result<PointSet, DpcError> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad_data("bad magic".into()));
    }
    let mut u4 = [0u8; 4];
    r.read_exact(&mut u4)?;
    let version = u32::from_le_bytes(u4);
    if version != VERSION {
        return Err(bad_data(format!("unsupported version {version}")));
    }
    let mut u8b = [0u8; 8];
    r.read_exact(&mut u8b)?;
    let n = u64::from_le_bytes(u8b) as usize;
    r.read_exact(&mut u4)?;
    let d = u32::from_le_bytes(u4) as usize;
    if d == 0 || n.checked_mul(d).is_none() {
        return Err(bad_data("bad header".into()));
    }
    let mut coords = Vec::with_capacity(n * d);
    for _ in 0..n * d {
        r.read_exact(&mut u8b)?;
        coords.push(f64::from_le_bytes(u8b));
    }
    let pts = PointSet::try_new(coords, d)?;
    pts.validate_finite()?;
    Ok(pts)
}

/// Write CSV (no header, one point per row).
pub fn write_csv(pts: &PointSet, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for i in 0..pts.len() {
        let row: Vec<String> = pts.point(i).iter().map(|c| format!("{c}")).collect();
        writeln!(w, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read CSV of floats (`#`-prefixed lines and a non-numeric first row are
/// skipped as headers/comments). Ragged rows surface as
/// [`DpcError::DimensionMismatch`], NaN/∞ as [`DpcError::NonFinite`].
pub fn read_csv(path: &Path) -> Result<PointSet, DpcError> {
    let r = BufReader::new(File::open(path)?);
    let mut coords: Vec<f64> = Vec::new();
    let mut d: Option<usize> = None;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let vals: Result<Vec<f64>, _> = t.split(',').map(|s| s.trim().parse::<f64>()).collect();
        let vals = match vals {
            Ok(v) => v,
            Err(_) if lineno == 0 => continue, // header row
            Err(e) => return Err(bad_data(format!("line {}: {e}", lineno + 1))),
        };
        match d {
            None => d = Some(vals.len()),
            Some(dd) if dd != vals.len() => {
                return Err(DpcError::DimensionMismatch { expected: dd, got: vals.len() })
            }
            _ => {}
        }
        coords.extend(vals);
    }
    let d = d.ok_or(DpcError::EmptyInput)?;
    let pts = PointSet::try_new(coords, d)?;
    pts.validate_finite()?;
    Ok(pts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::gen_uniform_points;
    use crate::prng::SplitMix64;

    fn tmpdir() -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("parcluster-io-{}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    #[test]
    fn binary_roundtrip() {
        let mut rng = SplitMix64::new(1);
        let pts = gen_uniform_points(&mut rng, 500, 3, 10.0);
        let path = tmpdir().join("rt.pclb");
        write_binary(&pts, &path).unwrap();
        let back = read_binary(&path).unwrap();
        assert_eq!(back.coords(), pts.coords());
        assert_eq!(back.dim(), 3);
    }

    #[test]
    fn binary_rejects_garbage() {
        let path = tmpdir().join("garbage.pclb");
        std::fs::write(&path, b"NOTAPOINTSET").unwrap();
        assert!(read_binary(&path).is_err());
    }

    #[test]
    fn binary_rejects_nonfinite_coords() {
        let path = tmpdir().join("nan.pclb");
        let pts = PointSet::new(vec![1.0, 2.0, f64::NAN, 4.0], 2);
        write_binary(&pts, &path).unwrap();
        assert!(matches!(read_binary(&path), Err(DpcError::NonFinite { point: 1, dim: 0 })));
    }

    #[test]
    fn csv_roundtrip() {
        let mut rng = SplitMix64::new(2);
        let pts = gen_uniform_points(&mut rng, 100, 2, 5.0);
        let path = tmpdir().join("rt.csv");
        write_csv(&pts, &path).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back.len(), 100);
        for i in 0..100 {
            for k in 0..2 {
                assert!((back.coord(i, k) - pts.coord(i, k)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn csv_skips_header_and_comments() {
        let path = tmpdir().join("hdr.csv");
        std::fs::write(&path, "x,y\n# comment\n1.0,2.0\n3.0,4.0\n").unwrap();
        let pts = read_csv(&path).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts.point(1), &[3.0, 4.0]);
    }

    #[test]
    fn csv_rejects_ragged() {
        let path = tmpdir().join("ragged.csv");
        std::fs::write(&path, "1.0,2.0\n3.0\n").unwrap();
        assert!(matches!(read_csv(&path), Err(DpcError::DimensionMismatch { expected: 2, got: 1 })));
    }

    #[test]
    fn csv_rejects_nonfinite_and_empty() {
        let path = tmpdir().join("nan.csv");
        std::fs::write(&path, "1.0,2.0\nNaN,4.0\n").unwrap();
        assert!(matches!(read_csv(&path), Err(DpcError::NonFinite { point: 1, dim: 0 })));
        let path = tmpdir().join("empty.csv");
        std::fs::write(&path, "# nothing here\n").unwrap();
        assert!(matches!(read_csv(&path), Err(DpcError::EmptyInput)));
    }
}
