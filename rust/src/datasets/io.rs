//! Point-set IO: a simple little-endian binary format (`PCLB`) and CSV.
//!
//! Binary layout, **version 2** (precision-tagged):
//! magic `PCLB`, u32 version = 2, u8 dtype tag (4 = f32, 8 = f64 — the
//! scalar width, self-describing), u64 n, u32 d, then n·d little-endian
//! scalars of the tagged width. **Version 1** files (magic, u32 version =
//! 1, u64 n, u32 d, n·d f64) still round-trip — the reader dispatches on
//! the version field, so every pre-upgrade cache file keeps working.
//!
//! Reads return [`DpcError`] and never a partially-parsed store:
//! filesystem failures as `DpcError::Io`, malformed content (bad magic,
//! unknown dtype tag, truncated payload, ragged rows, non-finite
//! coordinates) as the matching typed variant — nothing in this module
//! panics on user files.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::DpcError;
use crate::geom::{Dtype, DynPoints, PointSet, PointStore, Scalar};

const MAGIC: &[u8; 4] = b"PCLB";
/// Current write version. v1 (untagged f64) remains readable.
const VERSION: u32 = 2;

fn bad_data(msg: String) -> DpcError {
    DpcError::Io(std::io::Error::new(std::io::ErrorKind::InvalidData, msg))
}

/// Write a point store of either precision in the v2 binary format.
/// Streams through the `BufWriter` with one small reused scratch buffer —
/// no payload-sized allocation.
pub fn write_binary_store<S: Scalar>(pts: &PointStore<S>, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&[S::DTYPE.size_bytes() as u8])?;
    w.write_all(&(pts.len() as u64).to_le_bytes())?;
    w.write_all(&(pts.dim() as u32).to_le_bytes())?;
    let mut buf = Vec::with_capacity(S::BYTES);
    for &c in pts.coords() {
        buf.clear();
        c.write_le(&mut buf);
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Write an f64 point set (the pre-generic signature; emits v2 + f64 tag).
pub fn write_binary(pts: &PointSet, path: &Path) -> std::io::Result<()> {
    write_binary_store(pts, path)
}

/// Write a runtime-tagged store, preserving its precision on disk.
pub fn write_binary_dyn(pts: &DynPoints, path: &Path) -> std::io::Result<()> {
    match pts {
        DynPoints::F32(p) => write_binary_store(p, path),
        DynPoints::F64(p) => write_binary_store(p, path),
    }
}

/// Read a binary point file at its stored precision (v1 and v2).
pub fn read_binary_dyn(path: &Path) -> Result<DynPoints, DpcError> {
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad_data("bad magic".into()));
    }
    let mut u4 = [0u8; 4];
    r.read_exact(&mut u4)?;
    let version = u32::from_le_bytes(u4);
    let (dtype, header_len) = match version {
        // v1 predates the dtype tag: payload is always f64.
        1 => (Dtype::F64, 4 + 4 + 8 + 4),
        2 => {
            let mut tag = [0u8; 1];
            r.read_exact(&mut tag)?;
            let dt = Dtype::from_tag(tag[0]).ok_or(DpcError::UnsupportedDtype { tag: tag[0] })?;
            (dt, 4 + 4 + 1 + 8 + 4)
        }
        other => return Err(bad_data(format!("unsupported version {other}"))),
    };
    let mut u8b = [0u8; 8];
    r.read_exact(&mut u8b)?;
    let n = u64::from_le_bytes(u8b) as usize;
    r.read_exact(&mut u4)?;
    let d = u32::from_le_bytes(u4) as usize;
    if d == 0 || n.checked_mul(d).is_none() {
        return Err(bad_data("bad header".into()));
    }
    let avail = file_len.saturating_sub(header_len);
    match dtype {
        Dtype::F32 => Ok(DynPoints::F32(read_payload::<f32, _>(&mut r, n, d, avail)?)),
        Dtype::F64 => Ok(DynPoints::F64(read_payload::<f64, _>(&mut r, n, d, avail)?)),
    }
}

/// Decode `n·d` scalars straight into the store's shared allocation (no
/// intermediate `Vec` and no `Vec → Arc` copy). The header's count is
/// checked against `avail` — the file's actual payload size — *before*
/// allocating, so a crafted 17-byte header cannot request petabytes, and a
/// truncated file surfaces as a typed `DpcError::Io` (UnexpectedEof) before
/// any store is constructed — no partial parses.
fn read_payload<S: Scalar, R: Read>(r: &mut R, n: usize, d: usize, avail: u64) -> Result<PointStore<S>, DpcError> {
    let count = n.checked_mul(d).ok_or_else(|| bad_data("bad header".into()))?;
    let need = (count as u64)
        .checked_mul(S::BYTES as u64)
        .ok_or_else(|| bad_data("bad header".into()))?;
    if avail < need {
        return Err(DpcError::Io(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            format!("payload truncated: header promises {need} bytes, file holds {avail}"),
        )));
    }
    let mut buf = [0u8; 8];
    let pts = PointStore::try_from_flat_fn(n, d, |_| {
        r.read_exact(&mut buf[..S::BYTES])?;
        Ok(S::read_le(&buf))
    })?;
    pts.validate_finite()?;
    Ok(pts)
}

/// Read a binary point file widened to f64 (the pre-generic signature;
/// f32 payloads convert exactly).
pub fn read_binary(path: &Path) -> Result<PointSet, DpcError> {
    Ok(read_binary_dyn(path)?.into_f64())
}

/// Write CSV (no header, one point per row).
pub fn write_csv(pts: &PointSet, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for i in 0..pts.len() {
        let row: Vec<String> = pts.point(i).iter().map(|c| format!("{c}")).collect();
        writeln!(w, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read CSV of floats (`#`-prefixed lines and a non-numeric first row are
/// skipped as headers/comments). Ragged rows surface as
/// [`DpcError::DimensionMismatch`], NaN/∞ as
/// [`DpcError::NonFiniteCoordinate`].
pub fn read_csv(path: &Path) -> Result<PointSet, DpcError> {
    let r = BufReader::new(File::open(path)?);
    let mut coords: Vec<f64> = Vec::new();
    let mut d: Option<usize> = None;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let vals: Result<Vec<f64>, _> = t.split(',').map(|s| s.trim().parse::<f64>()).collect();
        let vals = match vals {
            Ok(v) => v,
            Err(_) if lineno == 0 => continue, // header row
            Err(e) => return Err(bad_data(format!("line {}: {e}", lineno + 1))),
        };
        match d {
            None => d = Some(vals.len()),
            Some(dd) if dd != vals.len() => {
                return Err(DpcError::DimensionMismatch { expected: dd, got: vals.len() })
            }
            _ => {}
        }
        coords.extend(vals);
    }
    let d = d.ok_or(DpcError::EmptyInput)?;
    // try_new scans for non-finite coordinates itself.
    PointSet::try_new(coords, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::{gen_grid_points, gen_uniform_points};
    use crate::prng::SplitMix64;

    fn tmpdir() -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("parcluster-io-{}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    #[test]
    fn binary_roundtrip() {
        let mut rng = SplitMix64::new(1);
        let pts = gen_uniform_points(&mut rng, 500, 3, 10.0);
        let path = tmpdir().join("rt.pclb");
        write_binary(&pts, &path).unwrap();
        let back = read_binary(&path).unwrap();
        assert_eq!(back.coords(), pts.coords());
        assert_eq!(back.dim(), 3);
        // The dyn reader reports the stored precision.
        let dynp = read_binary_dyn(&path).unwrap();
        assert_eq!(dynp.dtype(), Dtype::F64);
    }

    #[test]
    fn f32_binary_roundtrip_preserves_dtype() {
        let mut rng = SplitMix64::new(7);
        let pts64 = gen_grid_points(&mut rng, 200, 2, 64);
        let pts = PointStore::<f32>::try_lossless_from_f64(&pts64).unwrap();
        let path = tmpdir().join("rt32.pclb");
        write_binary_store(&pts, &path).unwrap();
        match read_binary_dyn(&path).unwrap() {
            DynPoints::F32(back) => assert_eq!(back.coords(), pts.coords()),
            other => panic!("expected f32 payload, got {:?}", other.dtype()),
        }
        // The widening reader recovers the identical f64 coordinates
        // (lossless by construction here).
        let widened = read_binary(&path).unwrap();
        assert_eq!(widened.coords(), pts64.coords());
        // And the dyn writer round-trips the tag.
        let path2 = tmpdir().join("rt32b.pclb");
        write_binary_dyn(&DynPoints::F32(pts.clone()), &path2).unwrap();
        assert_eq!(read_binary_dyn(&path2).unwrap().dtype(), Dtype::F32);
    }

    #[test]
    fn v1_files_still_read() {
        let mut rng = SplitMix64::new(3);
        let pts = gen_uniform_points(&mut rng, 40, 2, 5.0);
        // Hand-rolled v1 header: magic, version=1, n, d, f64 payload.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&(pts.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&(pts.dim() as u32).to_le_bytes());
        for &c in pts.coords() {
            bytes.extend_from_slice(&c.to_le_bytes());
        }
        let path = tmpdir().join("v1.pclb");
        std::fs::write(&path, &bytes).unwrap();
        let back = read_binary(&path).unwrap();
        assert_eq!(back.coords(), pts.coords());
        assert_eq!(read_binary_dyn(&path).unwrap().dtype(), Dtype::F64);
    }

    #[test]
    fn binary_rejects_garbage() {
        let path = tmpdir().join("garbage.pclb");
        std::fs::write(&path, b"NOTAPOINTSET").unwrap();
        assert!(read_binary(&path).is_err());
    }

    #[test]
    fn binary_rejects_bad_dtype_tag_and_truncation() {
        // A v2 header with an unknown dtype tag.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.push(3); // not 4 or 8
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        let path = tmpdir().join("badtag.pclb");
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(read_binary(&path), Err(DpcError::UnsupportedDtype { tag: 3 })));

        // A v2 file whose payload is cut short: typed Io error, no partial
        // store.
        let mut rng = SplitMix64::new(4);
        let pts = gen_uniform_points(&mut rng, 10, 2, 5.0);
        let path = tmpdir().join("trunc.pclb");
        write_binary(&pts, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        assert!(matches!(read_binary(&path), Err(DpcError::Io(_))));

        // A file truncated inside the dtype byte itself.
        std::fs::write(&path, &full[..8]).unwrap();
        assert!(matches!(read_binary(&path), Err(DpcError::Io(_))));

        // Future versions are rejected, not misparsed.
        let mut bytes = full.clone();
        bytes[4..8].copy_from_slice(&9u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_binary(&path).is_err());
    }

    #[test]
    fn binary_rejects_nonfinite_coords() {
        let path = tmpdir().join("nan.pclb");
        // Unvalidated generator path: `PointSet::new` rejects the NaN itself.
        let coords = [1.0, 2.0, f64::NAN, 4.0];
        let pts = PointSet::from_flat_fn(2, 2, |i| coords[i]);
        write_binary(&pts, &path).unwrap();
        assert!(matches!(read_binary(&path), Err(DpcError::NonFiniteCoordinate { point: 1, dim: 0 })));
    }

    #[test]
    fn csv_roundtrip() {
        let mut rng = SplitMix64::new(2);
        let pts = gen_uniform_points(&mut rng, 100, 2, 5.0);
        let path = tmpdir().join("rt.csv");
        write_csv(&pts, &path).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back.len(), 100);
        for i in 0..100 {
            for k in 0..2 {
                assert!((back.coord(i, k) - pts.coord(i, k)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn csv_skips_header_and_comments() {
        let path = tmpdir().join("hdr.csv");
        std::fs::write(&path, "x,y\n# comment\n1.0,2.0\n3.0,4.0\n").unwrap();
        let pts = read_csv(&path).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts.point(1), &[3.0, 4.0]);
    }

    #[test]
    fn csv_rejects_ragged() {
        let path = tmpdir().join("ragged.csv");
        std::fs::write(&path, "1.0,2.0\n3.0\n").unwrap();
        assert!(matches!(read_csv(&path), Err(DpcError::DimensionMismatch { expected: 2, got: 1 })));
    }

    #[test]
    fn csv_rejects_nonfinite_and_empty() {
        let path = tmpdir().join("nan.csv");
        std::fs::write(&path, "1.0,2.0\nNaN,4.0\n").unwrap();
        assert!(matches!(read_csv(&path), Err(DpcError::NonFiniteCoordinate { point: 1, dim: 0 })));
        let path = tmpdir().join("empty.csv");
        std::fs::write(&path, "# nothing here\n").unwrap();
        assert!(matches!(read_csv(&path), Err(DpcError::EmptyInput)));
    }
}
