//! Datasets: the paper's synthetic generators, surrogate generators for its
//! real-world datasets, and simple IO.
//!
//! The paper evaluates on three synthetic families (`uniform`, `simden`,
//! `varden` — the latter two are Gan–Tao random-walk cluster generators
//! [29]) and six real datasets (GeoLife, PAMAP2, Sensor, HT, Query,
//! Gowalla). The real datasets are not redistributable/downloadable in this
//! offline environment, so [`surrogate`] provides generators matched to each
//! dataset's (n, d) and qualitative density profile from Table 2 — see
//! DESIGN.md §5 for the substitution argument. Sizes default to a scaled-
//! down n (this container is a single core; the paper used 30).

pub mod synthetic;
pub mod surrogate;
pub mod io;

use crate::dpc::DpcParams;
use crate::geom::PointSet;

/// A named benchmark dataset with its Table-2 hyper-parameters.
pub struct Dataset {
    pub name: String,
    pub pts: PointSet,
    pub params: DpcParams,
    /// The paper's original size (for the Table-2 printout).
    pub paper_n: usize,
}

impl std::fmt::Debug for Dataset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Dataset")
            .field("name", &self.name)
            .field("n", &self.pts.len())
            .field("paper_n", &self.paper_n)
            .finish_non_exhaustive()
    }
}

/// The nine benchmark datasets of Table 2, at a scale factor (1.0 = the
/// sizes used by this repo's benches; the paper's original n is recorded in
/// [`Dataset::paper_n`]).
pub fn registry(scale: f64) -> Vec<&'static str> {
    let _ = scale;
    vec!["uniform", "simden", "varden", "geolife", "pamap2", "sensor", "ht", "query", "gowalla"]
}

/// Instantiate a benchmark dataset by name. `n` overrides the default
/// (scaled) size; pass `None` for the default.
pub fn by_name(name: &str, n: Option<usize>, seed: u64) -> Option<Dataset> {
    let ds = match name {
        // Synthetic family (Table 2: d=2, d_cut=30, rho_min=0, delta_min=100,
        // n up to 1e7; default scaled to 1e5). The extent is chosen so that
        // densities at d_cut=30 are "nonzero but much less than n" (§7.1).
        "uniform" => {
            let n = n.unwrap_or(100_000);
            let extent = 1000.0 * (n as f64 / 1e5).sqrt() * 30.0 / 30.0 * 30.0;
            Dataset {
                name: "uniform".into(),
                pts: synthetic::uniform(n, 2, extent, seed),
                params: DpcParams { d_cut: 30.0, rho_min: 0.0, delta_min: 100.0, ..DpcParams::default() },
                paper_n: 10_000_000,
            }
        }
        "simden" => {
            let n = n.unwrap_or(100_000);
            Dataset {
                name: "simden".into(),
                pts: synthetic::simden(n, 2, seed),
                params: DpcParams { d_cut: 30.0, rho_min: 0.0, delta_min: 100.0, ..DpcParams::default() },
                paper_n: 10_000_000,
            }
        }
        "varden" => {
            let n = n.unwrap_or(100_000);
            Dataset {
                name: "varden".into(),
                pts: synthetic::varden(n, 2, seed),
                params: DpcParams { d_cut: 30.0, rho_min: 0.0, delta_min: 100.0, ..DpcParams::default() },
                paper_n: 10_000_000,
            }
        }
        "geolife" => {
            let n = n.unwrap_or(250_000);
            Dataset {
                name: "geolife".into(),
                pts: surrogate::geolife_like(n, seed),
                params: DpcParams { d_cut: 1.0, rho_min: 10.0, delta_min: 10.0, ..DpcParams::default() },
                paper_n: 24_876_978,
            }
        }
        "pamap2" => {
            let n = n.unwrap_or(50_000);
            Dataset {
                name: "pamap2".into(),
                pts: surrogate::pamap2_like(n, seed),
                params: DpcParams { d_cut: 0.02, rho_min: 20.0, delta_min: 0.2, ..DpcParams::default() },
                paper_n: 259_803,
            }
        }
        _ => return by_name2(name, n, seed),
    };
    Some(ds)
}

fn by_name2(name: &str, n: Option<usize>, seed: u64) -> Option<Dataset> {
    let ds = match name {
        "sensor" => {
            let n = n.unwrap_or(100_000);
            Dataset {
                name: "sensor".into(),
                pts: surrogate::sensor_like(n, seed),
                params: DpcParams { d_cut: 0.2, rho_min: 5.0, delta_min: 2.0, ..DpcParams::default() },
                paper_n: 3_843_160,
            }
        }
        "ht" => {
            let n = n.unwrap_or(50_000);
            Dataset {
                name: "ht".into(),
                pts: surrogate::ht_like(n, seed),
                params: DpcParams { d_cut: 0.5, rho_min: 30.0, delta_min: 10.0, ..DpcParams::default() },
                paper_n: 928_991,
            }
        }
        "query" => {
            let n = n.unwrap_or(50_000);
            Dataset {
                name: "query".into(),
                pts: surrogate::query_like(n, seed),
                params: DpcParams { d_cut: 0.01, rho_min: 0.0, delta_min: 0.05, ..DpcParams::default() },
                paper_n: 50_000,
            }
        }
        "gowalla" => {
            let n = n.unwrap_or(150_000);
            Dataset {
                name: "gowalla".into(),
                pts: surrogate::gowalla_like(n, seed),
                params: DpcParams { d_cut: 0.03, rho_min: 0.0, delta_min: 40.0, ..DpcParams::default() },
                paper_n: 1_256_248,
            }
        }
        _ => return None,
    };
    Some(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_instantiates_all_datasets() {
        for name in registry(1.0) {
            let ds = by_name(name, Some(2000), 42).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(ds.pts.len(), 2000, "{name}");
            assert!(ds.pts.dim() >= 2 && ds.pts.dim() <= 8);
            assert!(ds.params.d_cut > 0.0);
            assert!(ds.paper_n >= 50_000);
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(by_name("nope", None, 1).is_none());
    }

    #[test]
    fn generation_is_deterministic() {
        let a = by_name("simden", Some(1000), 7).unwrap();
        let b = by_name("simden", Some(1000), 7).unwrap();
        assert_eq!(a.pts.coords(), b.pts.coords());
        let c = by_name("simden", Some(1000), 8).unwrap();
        assert_ne!(a.pts.coords(), c.pts.coords());
    }
}
