//! Synthetic generators: `uniform`, plus reimplementations of Gan & Tao's
//! random-walk cluster generators [29] — `simden` (clusters of **sim**ilar
//! **den**sity) and `varden` (**var**ying **den**sity). A cluster is the
//! trace of a random walk whose step length controls its density; restart
//! points scatter the clusters over the domain.

use crate::geom::PointSet;
use crate::prng::SplitMix64;

/// Uniform points in `[0, extent)^d`, generated straight into the store's
/// shared allocation (no `Vec → Arc` copy; see `PointStore::from_flat_fn`).
pub fn uniform(n: usize, d: usize, extent: f64, seed: u64) -> PointSet {
    let mut rng = SplitMix64::new(seed ^ 0x556E_1F0A); // stream-split
    PointSet::from_flat_fn(n, d, |_| rng.uniform(0.0, extent))
}

/// Shared random-walk engine. Each of `n_clusters` clusters walks
/// `n / n_clusters` steps with per-cluster step length `step(c)`; each step
/// displaces uniformly in `[-step, step]^d` and emits one point. Walks start
/// at uniform restarts in `[0, extent)^d` and reflect off the boundary.
fn random_walk_clusters<F: Fn(usize) -> f64>(
    n: usize,
    d: usize,
    extent: f64,
    n_clusters: usize,
    step_of: F,
    seed: u64,
) -> PointSet {
    let mut rng = SplitMix64::new(seed);
    let per = n / n_clusters;
    // Flat-index-driven fill into the store's shared allocation: cluster
    // restarts and walk steps fire at each point's first dimension, so the
    // RNG draw sequence (restart coords, then one step per emitted point)
    // is identical to the old push-loop generator.
    let mut pos: Vec<f64> = Vec::new();
    let mut cluster = 0usize;
    let mut left = 0usize; // points still owed by the current cluster
    let mut step = 0.0f64;
    PointSet::from_flat_fn(n, d, |idx| {
        if idx % d == 0 {
            // Empty clusters (n < n_clusters) still draw their restart,
            // matching the old generator's stream position.
            while left == 0 && cluster < n_clusters {
                step = step_of(cluster);
                pos = (0..d).map(|_| rng.uniform(0.0, extent)).collect();
                left = if cluster == n_clusters - 1 { n - cluster * per } else { per };
                cluster += 1;
            }
            for x in pos.iter_mut() {
                *x += rng.uniform(-step, step);
                // Reflect into the domain.
                if *x < 0.0 {
                    *x = -*x;
                }
                if *x > extent {
                    *x = 2.0 * extent - *x;
                }
            }
            left -= 1;
        }
        pos[idx % d]
    })
}

/// `simden`: 10 clusters of similar density (equal step length). The extent
/// scales with √n so the per-point density at the paper's d_cut = 30 stays
/// roughly constant as n grows (matching how the paper's densities remain
/// "nonzero but ≪ n" across its 10³..10⁷ sweep).
pub fn simden(n: usize, d: usize, seed: u64) -> PointSet {
    let extent = 30_000.0 * (n as f64 / 1e5).powf(1.0 / d as f64);
    random_walk_clusters(n, d, extent, 10, |_| 15.0, seed ^ 0x51D3)
}

/// `varden`: 10 clusters whose step lengths span ~2 orders of magnitude, so
/// cluster densities vary widely (the distribution on which the paper's
/// approximate baseline collapses).
pub fn varden(n: usize, d: usize, seed: u64) -> PointSet {
    let extent = 30_000.0 * (n as f64 / 1e5).powf(1.0 / d as f64);
    random_walk_clusters(n, d, extent, 10, |c| 2.0 * 1.8f64.powi(c as i32), seed ^ 0xFAde_0000u64 ^ 0xBDE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::{compute_density, DensityAlgo};

    #[test]
    fn sizes_and_dims() {
        for f in [uniform2 as fn(usize, u64) -> PointSet] {
            let p = f(1000, 1);
            assert_eq!(p.len(), 1000);
        }
        assert_eq!(simden(997, 2, 3).len(), 997); // non-divisible n
        assert_eq!(varden(1003, 3, 3).dim(), 3);
    }

    fn uniform2(n: usize, seed: u64) -> PointSet {
        uniform(n, 2, 100.0, seed)
    }

    #[test]
    fn simden_clusters_have_similar_density() {
        let pts = simden(10_000, 2, 5);
        let rho = compute_density(&pts, 30.0, DensityAlgo::TreePruned);
        // Compare mean density of first vs last cluster (1000 points each).
        let m1: f64 = rho[..1000].iter().map(|&r| r as f64).sum::<f64>() / 1000.0;
        let m2: f64 = rho[9000..].iter().map(|&r| r as f64).sum::<f64>() / 1000.0;
        assert!(m1 > 1.0 && m2 > 1.0);
        let ratio = m1.max(m2) / m1.min(m2);
        assert!(ratio < 3.0, "similar-density clusters, ratio={ratio}");
    }

    #[test]
    fn varden_clusters_have_varying_density() {
        let pts = varden(10_000, 2, 5);
        let rho = compute_density(&pts, 30.0, DensityAlgo::TreePruned);
        let m_dense: f64 = rho[..1000].iter().map(|&r| r as f64).sum::<f64>() / 1000.0;
        let m_sparse: f64 = rho[9000..].iter().map(|&r| r as f64).sum::<f64>() / 1000.0;
        let ratio = m_dense / m_sparse.max(1e-9);
        assert!(ratio > 10.0, "varying density expected, ratio={ratio}");
    }

    #[test]
    fn walk_points_stay_in_domain() {
        let pts = simden(5000, 2, 9);
        let bb = pts.bbox();
        assert!(bb.min().iter().all(|&v| v >= 0.0));
    }
}
