//! `pallas-lint` — the repo's contract linter (DESIGN.md §Static
//! analysis).
//!
//! Usage:
//!
//! ```text
//! pallas_lint [ROOT]     # default ROOT: rust/src relative to the cwd
//! ```
//!
//! Scans every `.rs` file under ROOT with the rule catalog in
//! [`parcluster::lint`] and prints one `file:line: [rule] message` per
//! violation. Exit status: 0 when clean, 1 when violations were found,
//! 2 on I/O failure. CI runs this on `rust/src` in the feature-matrix
//! legs; run it locally the same way before pushing.

use std::path::PathBuf;
use std::process::ExitCode;

use parcluster::lint;

fn main() -> ExitCode {
    let root = std::env::args_os()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("rust/src"));

    if !root.is_dir() {
        eprintln!("pallas-lint: {} is not a directory", root.display());
        return ExitCode::from(2);
    }

    let violations = match lint::scan_tree(&root) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("pallas-lint: scan failed: {e}");
            return ExitCode::from(2);
        }
    };

    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        eprintln!("pallas-lint: clean ({})", root.display());
        ExitCode::SUCCESS
    } else {
        eprintln!("pallas-lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}
