//! `loadgen` — drive the parcluster TCP serve surface with concurrent
//! mixed traffic and report latency percentiles + throughput.
//!
//! Two modes:
//!
//! - `loadgen --addr HOST:PORT ...` — hit an already-running
//!   `parcluster serve --listen HOST:PORT`.
//! - `loadgen --self-serve ...` — spin up an in-process server on
//!   `127.0.0.1:0` (OS-assigned port), run the workload against it over
//!   real sockets, then shut it down. This is what CI's smoke leg uses:
//!   no orchestration, still exercises the full TCP path.
//!
//! Exit status is nonzero if any protocol error occurred (the serve
//! contract is zero protocol errors under well-formed traffic) or if no
//! operations completed.

use std::process::ExitCode;
use std::sync::Arc;

use parcluster::cli::Args;
use parcluster::coordinator::{Coordinator, CoordinatorConfig};
use parcluster::serve::loadgen::{run, LoadgenOpts};
use parcluster::serve::{server, ServeState};

const USAGE: &str = "\
loadgen — concurrency/latency harness for `parcluster serve --listen`

USAGE:
  loadgen (--addr HOST:PORT | --self-serve) [FLAGS]

FLAGS:
  --addr HOST:PORT     target an external `parcluster serve --listen` server
  --self-serve         spawn an in-process server on 127.0.0.1:0 instead
  --connections M      concurrent client connections        (default 4)
  --ops N              operations per connection            (default 25)
  --n N                points per session / ingest batch    (default 200)
  --dataset NAME       server-side dataset generator        (default simden)
  --ingest-pct P       percent of ops that are stream ingests, rest are
                       session recuts                       (default 50)
  --tenant ID          tenant id sent in each connection's hello
  --workers N          (self-serve) coordinator worker threads
  --max-inflight N     (self-serve) coordinator admission cap, 0 = unlimited
  --smoke              tiny fast preset: --connections 4 --ops 5 --n 120

Reports total ops, Busy retries, p50/p99 latency, and throughput; exits
nonzero on any protocol error or if zero operations completed.
";

fn main() -> ExitCode {
    match real_main() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("loadgen: {e}");
            ExitCode::from(2)
        }
    }
}

fn real_main() -> anyhow::Result<ExitCode> {
    let args = Args::parse(std::env::args().skip(1))?;
    if args.switch("help") || args.positional.iter().any(|p| p == "help") {
        print!("{USAGE}");
        return Ok(ExitCode::SUCCESS);
    }
    let mut opts = LoadgenOpts::default();
    if args.switch("smoke") {
        opts.connections = 4;
        opts.ops_per_conn = 5;
        opts.n = 120;
    }
    opts.connections = args.get_or("connections", opts.connections)?;
    opts.ops_per_conn = args.get_or("ops", opts.ops_per_conn)?;
    opts.n = args.get_or("n", opts.n)?;
    opts.dataset = args.get("dataset").unwrap_or(&opts.dataset).to_string();
    opts.ingest_pct = args.get_or("ingest-pct", opts.ingest_pct)?;
    opts.tenant = args.get("tenant").unwrap_or("").to_string();
    let addr = args.get("addr").map(|s| s.to_string());
    let self_serve = args.switch("self-serve");
    let workers: usize = args.get_or("workers", 0)?;
    let max_inflight: u64 = args.get_or("max-inflight", 0)?;
    args.reject_unknown()?;

    // A self-served target owns its server handle for shutdown at the end.
    let mut owned: Option<server::ServerHandle> = None;
    match (addr, self_serve) {
        (Some(a), false) => opts.addr = a,
        (None, true) => {
            let mut cfg = CoordinatorConfig::default();
            if workers > 0 {
                cfg.workers = workers;
            }
            cfg.max_inflight_jobs = max_inflight;
            let state = Arc::new(ServeState::new(Coordinator::start(cfg)?));
            let handle = server::spawn("127.0.0.1:0", state)?;
            opts.addr = handle.local_addr.to_string();
            eprintln!("loadgen: self-serving on {}", opts.addr);
            owned = Some(handle);
        }
        _ => anyhow::bail!("exactly one of --addr or --self-serve is required (see --help)"),
    }

    let report = run(&opts);
    if let Some(h) = owned {
        h.shutdown();
    }

    println!(
        "loadgen: {} conns x {} ops, dataset={} n={} ingest={}%",
        opts.connections, opts.ops_per_conn, opts.dataset, opts.n, opts.ingest_pct
    );
    println!(
        "  ops={} busy_retries={} request_errors={} proto_errors={}",
        report.ops, report.busy, report.request_errors, report.proto_errors
    );
    println!(
        "  p50={:.2}ms p99={:.2}ms throughput={:.1} ops/s wall={:.2}s",
        report.p50.as_secs_f64() * 1e3,
        report.p99.as_secs_f64() * 1e3,
        report.ops_per_sec,
        report.wall.as_secs_f64()
    );

    if report.proto_errors > 0 {
        eprintln!("loadgen: FAIL — {} protocol errors", report.proto_errors);
        return Ok(ExitCode::FAILURE);
    }
    if report.ops == 0 {
        eprintln!("loadgen: FAIL — zero operations completed");
        return Ok(ExitCode::FAILURE);
    }
    Ok(ExitCode::SUCCESS)
}
