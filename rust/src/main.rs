//! `parcluster` — the leader binary: CLI over the coordinator service.

use std::io::BufRead;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use parcluster::bench::{fmt_secs, Table};
use parcluster::cli::{Args, USAGE};
use parcluster::coordinator::config::{parse_backend, parse_dep_algo};
use parcluster::coordinator::{ClusterJob, Coordinator, CoordinatorConfig, OpenSpec};
use parcluster::datasets::{self, io};
use parcluster::dpc::{decision, ClusterSession, DensityModel, DepAlgo, DpcParams};
use parcluster::geom::{Dtype, DynPoints, PointSet};
use parcluster::serve::{dispatch, ConnCtx, Request, ServeState};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "datasets" => cmd_datasets(&args),
        "generate" => cmd_generate(&args),
        "cluster" => cmd_cluster(&args),
        "decision" => cmd_decision(&args),
        "stream" => cmd_stream(&args),
        "serve" => cmd_serve(&args),
        "journal" => cmd_journal(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

/// Print the Table-2 style dataset inventory.
fn cmd_datasets(args: &Args) -> Result<()> {
    let n = args.get_parse::<usize>("n")?;
    let seed = args.get_or("seed", 42u64)?;
    args.reject_unknown()?;
    let mut table = parcluster::bench::Table::new(&["name", "n (here)", "n (paper)", "d", "d_cut", "rho_min", "delta_min"]);
    for name in datasets::registry(1.0) {
        // Registry names are self-reported, but route through the typed
        // error anyway: a registry/by_name drift must not abort the CLI.
        let ds = datasets::by_name(name, n, seed).with_context(|| format!("unknown dataset {name:?}"))?;
        table.row(vec![
            ds.name.clone(),
            ds.pts.len().to_string(),
            ds.paper_n.to_string(),
            ds.pts.dim().to_string(),
            format!("{}", ds.params.d_cut),
            format!("{}", ds.params.rho_min),
            format!("{}", ds.params.delta_min),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let name = args.require("dataset")?.to_string();
    let n = args.get_parse::<usize>("n")?;
    let seed = args.get_or("seed", 42u64)?;
    let out = args.require("out")?.to_string();
    let csv = args.switch("csv");
    let dtype = args.get_parse::<Dtype>("dtype")?.unwrap_or(Dtype::F64);
    args.reject_unknown()?;
    let ds = datasets::by_name(&name, n, seed).with_context(|| format!("unknown dataset {name:?}"))?;
    let (count, dim) = (ds.pts.len(), ds.pts.dim());
    let path = Path::new(&out);
    if csv {
        if dtype != Dtype::F64 {
            bail!("--dtype applies to the binary format only (CSV is decimal text)");
        }
        io::write_csv(&ds.pts, path)?;
    } else {
        // The v2 binary format stores the requested precision; a same-dtype
        // cast shares the generator's buffer instead of copying.
        io::write_binary_dyn(&DynPoints::F64(ds.pts).cast(dtype), path)?;
    }
    println!("wrote {count} points (d={dim}, dtype={dtype}) to {out}");
    Ok(())
}

/// Load points from --dataset/--input at their stored precision, plus
/// default params. Binary files keep their on-disk dtype (no widening
/// round trip); datasets and CSV are f64 sources.
fn load_input_dyn(args: &Args) -> Result<(DynPoints, DpcParams, String)> {
    if let Some(name) = args.get("dataset") {
        let n = args.get_parse::<usize>("n")?;
        let seed = args.get_or("seed", 42u64)?;
        let ds = datasets::by_name(name, n, seed).with_context(|| format!("unknown dataset {name:?}"))?;
        return Ok((DynPoints::F64(ds.pts), ds.params, ds.name));
    }
    if let Some(path) = args.get("input") {
        let p = Path::new(path);
        let pts = if path.ends_with(".csv") {
            DynPoints::F64(io::read_csv(p)?)
        } else {
            io::read_binary_dyn(p)?
        };
        return Ok((pts, DpcParams::default(), path.to_string()));
    }
    bail!("need --dataset NAME or --input FILE")
}

/// f64 view of [`load_input_dyn`] for the commands that stay
/// double-precision (decision graphs, streaming).
fn load_input(args: &Args) -> Result<(PointSet, DpcParams, String)> {
    let (pts, params, tag) = load_input_dyn(args)?;
    Ok((pts.into_f64(), params, tag))
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let (pts, mut params, tag) = load_input_dyn(args)?;
    params.d_cut = args.get_or("d-cut", params.d_cut)?;
    params.rho_min = args.get_or("rho-min", params.rho_min)?;
    params.delta_min = args.get_or("delta-min", params.delta_min)?;
    // Default to the input's stored precision (f64 for datasets/CSV; an
    // f32 binary file stays f32 unless --dtype says otherwise).
    params.dtype = args.get_parse::<Dtype>("dtype")?.unwrap_or(pts.dtype());
    params.density = args.get_parse::<DensityModel>("density")?.unwrap_or(params.density);
    let mut cfg = CoordinatorConfig { workers: 1, ..CoordinatorConfig::default() }.with_env_overrides()?;
    if let Some(b) = args.get("backend") {
        cfg.backend = parse_backend(b)?;
    }
    if let Some(a) = args.get("algo") {
        cfg.dep_algo = parse_dep_algo(a)?;
    }
    cfg.threads = args.get_or("threads", 0usize)?;
    let labels_out = args.get("labels-out").map(|s| s.to_string());
    args.reject_unknown()?;

    // The requested dtype picks the store the whole pipeline runs on.
    // `cast` refcount-shares when the input is already at that precision
    // (an f32 file stays one buffer end to end) and rounds otherwise (use
    // integer-coordinate data for bit-exact f32/f64 parity — see
    // DESIGN.md §2b). The cast result is already the job payload type.
    let payload = pts.cast(params.dtype);
    let coord = Coordinator::start(cfg)?;
    let out = coord
        .run_sync(ClusterJob::new_points(payload, params).tag(&tag))
        .map_err(|e| anyhow::anyhow!(e))?;
    let r = &out.result;
    println!("dataset    : {tag}");
    println!("backend    : {}", out.backend_used.name());
    println!("dtype      : {}", params.dtype);
    println!("density    : {}", params.density);
    println!("points     : {}", r.labels.len());
    println!("clusters   : {}", r.num_clusters);
    println!("noise      : {}", r.num_noise);
    println!(
        "time       : total {} (density {}, dep {}, linkage {})",
        fmt_secs(out.wall_s),
        fmt_secs(r.timings.density_s),
        fmt_secs(r.timings.dep_s),
        fmt_secs(r.timings.linkage_s)
    );
    if let Some(path) = labels_out {
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
        writeln!(f, "id,label")?;
        for (i, l) in r.labels.iter().enumerate() {
            writeln!(f, "{i},{l}")?;
        }
        println!("labels -> {path}");
    }
    Ok(())
}

fn cmd_decision(args: &Args) -> Result<()> {
    let (pts, mut params, tag) = load_input(args)?;
    params.d_cut = args.get_or("d-cut", params.d_cut)?;
    let k = args.get_or("k", 0usize)?;
    let csv_out = args.get("csv-out").map(|s| s.to_string());
    args.reject_unknown()?;
    // Staged session: the scan pass pays for the kd-tree and (ρ, δ) once;
    // the k-suggestion verification below re-cuts for the price of Step 3.
    let mut session = ClusterSession::build(&pts)?;
    session.density(params.d_cut)?;
    session.dependents(DepAlgo::Priority)?;
    let scan = session.cut(0.0, f64::INFINITY)?;
    let graph = decision::decision_graph(&scan);
    println!("decision graph for {tag} (n={}, d_cut={}):", pts.len(), params.d_cut);
    print!("{}", decision::ascii_plot(&graph, 64, 16));
    if k > 0 {
        let (rho_min, delta_min) = decision::suggest_params(&graph, k)?;
        let out = session.cut(rho_min, delta_min)?;
        println!(
            "suggested for k={k}: rho_min={rho_min}, delta_min={delta_min:.4} -> {} clusters, {} noise (re-cut {:.4}s)",
            out.num_clusters, out.num_noise, out.timings.linkage_s
        );
    }
    if let Some(path) = csv_out {
        let f = std::fs::File::create(&path)?;
        decision::write_csv(&graph, std::io::BufWriter::new(f))?;
        println!("decision graph -> {path}");
    }
    Ok(())
}

/// Streaming ingestion demo: feed the input in batches through a
/// coordinator stream, reporting per-batch ingest+cut latency (and, with
/// `--verify`, exactness against a from-scratch run on every prefix).
fn cmd_stream(args: &Args) -> Result<()> {
    let (pts, mut params, tag) = load_input(args)?;
    params.d_cut = args.get_or("d-cut", params.d_cut)?;
    params.rho_min = args.get_or("rho-min", params.rho_min)?;
    params.delta_min = args.get_or("delta-min", params.delta_min)?;
    params.density = args.get_parse::<DensityModel>("density")?.unwrap_or(params.density);
    let batches = args.get_or("batches", 10usize)?.max(1);
    let verify = args.switch("verify");
    args.reject_unknown()?;

    let cfg = CoordinatorConfig { workers: 1, ..CoordinatorConfig::default() }.with_env_overrides()?;
    let coord = Coordinator::start(cfg)?;
    let d = pts.dim();
    let n = pts.len();
    let per = n.div_ceil(batches);
    let sid = coord.open_stream(OpenSpec::dim(d, params.d_cut).density(params.density).tag(&tag))?;
    println!(
        "stream {sid}: {tag} (n={n}, d={d}) in {batches} batches, d_cut={}, rho_min={}, delta_min={}, density={}",
        params.d_cut, params.rho_min, params.delta_min, params.density
    );
    let mut table =
        Table::new(&["batch", "points", "total", "ingest+cut", "clusters", "noise", if verify { "exact" } else { "-" }]);
    let mut sent = 0usize;
    let mut batch_no = 0usize;
    let mut all_exact = true;
    while sent < n {
        let hi = (sent + per).min(n);
        let batch = PointSet::try_new(pts.coords()[sent * d..hi * d].to_vec(), d)?;
        let id = coord.submit_ingest(sid, Arc::new(batch), params.rho_min, params.delta_min)?;
        let out = coord.wait(id).map_err(|e| anyhow::anyhow!(e))?;
        let exact = if verify {
            let prefix = PointSet::try_new(pts.coords()[..hi * d].to_vec(), d)?;
            let fresh = parcluster::dpc::Dpc::new(params).run(&prefix)?;
            let same = out.result.rho == fresh.rho
                && out.result.dep == fresh.dep
                && out.result.delta == fresh.delta
                && out.result.labels == fresh.labels
                && out.result.centers == fresh.centers;
            all_exact &= same;
            if same { "yes" } else { "NO" }
        } else {
            "-"
        };
        table.row(vec![
            batch_no.to_string(),
            (hi - sent).to_string(),
            hi.to_string(),
            fmt_secs(out.wall_s),
            out.result.num_clusters.to_string(),
            out.result.num_noise.to_string(),
            exact.to_string(),
        ]);
        sent = hi;
        batch_no += 1;
    }
    table.print();
    if let Some(entry) = coord.stream(sid) {
        let s = entry.session.lock();
        let st = s.stats();
        println!(
            "forest levels: {:?}; trees rebuilt: {} ({} points total) for {} ingested points",
            s.level_sizes(),
            st.trees_built,
            st.tree_points_built,
            st.points_ingested
        );
    }
    if !all_exact {
        bail!("streaming state diverged from a from-scratch run (see the `exact` column)");
    }
    Ok(())
}

/// Serve mode: the stdin line surface and (with `--listen`) the TCP
/// binary surface, both feeding [`parcluster::serve::dispatch`] — one
/// parser, one dispatcher, one behavior. Each stdin line is parsed into
/// a [`Request`], dispatched synchronously, and its [`Response`] printed;
/// malformed lines report to stderr and never take the server down.
fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(p) => CoordinatorConfig::load(Path::new(p))?,
        None => CoordinatorConfig::default(),
    }
    .with_env_overrides()?;
    if let Some(w) = args.get_parse::<usize>("workers")? {
        cfg.workers = w.max(1);
    }
    if let Some(dir) = args.get("durable") {
        cfg.durable_dir = Some(std::path::PathBuf::from(dir));
    }
    if let Some(n) = args.get_parse::<u64>("fsync-every")? {
        cfg.fsync_every = n;
    }
    if let Some(n) = args.get_parse::<u64>("journal-rotate-bytes")? {
        cfg.journal_rotate_bytes = n;
    }
    if let Some(n) = args.get_parse::<u64>("checkpoint-retain")? {
        cfg.checkpoint_retain = n.max(1);
    }
    if let Some(a) = args.get("listen") {
        cfg.listen_addr = Some(a.to_string());
    }
    if let Some(n) = args.get_parse::<u64>("max-inflight")? {
        cfg.max_inflight_jobs = n;
    }
    if let Some(n) = args.get_parse::<usize>("max-sessions-per-tenant")? {
        cfg.max_sessions_per_tenant = n;
    }
    if let Some(n) = args.get_parse::<usize>("max-open-sessions")? {
        cfg.max_open_sessions = n;
    }
    args.reject_unknown()?;
    let listen = cfg.listen_addr.clone();
    let coord = Coordinator::start(cfg)?;
    println!(
        "parcluster serve: {} workers, xla={}, durable={}; lines: `<dataset> <n> <d_cut> <rho_min> <delta_min> [algo] [density] [full]`,\n  `hello <tenant>`, `open <dataset> <n> <d_cut> [density] [tag=T]` (prints session id), `recut <session> <rho_min> <delta_min> [full]`,\n  `close <session>`, `stream <dim> <d_cut> [density] [f32|f64] [tag=T]` (prints stream id),\n  `ingest <stream> <dataset> <n> <rho_min> <delta_min> [seed=S] [full]`, `closestream <stream>`,\n  `checkpoint` (durable mode: snapshot state now)",
        coord.config().workers,
        coord.has_xla(),
        coord.is_durable()
    );
    let state = Arc::new(ServeState::new(coord));
    let server = match &listen {
        Some(addr) => {
            let h = parcluster::serve::server::spawn(addr, Arc::clone(&state))?;
            println!("listening on {} (binary protocol v{})", h.local_addr, parcluster::serve::PROTO_VERSION);
            Some(h)
        }
        None => None,
    };
    let stdin = std::io::stdin();
    let mut ctx = ConnCtx::default();
    for line in stdin.lock().lines() {
        let line = line?;
        match Request::from_line(&line) {
            Ok(None) => {}
            // A malformed interactive line never takes the server down.
            Err(e) => eprintln!("skipping line {:?}: {e}", line.trim()),
            Ok(Some(req)) => {
                let resp = dispatch(&state, &mut ctx, req);
                println!("{}", resp.to_line());
            }
        }
    }
    if let Some(h) = server {
        h.shutdown();
    }
    println!("--- metrics ---\n{}", state.coord.metrics.render());
    Ok(())
}

/// `journal inspect --dir DIR` — read-only durable-directory forensics:
/// the manifest, the checkpoint files, and every frame across the
/// journal's segment chain, plus whether the tail is clean or torn.
/// Corruption surfaces as the same typed error recovery would report,
/// never a partial parse.
fn cmd_journal(args: &Args) -> Result<()> {
    use parcluster::durability::{journal, manifest, JournalEntry};
    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    if sub != "inspect" {
        bail!("unknown journal subcommand {sub:?} (want `journal inspect --dir DIR`)");
    }
    let dir = std::path::PathBuf::from(args.require("dir")?);
    args.reject_unknown()?;

    match manifest::read(&dir)? {
        None => println!("manifest   : none (directory not yet initialized)"),
        Some(m) => println!(
            "manifest   : checkpoint_seq={} journal_seq={} journal_offset={} next_lsn={} next_session_id={}",
            m.checkpoint_seq, m.journal_seq, m.journal_offset, m.next_lsn, m.next_session_id
        ),
    }
    let mut ckpts: Vec<(String, u64)> = std::fs::read_dir(&dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .filter_map(|e| e.ok())
        .filter(|e| {
            let n = e.file_name().to_string_lossy().into_owned();
            n.starts_with("checkpoint-") && n.ends_with(".pclc")
        })
        .map(|e| {
            let len = e.metadata().map(|m| m.len()).unwrap_or(0);
            (e.file_name().to_string_lossy().into_owned(), len)
        })
        .collect();
    ckpts.sort();
    if ckpts.is_empty() {
        println!("checkpoints: none");
    } else {
        for (name, len) in &ckpts {
            println!("checkpoint : {name} ({len} bytes)");
        }
    }

    // Scan the whole chain (from the lowest surviving segment, not the
    // manifest's replay horizon — inspection shows what's on disk, GC'd
    // or not).
    let segments = journal::list_segments(&dir)?;
    let Some(&(first_seq, _)) = segments.first() else {
        println!("journal    : none");
        return Ok(());
    };
    let scan = journal::scan_dir(&dir, first_seq)?;
    println!(
        "journal    : {} segments, {} frames, {} valid bytes",
        scan.segments.len(),
        scan.entries.len(),
        scan.segments.iter().map(|s| s.valid_len).sum::<u64>()
    );
    for s in &scan.segments {
        println!(
            "segment    : journal-{}.pclj first_lsn={} frames={} valid_bytes={}{}",
            s.seq,
            s.first_lsn,
            s.frames,
            s.valid_len,
            if s.torn_bytes > 0 { " (TORN TAIL)" } else { "" }
        );
    }
    let mut table = Table::new(&["segment", "offset", "lsn", "kind", "detail"]);
    for f in &scan.entries {
        let detail = match &f.entry {
            JournalEntry::OpenStream { stream, dim, dtype, d_cut, density } => {
                format!("stream={stream} dim={dim} dtype={dtype} d_cut={d_cut} density={density}")
            }
            JournalEntry::Ingest { stream, rho_min, delta_min, batch } => {
                format!("stream={stream} n={} rho_min={rho_min} delta_min={delta_min}", batch.len())
            }
            JournalEntry::CloseStream { stream } => format!("stream={stream}"),
            JournalEntry::OpenSession { session, d_cut, density, pts } => {
                format!("session={session} n={} d_cut={d_cut} density={density}", pts.len())
            }
            JournalEntry::Recut { session, rho_min, delta_min } => {
                format!("session={session} rho_min={rho_min} delta_min={delta_min}")
            }
            JournalEntry::CloseSession { session } => format!("session={session}"),
        };
        table.row(vec![
            f.seq.to_string(),
            f.offset.to_string(),
            f.lsn.to_string(),
            f.entry.kind_name().to_string(),
            detail,
        ]);
    }
    table.print();
    if scan.torn_bytes > 0 {
        println!("tail       : TORN ({} bytes past the last valid frame would be truncated)", scan.torn_bytes);
    } else {
        println!("tail       : clean (next lsn {})", scan.next_lsn);
    }
    Ok(())
}
