//! [`OrderedMutex`]/[`OrderedRwLock`]: `std::sync` wrappers that enforce
//! the global lock-rank table ([`super::rank`]) at runtime in debug builds.
//!
//! Each lock carries its rank as a const generic. A thread-local stack
//! records the ranks currently held by this thread; acquiring asserts the
//! new rank strictly exceeds the largest held rank. Because every push
//! exceeds the previous maximum, the stack is always sorted, so the check
//! is O(1) against the top. Guards may be dropped in any order (release
//! removes the matching rank wherever it sits), which keeps the
//! early-`drop(journal)` patterns in the coordinator legal.
//!
//! Poisoning policy matches the rest of the crate: a poisoned lock is a
//! fatal logic error (`lock` panics), exactly like the `.lock().unwrap()`
//! idiom these wrappers replace. In release builds (`debug_assertions`
//! off) the rank bookkeeping compiles to nothing and the wrappers are
//! zero-cost newtypes over `Mutex`/`RwLock`.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

#[cfg(debug_assertions)]
mod held {
    use std::cell::RefCell;

    thread_local! {
        /// Ranks of the ordered locks this thread currently holds, sorted
        /// ascending (each acquisition must exceed the current maximum).
        static HELD: RefCell<Vec<u16>> = const { RefCell::new(Vec::new()) };
    }

    pub fn acquire(rank: u16) {
        HELD.with(|h| {
            let mut s = h.borrow_mut();
            if let Some(&top) = s.last() {
                assert!(
                    rank > top,
                    "lock-order violation: acquiring rank {rank} while holding rank {top} \
                     (held stack: {s:?}; see the rank table in sync::rank)",
                );
            }
            s.push(rank);
        });
    }

    pub fn release(rank: u16) {
        HELD.with(|h| {
            let mut s = h.borrow_mut();
            if let Some(i) = s.iter().rposition(|&r| r == rank) {
                s.remove(i);
            }
        });
    }
}

#[cfg(not(debug_assertions))]
mod held {
    #[inline(always)]
    pub fn acquire(_rank: u16) {}
    #[inline(always)]
    pub fn release(_rank: u16) {}
}

/// A `Mutex` with a compile-time lock rank (see module docs).
pub struct OrderedMutex<T, const RANK: u16> {
    inner: Mutex<T>,
}

impl<T, const RANK: u16> OrderedMutex<T, RANK> {
    pub const fn new(value: T) -> Self {
        OrderedMutex { inner: Mutex::new(value) }
    }

    /// Acquire. Debug builds assert `RANK` exceeds every rank this thread
    /// already holds; a violation panics at the acquisition site with the
    /// full held stack.
    pub fn lock(&self) -> OrderedMutexGuard<'_, T, RANK> {
        held::acquire(RANK);
        match self.inner.lock() {
            Ok(g) => OrderedMutexGuard { inner: Some(g) },
            Err(poisoned) => {
                held::release(RANK);
                // lint: allow(panic-surface) — poisoning is fatal by policy,
                // matching the `.lock().unwrap()` idiom this wrapper replaces.
                panic!("ordered lock (rank {RANK}) poisoned: {poisoned}");
            }
        }
    }

    /// Consume the lock, returning its value (poison is discarded — by the
    /// time a lock can be consumed no other holder exists).
    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(|e| e.into_inner())
    }
}

impl<T: Default, const RANK: u16> Default for OrderedMutex<T, RANK> {
    fn default() -> Self {
        OrderedMutex::new(T::default())
    }
}

impl<T: fmt::Debug, const RANK: u16> fmt::Debug for OrderedMutex<T, RANK> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OrderedMutex").field("rank", &RANK).field("inner", &self.inner).finish()
    }
}

/// Guard for [`OrderedMutex`]. The inner `Option` exists only so
/// [`OrderedMutexGuard::wait`] can hand the std guard to a `Condvar` by
/// value; it is `Some` for the guard's entire observable lifetime.
pub struct OrderedMutexGuard<'a, T, const RANK: u16> {
    inner: Option<MutexGuard<'a, T>>,
}

impl<T, const RANK: u16> OrderedMutexGuard<'_, T, RANK> {
    /// Block on `cv`, releasing the mutex (and this thread's claim to
    /// `RANK`) while parked, reacquiring both on wake. Consumes and
    /// returns the guard, mirroring `Condvar::wait`'s guard-in/guard-out
    /// shape so the standard `while !cond { g = g.wait(&cv) }` loop works.
    pub fn wait(mut self, cv: &Condvar) -> Self {
        let std_guard = match self.inner.take() {
            Some(g) => g,
            // lint: allow(panic-surface) — unreachable by construction:
            // `inner` is None only transiently inside this method.
            None => unreachable!("ordered guard without inner std guard"),
        };
        held::release(RANK);
        // The wait itself re-blocks on the mutex before returning, which
        // re-establishes this thread's claim to the rank.
        let woke = cv.wait(std_guard);
        held::acquire(RANK);
        match woke {
            Ok(g) => {
                self.inner = Some(g);
                self
            }
            Err(poisoned) => {
                held::release(RANK);
                // lint: allow(panic-surface) — same fatal-poison policy as
                // `lock` (the pre-OrderedMutex code was `.wait(g).unwrap()`).
                panic!("ordered lock (rank {RANK}) poisoned during wait: {poisoned}");
            }
        }
    }
}

impl<T, const RANK: u16> Deref for OrderedMutexGuard<'_, T, RANK> {
    type Target = T;
    fn deref(&self) -> &T {
        match &self.inner {
            Some(g) => g,
            // lint: allow(panic-surface) — unreachable: `inner` is Some
            // whenever the guard is observable (see the struct docs).
            None => unreachable!("ordered guard without inner std guard"),
        }
    }
}

impl<T, const RANK: u16> DerefMut for OrderedMutexGuard<'_, T, RANK> {
    fn deref_mut(&mut self) -> &mut T {
        match &mut self.inner {
            Some(g) => g,
            // lint: allow(panic-surface) — unreachable: `inner` is Some
            // whenever the guard is observable (see the struct docs).
            None => unreachable!("ordered guard without inner std guard"),
        }
    }
}

impl<T, const RANK: u16> Drop for OrderedMutexGuard<'_, T, RANK> {
    fn drop(&mut self) {
        if self.inner.is_some() {
            held::release(RANK);
        }
    }
}

impl<T: fmt::Debug, const RANK: u16> fmt::Debug for OrderedMutexGuard<'_, T, RANK> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&**self, f)
    }
}

/// An `RwLock` with a compile-time lock rank. Both read and write
/// acquisitions claim the rank: two readers never conflict with each
/// other, but a read held while acquiring a lower-ranked lock is exactly
/// the kind of latent writer-deadlock the table exists to rule out.
pub struct OrderedRwLock<T, const RANK: u16> {
    inner: RwLock<T>,
}

impl<T, const RANK: u16> OrderedRwLock<T, RANK> {
    pub const fn new(value: T) -> Self {
        OrderedRwLock { inner: RwLock::new(value) }
    }

    pub fn read(&self) -> OrderedReadGuard<'_, T, RANK> {
        held::acquire(RANK);
        match self.inner.read() {
            Ok(g) => OrderedReadGuard { inner: g },
            Err(poisoned) => {
                held::release(RANK);
                // lint: allow(panic-surface) — fatal-poison policy (see
                // OrderedMutex::lock).
                panic!("ordered rwlock (rank {RANK}) poisoned: {poisoned}");
            }
        }
    }

    pub fn write(&self) -> OrderedWriteGuard<'_, T, RANK> {
        held::acquire(RANK);
        match self.inner.write() {
            Ok(g) => OrderedWriteGuard { inner: g },
            Err(poisoned) => {
                held::release(RANK);
                // lint: allow(panic-surface) — fatal-poison policy (see
                // OrderedMutex::lock).
                panic!("ordered rwlock (rank {RANK}) poisoned: {poisoned}");
            }
        }
    }
}

impl<T: fmt::Debug, const RANK: u16> fmt::Debug for OrderedRwLock<T, RANK> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OrderedRwLock").field("rank", &RANK).field("inner", &self.inner).finish()
    }
}

/// Read guard for [`OrderedRwLock`].
#[derive(Debug)]
pub struct OrderedReadGuard<'a, T, const RANK: u16> {
    inner: RwLockReadGuard<'a, T>,
}

impl<T, const RANK: u16> Deref for OrderedReadGuard<'_, T, RANK> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T, const RANK: u16> Drop for OrderedReadGuard<'_, T, RANK> {
    fn drop(&mut self) {
        held::release(RANK);
    }
}

/// Write guard for [`OrderedRwLock`].
#[derive(Debug)]
pub struct OrderedWriteGuard<'a, T, const RANK: u16> {
    inner: RwLockWriteGuard<'a, T>,
}

impl<T, const RANK: u16> Deref for OrderedWriteGuard<'_, T, RANK> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T, const RANK: u16> DerefMut for OrderedWriteGuard<'_, T, RANK> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T, const RANK: u16> Drop for OrderedWriteGuard<'_, T, RANK> {
    fn drop(&mut self) {
        held::release(RANK);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ascending_acquisition_is_fine() {
        let a: OrderedMutex<u32, 100> = OrderedMutex::new(1);
        let b: OrderedMutex<u32, 200> = OrderedMutex::new(2);
        let c: OrderedMutex<u32, 300> = OrderedMutex::new(3);
        let ga = a.lock();
        let gb = b.lock();
        let gc = c.lock();
        assert_eq!(*ga + *gb + *gc, 6);
    }

    #[test]
    fn out_of_order_drop_keeps_stack_consistent() {
        let a: OrderedMutex<(), 100> = OrderedMutex::new(());
        let b: OrderedMutex<(), 200> = OrderedMutex::new(());
        let c: OrderedMutex<(), 300> = OrderedMutex::new(());
        let ga = a.lock();
        let gb = b.lock();
        drop(ga); // release the LOWER rank first (journal-style early drop)
        let gc = c.lock(); // still legal: 300 > 200
        drop(gb);
        drop(gc);
        // And the stack is genuinely empty again.
        let _ = a.lock();
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "rank checks compile out in release")]
    fn descending_acquisition_panics() {
        let hi: Arc<OrderedMutex<(), 300>> = Arc::new(OrderedMutex::new(()));
        let lo: Arc<OrderedMutex<(), 100>> = Arc::new(OrderedMutex::new(()));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = hi.lock();
            let _bad = lo.lock();
        }));
        assert!(r.is_err(), "rank 100 under rank 300 must abort");
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "rank checks compile out in release")]
    fn same_rank_reacquisition_panics() {
        let a: OrderedMutex<(), 100> = OrderedMutex::new(());
        let b: OrderedMutex<(), 100> = OrderedMutex::new(());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = a.lock();
            let _bad = b.lock();
        }));
        assert!(r.is_err(), "two rank-100 locks held together must abort");
    }

    #[test]
    fn ranks_are_per_thread() {
        let hi: Arc<OrderedMutex<u32, 300>> = Arc::new(OrderedMutex::new(7));
        let lo: Arc<OrderedMutex<u32, 100>> = Arc::new(OrderedMutex::new(5));
        let _g = hi.lock();
        // Another thread's stack is empty; it may take the low rank.
        let lo2 = Arc::clone(&lo);
        let v = std::thread::spawn(move || *lo2.lock()).join().unwrap();
        assert_eq!(v, 5);
    }

    #[test]
    fn condvar_wait_releases_and_reclaims_rank() {
        let pair = Arc::new((OrderedMutex::<bool, 400>::new(false), Condvar::new()));
        let lower: Arc<OrderedMutex<(), 200>> = Arc::new(OrderedMutex::new(()));
        let p2 = Arc::clone(&pair);
        let t = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            let mut g = m.lock();
            while !*g {
                g = g.wait(cv);
            }
            *g
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        {
            let (m, cv) = &*pair;
            // Holding a lower rank while signalling a higher-ranked lock is
            // the checkpoint_now shape: journal (200) held, tickets (400)
            // waited on elsewhere.
            let _lo = lower.lock();
            *m.lock() = true;
            cv.notify_all();
        }
        assert!(t.join().unwrap());
    }

    #[test]
    fn rwlock_read_write_roundtrip() {
        let l: OrderedRwLock<Vec<u32>, 890> = OrderedRwLock::new(vec![1, 2]);
        assert_eq!(l.read().len(), 2);
        l.write().push(3);
        assert_eq!(*l.read(), vec![1, 2, 3]);
    }

    #[test]
    fn guard_wait_loop_with_lower_rank_held() {
        // The submit_ingest shape: journal (200) held across a tickets
        // (400) lock whose guard is dropped before the journal's.
        let j: OrderedMutex<(), 200> = OrderedMutex::new(());
        let t: OrderedMutex<u64, 400> = OrderedMutex::new(0);
        let gj = j.lock();
        let mut gt = t.lock();
        *gt += 1;
        drop(gt);
        drop(gj);
        assert_eq!(*t.lock(), 1);
    }
}
