//! Rank-ordered synchronization primitives (DESIGN.md §Static analysis).
//!
//! The coordinator/durability/serve stack holds several locks at once on
//! its hot paths (the write-ahead journal across ticket issuance, the
//! admission registry across coordinator opens, …). The global acyclicity
//! of that lock graph used to live only in comments; [`ordered`] turns it
//! into a machine-checked invariant: every shared lock is an
//! [`ordered::OrderedMutex`] carrying a compile-time rank from [`rank`],
//! and debug builds keep a per-thread stack of held ranks, asserting that
//! every acquisition's rank strictly exceeds every rank already held.
//! A future ordering violation therefore aborts deterministically at the
//! offending `lock()` in any debug/test run instead of deadlocking under
//! production load. Release builds compile the bookkeeping out entirely.

pub mod ordered;

pub use ordered::{OrderedMutex, OrderedMutexGuard, OrderedRwLock};

/// The global lock-rank table. One rank per lock *role*; a thread may only
/// acquire strictly increasing ranks. Gaps are deliberate — new locks slot
/// in without renumbering. The documented nesting paths each rank must
/// support are listed in DESIGN.md §Static analysis; the load-bearing
/// chains are:
///
/// - serve open: `SERVE_ADMISSION` → coordinator locks (eviction and open
///   run under the admission guard);
/// - durable ingest: `JOURNAL` → `STREAM_TICKETS` → `JOB_STATUS`/`JOB_QUEUE`
///   (WAL order == ticket order == queue order);
/// - checkpoint: `JOURNAL` → `STREAM_REGISTRY` → `STREAM_TICKETS` (wait) →
///   `STREAM_STATE` → `SESSION_REGISTRY`;
/// - compute: `STREAM_STATE` → pool locks (ingest repair runs parallel ops
///   while holding the stream).
pub mod rank {
    /// Serve-side admission registry ([`crate::serve`]): held across
    /// coordinator opens/closes (LRU eviction), so it ranks below every
    /// coordinator lock.
    pub const SERVE_ADMISSION: u16 = 100;
    /// The write-ahead journal — outermost coordinator state lock
    /// (DESIGN.md §Durability): journal order must equal application
    /// order, so it is taken before any ticket or registry lock.
    pub const JOURNAL: u16 = 200;
    /// Coordinator stream map (`Shared::streams`).
    pub const STREAM_REGISTRY: u16 = 300;
    /// Coordinator session map (`Shared::sessions`).
    pub const SESSION_REGISTRY: u16 = 310;
    /// Per-stream FIFO ingest tickets (taken after the journal on the
    /// submit path, and after the stream registry during checkpoint).
    pub const STREAM_TICKETS: u16 = 400;
    /// Per-stream [`crate::dpc::StreamingSession`] state — held across the
    /// whole ingest compute, which runs pool ops underneath.
    pub const STREAM_STATE: u16 = 500;
    /// Job status map (`Shared::status`).
    pub const JOB_STATUS: u16 = 600;
    /// Job queue (`Shared::queue`).
    pub const JOB_QUEUE: u16 = 610;
    /// The XLA engine's output memo ([`crate::coordinator::XlaEngine`]).
    pub const ENGINE_MEMO: u16 = 700;
    /// Metrics registry maps — leaf-adjacent: metrics are bumped while
    /// holding nearly anything above.
    pub const METRICS: u16 = 800;
    /// The global pool cell (`parlay::pool::GLOBAL`): read by every
    /// parallel op entry point, including under `STREAM_STATE`.
    pub const POOL_REGISTRY: u16 = 890;
    /// The pool's external-submission injector queue.
    pub const POOL_INJECTOR: u16 = 900;
    /// The pool's eventcount parking lock — a true leaf (nothing is ever
    /// acquired under it).
    pub const POOL_PARKING: u16 = 910;
}
