//! Typed errors for every user-facing API boundary.
//!
//! The seed API surfaced malformed input as `assert!`/panics; this enum
//! replaces that contract: `geom::PointSet::try_*`, the staged
//! [`crate::dpc::ClusterSession`], `Dpc::run`, `datasets::io`, and the
//! coordinator's session endpoints all return `Result<_, DpcError>`.
//! Internal invariants (never reachable from user input) remain
//! `debug_assert!`s.

use std::fmt;

/// Error type for clustering requests.
#[derive(Debug)]
pub enum DpcError {
    /// The point set has no points.
    EmptyInput,
    /// A row's length disagrees with the established dimension.
    DimensionMismatch { expected: usize, got: usize },
    /// A flat coordinate buffer whose length is not a multiple of the
    /// dimension.
    RaggedCoords { len: usize, dim: usize },
    /// A coordinate is NaN or infinite.
    NonFiniteCoordinate { point: usize, dim: usize },
    /// A requested *lossless* precision conversion would round the given
    /// coordinate (e.g. `0.1` into an `f32` store).
    LossyCast { point: usize, dim: usize, value: f64, dtype: &'static str },
    /// A binary point file carries a dtype tag this build does not know.
    UnsupportedDtype { tag: u8 },
    /// A hyper-parameter violates its documented requirement.
    InvalidParam { name: &'static str, value: f64, requirement: &'static str },
    /// A staged-session call arrived before its prerequisite stage.
    MissingStage { need: &'static str, call: &'static str },
    /// A session id that was never opened (or already closed).
    UnknownSession(u64),
    /// An execution backend failed (engine name + its message).
    Backend { engine: String, message: String },
    /// An underlying I/O failure (dataset files, label dumps).
    Io(std::io::Error),
    /// A fully-present write-ahead journal frame failed validation (bad
    /// magic/version, CRC mismatch, LSN discontinuity, undecodable
    /// payload). Distinct from a *torn tail* — an incomplete final frame —
    /// which recovery truncates silently instead of surfacing.
    CorruptJournal { offset: u64, detail: String },
    /// A checkpoint file failed validation (truncation, CRC mismatch,
    /// inconsistent section structure). Checkpoints are all-or-nothing:
    /// no partially-restored state ever escapes the decoder.
    CorruptCheckpoint { detail: String },
    /// The durability manifest is unreadable or inconsistent with the
    /// files it points at (e.g. a journal offset past the journal's end).
    CorruptManifest { detail: String },
    /// A write-ahead journal entry whose encoded payload exceeds the
    /// frame format's u32 length field. Rejected before any bytes reach
    /// the file — the alternative is a silently truncated length that a
    /// later scan reports as corruption.
    OversizedJournalEntry { len: u64, max: u64 },
    /// A point batch's coordinate precision disagrees with the stream it
    /// targets (e.g. an f64 batch into a recovered f32 stream). Streams
    /// are fixed-precision for their lifetime — silently widening or
    /// narrowing would break the byte-identity contract.
    DtypeMismatch { expected: &'static str, got: &'static str },
    /// Admission control rejected a job: the coordinator already has
    /// `limit` jobs queued or running. The caller should back off and
    /// retry; the serve surfaces translate this into a `Busy` response
    /// rather than queueing unboundedly.
    Backpressure { in_flight: u64, limit: u64 },
    /// Admission control rejected an open: the tenant already holds its
    /// full quota of open sessions/streams.
    QuotaExceeded { tenant: String, open: usize, limit: usize },
}

impl fmt::Display for DpcError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DpcError::EmptyInput => write!(f, "empty point set: nothing to cluster"),
            DpcError::DimensionMismatch { expected, got } => {
                write!(f, "dimension mismatch: expected {expected}-d row, got {got}-d")
            }
            DpcError::RaggedCoords { len, dim } => {
                write!(f, "coordinate buffer of length {len} is not divisible by dimension {dim}")
            }
            DpcError::NonFiniteCoordinate { point, dim } => {
                write!(f, "non-finite coordinate at point {point}, dimension {dim}")
            }
            DpcError::LossyCast { point, dim, value, dtype } => {
                write!(f, "coordinate {value} at point {point}, dimension {dim} is not exactly representable as {dtype}")
            }
            DpcError::UnsupportedDtype { tag } => {
                write!(f, "unsupported dtype tag {tag} (expected 4 = f32 or 8 = f64)")
            }
            DpcError::InvalidParam { name, value, requirement } => {
                write!(f, "invalid parameter {name} = {value}: {requirement}")
            }
            DpcError::MissingStage { need, call } => {
                write!(f, "`{call}` requires the `{need}` stage to have run first")
            }
            DpcError::UnknownSession(id) => write!(f, "unknown session {id}"),
            DpcError::Backend { engine, message } => write!(f, "{engine} backend: {message}"),
            DpcError::Io(e) => write!(f, "io: {e}"),
            DpcError::CorruptJournal { offset, detail } => {
                write!(f, "corrupt journal at byte {offset}: {detail}")
            }
            DpcError::CorruptCheckpoint { detail } => write!(f, "corrupt checkpoint: {detail}"),
            DpcError::CorruptManifest { detail } => write!(f, "corrupt manifest: {detail}"),
            DpcError::OversizedJournalEntry { len, max } => {
                write!(f, "journal entry payload of {len} bytes exceeds the frame format's maximum of {max}")
            }
            DpcError::DtypeMismatch { expected, got } => {
                write!(f, "dtype mismatch: stream is {expected}, batch is {got}")
            }
            DpcError::Backpressure { in_flight, limit } => {
                write!(f, "backpressure: {in_flight} jobs in flight at the admission limit of {limit}")
            }
            DpcError::QuotaExceeded { tenant, open, limit } => {
                write!(f, "tenant {tenant:?} already holds {open} open sessions at its quota of {limit}")
            }
        }
    }
}

impl std::error::Error for DpcError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DpcError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for DpcError {
    fn from(e: std::io::Error) -> Self {
        DpcError::Io(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let cases: Vec<(DpcError, &str)> = vec![
            (DpcError::EmptyInput, "empty"),
            (DpcError::DimensionMismatch { expected: 3, got: 2 }, "expected 3-d"),
            (DpcError::RaggedCoords { len: 7, dim: 2 }, "not divisible"),
            (DpcError::NonFiniteCoordinate { point: 4, dim: 1 }, "non-finite"),
            (DpcError::LossyCast { point: 2, dim: 0, value: 0.1, dtype: "f32" }, "not exactly representable"),
            (DpcError::UnsupportedDtype { tag: 3 }, "dtype tag 3"),
            (
                DpcError::InvalidParam { name: "d_cut", value: -1.0, requirement: "must be positive and finite" },
                "d_cut",
            ),
            (DpcError::MissingStage { need: "density", call: "cut" }, "density"),
            (DpcError::UnknownSession(9), "9"),
            (DpcError::Backend { engine: "xla".into(), message: "boom".into() }, "boom"),
            (DpcError::CorruptJournal { offset: 24, detail: "crc mismatch".into() }, "byte 24"),
            (DpcError::CorruptCheckpoint { detail: "truncated".into() }, "truncated"),
            (DpcError::CorruptManifest { detail: "offset past journal end".into() }, "manifest"),
            (DpcError::OversizedJournalEntry { len: 5_000_000_000, max: 4_294_967_295 }, "5000000000"),
            (DpcError::DtypeMismatch { expected: "f32", got: "f64" }, "stream is f32"),
            (DpcError::Backpressure { in_flight: 64, limit: 64 }, "64 jobs in flight"),
            (DpcError::QuotaExceeded { tenant: "acme".into(), open: 8, limit: 8 }, "acme"),
        ];
        for (e, needle) in cases {
            assert!(e.to_string().contains(needle), "{e}");
        }
    }

    #[test]
    fn io_error_preserves_source() {
        let e = DpcError::from(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
