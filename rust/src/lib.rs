//! # ParCluster-RS
//!
//! A parallel exact Density Peaks Clustering (DPC) library, reproducing
//! *"Faster Parallel Exact Density Peaks Clustering"* (Huang, Yu, Shun 2023)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! ## Layers
//!
//! - **L3 (this crate)** — the paper's contribution: parallel balanced
//!   kd-trees, the *priority search kd-tree*, the *Fenwick-tree-of-kd-trees*
//!   dependent-point finder, lock-free union-find single-linkage, plus the
//!   coordinator that routes clustering jobs between the tree engine and the
//!   AOT-compiled XLA brute-force engine.
//! - **L2** — `python/compile/model.py`: tensorized brute-force DPC in JAX,
//!   lowered once to HLO text under `artifacts/`.
//! - **L1** — `python/compile/kernels/pairwise.py`: the Pallas tiled
//!   pairwise-distance kernel feeding L2.
//!
//! ## Quickstart
//!
//! ```no_run
//! use parcluster::dpc::{DpcParams, Dpc, DepAlgo};
//! use parcluster::datasets::synthetic;
//!
//! let pts = synthetic::uniform(10_000, 2, 1000.0, 42);
//! let params = DpcParams { d_cut: 30.0, rho_min: 0.0, delta_min: 100.0, ..DpcParams::default() };
//! let out = Dpc::new(params).dep_algo(DepAlgo::Priority).run(&pts).expect("cluster");
//! println!("{} clusters, {} noise", out.num_clusters, out.num_noise);
//! ```
//!
//! For the iterative decision-graph workflow, hold a
//! [`dpc::ClusterSession`] instead: `build` once, then `density` →
//! `dependents` → `cut`, where re-cutting with new thresholds costs only the
//! union-find linkage step. For *growing* data, hold a
//! [`dpc::StreamingSession`]: `ingest` batches into a logarithmic kd-forest
//! that repairs (ρ, λ, δ) incrementally while staying byte-identical to a
//! from-scratch build on the concatenated points — then `cut` at any
//! thresholds. Malformed input surfaces as [`error::DpcError`], never a
//! panic.
//!
//! The data layer is **precision-generic**: [`geom::PointStore<S>`] holds
//! coordinates in one shared `Arc<[S]>` buffer (`S` = `f32` or `f64`, the
//! sealed [`geom::Scalar`] trait; `geom::PointSet` is the `f64` alias), and
//! the whole pipeline — trees, sessions, streams, engines — runs at either
//! precision. An f32 store halves coordinate bandwidth on the
//! memory-bound traversals and produces byte-identical results whenever
//! the data is f32-losslessly representable (see DESIGN.md §2b).
//!
//! Serve mode can run **durably**: with `--durable <dir>` the coordinator
//! write-ahead-journals every state-changing command and checkpoints live
//! stream/session state, so a crashed server restarts exactly where it
//! stopped ([`durability`], DESIGN.md §Durability).
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

// The crate's contracts are machine-checked: `pallas-lint` ([`lint`], run
// by CI) enforces the panic-surface / float-determinism / atomic-audit /
// wire-safety / SAFETY-comment rules statically, and these two lints keep
// the unsafe surface explicit and the public API debuggable.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(missing_debug_implementations)]

pub mod error;
pub mod parlay;
pub mod prng;
pub mod geom;
pub mod proputil;
pub mod kdtree;
pub mod pskd;
pub mod fenwick;
pub mod unionfind;
pub mod dpc;
pub mod datasets;
pub mod runtime;
pub mod coordinator;
pub mod durability;
pub mod serve;
pub mod bench;
pub mod cli;
pub mod metrics;
pub mod sync;
pub mod lint;

pub use error::DpcError;
