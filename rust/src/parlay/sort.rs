//! Parallel sorting: merge sort (comparison) and LSD radix sort (integer
//! keys; used for the density sort in Algorithm 2 line 9, which the paper
//! notes takes O(n) work because densities are bounded by n [53]).

use super::ops::{par_for_grained, par_map_grained};
use super::pool;

/// Parallel stable merge sort by a key function.
pub fn par_sort_by_key<T, K, F>(items: &mut [T], key: F)
where
    T: Send + Sync + Clone,
    K: Ord,
    F: Fn(&T) -> K + Sync,
{
    par_sort_unstable_by(items, |a, b| key(a).cmp(&key(b)));
}

/// Parallel sort with a comparator: chunk-sort then log-round pairwise merge.
/// (Merges within a round run in parallel across pairs; each merge is
/// sequential — adequate for the coarse-grained uses in this crate.)
pub fn par_sort_unstable_by<T, C>(items: &mut [T], cmp: C)
where
    T: Send + Sync + Clone,
    C: Fn(&T, &T) -> std::cmp::Ordering + Sync,
{
    let n = items.len();
    let threads = pool::num_threads();
    if threads == 1 || n < 4096 {
        items.sort_by(&cmp);
        return;
    }
    // Power-of-two chunk count so the pairwise merge rounds stay balanced;
    // ~4 chunks per worker gives the stealer slack on uneven comparators.
    let nchunks = (threads * 4).next_power_of_two();
    let chunk = n.div_ceil(nchunks);
    // Sort chunks in parallel. Split via chunks_mut to get disjoint &mut.
    {
        let chunks: Vec<&mut [T]> = items.chunks_mut(chunk).collect();
        let nreal = chunks.len();
        let ptrs: Vec<usize> = chunks.iter().map(|c| c.as_ptr() as usize).collect();
        let lens: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
        drop(chunks);
        par_for_grained(nreal, 1, |i| {
            // SAFETY: chunks are disjoint subslices of `items`.
            let s = unsafe { std::slice::from_raw_parts_mut(ptrs[i] as *mut T, lens[i]) };
            s.sort_by(&cmp);
        });
    }
    // Iterative pairwise merge rounds.
    let mut buf: Vec<T> = items.to_vec();
    let mut width = chunk;
    let mut src_is_items = true;
    while width < n {
        let (src, dst): (&[T], &mut [T]) = if src_is_items {
            // SAFETY: this round reads `items` and writes only `buf`; the
            // raw re-borrow just expresses that disjointness to the borrow
            // checker.
            (unsafe { std::slice::from_raw_parts(items.as_ptr(), n) }, &mut buf[..])
        } else {
            // SAFETY: mirror of the arm above — reads `buf`, writes only
            // `items`.
            (unsafe { std::slice::from_raw_parts(buf.as_ptr(), n) }, &mut items[..])
        };
        let dst_ptr = dst.as_mut_ptr() as usize;
        let npairs = n.div_ceil(2 * width);
        par_for_grained(npairs, 1, |p| {
            let lo = p * 2 * width;
            let mid = (lo + width).min(n);
            let hi = (lo + 2 * width).min(n);
            // SAFETY: [lo,hi) ranges are disjoint across p.
            let out = unsafe { std::slice::from_raw_parts_mut((dst_ptr as *mut T).add(lo), hi - lo) };
            merge_into(&src[lo..mid], &src[mid..hi], out, &cmp);
        });
        src_is_items = !src_is_items;
        width *= 2;
    }
    if !src_is_items {
        items.clone_from_slice(&buf);
    }
}

fn merge_into<T: Clone, C: Fn(&T, &T) -> std::cmp::Ordering>(a: &[T], b: &[T], out: &mut [T], cmp: &C) {
    debug_assert_eq!(a.len() + b.len(), out.len());
    let (mut i, mut j, mut k) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        if cmp(&a[i], &b[j]) != std::cmp::Ordering::Greater {
            out[k] = a[i].clone();
            i += 1;
        } else {
            out[k] = b[j].clone();
            j += 1;
        }
        k += 1;
    }
    while i < a.len() {
        out[k] = a[i].clone();
        i += 1;
        k += 1;
    }
    while j < b.len() {
        out[k] = b[j].clone();
        j += 1;
        k += 1;
    }
}

/// Parallel LSD radix sort of `(key, payload)` pairs by `key`, 8 bits per
/// round, skipping rounds where all keys share the digit. Stable.
pub fn par_radix_sort_u64(items: &mut Vec<(u64, u32)>) {
    let n = items.len();
    if n <= 1 {
        return;
    }
    let max_key = items.iter().map(|(k, _)| *k).max().unwrap_or(0);
    let rounds = if max_key == 0 { 1 } else { (64 - max_key.leading_zeros()).div_ceil(8) as usize };
    let threads = pool::num_threads();
    let nchunks = (threads * 2).max(1);
    let chunk = n.div_ceil(nchunks);
    // When n < nchunks·chunk, trailing chunks are empty and `c * chunk` can
    // exceed n — clamp BOTH bounds (an unclamped `lo` made `&items[lo..]`
    // panic for n < 2·threads, e.g. tiny conformance datasets under the
    // PALLAS_THREADS=8 CI leg).
    let mut buf: Vec<(u64, u32)> = vec![(0, 0); n];
    for r in 0..rounds {
        let shift = r * 8;
        // Per-chunk histograms. Grain 1: nchunks is a few heavy items, so
        // the auto grain's floor would collapse this loop to one sequential
        // task (matching the scatter loop below).
        let hists: Vec<[u32; 256]> = par_map_grained(nchunks, 1, |c| {
            let lo = (c * chunk).min(n);
            let hi = ((c + 1) * chunk).min(n);
            let mut h = [0u32; 256];
            for it in &items[lo..hi] {
                h[((it.0 >> shift) & 0xFF) as usize] += 1;
            }
            h
        });
        // Global digit offsets: for stability, order = digit-major then chunk.
        let mut offsets = vec![[0u32; 256]; nchunks];
        let mut run = 0u32;
        for d in 0..256 {
            for c in 0..nchunks {
                offsets[c][d] = run;
                run += hists[c][d];
            }
        }
        // Scatter.
        {
            let src = &*items;
            let dst = buf.as_mut_ptr() as usize;
            par_for_grained(nchunks, 1, |c| {
                let lo = (c * chunk).min(n);
                let hi = ((c + 1) * chunk).min(n);
                let mut offs = offsets[c];
                let dptr = dst as *mut (u64, u32);
                for it in &src[lo..hi] {
                    let d = ((it.0 >> shift) & 0xFF) as usize;
                    // SAFETY: offsets partition 0..n disjointly across
                    // (chunk, digit) pairs.
                    unsafe {
                        *dptr.add(offs[d] as usize) = *it;
                    }
                    offs[d] += 1;
                }
            });
        }
        std::mem::swap(items, &mut buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::SplitMix64;

    #[test]
    fn par_sort_matches_std() {
        let mut rng = SplitMix64::new(1);
        let mut v: Vec<u64> = (0..50_000).map(|_| rng.next_u64() % 10_000).collect();
        let mut expect = v.clone();
        expect.sort();
        par_sort_unstable_by(&mut v, |a, b| a.cmp(b));
        assert_eq!(v, expect);
    }

    #[test]
    fn par_sort_small_and_empty() {
        let mut v: Vec<u32> = vec![];
        par_sort_unstable_by(&mut v, |a, b| a.cmp(b));
        let mut v = vec![3u32, 1, 2];
        par_sort_unstable_by(&mut v, |a, b| a.cmp(b));
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    fn radix_sort_matches_std_and_is_stable() {
        let mut rng = SplitMix64::new(7);
        let mut v: Vec<(u64, u32)> = (0..30_000).map(|i| (rng.next_u64() % 512, i as u32)).collect();
        let mut expect = v.clone();
        expect.sort_by_key(|&(k, id)| (k, id)); // stability => id order within key
        par_radix_sort_u64(&mut v);
        assert_eq!(v, expect);
    }

    #[test]
    fn radix_sort_large_keys() {
        let mut rng = SplitMix64::new(9);
        let mut v: Vec<(u64, u32)> = (0..10_000).map(|i| (rng.next_u64(), i as u32)).collect();
        let mut expect = v.clone();
        expect.sort();
        par_radix_sort_u64(&mut v);
        assert_eq!(v, expect);
    }

    #[test]
    fn radix_sort_all_equal() {
        let mut v: Vec<(u64, u32)> = (0..100).map(|i| (42, i as u32)).collect();
        let expect = v.clone();
        par_radix_sort_u64(&mut v);
        assert_eq!(v, expect);
    }
}
