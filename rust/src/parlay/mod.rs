//! ParlayLib-style parallel primitives on top of `std::thread`.
//!
//! The paper's implementation uses ParlayLib [9] (fork-join work stealing,
//! parallel loops, sorts, and priority concurrent writes). Neither ParlayLib
//! nor rayon is available in this offline image, so this module rebuilds the
//! required subset from scratch:
//!
//! - [`pool`]: a randomized work-stealing fork-join scheduler — per-worker
//!   Chase–Lev deques (LIFO local push/pop, FIFO steals), a global injector
//!   for external submissions and overflow, parking for idle workers, and
//!   *help-first* joins (a blocked joiner executes pending tasks instead of
//!   sleeping, so nested parallelism — e.g. the recursive kd-tree build —
//!   cannot deadlock). Design notes: DESIGN.md §Scheduler.
//! - [`ops`]: `par_for`, `par_map`, `par_reduce`, `par_scan` (prefix sums),
//!   `par_filter`/`pack`, and the paper's `WRITE-MIN` priority concurrent
//!   write [60]. Loops split eagerly down to a grain auto-tuned from the
//!   pool's thread count ([`ops::auto_grain`]); pass an explicit grain for
//!   skewed or expensive per-index work.
//! - [`sort`]: parallel merge sort and a parallel LSD radix sort (used for
//!   the density sort in `FENWICK-DEPENDENT-POINT`, Algorithm 2 line 9).
//!
//! All primitives degrade to deterministic sequential code when the pool has
//! a single thread (`PALLAS_THREADS=1`), and every *use in this crate*
//! produces thread-count independent output: per-index loop bodies are pure,
//! scans are exact integer math, sorts are stable, and concurrent
//! minima/unions are order-independent or canonicalized — the stress suite
//! (`rust/tests/parlay_stress.rs`) and the conformance suite pin this. (The
//! primitives alone do not guarantee it: an auto-tuned grain varies with the
//! configured thread count, so a chunk-order-sensitive float reduction would
//! need an explicit grain — see [`ops::par_for_grained`].)

pub mod pool;
pub mod ops;
pub mod sort;

pub use ops::{
    auto_grain, par_chunks, par_filter, par_for, par_for_grained, par_map, par_map_grained,
    par_reduce, par_scan_add, WriteMinF64, WriteMinPair,
};
pub use pool::{num_threads, set_threads, Pool};
pub use sort::{par_radix_sort_u64, par_sort_by_key, par_sort_unstable_by};
