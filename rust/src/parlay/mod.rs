//! ParlayLib-style parallel primitives on top of `std::thread`.
//!
//! The paper's implementation uses ParlayLib [9] (fork-join work stealing,
//! parallel loops, sorts, and priority concurrent writes). Neither ParlayLib
//! nor rayon is available in this offline image, so this module rebuilds the
//! required subset from scratch:
//!
//! - [`pool`]: a fork-join thread pool with *help-first* joins (a blocked
//!   joiner executes queued tasks instead of sleeping, so nested parallelism
//!   — e.g. the recursive kd-tree build — cannot deadlock).
//! - [`ops`]: `par_for`, `par_map`, `par_reduce`, `par_scan` (prefix sums),
//!   `par_filter`/`pack`, and the paper's `WRITE-MIN` priority concurrent
//!   write [60].
//! - [`sort`]: parallel merge sort and a parallel LSD radix sort (used for
//!   the density sort in `FENWICK-DEPENDENT-POINT`, Algorithm 2 line 9).
//!
//! All primitives degrade to efficient sequential code when the pool has a
//! single thread (the container this repo was built in has one core; see
//! `EXPERIMENTS.md` §Threads for how parallel scalability is evidenced).

pub mod pool;
pub mod ops;
pub mod sort;

pub use ops::{par_for, par_for_grained, par_map, par_reduce, par_scan_add, par_filter, WriteMinF64, WriteMinPair};
pub use pool::{Pool, set_threads, num_threads};
pub use sort::{par_sort_by_key, par_radix_sort_u64, par_sort_unstable_by};
