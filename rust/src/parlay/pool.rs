//! Work-stealing fork-join scheduler (DESIGN.md §Scheduler).
//!
//! ParlayLib-style runtime underneath every parallel primitive in this crate.
//! The span bounds of the paper's algorithms (O(log n log log n) for Step 2)
//! assume a randomized work-stealing scheduler; the previous implementation —
//! a single mutex-guarded FIFO queue — serialized every `join` on one lock.
//! This version is the real thing:
//!
//! - **Per-worker Chase–Lev deques.** Each worker owns a bounded lock-free
//!   deque: it pushes and pops forked tasks LIFO at the bottom (preserving
//!   the sequential execution order, so working sets stay cache-hot), while
//!   thieves steal FIFO from the top (taking the *oldest* — i.e. biggest —
//!   subtree of the recursion, which minimizes steal frequency). Orderings
//!   follow Lê, Pop, Cohen, Zappa Nardelli, "Correct and Efficient
//!   Work-Stealing for Weak Memory Models" (PPoPP '13).
//! - **Global injector.** External threads (anything that is not a pool
//!   worker, e.g. the coordinator's job threads) submit through a
//!   mutex-guarded injector queue; workers drain it when their own deque is
//!   empty. Deque overflow also spills here, so pushes never block.
//! - **Randomized stealing with backoff.** An out-of-work worker scans the
//!   injector then sweeps victims starting at a random offset; failed sweeps
//!   back off exponentially (spin, then yield) before re-scanning.
//! - **Parking.** After repeated empty sweeps a worker sleeps on a condvar
//!   instead of burning a core. The epoch-counter protocol in [`Sleep`]
//!   makes lost wakeups impossible (proof at [`Shared::unpark_one`]).
//! - **Help-first joins.** `join(a, b)` forks `b`, runs `a` inline, then — if
//!   `b` was stolen — *executes other pending tasks* while waiting instead of
//!   blocking. Every thread waiting on a join is therefore still a worker, so
//!   nested fork-join (the recursive kd-tree builds) cannot deadlock at any
//!   worker count: a task's fork is always runnable by *someone*, including
//!   the joiner itself.
//! - **Panic propagation.** Both sides of a `join` run under `catch_unwind`:
//!   a panicking forked task still reaches its DONE state (no hung joiner,
//!   no dead worker), `join` always waits for the forked task before
//!   unwinding (its closure borrows the joiner's stack), and the panic
//!   resurfaces at the joiner via `resume_unwind`.
//! - **Deterministic single-thread mode.** `threads == 1` spawns no workers
//!   and runs both sides of every `join` inline in program order — bit-exact
//!   reproducible scheduling for tests (`PALLAS_THREADS=1`).

use std::cell::{Cell, UnsafeCell};
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{fence, AtomicBool, AtomicIsize, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, OnceLock};
use std::thread;

use crate::sync::{rank, OrderedMutex, OrderedRwLock};

// ---------------------------------------------------------------------------
// Tasks
// ---------------------------------------------------------------------------

const PENDING: u8 = 0;
const RUNNING: u8 = 1;
const DONE: u8 = 2;

/// A unit of forked work. The closure is type- and lifetime-erased; safety
/// relies on `join` not returning until the task has run (see the safety
/// discussion in [`Pool::join`]).
///
/// Claiming is a `PENDING -> RUNNING` CAS on `state`, so a task is executed
/// exactly once no matter how many hands it passes through (own deque, a
/// thief, the injector after an overflow spill).
struct Task {
    state: AtomicU8,
    func: UnsafeCell<Option<Box<dyn FnOnce() + Send + 'static>>>,
}

// SAFETY: `func` is only accessed by the single thread that wins the
// PENDING -> RUNNING CAS in `run`; every other thread only touches `state`.
unsafe impl Sync for Task {}

impl Task {
    fn new(f: Box<dyn FnOnce() + Send + 'static>) -> Arc<Self> {
        Arc::new(Task { state: AtomicU8::new(PENDING), func: UnsafeCell::new(Some(f)) })
    }

    /// Attempt to claim and run the task. Returns true iff this call ran it.
    ///
    /// Ordering audit: success ordering is `Acquire` so the claimer observes
    /// the closure written before the task was published (the publish edge
    /// itself is the deque's `bottom` Release store or the injector mutex;
    /// the Acquire here additionally orders any re-claim attempt after a
    /// failed one). Failure ordering `Relaxed`: a loser takes no action that
    /// depends on the task's contents.
    fn run(&self) -> bool {
        // relaxed: failure ordering only — a loser takes no action that
        // depends on the task's contents (full audit in the doc above).
        if self.state.compare_exchange(PENDING, RUNNING, Ordering::Acquire, Ordering::Relaxed).is_err() {
            return false;
        }
        // SAFETY: winning the CAS grants exclusive access to `func`.
        // lint: allow(panic-surface) — a claimed task always carries its
        // closure: `func` is taken exactly once, by the unique CAS winner.
        let f = unsafe { (*self.func.get()).take() }.expect("claimed task has a closure");
        f();
        // Release: everything the closure wrote (e.g. the join's result slot)
        // happens-before a joiner's Acquire load that observes DONE.
        self.state.store(DONE, Ordering::Release);
        true
    }

    fn is_done(&self) -> bool {
        self.state.load(Ordering::Acquire) == DONE
    }
}

// ---------------------------------------------------------------------------
// Chase–Lev deque
// ---------------------------------------------------------------------------

/// Slots per worker deque (power of two). Outstanding tasks per worker are
/// O(fork depth) — one per live `join` frame — so 1024 is far above any real
/// recursion in this crate; on overflow the push spills to the injector, so
/// capacity is a performance knob, never a correctness one.
const DEQUE_CAP: usize = 1024;

enum Steal {
    Empty,
    Retry,
    Task(Arc<Task>),
}

/// Bounded Chase–Lev work-stealing deque of `Arc<Task>`.
///
/// The owner pushes and pops at `bottom` (LIFO); thieves CAS `top` upward
/// (FIFO). Slots store `Arc::into_raw` pointers as `usize`; each index in
/// `top..bottom` is handed to exactly one consumer (the owner's pop or the
/// unique thief that wins the `top` CAS), which takes over the refcount.
///
/// Ordering audit (PPoPP '13, Fig. 1, adapted to a fixed ring):
/// - `push` publishes the slot write with a Release store of `bottom`;
///   thieves read `bottom` with Acquire, so a stolen slot's contents (and the
///   closure behind the pointer) are visible.
/// - `pop` decrements `bottom` then issues a SeqCst fence before reading
///   `top`: the decrement must be globally visible before the owner decides
///   the deque is non-empty, or owner and thief could both take the last
///   element. The thief's symmetric SeqCst fence sits between its `top` and
///   `bottom` loads.
/// - Both "take the last element" CASes on `top` are SeqCst, forming a total
///   order that arbitrates the owner/thief race.
struct Deque {
    top: AtomicIsize,
    bottom: AtomicIsize,
    slots: Box<[AtomicUsize]>,
}

impl Deque {
    fn new() -> Self {
        let slots: Vec<AtomicUsize> = (0..DEQUE_CAP).map(|_| AtomicUsize::new(0)).collect();
        Deque { top: AtomicIsize::new(0), bottom: AtomicIsize::new(0), slots: slots.into_boxed_slice() }
    }

    #[inline]
    fn slot(&self, i: isize) -> &AtomicUsize {
        &self.slots[(i as usize) & (DEQUE_CAP - 1)]
    }

    /// Owner-only. Returns the task back on overflow (caller spills it to the
    /// injector). Never overwrites an unconsumed slot: the fullness check
    /// against `top` guarantees writes stay ≥ DEQUE_CAP ahead of any index a
    /// thief could still claim.
    fn push(&self, task: Arc<Task>) -> Result<(), Arc<Task>> {
        // relaxed: owner-only read of our own last `bottom` store.
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        if b - t >= DEQUE_CAP as isize {
            return Err(task);
        }
        // relaxed: the Release store of `bottom` below publishes this slot.
        self.slot(b).store(Arc::into_raw(task) as usize, Ordering::Relaxed);
        self.bottom.store(b + 1, Ordering::Release);
        Ok(())
    }

    /// Owner-only LIFO pop.
    fn pop(&self) -> Option<Arc<Task>> {
        // relaxed: owner-only read of our own `bottom`.
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        // relaxed: the SeqCst fence below globally orders this decrement.
        self.bottom.store(b, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        // relaxed: ordered against thieves by the fence above.
        let t = self.top.load(Ordering::Relaxed);
        if t > b {
            // Deque was empty; restore bottom.
            // relaxed: owner-only restore; nothing is published.
            self.bottom.store(b + 1, Ordering::Relaxed);
            return None;
        }
        // relaxed: the slot write is ours (owner) or claimed via the `top`
        // CAS arbitration below before the pointer is consumed.
        let raw = self.slot(b).load(Ordering::Relaxed) as *const Task;
        if t == b {
            // Last element: race thieves for it via `top`.
            // relaxed: CAS failure means a thief won; we take no action
            // that depends on the failed value.
            let won = self.top.compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed).is_ok();
            // relaxed: owner-only restore of `bottom`.
            self.bottom.store(b + 1, Ordering::Relaxed);
            if !won {
                // The winning thief owns the refcount at this index.
                return None;
            }
        }
        // SAFETY: either b > t (thieves can never advance `top` to `b`
        // because they observe our decremented `bottom` after the fences), or
        // we won the CAS above — both make us the unique consumer of index b.
        Some(unsafe { Arc::from_raw(raw) })
    }

    /// Any thread. FIFO steal from the top.
    fn steal(&self) -> Steal {
        let t = self.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t >= b {
            return Steal::Empty;
        }
        // relaxed: the SeqCst CAS below is the real claim; a stale read
        // here is discarded unconsumed on CAS failure.
        let raw = self.slot(t).load(Ordering::Relaxed) as *const Task;
        // relaxed: failure ordering — the loser discards `raw` untouched.
        if self.top.compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed).is_err() {
            // Lost the race (to the owner's pop of a last element or another
            // thief). `raw` may be stale — discard it unconsumed.
            return Steal::Retry;
        }
        // SAFETY: winning the CAS at `t` makes us the unique consumer of that
        // index. The owner cannot have overwritten the slot: a colliding push
        // requires bottom - top >= DEQUE_CAP, which push refuses, so any
        // overwrite implies `top` already moved past `t` — and then our CAS
        // would have failed.
        Steal::Task(unsafe { Arc::from_raw(raw) })
    }
}

impl Drop for Deque {
    fn drop(&mut self) {
        // `&mut self`: no concurrent owner or thieves. Reclaim unconsumed
        // refcounts (possible only if the pool is torn down with tasks never
        // joined — defensive; join semantics prevent it in practice).
        let t = *self.top.get_mut();
        let b = *self.bottom.get_mut();
        for i in t..b {
            // relaxed: `&mut self` — no concurrent access remains.
            let raw = self.slots[(i as usize) & (DEQUE_CAP - 1)].load(Ordering::Relaxed) as *const Task;
            // SAFETY: indices in top..bottom each still own one refcount.
            drop(unsafe { Arc::from_raw(raw) });
        }
    }
}

// ---------------------------------------------------------------------------
// Shared pool state, parking, and the worker loop
// ---------------------------------------------------------------------------

/// Parking state. Workers sleep here after `PARK_AFTER_SCANS` empty sweeps.
///
/// The protocol is an eventcount: `epoch` is bumped on every wake signal, and
/// a worker only commits to sleeping if the epoch has not moved since *before*
/// its last (failed) scan for work. See [`Shared::unpark_one`] for the
/// lost-wakeup proof.
struct Sleep {
    /// Rank [`rank::POOL_PARKING`] — the maximum rank in the table:
    /// parking is a leaf, nothing is ever acquired while it is held.
    lock: OrderedMutex<(), { rank::POOL_PARKING }>,
    cv: Condvar,
    epoch: AtomicUsize,
    sleepers: AtomicUsize,
}

/// Empty find_task sweeps (with exponential spin/yield backoff between them)
/// before a worker parks.
const PARK_AFTER_SCANS: u32 = 16;

struct Shared {
    /// One deque per spawned worker (the external caller has none and uses
    /// the injector).
    deques: Box<[Deque]>,
    /// External submissions and deque-overflow spill. Rank
    /// [`rank::POOL_INJECTOR`]: jobs fork while holding coordinator locks,
    /// so the injector sits above the whole coordinator band and below
    /// only the parking lock.
    injector: OrderedMutex<VecDeque<Arc<Task>>, { rank::POOL_INJECTOR }>,
    /// Mirror of `injector.len()`, maintained under the injector lock and
    /// read without it: lets the (very hot) empty-injector path of
    /// `find_task` skip the mutex entirely, so spinning workers/joiners
    /// don't serialize on it. Approximate by design — a racing push is
    /// discovered on the next scan, and the pusher's epoch bump prevents a
    /// parked miss.
    injector_len: AtomicUsize,
    sleep: Sleep,
    shutdown: AtomicBool,
    /// Total parallelism (workers + the participating caller).
    threads: usize,
}

impl Shared {
    /// Append to the injector (external submission or deque-overflow spill).
    fn inject(&self, t: Arc<Task>) {
        let mut q = self.injector.lock();
        q.push_back(t);
        // relaxed: approximate mirror; see the field's audit note.
        self.injector_len.store(q.len(), Ordering::Relaxed);
    }

    /// Find one runnable task: own deque (LIFO), then the injector, then
    /// randomized steal sweeps over the other workers' deques.
    fn find_task(&self, me: Option<usize>, rng: &mut u64) -> Option<Arc<Task>> {
        if let Some(i) = me {
            if let Some(t) = self.deques[i].pop() {
                return Some(t);
            }
        }
        // relaxed: approximate fast-path read — a racing push is found on
        // the next scan, and the pusher's epoch bump prevents a parked miss.
        if self.injector_len.load(Ordering::Relaxed) > 0 {
            let mut q = self.injector.lock();
            let t = q.pop_front();
            // relaxed: mirror maintained under the injector lock.
            self.injector_len.store(q.len(), Ordering::Relaxed);
            if let Some(t) = t {
                return Some(t);
            }
        }
        let n = self.deques.len();
        if n == 0 {
            return None;
        }
        // Up to 4 sweeps; keep sweeping only while some victim said Retry
        // (a racing operation we may be able to win next time around).
        for _ in 0..4 {
            let start = (xorshift(rng) as usize) % n;
            let mut saw_retry = false;
            for k in 0..n {
                let v = (start + k) % n;
                if Some(v) == me {
                    continue;
                }
                match self.deques[v].steal() {
                    Steal::Task(t) => return Some(t),
                    Steal::Retry => saw_retry = true,
                    Steal::Empty => {}
                }
            }
            if !saw_retry {
                break;
            }
        }
        None
    }

    /// Wake (at most) one parked worker because one task became available.
    ///
    /// Lost-wakeup proof sketch: the bump of `epoch` comes FIRST, and both it
    /// and the parking worker's re-check are SeqCst, so they share one total
    /// order. If a worker commits to sleeping (re-check saw the old epoch),
    /// its re-check precedes our bump in that order; its `sleepers` increment
    /// precedes its re-check; therefore our `sleepers` load (after the bump)
    /// observes it and we take the lock and notify. Conversely if the worker
    /// observes the bumped epoch it aborts the park and re-scans — and the
    /// task was already published before `unpark_one` was called. The lock is
    /// held while notifying so the signal cannot fire between the re-check
    /// and the `Condvar::wait` (the parker holds the lock across that span).
    fn unpark_one(&self) {
        self.sleep.epoch.fetch_add(1, Ordering::SeqCst);
        if self.sleep.sleepers.load(Ordering::SeqCst) > 0 {
            let _g = self.sleep.lock.lock();
            self.sleep.cv.notify_one();
        }
    }

    /// Wake every parked worker (shutdown).
    fn wake_all(&self) {
        self.sleep.epoch.fetch_add(1, Ordering::SeqCst);
        let _g = self.sleep.lock.lock();
        self.sleep.cv.notify_all();
    }
}

thread_local! {
    /// (address of the `Shared` this thread is a worker of, worker index).
    /// The address cannot be stale-reused while the thread lives: each worker
    /// holds an `Arc<Shared>` for its entire lifetime.
    static WORKER: Cell<(usize, usize)> = const { Cell::new((0, usize::MAX)) };
}

#[inline]
fn xorshift(s: &mut u64) -> u64 {
    let mut x = *s;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *s = x;
    x
}

fn worker_loop(shared: &Arc<Shared>, idx: usize) {
    WORKER.with(|w| w.set((Arc::as_ptr(shared) as usize, idx)));
    let mut rng = 0x9E37_79B9_7F4A_7C15u64 ^ ((idx as u64 + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9));
    let mut idle: u32 = 0;
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        // Epoch is sampled BEFORE the scan: if a task is published after the
        // scan misses it, the publisher's epoch bump makes the park abort.
        let epoch = shared.sleep.epoch.load(Ordering::SeqCst);
        if let Some(t) = shared.find_task(Some(idx), &mut rng) {
            idle = 0;
            t.run();
            continue;
        }
        idle += 1;
        if idle < PARK_AFTER_SCANS {
            // Exponential backoff: spin briefly, then start yielding.
            for _ in 0..(1u32 << idle.min(6)) {
                std::hint::spin_loop();
            }
            if idle > 4 {
                thread::yield_now();
            }
            continue;
        }
        idle = 0;
        // Park. Order matters: advertise sleeper intent, then re-check the
        // epoch under the lock (see unpark_one).
        shared.sleep.sleepers.fetch_add(1, Ordering::SeqCst);
        let guard = shared.sleep.lock.lock();
        if shared.sleep.epoch.load(Ordering::SeqCst) == epoch && !shared.shutdown.load(Ordering::Acquire) {
            drop(guard.wait(&shared.sleep.cv));
        } else {
            drop(guard);
        }
        shared.sleep.sleepers.fetch_sub(1, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// Pool
// ---------------------------------------------------------------------------

/// A work-stealing fork-join pool. See module docs.
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool")
            .field("threads", &self.shared.threads)
            .field("workers", &self.handles.len())
            .finish_non_exhaustive()
    }
}

/// Worker stack size: the kd-tree/pskd builds and deep help-first chains
/// recurse; match the default main-thread stack instead of the 2 MiB thread
/// default.
const WORKER_STACK: usize = 8 << 20;

impl Pool {
    /// Create a pool with `threads` total parallelism (including the caller).
    /// `threads == 1` is the deterministic sequential mode: no workers are
    /// spawned and `join` runs both closures inline in program order.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let nworkers = threads - 1;
        let shared = Arc::new(Shared {
            deques: (0..nworkers).map(|_| Deque::new()).collect::<Vec<_>>().into_boxed_slice(),
            injector: OrderedMutex::new(VecDeque::new()),
            injector_len: AtomicUsize::new(0),
            sleep: Sleep {
                lock: OrderedMutex::new(()),
                cv: Condvar::new(),
                epoch: AtomicUsize::new(0),
                sleepers: AtomicUsize::new(0),
            },
            shutdown: AtomicBool::new(false),
            threads,
        });
        let handles = (0..nworkers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("parlay-{i}"))
                    .stack_size(WORKER_STACK)
                    .spawn(move || worker_loop(&sh, i))
                    // lint: allow(panic-surface) — thread spawn failing at
                    // pool construction is unrecoverable resource exhaustion.
                    .expect("spawn worker")
            })
            .collect();
        Pool { shared, handles }
    }

    /// Total parallelism of this pool (worker threads + caller).
    pub fn threads(&self) -> usize {
        self.shared.threads
    }

    /// This thread's worker index in `self`, if it is one of our workers.
    fn worker_index(&self) -> Option<usize> {
        let (addr, idx) = WORKER.with(|w| w.get());
        if addr == Arc::as_ptr(&self.shared) as usize && idx < self.shared.deques.len() {
            Some(idx)
        } else {
            None
        }
    }

    /// Fork `task`: workers push onto their own deque (LIFO end), external
    /// threads and deque overflow go through the injector.
    fn push_task(&self, task: Arc<Task>) {
        let spilled = match self.worker_index() {
            Some(i) => self.shared.deques[i].push(task).err(),
            None => Some(task),
        };
        if let Some(t) = spilled {
            self.shared.inject(t);
        }
        self.shared.unpark_one();
    }

    /// Help-first wait: execute other pending tasks until `task` completes.
    fn help_until(&self, task: &Task) {
        let me = self.worker_index();
        let mut rng = 0xD1B5_4A32_D192_ED03u64 ^ (task as *const Task as usize as u64);
        let mut idle: u32 = 0;
        while !task.is_done() {
            if let Some(t) = self.shared.find_task(me, &mut rng) {
                t.run();
                idle = 0;
            } else {
                // Nothing to help with: the task is running elsewhere. Spin
                // with backoff — never park, completion is imminent and there
                // is no wake signal tied to a specific task.
                idle = (idle + 1).min(10);
                for _ in 0..(1u32 << idle.min(6)) {
                    std::hint::spin_loop();
                }
                if idle > 3 {
                    thread::yield_now();
                }
            }
        }
    }

    /// Run `a` and `b`, potentially in parallel. Both have completed when
    /// this returns.
    ///
    /// # Safety discussion
    /// The closures may borrow from the caller's stack (they are not
    /// `'static`). This is sound for the same reason `std::thread::scope` is:
    /// `join` does not return until `b` has finished executing, so no borrow
    /// outlives its referent. The lifetime erasure below is confined to this
    /// function.
    pub fn join<'a, RA, RB>(
        &self,
        a: impl FnOnce() -> RA + Send + 'a,
        b: impl FnOnce() -> RB + Send + 'a,
    ) -> (RA, RB)
    where
        RA: Send + 'a,
        RB: Send + 'a,
    {
        if self.shared.threads == 1 {
            // Deterministic sequential mode.
            return (a(), b());
        }
        // Unwind safety: both closures run under `catch_unwind` so that
        // (1) a panicking forked task still reaches DONE — a joiner spinning
        //     on `is_done` would otherwise hang forever, and the panic would
        //     kill the worker thread that happened to steal the task;
        // (2) a panic in `a` cannot unwind out of `join` while the
        //     lifetime-erased task still holds borrows into this stack frame
        //     — we always wait for `b` before resuming the panic.
        let mut rb: Option<std::thread::Result<RB>> = None;
        // Raw pointer (not a borrow) so `rb` stays movable after the task
        // finishes; Send-wrapped for the closure.
        struct SendPtr<T>(*mut T);
        // SAFETY: the pointer targets `rb` on the joiner's stack, which
        // outlives the task (`join` does not return until the task is
        // done), and exactly one thread — the task's runner — writes it.
        unsafe impl<T> Send for SendPtr<T> {}
        let rb_ptr = SendPtr(&mut rb as *mut Option<std::thread::Result<RB>>);
        let bf: Box<dyn FnOnce() + Send + 'a> = Box::new(move || {
            let rb_ptr = rb_ptr;
            let r = catch_unwind(AssertUnwindSafe(b));
            // SAFETY: `rb` outlives the task (join blocks until done).
            unsafe {
                *rb_ptr.0 = Some(r);
            }
        });
        // SAFETY: the task is fully executed before `join` returns; all
        // captured borrows live at least that long because we do not return
        // until `task.is_done()` (or we ran it ourselves).
        let bf: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(bf) };
        let task = Task::new(bf);
        self.push_task(Arc::clone(&task));
        let ra = catch_unwind(AssertUnwindSafe(a));
        // Fast path: `b` is usually still at the bottom of our own deque
        // (LIFO — everything `a` forked has been consumed by the nesting
        // discipline), so pop it and run it inline.
        match self.worker_index() {
            Some(i) => match self.shared.deques[i].pop() {
                Some(t) if Arc::ptr_eq(&t, &task) => {
                    t.run();
                }
                Some(t) => {
                    // `b` is elsewhere (stolen, or spilled to the injector on
                    // overflow), so the bottom held an *ancestor* join's
                    // fork. Re-pushing restores it to exactly the position it
                    // was popped from; the epoch bump upholds the "every
                    // publication wakes a sleeper" invariant (a worker that
                    // parked during the pop→push window would otherwise
                    // sleep through a stealable fork). Then help until `b`
                    // completes.
                    if let Err(t) = self.shared.deques[i].push(t) {
                        self.shared.inject(t);
                    }
                    self.shared.unpark_one();
                    self.help_until(&task);
                }
                None => self.help_until(&task),
            },
            // External joiner: `b` went through the injector; help (the scan
            // checks the injector first, so we usually run `b` ourselves).
            None => {
                if !task.run() {
                    self.help_until(&task);
                }
            }
        }
        debug_assert!(task.is_done());
        // lint: allow(panic-surface) — `b` runs under catch_unwind and
        // always stores a result before DONE; reaching here without one is
        // a scheduler bug worth dying loudly on.
        let rb = rb.expect("join: task b did not produce a result");
        match (ra, rb) {
            (Ok(ra), Ok(rb)) => (ra, rb),
            // `a`'s panic wins if both sides panicked (its payload is the one
            // a sequential execution would have surfaced first).
            (Err(p), _) | (Ok(_), Err(p)) => resume_unwind(p),
        }
    }

    /// Eager binary splitting of `[lo, hi)` down to `grain`-sized chunks,
    /// each processed by `f(chunk_lo, chunk_hi)`. Splits are forked
    /// unconditionally (not steal-triggered), so for a *fixed* grain the
    /// chunk boundaries are independent of how many workers show up or what
    /// gets stolen. Note the caveat: a grain *derived from the thread count*
    /// (`ops::auto_grain`) changes boundaries when `set_threads` does —
    /// callers whose output depends on chunk-local association order (e.g.
    /// float reductions) must pass an explicit grain.
    pub fn for_range<'a, F>(&self, lo: usize, hi: usize, grain: usize, f: &F)
    where
        F: Fn(usize, usize) + Sync + 'a,
    {
        debug_assert!(grain >= 1);
        if hi <= lo {
            return;
        }
        if self.shared.threads == 1 || hi - lo <= grain {
            f(lo, hi);
            return;
        }
        let mid = lo + (hi - lo) / 2;
        self.join(|| self.for_range(lo, mid, grain, f), || self.for_range(mid, hi, grain, f));
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // Release pairs with the workers' Acquire loads of `shutdown`.
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.wake_all();
        let me = thread::current().id();
        for h in self.handles.drain(..) {
            // The last `Arc<Pool>` can legally be dropped *on one of this
            // pool's own workers*: a task body that cloned the global pool
            // (nested ops) and raced a `set_threads` swap. Joining our own
            // thread would deadlock — detach it instead (it exits on its own
            // via the shutdown flag) and join the rest.
            if h.thread().id() == me {
                drop(h);
            } else {
                let _ = h.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Global pool management
// ---------------------------------------------------------------------------

/// Rank [`rank::POOL_REGISTRY`]: read on every `ops` entry point (under
/// whatever coordinator locks the caller already holds), written only by
/// [`set_threads`] — and never held across worker shutdown.
static GLOBAL: OnceLock<OrderedRwLock<Arc<Pool>, { rank::POOL_REGISTRY }>> = OnceLock::new();
static OVERRIDE_THREADS: AtomicUsize = AtomicUsize::new(0);

fn global_cell() -> &'static OrderedRwLock<Arc<Pool>, { rank::POOL_REGISTRY }> {
    GLOBAL.get_or_init(|| OrderedRwLock::new(Arc::new(Pool::new(default_threads()))))
}

/// The thread-count environment override, if set: `PALLAS_THREADS` (the
/// documented knob — CI's thread matrix sets it), falling back to the legacy
/// `PARCLUSTER_THREADS`. Single source of truth for the parse policy —
/// unparsable values are ignored, parsed values clamp to ≥ 1 — so every
/// reader (this pool's default, the coordinator config's env override)
/// agrees on what a given value means.
pub fn env_threads() -> Option<usize> {
    for var in ["PALLAS_THREADS", "PARCLUSTER_THREADS"] {
        if let Ok(v) = std::env::var(var) {
            if let Ok(n) = v.parse::<usize>() {
                return Some(n.max(1));
            }
        }
    }
    None
}

fn default_threads() -> usize {
    // relaxed: plain configuration cell; the pool swap that accompanies a
    // change synchronizes through the registry rwlock.
    let ov = OVERRIDE_THREADS.load(Ordering::Relaxed);
    if ov > 0 {
        return ov;
    }
    if let Some(n) = env_threads() {
        return n;
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The global pool used by all `parlay::ops` entry points.
pub fn global() -> Arc<Pool> {
    Arc::clone(&global_cell().read())
}

/// Resize the global pool to `t` threads. Safe at any time, including while
/// parallel work is in flight: operations hold an `Arc` to the pool they
/// started on and run to completion there; the old pool's workers shut down
/// when its last reference drops. A no-op if the size already matches.
pub fn set_threads(t: usize) {
    let t = t.max(1);
    // relaxed: see `default_threads` — the registry rwlock is the sync edge.
    OVERRIDE_THREADS.store(t, Ordering::Relaxed);
    if global_cell().read().threads() == t {
        return;
    }
    // Spawn the replacement pool BEFORE taking the write lock — thread
    // creation is milliseconds of syscalls that must not stall every
    // `global()` reader — then swap under the lock, re-checking the size in
    // case a racing resize won.
    let fresh = Arc::new(Pool::new(t));
    let mut g = global_cell().write();
    if g.threads() == t {
        drop(g);
        return; // raced: discard `fresh` (its workers shut down on drop)
    }
    let old = std::mem::replace(&mut *g, fresh);
    drop(g);
    // Drop (and possibly join) the old pool outside the lock so readers are
    // never blocked behind worker shutdown.
    drop(old);
}

/// Current global parallelism.
pub fn num_threads() -> usize {
    global().threads()
}

/// Serializes unit tests (within this crate's test binary) that mutate the
/// global pool via [`set_threads`]: results are thread-count independent by
/// design, but a test asserting a specific `num_threads()` must not race a
/// neighbor's resize. Lock with
/// `.lock().unwrap_or_else(|e| e.into_inner())` so a panicking test does not
/// poison the rest.
#[cfg(test)]
pub(crate) static TEST_POOL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Mutex;

    /// Sizes shrink under miri (it interprets every instruction).
    const fn sz(real: usize, miri: usize) -> usize {
        if cfg!(miri) {
            miri
        } else {
            real
        }
    }

    #[test]
    fn join_returns_both_results() {
        let p = Pool::new(4);
        let (a, b) = p.join(|| 1 + 1, || "two");
        assert_eq!(a, 2);
        assert_eq!(b, "two");
    }

    #[test]
    fn join_borrows_stack_data() {
        let p = Pool::new(4);
        let data = vec![1u64, 2, 3, 4];
        let (s1, s2) = p.join(|| data[..2].iter().sum::<u64>(), || data[2..].iter().sum::<u64>());
        assert_eq!(s1 + s2, 10);
    }

    #[test]
    fn nested_joins_do_not_deadlock() {
        let p = Pool::new(2);
        fn fib(p: &Pool, n: u64) -> u64 {
            if n < 2 {
                return n;
            }
            let (a, b) = p.join(|| fib(p, n - 1), || fib(p, n - 2));
            a + b
        }
        let n = sz(16, 8) as u64;
        let expect = [0, 1, 1, 2, 3, 5, 8, 13, 21, 34, 55, 89, 144, 233, 377, 610, 987][n as usize];
        assert_eq!(fib(&p, n), expect);
    }

    #[test]
    fn for_range_covers_every_index_once() {
        let p = Pool::new(4);
        let n = sz(100_000, 2_000);
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        p.for_range(0, n, 64, &|lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_pool_is_sequential_and_ordered() {
        let p = Pool::new(1);
        let (a, b) = p.join(|| 7, || 8);
        assert_eq!((a, b), (7, 8));
        // Deterministic mode runs chunks inline in program order.
        let order = Mutex::new(Vec::new());
        p.for_range(0, 10, 4, &|lo, hi| {
            order.lock().unwrap().push((lo, hi));
        });
        let chunks = order.into_inner().unwrap();
        for w in chunks.windows(2) {
            assert!(w[0].1 == w[1].0, "in-order inline chunks: {chunks:?}");
        }
    }

    #[test]
    fn deque_lifo_pop_fifo_steal() {
        let d = Deque::new();
        let mk = || Task::new(Box::new(|| {}));
        let (t0, t1, t2) = (mk(), mk(), mk());
        d.push(Arc::clone(&t0)).unwrap();
        d.push(Arc::clone(&t1)).unwrap();
        d.push(Arc::clone(&t2)).unwrap();
        // Steal takes the oldest…
        match d.steal() {
            Steal::Task(t) => assert!(Arc::ptr_eq(&t, &t0)),
            _ => panic!("expected steal of t0"),
        }
        // …pop takes the newest.
        assert!(Arc::ptr_eq(&d.pop().unwrap(), &t2));
        assert!(Arc::ptr_eq(&d.pop().unwrap(), &t1));
        assert!(d.pop().is_none());
        assert!(matches!(d.steal(), Steal::Empty));
    }

    #[test]
    fn deque_overflow_returns_task() {
        let d = Deque::new();
        for _ in 0..DEQUE_CAP {
            d.push(Task::new(Box::new(|| {}))).unwrap();
        }
        assert!(d.push(Task::new(Box::new(|| {}))).is_err());
        // Consuming one makes room again.
        assert!(d.pop().is_some());
        d.push(Task::new(Box::new(|| {}))).unwrap();
    }

    #[test]
    fn deque_drop_reclaims_unconsumed_tasks() {
        // Drop with items still queued must not leak (exercised under miri).
        let d = Deque::new();
        for _ in 0..10 {
            d.push(Task::new(Box::new(|| {}))).unwrap();
        }
        drop(d);
    }

    #[test]
    fn deque_concurrent_steal_race_is_exactly_once() {
        let n = sz(20_000, 200);
        let nthieves = 3;
        let d = Arc::new(Deque::new());
        let counter = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let thieves: Vec<_> = (0..nthieves)
            .map(|_| {
                let d = Arc::clone(&d);
                let stop = Arc::clone(&stop);
                thread::spawn(move || {
                    let mut got = 0u64;
                    loop {
                        match d.steal() {
                            Steal::Task(t) => {
                                assert!(t.run(), "stolen task already claimed");
                                got += 1;
                            }
                            Steal::Retry => {}
                            Steal::Empty => {
                                if stop.load(Ordering::Acquire) {
                                    break;
                                }
                                thread::yield_now();
                            }
                        }
                    }
                    got
                })
            })
            .collect();
        // Owner interleaves pushes and pops.
        let mut owner_ran = 0u64;
        for i in 0..n {
            let c = Arc::clone(&counter);
            let t = Task::new(Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            }));
            if let Err(t) = d.push(t) {
                // Full (thieves stalled): run inline.
                assert!(t.run());
                owner_ran += 1;
            }
            if i % 3 == 0 {
                if let Some(t) = d.pop() {
                    assert!(t.run(), "popped task already claimed");
                    owner_ran += 1;
                }
            }
        }
        while let Some(t) = d.pop() {
            assert!(t.run());
            owner_ran += 1;
        }
        stop.store(true, Ordering::Release);
        let stolen: u64 = thieves.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(owner_ran + stolen, n as u64, "every task consumed exactly once");
        assert_eq!(counter.load(Ordering::Relaxed), n as u64, "every task ran exactly once");
    }

    #[test]
    fn panicking_closures_propagate_and_pool_survives() {
        let p = Pool::new(4);
        // Panic in the forked side: must reach the joiner, not hang it or
        // kill a worker.
        let r = catch_unwind(AssertUnwindSafe(|| {
            p.join(|| 1u32, || -> u32 { panic!("boom-b") });
        }));
        assert!(r.is_err());
        // Panic in the inline side: must wait for b (stack borrows!) and
        // then resume.
        let r = catch_unwind(AssertUnwindSafe(|| {
            p.join(|| -> u32 { panic!("boom-a") }, || 2u32);
        }));
        assert!(r.is_err());
        // The pool is still fully functional afterwards.
        let (a, b) = p.join(|| 3, || 4);
        assert_eq!((a, b), (3, 4));
        let n = sz(10_000, 200);
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        p.for_range(0, n, 64, &|lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn workers_park_and_unpark() {
        let p = Pool::new(4);
        // Give workers time to reach the parked state, then verify new work
        // still completes (i.e. unpark is not lost).
        if !cfg!(miri) {
            thread::sleep(std::time::Duration::from_millis(20));
        }
        for _ in 0..10 {
            let n = sz(10_000, 100);
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            p.for_range(0, n, 64, &|lo, hi| {
                for i in lo..hi {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn set_threads_swaps_global_pool_safely_mid_flight() {
        let _g = TEST_POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // An operation keeps the pool it started on alive and completes even
        // if the global is swapped underneath it.
        let before = global();
        let h = thread::spawn(move || {
            let n = sz(50_000, 500);
            let total = AtomicU64::new(0);
            before.for_range(0, n, 128, &|lo, hi| {
                let mut local = 0u64;
                for i in lo..hi {
                    local += i as u64;
                }
                total.fetch_add(local, Ordering::Relaxed);
            });
            total.load(Ordering::Relaxed)
        });
        set_threads(3);
        assert_eq!(num_threads(), 3);
        set_threads(2);
        assert_eq!(num_threads(), 2);
        let n = sz(50_000, 500) as u64;
        assert_eq!(h.join().unwrap(), n * (n - 1) / 2);
        set_threads(1);
        assert_eq!(num_threads(), 1);
        set_threads(2);
        assert_eq!(num_threads(), 2);
    }
}
