//! Fork-join thread pool with help-first joins.
//!
//! Design: a global FIFO injector queue guarded by a mutex plus a condvar.
//! `join(a, b)` pushes `b` as a claimable task, runs `a` inline, then either
//! claims and runs `b` itself or *helps* (executes other queued tasks) until
//! `b` completes. Help-first joining makes nested fork-join (the recursive
//! kd-tree builds in this crate) deadlock-free with a bounded worker count.
//!
//! This is deliberately simple (single shared queue, no per-worker deques):
//! the algorithms in this crate fork at coarse grains, so queue contention is
//! negligible relative to the work per task (verified in §Perf of
//! EXPERIMENTS.md).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread;

use once_cell::sync::Lazy;

/// A unit of queued work. The closure is type-erased and lifetime-erased;
/// safety relies on `join` not returning until the task has run (see the
/// `Safety` note in [`Pool::join`]).
struct Task {
    func: Mutex<Option<Box<dyn FnOnce() + Send + 'static>>>,
    done: AtomicBool,
}

impl Task {
    fn new(f: Box<dyn FnOnce() + Send + 'static>) -> Arc<Self> {
        Arc::new(Task { func: Mutex::new(Some(f)), done: AtomicBool::new(false) })
    }

    /// Attempt to claim and run the task. Returns true if this call ran it.
    fn run(&self) -> bool {
        let f = self.func.lock().unwrap().take();
        match f {
            Some(f) => {
                f();
                self.done.store(true, Ordering::Release);
                true
            }
            None => false,
        }
    }

    fn is_done(&self) -> bool {
        self.done.load(Ordering::Acquire)
    }
}

struct Shared {
    queue: Mutex<VecDeque<Arc<Task>>>,
    cond: Condvar,
    shutdown: AtomicBool,
}

/// A fork-join thread pool. See module docs.
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
    threads: usize,
}

impl Pool {
    /// Create a pool with `threads` total parallelism (including the caller).
    /// `threads == 1` means fully sequential: no worker threads are spawned
    /// and `join` runs both closures inline.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        // The caller participates, so spawn threads-1 workers.
        let handles = (1..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("parlay-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn worker")
            })
            .collect();
        Pool { shared, handles, threads }
    }

    /// Total parallelism of this pool (worker threads + caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn push(&self, t: Arc<Task>) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(t);
        drop(q);
        self.shared.cond.notify_one();
    }

    fn try_pop(&self) -> Option<Arc<Task>> {
        self.shared.queue.lock().unwrap().pop_front()
    }

    /// Run `a` and `b`, potentially in parallel. Both have completed when
    /// this returns.
    ///
    /// # Safety discussion
    /// The closures may borrow from the caller's stack (they are not
    /// `'static`). This is sound for the same reason `std::thread::scope` is:
    /// `join` does not return until `b` has finished executing, so no borrow
    /// outlives its referent. The lifetime erasure below is confined to this
    /// function.
    pub fn join<'a, RA, RB>(
        &self,
        a: impl FnOnce() -> RA + Send + 'a,
        b: impl FnOnce() -> RB + Send + 'a,
    ) -> (RA, RB)
    where
        RA: Send + 'a,
        RB: Send + 'a,
    {
        if self.threads == 1 {
            return (a(), b());
        }
        let mut rb: Option<RB> = None;
        // Raw pointer (not a borrow) so `rb` stays movable after the task
        // finishes; Send-wrapped for the closure.
        struct SendPtr<T>(*mut T);
        unsafe impl<T> Send for SendPtr<T> {}
        let rb_ptr = SendPtr(&mut rb as *mut Option<RB>);
        let bf: Box<dyn FnOnce() + Send + 'a> = Box::new(move || {
            let rb_ptr = rb_ptr;
            // SAFETY: `rb` outlives the task (join blocks until done).
            unsafe {
                *rb_ptr.0 = Some(b());
            }
        });
        // SAFETY: `task` is fully executed (or executed by us below) before
        // `join` returns; all captured borrows live at least that long
        // because we do not return until `task.is_done()`.
        let bf: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(bf) };
        let task = Task::new(bf);
        self.push(Arc::clone(&task));
        let ra = a();
        // Try to run b ourselves; if a worker already claimed it, help with
        // other tasks until it completes.
        if !task.run() {
            while !task.is_done() {
                if let Some(other) = self.try_pop() {
                    other.run();
                } else {
                    thread::yield_now();
                }
            }
        }
        (ra, rb.expect("join: task b did not produce a result"))
    }

    /// Recursive binary split of `[lo, hi)` down to `grain`-sized chunks,
    /// each processed by `f(chunk_lo, chunk_hi)`.
    pub fn for_range<'a, F>(&self, lo: usize, hi: usize, grain: usize, f: &F)
    where
        F: Fn(usize, usize) + Sync + 'a,
    {
        debug_assert!(grain >= 1);
        if hi <= lo {
            return;
        }
        if self.threads == 1 || hi - lo <= grain {
            f(lo, hi);
            return;
        }
        let mid = lo + (hi - lo) / 2;
        self.join(|| self.for_range(lo, mid, grain, f), || self.for_range(mid, hi, grain, f));
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cond.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let task = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if sh.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = sh.cond.wait(q).unwrap();
            }
        };
        task.run();
    }
}

// ---------------------------------------------------------------------------
// Global pool management
// ---------------------------------------------------------------------------

static GLOBAL: Lazy<RwLock<Arc<Pool>>> = Lazy::new(|| RwLock::new(Arc::new(Pool::new(default_threads()))));
static OVERRIDE_THREADS: AtomicUsize = AtomicUsize::new(0);

fn default_threads() -> usize {
    let ov = OVERRIDE_THREADS.load(Ordering::Relaxed);
    if ov > 0 {
        return ov;
    }
    if let Ok(v) = std::env::var("PARCLUSTER_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The global pool used by all `parlay::ops` entry points.
pub fn global() -> Arc<Pool> {
    Arc::clone(&GLOBAL.read().unwrap())
}

/// Replace the global pool with one of `t` threads. Used by the thread
/// scalability benches (Figure 4b). Must not be called while parallel work is
/// in flight.
pub fn set_threads(t: usize) {
    OVERRIDE_THREADS.store(t.max(1), Ordering::Relaxed);
    let mut g = GLOBAL.write().unwrap();
    *g = Arc::new(Pool::new(t.max(1)));
}

/// Current global parallelism.
pub fn num_threads() -> usize {
    global().threads()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn join_returns_both_results() {
        let p = Pool::new(4);
        let (a, b) = p.join(|| 1 + 1, || "two");
        assert_eq!(a, 2);
        assert_eq!(b, "two");
    }

    #[test]
    fn join_borrows_stack_data() {
        let p = Pool::new(4);
        let data = vec![1u64, 2, 3, 4];
        let (s1, s2) = p.join(|| data[..2].iter().sum::<u64>(), || data[2..].iter().sum::<u64>());
        assert_eq!(s1 + s2, 10);
    }

    #[test]
    fn nested_joins_do_not_deadlock() {
        let p = Pool::new(2);
        fn fib(p: &Pool, n: u64) -> u64 {
            if n < 2 {
                return n;
            }
            let (a, b) = p.join(|| fib(p, n - 1), || fib(p, n - 2));
            a + b
        }
        assert_eq!(fib(&p, 16), 987);
    }

    #[test]
    fn for_range_covers_every_index_once() {
        let p = Pool::new(4);
        let n = 100_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        p.for_range(0, n, 1024, &|lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_pool_is_sequential() {
        let p = Pool::new(1);
        let (a, b) = p.join(|| 7, || 8);
        assert_eq!((a, b), (7, 8));
        let mut acc = 0usize;
        // for_range with threads=1 runs inline, so a mutable capture is fine
        // through a cell.
        let cell = std::cell::Cell::new(&mut acc);
        let _ = cell; // (illustrative; real sequential use goes through ops::)
        p.for_range(0, 10, 4, &|lo, hi| {
            assert!(lo < hi);
        });
    }

    #[test]
    fn set_threads_swaps_global_pool() {
        set_threads(3);
        assert_eq!(num_threads(), 3);
        set_threads(1);
        assert_eq!(num_threads(), 1);
        set_threads(2);
        assert_eq!(num_threads(), 2);
    }
}
