//! Core parallel operations: loops, map, reduce, scan, pack, and the
//! paper's `WRITE-MIN` priority concurrent write [60].

use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, Ordering};

use super::pool;

/// Tasks the eager binary splitter aims to create per worker: enough slack
/// for the work-stealing scheduler to balance uneven chunks, few enough that
/// fork overhead stays negligible.
const TASKS_PER_THREAD: usize = 8;

/// Floor below which splitting further costs more than it balances, for
/// cheap per-index bodies. Loops with expensive bodies (tree queries) pass an
/// explicit finer grain instead.
const MIN_GRAIN: usize = 256;

/// Automatic granularity: the chunk size the splitter stops at, tuned from
/// the pool's thread count. `threads == 1` collapses to one sequential chunk.
/// Tuned in §Perf (EXPERIMENTS.md).
pub fn auto_grain(n: usize, threads: usize) -> usize {
    if threads <= 1 {
        return n.max(1);
    }
    (n / (TASKS_PER_THREAD * threads)).max(MIN_GRAIN).min(n.max(1))
}

/// Parallel for over `0..n`; grain auto-tuned from the pool's thread count.
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    par_for_grained(n, 0, f)
}

/// Parallel for over `0..n` with an explicit grain size — the default path
/// every loop entry point funnels into. `grain == 0` means auto-tune from
/// `num_threads` (see [`auto_grain`]). The split schedule is eager (forks are
/// unconditional down to the grain), so for a given grain the chunk
/// boundaries do not depend on stealing or on how many workers show up.
/// An **auto** grain, however, is derived from the configured thread count,
/// so its chunk boundaries change with `set_threads`: callers whose output
/// depends on chunk-local evaluation order (e.g. a float reduction) must
/// pass an explicit grain; per-index-pure loops (every caller in this crate)
/// are unaffected.
pub fn par_for_grained<F: Fn(usize) + Sync>(n: usize, grain: usize, f: F) {
    let p = pool::global();
    let grain = if grain == 0 { auto_grain(n, p.threads()) } else { grain };
    p.for_range(0, n, grain.max(1), &|lo, hi| {
        for i in lo..hi {
            f(i);
        }
    });
}

/// Parallel chunked for: `f(lo, hi)` is called on disjoint chunks covering
/// `0..n`. Lets callers hoist per-chunk state (e.g. reused query stacks).
/// `grain == 0` auto-tunes.
pub fn par_chunks<F: Fn(usize, usize) + Sync>(n: usize, grain: usize, f: F) {
    let p = pool::global();
    let grain = if grain == 0 { auto_grain(n, p.threads()) } else { grain };
    p.for_range(0, n, grain.max(1), &f);
}

/// Parallel map `0..n -> Vec<T>`; grain auto-tuned.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    par_map_grained(n, 0, f)
}

/// Parallel map with an explicit grain (`0` = auto). Query-heavy loops (kd
/// traversals, priority-NN) pass a finer grain than [`auto_grain`]'s default:
/// their per-index cost is large and skewed, so smaller chunks give the
/// stealer something to balance.
pub fn par_map_grained<T: Send, F: Fn(usize) -> T + Sync>(n: usize, grain: usize, f: F) -> Vec<T> {
    let mut out: Vec<MaybeUninit<T>> = Vec::with_capacity(n);
    // SAFETY: every slot in 0..n is written exactly once below before we
    // assume initialization (for_range covers 0..n with disjoint chunks).
    #[allow(clippy::uninit_vec)]
    unsafe {
        out.set_len(n);
    }
    {
        let slots = out.as_mut_ptr() as usize;
        par_for_grained(n, grain, |i| {
            let p = slots as *mut MaybeUninit<T>;
            // SAFETY: disjoint indices; each written once.
            unsafe {
                (*p.add(i)).write(f(i));
            }
        });
    }
    // SAFETY: all n slots initialized.
    unsafe { std::mem::transmute::<Vec<MaybeUninit<T>>, Vec<T>>(out) }
}

/// Parallel reduce of `map(0) ⊕ map(1) ⊕ ... ⊕ map(n-1)` with identity `id`.
/// `combine` must be associative.
pub fn par_reduce<T, M, C>(n: usize, id: T, map: M, combine: C) -> T
where
    T: Send + Sync + Clone,
    M: Fn(usize) -> T + Sync,
    C: Fn(T, T) -> T + Sync + Send,
{
    let p = pool::global();
    let grain = auto_grain(n, p.threads());
    let nchunks = n.div_ceil(grain.max(1)).max(1);
    // Grain 1: nchunks is a few heavy items; the auto grain's floor would
    // collapse them into one sequential task.
    let partials: Vec<T> = par_map_grained(nchunks, 1, |c| {
        let lo = c * grain;
        let hi = ((c + 1) * grain).min(n);
        let mut acc = id.clone();
        for i in lo..hi {
            acc = combine(acc, map(i));
        }
        acc
    });
    let mut acc = id;
    for x in partials {
        acc = combine(acc, x);
    }
    acc
}

/// Parallel exclusive prefix sum over `vals`. Returns the prefix array
/// (`out[i] = Σ_{j<i} vals[j]`) and the total sum.
pub fn par_scan_add(vals: &[usize]) -> (Vec<usize>, usize) {
    let n = vals.len();
    if n == 0 {
        return (Vec::new(), 0);
    }
    let p = pool::global();
    let grain = auto_grain(n, p.threads());
    let nchunks = n.div_ceil(grain);
    // Pass 1: per-chunk sums. Grain 1 for the same reason as par_reduce —
    // nchunks heavy items must not collapse to one sequential task.
    let sums: Vec<usize> = par_map_grained(nchunks, 1, |c| {
        let lo = c * grain;
        let hi = ((c + 1) * grain).min(n);
        vals[lo..hi].iter().sum()
    });
    // Sequential scan over chunk sums (nchunks is small).
    let mut offsets = vec![0usize; nchunks];
    let mut total = 0usize;
    for c in 0..nchunks {
        offsets[c] = total;
        total += sums[c];
    }
    // Pass 2: local scans with offsets.
    let mut out: Vec<MaybeUninit<usize>> = Vec::with_capacity(n);
    // SAFETY: every slot in 0..n is written exactly once below before the
    // transmute assumes initialization (chunks partition 0..n).
    #[allow(clippy::uninit_vec)]
    unsafe {
        out.set_len(n);
    }
    let base = out.as_mut_ptr() as usize;
    par_for_grained(nchunks, 1, |c| {
        let lo = c * grain;
        let hi = ((c + 1) * grain).min(n);
        let mut acc = offsets[c];
        let ptr = base as *mut MaybeUninit<usize>;
        for i in lo..hi {
            // SAFETY: disjoint chunks, each index written once.
            unsafe {
                (*ptr.add(i)).write(acc);
            }
            acc += vals[i];
        }
    });
    // SAFETY: all n slots initialized by the pass above; MaybeUninit<usize>
    // and usize share layout.
    let out = unsafe { std::mem::transmute::<Vec<MaybeUninit<usize>>, Vec<usize>>(out) };
    (out, total)
}

/// Parallel filter: keep `i` where `keep(i)`, mapping kept indices through
/// `f`. Stable (output preserves index order).
pub fn par_filter<T, K, F>(n: usize, keep: K, f: F) -> Vec<T>
where
    T: Send,
    K: Fn(usize) -> bool + Sync,
    F: Fn(usize) -> T + Sync,
{
    let flags: Vec<usize> = par_map(n, |i| usize::from(keep(i)));
    let (pos, total) = par_scan_add(&flags);
    let mut out: Vec<MaybeUninit<T>> = Vec::with_capacity(total);
    // SAFETY: the scan gives every kept index a unique slot in 0..total and
    // the loop below writes each exactly once before the transmute.
    #[allow(clippy::uninit_vec)]
    unsafe {
        out.set_len(total);
    }
    let base = out.as_mut_ptr() as usize;
    par_for(n, |i| {
        if flags[i] == 1 {
            let ptr = base as *mut MaybeUninit<T>;
            // SAFETY: scan positions are unique for kept elements.
            unsafe {
                (*ptr.add(pos[i])).write(f(i));
            }
        }
    });
    // SAFETY: all `total` slots initialized above; MaybeUninit<T> and T
    // share layout.
    unsafe { std::mem::transmute::<Vec<MaybeUninit<T>>, Vec<T>>(out) }
}

// ---------------------------------------------------------------------------
// WRITE-MIN priority concurrent writes [60]
// ---------------------------------------------------------------------------

/// Atomic minimum over non-negative `f64` values (`WRITE-MIN`).
///
/// Relies on the fact that for non-negative IEEE-754 doubles the bit pattern
/// ordering equals numeric ordering, so `fetch_min` on the raw bits is exact.
#[derive(Debug)]
pub struct WriteMinF64 {
    bits: AtomicU64,
}

impl WriteMinF64 {
    pub fn new() -> Self {
        WriteMinF64 { bits: AtomicU64::new(f64::INFINITY.to_bits()) }
    }

    /// Atomically `self = min(self, v)`. `v` must be non-negative (or +inf).
    #[inline]
    pub fn update(&self, v: f64) {
        debug_assert!(v >= 0.0);
        // relaxed: commutative min — any interleaving yields the same
        // final value; readers synchronize via the enclosing join.
        self.bits.fetch_min(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        // relaxed: read after the parallel phase's join edge.
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

impl Default for WriteMinF64 {
    fn default() -> Self {
        Self::new()
    }
}

/// Atomic `WRITE-MIN` over `(distance, id)` pairs, packed into one `u64`:
/// high 32 bits = monotone bits of the `f32`-rounded distance, low 32 bits =
/// id. Ordering is therefore (f32(dist), id) lexicographic — ties at f32
/// resolution are broken by smaller id, matching the paper's tie rule.
///
/// Call sites that need exact f64 comparisons (e.g. the Fenwick query's
/// O(log n)-way aggregation) use a sequential exact reduce instead; this type
/// is for high-fan-in concurrent writes where f32 key resolution suffices.
#[derive(Debug)]
pub struct WriteMinPair {
    bits: AtomicU64,
}

impl WriteMinPair {
    pub fn new() -> Self {
        WriteMinPair { bits: AtomicU64::new(u64::MAX) }
    }

    #[inline]
    fn pack(dist: f64, id: u32) -> u64 {
        let key = (dist as f32).to_bits(); // non-negative => monotone
        ((key as u64) << 32) | id as u64
    }

    /// Atomically keep the smallest `(dist, id)`.
    #[inline]
    pub fn update(&self, dist: f64, id: u32) {
        debug_assert!(dist >= 0.0);
        // relaxed: commutative min over packed (key, id) — order-free;
        // readers synchronize via the enclosing join.
        self.bits.fetch_min(Self::pack(dist, id), Ordering::Relaxed);
    }

    /// Returns `(dist, id)`, or `None` if never updated.
    pub fn get(&self) -> Option<(f32, u32)> {
        // relaxed: read after the parallel phase's join edge.
        let b = self.bits.load(Ordering::Relaxed);
        if b == u64::MAX {
            return None;
        }
        Some((f32::from_bits((b >> 32) as u32), (b & 0xFFFF_FFFF) as u32))
    }
}

impl Default for WriteMinPair {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let v = par_map(10_000, |i| i * i);
        assert_eq!(v.len(), 10_000);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn par_reduce_sum() {
        let n = 100_000usize;
        let s = par_reduce(n, 0u64, |i| i as u64, |a, b| a + b);
        assert_eq!(s, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn par_scan_matches_serial() {
        let vals: Vec<usize> = (0..5000).map(|i| (i * 7 + 3) % 11).collect();
        let (scan, total) = par_scan_add(&vals);
        let mut acc = 0;
        for i in 0..vals.len() {
            assert_eq!(scan[i], acc, "at {i}");
            acc += vals[i];
        }
        assert_eq!(total, acc);
    }

    #[test]
    fn par_scan_empty_and_one() {
        assert_eq!(par_scan_add(&[]), (vec![], 0));
        assert_eq!(par_scan_add(&[5]), (vec![0], 5));
    }

    #[test]
    fn par_filter_stable() {
        let v = par_filter(1000, |i| i % 3 == 0, |i| i);
        let expect: Vec<usize> = (0..1000).filter(|i| i % 3 == 0).collect();
        assert_eq!(v, expect);
    }

    #[test]
    fn write_min_f64_concurrent() {
        let wm = WriteMinF64::new();
        par_for(10_000, |i| {
            wm.update((i as f64 * 13.7) % 997.0);
        });
        let seq = (0..10_000).map(|i| (i as f64 * 13.7) % 997.0).fold(f64::INFINITY, f64::min);
        assert_eq!(wm.get(), seq);
    }

    #[test]
    fn write_min_pair_tie_breaks_by_id() {
        let wm = WriteMinPair::new();
        wm.update(1.5, 7);
        wm.update(1.5, 3);
        wm.update(2.0, 1);
        assert_eq!(wm.get(), Some((1.5, 3)));
    }

    #[test]
    fn write_min_pair_empty() {
        assert_eq!(WriteMinPair::new().get(), None);
    }
}
