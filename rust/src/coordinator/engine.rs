//! The unified execution-backend abstraction.
//!
//! The coordinator used to hard-code an `if xla / else tree` branch per
//! job; both backends now sit behind the [`Engine`] trait — Step 1
//! (`density`) and Step 2 (`dependents`) as separate calls so staged
//! sessions can cache each, with Step 3 (union-find linkage) always in Rust
//! on the caller's side. The [`super::Router`] hands out `Arc<dyn Engine>`
//! per resolved backend.

use std::sync::{Arc, Mutex, Weak};

use crate::dpc::{self, DensityAlgo, DepAlgo};
use crate::error::DpcError;
use crate::geom::{Dtype, PointSet, PointStore, Scalar};
use crate::runtime::engine::D_PAD;
use crate::runtime::{XlaDpcOutput, XlaService};

use super::job::PointsPayload;

/// Shape and algorithm choices of one clustering job — what an engine needs
/// for capability checks ([`Engine::supports`]) and per-job overrides.
#[derive(Clone, Copy, Debug)]
pub struct JobSpec {
    pub n: usize,
    pub d: usize,
    pub d_cut: f64,
    /// Coordinate precision of the payload (the payload is authoritative;
    /// [`JobSpec::from_payload`] derives this field from it).
    pub dtype: Dtype,
    /// Step-2 algorithm (tree backend only; brute-force backends ignore it).
    pub dep_algo: DepAlgo,
    /// Step-1 variant (tree backend only).
    pub density_algo: DensityAlgo,
}

impl JobSpec {
    pub fn new<S: Scalar>(pts: &PointStore<S>, d_cut: f64) -> Self {
        JobSpec {
            n: pts.len(),
            d: pts.dim(),
            d_cut,
            dtype: S::DTYPE,
            dep_algo: DepAlgo::Priority,
            density_algo: DensityAlgo::TreePruned,
        }
    }

    /// Spec for a queued payload (dtype taken from the payload's tag).
    pub fn from_payload(pts: &PointsPayload, d_cut: f64) -> Self {
        JobSpec {
            n: pts.len(),
            d: pts.dim(),
            d_cut,
            dtype: pts.dtype(),
            dep_algo: DepAlgo::Priority,
            density_algo: DensityAlgo::TreePruned,
        }
    }

    pub fn dep_algo(mut self, a: DepAlgo) -> Self {
        self.dep_algo = a;
        self
    }
}

/// An execution backend for Steps 1–2 of the DPC pipeline. Payloads are
/// precision-tagged; engines advertise which dtypes they take via
/// [`Engine::supports`] (the router falls back to the tree engine, which
/// takes both).
pub trait Engine: Send + Sync {
    fn name(&self) -> &'static str;

    /// Can this engine execute a job of the given shape?
    fn supports(&self, job: &JobSpec) -> bool;

    /// Step 1: ρ(x) for every point at radius `job.d_cut`.
    fn density(&self, pts: &PointsPayload, job: &JobSpec) -> Result<Vec<u32>, DpcError>;

    /// Step 2: λ(x) per point — `None` for points below `rho_min` and the
    /// global peak. Candidate sets are threshold-free (pass `rho_min = 0.0`
    /// for the full forest used by cached sessions).
    fn dependents(
        &self,
        pts: &PointsPayload,
        rho: &[u32],
        rho_min: f64,
        job: &JobSpec,
    ) -> Result<Vec<Option<u32>>, DpcError>;
}

/// The Rust tree engine: the paper's algorithm suite. Exact per precision,
/// any size, dimension, and dtype.
pub struct TreeEngine;

impl Engine for TreeEngine {
    fn name(&self) -> &'static str {
        "tree"
    }

    fn supports(&self, _job: &JobSpec) -> bool {
        true
    }

    fn density(&self, pts: &PointsPayload, job: &JobSpec) -> Result<Vec<u32>, DpcError> {
        Ok(match pts {
            PointsPayload::F32(p) => dpc::compute_density(p, job.d_cut, job.density_algo),
            PointsPayload::F64(p) => dpc::compute_density(p, job.d_cut, job.density_algo),
        })
    }

    fn dependents(
        &self,
        pts: &PointsPayload,
        rho: &[u32],
        rho_min: f64,
        job: &JobSpec,
    ) -> Result<Vec<Option<u32>>, DpcError> {
        Ok(match pts {
            PointsPayload::F32(p) => dpc::dep::compute_dependents(p, rho, rho_min, job.dep_algo),
            PointsPayload::F64(p) => dpc::dep::compute_dependents(p, rho, rho_min, job.dep_algo),
        })
    }
}

/// The AOT-compiled XLA brute-force engine, adapted to the trait.
///
/// One PJRT execution produces both ρ and λ; since the trait splits the
/// steps, the adapter memoizes recent (point set, radius) outputs so each
/// job's `density` → `dependents` sequence executes once — including when
/// several workers interleave jobs (one slot per in-flight point set, not a
/// single global slot). Each memo holds a `Weak` to its point set: the weak
/// count pins the allocation, so a pointer match can never be a recycled
/// address from a dropped job, and dead entries are pruned on insert.
pub struct XlaEngine {
    svc: Arc<XlaService>,
    memo: Mutex<Vec<Memo>>,
}

/// More concurrent XLA jobs than this re-execute instead of caching.
const MEMO_CAP: usize = 16;

struct Memo {
    pts: Weak<PointSet>,
    d_cut_bits: u64,
    out: XlaDpcOutput,
}

impl XlaEngine {
    pub fn new(svc: Arc<XlaService>) -> Self {
        XlaEngine { svc, memo: Mutex::new(Vec::new()) }
    }

    pub fn capacity(&self) -> usize {
        self.svc.capacity()
    }

    fn run_memo(&self, pts: &Arc<PointSet>, d_cut: f64) -> Result<XlaDpcOutput, DpcError> {
        let bits = d_cut.to_bits();
        {
            let memo = self.memo.lock().unwrap();
            if let Some(m) = memo
                .iter()
                .find(|m| std::ptr::eq(m.pts.as_ptr(), Arc::as_ptr(pts)) && m.d_cut_bits == bits)
            {
                return Ok(m.out.clone());
            }
        }
        let out = self
            .svc
            .run(Arc::clone(pts), d_cut)
            .map_err(|e| DpcError::Backend { engine: "xla".into(), message: e.to_string() })?;
        let mut memo = self.memo.lock().unwrap();
        memo.retain(|m| m.pts.strong_count() > 0);
        if memo.len() >= MEMO_CAP {
            memo.remove(0);
        }
        memo.push(Memo { pts: Arc::downgrade(pts), d_cut_bits: bits, out: out.clone() });
        Ok(out)
    }
}

/// Extract the f64 store an XLA job runs over. The router never sends f32
/// payloads here (`supports` gates on dtype), so the error is defensive.
fn xla_f64(pts: &PointsPayload) -> Result<&Arc<PointSet>, DpcError> {
    match pts {
        PointsPayload::F64(p) => Ok(p),
        PointsPayload::F32(_) => Err(DpcError::Backend {
            engine: "xla".into(),
            message: "f32 payloads route to the tree engine (the XLA memo keys on f64 stores)".into(),
        }),
    }
}

impl Engine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn supports(&self, job: &JobSpec) -> bool {
        job.n <= self.svc.capacity() && job.d <= D_PAD && job.dtype == Dtype::F64
    }

    fn density(&self, pts: &PointsPayload, job: &JobSpec) -> Result<Vec<u32>, DpcError> {
        Ok(self.run_memo(xla_f64(pts)?, job.d_cut)?.rho)
    }

    fn dependents(
        &self,
        pts: &PointsPayload,
        rho: &[u32],
        rho_min: f64,
        job: &JobSpec,
    ) -> Result<Vec<Option<u32>>, DpcError> {
        let out = self.run_memo(xla_f64(pts)?, job.d_cut)?;
        // Noise handling mirrors the tree engine: noise points get no λ.
        Ok(rho
            .iter()
            .zip(&out.dep)
            .map(|(&r, &d)| if (r as f64) < rho_min { None } else { d })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::DpcParams;
    use crate::prng::SplitMix64;
    use crate::proputil::gen_clustered_points;

    #[test]
    fn tree_engine_matches_direct_pipeline() {
        let mut rng = SplitMix64::new(77);
        let pts = Arc::new(gen_clustered_points(&mut rng, 300, 2, 3, 80.0, 2.0));
        let params = DpcParams { d_cut: 4.0, rho_min: 2.0, delta_min: 10.0, ..DpcParams::default() };
        let payload = PointsPayload::F64(Arc::clone(&pts));
        let spec = JobSpec::from_payload(&payload, params.d_cut).dep_algo(DepAlgo::Fenwick);
        assert_eq!(spec.dtype, Dtype::F64);
        let eng = TreeEngine;
        assert!(eng.supports(&spec));
        let rho = eng.density(&payload, &spec).unwrap();
        assert_eq!(rho, dpc::compute_density(&pts, params.d_cut, DensityAlgo::TreePruned));
        let dep = eng.dependents(&payload, &rho, params.rho_min, &spec).unwrap();
        assert_eq!(dep, dpc::dep::compute_dependents(&pts, &rho, params.rho_min, DepAlgo::Fenwick));
    }

    #[test]
    fn tree_engine_runs_f32_payloads() {
        let mut rng = SplitMix64::new(78);
        let pts64 = gen_clustered_points(&mut rng, 200, 2, 3, 60.0, 2.0);
        let pts = Arc::new(PointStore::<f32>::cast_from_f64(&pts64));
        let payload = PointsPayload::F32(Arc::clone(&pts));
        let spec = JobSpec::from_payload(&payload, 4.0);
        assert_eq!(spec.dtype, Dtype::F32);
        let eng = TreeEngine;
        assert!(eng.supports(&spec));
        let rho = eng.density(&payload, &spec).unwrap();
        assert_eq!(rho, dpc::compute_density(&pts, 4.0, DensityAlgo::TreePruned));
        let dep = eng.dependents(&payload, &rho, 0.0, &spec).unwrap();
        assert_eq!(dep, dpc::dep::compute_dependents(&pts, &rho, 0.0, DepAlgo::Priority));
    }
}
